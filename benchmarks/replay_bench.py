"""Parallel-in-time replay benchmarks: scan vs blocked vs per-tick rebuild.

One record per (family, T): the same T-tick replay log rebuilt three ways —

* ``sequential`` — the per-tick training scan (bitwise the train path;
  critical path T combine steps);
* ``scan`` — per-tick associative elements + ``lax.associative_scan``
  (critical path ceil(log2 T), but T (D, D) element materializations);
* ``blocked`` — the chunk-element kernels (kernels/rff_scan.py) compose Tc
  ticks per launch in VMEM at O(D^2)/tick rank-1 cost, then a short
  cross-chunk scan (critical path Tc + ceil(log2 nc), only nc (D, D)
  elements ever hit HBM).

Each mode column carries both the measurement (``us_per_rebuild``,
``ticks_per_s``) and the analytic model (``depth`` = critical-path combine
steps, ``element_bytes`` = f32 bytes of materialized elements) so the JSON
artifact records prediction AND observation: on CPU the depth model is a
proxy (no real parallel combine tree), on TPU/GPU it is the quantity the
schedule buys. The committed ``BENCH_replay.json`` is the CPU baseline —
regenerate with::

    PYTHONPATH=src python benchmarks/replay_bench.py --out BENCH_replay.json
    PYTHONPATH=src python benchmarks/replay_bench.py --tiny   # CI smoke

Without an explicit ``--out``, a ``--tiny`` run writes to /tmp so tiny
shapes can never overwrite the committed full-shape baseline.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import time

MODES = ("sequential", "scan", "blocked")


def _time(fn, iters: int = 5) -> float:
    import jax

    jax.block_until_ready(fn())  # compile
    jax.block_until_ready(fn())  # warm
    t0 = time.perf_counter()
    out = None
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def replay_models(tlen: int, chunk: int, dfeat: int) -> dict:
    """Analytic depth/traffic columns for the three replay schedules.

    ``depth`` counts combine steps on the critical path; ``element_bytes``
    counts f32 bytes of (D, D)+(D,) elements materialized outside VMEM
    (sequential materializes none — its state stays a (D,) / (D, D)
    running value; scan materializes one element per tick; blocked only
    one per chunk)."""
    nc = -(-tlen // chunk)
    ebytes = 4 * (dfeat * dfeat + dfeat)
    return {
        "sequential_depth": tlen,
        "scan_depth": max(1, math.ceil(math.log2(tlen))),
        "blocked_depth": chunk + max(1, math.ceil(math.log2(max(nc, 2)))),
        "sequential_element_bytes": 0,
        "scan_element_bytes": tlen * ebytes,
        "blocked_element_bytes": nc * ebytes,
    }


def bench_replay(
    ts=(64, 256, 1024, 4096),
    d: int = 4,
    dfeat: int = 64,
    iters: int = 5,
) -> list:
    """Rebuild-latency sweep over log length T for both replayable
    families. KLMS pure-scan at T=4096, D=64 materializes a 64 MiB
    (T, D, D) element buffer — the point of the blocked schedule."""
    import jax
    import jax.numpy as jnp

    from repro.core.learner import klms_learner, krls_learner
    from repro.core.rff import sample_rff
    from repro.kernels.chunking import default_chunk_t

    rff = sample_rff(jax.random.PRNGKey(0), d, dfeat, 1.0)
    learners = {
        "klms": klms_learner(rff, 0.2),
        "krls": krls_learner(rff, lam=0.1, beta=0.9995),
    }
    records = []
    for family, lrn in learners.items():
        for tlen in ts:
            kx, ky = jax.random.split(jax.random.PRNGKey(tlen))
            xs = jax.random.normal(kx, (tlen, d))
            ys = jax.random.normal(ky, (tlen,))
            chunk = min(
                tlen,
                default_chunk_t(1, dfeat, xs.dtype, input_dim=d,
                                elements=True),
            )
            rec = {
                "bench": f"replay_{family}",
                "family": family,
                "tlen": tlen,
                "d": d,
                "dfeat": dfeat,
                "chunk": chunk,
                **replay_models(tlen, chunk, dfeat),
            }
            for mode in MODES:
                fn = jax.jit(
                    lambda a, b, m=mode: lrn.rebuild(a, b, mode=m,
                                                     chunk=chunk)
                )
                us = _time(lambda: fn(xs, ys), iters) * 1e6
                rec[f"{mode}_us_per_rebuild"] = us
                rec[f"{mode}_ticks_per_s"] = tlen / (us / 1e6)
            rec["scan_speedup_vs_sequential"] = (
                rec["sequential_us_per_rebuild"] / rec["scan_us_per_rebuild"]
            )
            rec["blocked_speedup_vs_sequential"] = (
                rec["sequential_us_per_rebuild"]
                / rec["blocked_us_per_rebuild"]
            )
            records.append(rec)
            print(f"# {json.dumps(rec)}", flush=True)
    return records


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true", help="CI smoke shapes")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.out is None:
        # Tiny runs must not clobber the committed full-shape baseline.
        args.out = (
            "/tmp/BENCH_replay.json" if args.tiny else "BENCH_replay.json"
        )

    # Tiny keeps the full-shape dfeat so the tlen=64 record joins exactly
    # against the committed baseline in scripts/check_bench_regress.py.
    kw = (
        dict(ts=(16, 64), dfeat=64, iters=2)
        if args.tiny
        else dict(ts=(64, 256, 1024, 4096), dfeat=64, iters=5)
    )
    records = bench_replay(**kw)

    import jax

    payload = {
        "suite": "replay_bench",
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "tiny": args.tiny,
        "records": records,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    json.dump(payload, sys.stdout, indent=2)
    print()


if __name__ == "__main__":
    main()
