"""Feature-quality harness: families x D sweep for the pluggable feature
subsystem (repro.features).

Two measurements per (family, D) cell:

* Kernel-approximation error against the exact Gaussian kernel on sampled
  input pairs — sup and MSE of ``z(x).z(y) - kappa(x, y)``. Monte-Carlo
  families are additionally averaged over seeds with the across-seed spread
  recorded (deterministic families have zero spread by construction).
* Steady-state MSE of RFF-KLMS on the paper's chaotic-series task (§5.3),
  averaged over the final quarter of the stream — the end-to-end quantity
  the accuracy-vs-D trade actually buys.

The sweep is the evidence for the No-Trick claim: deterministic GQ (and
QMC) reach the Monte-Carlo error floor at equal or smaller D with zero seed
variance. ``derived`` per record = the smallest swept D at which each
family's kernel RMSE beats iid RFF at the largest swept D.

Run as a script to emit ``BENCH_features.json``:

    PYTHONPATH=src python benchmarks/features_bench.py --out BENCH_features.json
    PYTHONPATH=src python benchmarks/features_bench.py --tiny   # CI smoke

Without an explicit ``--out``, a ``--tiny`` run writes to /tmp so tiny
shapes can never overwrite the committed full-shape baseline at the repo
root.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


FAMILY_ORDER = ("rff", "orf", "qmc", "gq", "taylor")


def _build(family, d, dfeat, sigma, seed=0):
    import jax

    from repro.features import make_feature_map

    return make_feature_map(
        family, d, dfeat, sigma, key=jax.random.PRNGKey(seed)
    )


def kernel_error_cell(
    family: str,
    d: int,
    dfeat: int,
    sigma: float,
    num_pairs: int = 512,
    num_seeds: int = 4,
) -> dict:
    """Sup/MSE of the kernel estimate vs the exact Gaussian kernel.

    Monte-Carlo families average over ``num_seeds`` independent maps and
    record the across-seed RMSE spread; deterministic families run once
    (their spread is identically zero — that IS the point).
    """
    import jax
    import jax.numpy as jnp

    from repro.core.rff import gaussian_kernel
    from repro.features import featurize

    kx, ky = jax.random.split(jax.random.PRNGKey(1234))
    x = jax.random.normal(kx, (num_pairs, d))
    y = jax.random.normal(ky, (num_pairs, d))
    exact = gaussian_kernel(x, y, sigma)

    fm0 = _build(family, d, dfeat, sigma, seed=0)
    seeds = range(num_seeds) if not fm0.deterministic else range(1)
    rmses, sups = [], []
    for seed in seeds:
        fm = _build(family, d, dfeat, sigma, seed=seed)
        est = jnp.sum(featurize(fm, x) * featurize(fm, y), axis=-1)
        err = est - exact
        rmses.append(float(jnp.sqrt(jnp.mean(err**2))))
        sups.append(float(jnp.max(jnp.abs(err))))
    mean_rmse = sum(rmses) / len(rmses)
    spread = (
        max(rmses) - min(rmses) if len(rmses) > 1 else 0.0
    )
    return {
        "kernel_rmse": mean_rmse,
        "kernel_sup": sum(sups) / len(sups),
        "kernel_rmse_seed_spread": spread,
        "actual_num_features": fm0.num_features,
        "deterministic": bool(fm0.deterministic),
    }


def steady_state_cell(
    family: str,
    dfeat: int,
    sigma: float,
    num_samples: int,
    mu: float = 0.5,
) -> dict:
    """Steady-state KLMS MSE on the chaotic-series task (paper §5.3).

    The task fixes the input dimension at 2 (the ``(u_{n-1}, d_{n-1})``
    regressor), so this cell builds ITS OWN map at d=2 — a different map
    from the kernel-error cell's swept-d one. Its identity is recorded in
    ``steady_input_dim`` / ``steady_actual_num_features`` so a record never
    reads as one map's quality profile when two maps were measured.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.klms import rff_klms_run
    from repro.data.synthetic import gen_chaotic1
    from repro.features import make_feature_map

    xs, ys = gen_chaotic1(jax.random.PRNGKey(42), num_samples=num_samples)
    fm = make_feature_map(
        family, 2, dfeat, sigma, key=jax.random.PRNGKey(7)
    )
    t0 = time.perf_counter()
    _, out = jax.jit(
        lambda a, b: rff_klms_run(fm, a, b, mu)
    )(xs, ys)
    err = jax.block_until_ready(out.error)
    wall = time.perf_counter() - t0
    tail = err[-num_samples // 4 :]
    return {
        "steady_state_mse": float(jnp.mean(tail**2)),
        "steady_input_dim": fm.input_dim,
        "steady_actual_num_features": fm.num_features,
        "run_wall_s": wall,
    }


def bench_feature_quality(
    d: int = 3,
    sigma: float = 1.5,
    d_sweep=(64, 128, 256, 512),
    num_pairs: int = 512,
    num_samples: int = 2000,
) -> list[dict]:
    """The families x D sweep; one record per (family, D) cell."""
    records = []
    for family in FAMILY_ORDER:
        for dfeat in d_sweep:
            cell = {"family": family, "num_features": dfeat}
            cell.update(
                kernel_error_cell(family, d, dfeat, sigma, num_pairs)
            )
            cell.update(
                steady_state_cell(family, dfeat, sigma, num_samples)
            )
            records.append(cell)
            print(
                f"# {family:7s} D={dfeat:5d} (actual {cell['actual_num_features']:5d}) "
                f"kernel_rmse={cell['kernel_rmse']:.5f} "
                f"sup={cell['kernel_sup']:.5f} "
                f"spread={cell['kernel_rmse_seed_spread']:.5f} "
                f"klms_mse={cell['steady_state_mse']:.5f}",
                file=sys.stderr,
            )
    # derived summary: smallest D per family beating iid RFF at max D.
    rff_floor = min(
        r["kernel_rmse"] for r in records if r["family"] == "rff"
    )
    for family in FAMILY_ORDER:
        cells = [r for r in records if r["family"] == family]
        beating = [
            c["num_features"] for c in cells if c["kernel_rmse"] <= rff_floor
        ]
        for c in cells:
            c["d_matching_rff_floor"] = min(beating) if beating else None
            c["rff_floor_rmse"] = rff_floor
    return records


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true", help="CI smoke shapes")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.out is None:
        args.out = (
            "/tmp/BENCH_features.json" if args.tiny else "BENCH_features.json"
        )

    import jax

    if args.tiny:
        records = bench_feature_quality(
            d=2, d_sweep=(32, 64), num_pairs=128, num_samples=400
        )
    else:
        records = bench_feature_quality()

    payload = {
        "suite": "run_features",
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "full": not args.tiny,
        "records": [
            {
                "bench": f"features_{r['family']}_D{r['num_features']}",
                "us_per_call": r["run_wall_s"] * 1e6,
                "derived": r["kernel_rmse"],
                "detail": r,
            }
            for r in records
        ],
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    json.dump(payload, sys.stdout, indent=2)
    print()


if __name__ == "__main__":
    main()
