"""Recovery-latency harness: what does self-healing cost on the write path?

Two questions, one per record kind:

* ``recovery_repair`` — from the faulted flush's start, how long until the
  probe fold *detects* the corruption (``detect_us``: chunk train + in-jit
  tap + threshold fold), and from detection, how long until the ladder's
  repair is verified and published (``repair_us``)? Repairs replay the
  tenant's log, so the grid sweeps ``log_len`` for the rebuild action and
  covers every ladder rung (resymmetrize / rebuild / reset) across the
  learner families. Each config runs the episode twice where the fault
  allows it: the first pass pays the rebuild jit compile
  (``cold_repair_us``), the recorded ``repair_us`` is the warm second
  episode — the steady-state cost a long-running server sees.
* ``ckpt_roundtrip`` — wall cost of durability: ``save_us`` for an atomic
  generation write (serialize + fsync + rename), ``restore_us`` for
  loading it into a fresh identically-configured server, ``bytes`` on
  disk, and ``state_bitwise`` confirming the round-trip loses nothing.

Run as a script to emit ``BENCH_recovery.json``:

    PYTHONPATH=src python benchmarks/recovery_bench.py --out BENCH_recovery.json
    PYTHONPATH=src python benchmarks/recovery_bench.py --tiny   # CI smoke

Without an explicit ``--out``, a ``--tiny`` run writes to /tmp so tiny
grids can never overwrite the committed full-shape baseline.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import tempfile
import time

import numpy as np

_KW = {
    "klms": dict(mu=0.3),
    "nklms": dict(mu=0.3),
    "krls": dict(lam=0.1, beta=0.99),
    "qklms": dict(sigma=1.0, mu=0.3, quant_eps=0.1, capacity=32),
    "ald": dict(sigma=1.0, nu=5e-4, capacity=32),
}

# (learner, fault kind, target-tenant log length). nan_state lands on the
# rebuild rung, log_corrupt forces the reset fallthrough, asym_pmat on an
# RLS bank exercises the cheap resymmetrize rung.
REPAIR_GRID = (
    ("klms", "nan_state", 32),
    ("klms", "nan_state", 128),
    ("klms", "nan_state", 512),
    ("nklms", "nan_state", 128),
    ("krls", "nan_state", 128),
    ("qklms", "nan_state", 128),
    ("ald", "nan_state", 128),
    ("klms", "log_corrupt", 128),
    ("krls", "asym_pmat", 128),
)
TINY_REPAIR_GRID = (
    ("klms", "nan_state", 32),
    ("klms", "log_corrupt", 128),
    ("krls", "asym_pmat", 128),
)

CKPT_GRID = (("klms", 8), ("klms", 32), ("krls", 8))
TINY_CKPT_GRID = (("klms", 8),)

_D, _DFEAT = 8, 64
_TENANT = 1


def _rff():
    import jax

    from repro.core.rff import sample_rff

    return sample_rff(jax.random.PRNGKey(0), _D, _DFEAT, 1.0)


def _feed(srv, rng, counts):
    """Interleaved per-tenant arrival counts, then drain."""
    order = np.concatenate(
        [np.full(n, t) for t, n in counts.items()]
    )
    rng.shuffle(order)
    for t in order:
        srv.submit(
            int(t),
            rng.standard_normal(_D).astype(np.float32),
            float(rng.standard_normal()),
        )
    srv.drain()


def _healthy(srv) -> bool:
    import jax

    if srv.recovery.quarantined:
        return False
    return all(
        bool(np.isfinite(np.asarray(leaf)).all())
        for leaf in jax.tree.leaves(srv.queue.state)
    )


def bench_repair(learner: str, kind: str, log_len: int) -> dict:
    from repro.obs.faults import Fault, FaultInjector, FaultPlan
    from repro.serve import make_server

    srv = make_server(
        learner, feature_map=_rff(), bank=4, chunk=8, policy="lru",
        log_capacity=max(1024, 2 * log_len), recovery=True,
        **_KW[learner],
    )
    rng = np.random.default_rng(0)
    _feed(
        srv, rng,
        {0: log_len // 4, _TENANT: log_len, 2: log_len // 4},
    )

    fired: list[float] = []
    srv.probe.subscribe(lambda ev: fired.append(time.perf_counter()))

    # log_corrupt clears the target's log (reset repair), so only its
    # first episode is representative; the others run twice — episode 1
    # pays the per-log-length rebuild compile, episode 2 is steady state.
    episodes = 1 if kind == "log_corrupt" else 2
    timings = []
    for _ in range(episodes):
        fired.clear()
        inj = FaultInjector(
            srv, FaultPlan([Fault(kind, tenant=_TENANT, at_flush=0)])
        ).attach()
        # Non-target arrivals drive the faulted flush so the corruption
        # survives to the tap (trained rows get overwritten).
        for t in (0, 2, 0, 2, 0, 2, 0, 2):
            srv.submit(
                t,
                rng.standard_normal(_D).astype(np.float32),
                float(rng.standard_normal()),
            )
        t0 = time.perf_counter()
        srv.flush()
        t1 = time.perf_counter()
        srv.drain()
        inj.detach()
        assert fired, f"{learner}/{kind}: fault was never detected"
        timings.append((fired[0] - t0, t1 - fired[0]))

    detect_us, repair_us = (v * 1e6 for v in timings[-1])
    return {
        "bench": "recovery_repair",
        "learner": learner,
        "fault": kind,
        "action": srv.recovery.history[-1]["action"],
        "log_len": log_len,
        "detect_us": round(detect_us, 1),
        "repair_us": round(repair_us, 1),
        "cold_repair_us": round(timings[0][1] * 1e6, 1),
        "end_healthy": _healthy(srv),
    }


def bench_ckpt(learner: str, slots: int) -> dict:
    import jax

    from repro.serve import make_server
    from repro.serve.recovery import restore_checkpoint

    args = dict(
        feature_map=_rff(), bank=slots, chunk=8, policy="lru",
        log_capacity=256, **_KW[learner],
    )
    srv = make_server(learner, **args)
    rng = np.random.default_rng(1)
    _feed(srv, rng, {t: 16 for t in range(slots)})

    saves, restores, nbytes, bitwise = [], [], 0, True
    for _ in range(3):
        with tempfile.TemporaryDirectory() as tmp:
            t0 = time.perf_counter()
            srv.checkpoint(tmp)
            saves.append(time.perf_counter() - t0)
            nbytes = max(
                os.path.getsize(p)
                for p in glob.glob(os.path.join(tmp, "gen_*.ckpt"))
            )
            fresh = make_server(learner, **args)
            t0 = time.perf_counter()
            restore_checkpoint(fresh, tmp)
            restores.append(time.perf_counter() - t0)
            for a, b in zip(
                jax.tree.leaves(srv.queue.state),
                jax.tree.leaves(fresh.queue.state),
            ):
                bitwise &= bool(
                    np.array_equal(
                        np.asarray(a), np.asarray(b), equal_nan=True
                    )
                )
    return {
        "bench": "ckpt_roundtrip",
        "learner": learner,
        "slots": slots,
        "dfeat": _DFEAT,
        "save_us": round(min(saves) * 1e6, 1),
        "restore_us": round(min(restores) * 1e6, 1),
        "bytes": nbytes,
        "state_bitwise": bitwise,
    }


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default=None)
    parser.add_argument("--tiny", action="store_true",
                        help="CI smoke shapes (never the committed baseline)")
    args = parser.parse_args(argv)

    import jax

    repair_grid = TINY_REPAIR_GRID if args.tiny else REPAIR_GRID
    ckpt_grid = TINY_CKPT_GRID if args.tiny else CKPT_GRID

    records = []
    for learner, kind, log_len in repair_grid:
        rec = bench_repair(learner, kind, log_len)
        records.append(rec)
        print(
            f"{learner:>5} {kind:<11} log={log_len:<4} "
            f"-> {rec['action']:<12} detect={rec['detect_us']}us "
            f"repair={rec['repair_us']}us (cold {rec['cold_repair_us']}us)",
            flush=True,
        )
    for learner, slots in ckpt_grid:
        rec = bench_ckpt(learner, slots)
        records.append(rec)
        print(
            f"{learner:>5} ckpt slots={slots:<3} save={rec['save_us']}us "
            f"restore={rec['restore_us']}us bytes={rec['bytes']} "
            f"bitwise={rec['state_bitwise']}",
            flush=True,
        )

    payload = {
        "suite": "recovery",
        "tiny": args.tiny,
        "backend": jax.default_backend(),
        "config": {"d": _D, "dfeat": _DFEAT, "chunk": 8},
        "caveats": [
            "repair_us is the warm (second) episode; cold_repair_us keeps"
            " the one-time per-log-length rebuild compile visible",
            "detect_us includes the faulted flush's chunk train — detection"
            " rides the write path, it is not a separate scan",
        ],
        "records": records,
    }
    out = args.out or (
        "/tmp/BENCH_recovery.json" if args.tiny else "BENCH_recovery.json"
    )
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {out} ({len(records)} records)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
