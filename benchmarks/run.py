"""Benchmark harness: one function per paper table/figure + kernel/roofline.

Prints ``name,us_per_call,derived`` CSV (detail dicts go to stderr-style
comment lines prefixed with '#'). ``--full`` switches to paper-scale
Monte-Carlo run counts; default sizes keep the whole suite at CI scale.
"""
from __future__ import annotations

import argparse
import json
import sys

from benchmarks import bank_bench, kernels_bench, krls_shard_bench, paper, roofline_report


def _krls_bank_fused_vs_twopass():
    """Adapt krls_shard_bench's record format to the (us, derived, detail)
    CSV contract. derived = fused speedup (x)."""
    rec = krls_shard_bench.bench_krls_bank_fused_vs_twopass()[0]
    return rec["fused_us"], rec["fused_speedup"], rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale runs")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    scale = 4 if args.full else 1
    benches = {
        "fig1_convergence": lambda: paper.fig1_convergence(runs=25 * scale),
        "fig2a_klms_vs_qklms": lambda: paper.fig2a_klms_vs_qklms(runs=10 * scale),
        "fig2b_krls": lambda: paper.fig2b_krls(runs=5 * scale),
        "fig3a_chaotic1": lambda: paper.fig3a_chaotic1(runs=100 * scale),
        "fig3b_chaotic2": lambda: paper.fig3b_chaotic2(runs=100 * scale),
        "table1_timing": lambda: paper.table1_timing(runs=3 * scale),
        "table1_highdim": lambda: paper.table1_highdim(runs=3 * scale),
        "orf_vs_iid": lambda: paper.orf_vs_iid(num_seeds=8 * scale),
        "kernel_rff_features": kernels_bench.bench_rff_features,
        "kernel_rff_attention": kernels_bench.bench_rff_attention,
        "bank_fused_vs_twopass": bank_bench.bench_bank_fused_vs_twopass,
        "bank_streams": bank_bench.bench_bank_streams,
        "krls_bank_fused_vs_twopass": _krls_bank_fused_vs_twopass,
        "roofline": roofline_report.roofline_table,
    }
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches.items():
        if args.only and args.only != name:
            continue
        try:
            us, derived, detail = fn()
            print(f"{name},{us:.3f},{derived:.4f}")
            print(f"# {name}: {json.dumps(detail)[:2000]}", flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{name},nan,nan")
            print(f"# {name} FAILED: {e!r}", file=sys.stderr, flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmarks failed")


if __name__ == "__main__":
    main()
