"""Benchmark harness: one function per paper table/figure + kernel/roofline.

Prints ``name,us_per_call,derived`` CSV (detail dicts go to stderr-style
comment lines prefixed with '#'). ``--full`` switches to paper-scale
Monte-Carlo run counts; default sizes keep the whole suite at CI scale.

Besides the CSV, the harness persists the results as ``BENCH_klms.json`` /
``BENCH_krls.json`` / ``BENCH_bank.json`` in ``--json-dir`` (default: repo
root, next to this package) with a stable schema::

    {"suite": "run_<family>", "backend": ..., "jax": ..., "full": bool,
     "records": [{"bench": ..., "us_per_call": ..., "derived": ...,
                  "detail": {...}}, ...]}

The committed copies at the repo root are the CPU baselines — re-run and
commit to track the perf trajectory across PRs instead of losing it with
CI artifacts. ``--no-json`` disables writing.

The feature-quality and serve-read-path suites keep their own record
schemas (they predate/outgrow the CSV contract); a clean full pass
delegates to their modules' writers so ``python -m benchmarks.run``
regenerates ``BENCH_features.json``, ``BENCH_serve.json``,
``BENCH_replay.json``, ``BENCH_decode.json`` and
``BENCH_recovery.json`` too, and ``--only features`` / ``--only serve``
/ ``--only replay`` / ``--only decode`` / ``--only recovery``
regenerates just that file.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from benchmarks import (
    bank_bench,
    decode_bench,
    features_bench,
    kernels_bench,
    krls_shard_bench,
    paper,
    recovery_bench,
    replay_bench,
    roofline_report,
    serve_bench,
    zipf_bench,
)

# bench name -> which BENCH_<family>.json it persists to.
SUITE_OF = {
    "fig1_convergence": "klms",
    "fig2a_klms_vs_qklms": "klms",
    "fig3a_chaotic1": "klms",
    "fig3b_chaotic2": "klms",
    "table1_timing": "klms",
    "table1_highdim": "klms",
    "orf_vs_iid": "klms",
    "kernel_rff_features": "klms",
    "kernel_rff_attention": "klms",
    "kernel_rff_attention_decode": "klms",
    "roofline": "klms",
    "fig2b_krls": "krls",
    "krls_bank_fused_vs_twopass": "krls",
    "bank_fused_vs_twopass": "bank",
    "bank_streams": "bank",
    "bank_chunked_streams": "bank",
}

# Suites whose committed baseline has its own (richer) record schema and
# writer: run.py delegates to the module's main() so ONE entry point
# regenerates every committed BENCH_*.json. Each writes a *whole* file, so
# unlike the CSV suites a --only=<name> run may safely (re)write it.
# (BENCH_chunk.json stays manual: chunk_bench must set XLA_FLAGS device
# counts before the first jax import, which run.py has already done.)
DELEGATED = {
    "decode": decode_bench.main,
    "features": features_bench.main,
    "recovery": recovery_bench.main,
    "replay": replay_bench.main,
    "serve": serve_bench.main,
    "zipf": zipf_bench.main,
}


def _krls_bank_fused_vs_twopass():
    """Adapt krls_shard_bench's record format to the (us, derived, detail)
    CSV contract. derived = fused speedup (x)."""
    rec = krls_shard_bench.bench_krls_bank_fused_vs_twopass()[0]
    return rec["fused_us"], rec["fused_speedup"], rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale runs")
    ap.add_argument("--only", default=None)
    ap.add_argument(
        "--json-dir",
        default=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        help="where BENCH_<family>.json files land (default: repo root)",
    )
    ap.add_argument(
        "--no-json", action="store_true", help="skip writing BENCH_*.json",
    )
    args = ap.parse_args()

    scale = 4 if args.full else 1
    benches = {
        "fig1_convergence": lambda: paper.fig1_convergence(runs=25 * scale),
        "fig2a_klms_vs_qklms": lambda: paper.fig2a_klms_vs_qklms(runs=10 * scale),
        "fig2b_krls": lambda: paper.fig2b_krls(runs=5 * scale),
        "fig3a_chaotic1": lambda: paper.fig3a_chaotic1(runs=100 * scale),
        "fig3b_chaotic2": lambda: paper.fig3b_chaotic2(runs=100 * scale),
        "table1_timing": lambda: paper.table1_timing(runs=3 * scale),
        "table1_highdim": lambda: paper.table1_highdim(runs=3 * scale),
        "orf_vs_iid": lambda: paper.orf_vs_iid(num_seeds=8 * scale),
        "kernel_rff_features": kernels_bench.bench_rff_features,
        "kernel_rff_attention": kernels_bench.bench_rff_attention,
        "kernel_rff_attention_decode": kernels_bench.bench_rff_attention_decode,
        "bank_fused_vs_twopass": bank_bench.bench_bank_fused_vs_twopass,
        "bank_streams": bank_bench.bench_bank_streams,
        "bank_chunked_streams": bank_bench.bench_bank_chunked_streams,
        "krls_bank_fused_vs_twopass": _krls_bank_fused_vs_twopass,
        "roofline": roofline_report.roofline_table,
    }
    missing = set(benches) - set(SUITE_OF)
    assert not missing, f"benches missing a SUITE_OF entry: {sorted(missing)}"

    if args.only in DELEGATED:
        if args.no_json:
            print(f"# --only={args.only} is a delegated suite; nothing to do")
            return
        out = os.path.join(args.json_dir, f"BENCH_{args.only}.json")
        DELEGATED[args.only](["--out", out])
        print(f"# wrote {out}")
        return

    print("name,us_per_call,derived")
    failures = 0
    by_suite: dict[str, list] = {}
    for name, fn in benches.items():
        if args.only and args.only != name:
            continue
        try:
            us, derived, detail = fn()
            print(f"{name},{us:.3f},{derived:.4f}")
            print(f"# {name}: {json.dumps(detail)[:2000]}", flush=True)
            by_suite.setdefault(SUITE_OF[name], []).append({
                "bench": name,
                "us_per_call": us,
                "derived": derived,
                "detail": detail,
            })
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{name},nan,nan")
            print(f"# {name} FAILED: {e!r}", file=sys.stderr, flush=True)

    # Baselines are only trustworthy from a clean full pass: a --only run
    # or a failing bench would overwrite the committed multi-record files
    # with a partial record set.
    if args.only or failures:
        if not args.no_json:
            print(
                "# BENCH_*.json not written (partial/--only or failed run)",
                flush=True,
            )
    elif not args.no_json and by_suite:
        import jax

        for family, records in sorted(by_suite.items()):
            payload = {
                "suite": f"run_{family}",
                "backend": jax.default_backend(),
                "jax": jax.__version__,
                "full": args.full,
                "records": records,
            }
            path = os.path.join(args.json_dir, f"BENCH_{family}.json")
            with open(path, "w") as f:
                json.dump(payload, f, indent=2)
            print(f"# wrote {path}", flush=True)
        # Full clean pass: also regenerate the delegated-suite baselines so
        # `python -m benchmarks.run` refreshes EVERY committed BENCH_*.json
        # (except BENCH_chunk.json — see the DELEGATED comment).
        for family, entry in sorted(DELEGATED.items()):
            path = os.path.join(args.json_dir, f"BENCH_{family}.json")
            entry(["--out", path])
            print(f"# wrote {path}", flush=True)

    if failures:
        raise SystemExit(f"{failures} benchmarks failed")


if __name__ == "__main__":
    main()
