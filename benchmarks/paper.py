"""Paper-figure reproductions (one function per figure/table).

Each returns ``(us_per_call, derived, detail)`` where ``us_per_call`` is the
mean per-sample processing time of the headline algorithm and ``derived`` is
the figure's headline quantity. ``--runs`` trades CI time for Monte-Carlo
smoothness; defaults are sized for minutes-not-hours on CPU while preserving
every qualitative claim (full paper-scale settings via flags).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import (
    ald_krls_run,
    qklms_run,
    rff_klms_run,
    rff_krls_run,
    sample_rff,
)
from repro.core.adaptive import monte_carlo_mse
from repro.core.theory import rzz_closed_form, steady_state_mse
from repro.data.synthetic import (
    gen_chaotic1,
    gen_chaotic2,
    gen_kernel_expansion,
    gen_nonlinear_wiener,
)

__all__ = [
    "fig1_convergence",
    "fig2a_klms_vs_qklms",
    "fig2b_krls",
    "fig3a_chaotic1",
    "fig3b_chaotic2",
    "table1_timing",
]


def _timed(fn):
    fn()  # compile
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    return out, time.perf_counter() - t0


def fig1_convergence(runs: int = 50, num_samples: int = 5000, rff_dim: int = 1000):
    """§5.1/Fig.1: RFFKLMS on model (7); steady-state vs Prop. 1.4 theory.

    derived = measured steady-state MSE / theoretical prediction (target ~1).
    """
    key = jax.random.PRNGKey(0)
    rff = sample_rff(key, 5, rff_dim, sigma=5.0)

    def realization(k):
        data = gen_kernel_expansion(k, num_samples=num_samples)
        _, out = rff_klms_run(rff, data.xs, data.ys, mu=1.0)
        return out.error

    mse_fn = jax.jit(lambda k: monte_carlo_mse(realization, k, runs))
    curve, dt = _timed(lambda: mse_fn(jax.random.PRNGKey(1)))
    steady = float(jnp.mean(curve[-500:]))
    theory = float(steady_state_mse(rzz_closed_form(rff, 1.0), 1.0, 0.1))
    us = dt / (runs * num_samples) * 1e6
    detail = {
        "mse_at_500": float(jnp.mean(curve[450:550])),
        "mse_at_2000": float(jnp.mean(curve[1950:2050])),
        "steady_state_mse": steady,
        "theory_mse": theory,
    }
    return us, steady / theory, detail


def _klms_vs_qklms(gen, sigma, mu, eps, rff_dim, qcap, runs, n):
    key = jax.random.PRNGKey(0)
    rff = sample_rff(key, gen(jax.random.PRNGKey(9))[0].shape[-1], rff_dim, sigma)

    def real_rff(k):
        xs, ys = gen(k)
        _, out = rff_klms_run(rff, xs, ys, mu=mu)
        return out.error

    def real_q(k):
        xs, ys = gen(k)
        _, out = qklms_run(xs, ys, sigma=sigma, mu=mu, eps=eps, capacity=qcap)
        return out.error

    rff_fn = jax.jit(lambda k: monte_carlo_mse(real_rff, k, runs))
    q_fn = jax.jit(lambda k: monte_carlo_mse(real_q, k, runs))
    curve_rff, t_rff = _timed(lambda: rff_fn(jax.random.PRNGKey(1)))
    curve_q, t_q = _timed(lambda: q_fn(jax.random.PRNGKey(1)))
    tail = max(n // 10, 50)
    mse_rff = float(jnp.mean(curve_rff[-tail:]))
    mse_q = float(jnp.mean(curve_q[-tail:]))
    # final dictionary size of one QKLMS run (for the table)
    xs, ys = gen(jax.random.PRNGKey(2))
    final_q, _ = qklms_run(xs, ys, sigma=sigma, mu=mu, eps=eps, capacity=qcap)
    return {
        "us_rffklms": t_rff / (runs * n) * 1e6,
        "us_qklms": t_q / (runs * n) * 1e6,
        "mse_rffklms": mse_rff,
        "mse_qklms": mse_q,
        "qklms_dict_size": int(final_q.size),
        "speedup": t_q / t_rff,
    }


def fig2a_klms_vs_qklms(runs: int = 25, num_samples: int = 15000):
    """§5.2/Fig.2a: RFFKLMS (D=300) vs QKLMS (eps=5, M~100) on model (9).

    derived = MSE(RFFKLMS)/MSE(QKLMS) at steady state (paper: ~1).
    """
    r = _klms_vs_qklms(
        lambda k: gen_nonlinear_wiener(k, num_samples=num_samples),
        sigma=5.0, mu=1.0, eps=5.0, rff_dim=300, qcap=256,
        runs=runs, n=num_samples,
    )
    return r["us_rffklms"], r["mse_rffklms"] / r["mse_qklms"], r


def fig3a_chaotic1(runs: int = 200, num_samples: int = 500):
    """§5.3/Fig.3a: chaotic series 1, D=100 vs QKLMS eps=0.01 (M~7)."""
    r = _klms_vs_qklms(
        lambda k: gen_chaotic1(k, num_samples=num_samples),
        sigma=0.05, mu=1.0, eps=0.01, rff_dim=100, qcap=64,
        runs=runs, n=num_samples,
    )
    return r["us_rffklms"], r["mse_rffklms"] / r["mse_qklms"], r


def fig3b_chaotic2(runs: int = 200, num_samples: int = 1000):
    """§5.4/Fig.3b: chaotic series 2, D=100 vs QKLMS eps=0.01 (M~32)."""
    r = _klms_vs_qklms(
        lambda k: gen_chaotic2(k, num_samples=num_samples),
        sigma=0.05, mu=1.0, eps=0.01, rff_dim=100, qcap=128,
        runs=runs, n=num_samples,
    )
    return r["us_rffklms"], r["mse_rffklms"] / r["mse_qklms"], r


def fig2b_krls(runs: int = 10, num_samples: int = 3000):
    """§6/Fig.2b: RFFKRLS (D=300, lam=1e-4, beta=0.9995) vs Engel ALD-KRLS.

    nu=5e-3 instead of the paper's 5e-4: the bordered inverse of the
    near-flat sigma=5 kernel is f64-only at 5e-4 (see tests) — documented
    deviation. derived = MSE(RFFKRLS)/MSE(ALD-KRLS).
    """
    key = jax.random.PRNGKey(0)
    rff = sample_rff(key, 5, 300, sigma=5.0)

    def real_rff(k):
        xs, ys = gen_nonlinear_wiener(k, num_samples=num_samples)
        _, out = rff_krls_run(rff, xs, ys, lam=1e-4, beta=0.9995)
        return out.error

    def real_ald(k):
        xs, ys = gen_nonlinear_wiener(k, num_samples=num_samples)
        _, out = ald_krls_run(xs, ys, sigma=5.0, nu=5e-3, capacity=128)
        return out.error

    f_r = jax.jit(lambda k: monte_carlo_mse(real_rff, k, runs))
    f_a = jax.jit(lambda k: monte_carlo_mse(real_ald, k, runs))
    curve_r, t_r = _timed(lambda: f_r(jax.random.PRNGKey(1)))
    curve_a, t_a = _timed(lambda: f_a(jax.random.PRNGKey(1)))
    mse_r = float(jnp.mean(curve_r[-300:]))
    mse_a = float(jnp.mean(curve_a[-300:]))
    detail = {
        "mse_rffkrls": mse_r,
        "mse_aldkrls": mse_a,
        "us_rffkrls": t_r / (runs * num_samples) * 1e6,
        "us_aldkrls": t_a / (runs * num_samples) * 1e6,
        "speedup_vs_engel": t_a / t_r,
    }
    return detail["us_rffkrls"], mse_r / mse_a, detail


def table1_highdim(runs: int = 3, num_samples: int = 4000, input_dim: int = 20):
    """The paper's §1 scaling argument, demonstrated: at input_dim=20 the
    quantized dictionary blows up (curse of dimensionality) while RFFKLMS
    stays at fixed D — this is the regime where the complexity claim
    O(Dd) < O(Md) holds even for a fully vectorized QKLMS.

    derived = RFFKLMS speedup over QKLMS (>1 expected here).
    """
    key = jax.random.PRNGKey(0)
    rff = sample_rff(key, input_dim, 300, sigma=5.0)

    def gen(k):
        d = gen_kernel_expansion(
            k, num_samples=num_samples, input_dim=input_dim, sigma=5.0
        )
        return d.xs, d.ys

    def real_rff(k):
        xs, ys = gen(k)
        _, out = rff_klms_run(rff, xs, ys, mu=1.0)
        return out.error

    def real_q(k):
        xs, ys = gen(k)
        _, out = qklms_run(xs, ys, sigma=5.0, mu=1.0, eps=10.0, capacity=2048)
        return out.error

    f_r = jax.jit(lambda k: monte_carlo_mse(real_rff, k, runs))
    f_q = jax.jit(lambda k: monte_carlo_mse(real_q, k, runs))
    curve_r, t_r = _timed(lambda: f_r(jax.random.PRNGKey(1)))
    curve_q, t_q = _timed(lambda: f_q(jax.random.PRNGKey(1)))
    xs, ys = gen(jax.random.PRNGKey(2))
    fq, _ = qklms_run(xs, ys, sigma=5.0, mu=1.0, eps=10.0, capacity=2048)
    detail = {
        "qklms_dict_size": int(fq.size),
        "rff_D": 300,
        "us_rffklms": t_r / (runs * num_samples) * 1e6,
        "us_qklms": t_q / (runs * num_samples) * 1e6,
        "mse_rffklms": float(jnp.mean(curve_r[-400:])),
        "mse_qklms": float(jnp.mean(curve_q[-400:])),
        "speedup": t_q / t_r,
    }
    return detail["us_rffklms"], detail["speedup"], detail


def table1_timing(runs: int = 5):
    """Table 1: mean training time, QKLMS vs RFFKLMS, examples 2/3/4.

    derived = mean RFFKLMS speedup across the three examples (paper: 2-6x).
    """
    rows = {}
    speeds = []
    for name, fn in (
        ("example2", lambda: fig2a_klms_vs_qklms(runs=runs, num_samples=15000)),
        ("example3", lambda: fig3a_chaotic1(runs=runs, num_samples=500)),
        ("example4", lambda: fig3b_chaotic2(runs=runs, num_samples=1000)),
    ):
        _, _, r = fn()
        rows[name] = {
            "rffklms_s_per_run": r["us_rffklms"] * 1e-6 * (15000 if name == "example2" else 500 if name == "example3" else 1000),
            "qklms_s_per_run": r["us_qklms"] * 1e-6 * (15000 if name == "example2" else 500 if name == "example3" else 1000),
            "qklms_dict": r["qklms_dict_size"],
            "speedup": r["speedup"],
        }
        speeds.append(r["speedup"])
    us = rows["example2"]["rffklms_s_per_run"] / 15000 * 1e6
    return us, float(jnp.mean(jnp.asarray(speeds))), rows


def orf_vs_iid(num_seeds: int = 16, input_dim: int = 8, rff_dim: int = 64):
    """Beyond-paper: Orthogonal Random Features vs the paper's iid draw.

    derived = RMSE(iid) / RMSE(orthogonal) at equal D (>1 means ORF wins —
    the same fixed-size solution buys a lower kernel-approximation error).
    """
    from repro.core.rff import gaussian_kernel, kernel_estimate

    x = jax.random.normal(jax.random.PRNGKey(1), (256, input_dim))
    y = jax.random.normal(jax.random.PRNGKey(2), (256, input_dim))
    exact = gaussian_kernel(x, y, 2.0)

    def rmse(orth):
        errs = []
        for s in range(num_seeds):
            rff = sample_rff(
                jax.random.PRNGKey(100 + s), input_dim, rff_dim, 2.0,
                orthogonal=orth,
            )
            approx = kernel_estimate(rff, x, y)
            errs.append(float(jnp.sqrt(jnp.mean((approx - exact) ** 2))))
        return sum(errs) / len(errs)

    t0 = time.perf_counter()
    r_iid = rmse(False)
    r_orf = rmse(True)
    dt = time.perf_counter() - t0
    detail = {"rmse_iid": r_iid, "rmse_orthogonal": r_orf}
    return dt / (2 * num_seeds) * 1e6, r_iid / r_orf, detail
