"""Filter-bank benchmarks: fused step vs two-pass, bank scaling.

Two comparisons:

* ``bench_bank_fused_vs_twopass`` — the per-tick hot path as one fused
  program (featurize+predict+update in a single jit; on TPU the Pallas
  kernel, on CPU one XLA fusion) vs the two-pass form (feature kernel and
  update as *separate* jitted calls, forcing the ``(B, D)`` feature block
  through HBM between them). derived = fused speedup (x). NOTE: on CPU
  XLA the two-pass form often *wins* (observed 0.5-1.0x fused speedup at
  the default sizes) — XLA-CPU parallelizes the standalone feature fusion
  better than the combined program, and a CPU cache hides the round-trip.
  The number this tracks is the memory-traffic argument for the TPU Pallas
  kernel, whose VMEM-resident ``z`` interpret mode cannot time; treat the
  CPU figure as a baseline to beat when real-TPU numbers land (ROADMAP).
* ``bench_bank_streams`` — B >= 64 concurrent streams of length n served by
  ONE jitted call (the acceptance-criteria path). derived = stream-steps/s.

Run as a script to emit the CI bench-smoke artifact ``BENCH_bank.json``:

    python -m benchmarks.bank_bench --tiny --out BENCH_bank.json
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp

from benchmarks.kernels_bench import _time
from repro.core.bank import klms_bank_init, klms_bank_run
from repro.core.rff import sample_rff
from repro.kernels import ops, ref

__all__ = [
    "bench_bank_fused_vs_twopass",
    "bench_bank_streams",
    "bench_bank_chunked_streams",
    "main",
]


def bench_bank_fused_vs_twopass(
    bank: int = 64, d: int = 8, dfeat: int = 512
):
    """One bank tick, fused vs two-pass. derived = fused speedup (x)."""
    rff = sample_rff(jax.random.PRNGKey(0), d, dfeat, sigma=2.0)
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    theta = jax.random.normal(ks[0], (bank, dfeat))
    x = jax.random.normal(ks[1], (bank, d))
    y = jax.random.normal(ks[2], (bank,))

    # All arrays enter as jit *arguments* (closed-over values become
    # compile-time constants and XLA folds the whole computation away).
    # mode="auto": the Pallas kernel on TPU, the XLA ref path elsewhere.
    fused = jax.jit(
        lambda t, xx, yy: ops.rff_klms_bank_step(
            t, xx, yy, rff.omega, rff.bias, 0.5, mode="auto"
        )
    )

    # Two-pass: feature map and LMS update in separate jits — z and theta
    # make an extra HBM round-trip between the calls.
    features = jax.jit(
        lambda xx: ref.rff_features_ref(xx, rff.omega, rff.bias)
    )

    @jax.jit
    def update(t, z, yy):
        pred = jnp.sum(t * z, axis=-1)
        err = yy - pred
        return t + (0.5 * err)[:, None] * z, pred, err

    def twopass():
        z = features(x)
        return update(theta, z, y)

    dt_fused = _time(lambda: fused(theta, x, y), iters=10)
    dt_two = _time(twopass, iters=10)
    return dt_fused * 1e6, dt_two / dt_fused, {
        "fused_us": dt_fused * 1e6,
        "twopass_us": dt_two * 1e6,
        "bank": bank,
        "dfeat": dfeat,
    }


def bench_bank_streams(
    bank: int = 64, n: int = 256, d: int = 8, dfeat: int = 256
):
    """B concurrent streams, one jitted call. derived = stream-steps/s."""
    rff = sample_rff(jax.random.PRNGKey(0), d, dfeat, sigma=2.0)
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    xs = jax.random.normal(ks[0], (bank, n, d))
    ys = jax.random.normal(ks[1], (bank, n))
    state = klms_bank_init(rff, bank)

    fn = jax.jit(
        lambda s, xx, yy: klms_bank_run(rff, xx, yy, 0.5, state=s, mode="auto")
    )
    dt = _time(lambda: fn(state, xs, ys), iters=5)
    return dt / (bank * n) * 1e6, bank * n / dt, {
        "seconds": dt,
        "bank": bank,
        "steps": n,
    }


def bench_bank_chunked_streams(
    bank: int = 64, n: int = 256, d: int = 8, dfeat: int = 256,
    chunk: int = 16,
):
    """The streams bench on the chunked schedule (one launch per T ticks
    inside the jit instead of a per-tick scan). derived = stream-steps/s;
    compare against ``bench_bank_streams`` for the in-jit chunking effect
    (the out-of-jit dispatch-amortization story lives in chunk_bench.py).
    """
    rff = sample_rff(jax.random.PRNGKey(0), d, dfeat, sigma=2.0)
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    xs = jax.random.normal(ks[0], (bank, n, d))
    ys = jax.random.normal(ks[1], (bank, n))
    state = klms_bank_init(rff, bank)

    fn = jax.jit(
        lambda s, xx, yy: klms_bank_run(
            rff, xx, yy, 0.5, state=s, mode="auto", chunk=chunk
        )
    )
    dt = _time(lambda: fn(state, xs, ys), iters=5)
    return dt / (bank * n) * 1e6, bank * n / dt, {
        "seconds": dt,
        "bank": bank,
        "steps": n,
        "chunk": chunk,
    }


def main(argv=None) -> None:
    """Emit the KLMS bank benchmarks as a ``BENCH_bank.json`` artifact."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true", help="CI smoke shapes")
    ap.add_argument("--out", default="BENCH_bank.json")
    args = ap.parse_args(argv)

    if args.tiny:
        fused_kw = dict(bank=8, d=4, dfeat=64)
        stream_kw = dict(bank=8, n=32, d=4, dfeat=64)
        chunk_kw = dict(bank=8, n=32, d=4, dfeat=64, chunk=8)
    else:
        fused_kw = dict(bank=64, d=8, dfeat=512)
        stream_kw = dict(bank=64, n=256, d=8, dfeat=256)
        chunk_kw = dict(bank=64, n=256, d=8, dfeat=256, chunk=16)

    records = []
    us, derived, detail = bench_bank_fused_vs_twopass(**fused_kw)
    records.append({
        "bench": "bank_fused_vs_twopass",
        "us_per_call": us,
        "fused_speedup": derived,
        **detail,
    })
    us, derived, detail = bench_bank_streams(**stream_kw)
    records.append({
        "bench": "bank_streams",
        "us_per_step": us,
        "stream_steps_per_s": derived,
        **detail,
    })
    us, derived, detail = bench_bank_chunked_streams(**chunk_kw)
    records.append({
        "bench": "bank_chunked_streams",
        "us_per_step": us,
        "stream_steps_per_s": derived,
        **detail,
    })

    payload = {
        "suite": "bank_bench",
        "backend": jax.default_backend(),
        "tiny": args.tiny,
        "records": records,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    json.dump(payload, sys.stdout, indent=2)
    print()


if __name__ == "__main__":
    main()
