"""Sharded-KRLS benchmarks: dense vs sharded tick across D, fused vs
two-pass KRLS bank tick. Emits ``BENCH_krls.json`` (the CI bench-smoke
artifact recording the perf trajectory per PR).

Run as a script — it forces a multi-device host platform *before* first jax
use, so the sharded path actually distributes:

    python benchmarks/krls_shard_bench.py --shards 8 --out BENCH_krls.json
    python benchmarks/krls_shard_bench.py --tiny   # CI smoke shapes

On CPU the sharded tick is expected to LOSE to dense (host "devices" share
the same cores and the psum is pure overhead) — the number that matters is
the per-shard memory column: the (D/n, D) P block is what fits under a
single-chip VMEM/HBM budget when the dense (D, D) no longer does. Treat the
CPU timing as the baseline for real-ICI runs (ROADMAP).

All jax imports are deferred so ``main()`` can set XLA_FLAGS first.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _time(fn, iters: int = 10) -> float:
    import jax

    jax.block_until_ready(fn())  # compile
    jax.block_until_ready(fn())  # warm
    t0 = time.perf_counter()
    out = None
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def bench_dense_vs_sharded_tick(dfeats, n_shards: int, iters: int = 10):
    """Per-tick latency + per-shard memory, dense vs sharded, across D."""
    import jax
    import jax.numpy as jnp

    from repro.core.krls import (
        make_sharded_krls_step,
        rff_krls_init,
        rff_krls_step,
        sharded_krls_init,
    )
    from repro.core.rff import sample_rff
    from repro.launch.mesh import make_krls_mesh
    from repro.launch.sharding import krls_shard_bytes

    mesh = make_krls_mesh(n_shards)
    d_in = 8
    records = []
    for dfeat in dfeats:
        rff = sample_rff(jax.random.PRNGKey(0), d_in, dfeat, sigma=2.0)
        x = jax.random.normal(jax.random.PRNGKey(1), (d_in,))
        y = jnp.asarray(0.5)

        dense_state = rff_krls_init(dfeat, 1e-2)
        dense_step = jax.jit(
            lambda s, xx, yy: rff_krls_step(s, (xx, yy), rff, 0.9995)
        )
        dt_dense = _time(lambda: dense_step(dense_state, x, y), iters)

        sh_state = sharded_krls_init(mesh, dfeat, 1e-2)
        sh_step = make_sharded_krls_step(mesh, rff, 0.9995)
        dt_sh = _time(lambda: sh_step(sh_state, x, y), iters)

        mem = krls_shard_bytes(dfeat, n_shards, input_dim=d_in)
        records.append({
            "bench": "dense_vs_sharded_tick",
            "dfeat": dfeat,
            "n_shards": n_shards,
            "dense_us": dt_dense * 1e6,
            "sharded_us": dt_sh * 1e6,
            "sharded_speedup": dt_dense / dt_sh,
            "p_block_bytes_per_shard": mem["p_block_bytes"],
            "dense_p_bytes": mem["dense_p_bytes"],
        })
    return records


def bench_krls_bank_fused_vs_twopass(
    bank: int = 16, d: int = 8, dfeat: int = 256, iters: int = 10
):
    """One KRLS bank tick: fused single program vs two-pass (standalone
    feature jit, then the batched RLS update jit — z, pz and P make extra
    HBM round-trips between the calls)."""
    import jax
    import jax.numpy as jnp

    from repro.core.rff import sample_rff
    from repro.kernels import ops, ref

    rff = sample_rff(jax.random.PRNGKey(0), d, dfeat, sigma=2.0)
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    theta = jax.random.normal(ks[0], (bank, dfeat))
    pmat = jnp.broadcast_to(jnp.eye(dfeat) * 100.0, (bank, dfeat, dfeat))
    x = jax.random.normal(ks[1], (bank, d))
    y = jax.random.normal(ks[2], (bank,))

    fused = jax.jit(
        lambda t, p, xx, yy: ops.rff_krls_bank_step(
            t, p, xx, yy, rff.omega, rff.bias, 0.9995, mode="auto"
        )
    )
    features = jax.jit(
        lambda xx: ref.rff_features_ref(xx, rff.omega, rff.bias)
    )

    @jax.jit
    def update(t, p, z, yy):
        pred = jnp.sum(t * z, axis=-1)
        err = yy - pred
        pz = jnp.einsum("bij,bj->bi", p, z)
        denom = 0.9995 + jnp.sum(z * pz, axis=-1)
        gain = pz / denom[:, None]
        t = t + gain * err[:, None]
        p = (p - gain[:, :, None] * pz[:, None, :]) / 0.9995
        p = 0.5 * (p + jnp.swapaxes(p, -1, -2))
        return t, p, pred, err

    def twopass():
        z = features(x)
        return update(theta, pmat, z, y)

    dt_fused = _time(lambda: fused(theta, pmat, x, y), iters)
    dt_two = _time(twopass, iters)
    return [{
        "bench": "krls_bank_fused_vs_twopass",
        "bank": bank,
        "dfeat": dfeat,
        "fused_us": dt_fused * 1e6,
        "twopass_us": dt_two * 1e6,
        "fused_speedup": dt_two / dt_fused,
    }]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--tiny", action="store_true", help="CI smoke shapes")
    ap.add_argument("--out", default="BENCH_krls.json")
    args = ap.parse_args(argv)

    # Must precede first jax use: the host platform locks its device count
    # at backend init.
    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={args.shards}",
    )
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    if args.tiny:
        dfeats, bank, dfeat_bank, iters = [64, 128], 4, 64, 3
    else:
        dfeats, bank, dfeat_bank, iters = [256, 512, 1024], 16, 256, 10

    records = []
    records += bench_dense_vs_sharded_tick(dfeats, args.shards, iters)
    records += bench_krls_bank_fused_vs_twopass(
        bank=bank, dfeat=dfeat_bank, iters=iters
    )

    import jax

    payload = {
        "suite": "krls_shard_bench",
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "tiny": args.tiny,
        "records": records,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    json.dump(payload, sys.stdout, indent=2)
    print()


if __name__ == "__main__":
    main()
