"""Roofline table from the dry-run artifacts (experiments/dryrun/*.json)."""
from __future__ import annotations

import glob
import json
import os

__all__ = ["roofline_table", "load_cells"]

DEFAULT_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "experiments", "dryrun",
)


def load_cells(dryrun_dir: str = DEFAULT_DIR, mesh: str = "16x16") -> list[dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(f))
        if rec.get("mesh") == mesh:
            cells.append(rec)
    return cells


def roofline_table(dryrun_dir: str = DEFAULT_DIR):
    """derived = mean useful-FLOPs fraction over the 16 train+prefill cells
    (decode cells are inherently memory-bound; their 'useful' fraction is
    not a compute-efficiency signal)."""
    cells = load_cells(dryrun_dir)
    if not cells:
        return 0.0, 0.0, {"error": "no dry-run artifacts; run repro.launch.dryrun"}
    rows = {}
    fracs = []
    for rec in cells:
        r = rec["roofline"]
        rows[f"{rec['arch']}/{rec['shape']}"] = {
            "dominant": r["dominant"],
            "compute_s": round(r["compute_s"], 5),
            "memory_s": round(r["memory_s"], 5),
            "collective_s": round(r["collective_s"], 5),
            "useful_frac": round(r["useful_flops_frac"], 4),
        }
        if rec["kind"] in ("train", "prefill"):
            fracs.append(r["useful_flops_frac"])
    mean_frac = sum(fracs) / max(len(fracs), 1)
    return 0.0, mean_frac, rows
