"""Kernel-layer microbenchmarks (XLA path on CPU; Pallas targets TPU)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops

__all__ = [
    "bench_rff_features",
    "bench_rff_attention",
    "bench_rff_attention_decode",
]


def _time(fn, iters=5):
    fn()
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def bench_rff_features(m: int = 8192, d: int = 128, dfeat: int = 256):
    """Feature-map GEMM+cos throughput. derived = GFLOP/s achieved."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (d, dfeat))
    b = jnp.zeros((dfeat,))
    fn = jax.jit(lambda: ops.rff_features(x, w, b, mode="xla"))
    dt = _time(fn)
    flops = 2 * m * d * dfeat
    return dt / m * 1e6, flops / dt / 1e9, {"seconds": dt}


def bench_rff_attention(s: int = 4096, dfeat: int = 64, dv: int = 64,
                        chunk: int = 256):
    """Chunked linear attention throughput. derived = tokens/second."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.nn.relu(jax.random.normal(ks[0], (4, s, dfeat))) + 0.01
    k = jax.nn.relu(jax.random.normal(ks[1], (4, s, dfeat))) + 0.01
    v = jax.random.normal(ks[2], (4, s, dv))
    fn = jax.jit(lambda: ops.rff_attention(q, k, v, mode="xla", chunk=chunk))
    dt = _time(fn)
    return dt / (4 * s) * 1e6, 4 * s / dt, {"seconds": dt}


def bench_rff_attention_decode(bh: int = 8, t: int = 64, dh: int = 64,
                               dfeat: int = 256, dv: int = 64):
    """Decode from the fixed-size state: fused block vs per-token dispatch.

    The prefill row above never measured decode; this one times T decode
    ticks both ways. derived = fused-block speedup (x) over T single-token
    launches; detail carries each path's tokens/s (the trajectory columns
    benchmarks/decode_bench.py sweeps in depth).
    """
    ks = jax.random.split(jax.random.PRNGKey(0), 7)
    q = jax.random.normal(ks[0], (bh, t, dh)) * 0.1
    k = jax.random.normal(ks[1], (bh, t, dh)) * 0.1
    v = jax.random.normal(ks[2], (bh, t, dv))
    w = jax.random.normal(ks[3], (dh, dfeat)) * 0.3
    b = jax.random.uniform(ks[4], (dfeat,), maxval=2 * jnp.pi)
    s_state = jax.random.normal(ks[5], (bh, dfeat, dv)) * 0.1
    z_state = jax.nn.relu(jax.random.normal(ks[6], (bh, dfeat))) + 0.5

    blocked = jax.jit(lambda s, z: ops.rff_attention_decode_block(
        s, z, q, k, v, w, b, mode="xla", block_t=t))
    step = jax.jit(lambda s, z, q1, k1, v1: ops.rff_attention_decode_block(
        s, z, q1, k1, v1, w, b, mode="xla", block_t=1))

    def per_token():
        s_st, z_st = s_state, z_state
        out = None
        for i in range(t):
            out, s_st, z_st = step(s_st, z_st, q[:, i:i + 1], k[:, i:i + 1],
                                   v[:, i:i + 1])
        return out, s_st, z_st

    dt_blk = _time(lambda: blocked(s_state, z_state))
    dt_tok = _time(per_token)
    return dt_blk / (bh * t) * 1e6, dt_tok / dt_blk, {
        "seconds_block": dt_blk,
        "seconds_per_token_path": dt_tok,
        "tokens_per_s_block": bh * t / dt_blk,
        "tokens_per_s_per_token": bh * t / dt_tok,
    }
