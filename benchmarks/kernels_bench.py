"""Kernel-layer microbenchmarks (XLA path on CPU; Pallas targets TPU)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops

__all__ = ["bench_rff_features", "bench_rff_attention"]


def _time(fn, iters=5):
    fn()
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def bench_rff_features(m: int = 8192, d: int = 128, dfeat: int = 256):
    """Feature-map GEMM+cos throughput. derived = GFLOP/s achieved."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (d, dfeat))
    b = jnp.zeros((dfeat,))
    fn = jax.jit(lambda: ops.rff_features(x, w, b, mode="xla"))
    dt = _time(fn)
    flops = 2 * m * d * dfeat
    return dt / m * 1e6, flops / dt / 1e9, {"seconds": dt}


def bench_rff_attention(s: int = 4096, dfeat: int = 64, dv: int = 64,
                        chunk: int = 256):
    """Chunked linear attention throughput. derived = tokens/second."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.nn.relu(jax.random.normal(ks[0], (4, s, dfeat))) + 0.01
    k = jax.nn.relu(jax.random.normal(ks[1], (4, s, dfeat))) + 0.01
    v = jax.random.normal(ks[2], (4, s, dv))
    fn = jax.jit(lambda: ops.rff_attention(q, k, v, mode="xla", chunk=chunk))
    dt = _time(fn)
    return dt / (4 * s) * 1e6, 4 * s / dt, {"seconds": dt}
