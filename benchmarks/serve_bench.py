"""Read-path benchmarks: fused query-block predict vs the vmapped adapter.

Two measurements, each paired with the analytic bytes-moved model so the
JSON artifact records prediction AND observation:

* ``bench_read_block`` — Q queries per tenant served as Q separate
  ``core.bank.bank_predict`` calls (the PR-1 adapter: one vmapped
  featurize+matvec per query, theta and W re-fetched every call) vs ONE
  ``ops.rff_bank_predict`` launch over the ``(B, Q, d)`` block, at f32 and
  bf16 read precision. On CPU the fused win is batching + dispatch
  amortization; on TPU the same schedule additionally keeps theta and W
  VMEM-resident across the block (the bytes model below).
* ``bench_read_write_mix`` — a read:write ratio sweep (1:1 -> 1000:1) of
  the train-coupled baseline (per-tick train server + per-query adapter
  reads against the live state) vs the snapshot-decoupled server
  (chunked micro-batch flushes + fused block reads from the frozen
  replica). Queries dominate real serving traffic, so this is the
  end-to-end quantity the read-path overhaul buys.

Plus ``bench_bf16_read_error`` — the per-family bf16-vs-f32 prediction
error floor (the README "Read path and serving precision" table).

Run as a script to emit ``BENCH_serve.json``:

    PYTHONPATH=src python benchmarks/serve_bench.py --out BENCH_serve.json
    PYTHONPATH=src python benchmarks/serve_bench.py --tiny   # CI smoke

Without an explicit ``--out``, a ``--tiny`` run writes to /tmp so tiny
shapes can never overwrite the committed full-shape baseline at the repo
root.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _time(fn, iters: int = 5) -> float:
    import jax

    jax.block_until_ready(fn())  # compile
    jax.block_until_ready(fn())  # warm
    t0 = time.perf_counter()
    out = None
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def read_bytes_model(bank: int, d: int, dfeat: int, q: int) -> dict:
    """f32 HBM bytes moved to serve Q queries per tenant, both schedules.

    Adapter (Q separate bank_predict calls): every call re-reads W (d*D)
    and the whole theta (B*D), streams x (B*d) in and predictions (B) out.
    Fused block (one launch): W and theta are fetched ONCE — the
    VMEM-resident theta tile of kernels/rff_predict.py — and only the
    query/prediction streams scale with Q. The crossover is entirely the
    amortized (d*D + B*D) term, which is why the fused path pulls away as
    the read:write ratio (and hence Q per flush interval) grows.

    The closed form lives in repro.obs.telemetry — the same model feeds
    the live kernel.bytes_moved gauge, so bench and serving cannot drift.
    """
    from repro.obs.telemetry import predict_read_bytes

    return predict_read_bytes(bank, d, dfeat, q)


def bench_read_block(
    bank: int = 16,
    d: int = 8,
    dfeat: int = 256,
    qs: tuple = (1, 4, 16, 64, 256),
    iters: int = 5,
):
    """Q-per-query adapter loop vs one fused (B, Q, d) launch, f32 + bf16."""
    import jax
    import jax.numpy as jnp

    from repro.core.bank import bank_predict, klms_bank_init
    from repro.core.learner import klms_learner
    from repro.core.rff import sample_rff
    from repro.features.base import as_trig
    from repro.kernels import ops

    rff = sample_rff(jax.random.PRNGKey(0), d, dfeat, sigma=2.0)
    tf = as_trig(rff)
    learner = klms_learner(rff, 0.5)
    state = klms_bank_init(rff, bank)
    adapter = jax.jit(lambda s, x: bank_predict(learner, s, x))

    records = []
    for q in qs:
        xq = jax.random.normal(jax.random.PRNGKey(q), (bank, q, d))
        per_query = [jnp.asarray(xq[:, i]) for i in range(q)]

        def run_adapter():
            out = None
            for x in per_query:
                out = adapter(state, x)
            return out

        def run_fused(precision=None):
            return ops.rff_bank_predict(
                state.theta,
                xq,
                tf.omega,
                tf.bias,
                tf.scale,
                mode="auto",
                precision=precision,
            )

        dt_adapter = _time(run_adapter, iters)
        dt_fused = _time(run_fused, iters)
        dt_bf16 = _time(lambda: run_fused("bf16"), iters)
        qps = bank * q / dt_fused
        records.append({
            "bench": "read_block",
            "bank": bank,
            "dfeat": dfeat,
            "q": q,
            "adapter_us": dt_adapter * 1e6,
            "fused_us": dt_fused * 1e6,
            "fused_bf16_us": dt_bf16 * 1e6,
            "fused_qps": qps,
            "fused_speedup": dt_adapter / dt_fused,
            "bf16_speedup_vs_f32": dt_fused / dt_bf16,
            **read_bytes_model(bank, d, dfeat, q),
        })
    return records


def bench_read_write_mix(
    bank: int = 8,
    d: int = 8,
    dfeat: int = 128,
    n_writes: int = 16,
    q: int = 32,
    chunk: int = 16,
    ratios: tuple = (1, 10, 100, 1000),
    iters: int = 3,
):
    """Train-coupled adapter serving vs snapshot-decoupled fused serving.

    One round = one write tick per tenant + ``ratio`` bank-wide reads.
    The baseline trains per tick and answers every read with the per-query
    adapter against the live state; the snapshot path batches writes
    through the micro-batch queue (chunk=T flushes) and answers reads in
    ``q``-query fused blocks from the frozen replica.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.bank import bank_predict, klms_bank_init
    from repro.core.learner import klms_learner
    from repro.core.rff import sample_rff
    from repro.serve.api import make_server, make_tick

    rff = sample_rff(jax.random.PRNGKey(0), d, dfeat, sigma=2.0)
    learner = klms_learner(rff, 0.5)
    adapter = jax.jit(lambda s, x: bank_predict(learner, s, x))
    tick = make_tick("klms", rff, mode="auto", mu=0.5)

    rng = np.random.RandomState(0)
    xs = rng.randn(n_writes, bank, d).astype(np.float32)
    ys = rng.randn(n_writes, bank).astype(np.float32)
    init_state = klms_bank_init(rff, bank)
    # One server for the whole sweep (its jitted chunk/predict programs
    # trace once); each timed run restarts it on the fresh init state.
    srv = make_server(
        "klms", feature_map=rff, bank=bank, mu=0.5, chunk=chunk,
        publish_every=chunk, mode="auto",
    ).snapshot_server

    records = []
    for ratio in ratios:
        reads_per_round = ratio
        blocks_per_round = -(-reads_per_round // q)
        xq_block = jnp.asarray(rng.randn(bank, q, d).astype(np.float32))
        x_read = jnp.asarray(xs[0])

        def run_baseline():
            s = init_state
            out = None
            for w in range(n_writes):
                s, _ = tick(s, jnp.asarray(xs[w]), jnp.asarray(ys[w]))
                for _ in range(reads_per_round):
                    out = adapter(s, x_read)
            return out

        def run_snapshot():
            srv.reset(init_state)
            out = None
            for w in range(n_writes):
                for t in range(bank):
                    srv.submit(t, xs[w, t], ys[w, t])
                if (w + 1) % chunk == 0:
                    srv.flush()
                for _ in range(blocks_per_round):
                    out = srv.predict_block(xq_block)
            srv.drain()
            return out

        dt_base = _time(run_baseline, iters)
        dt_snap = _time(run_snapshot, iters)
        total_reads = n_writes * reads_per_round * bank
        records.append({
            "bench": "read_write_mix",
            "bank": bank,
            "dfeat": dfeat,
            "ratio": ratio,
            "q": q,
            "chunk": chunk,
            "n_writes": n_writes,
            "baseline_us": dt_base * 1e6,
            "snapshot_us": dt_snap * 1e6,
            "snapshot_speedup": dt_base / dt_snap,
            "snapshot_reads_per_s": total_reads / dt_snap,
            **read_bytes_model(bank, d, dfeat, reads_per_round * n_writes),
        })
    return records


def bench_bf16_read_error(
    families: tuple = ("rff", "orf", "qmc", "gq", "taylor"),
    d: int = 4,
    dfeat: int = 256,
    bank: int = 8,
    q: int = 256,
):
    """Per-family bf16-vs-f32 prediction error floor at serving shapes.

    The quantity the mixed-precision read contract trades away: max/RMS
    absolute prediction error of the bf16 read path against the f32
    reference, on unit-scale theta. This is the README error-floor table;
    tests/test_read_path.py pins the same bound per family.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.bank import bank_predict_block
    from repro.core.klms import LMSState
    from repro.features import make_feature_map

    records = []
    for family in families:
        fm = make_feature_map(
            family, d, dfeat, 2.0, key=jax.random.PRNGKey(0)
        )
        nfeat = fm.num_features
        ks = jax.random.split(jax.random.PRNGKey(1), 2)
        theta = 0.3 * jax.random.normal(ks[0], (bank, nfeat))
        xq = jax.random.normal(ks[1], (bank, q, d))
        state = LMSState(theta=theta, step=jnp.zeros((bank,), jnp.int32))
        f32 = bank_predict_block(state, xq, fm, mode="auto")
        bf16 = bank_predict_block(
            state, xq, fm, mode="auto", precision="bf16"
        )
        err = jnp.abs(f32 - bf16)
        records.append({
            "bench": "bf16_read_error",
            "family": family,
            "dfeat": nfeat,
            "bank": bank,
            "q": q,
            "max_abs_err": float(jnp.max(err)),
            "rms_err": float(jnp.sqrt(jnp.mean(err**2))),
            "pred_rms": float(jnp.sqrt(jnp.mean(f32**2))),
        })
    return records


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true", help="CI smoke shapes")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.out is None:
        # Tiny runs must not clobber the committed full-shape baseline.
        args.out = "/tmp/BENCH_serve.json" if args.tiny else "BENCH_serve.json"

    if args.tiny:
        block_kw = dict(bank=4, d=4, dfeat=64, qs=(1, 8, 32), iters=2)
        mix_kw = dict(
            bank=2,
            d=4,
            dfeat=64,
            n_writes=8,
            q=8,
            chunk=8,
            ratios=(1, 10, 100),
            iters=2,
        )
    else:
        block_kw = dict(bank=16, d=8, dfeat=256, qs=(1, 4, 16, 64, 256),
                        iters=5)
        mix_kw = dict(bank=8, d=8, dfeat=128, n_writes=16, q=32, chunk=16,
                      ratios=(1, 10, 100, 1000), iters=3)

    err_kw = dict(dfeat=64, q=32) if args.tiny else {}
    records = (
        bench_read_block(**block_kw)
        + bench_read_write_mix(**mix_kw)
        + bench_bf16_read_error(**err_kw)
    )

    import jax

    payload = {
        "suite": "serve_bench",
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "tiny": args.tiny,
        "records": records,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    json.dump(payload, sys.stdout, indent=2)
    print()


if __name__ == "__main__":
    main()
