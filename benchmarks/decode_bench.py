"""Decode-path benchmarks: fused block decode vs per-token dispatch vs flash.

Three measurements, together the perf story for the fused decode-block
kernel (kernels/rff_attention.py):

* ``bench_context_sweep`` — tokens/s decoding from the fixed-size RFF
  state vs from a growing softmax KV cache, across context lengths. The
  RFF state is (D, dv) regardless of how many tokens came before, so its
  tokens/s is FLAT in context; the flash/dense baseline re-reads a
  (context, dh) cache every token and degrades linearly. This is the
  paper's fixed-size-solution claim measured on the serving axis.
* ``bench_block_sweep`` — the same T decode ticks dispatched as T
  single-token launches (block_t=1, the pre-fused path) vs one fused
  launch per block_t ticks. On CPU the win is dispatch amortization; on
  TPU the same schedule additionally keeps the (D, dv) S tile and z row
  VMEM-resident across the block (one state read/write per block_t ticks
  instead of block_t).
* ``bench_bf16_error`` — bf16 read-path decode (features + numerator
  GEMMs in bf16, state f32) vs the f32 oracle: the error floor the
  mixed-precision contract promises (<= 2e-2 scale-relative).

Record schema (guarded by scripts/check_bench_schema.py)::

    {"suite": "decode", "backend": ..., "jax": ..., "tiny": bool,
     "records": [
       {"bench": "decode_context_sweep", "attn": "rff_block"|"flash",
        "context_len": int, "tokens_per_s": float, "us_per_token": float},
       {"bench": "decode_block_sweep", "block_t": int,
        "tokens_per_s": float, "us_per_token": float,
        "speedup_vs_per_token": float},
       {"bench": "decode_bf16_error", "feature_kind": str,
        "rel_err_out": float, "rel_err_state": float}, ...]}

Run as a script to emit ``BENCH_decode.json``:

    PYTHONPATH=src python benchmarks/decode_bench.py --out BENCH_decode.json
    PYTHONPATH=src python benchmarks/decode_bench.py --tiny   # CI smoke

Without an explicit ``--out``, a ``--tiny`` run writes to /tmp so tiny
shapes can never overwrite the committed full-shape baseline at the repo
root.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _time(fn, iters: int = 5) -> float:
    import jax

    jax.block_until_ready(fn())  # compile
    jax.block_until_ready(fn())  # warm
    t0 = time.perf_counter()
    out = None
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _decode_inputs(bh, t, dh, dfeat, dv, seed=0):
    import jax
    import jax.numpy as jnp

    ks = jax.random.split(jax.random.PRNGKey(seed), 7)
    q = jax.random.normal(ks[0], (bh, t, dh)) * 0.1
    k = jax.random.normal(ks[1], (bh, t, dh)) * 0.1
    v = jax.random.normal(ks[2], (bh, t, dv))
    w = jax.random.normal(ks[3], (dh, dfeat)) * 0.3
    b = jax.random.uniform(ks[4], (dfeat,), maxval=6.283185)
    s_state = jax.random.normal(ks[5], (bh, dfeat, dv)) * 0.1
    z_state = jax.nn.relu(jax.random.normal(ks[6], (bh, dfeat))) + 0.5
    return q, k, v, w, b, s_state, z_state


def bench_context_sweep(bh=8, dh=64, dfeat=256, dv=64, t=32,
                        contexts=(512, 2048, 8192), iters=5) -> list[dict]:
    """tokens/s vs context length: fixed-size RFF state vs softmax cache.

    The RFF decode reads NOTHING that scales with context (same (D, dv)
    state whatever came before), so the context axis only changes the
    baseline: a per-token softmax step over a (context, dh) KV cache.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    q, k, v, w, b, s_state, z_state = _decode_inputs(bh, t, dh, dfeat, dv)
    records = []
    blocked = jax.jit(lambda s, z: ops.rff_attention_decode_block(
        s, z, q, k, v, w, b, mode="xla", block_t=t))

    def flash_step(q1, kc, vc):
        # one softmax decode tick over the cache — linear in context
        logits = jnp.einsum("bd,bsd->bs", q1, kc) / jnp.sqrt(
            jnp.float32(q1.shape[-1]))
        return jnp.einsum("bs,bsv->bv", jax.nn.softmax(logits, axis=-1), vc)

    flash = jax.jit(flash_step)
    for ctx in contexts:
        dt = _time(lambda: blocked(s_state, z_state), iters)
        records.append({
            "bench": "decode_context_sweep", "attn": "rff_block",
            "context_len": int(ctx), "block_t": int(t),
            "us_per_token": dt / (bh * t) * 1e6,
            "tokens_per_s": bh * t / dt,
        })
        kc = jax.random.normal(jax.random.PRNGKey(1), (bh, ctx, dh)) * 0.1
        vc = jax.random.normal(jax.random.PRNGKey(2), (bh, ctx, dv))
        q1 = q[:, 0]
        dtf = _time(lambda: flash(q1, kc, vc), iters)
        records.append({
            "bench": "decode_context_sweep", "attn": "flash",
            "context_len": int(ctx),
            "us_per_token": dtf / bh * 1e6,
            "tokens_per_s": bh / dtf,
        })
    return records


def bench_block_sweep(bh=8, dh=64, dfeat=256, dv=64, t=64,
                      block_ts=(1, 4, 16, 64), iters=5) -> list[dict]:
    """T decode ticks as T launches (per-token dispatch) vs fused blocks.

    block_t=1 is the honest per-token path — a Python loop of T jitted
    single-token calls threading the state, exactly what serving does
    without the fused kernel. Larger block_t amortizes launches (and, on
    TPU, state movement) over the block.
    """
    import jax

    from repro.kernels import ops

    q, k, v, w, b, s_state, z_state = _decode_inputs(bh, t, dh, dfeat, dv)
    step = jax.jit(lambda s, z, q1, k1, v1: ops.rff_attention_decode_block(
        s, z, q1, k1, v1, w, b, mode="xla", block_t=1))

    def per_token():
        s_st, z_st = s_state, z_state
        out = None
        for i in range(t):
            out, s_st, z_st = step(s_st, z_st, q[:, i:i + 1], k[:, i:i + 1],
                                   v[:, i:i + 1])
        return out, s_st, z_st

    base_dt = _time(per_token, iters)
    records = [{
        "bench": "decode_block_sweep", "block_t": 1,
        "us_per_token": base_dt / (bh * t) * 1e6,
        "tokens_per_s": bh * t / base_dt,
        "speedup_vs_per_token": 1.0,
    }]
    for bt in block_ts:
        if bt == 1:
            continue
        fn = jax.jit(lambda s, z, bt=bt: ops.rff_attention_decode_block(
            s, z, q, k, v, w, b, mode="xla", block_t=bt))
        dt = _time(lambda: fn(s_state, z_state), iters)
        records.append({
            "bench": "decode_block_sweep", "block_t": int(bt),
            "us_per_token": dt / (bh * t) * 1e6,
            "tokens_per_s": bh * t / dt,
            "speedup_vs_per_token": base_dt / dt,
        })
    return records


def bench_bf16_error(bh=4, t=32, dh=32, dfeat=256, dv=32) -> list[dict]:
    """bf16 read-path decode vs the f32 oracle: scale-relative max error."""
    import numpy as np

    from repro.kernels import ref

    records = []
    for kind in ("prf", "trig"):
        q, k, v, w, b, s_state, z_state = _decode_inputs(
            bh, t, dh, dfeat, dv, seed=3)
        normalize = kind == "prf"
        f32 = ref.rff_attention_decode_block_ref(
            s_state, z_state, q, k, v, w, b, feature_kind=kind,
            normalize=normalize)
        bf16 = ref.rff_attention_decode_block_ref(
            s_state, z_state, q, k, v, w, b, feature_kind=kind,
            normalize=normalize, precision="bf16")
        def rel(g, wv):
            g = np.asarray(g, np.float32)
            wv = np.asarray(wv, np.float32)
            return float(np.max(np.abs(g - wv)) / (np.max(np.abs(wv)) + 1e-6))
        records.append({
            "bench": "decode_bf16_error", "feature_kind": kind,
            "rel_err_out": rel(bf16[0], f32[0]),
            "rel_err_state": max(rel(bf16[1], f32[1]), rel(bf16[2], f32[2])),
        })
    return records


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true", help="CI smoke shapes")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.out is None:
        # Tiny runs must not clobber the committed full-shape baseline.
        args.out = "/tmp/BENCH_decode.json" if args.tiny else "BENCH_decode.json"

    if args.tiny:
        ctx_kw = dict(bh=2, dh=16, dfeat=64, dv=16, t=8,
                      contexts=(64, 256), iters=2)
        blk_kw = dict(bh=2, dh=16, dfeat=64, dv=16, t=16,
                      block_ts=(1, 4, 16), iters=2)
        err_kw = dict(bh=2, t=8, dh=16, dfeat=64, dv=16)
    else:
        ctx_kw = dict(contexts=(512, 2048, 8192, 32768), iters=5)
        blk_kw = dict(block_ts=(1, 4, 16, 64), iters=5)
        err_kw = {}

    records = (
        bench_context_sweep(**ctx_kw)
        + bench_block_sweep(**blk_kw)
        + bench_bf16_error(**err_kw)
    )

    import jax

    payload = {
        "suite": "decode",
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "tiny": bool(args.tiny),
        "records": records,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    for rec in records:
        print(json.dumps(rec), file=sys.stderr)
    print(f"wrote {args.out} ({len(records)} records)")


if __name__ == "__main__":
    main()
