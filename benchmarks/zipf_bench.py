"""Zipf tail-latency harness: the bank as a cache under skewed tenant load.

The tentpole question for the policy tier (serve/policy.py): when tenant
ids outnumber bank slots, what do eviction scoring and admission control
buy? This bench drives ``serve.make_server(policy=...)`` with a Zipf(α)
tenant arrival stream — pmf ∝ 1/rank^α over a fixed tenant universe — at
several bank:tenant ratios, interleaving reads (1 per ``read_every``
writes, tenants drawn from the same distribution), and reports per config:

* ``hit_rate`` — fraction of requests whose tenant was already resident;
* ``write_us`` / ``read_us`` — p50/p95/p99 request latency from the
  server's own metrics registry (serve/metrics.py), measured around the
  full submit/predict call: queue work, watermark flushes, eviction
  parks, and replay rebuilds all land in the write tail;
* the lifecycle counters (evictions / readmissions / admission rejects).

Policies compared: ``lru`` (always-admit, classic), ``lfu`` and ``cost``
(admission floor — a candidate must outscore the coldest incumbent, so
one-hit Zipf-tail tenants stop flushing the hot set; ``cost`` weights
recency by the family's rebuild cost). The payload's ``notes`` record
which skewed configs had ``cost`` beating plain ``lru`` on hit-rate or
p99 write latency.

Caveats recorded in the payload: replay rebuilds jit-compile once per
distinct log length, so the first pass over a config pays compile time
inside the write tail — a real cold-start cost, but one that amortizes
away in long-running servers; and latency percentiles come from
one-octave geometric buckets (serve/metrics.py), so read them as
trajectory signals, not microsecond forensics.

Run as a script to emit ``BENCH_zipf.json``:

    PYTHONPATH=src python benchmarks/zipf_bench.py --out BENCH_zipf.json
    PYTHONPATH=src python benchmarks/zipf_bench.py --tiny   # CI smoke

Without an explicit ``--out``, a ``--tiny`` run writes to /tmp so tiny
shapes can never overwrite the committed full-shape baseline.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

ALPHAS = (0.6, 0.9, 1.2)
RATIOS = ((16, 64), (16, 128))  # (bank slots, tenant universe)
POLICIES = ("lru", "lfu", "cost")


def zipf_stream(rng, tenants: int, alpha: float, n: int) -> np.ndarray:
    """n tenant ids with pmf ∝ 1/rank^alpha over [0, tenants)."""
    ranks = np.arange(1, tenants + 1, dtype=np.float64)
    probs = ranks**-alpha
    probs /= probs.sum()
    return rng.choice(tenants, size=n, p=probs)


def run_config(
    policy: str,
    alpha: float,
    bank: int,
    tenants: int,
    *,
    learner: str = "klms",
    requests: int = 4000,
    read_every: int = 4,
    chunk: int = 8,
    d: int = 8,
    dfeat: int = 64,
    log_capacity: int = 64,
    seed: int = 0,
    trace_out: str | None = None,
    ckpt: bool = False,
) -> dict:
    import jax

    from repro.core.rff import sample_rff
    from repro.serve import make_server

    rff = sample_rff(jax.random.PRNGKey(0), d, dfeat, 1.0)
    server_kw = dict(
        feature_map=rff,
        bank=bank,
        chunk=chunk,
        mu=0.3,
        policy=policy,
        log_capacity=log_capacity,
        size_watermark=chunk,
        probe=True,
    )
    srv = make_server(learner, trace=trace_out is not None, **server_kw)
    rng = np.random.default_rng(seed)
    ids = zipf_stream(rng, tenants, alpha, requests)
    xs = rng.standard_normal((requests, d)).astype(np.float32)
    ys = rng.standard_normal(requests).astype(np.float32)
    for i in range(requests):
        if read_every and i % read_every == read_every - 1:
            srv.predict(int(ids[i]), xs[i])
        else:
            srv.submit(int(ids[i]), xs[i], float(ys[i]))
    srv.drain()
    # Numerics-health columns: the in-jit tap's last flush readout plus
    # one bf16-vs-f32 read-contract sample on a Zipf-shaped query block.
    bf16_err = srv.check_read_contract(
        xs[: bank * 4].reshape(bank, 4, d)
    )
    ckpt_bitwise = None
    if ckpt:
        # Durability smoke riding the Zipf drive: checkpoint the loaded
        # server, restore into a fresh one, and demand a bitwise match on
        # every state leaf (the chaos suite covers kill-mid-stream; this
        # keeps the round-trip contract exercised at serving shapes).
        import tempfile

        from repro.serve.recovery import restore_checkpoint

        with tempfile.TemporaryDirectory() as tmp:
            srv.checkpoint(tmp)
            fresh = make_server(learner, **server_kw)
            restore_checkpoint(fresh, tmp)
            ckpt_bitwise = all(
                bool(np.array_equal(np.asarray(a), np.asarray(b),
                                    equal_nan=True))
                for a, b in zip(jax.tree.leaves(srv.queue.state),
                                jax.tree.leaves(fresh.queue.state))
            )
            assert ckpt_bitwise, "checkpoint round-trip lost state"
    probe = srv.probe.state()
    snap = srv.metrics.snapshot()
    lat = snap["histograms"]
    if trace_out is not None:
        srv.tracer.to_chrome_trace(trace_out)

    def pct(name):
        h = lat.get(name, {})
        return {k: round(h.get(k, 0.0), 1) for k in ("p50", "p95", "p99")}

    rec = {
        "bench": "zipf_serve",
        "learner": learner,
        "policy": policy,
        "alpha": alpha,
        "bank": bank,
        "tenants": tenants,
        "ratio": f"{bank}:{tenants}",
        "requests": requests,
        "hit_rate": round(srv.hit_rate(), 4),
        "write_us": pct("latency.write_us"),
        "read_us": pct("latency.read_us"),
        "counters": snap["counters"],
        "probes": {
            "healthy": probe["healthy"],
            "finite": probe["last"].get("finite", 1.0),
            "theta_norm_max": round(
                probe["last"].get("theta.norm_max", 0.0), 4
            ),
            "bf16_read_error": round(bf16_err, 6),
            "degradation_events": probe["total_events"],
        },
    }
    if ckpt_bitwise is not None:
        rec["ckpt_bitwise"] = ckpt_bitwise
    return rec


def cost_vs_lru_notes(records: list[dict]) -> list[str]:
    """Configs where the cost-aware policy beat plain LRU (the acceptance
    question), on hit-rate or p99 write latency."""
    notes = []
    by_key = {(r["policy"], r["alpha"], r["ratio"]): r for r in records}
    for (policy, alpha, ratio), rec in sorted(
        by_key.items(), key=lambda kv: (kv[0][1], kv[0][2])
    ):
        if policy != "cost":
            continue
        lru = by_key.get(("lru", alpha, ratio))
        if lru is None:
            continue
        wins = []
        if rec["hit_rate"] > lru["hit_rate"]:
            wins.append(
                f"hit_rate {rec['hit_rate']:.3f} > {lru['hit_rate']:.3f}"
            )
        if rec["write_us"]["p99"] < lru["write_us"]["p99"]:
            wins.append(
                f"p99 write {rec['write_us']['p99']} < "
                f"{lru['write_us']['p99']} us"
            )
        verdict = "; ".join(wins) if wins else "no win (LRU held)"
        notes.append(f"alpha={alpha} {ratio}: cost vs lru — {verdict}")
    return notes


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default=None)
    parser.add_argument("--tiny", action="store_true",
                        help="CI smoke shapes (never the committed baseline)")
    parser.add_argument("--requests", type=int, default=None)
    parser.add_argument("--trace", default=None, metavar="PATH",
                        help="run the first recorded config traced and "
                             "write its Chrome trace-event JSON here")
    parser.add_argument("--ckpt", action="store_true",
                        help="checkpoint/restore round-trip on the first "
                             "recorded config (asserts a bitwise match)")
    args = parser.parse_args(argv)

    import jax

    if args.tiny:
        alphas, ratios, policies = (0.9,), ((4, 16),), ("lru", "cost")
        requests = args.requests or 300
    else:
        alphas, ratios, policies = ALPHAS, RATIOS, POLICIES
        requests = args.requests or 4000

    # Warmup pass (discarded): populates the process-wide compile caches
    # (chunk scans, replay lengths, fused predict) so the recorded grid's
    # tails measure serving, not first-touch tracing. One jit per config
    # remains (each server owns its chunk-step closure) — the cold-start
    # caveat below.
    for policy in policies:
        run_config(
            policy, alphas[0], *ratios[0],
            requests=min(1500, requests), seed=99,
        )
        print(f"# warmup {policy} done", flush=True)

    records = []
    for alpha in alphas:
        for bank, tenants in ratios:
            for policy in policies:
                trace_out = args.trace if not records else None
                rec = run_config(
                    policy, alpha, bank, tenants, requests=requests,
                    trace_out=trace_out,
                    ckpt=args.ckpt and not records,
                )
                records.append(rec)
                print(
                    f"alpha={alpha} {rec['ratio']} {policy:>4}: "
                    f"hit={rec['hit_rate']:.3f} "
                    f"p99w={rec['write_us']['p99']}us "
                    f"p99r={rec['read_us']['p99']}us",
                    flush=True,
                )

    payload = {
        "suite": "zipf",
        "tiny": args.tiny,
        "backend": jax.default_backend(),
        "config": {
            "requests": requests,
            "read_every": 4,
            "chunk": 8,
            "dfeat": 64,
            "log_capacity": 64,
        },
        "notes": cost_vs_lru_notes(records),
        "caveats": [
            "write p99 includes one-time jit compiles (per distinct replay"
            " length) — cold-start cost, amortizes in long-running servers",
            "percentiles from one-octave geometric buckets (serve/metrics)",
        ],
        "records": records,
    }
    out = args.out or ("/tmp/BENCH_zipf.json" if args.tiny else "BENCH_zipf.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {out} ({len(records)} records)")
    for note in payload["notes"]:
        print("  " + note)
    return 0


if __name__ == "__main__":
    sys.exit(main())
