"""Chunked multi-tick engine benchmarks: T sweep + combine_every sweep.

Three measurements, each paired with the analytic bytes/collectives model so
the JSON artifact records prediction AND observation:

* ``bench_chunk_dispatch`` (KLMS and KRLS) — the scan-driver dispatch loop:
  a per-tick jitted server called n times from Python vs the chunked server
  called n/T times (T in {1, 4, 16, 64}). On CPU the win is pure dispatch
  amortization (one Python->XLA round-trip per T ticks); on TPU the same
  schedule additionally keeps theta/P VMEM-resident per chunk (bytes model
  below). derived = chunked-vs-per-tick ticks/sec speedup at each T.
* ``bench_combine_every`` — sharded KRLS over a forced multi-device host
  mesh with k in {1, 8, 32} ticks per psum. On host devices the collective
  is cheap so CPU numbers are a baseline; the model column (collectives per
  tick, payload bytes per collective) is what transfers to ICI/DCN.

Run as a script to emit ``BENCH_chunk.json`` (sets XLA_FLAGS before first
jax use so the sharded sweep actually distributes):

    python benchmarks/chunk_bench.py --shards 8 --out BENCH_chunk.json
    python benchmarks/chunk_bench.py --tiny   # CI smoke -> /tmp by default

Without an explicit ``--out``, a ``--tiny`` run writes to /tmp so tiny
shapes can never overwrite the committed full-shape baseline at the repo
root.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _time(fn, iters: int = 5) -> float:
    import jax

    jax.block_until_ready(fn())  # compile
    jax.block_until_ready(fn())  # warm
    t0 = time.perf_counter()
    out = None
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


# Bytes models live in repro.obs.telemetry — the same closed forms feed the
# live kernel.bytes_moved gauges, so the bench columns cannot drift from
# what serving reports.
def klms_chunk_bytes_per_tick(
    bank: int, d: int, dfeat: int, tchunk: int,
) -> dict:
    from repro.obs.telemetry import klms_chunk_bytes

    return klms_chunk_bytes(bank, d, dfeat, tchunk)


def krls_chunk_bytes_per_tick(
    bank: int, d: int, dfeat: int, tchunk: int,
) -> dict:
    from repro.obs.telemetry import krls_chunk_bytes

    return krls_chunk_bytes(bank, d, dfeat, tchunk)


def bench_chunk_dispatch(
    algo: str = "klms",
    bank: int = 16,
    d: int = 8,
    dfeat: int = 128,
    n_ticks: int = 256,
    tees: tuple = (1, 4, 16, 64),
    iters: int = 5,
):
    """Per-tick server loop vs chunked server loop, ticks/sec at each T."""
    import jax
    import jax.numpy as jnp

    from repro.core.bank import klms_bank_init, krls_bank_init
    from repro.core.rff import sample_rff
    from repro.serve.api import make_chunk_step, make_tick

    rff = sample_rff(jax.random.PRNGKey(0), d, dfeat, sigma=2.0)
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    xs = jax.random.normal(ks[0], (bank, n_ticks, d))
    ys = jax.random.normal(ks[1], (bank, n_ticks))
    if algo == "klms":
        state = klms_bank_init(rff, bank)
        tick = make_tick("klms", rff, mode="auto", mu=0.5)
        chunk_srv = make_chunk_step("klms", rff, mode="auto", mu=0.5)
        model = klms_chunk_bytes_per_tick
    else:
        state = krls_bank_init(rff, bank, lam=1e-2)
        tick = make_tick("krls", rff, mode="auto", beta=0.9995)
        chunk_srv = make_chunk_step("krls", rff, mode="auto", beta=0.9995)
        model = krls_chunk_bytes_per_tick

    # Host-side pre-split so each timed call is pure dispatch + compute
    # (arrivals in a real serving loop come from the host anyway).
    tick_args = [
        (jnp.asarray(xs[:, t]), jnp.asarray(ys[:, t]))
        for t in range(n_ticks)
    ]

    def run_per_tick():
        s = state
        for x_t, y_t in tick_args:
            s, _ = tick(s, x_t, y_t)
        return s

    dt_tick = _time(run_per_tick, iters)
    base_tps = n_ticks / dt_tick
    records = [{
        "bench": f"{algo}_chunk_dispatch",
        "schedule": "per_tick_server",
        "bank": bank,
        "dfeat": dfeat,
        "n_ticks": n_ticks,
        "ticks_per_s": base_tps,
        "us_per_tick": dt_tick / n_ticks * 1e6,
        **model(bank, d, dfeat, 1),
    }]

    for tchunk in tees:
        nb = n_ticks // tchunk
        chunk_args = [
            (
                jnp.asarray(xs[:, i * tchunk : (i + 1) * tchunk]),
                jnp.asarray(ys[:, i * tchunk : (i + 1) * tchunk]),
                jnp.ones((bank, tchunk)),
            )
            for i in range(nb)
        ]

        def run_chunked():
            s = state
            for xc, yc, mc in chunk_args:
                s, _ = chunk_srv(s, xc, yc, mc)
            return s

        dt = _time(run_chunked, iters)
        tps = nb * tchunk / dt
        records.append({
            "bench": f"{algo}_chunk_dispatch",
            "schedule": f"chunked_T{tchunk}",
            "bank": bank,
            "dfeat": dfeat,
            "n_ticks": nb * tchunk,
            "chunk_T": tchunk,
            "ticks_per_s": tps,
            "us_per_tick": dt / (nb * tchunk) * 1e6,
            "speedup_vs_per_tick": tps / base_tps,
            **model(bank, d, dfeat, tchunk),
        })
    return records


def bench_combine_every(
    n_shards: int,
    dfeat: int = 256,
    n_ticks: int = 128,
    ks_sweep: tuple = (1, 8, 32),
    iters: int = 5,
):
    """Sharded-KRLS stream with k ticks per psum; model = collectives/tick."""
    import jax

    from repro.core.krls import sharded_krls_run
    from repro.core.rff import sample_rff
    from repro.launch.mesh import make_krls_mesh

    mesh = make_krls_mesh(n_shards)
    d_in = 8
    rff = sample_rff(jax.random.PRNGKey(0), d_in, dfeat, sigma=2.0)
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    xs = jax.random.normal(ks[0], (n_ticks, d_in))
    ys = jax.random.normal(ks[1], (n_ticks,))

    records = []
    base_tps = None
    for k in ks_sweep:
        def run():
            return sharded_krls_run(
                mesh, rff, xs, ys, lam=1e-2, beta=0.9995, combine_every=k,
            )

        dt = _time(run, iters)
        tps = n_ticks / dt
        if base_tps is None:
            base_tps = tps
        records.append({
            "bench": "krls_combine_every",
            "combine_every": k,
            "n_shards": n_shards,
            "dfeat": dfeat,
            "n_ticks": n_ticks,
            "ticks_per_s": tps,
            "us_per_tick": dt / n_ticks * 1e6,
            "speedup_vs_k1": tps / base_tps,
            "collectives_per_tick_model": 1.0 / k,
            "payload_bytes_per_collective": 4 * k * (2 * dfeat + 1),
        })
    return records


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--tiny", action="store_true", help="CI smoke shapes")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.out is None:
        # Tiny runs must not clobber the committed full-shape baseline.
        args.out = "/tmp/BENCH_chunk.json" if args.tiny else "BENCH_chunk.json"

    # Must precede first jax use: the host platform locks its device count
    # at backend init.
    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={args.shards}",
    )
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    if args.tiny:
        disp_kw = dict(bank=4, d=4, dfeat=64, n_ticks=64, iters=2)
        krls_kw = dict(bank=2, d=4, dfeat=64, n_ticks=64, iters=2)
        comb_kw = dict(dfeat=64, n_ticks=64, iters=2)
    else:
        # Serving-shaped banks: small enough that per-launch dispatch is a
        # real fraction of the tick (the quantity chunking amortizes).
        disp_kw = dict(bank=16, d=8, dfeat=128, n_ticks=256, iters=5)
        krls_kw = dict(bank=8, d=8, dfeat=128, n_ticks=256, iters=5)
        comb_kw = dict(dfeat=256, n_ticks=128, iters=5)

    records = []
    records += bench_chunk_dispatch("klms", **disp_kw)
    records += bench_chunk_dispatch("krls", **krls_kw)
    records += bench_combine_every(args.shards, **comb_kw)

    import jax

    payload = {
        "suite": "chunk_bench",
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "tiny": args.tiny,
        "records": records,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    json.dump(payload, sys.stdout, indent=2)
    print()


if __name__ == "__main__":
    main()
