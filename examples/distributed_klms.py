"""Diffusion RFF-KLMS over a device mesh — the paper's distributed payoff.

Classic diffusion KLMS ships growing dictionaries between nodes; with RFF,
nodes exchange one fixed R^D vector per combine round (here: a pmean over
the mesh's data axis, optionally int8-compressed with error feedback).

Run (forces 8 host devices; must be set before jax imports):

    PYTHONPATH=src python examples/distributed_klms.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro.core.distributed import diffusion_klms_run
from repro.core.rff import sample_rff
from repro.data.synthetic import gen_nonlinear_wiener


def main():
    nodes = 8
    mesh = jax.make_mesh((nodes,), ("data",))
    rff = sample_rff(jax.random.PRNGKey(0), 5, 100, sigma=5.0)

    # one common unknown system, observed as per-node streams
    xs_all, ys_all = gen_nonlinear_wiener(
        jax.random.PRNGKey(1), num_samples=800 * nodes
    )
    xs = xs_all.reshape(nodes, 800, -1)
    ys = ys_all.reshape(nodes, 800)

    for label, kwargs in (
        ("isolated nodes     ", dict(combine_every=10**9)),
        ("diffusion (f32)    ", dict()),
        ("diffusion (int8+EF)", dict(compress=True)),
    ):
        theta, errs = diffusion_klms_run(mesh, "data", rff, xs, ys, mu=0.5, **kwargs)
        mse = float(jnp.mean(errs[:, -100:] ** 2))
        spread = float(jnp.max(jnp.abs(theta - jnp.mean(theta, 0, keepdims=True))))
        print(f"{label}: steady MSE {mse:.5f}   node-solution spread {spread:.2e}")

    print("\nper-round network payload: "
          f"f32 {100*4} B/node vs int8 {100} B/node (fixed D=100, forever)")


if __name__ == "__main__":
    main()
