"""Feature families quickstart: RFF vs ORF vs GQ on the chaotic series.

The paper's device is ONE fixed-size feature map; repro.features makes the
map pluggable. This example builds three families at the same budget D,
drives the identical RFF-KLMS learner with each (the learner never
branches on the family), and prints the error-vs-D table in the
``BENCH_features.json`` record schema — plus the determinism check that is
the whole point of GQ: two constructions from different PRNG keys are
bitwise the same filter.

Run: PYTHONPATH=src python examples/feature_families.py
"""
import jax
import jax.numpy as jnp

from repro.core.klms import rff_klms_run
from repro.core.rff import gaussian_kernel
from repro.data.synthetic import gen_chaotic1
from repro.features import featurize, make_feature_map


def kernel_rmse(fm, sigma, num_pairs=512):
    kx, ky = jax.random.split(jax.random.PRNGKey(1234))
    x = jax.random.normal(kx, (num_pairs, fm.input_dim))
    y = jax.random.normal(ky, (num_pairs, fm.input_dim))
    exact = gaussian_kernel(x, y, sigma)
    est = jnp.sum(featurize(fm, x) * featurize(fm, y), axis=-1)
    return float(jnp.sqrt(jnp.mean((est - exact) ** 2)))


def main():
    d, sigma, mu, n = 2, 0.5, 0.5, 2000
    xs, ys = gen_chaotic1(jax.random.PRNGKey(42), num_samples=n)

    # --- error-vs-D table, BENCH_features.json record schema -------------
    print(f"{'family':8s} {'D':>5s} {'kernel_rmse':>12s} {'klms_mse':>10s} "
          f"{'deterministic':>13s}")
    for family in ("rff", "orf", "gq"):
        for dfeat in (64, 128, 256):
            fm = make_feature_map(
                family, d, dfeat, sigma, key=jax.random.PRNGKey(0)
            )
            _, out = rff_klms_run(fm, xs, ys, mu)
            record = {  # the BENCH_features.json "detail" schema
                "family": family,
                "num_features": dfeat,
                "kernel_rmse": kernel_rmse(fm, sigma),
                "steady_state_mse": float(jnp.mean(out.error[-n // 4:] ** 2)),
                "deterministic": bool(fm.deterministic),
            }
            print(f"{record['family']:8s} {record['num_features']:5d} "
                  f"{record['kernel_rmse']:12.5f} "
                  f"{record['steady_state_mse']:10.5f} "
                  f"{str(record['deterministic']):>13s}")

    # --- the deterministic dividend: no seed coordination, ever ----------
    gq_a = make_feature_map("gq", d, 128, sigma, key=jax.random.PRNGKey(0))
    gq_b = make_feature_map("gq", d, 128, sigma, key=jax.random.PRNGKey(99))
    _, out_a = rff_klms_run(gq_a, xs, ys, mu)
    _, out_b = rff_klms_run(gq_b, xs, ys, mu)
    same = bool(jnp.all(out_a.error == out_b.error))
    print(f"\ngq learners from different seeds bitwise identical: {same}")

    rff_a = make_feature_map("rff", d, 128, sigma, key=jax.random.PRNGKey(0))
    rff_b = make_feature_map("rff", d, 128, sigma, key=jax.random.PRNGKey(99))
    _, ra = rff_klms_run(rff_a, xs, ys, mu)
    _, rb = rff_klms_run(rff_b, xs, ys, mu)
    drift = float(
        jnp.abs(
            jnp.mean(ra.error[-n // 4:] ** 2)
            - jnp.mean(rb.error[-n // 4:] ** 2)
        )
    )
    print(f"rff steady-state MSE spread across the same two seeds: {drift:.2e}")


if __name__ == "__main__":
    main()
