"""Batched serving with fixed-size-state long-context decode.

Compares the growing KV cache (standard GQA) against the paper-derived RFF
linear-attention state whose size is independent of context length — the
serving analogue of RFFKLMS's fixed theta.

    PYTHONPATH=src python examples/serve_lm.py --tokens 64
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import (
    decode_state_init,
    decode_step,
    init_params,
    with_rff_attention,
)


def bytes_of(tree):
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def generate(cfg, params, batch, steps, max_len):
    state = decode_state_init(cfg, batch, max_len=max_len)
    step = jax.jit(lambda p, s, t: decode_step(p, cfg, s, t))
    tok = jnp.zeros((batch,), jnp.int32)
    toks = []
    t0 = time.perf_counter()
    for _ in range(steps):
        logits, state = step(params, state, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(np.asarray(tok))
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    return np.stack(toks, 1), dt, bytes_of(state)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    base = get_config(args.arch).reduced()
    key = jax.random.PRNGKey(0)

    for label, cfg, max_len in (
        ("gqa + KV cache (ctx 4096)   ", base, 4096),
        ("rff fixed state (ctx = any) ", with_rff_attention(base), 4096),
    ):
        params = init_params(key, cfg)
        toks, dt, state_bytes = generate(cfg, params, args.batch, args.tokens, max_len)
        print(
            f"{label}: {args.tokens} toks x{args.batch} in {dt:.2f}s "
            f"({args.batch*args.tokens/dt:.1f} tok/s), decode state "
            f"{state_bytes/1e6:.2f} MB"
        )
    print("\nThe RFF state stays the same size at 4k, 32k, or 524k context —")
    print("that is what makes the long_500k decode cells lowerable at all.")


if __name__ == "__main__":
    main()
