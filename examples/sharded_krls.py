"""Sharded RFF-KRLS — scaling the (D, D) inverse correlation past one chip.

The paper's fixed-size-solution property is what makes this possible: the
KRLS state is a Euclidean (theta, P) pair, so P partitions into (D/n, D)
row blocks over a mesh axis and each tick needs exactly one psum.

Run (forces 8 host devices; must be set before jax imports):

    PYTHONPATH=src python examples/sharded_krls.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.krls import rff_krls_run, sharded_krls_run  # noqa: E402
from repro.core.rff import sample_rff  # noqa: E402
from repro.data.synthetic import gen_nonlinear_wiener  # noqa: E402
from repro.launch.mesh import make_krls_mesh  # noqa: E402
from repro.launch.sharding import krls_shard_bytes  # noqa: E402


def main():
    n_shards = 8
    dfeat = 512
    mesh = make_krls_mesh(n_shards)
    rff = sample_rff(jax.random.PRNGKey(0), 5, dfeat, sigma=5.0)
    xs, ys = gen_nonlinear_wiener(jax.random.PRNGKey(1), num_samples=1000)

    _, dense = rff_krls_run(rff, xs, ys, lam=1e-2, beta=0.9995)
    _, shard = sharded_krls_run(mesh, rff, xs, ys, lam=1e-2, beta=0.9995)

    gap = float(jnp.max(jnp.abs(dense.prediction - shard.prediction)))
    mse = float(jnp.mean(shard.error[-100:] ** 2))
    mem = krls_shard_bytes(dfeat, n_shards, input_dim=5)
    print(f"devices={jax.device_count()} shards={n_shards} D={dfeat}")
    print(f"dense-vs-sharded prediction gap: {gap:.2e}")
    print(f"sharded steady-state MSE (last 100 ticks): {mse:.4f}")
    print(
        f"P bytes per shard: {mem['p_block_bytes']:,} "
        f"(dense: {mem['dense_p_bytes']:,})"
    )


if __name__ == "__main__":
    main()
