"""End-to-end LM training driver: any assigned arch, fault-tolerant loop.

Defaults train a ~small reduced config for a few hundred steps on CPU; the
same flags drive the full configs on a real mesh (see repro.launch.dryrun
for the production lowering of every arch x shape).

    PYTHONPATH=src python examples/train_lm.py --arch qwen2-0.5b --steps 200
    PYTHONPATH=src python examples/train_lm.py --arch llama3-8b --full   # real cfg

Features exercised: microbatch grad accumulation, AdamW, checkpoint/resume
(kill it mid-run and rerun the same command), straggler watchdog, seekable
deterministic data.
"""
import argparse

from repro.configs import ARCH_IDS, get_config
from repro.data.lm_data import batch_at_step
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--micro", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-reduced) architecture config")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"(family={cfg.family})")

    def batch_fn(step):
        return {
            "tokens": batch_at_step(
                0, step, global_batch=args.batch, seq_len=args.seq,
                vocab=cfg.vocab_size,
            )
        }

    trainer = Trainer(
        cfg,
        TrainerConfig(
            total_steps=args.steps,
            ckpt_every=50,
            ckpt_dir=args.ckpt_dir,
            num_microbatches=args.micro,
            peak_lr=args.lr,
            log_every=20,
        ),
        batch_fn,
    )
    metrics = trainer.run()
    print(f"final: {metrics}")
    print(f"stragglers flagged: {trainer.straggler_events}")


if __name__ == "__main__":
    main()
