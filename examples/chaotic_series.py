"""Paper Examples 3 & 4 (chaotic series) + the KRLS variants (§6).

    PYTHONPATH=src python examples/chaotic_series.py
"""
import jax
import jax.numpy as jnp

from repro.core import (
    ald_krls_run,
    qklms_run,
    rff_klms_run,
    rff_krls_run,
    sample_rff,
)
from repro.data.synthetic import gen_chaotic1, gen_chaotic2


def tail_mse(err, n=100):
    return float(jnp.mean(err[-n:] ** 2))


def main():
    # --- Example 3 (sigma=0.05, D=100, eps=0.01) ---------------------------
    xs, ys = gen_chaotic1(jax.random.PRNGKey(0), num_samples=500)
    rff = sample_rff(jax.random.PRNGKey(1), 2, 100, sigma=0.05)
    _, out_rff = rff_klms_run(rff, xs, ys, mu=1.0)
    fq, out_q = qklms_run(xs, ys, sigma=0.05, mu=1.0, eps=0.01, capacity=64)
    print("Example 3 (chaotic series 1):")
    print(f"  RFFKLMS MSE {tail_mse(out_rff.error):.6f}")
    print(f"  QKLMS   MSE {tail_mse(out_q.error):.6f}  (dict M={int(fq.size)})")

    # --- Example 4 ----------------------------------------------------------
    xs, ys = gen_chaotic2(jax.random.PRNGKey(2), num_samples=1000)
    rff = sample_rff(jax.random.PRNGKey(3), 2, 100, sigma=0.05)
    _, out_rff = rff_klms_run(rff, xs, ys, mu=1.0)
    fq, out_q = qklms_run(xs, ys, sigma=0.05, mu=1.0, eps=0.01, capacity=128)
    print("Example 4 (chaotic series 2):")
    print(f"  RFFKLMS MSE {tail_mse(out_rff.error):.6f}")
    print(f"  QKLMS   MSE {tail_mse(out_q.error):.6f}  (dict M={int(fq.size)})")

    # --- KRLS variants on Example 2-style data (§6) -------------------------
    from repro.data.synthetic import gen_nonlinear_wiener

    xs, ys = gen_nonlinear_wiener(jax.random.PRNGKey(4), num_samples=3000)
    rff = sample_rff(jax.random.PRNGKey(5), 5, 300, sigma=5.0)
    _, out_rls = rff_krls_run(rff, xs, ys, lam=1e-4, beta=0.9995)
    fa, out_ald = ald_krls_run(xs, ys, sigma=5.0, nu=5e-3, capacity=128)
    print("KRLS (paper section 6):")
    print(f"  RFFKRLS   MSE {tail_mse(out_rls.error, 300):.6f}  (state: fixed D=300)")
    print(f"  ALD-KRLS  MSE {tail_mse(out_ald.error, 300):.6f}  (dict M={int(fa.size)})")


if __name__ == "__main__":
    main()
