"""Quickstart: RFFKLMS vs QKLMS on the paper's Example 2 (§5.2).

    PYTHONPATH=src python examples/quickstart.py

The whole point of the paper in ~20 lines: map inputs through a fixed random
Fourier feature bank, run plain LMS, get kernel-filter accuracy with a
fixed-size solution.
"""
import jax
import jax.numpy as jnp

from repro.core import qklms_run, rff_klms_run, sample_rff
from repro.data.synthetic import gen_nonlinear_wiener


def main():
    key = jax.random.PRNGKey(0)
    xs, ys = gen_nonlinear_wiener(key, num_samples=15000)  # model (9)

    # RFFKLMS: D=300 random features of the sigma=5 Gaussian kernel
    rff = sample_rff(jax.random.PRNGKey(1), input_dim=5, num_features=300, sigma=5.0)
    theta, out_rff = jax.jit(lambda: rff_klms_run(rff, xs, ys, mu=1.0))()
    print(f"RFFKLMS  solution size: {theta.theta.shape}  (fixed, forever)")

    # QKLMS baseline: quantized growing dictionary (eps = 5)
    final_q, out_q = jax.jit(
        lambda: qklms_run(xs, ys, sigma=5.0, mu=1.0, eps=5.0, capacity=256)
    )()
    print(f"QKLMS    dictionary size: {int(final_q.size)}  (grows with data)")

    for name, out in (("RFFKLMS", out_rff), ("QKLMS", out_q)):
        mse = float(jnp.mean(out.error[-1500:] ** 2))
        print(f"{name:8s} steady-state MSE: {mse:.5f}")


if __name__ == "__main__":
    main()
