"""Multi-tenant filter bank: 64 concurrent RFFKLMS streams, one jitted call.

Two serving patterns on synthetic nonlinear-Wiener traffic:

* per-tenant isolation — 64 tenants, shared hyperparams, each filter sees
  only its own stream;
* step-size sweep — the same stream replicated across the bank with a
  per-filter mu grid, picking the best mu in a single pass.

Run: PYTHONPATH=src python examples/filter_bank.py
"""
import jax
import jax.numpy as jnp

from repro.core import klms_learner, sample_rff
from repro.core.bank import bank_init, bank_run
from repro.serve import make_tick, run_stream
from repro.data.synthetic import gen_nonlinear_wiener


def main():
    bank, n, d, dfeat = 64, 1000, 5, 200
    rff = sample_rff(jax.random.PRNGKey(0), d, dfeat, sigma=5.0)

    # --- per-tenant isolation: 64 independent streams --------------------
    xs_all, ys_all = gen_nonlinear_wiener(
        jax.random.PRNGKey(1), num_samples=bank * n
    )
    xs = xs_all.reshape(bank, n, -1)
    ys = ys_all.reshape(bank, n)

    final, outs = run_stream("klms", rff, xs, ys, mu=0.5)
    tail_mse = jnp.mean(outs.error[:, -200:] ** 2, axis=1)
    print(f"{bank} tenants, {n} ticks each, one jitted call")
    print(f"  tail MSE: mean={float(jnp.mean(tail_mse)):.4f} "
          f"worst={float(jnp.max(tail_mse)):.4f}")

    # --- per-tick serving (the online loop a real server runs) -----------
    tick = make_tick("klms", rff, mu=0.5)
    state = jax.tree.map(jnp.zeros_like, final)
    for t in range(3):
        state, out = tick(state, xs[:, t], ys[:, t])
    print(f"  per-tick server: 3 ticks, mean |e| = "
          f"{float(jnp.mean(jnp.abs(out.error))):.4f}")

    # --- hyperparameter sweep: same stream, per-filter mu grid ------------
    mus = jnp.linspace(0.05, 1.5, bank)
    xs_rep = jnp.broadcast_to(xs[0], (bank,) + xs[0].shape)
    ys_rep = jnp.broadcast_to(ys[0], (bank,) + ys[0].shape)
    _, sweep = run_stream("klms", rff, xs_rep, ys_rep, mu=mus)
    sweep_mse = jnp.mean(sweep.error[:, -200:] ** 2, axis=1)
    best = int(jnp.argmin(sweep_mse))
    print(f"mu sweep over {bank} candidates in one pass: "
          f"best mu={float(mus[best]):.3f} "
          f"(tail MSE {float(sweep_mse[best]):.4f})")

    # --- the generic bank drives any OnlineLearner the same way ----------
    learner = klms_learner(rff, mu=0.5)
    states = bank_init(learner, bank)
    _, outs_g = jax.jit(lambda s: bank_run(learner, s, xs, ys))(states)
    drift = float(jnp.max(jnp.abs(outs_g.error - outs.error)))
    print(f"generic bank_run == fused serve path (max |diff| = {drift:.2e})")


if __name__ == "__main__":
    main()
