"""Distribution-layer tests.

The production-mesh checks (16x16 / 2x16x16, all 40 cells) live in the
dry-run artifacts (experiments/dryrun). Here: diffusion RFF-KLMS semantics
on small forced-multi-device meshes via a subprocess (device count locks at
backend init, so the main test process cannot do it), and sharding-spec
divisibility audited mathematically for every arch x mesh.
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_DIFFUSION_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, json
from repro.core.distributed import diffusion_klms_run
from repro.core.rff import sample_rff
from repro.data.synthetic import gen_nonlinear_wiener

mesh = jax.make_mesh((8,), ("data",))
rff = sample_rff(jax.random.PRNGKey(0), 5, 100, sigma=5.0)
nodes = 8
# ONE underlying system, split into per-node streams (the diffusion setting:
# common unknown plant, per-node observations)
xs_all, ys_all = gen_nonlinear_wiener(jax.random.PRNGKey(1), num_samples=600 * nodes)
xs = xs_all.reshape(nodes, 600, -1)
ys = ys_all.reshape(nodes, 600)

theta, errs = diffusion_klms_run(mesh, "data", rff, xs, ys, mu=0.5)
# combine every step => all thetas equal
spread = float(jnp.max(jnp.abs(theta - theta[0:1])))
mse_diff = float(jnp.mean(errs[:, -100:] ** 2))

theta_solo, errs_solo = diffusion_klms_run(
    mesh, "data", rff, xs, ys, mu=0.5, combine_every=10**9)
mse_solo = float(jnp.mean(errs_solo[:, -100:] ** 2))

theta_c, errs_c = diffusion_klms_run(
    mesh, "data", rff, xs, ys, mu=0.5, compress=True)
mse_comp = float(jnp.mean(errs_c[:, -100:] ** 2))

print(json.dumps({
    "spread": spread, "mse_diffusion": mse_diff,
    "mse_solo": mse_solo, "mse_compressed": mse_comp,
}))
"""


@pytest.mark.slow
def test_diffusion_klms_on_8_devices():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c", _DIFFUSION_SCRIPT],
        capture_output=True, text=True, env=env, timeout=420,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    # per-step combine keeps all node solutions identical
    assert res["spread"] < 1e-4
    # cooperation helps: diffusion <= isolated-node error floor
    assert res["mse_diffusion"] <= res["mse_solo"] * 1.05
    # int8+EF combine lands near the uncompressed floor
    assert res["mse_compressed"] <= res["mse_diffusion"] * 1.5


def _audit_specs(mesh_axes: dict):
    """Every sharded dim must divide by the product of its mesh axes."""
    from repro.configs import ARCH_IDS, get_config
    from repro.launch import sharding as sh
    from repro.launch.specs import resolve_cell
    from repro.configs.base import SHAPES

    class FakeMesh:
        def __init__(self, axes):
            self.shape = dict(axes)
            self.axis_names = tuple(axes)
            self.size = int(np.prod(list(axes.values())))

    mesh = FakeMesh(mesh_axes)
    bad = []
    for arch in ARCH_IDS:
        for shape_name in ("train_4k", "long_500k"):
            cfg, _ = resolve_cell(get_config(arch), SHAPES[shape_name])
            params_shape = jax.eval_shape(
                lambda cfg=cfg: __import__(
                    "repro.models.transformer", fromlist=["init_params"]
                ).init_params(jax.random.PRNGKey(0), cfg)
            )
            specs = sh.param_specs(cfg, mesh, params_shape)

            def check(path, leaf, spec):
                for dim, part in zip(leaf.shape, tuple(spec) + (None,) * 9):
                    if part is None:
                        continue
                    axes = part if isinstance(part, tuple) else (part,)
                    total = int(np.prod([mesh.shape[a] for a in axes]))
                    if dim % total:
                        bad.append((arch, shape_name, jax.tree_util.keystr(path), dim, total))

            jax.tree_util.tree_map_with_path(check, params_shape, specs)
    assert not bad, bad[:10]


def test_param_spec_divisibility_single_pod():
    _audit_specs({"data": 16, "model": 16})


def test_param_spec_divisibility_multi_pod():
    _audit_specs({"pod": 2, "data": 16, "model": 16})


def test_dryrun_artifacts_complete():
    """All 80 cells (40 x 2 meshes) must exist and be green."""
    d = os.path.join(REPO, "experiments", "dryrun")
    if not os.path.isdir(d):
        pytest.skip("dry-run artifacts not generated yet")
    files = [f for f in os.listdir(d) if f.endswith(".json")]
    assert len(files) >= 80, f"expected 80 cells, found {len(files)}"
    for f in files:
        rec = json.load(open(os.path.join(d, f)))
        assert "roofline" in rec and "memory" in rec, f
        assert rec["cost"]["flops_per_device"] > 0, f
