"""Pallas kernel sweeps vs pure-jnp oracles (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.rff_attention import rff_attention_pallas
from repro.kernels.rff_features import rff_features_pallas


@pytest.mark.parametrize(
    "m,d,D",
    [(7, 5, 300), (128, 128, 256), (200, 64, 100), (1, 2, 17), (257, 33, 129)],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rff_features_kernel_sweep(key, m, d, D, dtype):
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (m, d), dtype)
    w = jax.random.normal(ks[1], (d, D), jnp.float32).astype(dtype)
    b = jax.random.uniform(ks[2], (D,), jnp.float32, 0, 2 * np.pi).astype(dtype)
    out = rff_features_pallas(x, w, b, interpret=True)
    want = ref.rff_features_ref(x.astype(jnp.float32), w.astype(jnp.float32),
                                b.astype(jnp.float32))
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want), atol=tol, rtol=tol
    )


@pytest.mark.parametrize(
    "bank,d,D", [(64, 8, 512), (7, 5, 300), (1, 1, 17), (33, 128, 129)]
)
@pytest.mark.parametrize("per_stream_mu", [False, True])
def test_rff_klms_step_kernel_sweep(key, bank, d, D, per_stream_mu):
    """Fused featurize+predict+update step vs the two-pass oracle."""
    from repro.kernels.rff_klms_step import rff_klms_bank_step_pallas

    ks = jax.random.split(key, 6)
    theta = jax.random.normal(ks[0], (bank, D))
    x = jax.random.normal(ks[1], (bank, d))
    y = jax.random.normal(ks[2], (bank,))
    w = jax.random.normal(ks[3], (d, D))
    b = jax.random.uniform(ks[4], (D,), maxval=2 * np.pi)
    mu = (
        jax.random.uniform(ks[5], (bank,), minval=0.05, maxval=1.5)
        if per_stream_mu
        else jnp.asarray(0.5)
    )
    got = rff_klms_bank_step_pallas(theta, x, y, w, b, mu, interpret=True)
    want = ref.rff_klms_bank_step_ref(theta, x, y, w, b, mu)
    for g, w_ in zip(got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w_), atol=1e-5, rtol=1e-5
        )


@pytest.mark.parametrize("block_b", [1, 8, 32])
def test_rff_klms_step_block_shape_invariance(key, block_b):
    from repro.kernels.rff_klms_step import rff_klms_bank_step_pallas

    ks = jax.random.split(key, 5)
    theta = jax.random.normal(ks[0], (20, 200))
    x = jax.random.normal(ks[1], (20, 6))
    y = jax.random.normal(ks[2], (20,))
    w = jax.random.normal(ks[3], (6, 200))
    b = jax.random.uniform(ks[4], (200,), maxval=2 * np.pi)
    got = rff_klms_bank_step_pallas(
        theta, x, y, w, b, jnp.asarray(0.7), block_b=block_b, interpret=True
    )
    want = ref.rff_klms_bank_step_ref(theta, x, y, w, b, 0.7)
    for g, w_ in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w_), atol=1e-5)


def test_rff_klms_step_ops_dispatch(key):
    """mode='interpret' (Pallas) and mode='xla' agree through ops."""
    ks = jax.random.split(key, 5)
    theta = jax.random.normal(ks[0], (16, 128))
    x = jax.random.normal(ks[1], (16, 4))
    y = jax.random.normal(ks[2], (16,))
    w = jax.random.normal(ks[3], (4, 128))
    b = jax.random.uniform(ks[4], (128,), maxval=2 * np.pi)
    got = ops.rff_klms_bank_step(theta, x, y, w, b, 0.5, mode="interpret")
    want = ops.rff_klms_bank_step(theta, x, y, w, b, 0.5, mode="xla")
    for g, w_ in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w_), atol=1e-5)


@pytest.mark.parametrize("block", [(64, 64, 64), (128, 128, 128), (32, 256, 128)])
def test_rff_features_block_shape_invariance(key, block):
    bm, bn, bk = block
    x = jax.random.normal(key, (100, 48))
    w = jax.random.normal(jax.random.PRNGKey(1), (48, 200))
    b = jnp.zeros((200,))
    out = rff_features_pallas(x, w, b, block_m=bm, block_n=bn, block_k=bk,
                              interpret=True)
    want = ref.rff_features_ref(x, w, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("s,chunk", [(64, 16), (128, 128), (256, 64)])
@pytest.mark.parametrize("normalize", [True, False])
def test_rff_attention_kernel_sweep(key, s, chunk, normalize):
    bh, D, dv = 3, 32, 16
    ks = jax.random.split(key, 3)
    q = jax.nn.softplus(jax.random.normal(ks[0], (bh, s, D))) + 0.01
    k = jax.nn.softplus(jax.random.normal(ks[1], (bh, s, D))) + 0.01
    v = jax.random.normal(ks[2], (bh, s, dv))
    out = rff_attention_pallas(q, k, v, chunk=chunk, normalize=normalize,
                               interpret=True)
    want = ref.rff_attention_ref(q, k, v, normalize=normalize)
    scale = float(jnp.max(jnp.abs(want))) + 1e-6
    np.testing.assert_allclose(
        np.asarray(out) / scale, np.asarray(want) / scale, atol=2e-5
    )


def test_rff_attention_xla_path_matches_ref(key):
    bh, s, D, dv = 2, 192, 24, 8
    ks = jax.random.split(key, 3)
    q = jax.nn.relu(jax.random.normal(ks[0], (bh, s, D))) + 0.05
    k = jax.nn.relu(jax.random.normal(ks[1], (bh, s, D))) + 0.05
    v = jax.random.normal(ks[2], (bh, s, dv))
    out = ops.rff_attention(q, k, v, mode="xla", chunk=48)
    want = ref.rff_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4)


def test_state_semantics_prefill_then_decode(key):
    """Chunked prefill state == sequential decode state (the fixed-size-state
    contract the serving path relies on)."""
    bh, s, D, dv = 2, 64, 16, 8
    ks = jax.random.split(key, 4)
    q = jax.nn.relu(jax.random.normal(ks[0], (bh, s + 1, D))) + 0.05
    k = jax.nn.relu(jax.random.normal(ks[1], (bh, s + 1, D))) + 0.05
    v = jax.random.normal(ks[2], (bh, s + 1, dv))
    # oracle full run
    outs_all, S_all, Z_all = ref.rff_attention_state_ref(q, k, v)
    # prefill s tokens via state oracle, then one decode step via ops
    _, S_pre, Z_pre = ref.rff_attention_state_ref(q[:, :s], k[:, :s], v[:, :s])
    out_dec, S_new, Z_new = ops.rff_attention_decode(
        S_pre, Z_pre, q[:, s], k[:, s], v[:, s]
    )
    np.testing.assert_allclose(np.asarray(out_dec), np.asarray(outs_all[:, s]),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(S_new), np.asarray(S_all), atol=1e-5)
    np.testing.assert_allclose(np.asarray(Z_new), np.asarray(Z_all), atol=1e-5)


@pytest.mark.parametrize("bh,t,dh,D,dv", [(3, 8, 16, 32, 16), (2, 17, 5, 300, 8)])
@pytest.mark.parametrize("feature_kind", ["prf", "trig"])
def test_rff_decode_block_kernel_sweep(key, bh, t, dh, D, dv, feature_kind):
    """Fused decode-block kernel (VMEM-resident S/z across T in-kernel
    ticks) vs the scan-of-ticks oracle."""
    from repro.kernels.rff_attention import rff_attention_decode_block_pallas

    ks = jax.random.split(key, 7)
    q = jax.random.normal(ks[0], (bh, t, dh)) * 0.1
    k = jax.random.normal(ks[1], (bh, t, dh)) * 0.1
    v = jax.random.normal(ks[2], (bh, t, dv))
    w = jax.random.normal(ks[3], (dh, D)) * 0.3
    b = jax.random.uniform(ks[4], (D,), maxval=2 * np.pi)
    s_state = jax.random.normal(ks[5], (bh, D, dv)) * 0.1
    z_state = jax.nn.relu(jax.random.normal(ks[6], (bh, D))) + 0.5
    normalize = feature_kind == "prf"
    got = rff_attention_decode_block_pallas(
        s_state, z_state, q, k, v, w, b, feature_kind=feature_kind,
        normalize=normalize, interpret=True,
    )
    want = ref.rff_attention_decode_block_ref(
        s_state, z_state, q, k, v, w, b, feature_kind=feature_kind,
        normalize=normalize,
    )
    for g, w_ in zip(got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w_), atol=1e-5, rtol=1e-5
        )


@pytest.mark.parametrize(
    "s,dh,dv,bq,bk", [(256, 64, 64, 128, 128), (256, 128, 64, 256, 64),
                      (384, 32, 32, 128, 384)]
)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_kernel_sweep(key, s, dh, dv, bq, bk, causal):
    from repro.kernels.flash_attention import flash_attention_pallas

    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, s, dh))
    k = jax.random.normal(ks[1], (2, s, dh))
    v = jax.random.normal(ks[2], (2, s, dv))
    out = flash_attention_pallas(
        q, k, v, block_q=bq, block_k=bk, causal=causal, interpret=True
    )
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_flash_vs_model_dense_attention(key):
    """Pallas flash == the model's dense attention path (same math)."""
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.models.attention import dense_attention

    ks = jax.random.split(key, 3)
    b, s, h, dh = 2, 128, 4, 32
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, h, dh))
    v = jax.random.normal(ks[2], (b, s, h, dh))
    want = dense_attention(q, k, v, causal=True)
    got = flash_attention_pallas(
        q.transpose(0, 2, 1, 3).reshape(b * h, s, dh),
        k.transpose(0, 2, 1, 3).reshape(b * h, s, dh),
        v.transpose(0, 2, 1, 3).reshape(b * h, s, dh),
        block_q=64, block_k=64, interpret=True,
    ).reshape(b, h, s, dh).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
