"""Read-path overhaul tests: fused predict-only kernel vs the vmapped
adapter oracle, the mixed-precision contract per feature family, the
VMEM-budget default chunk T, and adaptive flush sizing in the serve queue.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bank import (
    bank_predict,
    bank_predict_block,
    klms_bank_init,
)
from repro.core.klms import LMSState, rff_klms_run
from repro.core.learner import klms_learner
from repro.core.rff import sample_rff
from repro.features import as_trig, make_feature_map
from repro.kernels import ops, ref
from repro.kernels.chunking import default_chunk_t
from repro.kernels.rff_predict import rff_bank_predict_pallas
from repro.serve.queue import klms_micro_batch_queue

TRIG_FAMILIES = ("rff", "orf", "qmc", "gq")
ALL_FAMILIES = TRIG_FAMILIES + ("taylor",)

# bf16 has an 8-bit mantissa: a D-term f32 accumulation of bf16-rounded
# features against unit-scale theta lands within ~2^-8 of the f32 path.
# The contract tests/README quote is this bound per family.
BF16_PRED_TOL = 2e-2


def _fm(family, d=4, dfeat=64, sigma=2.0, seed=0):
    return make_feature_map(
        family, d, dfeat, sigma, key=jax.random.PRNGKey(seed)
    )


def _bank_inputs(key, bank, qlen, d, dfeat, scale=0.3):
    ks = jax.random.split(key, 2)
    theta = scale * jax.random.normal(ks[0], (bank, dfeat))
    xq = jax.random.normal(ks[1], (bank, qlen, d))
    return theta, xq


@pytest.mark.parametrize("family", TRIG_FAMILIES)
def test_predict_kernel_bitwise_vs_oracle_f32(key, family):
    """Interpret-mode fused predict == the predict oracle, bitwise, for
    every trig family (the acceptance contract of the read-path kernel)."""
    fm = _fm(family)
    tf = as_trig(fm)
    theta, xq = _bank_inputs(key, 5, 13, 4, tf.num_features)
    want = ref.rff_bank_predict_ref(theta, xq, tf.omega, tf.bias, tf.scale)
    got = rff_bank_predict_pallas(
        theta, xq, tf.omega, tf.bias, tf.scale, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("family", TRIG_FAMILIES)
def test_predict_kernel_bitwise_vs_oracle_bf16(key, family):
    """Kernel and oracle share ONE mixed-precision definition — interpret
    mode matches bitwise at bf16 too; the tolerance lives between bf16 and
    the f32 reference, not between kernel and oracle."""
    fm = _fm(family)
    tf = as_trig(fm)
    theta, xq = _bank_inputs(key, 5, 13, 4, tf.num_features)
    want16 = ref.rff_bank_predict_ref(
        theta, xq, tf.omega, tf.bias, tf.scale, "bf16"
    )
    got16 = rff_bank_predict_pallas(
        theta, xq, tf.omega, tf.bias, tf.scale, precision="bf16", interpret=True
    )
    np.testing.assert_array_equal(np.asarray(got16), np.asarray(want16))
    want32 = ref.rff_bank_predict_ref(theta, xq, tf.omega, tf.bias, tf.scale)
    assert float(jnp.max(jnp.abs(want16 - want32))) < BF16_PRED_TOL


@pytest.mark.parametrize(
    "bank,qlen,d,D", [(1, 1, 2, 17), (9, 70, 5, 96), (16, 3, 8, 128)]
)
def test_predict_kernel_shape_sweep(key, bank, qlen, d, D):
    """Padding on every axis (bank, query, d, D) is exact."""
    rff = sample_rff(jax.random.PRNGKey(3), d, D, sigma=2.0)
    tf = as_trig(rff)
    theta, xq = _bank_inputs(key, bank, qlen, d, D)
    want = ref.rff_bank_predict_ref(theta, xq, tf.omega, tf.bias, tf.scale)
    got = rff_bank_predict_pallas(
        theta, xq, tf.omega, tf.bias, tf.scale, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # Block-shape invariance: different (block_b, block_q) tilings agree.
    got2 = rff_bank_predict_pallas(
        theta, xq, tf.omega, tf.bias, tf.scale, block_b=1, block_q=8, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(got2), np.asarray(want))


def test_predict_oracle_matches_vmapped_adapter(key):
    """The predict oracle IS the PR-1 `bank_predict` adapter, batched: per
    query they agree to reduction-order rounding (matvec vs mul-reduce)."""
    rff = sample_rff(jax.random.PRNGKey(0), 5, 96, sigma=2.0)
    tf = as_trig(rff)
    theta, xq = _bank_inputs(key, 6, 11, 5, 96)
    learner = klms_learner(rff, 0.5)
    state = LMSState(theta=theta, step=jnp.zeros((6,), jnp.int32))
    adapter = jnp.stack(
        [bank_predict(learner, state, xq[:, i]) for i in range(11)], axis=1
    )
    oracle = ref.rff_bank_predict_ref(theta, xq, tf.omega, tf.bias, tf.scale)
    np.testing.assert_allclose(
        np.asarray(adapter), np.asarray(oracle), atol=1e-6, rtol=1e-6
    )


@pytest.mark.parametrize("family", ALL_FAMILIES)
def test_bank_predict_block_all_families(key, family):
    """The family-agnostic read path (fused for trig, featurize fallback
    for taylor) matches the per-query adapter for every family."""
    fm = _fm(family)
    dfeat = fm.num_features
    theta, xq = _bank_inputs(key, 4, 7, 4, dfeat)
    state = LMSState(theta=theta, step=jnp.zeros((4,), jnp.int32))
    learner = klms_learner(fm, 0.5)
    adapter = jnp.stack(
        [bank_predict(learner, state, xq[:, i]) for i in range(7)], axis=1
    )
    got = bank_predict_block(state, xq, fm, mode="xla")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(adapter), atol=1e-6, rtol=1e-6
    )
    got_interp = bank_predict_block(state, xq, fm, mode="interpret")
    np.testing.assert_allclose(
        np.asarray(got_interp), np.asarray(adapter), atol=1e-6, rtol=1e-6
    )


@pytest.mark.parametrize("family", ALL_FAMILIES)
def test_bf16_read_tolerance_all_families(key, family):
    """The documented bf16-vs-f32 prediction tolerance holds for all five
    families (taylor runs the generic bf16-feature fallback)."""
    fm = _fm(family)
    dfeat = fm.num_features
    theta, xq = _bank_inputs(key, 4, 32, 4, dfeat)
    state = LMSState(theta=theta, step=jnp.zeros((4,), jnp.int32))
    f32 = bank_predict_block(state, xq, fm, mode="xla")
    bf16 = bank_predict_block(state, xq, fm, mode="xla", precision="bf16")
    err = float(jnp.max(jnp.abs(f32 - bf16)))
    assert err < BF16_PRED_TOL, (family, err)
    assert err > 0  # bf16 really ran at reduced precision


def test_rff_features_precision_contract(key):
    """ops.rff_features precision knob: bf16 output dtype, interpret-vs-ref
    bitwise, and error bounded against the f32 path."""
    rff = sample_rff(jax.random.PRNGKey(1), 6, 80, sigma=2.0)
    tf = as_trig(rff)
    x = jax.random.normal(key, (33, 6))
    z32 = ops.rff_features(x, tf.omega, tf.bias, tf.scale, mode="xla")
    z16 = ops.rff_features(
        x, tf.omega, tf.bias, tf.scale, mode="xla", precision="bf16"
    )
    assert z16.dtype == jnp.bfloat16
    zi = ops.rff_features(
        x, tf.omega, tf.bias, tf.scale, mode="interpret", precision="bf16"
    )
    np.testing.assert_array_equal(np.asarray(zi), np.asarray(z16))
    # |z| <= max scale, so absolute feature error sits at bf16 epsilon.
    assert float(jnp.max(jnp.abs(z16.astype(jnp.float32) - z32))) < 1e-2
    # f32 stays bitwise-legacy.
    z_legacy = ops.rff_features(x, tf.omega, tf.bias, tf.scale, mode="xla",
                                precision="f32")
    np.testing.assert_array_equal(np.asarray(z_legacy), np.asarray(z32))


def test_default_chunk_t_corners():
    """Pin the VMEM-budget heuristic at representative (B, D) corners."""
    # Serving-sized KLMS bank: budget is stream-bound -> saturates the cap.
    assert default_chunk_t(16, 128) == 512
    # KRLS carries the (D, D) P tile; still saturates at moderate D...
    assert default_chunk_t(8, 512, pmat=True) == 512
    # ...but a huge-D P busts the budget entirely -> the dispatch floor.
    assert default_chunk_t(8, 1408, pmat=True) == 8
    # Tighter budget exercises the power-of-two floor between the clamps.
    assert default_chunk_t(16, 256, vmem_budget=2**20) == 128
    # f64 streams halve the tick count before clamping.
    assert default_chunk_t(16, 256, jnp.float64, vmem_budget=2**20) == 64
    # A wide input dim is charged at its real lane-padded width: the W
    # tile and x streams shrink the budget (vs the low-d default of one
    # lane tile, which would still pick 512 here).
    assert default_chunk_t(16, 2048, input_dim=512) == 128
    # Everything stays inside the documented clamp range.
    for bank in (1, 8, 64):
        for dfeat in (17, 128, 2048):
            for pmat in (False, True):
                for din in (None, 4, 700):
                    t = default_chunk_t(bank, dfeat, pmat=pmat,
                                        input_dim=din)
                    assert 8 <= t <= 512 and t & (t - 1) == 0


def test_precision_knob_validated_identically_everywhere(key):
    """A typo'd precision string raises on EVERY backend path instead of
    silently running f32 on one of them."""
    rff = sample_rff(jax.random.PRNGKey(0), 4, 32, sigma=2.0)
    tf = as_trig(rff)
    theta, xq = _bank_inputs(key, 2, 3, 4, 32)
    state = LMSState(theta=theta, step=jnp.zeros((2,), jnp.int32))
    x2 = xq.reshape(-1, 4)
    for bad in ("f16", "fp16", "half"):
        with pytest.raises(ValueError):
            ref.rff_bank_predict_ref(
                theta, xq, tf.omega, tf.bias, tf.scale, bad
            )
        with pytest.raises(ValueError):
            rff_bank_predict_pallas(
                theta, xq, tf.omega, tf.bias, tf.scale, precision=bad,
                interpret=True,
            )
        with pytest.raises(ValueError):
            ops.rff_features(
                x2, tf.omega, tf.bias, tf.scale, mode="interpret",
                precision=bad,
            )
        with pytest.raises(ValueError):
            bank_predict_block(state, xq, rff, mode="xla", precision=bad)
    # The aliases stay accepted on every path.
    out = bank_predict_block(state, xq, rff, mode="xla", precision="bfloat16")
    assert out.shape == (2, 3)


def test_chunk_none_uses_default_and_matches_explicit(key):
    """chunk=None routes through default_chunk_t and stays numerically the
    per-tick schedule (the KLMS chunk path is bitwise by contract)."""
    rff = sample_rff(jax.random.PRNGKey(0), 4, 48, sigma=2.0)
    tf = as_trig(rff)
    bank, n = 3, 40
    ks = jax.random.split(key, 2)
    xs = jax.random.normal(ks[0], (bank, n, 4))
    ys = jax.random.normal(ks[1], (bank, n))
    theta0 = jnp.zeros((bank, 48))
    th_none, p_none, e_none = ops.rff_klms_bank_chunk(
        theta0, xs, ys, tf.omega, tf.bias, 0.5, None, tf.scale, mode="xla"
    )
    th_exp, p_exp, e_exp = ops.rff_klms_bank_chunk(
        theta0, xs, ys, tf.omega, tf.bias, 0.5, None, tf.scale, mode="xla",
        chunk=8,
    )
    np.testing.assert_array_equal(np.asarray(th_none), np.asarray(th_exp))
    np.testing.assert_array_equal(np.asarray(e_none), np.asarray(e_exp))


def test_adaptive_queue_matches_sequential():
    """Backlog-adaptive flush T preserves the ragged-stream contract: every
    tenant sees exactly its own sequential trajectory."""
    rff = sample_rff(jax.random.PRNGKey(0), 5, 64, sigma=5.0)
    rng = np.random.RandomState(1)
    xs = rng.randn(120, 5).astype(np.float32)
    ys = rng.randn(120).astype(np.float32)
    streams = {0: 55, 1: 7, 2: 0, 3: 23}
    per_tenant, offs = {}, 0
    for t, n in streams.items():
        per_tenant[t] = (xs[offs:offs + n], ys[offs:offs + n])
        offs += n

    q = klms_micro_batch_queue(rff, 4, mu=0.5, chunk=16, mode="xla",
                               adaptive=True)
    order = [t for t, n in streams.items() for _ in range(n)]
    rng.shuffle(order)
    results = {t: [] for t in streams}
    iters = {t: 0 for t in streams}
    seen_chunks = set()
    for i, t in enumerate(order):
        k = iters[t]
        iters[t] += 1
        q.submit(t, per_tenant[t][0][k], per_tenant[t][1][k])
        if i % 7 == 6:  # frequent flushes -> shallow adaptive chunks
            seen_chunks.add(q._flush_chunk())
            for b, res in q.flush().items():
                results[b].extend(res)
    while any(q.backlog()):
        seen_chunks.add(q._flush_chunk())
        for b, res in q.flush().items():
            results[b].extend(res)

    assert q.arrivals == [55, 7, 0, 23]
    assert len(seen_chunks) > 1  # adaptation actually varied T
    assert all(1 <= c <= 16 and c & (c - 1) == 0 for c in seen_chunks)
    for t, n in streams.items():
        if n == 0:
            assert not results[t]
            continue
        assert len(results[t]) == n
        _, want = rff_klms_run(rff, per_tenant[t][0], per_tenant[t][1], 0.5)
        got = np.array([e for _, e in results[t]])
        np.testing.assert_allclose(got, np.asarray(want.error), atol=1e-5)


def test_bank_predict_block_on_trained_bank(key):
    """End-to-end: train a bank, then the fused read path reproduces the
    adapter's predictions on the trained theta."""
    rff = sample_rff(jax.random.PRNGKey(0), 5, 64, sigma=5.0)
    learner = klms_learner(rff, 0.5)
    bank = 4
    state = klms_bank_init(rff, bank)
    ks = jax.random.split(key, 2)
    xs = jax.random.normal(ks[0], (bank, 30, 5))
    ys = jax.random.normal(ks[1], (bank, 30))
    from repro.core.bank import klms_bank_run

    state, _ = klms_bank_run(rff, xs, ys, 0.5, state=state, mode="xla")
    xq = jax.random.normal(jax.random.PRNGKey(9), (bank, 5, 5))
    adapter = jnp.stack(
        [bank_predict(learner, state, xq[:, i]) for i in range(5)], axis=1
    )
    got = bank_predict_block(state, xq, rff, mode="xla")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(adapter), atol=1e-6, rtol=1e-6
    )
