"""Convergence-theory oracles (paper Lemma 1 / Prop. 1)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rff import sample_rff
from repro.core.theory import (
    max_stable_mu,
    mse_evolution,
    rzz_closed_form,
    rzz_monte_carlo,
    steady_state_mse,
    theta_opt,
)


def test_rzz_closed_form_matches_monte_carlo(key):
    rff = sample_rff(key, 4, 40, sigma=3.0)
    cf = rzz_closed_form(rff, sigma_x=1.3)
    mc = rzz_monte_carlo(rff, 1.3, jax.random.PRNGKey(1), 150_000)
    np.testing.assert_allclose(np.asarray(cf), np.asarray(mc), atol=5e-3)


def test_rzz_positive_definite(key):
    """Lemma 1: distinct omegas -> strictly PD."""
    rff = sample_rff(key, 4, 60, sigma=2.0)
    eig = jnp.linalg.eigvalsh(rzz_closed_form(rff, 1.0))
    assert float(eig[0]) > 0


def test_max_stable_mu_positive(key):
    rff = sample_rff(key, 5, 64, sigma=5.0)
    mu = float(max_stable_mu(rzz_closed_form(rff, 1.0)))
    assert mu > 0


def test_theta_opt_predicts_noise_free_targets(key):
    """Eq. (8): theta_opt ~ Z_C a reproduces the kernel expansion."""
    from repro.core.rff import gaussian_kernel, rff_features

    rff = sample_rff(key, 4, 4096, sigma=3.0)
    centers = jax.random.normal(jax.random.PRNGKey(1), (6, 4))
    coeffs = jax.random.normal(jax.random.PRNGKey(2), (6,))
    th = theta_opt(rff, centers, coeffs)
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 4))
    target = gaussian_kernel(x[:, None, :], centers[None], 3.0) @ coeffs
    pred = rff_features(rff, x) @ th
    assert float(jnp.sqrt(jnp.mean((pred - target) ** 2))) < 0.15


def test_mse_evolution_decreasing_then_flat(key):
    rff = sample_rff(key, 4, 32, sigma=3.0)
    rzz = rzz_closed_form(rff, 1.0)
    a0 = jnp.eye(32) * 1.0
    js = mse_evolution(rzz, a0, mu=0.5, sigma_eta=0.1, num_steps=4000)
    assert float(js[0]) > float(js[-1])
    # settles near the closed-form steady state
    ss = float(steady_state_mse(rzz, 0.5, 0.1))
    assert abs(float(js[-1]) - ss) / ss < 0.2
