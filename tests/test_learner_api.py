"""Unified OnlineLearner adapters + filter bank vs the legacy drivers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ald_krls_learner,
    ald_krls_run,
    bank_init,
    bank_predict,
    bank_run,
    klms_bank_run,
    klms_learner,
    krls_learner,
    nklms_learner,
    qklms_learner,
    qklms_run,
    rff_klms_run,
    rff_krls_run,
    sample_rff,
)
from repro.data.synthetic import gen_nonlinear_wiener
from repro.serve import make_bank_server, reset_tenants, serve_bank_stream


@pytest.fixture(scope="module")
def stream():
    return gen_nonlinear_wiener(jax.random.PRNGKey(5), num_samples=400)


@pytest.fixture(scope="module")
def rff():
    return sample_rff(jax.random.PRNGKey(0), 5, 100, sigma=5.0)


def _assert_same_run(out_a, out_b):
    np.testing.assert_array_equal(
        np.asarray(out_a.error), np.asarray(out_b.error)
    )
    np.testing.assert_array_equal(
        np.asarray(out_a.prediction), np.asarray(out_b.prediction)
    )


def test_klms_adapter_matches_legacy(rff, stream):
    xs, ys = stream
    _, out = klms_learner(rff, 0.5).run(None, xs, ys)
    _, want = rff_klms_run(rff, xs, ys, mu=0.5)
    _assert_same_run(out, want)


def test_nklms_adapter_matches_legacy(rff, stream):
    xs, ys = stream
    _, out = nklms_learner(rff, 0.5).run(None, xs, ys)
    _, want = rff_klms_run(rff, xs, ys, mu=0.5, normalized=True)
    _assert_same_run(out, want)


def test_krls_adapter_matches_legacy(rff, stream):
    xs, ys = stream
    _, out = krls_learner(rff, lam=1e-4, beta=0.9995).run(None, xs, ys)
    _, want = rff_krls_run(rff, xs, ys, lam=1e-4, beta=0.9995)
    _assert_same_run(out, want)


def test_qklms_adapter_matches_legacy(stream):
    xs, ys = stream
    learner = qklms_learner(5, sigma=5.0, mu=1.0, eps=5.0, capacity=128)
    _, out = learner.run(None, xs, ys)
    _, want = qklms_run(xs, ys, sigma=5.0, mu=1.0, eps=5.0, capacity=128)
    _assert_same_run(out, want)


def test_ald_krls_adapter_matches_legacy(stream):
    xs, ys = stream
    learner = ald_krls_learner(5, sigma=5.0, nu=5e-3, capacity=64)
    _, out = learner.run(None, xs, ys)
    _, want = ald_krls_run(xs, ys, sigma=5.0, nu=5e-3, capacity=64)
    _assert_same_run(out, want)


@pytest.mark.parametrize(
    "make",
    [
        lambda rff: klms_learner(rff, 0.5),
        lambda rff: krls_learner(rff),
        lambda rff: qklms_learner(5, 5.0, 1.0, 5.0, capacity=64),
        lambda rff: ald_krls_learner(5, 5.0, nu=5e-3, capacity=64),
    ],
    ids=["klms", "krls", "qklms", "ald_krls"],
)
def test_predict_matches_step_prediction(make, rff, stream):
    """predict(state, x) == the prediction step() would make on x."""
    xs, ys = stream
    learner = make(rff)
    state, _ = learner.run(None, xs[:100], ys[:100])
    _, out = learner.step(state, xs[100], ys[100])
    pred = learner.predict(state, xs[100])
    np.testing.assert_allclose(
        np.asarray(pred), np.asarray(out.prediction), atol=1e-6
    )


@pytest.mark.parametrize(
    "make,atol",
    [
        (lambda rff: klms_learner(rff, 0.5), 1e-6),
        # KRLS propagates a (D, D) P matrix: batched-matmul accumulation
        # order differs from the sequential matvec, so allow f32 drift.
        (lambda rff: krls_learner(rff), 1e-3),
        (lambda rff: qklms_learner(5, 5.0, 1.0, 5.0, capacity=64), 1e-6),
    ],
    ids=["klms", "krls", "qklms"],
)
def test_bank_matches_sequential_runs(make, atol, rff, stream):
    """vmapped bank over B streams == B independent sequential runs."""
    xs, ys = stream
    bank, n = 5, 80
    xb = xs[: bank * n].reshape(bank, n, -1)
    yb = ys[: bank * n].reshape(bank, n)
    learner = make(rff)
    states = bank_init(learner, bank)
    final, outs = jax.jit(lambda s: bank_run(learner, s, xb, yb))(states)
    for i in range(bank):
        _, want = learner.run(None, xb[i], yb[i])
        np.testing.assert_allclose(
            np.asarray(outs.error[i]), np.asarray(want.error), atol=atol
        )
    preds = bank_predict(learner, final, xb[:, -1])
    assert preds.shape == (bank,)


def test_fused_klms_bank_matches_sequential(rff, stream):
    """Fused-step bank (shared feature map) == sequential rff_klms_run."""
    xs, ys = stream
    bank, n = 4, 100
    xb = xs[: bank * n].reshape(bank, n, -1)
    yb = ys[: bank * n].reshape(bank, n)
    _, outs = jax.jit(lambda: klms_bank_run(rff, xb, yb, 0.5, mode="xla"))()
    for i in range(bank):
        _, want = rff_klms_run(rff, xb[i], yb[i], mu=0.5)
        np.testing.assert_allclose(
            np.asarray(outs.error[i]), np.asarray(want.error), atol=1e-5
        )


def test_fused_klms_bank_per_stream_mu(rff, stream):
    """(B,) mu vector == per-stream sequential runs with scalar mus."""
    xs, ys = stream
    bank, n = 3, 100
    xb = jnp.broadcast_to(xs[:n], (bank, n, xs.shape[-1]))
    yb = jnp.broadcast_to(ys[:n], (bank, n))
    mus = jnp.array([0.1, 0.5, 1.0])
    _, outs = klms_bank_run(rff, xb, yb, mus, mode="xla")
    for i in range(bank):
        _, want = rff_klms_run(rff, xs[:n], ys[:n], mu=float(mus[i]))
        np.testing.assert_allclose(
            np.asarray(outs.error[i]), np.asarray(want.error), atol=1e-5
        )


def test_bank_serves_64_streams_one_jit(rff):
    """Acceptance: >=64 concurrent streams through a single jitted call."""
    bank, n = 64, 50
    xs_all, ys_all = gen_nonlinear_wiener(
        jax.random.PRNGKey(9), num_samples=bank * n
    )
    xb = xs_all.reshape(bank, n, -1)
    yb = ys_all.reshape(bank, n)
    served = jax.jit(
        lambda: serve_bank_stream(rff, xb, yb, mu=0.5, mode="xla")
    )
    final, outs = served()
    assert outs.error.shape == (bank, n)
    assert final.theta.shape == (bank, rff.num_features)
    assert bool(jnp.all(final.step == n))
    # learning happened on every stream
    assert float(jnp.mean(outs.error[:, -10:] ** 2)) < float(
        jnp.mean(outs.error[:, :10] ** 2)
    )


def test_bank_server_tick_and_tenant_reset(rff, stream):
    xs, ys = stream
    bank = 8
    xb = xs[:bank]
    yb = ys[:bank]
    tick = make_bank_server(rff, mu=0.5, mode="xla")
    state, _ = serve_bank_stream(
        rff, jnp.broadcast_to(xb[:, None], (bank, 1, 5)), yb[:, None],
        mu=0.5, mode="xla",
    )
    state, out = tick(state, xb, yb)
    assert out.prediction.shape == (bank,)
    state = reset_tenants(state, jnp.array([2, 5]))
    assert float(jnp.max(jnp.abs(state.theta[2]))) == 0.0
    assert int(state.step[5]) == 0
    assert float(jnp.max(jnp.abs(state.theta[0]))) > 0.0
