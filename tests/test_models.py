"""Per-arch smoke tests (REQUIRED: reduced config, one forward/train step on
CPU, output shapes + no NaNs) + decode/forward consistency integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from dataclasses import replace

from repro.configs import ARCH_IDS, get_config
from repro.models import (
    decode_state_init,
    decode_step,
    forward,
    init_params,
    lm_loss,
    with_rff_attention,
)

B, S = 2, 32


def _batch(cfg, key):
    if cfg.frontend:
        embeds = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
        labels = jnp.zeros((B, S), jnp.int32)
        return dict(embeds=embeds, labels=labels, tokens=None)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return dict(tokens=toks, embeds=None, labels=None)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch, key):
    cfg = get_config(arch).reduced()
    params = init_params(key, cfg)
    b = _batch(cfg, key)
    logits = forward(params, cfg, tokens=b["tokens"], embeds=b["embeds"])
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())

    loss, grads = jax.value_and_grad(
        lambda p: lm_loss(p, cfg, tokens=b["tokens"], embeds=b["embeds"],
                          labels=b["labels"])
    )(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode_step(arch, key):
    cfg = get_config(arch).reduced()
    params = init_params(key, cfg)
    state = decode_state_init(cfg, B, max_len=64)
    if cfg.frontend:
        emb = jax.random.normal(key, (B, 1, cfg.d_model), jnp.float32)
        logits, state = decode_step(params, cfg, state, None, embed_in=emb)
    else:
        logits, state = decode_step(params, cfg, state, jnp.zeros((B,), jnp.int32))
    assert logits.shape == (B, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize(
    "arch", ["llama3-8b", "qwen2-0.5b", "mamba2-130m", "minicpm3-4b"]
)
def test_decode_matches_forward(arch, key):
    """Token-by-token decode logits == full-sequence forward logits. This
    pins cache indexing, RoPE offsets and state updates across families."""
    cfg = get_config(arch).reduced()
    params = init_params(key, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, cfg.vocab_size)
    full = forward(params, cfg, tokens=toks)  # (B, 8, V)

    state = decode_state_init(cfg, B, max_len=16)
    outs = []
    for t in range(8):
        lg, state = decode_step(params, cfg, state, toks[:, t])
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full), atol=2e-3, rtol=2e-3
    )


def test_rff_decode_matches_forward(key):
    """Same consistency for the paper's RFF attention (fixed-size state)."""
    cfg = with_rff_attention(get_config("llama3-8b").reduced())
    params = init_params(key, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, cfg.vocab_size)
    full = forward(params, cfg, tokens=toks)
    state = decode_state_init(cfg, B, max_len=16)
    outs = []
    for t in range(8):
        lg, state = decode_step(params, cfg, state, toks[:, t])
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=5e-3,
                               rtol=5e-3)


def test_rff_block_decode_matches_per_token(key):
    """Block decode through the fused dispatch == the per-token loop at the
    attention-layer level, bitwise — blocking only changes launch count."""
    from repro.models import rff_attention as rff_mod

    cfg = with_rff_attention(get_config("llama3-8b").reduced())
    p = rff_mod.rff_attn_init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (B, 8, cfg.d_model)) * 0.1
    st_b = rff_mod.rff_state_init(cfg, B)
    out_blk, st_b = rff_mod.rff_attn_decode_block(p, cfg, x, st_b)
    st_s = rff_mod.rff_state_init(cfg, B)
    outs = []
    for t in range(8):
        o, st_s = rff_mod.rff_attn_decode(p, cfg, x[:, t:t + 1], st_s)
        outs.append(o)
    np.testing.assert_array_equal(
        np.asarray(out_blk), np.asarray(jnp.concatenate(outs, axis=1))
    )
    np.testing.assert_array_equal(np.asarray(st_b.s), np.asarray(st_s.s))
    assert int(st_b.pos) == int(st_s.pos) == 8


def test_hybrid_decode_matches_forward(key):
    cfg = get_config("recurrentgemma-2b").reduced()
    params = init_params(key, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, cfg.vocab_size)
    full = forward(params, cfg, tokens=toks)
    state = decode_state_init(cfg, B, max_len=16)
    outs = []
    for t in range(8):
        lg, state = decode_step(params, cfg, state, toks[:, t])
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-3,
                               rtol=2e-3)


def test_causality(key):
    """Changing future tokens must not change past logits (all families)."""
    for arch in ("llama3-8b", "mamba2-130m", "recurrentgemma-2b"):
        cfg = get_config(arch).reduced()
        params = init_params(key, cfg)
        t1 = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab_size)
        t2 = t1.at[:, 8:].set((t1[:, 8:] + 7) % cfg.vocab_size)
        l1 = forward(params, cfg, tokens=t1)
        l2 = forward(params, cfg, tokens=t2)
        np.testing.assert_allclose(
            np.asarray(l1[:, :8]), np.asarray(l2[:, :8]), atol=1e-4,
            err_msg=arch,
        )


def test_head_padding_inert(key):
    """pad_heads_to changes nothing: function equal, pad grads zero."""
    base = replace(
        get_config("llama3-8b").reduced(), num_heads=3, num_kv_heads=1,
        pad_heads_to=0,
    )
    padded = replace(base, pad_heads_to=4)
    p_pad = init_params(key, padded)

    def slice_heads(path, leaf):
        names = [str(k.key) for k in path if hasattr(k, "key")]
        if "attn" in names and leaf.ndim == 3:
            if names[-2] == "wq":
                return leaf[:, :3, :]
            if names[-2] == "wo":
                return leaf[:3]
        return leaf

    p_ref = jax.tree_util.tree_map_with_path(slice_heads, p_pad)
    toks = jax.random.randint(key, (2, 16), 0, base.vocab_size)
    np.testing.assert_allclose(
        np.asarray(forward(p_ref, base, tokens=toks)),
        np.asarray(forward(p_pad, padded, tokens=toks)),
        atol=1e-5,
    )
    g = jax.grad(lambda p: lm_loss(p, padded, tokens=toks))(p_pad)
    go = g["blocks_list"][0]["attn"]["wo"]["w"]
    assert float(jnp.abs(go[3:]).max()) == 0.0


def test_vocab_padding_inert(key):
    """padded vocab slots never win the softmax and get -inf logits."""
    cfg = replace(get_config("minicpm3-4b").reduced(), vocab_size=250,
                  pad_vocab_to=256)
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (1, 8), 0, 250)
    logits = forward(params, cfg, tokens=toks)
    assert logits.shape[-1] == 256
    assert float(jnp.max(logits[..., 250:])) < -1e29


def test_param_count_analytic_close(key):
    """Analytic param_count within 5% of the real (eval_shape) store for
    every FULL config — this anchors the roofline's MODEL_FLOPS estimate.
    (Gap = inert head padding, correctly excluded from useful work.)"""
    from repro.models.transformer import init_params as init

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        shapes = jax.eval_shape(lambda cfg=cfg: init(jax.random.PRNGKey(0), cfg))
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
        est = cfg.param_count()
        assert abs(actual - est) / actual < 0.05, (arch, actual, est)
