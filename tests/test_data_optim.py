"""Data generators + optimizer correctness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import (
    gen_chaotic1,
    gen_chaotic2,
    gen_kernel_expansion,
    gen_nonlinear_wiener,
)
from repro.optim.optimizers import adamw_init, adamw_update, global_norm
from repro.optim.schedules import warmup_cosine


def test_generators_shapes_and_determinism(key):
    d1 = gen_kernel_expansion(key, num_samples=100)
    d2 = gen_kernel_expansion(key, num_samples=100)
    np.testing.assert_array_equal(np.asarray(d1.ys), np.asarray(d2.ys))
    assert d1.xs.shape == (100, 5)

    xs, ys = gen_nonlinear_wiener(key, num_samples=50)
    assert xs.shape == (50, 5) and ys.shape == (50,)

    xs, ys = gen_chaotic1(key, num_samples=60)
    assert xs.shape == (60, 2) and bool(jnp.all(jnp.isfinite(ys)))

    xs, ys = gen_chaotic2(key, num_samples=60)
    assert xs.shape == (60, 2) and bool(jnp.all(jnp.isfinite(ys)))


def test_chaotic1_matches_recursion(key):
    """y_n - eta = d_{n-1}/(1+d_{n-1}^2) + u_{n-1}^3 holds along the series."""
    xs, ys = gen_chaotic1(key, num_samples=200, sigma_eta=0.0)
    u_prev, d_prev = xs[:, 0], xs[:, 1]
    want = d_prev / (1 + d_prev**2) + u_prev**3
    np.testing.assert_allclose(np.asarray(ys), np.asarray(want), atol=1e-6)


def test_adamw_minimizes_quadratic(key):
    w = jax.random.normal(key, (10,))
    target = jnp.ones(10)

    def loss(w):
        return 0.5 * jnp.sum((w - target) ** 2)

    opt = adamw_init({"w": w})
    params = {"w": w}
    for _ in range(400):
        g = jax.grad(lambda p: loss(p["w"]))(params)
        params, opt = adamw_update(params, g, opt, lr=0.05, weight_decay=0.0)
    assert float(loss(params["w"])) < 1e-3


def test_adamw_weight_decay_shrinks_weights(key):
    params = {"w": 5.0 * jnp.ones((4, 4))}
    opt = adamw_init(params)
    zeros = {"w": jnp.zeros((4, 4))}
    p2, _ = adamw_update(params, zeros, opt, lr=0.1, weight_decay=0.5)
    assert float(jnp.max(jnp.abs(p2["w"]))) < 5.0


def test_grad_clip_bounds_update(key):
    params = {"w": jnp.zeros(8)}
    opt = adamw_init(params)
    big = {"w": 1e6 * jnp.ones(8)}
    assert float(global_norm(big)) > 1e6
    p2, _ = adamw_update(params, big, opt, lr=0.1, grad_clip=1.0)
    assert bool(jnp.all(jnp.isfinite(p2["w"])))


def test_warmup_cosine_shape():
    steps = jnp.arange(0, 1000)
    lrs = jax.vmap(
        lambda s: warmup_cosine(s, peak_lr=1.0, warmup_steps=100, total_steps=1000)
    )(steps)
    assert float(lrs[0]) < 0.02
    assert abs(float(lrs[100]) - 1.0) < 0.02
    assert float(lrs[-1]) < 0.2
    assert float(jnp.max(lrs)) <= 1.0 + 1e-6
