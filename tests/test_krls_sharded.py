"""Sharded-KRLS tests: dense-vs-sharded equivalence on a forced 8-device
host mesh (subprocess — the device count locks at backend init, same
pattern as tests/test_distributed.py) and fused RLS bank kernel parity
against its pure-JAX oracle in interpret mode.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.krls import rff_krls_run, rff_krls_step
from repro.core.bank import krls_bank_init, krls_bank_run
from repro.core.rff import sample_rff
from repro.data.synthetic import gen_nonlinear_wiener
from repro.kernels import ops, ref
from repro.kernels.rff_krls_step import rff_krls_bank_step_pallas
from repro.launch.sharding import krls_shard_bytes
from repro.serve import reset_krls_tenants

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, json
from repro.core.krls import rff_krls_run, sharded_krls_run
from repro.core.learner import krls_learner, sharded_krls_learner
from repro.data.synthetic import gen_nonlinear_wiener
from repro.core.rff import sample_rff

res = {}
xs64, ys64 = gen_nonlinear_wiener(jax.random.PRNGKey(1), num_samples=600)
# under JAX_ENABLE_X64 the generator emits f64; the f32 sections cast down
xs, ys = xs64.astype(jnp.float32), ys64.astype(jnp.float32)
rff = sample_rff(jax.random.PRNGKey(0), 5, 256, sigma=5.0)

# f32, well-conditioned regularizer, 600 ticks, every shard count that
# divides the 8 host devices.
for n in (2, 4, 8):
    mesh = jax.make_mesh((n,), ("shard",))
    _, dense = rff_krls_run(rff, xs, ys, lam=1e-2, beta=0.9995)
    _, shard = sharded_krls_run(mesh, rff, xs, ys, lam=1e-2, beta=0.9995)
    res[f"f32_pred_maxdiff_n{n}"] = float(
        jnp.max(jnp.abs(dense.prediction - shard.prediction)))

# f64 at the paper's hyperparams (lam=1e-4, beta=0.9995): the sharded
# restructuring is exact math, so the gap is pure reduction-order noise.
if jax.config.jax_enable_x64:
    mesh = jax.make_mesh((8,), ("shard",))
    rff64 = sample_rff(jax.random.PRNGKey(0), 5, 256, sigma=5.0,
                       dtype=jnp.float64)
    _, dense = rff_krls_run(rff64, xs64, ys64, lam=1e-4, beta=0.9995)
    _, shard = sharded_krls_run(mesh, rff64, xs64, ys64, lam=1e-4,
                                beta=0.9995)
    res["f64_pred_maxdiff"] = float(
        jnp.max(jnp.abs(dense.prediction - shard.prediction)))

# the OnlineLearner adapter: per-tick step fn + predict fn
mesh = jax.make_mesh((8,), ("shard",))
ls = sharded_krls_learner(mesh, rff, lam=1e-2, beta=0.9995)
ld = krls_learner(rff, lam=1e-2, beta=0.9995)
ss, sd = ls.init(), ld.init()
dmax = 0.0
for i in range(32):
    ss, outs = ls.step(ss, xs[i], ys[i])
    sd, outd = ld.step(sd, xs[i], ys[i])
    dmax = max(dmax, float(jnp.abs(outs.prediction - outd.prediction)))
res["adapter_step_maxdiff"] = dmax
res["adapter_predict_diff"] = float(
    jnp.abs(ls.predict(ss, xs[40]) - ld.predict(sd, xs[40])))
res["theta_is_sharded"] = len(ss.theta.sharding.device_set) == 8
res["pmat_is_sharded"] = len(ss.pmat.sharding.device_set) == 8
print(json.dumps(res))
"""


@pytest.mark.slow
def test_sharded_krls_matches_dense_on_8_devices():
    """Acceptance: sharded == dense to 1e-5 over >=500 ticks, 8-way mesh."""
    env = dict(
        os.environ,
        PYTHONPATH=os.path.join(REPO, "src"),
        JAX_ENABLE_X64="1",
    )
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        timeout=420,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    for n in (2, 4, 8):
        assert res[f"f32_pred_maxdiff_n{n}"] < 1e-5, res
    assert res["f64_pred_maxdiff"] < 1e-8, res
    assert res["adapter_step_maxdiff"] < 1e-5, res
    assert res["adapter_predict_diff"] < 1e-4, res
    assert res["theta_is_sharded"] and res["pmat_is_sharded"], res


def test_krls_shard_bytes_memory_model():
    """Per-shard P block is the dense bytes / n_shards; D must divide."""
    m = krls_shard_bytes(4096, 8, input_dim=16)
    assert m["p_block_bytes"] == 4096 * 512 * 4
    assert m["dense_p_bytes"] == 8 * m["p_block_bytes"]
    assert m["tick_payload_bytes"] == (2 * 4096 + 1) * 4
    with pytest.raises(ValueError):
        krls_shard_bytes(100, 8)


@pytest.mark.parametrize("bank,d,D", [(4, 5, 128), (3, 5, 100), (1, 2, 17)])
@pytest.mark.parametrize("per_tenant_beta", [False, True])
def test_rff_krls_step_kernel_sweep(key, bank, d, D, per_tenant_beta):
    """Fused featurize+predict+downdate step vs the two-pass oracle."""
    ks = jax.random.split(key, 7)
    theta = jax.random.normal(ks[0], (bank, D))
    a = jax.random.normal(ks[1], (bank, D, D)) * 0.1
    pmat = jnp.eye(D) * 10.0 + jnp.einsum("bij,bkj->bik", a, a)
    x = jax.random.normal(ks[2], (bank, d))
    y = jax.random.normal(ks[3], (bank,))
    w = jax.random.normal(ks[4], (d, D))
    b = jax.random.uniform(ks[5], (D,), maxval=2 * np.pi)
    if per_tenant_beta:
        beta = jax.random.uniform(ks[6], (bank,), minval=0.9, maxval=1.0)
    else:
        beta = jnp.asarray(0.9995)
    got = rff_krls_bank_step_pallas(
        theta,
        pmat,
        x,
        y,
        w,
        b,
        beta,
        interpret=True,
    )
    want = ref.rff_krls_bank_step_ref(theta, pmat, x, y, w, b, beta)
    for g, expect in zip(got, want):
        np.testing.assert_allclose(
            np.asarray(g),
            np.asarray(expect),
            atol=1e-5,
            rtol=1e-5,
        )


def test_rff_krls_step_ops_dispatch(key):
    """mode='interpret' (Pallas) and mode='xla' (oracle) agree through ops."""
    ks = jax.random.split(key, 4)
    bank, d, D = 6, 4, 96
    theta = jax.random.normal(ks[0], (bank, D))
    pmat = jnp.broadcast_to(jnp.eye(D) * 50.0, (bank, D, D))
    x = jax.random.normal(ks[1], (bank, d))
    y = jax.random.normal(ks[2], (bank,))
    w = jax.random.normal(ks[3], (d, D))
    b = jnp.zeros((D,))
    got = ops.rff_krls_bank_step(theta, pmat, x, y, w, b, 0.99, mode="interpret")
    want = ops.rff_krls_bank_step(theta, pmat, x, y, w, b, 0.99, mode="xla")
    for g, expect in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(expect), atol=1e-5)


def test_fused_krls_bank_matches_sequential():
    """Fused-step KRLS bank == B sequential rff_krls_run streams."""
    rff = sample_rff(jax.random.PRNGKey(0), 5, 100, sigma=5.0)
    xs, ys = gen_nonlinear_wiener(jax.random.PRNGKey(5), num_samples=400)
    bank, n = 4, 100
    xb = xs[: bank * n].reshape(bank, n, -1)
    yb = ys[: bank * n].reshape(bank, n)
    run = jax.jit(
        lambda: krls_bank_run(rff, xb, yb, lam=1e-2, beta=0.9995, mode="xla")
    )
    _, outs = run()
    for i in range(bank):
        _, want = rff_krls_run(rff, xb[i], yb[i], lam=1e-2, beta=0.9995)
        np.testing.assert_allclose(
            np.asarray(outs.error[i]),
            np.asarray(want.error),
            atol=1e-4,
        )


def test_fused_krls_bank_per_tenant_beta():
    """(B,) beta vector == per-stream sequential runs with scalar betas."""
    rff = sample_rff(jax.random.PRNGKey(0), 5, 64, sigma=5.0)
    xs, ys = gen_nonlinear_wiener(jax.random.PRNGKey(7), num_samples=120)
    bank, n = 3, 120
    xb = jnp.broadcast_to(xs[:n], (bank, n, xs.shape[-1]))
    yb = jnp.broadcast_to(ys[:n], (bank, n))
    betas = jnp.array([0.97, 0.99, 1.0])
    _, outs = krls_bank_run(rff, xb, yb, lam=1e-2, beta=betas, mode="xla")
    for i in range(bank):
        _, want = rff_krls_run(rff, xs[:n], ys[:n], lam=1e-2, beta=float(betas[i]))
        np.testing.assert_allclose(
            np.asarray(outs.error[i]),
            np.asarray(want.error),
            atol=1e-4,
        )


def test_krls_bank_vs_vmapped_dense_step(key):
    """One fused tick == vmapped core rls_step over the bank."""
    rff = sample_rff(jax.random.PRNGKey(0), 5, 64, sigma=5.0)
    bank = 5
    state = krls_bank_init(rff, bank, lam=1e-2)
    x = jax.random.normal(key, (bank, 5))
    y = jax.random.normal(jax.random.PRNGKey(3), (bank,))
    got = ops.rff_krls_bank_step(
        state.theta,
        state.pmat,
        x,
        y,
        rff.omega,
        rff.bias,
        0.9995,
        mode="xla",
    )
    vstep = jax.vmap(lambda s, xx, yy: rff_krls_step(s, (xx, yy), rff, 0.9995))
    want_state, want_out = vstep(state, x, y)
    np.testing.assert_allclose(
        np.asarray(got[0]), np.asarray(want_state.theta), atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(got[1]), np.asarray(want_state.pmat), atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(got[2]), np.asarray(want_out.prediction), atol=1e-5
    )


def test_reset_krls_tenants():
    rff = sample_rff(jax.random.PRNGKey(0), 5, 32, sigma=5.0)
    xs, ys = gen_nonlinear_wiener(jax.random.PRNGKey(9), num_samples=64)
    xb = xs[:64].reshape(4, 16, -1)
    yb = ys[:64].reshape(4, 16)
    state, _ = krls_bank_run(rff, xb, yb, lam=1e-2, mode="xla")
    state = reset_krls_tenants(state, jnp.array([1, 3]), lam=1e-2)
    assert float(jnp.max(jnp.abs(state.theta[1]))) == 0.0
    np.testing.assert_allclose(
        np.asarray(state.pmat[3]), np.eye(32) * 100.0, atol=1e-6
    )
    assert int(state.step[1]) == 0
    assert float(jnp.max(jnp.abs(state.theta[0]))) > 0.0
