"""Fused decode-block attention: kernel vs oracle, block vs per-token,
prefill-then-decode vs full-sequence, bf16 floor, feature-family wiring.

Tolerance contract: comparisons that run through the SAME code path at both
grains (block T vs T sequential T=1 launches) are pinned bitwise at f32 —
every tick is sequential either way, so nothing reassociates. Kernel-vs-
oracle comparisons cross code paths (the kernel featurizes lane-padded
blocks; the oracle runs unpadded batched GEMMs), which shifts reduction
order by a few ulps — those pin tight f32 allclose instead.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.features import make_feature_map
from repro.kernels import ops, ref
from repro.kernels.chunking import default_decode_block_t
from repro.kernels.rff_attention import rff_attention_decode_block_pallas
from repro.models import rff_attention as rff_mod
from repro.models.transformer import with_rff_attention


def _decode_inputs(key, bh, t, dh, dfeat, dv):
    ks = jax.random.split(key, 7)
    q = jax.random.normal(ks[0], (bh, t, dh)) * 0.1
    k = jax.random.normal(ks[1], (bh, t, dh)) * 0.1
    v = jax.random.normal(ks[2], (bh, t, dv))
    w = jax.random.normal(ks[3], (dh, dfeat)) * 0.3
    b = jax.random.uniform(ks[4], (dfeat,), maxval=2 * np.pi)
    s_state = jax.random.normal(ks[5], (bh, dfeat, dv)) * 0.1
    z_state = jax.nn.relu(jax.random.normal(ks[6], (bh, dfeat))) + 0.5
    return q, k, v, w, b, s_state, z_state


@pytest.mark.parametrize(
    "bh,t,dh,dfeat,dv",
    [(3, 8, 16, 32, 16), (2, 17, 5, 300, 8), (1, 1, 16, 64, 16),
     (4, 32, 128, 128, 128)],
)
@pytest.mark.parametrize("feature_kind", ["prf", "trig"])
def test_decode_block_kernel_vs_oracle(key, bh, t, dh, dfeat, dv,
                                       feature_kind):
    """Interpret-mode fused kernel vs the scan-of-ticks oracle at f32."""
    q, k, v, w, b, s_state, z_state = _decode_inputs(key, bh, t, dh, dfeat, dv)
    normalize = feature_kind == "prf"
    got = rff_attention_decode_block_pallas(
        s_state, z_state, q, k, v, w, b, feature_kind=feature_kind,
        normalize=normalize, interpret=True,
    )
    want = ref.rff_attention_decode_block_ref(
        s_state, z_state, q, k, v, w, b, feature_kind=feature_kind,
        normalize=normalize,
    )
    for g, wv in zip(got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(wv), atol=1e-5, rtol=1e-5
        )


@pytest.mark.parametrize("bh,t,dh,dfeat,dv",
                         [(3, 8, 16, 32, 16), (4, 32, 128, 128, 128)])
@pytest.mark.parametrize("feature_kind", ["prf", "trig"])
def test_decode_block_bitwise_vs_sequential_pallas(key, bh, t, dh, dfeat, dv,
                                                   feature_kind):
    """Block of T ticks == T sequential T=1 launches, bitwise at f32: the
    kernel runs every tick sequentially either way, so blocking must not
    change a single bit of output or state."""
    q, k, v, w, b, s_state, z_state = _decode_inputs(key, bh, t, dh, dfeat, dv)
    normalize = feature_kind == "prf"
    blk = rff_attention_decode_block_pallas(
        s_state, z_state, q, k, v, w, b, feature_kind=feature_kind,
        normalize=normalize, interpret=True,
    )
    s_st, z_st = s_state, z_state
    outs = []
    for i in range(t):
        o, s_st, z_st = rff_attention_decode_block_pallas(
            s_st, z_st, q[:, i:i + 1], k[:, i:i + 1], v[:, i:i + 1], w, b,
            feature_kind=feature_kind, normalize=normalize, interpret=True,
        )
        outs.append(o)
    seq = (jnp.concatenate(outs, axis=1), s_st, z_st)
    for g, wv in zip(blk, seq):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(wv))


@pytest.mark.parametrize("feature_kind", ["prf", "trig"])
def test_decode_block_ops_vs_sequential(key, feature_kind):
    """Block vs per-token through the ops dispatch (XLA oracle path). The
    oracle featurizes the whole block in one batched GEMM whose M dimension
    differs between the two grains, which can shift the reduction blocking
    by a few ulps — so this pins ulp-tight allclose; the strict bitwise
    contract lives on the kernel path above, where each tick's math is
    literally identical at both grains."""
    bh, t, dh, dfeat, dv = 2, 12, 16, 48, 8
    q, k, v, w, b, s_state, z_state = _decode_inputs(key, bh, t, dh, dfeat, dv)
    normalize = feature_kind == "prf"
    blk = ops.rff_attention_decode_block(
        s_state, z_state, q, k, v, w, b, feature_kind=feature_kind,
        mode="xla", normalize=normalize,
    )
    s_st, z_st = s_state, z_state
    outs = []
    for i in range(t):
        o, s_st, z_st = ops.rff_attention_decode_block(
            s_st, z_st, q[:, i:i + 1], k[:, i:i + 1], v[:, i:i + 1], w, b,
            feature_kind=feature_kind, mode="xla", normalize=normalize,
        )
        outs.append(o)
    seq = (jnp.concatenate(outs, axis=1), s_st, z_st)
    for g, wv in zip(blk, seq):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(wv), atol=1e-6, rtol=1e-6
        )


@pytest.mark.parametrize("block_t", [4, 8, 16])
@pytest.mark.parametrize("mode", ["xla", "interpret"])
def test_decode_block_sub_chunking(key, block_t, mode):
    """tlen > block_t scans full blocks + an unpadded remainder launch; the
    result must match one all-at-once launch (remainder ticks are real
    launches, never masked pad rows — a PRF feature of a zero token is NOT
    zero, so masking would corrupt state)."""
    bh, t, dh, dfeat, dv = 2, 37, 16, 64, 8
    q, k, v, w, b, s_state, z_state = _decode_inputs(key, bh, t, dh, dfeat, dv)
    chunked = ops.rff_attention_decode_block(
        s_state, z_state, q, k, v, w, b, mode=mode, block_t=block_t,
    )
    whole = ops.rff_attention_decode_block(
        s_state, z_state, q, k, v, w, b, mode=mode, block_t=t,
    )
    for g, wv in zip(chunked, whole):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(wv), atol=1e-5, rtol=1e-5
        )


@pytest.mark.parametrize("feature_kind", ["prf", "trig"])
def test_decode_block_bf16_floor(key, feature_kind):
    """bf16 read-path precision stays within the contract floor (<= 2e-2
    relative) of the f32 oracle — state is f32 either way, only the feature
    and numerator GEMM operands drop to bf16."""
    bh, t, dh, dfeat, dv = 3, 16, 16, 128, 16
    q, k, v, w, b, s_state, z_state = _decode_inputs(key, bh, t, dh, dfeat, dv)
    normalize = feature_kind == "prf"
    f32 = ref.rff_attention_decode_block_ref(
        s_state, z_state, q, k, v, w, b, feature_kind=feature_kind,
        normalize=normalize,
    )
    bf16 = rff_attention_decode_block_pallas(
        s_state, z_state, q, k, v, w, b, feature_kind=feature_kind,
        normalize=normalize, precision="bf16", interpret=True,
    )
    for g, wv in zip(bf16, f32):
        g, wv = np.asarray(g, np.float32), np.asarray(wv)
        # scale-relative max error, same normalization as the prefill
        # attention sweep — per-element ratios blow up at near-zero entries
        err = np.max(np.abs(g - wv)) / (np.max(np.abs(wv)) + 1e-6)
        assert err <= 2e-2


def test_default_decode_block_t_budget():
    """The VMEM default charges the resident (D, dv) state: growing the
    state shrinks T, and T stays within the [8, 512] clamp."""
    small = default_decode_block_t(128, 64, 64)
    big = default_decode_block_t(4096, 128, 64)
    assert 8 <= big <= small <= 512
    # bf16 streams fit more ticks per launch than f32 ones
    assert default_decode_block_t(256, 64, 64, jnp.bfloat16) >= \
        default_decode_block_t(256, 64, 64, jnp.float32)


def _rff_cfg():
    return with_rff_attention(get_config("llama3-8b").reduced())


@pytest.mark.parametrize("feature_kind", ["prf", "trig"])
def test_model_decode_block_bitwise_vs_per_token(key, feature_kind):
    """Model-level block decode == per-token decode loop, bitwise: both run
    the same dispatch, so blocking is purely a launch-count optimization."""
    cfg = _rff_cfg()
    p = rff_mod.rff_attn_init(key, cfg)
    B, T = 2, 8
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model)) * 0.1
    st = rff_mod.rff_state_init(cfg, B)
    out_blk, st_blk = rff_mod.rff_attn_decode_block(
        p, cfg, x, st, feature_kind=feature_kind
    )
    st_seq = rff_mod.rff_state_init(cfg, B)
    outs = []
    for t in range(T):
        o, st_seq = rff_mod.rff_attn_decode(
            p, cfg, x[:, t:t + 1], st_seq, feature_kind=feature_kind
        )
        outs.append(o)
    np.testing.assert_array_equal(
        np.asarray(out_blk), np.asarray(jnp.concatenate(outs, axis=1))
    )
    np.testing.assert_array_equal(np.asarray(st_blk.s), np.asarray(st_seq.s))
    np.testing.assert_array_equal(np.asarray(st_blk.z), np.asarray(st_seq.z))
    assert int(st_blk.pos) == int(st_seq.pos) == T


@pytest.mark.parametrize("feature_kind", ["prf", "trig"])
def test_model_prefill_then_decode_matches_apply(key, feature_kind):
    """Prefill s tokens as one decode block, decode the rest per token; the
    concatenation must match full-sequence rff_attn_apply for BOTH feature
    kinds (the state contract that makes O(1)-in-context serving sound)."""
    cfg = _rff_cfg()
    p = rff_mod.rff_attn_init(key, cfg)
    B, T, s = 2, 10, 6
    x = jax.random.normal(jax.random.PRNGKey(2), (B, T, cfg.d_model)) * 0.1
    full = rff_mod.rff_attn_apply(p, cfg, x, feature_kind=feature_kind)
    st = rff_mod.rff_state_init(cfg, B)
    pre, st = rff_mod.rff_attn_decode_block(
        p, cfg, x[:, :s], st, feature_kind=feature_kind
    )
    outs = [pre]
    for t in range(s, T):
        o, st = rff_mod.rff_attn_decode(
            p, cfg, x[:, t:t + 1], st, feature_kind=feature_kind
        )
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full), atol=1e-5, rtol=1e-5
    )


@pytest.mark.parametrize("family", ["qmc", "gq"])
def test_model_decode_feature_family(key, family):
    """Deterministic trig families plug straight into the attention decode
    path via rff_attn_init(feature_map=...) and keep the prefill/decode
    state contract."""
    cfg = _rff_cfg()
    fm = make_feature_map(
        family, cfg.resolved_head_dim, cfg.rff_num_features, 1.0
    )
    p = rff_mod.rff_attn_init(key, cfg, feature_map=fm)
    assert p["omega"].shape == (cfg.resolved_head_dim, cfg.rff_num_features)
    assert p["scale"].shape == (cfg.rff_num_features,)
    B, T = 2, 6
    x = jax.random.normal(jax.random.PRNGKey(3), (B, T, cfg.d_model)) * 0.1
    full = rff_mod.rff_attn_apply(p, cfg, x, feature_kind="trig")
    st = rff_mod.rff_state_init(cfg, B)
    dec, st = rff_mod.rff_attn_decode_block(
        p, cfg, x, st, feature_kind="trig"
    )
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full), atol=1e-5, rtol=1e-5
    )
    assert int(st.pos) == T


def test_model_feature_map_shape_mismatch(key):
    cfg = _rff_cfg()
    fm = make_feature_map("qmc", cfg.resolved_head_dim + 1,
                          cfg.rff_num_features, 1.0)
    with pytest.raises(ValueError, match="feature_map"):
        rff_mod.rff_attn_init(key, cfg, feature_map=fm)
