"""Bank-slot eviction / rebuild lifecycle (core/bank.py + serve/snapshot.py).

The property under test (hypothesis, all five learners): evicting a
learner and rebuilding it from its replay log reproduces the
never-evicted state — bitwise through the sequential replay path, within
the pinned replay tolerances through the scan/blocked engine, at
arbitrary (mid-chunk) eviction boundaries. The f64 variant rides in a
subprocess (conftest pins x64 off) and shows drift shrinking with
precision, i.e. the lifecycle is exact algebra, not a lucky f32 artifact.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Only the two property tests need hypothesis (optional dep, installed in
# CI) — the bank/server/f64 lifecycle tests below must run without it.
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in minimal containers
    HAVE_HYPOTHESIS = False

from repro.core.bank import (
    evict_tenant,
    klms_bank_init,
    krls_bank_init,
    rebuild_tenant,
    set_tenant_row,
    tenant_row,
)
from repro.core.klms import rff_klms_run
from repro.core.krls import rff_krls_run
from repro.core.learner import (
    ald_krls_learner,
    klms_learner,
    krls_learner,
    nklms_learner,
    qklms_learner,
)
from repro.core.rff import sample_rff
from repro.serve.snapshot import (
    ReplayLog,
    klms_snapshot_server,
    krls_snapshot_server,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_RFF = sample_rff(jax.random.PRNGKey(0), 3, 32, 1.0)

FAMILIES = ["klms", "nklms", "krls", "qklms", "ald"]


def _learner(family):
    return {
        "klms": lambda: klms_learner(_RFF, 0.3),
        "nklms": lambda: nklms_learner(_RFF, 0.3),
        "krls": lambda: krls_learner(_RFF, lam=0.1, beta=0.99),
        "qklms": lambda: qklms_learner(3, 1.0, 0.3, 0.1, capacity=32),
        "ald": lambda: ald_krls_learner(3, 1.0, nu=5e-4, capacity=32),
    }[family]()


def _stream(seed, n, d=3):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    return (
        jax.random.normal(kx, (n, d)),
        jax.random.normal(ky, (n,)),
    )


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert bool(jnp.array_equal(la, lb)), (la, lb)


def _rel(a, b):
    return float(jnp.linalg.norm(a - b) / jnp.linalg.norm(b))


# -- the satellite property: evict -> rebuild(log) == never evicted ---------

if HAVE_HYPOTHESIS:

    @pytest.mark.parametrize("family", FAMILIES)
    @given(seed=st.integers(0, 2**16), cut=st.integers(1, 47))
    @settings(max_examples=8, deadline=None)
    def test_evict_rebuild_from_log_matches_never_evicted(family, seed, cut):
        """Sequential rebuild of the full log is BITWISE the never-evicted
        state for every learner — the state at the (arbitrary, mid-chunk)
        eviction tick is discarded and never consulted."""
        lrn = _learner(family)
        xs, ys = _stream(seed, 48)
        never, _ = lrn.run(None, xs, ys)
        # Evict at `cut`: whatever state existed there is dropped on the
        # floor; the rebuild sees only the log.
        _discarded, _ = lrn.run(None, xs[:cut], ys[:cut])
        rebuilt = lrn.rebuild(xs, ys, mode="sequential")
        _assert_trees_equal(never, rebuilt)

    @pytest.mark.parametrize("family", ["klms", "nklms", "krls"])
    @given(seed=st.integers(0, 2**16), cut=st.integers(1, 47))
    @settings(max_examples=8, deadline=None)
    def test_warm_rebuild_across_cut_matches_never_evicted(family, seed, cut):
        """Scan/blocked replay restarted from the state at an arbitrary
        cut (mid-chunk boundaries included: chunk=16, cut uniform in
        [1, 47]) lands on the never-evicted state within the replay
        tolerance."""
        lrn = _learner(family)
        xs, ys = _stream(seed, 48)
        never, _ = lrn.run(None, xs, ys)
        at_cut, _ = lrn.run(None, xs[:cut], ys[:cut])
        for mode in ("scan", "blocked"):
            rebuilt = lrn.rebuild(
                xs[cut:], ys[cut:], state=at_cut, mode=mode, chunk=16
            )
            # KRLS warm start round-trips Phi_0 = inv(P_0) at f32.
            tol = 5e-4 if family == "krls" else 5e-5
            assert _rel(rebuilt.theta, never.theta) < tol, (mode, cut)
            assert int(rebuilt.step) == 48


# -- bank-level lifecycle ----------------------------------------------------


def test_bank_evict_parks_fresh_row(key):
    lms = klms_bank_init(_RFF, 3)
    lms = jax.tree.map(lambda a: a + 1.0, lms)  # make rows non-trivial
    ev = evict_tenant(lms, 1)
    assert float(jnp.abs(ev.theta[1]).max()) == 0.0
    assert float(jnp.abs(ev.theta[0] - lms.theta[0]).max()) == 0.0

    rls = krls_bank_init(_RFF, 3, jnp.asarray([0.1, 0.2, 0.5]))
    ev = evict_tenant(rls, 2, lam=jnp.asarray([0.1, 0.2, 0.5]))
    # P_0 = I/lam with the TENANT'S lam from the (B,) sweep.
    np.testing.assert_allclose(
        np.asarray(ev.pmat[2]), np.eye(32, dtype=np.float32) / 0.5, atol=1e-6
    )


def test_bank_rebuild_tenant_sequential_is_bitwise(key):
    xs, ys = _stream(3, 50)
    state = klms_bank_init(_RFF, 3)
    state = rebuild_tenant(state, 1, _RFF, xs, ys, mu=0.3, mode="sequential")
    seq, _ = rff_klms_run(_RFF, xs, ys, 0.3)
    assert bool(jnp.array_equal(state.theta[1], seq.theta))

    rls = krls_bank_init(_RFF, 3, 0.1)
    rls = rebuild_tenant(
        rls, 2, _RFF, xs, ys, lam=0.1, beta=0.99, mode="sequential"
    )
    kseq, _ = rff_krls_run(_RFF, xs, ys, lam=0.1, beta=0.99)
    assert bool(jnp.array_equal(rls.theta[2], kseq.theta))
    assert bool(jnp.array_equal(rls.pmat[2], kseq.pmat))


def test_tenant_row_roundtrip(key):
    state = klms_bank_init(_RFF, 4)
    row = tenant_row(state, 2)
    bumped = jax.tree.map(lambda a: a + 3.0, row)
    state2 = set_tenant_row(state, 2, bumped)
    _assert_trees_equal(tenant_row(state2, 2), bumped)
    _assert_trees_equal(tenant_row(state2, 0), tenant_row(state, 0))


# -- replay log --------------------------------------------------------------


def test_replay_log_ring_semantics():
    log = ReplayLog(2, capacity=4)
    for i in range(6):
        log.append(0, np.full(3, i, np.float32), float(i))
    assert log.size(0) == 4
    assert log.dropped(0) == 2
    assert not log.complete(0)
    xs, ys = log.arrays(0)
    assert xs.shape == (4, 3)
    np.testing.assert_array_equal(ys, [2.0, 3.0, 4.0, 5.0])
    assert log.complete(1) and log.size(1) == 0
    log.clear(0)
    assert log.size(0) == 0 and log.complete(0)


# -- snapshot-server integration --------------------------------------------


def _drive(server, obs):
    for t, x, y in obs:
        server.submit(t, x, y)
    server.drain()


def _obs(seed, n, tenants=3):
    rng = np.random.default_rng(seed)
    return [
        (int(rng.integers(0, tenants)), rng.normal(size=3).astype(np.float32),
         float(rng.normal()))
        for _ in range(n)
    ]


@pytest.mark.parametrize("family", ["klms", "krls"])
def test_server_evict_readmit_matches_never_evicted(family):
    make = {
        "klms": lambda: klms_snapshot_server(
            _RFF, 3, mu=0.3, chunk=8, log_capacity=512
        ),
        "krls": lambda: krls_snapshot_server(
            _RFF, 3, lam=0.1, beta=0.99, chunk=8, log_capacity=512
        ),
    }[family]
    srv, ctl = make(), make()
    obs = _obs(7, 240)
    _drive(ctl, obs)

    _drive(srv, obs[:100])
    srv.evict(1)
    assert 1 in srv.evicted
    # While evicted: reads serve the parked fresh row, arrivals only log.
    if family == "klms":
        assert float(jnp.abs(srv.snapshot.state.theta[1]).max()) == 0.0
    _drive(srv, obs[100:])
    assert srv.queue.backlog()[1] == 0  # nothing queued while evicted

    n1 = sum(1 for t, _, _ in obs if t == 1)
    assert srv.readmit(1) == n1
    assert 1 not in srv.evicted
    assert _rel(srv.snapshot.state.theta[1], ctl.snapshot.state.theta[1]) < 5e-5
    # Untouched tenants are bit-identical to the control server.
    for b in (0, 2):
        _assert_trees_equal(
            tenant_row(srv.snapshot.state, b), tenant_row(ctl.snapshot.state, b)
        )


def test_server_sequential_readmit_is_bitwise():
    srv = klms_snapshot_server(
        _RFF, 3, mu=0.3, chunk=8, log_capacity=512,
        rebuild_mode="sequential",
    )
    obs = _obs(11, 200)
    _drive(srv, obs)
    srv.evict(2)
    srv.readmit(2)
    x2 = np.stack([x for t, x, _ in obs if t == 2])
    y2 = np.asarray([y for t, _, y in obs if t == 2], np.float32)
    seq, _ = rff_klms_run(_RFF, jnp.asarray(x2), jnp.asarray(y2), 0.3)
    assert bool(jnp.array_equal(srv.snapshot.state.theta[2], seq.theta))


def test_server_evict_drops_pending_and_publishes():
    srv = klms_snapshot_server(
        _RFF, 2, mu=0.3, chunk=16, log_capacity=64, publish_every=1000
    )
    rng = np.random.default_rng(0)
    for _ in range(5):
        srv.submit(0, rng.normal(size=3).astype(np.float32), 1.0)
    version_before = srv.snapshot.version
    assert srv.evict(0) == 5
    assert srv.queue.backlog() == [0, 0]
    assert srv.snapshot.version == version_before + 1  # eviction publishes
    assert srv.log.size(0) == 5  # the log keeps what the queue dropped
    assert srv.readmit(0) == 5


def test_server_readmit_overflowed_log_is_windowed():
    """Ring overflow -> readmission rebuilds fresh-init + last `capacity`
    ticks, and the log flags the truncation."""
    srv = klms_snapshot_server(_RFF, 2, mu=0.3, chunk=8, log_capacity=16)
    obs = [(0, x, y) for _, x, y in _obs(13, 40)]
    _drive(srv, obs)
    srv.evict(0)
    assert not srv.log.complete(0)
    assert srv.readmit(0) == 16
    xs = np.stack([x for _, x, _ in obs[-16:]])
    ys = np.asarray([y for _, _, y in obs[-16:]], np.float32)
    win, _ = rff_klms_run(_RFF, jnp.asarray(xs), jnp.asarray(ys), 0.3)
    assert _rel(srv.snapshot.state.theta[0], win.theta) < 5e-5


def test_server_reset_clears_lifecycle_state():
    srv = klms_snapshot_server(_RFF, 2, mu=0.3, log_capacity=8)
    srv.submit(0, np.zeros(3, np.float32), 1.0)
    srv.drain()
    srv.evict(0)
    from repro.core.bank import klms_bank_init

    srv.reset(klms_bank_init(_RFF, 2))
    assert srv.evicted == frozenset()
    assert srv.log.size(0) == 0 and srv.log.complete(0)


def test_reset_tenant_clears_stale_log_truncation_flags():
    """Regression: a per-tenant reset while the ring has overflowed must
    clear the dropped-entry counter along with the history. Before the
    fix, the next occupant of the slot inherited ``complete() == False``
    from the previous tenant and a later evict/rebuild silently replayed
    a truncated (empty) history as if it were the full stream."""
    srv = klms_snapshot_server(_RFF, 3, mu=0.3, chunk=8, log_capacity=16)
    obs = _obs(11, 120)
    _drive(srv, obs)
    assert srv.log.dropped(1) > 0 and not srv.log.complete(1)

    srv.evict(1)
    dropped = srv.reset_tenant(1)
    assert dropped == 0  # drain()ed above, nothing pending
    # Log state fully cleared: no history AND no stale truncation flag.
    assert srv.log.size(1) == 0
    assert srv.log.dropped(1) == 0
    assert srv.log.complete(1)
    # The slot left the evicted set and serves the parked fresh row.
    assert 1 not in srv.evicted
    assert float(jnp.abs(srv.snapshot.state.theta[1]).max()) == 0.0

    # The slot trains normally again, identical to a fresh server fed the
    # same post-reset stream.
    post = [(t, x, y) for (t, x, y) in _obs(13, 80) if t == 1]
    ctl = klms_snapshot_server(_RFF, 3, mu=0.3, chunk=8, log_capacity=16)
    _drive(srv, post)
    _drive(ctl, post)
    assert bool(
        jnp.array_equal(
            srv.snapshot.state.theta[1], ctl.snapshot.state.theta[1]
        )
    )
    assert srv.log.complete(1) == ctl.log.complete(1)


# -- f64 (subprocess: conftest pins x64 off) --------------------------------

_F64_SCRIPT = r"""
import json
import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from repro.core.learner import klms_learner, krls_learner
from repro.core.rff import sample_rff

rff = sample_rff(jax.random.PRNGKey(0), 3, 32, 1.0, dtype=jnp.float64)
kx, ky = jax.random.split(jax.random.PRNGKey(9))
xs = jax.random.normal(kx, (48, 3), jnp.float64)
ys = jax.random.normal(ky, (48,), jnp.float64)
res = {}
for name, lrn in (
    ("klms", klms_learner(rff, 0.3)),
    ("krls", krls_learner(rff, lam=0.1, beta=0.99)),
):
    never, _ = lrn.run(None, xs, ys)
    seq = lrn.rebuild(xs, ys, mode="sequential")
    res[f"{name}_seq_bitwise"] = bool(jnp.array_equal(seq.theta, never.theta))
    for cut in (7, 23):
        at_cut, _ = lrn.run(None, xs[:cut], ys[:cut])
        rb = lrn.rebuild(xs[cut:], ys[cut:], state=at_cut, mode="scan",
                         chunk=16)
        res[f"{name}_scan_cut{cut}"] = float(
            jnp.linalg.norm(rb.theta - never.theta)
            / jnp.linalg.norm(never.theta)
        )
print(json.dumps(res))
"""


@pytest.mark.slow
def test_evict_rebuild_f64_drift_shrinks():
    """At f64 the scan rebuild across arbitrary cuts lands within 1e-10
    of the never-evicted state (measured ~1e-13) — the f32 tolerances
    above are working-precision rounding, not algebra error."""
    env = dict(
        os.environ,
        PYTHONPATH=os.path.join(REPO, "src"),
        JAX_ENABLE_X64="1",
    )
    out = subprocess.run(
        [sys.executable, "-c", _F64_SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        timeout=420,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["klms_seq_bitwise"] and res["krls_seq_bitwise"], res
    for k, v in res.items():
        if not k.endswith("bitwise"):
            assert v < 1e-10, res
