"""Parallel-in-time replay engine (core/scan.py + kernels/rff_scan.py).

Contract under test, per mode:

* ``sequential`` — delegates to the jitted training drivers, so a rebuild
  is BITWISE the never-replayed state (asserted with array_equal);
* ``scan`` / ``blocked`` — associative-element rebuilds match the
  sequential state within pinned tolerances. KLMS elements are products of
  ``I - mu z z^T`` contractions, so f32 drift stays ~1e-6 at any length;
  KRLS composes information-form (Phi, r) and the final solve amplifies
  element rounding by cond(Phi) — the pinned config (D=32, lam=0.1,
  beta=0.99, T=1024) keeps the ISSUE's 1e-5 f32 bound honest, and the
  f64 subprocess test pins 1e-8 at D=64 over the same horizon.

The chunk-element kernels are swept against their pure-jnp oracles in
interpret mode (CPU), same as every other Pallas kernel in the repo.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import scan
from repro.core.klms import rff_klms_run
from repro.core.krls import rff_krls_run
from repro.core.learner import klms_learner, krls_learner, qklms_learner
from repro.core.rff import sample_rff
from repro.features.base import as_trig_or_none
from repro.kernels import ops, ref
from repro.kernels.chunking import default_chunk_t

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _stream(key, n, d, dtype=jnp.float32):
    kx, ky = jax.random.split(key)
    return (
        jax.random.normal(kx, (n, d), dtype),
        jax.random.normal(ky, (n,), dtype),
    )


def _rel(a, b):
    return float(jnp.linalg.norm(a - b) / jnp.linalg.norm(b))


# -- element algebra ---------------------------------------------------------


def test_affine_combine_associative_and_identity():
    e = [
        scan.klms_to_element(
            jax.random.normal(jax.random.PRNGKey(i), (16,)),
            jnp.asarray(float(i + 1)),
            0.3,
        )
        for i in range(3)
    ]
    left = scan.affine_combine(scan.affine_combine(e[0], e[1]), e[2])
    right = scan.affine_combine(e[0], scan.affine_combine(e[1], e[2]))
    np.testing.assert_allclose(
        np.asarray(left.a), np.asarray(right.a), atol=1e-6, rtol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(left.v), np.asarray(right.v), atol=1e-6, rtol=1e-6
    )
    ident = scan.affine_identity(16, jnp.float32)
    for combined in (
        scan.affine_combine(ident, e[0]),
        scan.affine_combine(e[0], ident),
    ):
        assert bool(jnp.array_equal(combined.a, e[0].a))
        assert bool(jnp.array_equal(combined.v, e[0].v))


def test_decay_combine_associative_and_identity():
    es = []
    for i in range(3):
        z = jax.random.normal(jax.random.PRNGKey(i), (8,))
        es.append(scan.krls_to_element(z, jnp.asarray(float(i + 1)), 0.97))
    left = scan.decay_combine(scan.decay_combine(es[0], es[1]), es[2])
    right = scan.decay_combine(es[0], scan.decay_combine(es[1], es[2]))
    for field in ("g", "phi", "r"):
        np.testing.assert_allclose(
            np.asarray(getattr(left, field)),
            np.asarray(getattr(right, field)),
            atol=1e-6,
            rtol=1e-6,
        )
    ident = scan.decay_identity(8, jnp.float32)
    for combined in (
        scan.decay_combine(ident, es[0]),
        scan.decay_combine(es[0], ident),
    ):
        for field in ("g", "phi", "r"):
            assert bool(
                jnp.array_equal(
                    getattr(combined, field), getattr(es[0], field)
                )
            )


def test_scan_element_factories_expose_algebra():
    for maker, hp in (
        (scan.klms_scan_element, (0.3,)),
        (scan.nklms_scan_element, (0.3, 1e-6)),
        (scan.krls_scan_element, (0.99,)),
    ):
        elem = maker(*hp)
        assert callable(elem.to_element)
        assert callable(elem.combine)
        assert callable(elem.identity)
        assert callable(elem.apply)


# -- replay modes vs the sequential training path ---------------------------


@pytest.mark.parametrize("normalized", [False, True])
def test_klms_sequential_replay_is_bitwise(key, normalized):
    rff = sample_rff(key, 4, 64, 1.0)
    xs, ys = _stream(jax.random.PRNGKey(2), 150, 4)
    seq, _ = rff_klms_run(rff, xs, ys, 0.3, normalized=normalized)
    rep = scan.replay_klms(
        rff, xs, ys, 0.3, mode="sequential", normalized=normalized
    )
    assert bool(jnp.array_equal(rep.theta, seq.theta))
    assert int(rep.step) == 150


@pytest.mark.parametrize("mode", ["scan", "blocked"])
@pytest.mark.parametrize("normalized", [False, True])
def test_klms_parallel_replay_matches_sequential(key, mode, normalized):
    rff = sample_rff(key, 4, 64, 1.0)
    xs, ys = _stream(jax.random.PRNGKey(3), 200, 4)
    seq, _ = rff_klms_run(rff, xs, ys, 0.3, normalized=normalized)
    # chunk=16 forces a masked remainder chunk (200 = 12*16 + 8).
    rep = scan.replay_klms(
        rff, xs, ys, 0.3, mode=mode, chunk=16, normalized=normalized
    )
    assert _rel(rep.theta, seq.theta) < 2e-5
    assert int(rep.step) == 200


@pytest.mark.parametrize("mode", ["scan", "blocked"])
def test_klms_warm_start_replay(key, mode):
    rff = sample_rff(key, 4, 64, 1.0)
    xs, ys = _stream(jax.random.PRNGKey(4), 200, 4)
    seq, _ = rff_klms_run(rff, xs, ys, 0.3)
    half, _ = rff_klms_run(rff, xs[:100], ys[:100], 0.3)
    rep = scan.replay_klms(
        rff, xs[100:], ys[100:], 0.3, state=half, mode=mode, chunk=16
    )
    assert _rel(rep.theta, seq.theta) < 2e-5
    assert int(rep.step) == 200


def test_krls_parallel_replay_pinned_f32(key):
    """The ISSUE acceptance bound: <= 1e-5 relative over >= 1024 ticks.

    Pinned at D=32, lam=0.1, beta=0.99 (measured ~3e-6 theta / ~2e-6
    pmat). The contract is config-dependent on two axes: cond(Phi) ~ 1/lam
    amplifies element rounding through the final solve, and the forgetting
    factor sets the f32 accumulation window (1/(1-beta) ticks) over which
    the information-form sum and the sequential Sherman-Morrison recursion
    drift apart — beta -> 1 at D=64 reaches ~2e-5 and belongs to the f64
    path (subprocess test below, ~1e-13)."""
    rff = sample_rff(key, 4, 32, 1.0)
    xs, ys = _stream(jax.random.PRNGKey(5), 1024, 4)
    seq, _ = rff_krls_run(rff, xs, ys, lam=0.1, beta=0.99)
    for mode in ("scan", "blocked"):
        rep = scan.replay_krls(rff, xs, ys, lam=0.1, beta=0.99, mode=mode)
        assert _rel(rep.theta, seq.theta) < 1e-5, mode
        assert _rel(rep.pmat, seq.pmat) < 1e-5, mode
        assert int(rep.step) == 1024


def test_krls_sequential_replay_is_bitwise(key):
    rff = sample_rff(key, 4, 32, 1.0)
    xs, ys = _stream(jax.random.PRNGKey(6), 120, 4)
    seq, _ = rff_krls_run(rff, xs, ys, lam=0.1, beta=0.9995)
    rep = scan.replay_krls(rff, xs, ys, lam=0.1, beta=0.9995,
                           mode="sequential")
    assert bool(jnp.array_equal(rep.theta, seq.theta))
    assert bool(jnp.array_equal(rep.pmat, seq.pmat))


def test_krls_warm_start_replay(key):
    rff = sample_rff(key, 4, 32, 1.0)
    xs, ys = _stream(jax.random.PRNGKey(7), 256, 4)
    seq, _ = rff_krls_run(rff, xs, ys, lam=0.1, beta=0.9995)
    half, _ = rff_krls_run(rff, xs[:128], ys[:128], lam=0.1, beta=0.9995)
    rep = scan.replay_krls(
        rff, xs[128:], ys[128:], beta=0.9995, state=half, mode="scan"
    )
    # Warm start round-trips Phi_0 = inv(P_0): one extra f32 inversion.
    assert _rel(rep.theta, seq.theta) < 5e-4
    assert int(rep.step) == 256


def test_learner_rebuild_dispatch(key):
    """OnlineLearner.rebuild: replay_fn when wired, sequential fallback
    (bitwise) for learners without associative elements."""
    rff = sample_rff(key, 4, 32, 1.0)
    xs, ys = _stream(jax.random.PRNGKey(8), 100, 4)
    lrn = klms_learner(rff, 0.2)
    assert lrn.scan_element is not None
    seq, _ = lrn.run(None, xs, ys)
    assert bool(
        jnp.array_equal(lrn.rebuild(xs, ys, mode="sequential").theta,
                        seq.theta)
    )
    assert _rel(lrn.rebuild(xs, ys, mode="scan").theta, seq.theta) < 2e-5

    q = qklms_learner(4, 1.0, 0.2, 0.1, capacity=32)
    assert q.scan_element is None and q.replay_fn is None
    qseq, _ = q.run(None, xs, ys)
    qrb = q.rebuild(xs, ys, mode="scan")  # silently sequential
    assert bool(jnp.array_equal(qseq.centers, qrb.centers))
    assert bool(jnp.array_equal(qseq.coeffs, qrb.coeffs))


# -- chunk-element kernels vs oracles (interpret mode on CPU) ---------------


@pytest.mark.parametrize("tlen,chunk", [(64, 16), (100, 16), (30, 32)])
@pytest.mark.parametrize("normalized", [False, True])
def test_klms_chunk_elements_kernel_sweep(key, tlen, chunk, normalized):
    tf = as_trig_or_none(sample_rff(key, 5, 48, 1.0))
    xs, ys = _stream(jax.random.PRNGKey(9), tlen, 5)
    want = ops.rff_klms_chunk_elements(
        xs, ys, tf.omega, tf.bias, 0.3, tf.scale,
        mode="xla", chunk=chunk, normalized=normalized,
    )
    got = ops.rff_klms_chunk_elements(
        xs, ys, tf.omega, tf.bias, 0.3, tf.scale,
        mode="interpret", chunk=chunk, normalized=normalized,
    )
    for g, w in zip(got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), atol=2e-6, rtol=2e-6
        )


@pytest.mark.parametrize("tlen,chunk", [(64, 16), (100, 16), (30, 32)])
def test_krls_chunk_elements_kernel_sweep(key, tlen, chunk):
    tf = as_trig_or_none(sample_rff(key, 5, 48, 1.0))
    xs, ys = _stream(jax.random.PRNGKey(10), tlen, 5)
    want = ops.rff_krls_chunk_elements(
        xs, ys, tf.omega, tf.bias, 0.9995, tf.scale,
        mode="xla", chunk=chunk,
    )
    got = ops.rff_krls_chunk_elements(
        xs, ys, tf.omega, tf.bias, 0.9995, tf.scale,
        mode="interpret", chunk=chunk,
    )
    for g, w in zip(got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), atol=2e-6, rtol=2e-6
        )


def test_chunk_elements_remainder_composes_identity(key):
    """Masked remainder ticks must compose the identity: 16 ticks at
    chunk=12 give a second chunk with 4 real + 8 masked ticks, and the
    two chunk elements composed must equal the single 16-tick element."""
    tf = as_trig_or_none(sample_rff(key, 3, 32, 1.0))
    xs, ys = _stream(jax.random.PRNGKey(11), 16, 3)
    a2, v2 = ops.rff_klms_chunk_elements(
        xs, ys, tf.omega, tf.bias, 0.3, tf.scale, mode="xla", chunk=12,
    )
    one_a, one_v = ops.rff_klms_chunk_elements(
        xs, ys, tf.omega, tf.bias, 0.3, tf.scale, mode="xla", chunk=16,
    )
    composed = scan.affine_combine(
        scan.AffineElement(a=a2[0], v=v2[0]),
        scan.AffineElement(a=a2[1], v=v2[1]),
    )
    np.testing.assert_allclose(
        np.asarray(composed.a), np.asarray(one_a[0]), atol=2e-6, rtol=2e-6
    )
    np.testing.assert_allclose(
        np.asarray(composed.v), np.asarray(one_v[0]), atol=2e-6, rtol=2e-6
    )


# -- chunk sizing ------------------------------------------------------------


def test_default_chunk_t_elements_charge():
    """The element kernels' (D, D) accumulator + output tiles shrink the
    default T (satellite: the scan path must not reuse the theta-only
    sizing and bust VMEM)."""
    plain = default_chunk_t(1, 512, jnp.float32, input_dim=8)
    elems = default_chunk_t(1, 512, jnp.float32, input_dim=8, elements=True)
    assert elems <= plain
    # Huge-D: resident elements alone bust the budget -> floor of 8.
    assert default_chunk_t(1, 4096, jnp.float32, elements=True) == 8
    # Still a power of two within [8, 512].
    assert elems & (elems - 1) == 0
    assert 8 <= elems <= 512


# -- f64 acceptance bound (subprocess: conftest pins x64 off) ---------------

_F64_SCRIPT = r"""
import json
import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from repro.core.rff import sample_rff
from repro.core.krls import rff_krls_run
from repro.core.scan import replay_krls

rff = sample_rff(jax.random.PRNGKey(0), 4, 64, 1.0, dtype=jnp.float64)
kx, ky = jax.random.split(jax.random.PRNGKey(5))
xs = jax.random.normal(kx, (1024, 4), jnp.float64)
ys = jax.random.normal(ky, (1024,), jnp.float64)
seq, _ = rff_krls_run(rff, xs, ys, lam=0.1, beta=0.9995)
rep = replay_krls(rff, xs, ys, lam=0.1, beta=0.9995, mode="scan")
res = {
    "theta_scan": float(
        jnp.linalg.norm(rep.theta - seq.theta) / jnp.linalg.norm(seq.theta)
    ),
    "pmat_scan": float(
        jnp.linalg.norm(rep.pmat - seq.pmat) / jnp.linalg.norm(seq.pmat)
    ),
}
print(json.dumps(res))
"""


@pytest.mark.slow
def test_krls_replay_f64_acceptance_bound():
    """<= 1e-8 relative at f64 over 1024 ticks (measured ~3e-14 theta,
    ~5e-14 pmat at D=64, lam=0.1, beta=0.9995). Scan mode only: the
    blocked path runs through the chunk-element kernels, which accumulate
    at f32 working precision by the repo-wide kernel contract."""
    env = dict(
        os.environ,
        PYTHONPATH=os.path.join(REPO, "src"),
        JAX_ENABLE_X64="1",
    )
    out = subprocess.run(
        [sys.executable, "-c", _F64_SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        timeout=420,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    for k, v in res.items():
        assert v < 1e-8, res
