"""Chaos suite: injected faults -> detection -> quarantine -> repair.

The property, for every fault kind x all five learners: a fault injected
at a flush boundary raises a DegradationEvent at that same fold, the
offending tenant is quarantined and repaired by the ladder
(resymmetrize -> rebuild -> reset), no event ever re-fires after the
release, and the recovered server matches a never-faulted control that
had the *equivalent operator op* applied at the same boundary —
**bitwise** on every state leaf for reset and rebuild-from-complete-log
(the repair replays the same history through the same engine the
operator path uses), within a pinned f32 bound for re-symmetrize (the
symmetric projection of a perturbed P is not the unperturbed P; the
bound pins how far the perturbation can propagate into predictions).

Durability rides the same standard: kill-at-arbitrary-flush ->
restore(checkpoint + WAL suffix) matches the never-killed control
bitwise on all state leaves.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.rff import sample_rff
from repro.obs.faults import Fault, FaultInjector, FaultPlan
from repro.serve.api import make_server
from repro.serve.recovery import restore_checkpoint

_RFF = sample_rff(jax.random.PRNGKey(0), 3, 32, 1.0)

FAMILIES = ["klms", "nklms", "krls", "qklms", "ald"]

_KW = {
    "klms": dict(mu=0.3),
    "nklms": dict(mu=0.3),
    "krls": dict(lam=0.1, beta=0.99),
    "qklms": dict(sigma=1.0, mu=0.3, quant_eps=0.1, capacity=32),
    "ald": dict(sigma=1.0, nu=5e-4, capacity=32),
}

# Max relative prediction error after a resymmetrize repair vs the
# never-faulted control: the injected off-symmetric delta (5% of max|P|)
# is halved by the symmetric projection and only touches predictions
# through subsequent P-weighted updates.
_RESYM_TOL = 5e-2

_TENANT = 1  # the faulted tenant in every scenario (resident from warmup)


def _make(learner, **kw):
    return make_server(
        learner, feature_map=_RFF, bank=4, chunk=4,
        policy="lru", log_capacity=512, **_KW[learner], **kw,
    )


def _traffic(seed, n, tenants=3):
    rng = np.random.default_rng(seed)
    return [
        (
            int(rng.integers(0, tenants)),
            rng.standard_normal(3).astype(np.float32),
            float(rng.standard_normal()),
        )
        for _ in range(n)
    ]


def _assert_leaves_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert np.array_equal(
            np.asarray(la), np.asarray(lb), equal_nan=True
        ), (la, lb)


def _expected_outcome(kind, learner):
    """(probe that must fire, history the ladder must record)."""
    if kind == "drop_flush":
        return "ticks_lag", [("rebuild", True)]
    if kind == "log_corrupt":
        return "finite", [("rebuild", None), ("reset", True)]
    if kind == "asym_pmat" and learner == "krls":
        return "pmat.asym_rel", [("resymmetrize", True)]
    # nan_state everywhere; asym_pmat degrades to an Inf poison on the
    # non-RLS families. A complete log means the ladder stops at rebuild.
    return "finite", [("rebuild", True)]


@pytest.mark.parametrize("learner", FAMILIES)
@pytest.mark.parametrize(
    "kind", ["nan_state", "asym_pmat", "log_corrupt", "drop_flush"]
)
def test_fault_matrix_detect_quarantine_repair(kind, learner):
    srv = _make(learner, recovery=True)
    ctrl = _make(learner, probe=True)
    traffic = _traffic(3, 60)
    warm, mid, tail = traffic[:30], traffic[30:42], traffic[42:]
    if kind != "drop_flush":
        # The fused kernels overwrite / wash out a poisoned row they
        # train, so the corruption must land on a masked slot to survive
        # to the tap; drop_flush instead needs a backlog to drop.
        mid = [a for a in mid if a[0] != _TENANT]
    for s in (srv, ctrl):
        for t, x, y in warm:
            s.submit(t, x, y)
        s.drain()
    assert srv.probe.total_events == 0

    inj = FaultInjector(
        srv, FaultPlan([Fault(kind, tenant=_TENANT, at_flush=0)])
    ).attach()
    for t, x, y in mid:
        srv.submit(t, x, y)
        ctrl.submit(t, x, y)
    srv.flush()
    ctrl.flush()
    srv.drain()
    ctrl.drain()
    inj.detach()
    assert inj.applied and inj.applied[0]["flush"] == 0

    # Detection, quarantine and the full repair all happened inside the
    # faulted flush's fold.
    probe_name, ladder = _expected_outcome(kind, learner)
    at_detect = srv.probe.total_events
    assert at_detect >= 1
    assert probe_name in {ev.probe for ev in srv.probe.events}
    assert [
        (h["action"], h.get("verified")) for h in srv.recovery.history
    ] == ladder
    assert srv.recovery.quarantined == frozenset()
    counters = srv.metrics.snapshot()["counters"]
    assert counters["recovery.quarantines"] == 1
    assert counters["recovery.releases"] == 1
    assert counters[f"recovery.repairs{{action={ladder[-1][0]}}}"] == 1

    # The control takes the equivalent operator op at the same boundary.
    final_action = ladder[-1][0]
    if final_action == "reset":
        ctrl.reset_tenant(_TENANT)
    elif final_action == "rebuild":
        ctrl.evict(_TENANT)
        ctrl.readmit(_TENANT)

    for t, x, y in tail:
        srv.submit(t, x, y)
        ctrl.submit(t, x, y)
    srv.drain()
    ctrl.drain()

    # No event ever re-fires after the release.
    assert srv.probe.total_events == at_detect
    assert srv.recovery.quarantined == frozenset()
    for leaf in jax.tree.leaves(srv.queue.state):
        assert np.isfinite(np.asarray(leaf)).all()
    assert all(lag <= 0 for lag in srv._slot_lags())

    if final_action == "resymmetrize":
        # Symmetric again, exactly (f32 rounding of the projection)...
        slot = srv.resident[_TENANT]
        p = np.asarray(srv.queue.state.pmat[slot])
        assert np.max(np.abs(p - p.T)) <= 1e-5 * np.max(np.abs(p))
        # ...and predictions within the pinned bound of the control.
        xq = np.asarray(_traffic(9, 8)[0][1])[None].repeat(8, axis=0)
        a = np.asarray(srv.predict(_TENANT, xq))
        b = np.asarray(ctrl.predict(_TENANT, xq))
        denom = max(float(np.max(np.abs(b))), 1e-6)
        assert float(np.max(np.abs(a - b))) / denom < _RESYM_TOL
    else:
        _assert_leaves_equal(srv.queue.state, ctrl.queue.state)
        assert srv._expected == ctrl._expected


def test_clock_skew_is_detected_and_reclocked():
    import time

    srv = _make(
        "klms",
        probe={"clock_skew": 0.25},
        recovery={"reference_clock": time.monotonic},
    )
    traffic = _traffic(4, 50)
    for t, x, y in traffic[:30]:
        srv.submit(t, x, y)
    srv.drain()
    assert srv.recovery.measure_skew() < 0.25

    inj = FaultInjector(
        srv,
        FaultPlan([
            Fault("clock_skew", tenant=0, at_flush=0, magnitude=2.0)
        ]),
    ).attach()
    for t, x, y in traffic[30:40]:
        srv.submit(t, x, y)
    srv.flush()
    srv.drain()
    inj.detach()

    # One event, one reclock repair, no quarantine (global fault), and
    # the snapshot clock is back on the reference baseline.
    assert srv.probe.total_events == 1
    assert srv.probe.events[0].probe == "clock_skew"
    assert srv.recovery.history == [
        {
            "event": "clock_skew",
            "action": "reclock",
            "skew": pytest.approx(2.0, abs=0.05),
        }
    ]
    assert srv.recovery.quarantined == frozenset()
    counters = srv.metrics.snapshot()["counters"]
    assert counters["recovery.repairs{action=reclock}"] == 1
    assert srv.recovery.measure_skew() < 0.25
    before = srv.probe.total_events
    for t, x, y in traffic[40:]:
        srv.submit(t, x, y)
    srv.drain()
    assert srv.probe.total_events == before


@pytest.mark.parametrize("learner", ["klms", "krls", "ald"])
@pytest.mark.parametrize("cut", [7, 23, 41])
def test_kill_at_arbitrary_flush_restore_matches_never_killed(
    tmp_path, learner, cut
):
    args = dict(
        feature_map=_RFF, bank=4, chunk=4, policy="lru",
        log_capacity=512, size_watermark=4, **_KW[learner],
    )
    wal_path = str(tmp_path / "wal.jsonl")
    traffic = _traffic(5, 48)

    # The original server checkpoints mid-stream (mid-chunk backlogs
    # included) and keeps going — its drained end state is the
    # never-killed truth. Every arrival is in the WAL.
    orig = make_server(learner, wal=wal_path, **args)
    for t, x, y in traffic[:cut]:
        orig.submit(t, x, y)
    orig.checkpoint(tmp_path / "ckpt")
    for t, x, y in traffic[cut:]:
        orig.submit(t, x, y)
    orig.drain()

    # "Kill" = the process is gone; all that survives is the checkpoint
    # directory and the WAL. A fresh identically-configured server
    # restores the generation and replays the WAL suffix.
    restored = make_server(learner, wal=wal_path, **args)
    info = restore_checkpoint(restored, tmp_path / "ckpt")
    assert info["replayed"] == len(traffic) - cut
    restored.drain()

    _assert_leaves_equal(orig.queue.state, restored.queue.state)
    _assert_leaves_equal(orig.snapshot.state, restored.snapshot.state)
    assert orig.policy.state_dict() == restored.policy.state_dict()
    assert orig._expected == restored._expected
    # And both serve identical predictions going forward.
    xq = np.stack([x for _, x, _ in traffic[:6]])
    for tenant in range(3):
        a = np.asarray(orig.predict(tenant, xq))
        b = np.asarray(restored.predict(tenant, xq))
        assert np.array_equal(a, b)
