import jax
import pytest

# Tests run on the default single-CPU backend (the 512-device override is
# dry-run-only by design). Everything here must be fast and deterministic.
jax.config.update("jax_enable_x64", False)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)
