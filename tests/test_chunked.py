"""Chunked-vs-tick equivalence: the multi-tick engine must be a pure
reschedule, not a new algorithm.

Contracts pinned here:
* KLMS chunked (oracle and fused-interpret) is BITWISE the per-tick path —
  the time-blocked kernel multiplies masked updates by exactly 1.0, so an
  unmasked chunk replays the identical f32 op sequence.
* KRLS chunked matches per-tick to 1e-5 f32 (reduction-order only); the
  f64 1e-8 bound rides in the 8-device subprocess test below.
* Masked-remainder chunks are no-ops on state and don't perturb the
  trajectory (the serve queue's ragged-arrival contract).
* ``combine_every`` sharded KRLS (one packed psum per k ticks) drifts from
  the per-tick-psum path by <= 1e-5 f32 / 1e-8 f64 over hundreds of ticks
  on an 8-way host mesh — the communication restructuring is exact math.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bank import (
    bank_hparams,
    hp_bank_init,
    hp_bank_run,
    klms_bank_run,
    krls_bank_init,
    krls_bank_run,
)
from repro.core.klms import lms_step, rff_klms_init, rff_klms_run
from repro.core.krls import rff_krls_run
from repro.core.rff import rff_features, sample_rff
from repro.data.synthetic import gen_nonlinear_wiener
from repro.kernels import ops, ref
from repro.kernels.rff_klms_step import rff_klms_bank_chunk_pallas
from repro.kernels.rff_krls_step import rff_krls_bank_chunk_pallas
from repro.serve import klms_micro_batch_queue, krls_micro_batch_queue

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _chunk_data(key, bank, tlen, d, dfeat):
    ks = jax.random.split(key, 6)
    return (
        jax.random.normal(ks[0], (bank, dfeat)),  # theta
        jax.random.normal(ks[1], (bank, tlen, d)),  # xs
        jax.random.normal(ks[2], (bank, tlen)),  # ys
        jax.random.normal(ks[3], (d, dfeat)),  # w
        jax.random.uniform(ks[4], (dfeat,), maxval=2 * np.pi),  # b
        ks[5],
    )


def test_klms_chunk_oracle_bitwise_vs_tick_scan(key):
    """ops chunk path (xla) == a jitted per-tick scan, BITWISE."""
    theta, xs, ys, w, b, k2 = _chunk_data(key, 5, 13, 4, 96)
    mu = jax.random.uniform(k2, (5,), minval=0.1, maxval=1.0)

    @jax.jit
    def tick_scan(th):
        def body(t, xy):
            x_t, y_t = xy
            t2, p, e = ref.rff_klms_bank_step_ref(t, x_t, y_t, w, b, mu)
            return t2, (p, e)

        th, (ps, es) = jax.lax.scan(
            body, th, (jnp.swapaxes(xs, 0, 1), jnp.swapaxes(ys, 0, 1)),
        )
        return th, jnp.swapaxes(ps, 0, 1), jnp.swapaxes(es, 0, 1)

    want = tick_scan(theta)
    got = ops.rff_klms_bank_chunk(theta, xs, ys, w, b, mu, mode="xla")
    for g, wv in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(wv))


@pytest.mark.parametrize(
    "bank,d,D,T", [(8, 5, 128, 4), (3, 5, 100, 7), (1, 2, 17, 3)],
)
@pytest.mark.parametrize("masked", [False, True])
def test_klms_chunk_kernel_sweep(key, bank, d, D, T, masked):
    """Fused T-chunk kernel (interpret) vs the scan oracle, incl. masks."""
    theta, xs, ys, w, b, k2 = _chunk_data(key, bank, T, d, D)
    ks = jax.random.split(k2, 2)
    mu = jax.random.uniform(ks[0], (bank,), minval=0.05, maxval=1.5)
    mask = (
        (jax.random.uniform(ks[1], (bank, T)) > 0.4).astype(jnp.float32)
        if masked
        else None
    )
    got = rff_klms_bank_chunk_pallas(
        theta, xs, ys, w, b, mu, mask, interpret=True,
    )
    want = ref.rff_klms_bank_chunk_ref(theta, xs, ys, w, b, mu, mask)
    for g, wv in zip(got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(wv), atol=1e-5, rtol=1e-5,
        )


@pytest.mark.parametrize(
    "bank,d,D,T", [(4, 5, 128, 4), (2, 5, 100, 6), (1, 2, 17, 3)],
)
@pytest.mark.parametrize("masked", [False, True])
def test_krls_chunk_kernel_sweep(key, bank, d, D, T, masked):
    """Fused T-chunk RLS kernel (interpret) vs the scan oracle."""
    theta, xs, ys, w, b, k2 = _chunk_data(key, bank, T, d, D)
    ks = jax.random.split(k2, 3)
    a = jax.random.normal(ks[0], (bank, D, D)) * 0.1
    pmat = jnp.eye(D) * 10.0 + jnp.einsum("bij,bkj->bik", a, a)
    beta = jax.random.uniform(ks[1], (bank,), minval=0.9, maxval=1.0)
    mask = (
        (jax.random.uniform(ks[2], (bank, T)) > 0.4).astype(jnp.float32)
        if masked
        else None
    )
    got = rff_krls_bank_chunk_pallas(
        theta, pmat, xs, ys, w, b, beta, mask, interpret=True,
    )
    want = ref.rff_krls_bank_chunk_ref(theta, pmat, xs, ys, w, b, beta, mask)
    for g, wv in zip(got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(wv), atol=1e-5, rtol=1e-5,
        )


def test_chunk_masked_remainder_is_noop(key):
    """A zero-masked tail changes nothing: state after a padded chunk ==
    state after the short chunk (both kernels, both backends)."""
    theta, xs, ys, w, b, k2 = _chunk_data(key, 3, 8, 4, 64)
    valid = 5
    mask = jnp.concatenate(
        [jnp.ones((3, valid)), jnp.zeros((3, 8 - valid))], axis=1,
    )
    for mode in ("xla", "interpret"):
        th_pad, pr_pad, _ = ops.rff_klms_bank_chunk(
            theta, xs, ys, w, b, 0.5, mask, mode=mode,
        )
        th_short, pr_short, _ = ops.rff_klms_bank_chunk(
            theta, xs[:, :valid], ys[:, :valid], w, b, 0.5, mode=mode,
        )
        np.testing.assert_allclose(
            np.asarray(th_pad), np.asarray(th_short), atol=1e-6,
        )
        np.testing.assert_allclose(
            np.asarray(pr_pad[:, :valid]), np.asarray(pr_short), atol=1e-6,
        )

    pmat = jnp.broadcast_to(jnp.eye(64) * 50.0, (3, 64, 64))
    for mode in ("xla", "interpret"):
        got = ops.rff_krls_bank_chunk(
            theta, pmat, xs, ys, w, b, 0.99, mask, mode=mode,
        )
        want = ops.rff_krls_bank_chunk(
            theta, pmat, xs[:, :valid], ys[:, :valid], w, b, 0.99, mode=mode,
        )
        np.testing.assert_allclose(
            np.asarray(got[0]), np.asarray(want[0]), atol=1e-6,
        )
        np.testing.assert_allclose(
            np.asarray(got[1]), np.asarray(want[1]), atol=1e-5,
        )


def test_ops_chunk_knob_splits_launches(key):
    """chunk=k (multiple scanned launches, padded tail) == one launch."""
    theta, xs, ys, w, b, k2 = _chunk_data(key, 4, 11, 3, 48)
    mu = 0.4
    full = ops.rff_klms_bank_chunk(theta, xs, ys, w, b, mu, mode="xla")
    split = ops.rff_klms_bank_chunk(
        theta, xs, ys, w, b, mu, mode="xla", chunk=4,
    )
    for g, wv in zip(split, full):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(wv))

    pmat = jnp.broadcast_to(jnp.eye(48) * 20.0, (4, 48, 48))
    full = ops.rff_krls_bank_chunk(theta, pmat, xs, ys, w, b, 0.99, mode="xla")
    split = ops.rff_krls_bank_chunk(
        theta, pmat, xs, ys, w, b, 0.99, mode="xla", chunk=4,
    )
    for g, wv in zip(split, full):
        np.testing.assert_allclose(np.asarray(g), np.asarray(wv), atol=1e-6)


def test_klms_bank_run_chunked_bitwise():
    """klms_bank_run(chunk=16) == per-tick schedule, bitwise, with a
    masked remainder (n % 16 != 0)."""
    rff = sample_rff(jax.random.PRNGKey(0), 5, 64, sigma=5.0)
    xs, ys = gen_nonlinear_wiener(jax.random.PRNGKey(5), num_samples=200)
    bank, n = 4, 50
    xb = xs[: bank * n].reshape(bank, n, -1)
    yb = ys[: bank * n].reshape(bank, n)
    s1, o1 = klms_bank_run(rff, xb, yb, 0.5, mode="xla")
    s2, o2 = klms_bank_run(rff, xb, yb, 0.5, mode="xla", chunk=16)
    np.testing.assert_array_equal(np.asarray(s1.theta), np.asarray(s2.theta))
    np.testing.assert_array_equal(np.asarray(o1.error), np.asarray(o2.error))
    np.testing.assert_array_equal(np.asarray(s1.step), np.asarray(s2.step))


def test_krls_bank_run_chunked():
    """krls_bank_run(chunk=16) == per-tick schedule to 1e-5 f32."""
    rff = sample_rff(jax.random.PRNGKey(0), 5, 64, sigma=5.0)
    xs, ys = gen_nonlinear_wiener(jax.random.PRNGKey(7), num_samples=200)
    bank, n = 4, 50
    xb = xs[: bank * n].reshape(bank, n, -1)
    yb = ys[: bank * n].reshape(bank, n)
    s1, o1 = krls_bank_run(rff, xb, yb, lam=1e-2, mode="xla")
    s2, o2 = krls_bank_run(rff, xb, yb, lam=1e-2, mode="xla", chunk=16)
    np.testing.assert_allclose(
        np.asarray(o1.error), np.asarray(o2.error), atol=1e-5,
    )
    # state is the more drift-sensitive quantity (P enters every update);
    # reduction-order noise lands ~2e-5 over 50 ticks at lam=1e-2
    np.testing.assert_allclose(
        np.asarray(s1.theta), np.asarray(s2.theta), atol=1e-4,
    )


def test_single_stream_chunked_runs():
    """rff_klms_run / rff_krls_run with chunk=16 (featurize-per-chunk GEMM)
    match the per-tick drivers over a non-multiple-length stream."""
    rff = sample_rff(jax.random.PRNGKey(0), 5, 64, sigma=5.0)
    xs, ys = gen_nonlinear_wiener(jax.random.PRNGKey(9), num_samples=205)
    s1, o1 = rff_klms_run(rff, xs, ys, 0.5)
    s2, o2 = rff_klms_run(rff, xs, ys, 0.5, chunk=16)
    np.testing.assert_allclose(
        np.asarray(o1.error), np.asarray(o2.error), atol=1e-5,
    )
    assert int(s2.step) == 205
    s1, o1 = rff_krls_run(rff, xs, ys, lam=1e-2)
    s2, o2 = rff_krls_run(rff, xs, ys, lam=1e-2, chunk=16)
    np.testing.assert_allclose(
        np.asarray(o1.error), np.asarray(o2.error), atol=2e-5,
    )
    assert int(s2.step) == 205


def test_micro_batch_queue_matches_sequential():
    """Ragged arrivals through masked chunks == per-tenant sequential runs
    (the serve-queue contract: coalescing is invisible to each tenant)."""
    rff = sample_rff(jax.random.PRNGKey(0), 5, 64, sigma=5.0)
    xs, ys = gen_nonlinear_wiener(jax.random.PRNGKey(5), num_samples=200)
    streams = {0: 37, 1: 11, 2: 0, 3: 60}
    per_tenant, offs = {}, 0
    for t, n in streams.items():
        per_tenant[t] = (xs[offs : offs + n], ys[offs : offs + n])
        offs += n

    q = klms_micro_batch_queue(rff, 4, mu=0.5, chunk=16, mode="xla")
    rng = np.random.RandomState(0)
    order = [t for t, n in streams.items() for _ in range(n)]
    rng.shuffle(order)
    results = {t: [] for t in streams}
    iters = {t: 0 for t in streams}
    for i, t in enumerate(order):
        k = iters[t]
        iters[t] += 1
        q.submit(t, per_tenant[t][0][k], per_tenant[t][1][k])
        if i % 23 == 22:  # flush mid-traffic at arbitrary moments
            for b, res in q.flush().items():
                results[b].extend(res)
    for b, res in q.drain().items():
        results[b].extend(res)

    assert not results[2] and q.backlog() == [0, 0, 0, 0]
    for t, n in streams.items():
        if n == 0:
            continue
        assert len(results[t]) == n
        _, want = rff_klms_run(rff, per_tenant[t][0], per_tenant[t][1], 0.5)
        got = np.array([e for _, e in results[t]])
        np.testing.assert_allclose(got, np.asarray(want.error), atol=1e-5)

    qk = krls_micro_batch_queue(rff, 2, lam=1e-2, chunk=8, mode="xla")
    for i in range(21):
        qk.submit(0, xs[i], ys[i])
    for i in range(5):
        qk.submit(1, xs[100 + i], ys[100 + i])
    res = qk.drain()
    _, want0 = rff_krls_run(rff, xs[:21], ys[:21], lam=1e-2)
    _, want1 = rff_krls_run(rff, xs[100:105], ys[100:105], lam=1e-2)
    np.testing.assert_allclose(
        np.array([e for _, e in res[0]]), np.asarray(want0.error), atol=1e-4,
    )
    np.testing.assert_allclose(
        np.array([e for _, e in res[1]]), np.asarray(want1.error), atol=1e-4,
    )


def test_krls_bank_per_tenant_lam_and_beta():
    """(B,) lam AND beta in one bank == per-stream sequential runs — the
    KRLS hyperparameter-sweep item (lambda sweeps in one bank)."""
    rff = sample_rff(jax.random.PRNGKey(0), 5, 64, sigma=5.0)
    xs, ys = gen_nonlinear_wiener(jax.random.PRNGKey(7), num_samples=120)
    bank, n = 3, 120
    xb = jnp.broadcast_to(xs[:n], (bank, n, xs.shape[-1]))
    yb = jnp.broadcast_to(ys[:n], (bank, n))
    lams = jnp.array([1e-1, 1e-2, 1e-3])
    betas = jnp.array([0.97, 0.995, 1.0])
    state = krls_bank_init(rff, bank, lam=lams)
    np.testing.assert_allclose(
        np.asarray(state.pmat[0]), np.eye(64) * 10.0, atol=1e-6,
    )
    _, outs = krls_bank_run(
        rff, xb, yb, lam=lams, beta=betas, mode="xla", chunk=16,
    )
    for i in range(bank):
        _, want = rff_krls_run(
            rff, xs[:n], ys[:n], lam=float(lams[i]), beta=float(betas[i]),
        )
        np.testing.assert_allclose(
            np.asarray(outs.error[i]), np.asarray(want.error), atol=1e-4,
        )


def test_hp_bank_generic_tier(key):
    """The hyperparam-pytree generic bank: vmap over BankHParams rows."""
    rff = sample_rff(jax.random.PRNGKey(0), 5, 64, sigma=5.0)
    xs, ys = gen_nonlinear_wiener(jax.random.PRNGKey(3), num_samples=150)
    bank, n = 3, 50
    xb = xs[: bank * n].reshape(bank, n, -1)
    yb = ys[: bank * n].reshape(bank, n)
    hp = bank_hparams(bank, mu=jnp.array([0.2, 0.5, 0.9]))

    def init_fn(h, k):
        return rff_klms_init(rff.num_features)

    def step_fn(s, h, x, y):
        theta, out = lms_step(s.theta, rff_features(rff, x), y, h.mu)
        return type(s)(theta=theta, step=s.step + 1), out

    states = hp_bank_init(init_fn, hp)
    assert jax.tree.leaves(states)[0].shape[0] == bank
    states, outs = hp_bank_run(step_fn, states, hp, xb, yb)
    for i, m in enumerate([0.2, 0.5, 0.9]):
        _, want = rff_klms_run(rff, xb[i], yb[i], float(m))
        np.testing.assert_allclose(
            np.asarray(outs.error[i]), np.asarray(want.error), atol=1e-5,
        )


_COMBINE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, json
from repro.core.krls import rff_krls_run, sharded_krls_run
from repro.core.rff import sample_rff
from repro.data.synthetic import gen_nonlinear_wiener

res = {}
xs64, ys64 = gen_nonlinear_wiener(jax.random.PRNGKey(1), num_samples=300)
xs, ys = xs64.astype(jnp.float32), ys64.astype(jnp.float32)
rff = sample_rff(jax.random.PRNGKey(0), 5, 256, sigma=5.0)
mesh = jax.make_mesh((8,), ("shard",))

_, tick = sharded_krls_run(mesh, rff, xs, ys, lam=1e-2, beta=0.9995)
_, dense = rff_krls_run(rff, xs, ys, lam=1e-2, beta=0.9995)
for k in (8, 32):
    _, blk = sharded_krls_run(mesh, rff, xs, ys, lam=1e-2, beta=0.9995,
                              combine_every=k)
    res[f"f32_drift_vs_tick_k{k}"] = float(
        jnp.max(jnp.abs(tick.prediction - blk.prediction)))
    res[f"f32_vs_dense_k{k}"] = float(
        jnp.max(jnp.abs(dense.prediction - blk.prediction)))

# remainder: n=300 is not a multiple of 32 -> masked final block above;
# also check state equality via a held-out prediction
if jax.config.jax_enable_x64:
    rff64 = sample_rff(jax.random.PRNGKey(0), 5, 256, sigma=5.0,
                       dtype=jnp.float64)
    _, tick64 = sharded_krls_run(mesh, rff64, xs64, ys64, lam=1e-4,
                                 beta=0.9995)
    _, blk64 = sharded_krls_run(mesh, rff64, xs64, ys64, lam=1e-4,
                                beta=0.9995, combine_every=8)
    res["f64_drift_vs_tick_k8"] = float(
        jnp.max(jnp.abs(tick64.prediction - blk64.prediction)))
print(json.dumps(res))
"""


@pytest.mark.slow
def test_combine_every_drift_on_8_devices():
    """combine_every in {8, 32}: one packed psum per k ticks.

    The f64 bound (1e-8; measured ~7e-13 over 300 ticks) is the exactness
    proof — the replay restructuring is the same algebra, so drift shrinks
    with precision. The f32 bound is reduction-order noise at working
    precision (measured ~2.5e-5 over 300 ticks at D=256, lam=1e-2).
    """
    env = dict(
        os.environ,
        PYTHONPATH=os.path.join(REPO, "src"),
        JAX_ENABLE_X64="1",
    )
    out = subprocess.run(
        [sys.executable, "-c", _COMBINE_SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        timeout=420,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    for k in (8, 32):
        assert res[f"f32_drift_vs_tick_k{k}"] < 5e-5, res
        assert res[f"f32_vs_dense_k{k}"] < 5e-5, res
    assert res["f64_drift_vs_tick_k8"] < 1e-8, res
