"""Observability-layer contracts (repro/obs, serve/metrics, kernel dispatch).

Five families:

* tracer — span nesting/ordering/depth with an injected fake clock,
  ring-buffer overflow truncation accounting, JSONL and Chrome trace-event
  exports (the Chrome export must also satisfy the repo's own
  ``scripts/check_bench_schema.py --trace`` validator);
* probes — ``stats_tap`` reductions pinned against pure-numpy oracles
  (including the non-finite latch), ``ProbeMonitor`` degradation events at
  the pinned default thresholds, event-buffer capping;
* metrics — ``Histogram.observe`` float-exponent bucketing (sub-unit
  observations must NOT collapse into bucket 0 — the bug the frexp fix
  removed), percentile semantics, cross-registry ``merge``;
* dispatch telemetry — live launch/remainder counters and bytes-moved
  gauges from the kernels/ops.py host wrappers, and the traced-vs-live
  split under an enclosing jit;
* server integration — observability must be a pure *observer*: a traced
  + probed server is BITWISE state-identical to an untraced one on the
  same stream, its spans cover the serve tiers, its flush overhead stays
  within a pinned (generous) factor, and ``Server.observability()``
  exports the documented schema.
"""
import importlib.util
import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.rff import sample_rff
from repro.kernels import ops
from repro.obs import probes as obs_probes
from repro.obs import telemetry as obs_telemetry
from repro.obs import trace as obs_trace
from repro.serve import api
from repro.serve.metrics import Histogram, MetricsRegistry

D_IN, D_FEAT = 3, 16
RFF = sample_rff(jax.random.PRNGKey(0), D_IN, D_FEAT, 1.0)


class FakeClock:
    """Deterministic monotonic clock: advances by ``step`` per call."""

    def __init__(self, step=1.0):
        self.t = 0.0
        self.step = step

    def __call__(self):
        self.t += self.step
        return self.t


def ragged_traffic(tenants=3, n=24, seed=0):
    rng = np.random.default_rng(seed)
    return [
        (
            int(rng.integers(0, tenants)),
            rng.normal(size=D_IN).astype(np.float32),
            float(rng.normal()),
        )
        for _ in range(n)
    ]


def assert_trees_bitwise(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# Tracer: nesting, ordering, ring overflow, exports
# ---------------------------------------------------------------------------


def test_span_nesting_parents_depths_and_close_order():
    tr = obs_trace.Tracer(clock=FakeClock())
    with tr.span("serve.submit", tenant=1) as outer:
        with tr.span("queue.flush") as mid:
            with tr.span("kernel.klms_chunk"):
                pass
        tr.instant("snapshot.publish", version=2)
    spans = tr.spans()
    # Spans record at close (innermost first); instants record when called.
    assert [s.name for s in spans] == [
        "kernel.klms_chunk", "queue.flush", "snapshot.publish",
        "serve.submit",
    ]
    by_name = {s.name: s for s in spans}
    k, q, s = (
        by_name["kernel.klms_chunk"],
        by_name["queue.flush"],
        by_name["serve.submit"],
    )
    assert s.parent_id is None and s.depth == 0
    assert q.parent_id == s.span_id and q.depth == 1
    assert k.parent_id == q.span_id and k.depth == 2
    inst = by_name["snapshot.publish"]
    assert inst.kind == "instant"
    assert inst.parent_id == s.span_id and inst.duration == 0.0
    assert mid.t1 is not None and outer.t1 is not None
    # Fake clock: every span got a strictly positive integer duration.
    assert k.duration > 0 and q.duration > k.duration
    assert s.attrs == {"tenant": 1}


def test_ring_overflow_drops_oldest_and_flags_truncation():
    tr = obs_trace.Tracer(capacity=4, clock=FakeClock())
    for i in range(10):
        with tr.span(f"serve.op{i}"):
            pass
    assert len(tr.spans()) == 4
    assert [s.name for s in tr.spans()] == [
        "serve.op6", "serve.op7", "serve.op8", "serve.op9",
    ]
    assert tr.dropped == 6 and tr.truncated
    header = json.loads(tr.to_jsonl().splitlines()[0])
    assert header == {
        "kind": "header", "spans": 4, "dropped": 6, "truncated": True,
    }
    chrome = tr.to_chrome_trace()
    assert chrome["otherData"] == {"dropped": 6, "truncated": True}


def test_tracer_rejects_zero_capacity():
    with pytest.raises(ValueError, match="capacity"):
        obs_trace.Tracer(capacity=0)


def test_jsonl_round_trips_every_span():
    tr = obs_trace.Tracer(clock=FakeClock())
    with tr.span("serve.flush", ticks=3):
        tr.instant("probe.degraded", probe="finite")
    lines = [json.loads(ln) for ln in tr.to_jsonl().splitlines()]
    assert lines[0]["kind"] == "header" and not lines[0]["truncated"]
    recs = {r["name"]: r for r in lines[1:]}
    assert recs["serve.flush"]["attrs"] == {"ticks": 3}
    assert recs["serve.flush"]["dur_us"] > 0
    assert recs["probe.degraded"]["kind"] == "instant"


def _load_schema_checker():
    path = os.path.join(
        os.path.dirname(__file__), "..", "scripts", "check_bench_schema.py"
    )
    spec = importlib.util.spec_from_file_location("check_bench_schema", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_chrome_trace_round_trip_and_schema(tmp_path):
    tr = obs_trace.Tracer(clock=FakeClock())
    with tr.span("serve.submit", tenant=0):
        with tr.span("queue.flush"):
            with tr.span("kernel.klms_chunk", dtype=jnp.float32.dtype):
                pass
        tr.instant("snapshot.publish", version=1)
    path = tmp_path / "trace.json"
    payload = tr.to_chrome_trace(str(path))
    loaded = json.load(open(path))
    assert loaded == json.loads(json.dumps(payload))  # file == return value
    for ev in loaded["traceEvents"]:
        if ev["ph"] == "X":
            assert ev["dur"] > 0
        else:
            assert ev["ph"] == "i"
        json.dumps(ev["args"])  # attrs stayed JSON-able (dtype stringified)
    checker = _load_schema_checker()
    assert checker.check_trace(str(path)) == []
    # And the validator actually bites: drop the kernel span.
    loaded["traceEvents"] = [
        e for e in loaded["traceEvents"] if not e["name"].startswith("kernel.")
    ]
    bad = tmp_path / "bad.json"
    json.dump(loaded, open(bad, "w"))
    errs = checker.check_trace(str(bad))
    assert any("kernel" in e for e in errs)


def test_ambient_helpers_noop_without_active_tracer():
    assert obs_trace.current_tracer() is None
    with obs_trace.span("serve.submit") as sp:
        assert sp is None  # shared null context — untraced fast path
    assert obs_trace.instant("snapshot.publish") is None
    tr = obs_trace.Tracer(clock=FakeClock())
    with obs_trace.activate(None):  # no-op activation needs no branching
        assert obs_trace.current_tracer() is None
    with obs_trace.activate(tr):
        assert obs_trace.current_tracer() is tr
        with obs_trace.span("serve.submit"):
            obs_trace.instant("snapshot.publish")
    assert obs_trace.current_tracer() is None
    assert {s.name for s in tr.spans()} == {
        "serve.submit", "snapshot.publish",
    }


# ---------------------------------------------------------------------------
# Probes: stats_tap vs numpy oracles, monitor thresholds
# ---------------------------------------------------------------------------


def _tap_state(seed=0, poison=False):
    rng = np.random.default_rng(seed)
    theta = rng.normal(size=(3, 8)).astype(np.float32)
    pmat = rng.normal(size=(3, 8, 8)).astype(np.float32)
    pmat = pmat + np.swapaxes(pmat, -1, -2)  # symmetric base
    pmat += 1e-3 * rng.normal(size=pmat.shape).astype(np.float32)
    if poison:
        theta[1, 2] = np.nan
    return {
        "theta": jnp.asarray(theta),
        "pmat": jnp.asarray(pmat),
        "steps": jnp.arange(3, dtype=jnp.int32),  # int leaf: skipped
    }


def test_stats_tap_matches_numpy_oracles():
    state = _tap_state()
    stats = jax.jit(obs_probes.stats_tap)(state)
    theta = np.asarray(state["theta"], np.float64).astype(np.float32)
    pmat = np.asarray(state["pmat"], np.float32)
    assert float(stats["finite"]) == 1.0
    np.testing.assert_allclose(
        float(stats["theta.max_abs"]), np.abs(theta).max(), rtol=1e-6
    )
    np.testing.assert_allclose(
        float(stats["theta.norm_max"]),
        np.sqrt((theta.astype(np.float64) ** 2).sum(-1)).max(),
        rtol=1e-5,
    )
    asym = np.abs(pmat - np.swapaxes(pmat, -1, -2)).max()
    scale = np.abs(pmat).max()
    np.testing.assert_allclose(
        float(stats["pmat.asym_rel"]), asym / scale, rtol=1e-5
    )
    diag = np.abs(np.diagonal(pmat, axis1=-2, axis2=-1))
    np.testing.assert_allclose(
        float(stats["pmat.diag_min"]), diag.min(), rtol=1e-6
    )
    np.testing.assert_allclose(
        float(stats["pmat.cond_proxy"]), diag.max() / diag.min(), rtol=1e-5
    )
    assert not any(k.startswith("steps") for k in stats)  # int leaf skipped


def test_stats_tap_latches_nonfinite():
    stats = obs_probes.stats_tap(_tap_state(poison=True))
    assert float(stats["finite"]) == 0.0


def test_default_thresholds_are_pinned():
    # The documented degradation floors — moving them is an API change.
    t = obs_probes.DEFAULT_THRESHOLDS
    assert t["finite"] == ("min", 1.0)
    assert t["theta.norm_max"] == ("max", 1e6)
    assert t["pmat.asym_rel"] == ("max", 1e-2)
    assert t["pmat.cond_proxy"] == ("max", 1e12)
    assert t["bf16_read_error"] == ("max", 2e-2)


def test_monitor_fires_events_at_pinned_thresholds():
    reg = MetricsRegistry()
    mon = obs_probes.ProbeMonitor(registry=reg)
    tr = obs_trace.Tracer(clock=FakeClock())
    with obs_trace.activate(tr):
        fired = mon.update(
            {"finite": 0.0, "theta.norm_max": 2e6, "pmat.asym_rel": 1e-4},
            tick=7,
        )
    assert {e.probe for e in fired} == {"finite", "theta.norm_max"}
    by_probe = {e.probe: e for e in fired}
    assert by_probe["finite"].direction == "below"
    assert by_probe["theta.norm_max"].direction == "above"
    assert by_probe["theta.norm_max"].threshold == 1e6
    assert by_probe["theta.norm_max"].tick == 7
    assert not mon.healthy() and mon.total_events == 2
    assert reg.count("probe.degraded", probe="finite") == 1
    # Breaches also land as instant events in the active trace.
    marks = [s for s in tr.spans() if s.name == "probe.degraded"]
    assert {m.attrs["probe"] for m in marks} == {"finite", "theta.norm_max"}
    # Healthy update: nothing fires, stats still recorded.
    assert mon.update({"finite": 1.0, "theta.norm_max": 3.0}) == []
    assert mon.last_stats["theta.norm_max"] == 3.0
    assert mon.total_events == 2


def test_monitor_staleness_bf16_and_override_forms():
    mon = obs_probes.ProbeMonitor(
        thresholds={"staleness_ticks": 3, "bf16_read_error": ("max", 1e-3)},
    )
    fired = mon.update({}, staleness=5, bf16_err=5e-4)
    assert [e.probe for e in fired] == ["staleness_ticks"]
    fired = mon.update({}, staleness=1, bf16_err=2e-3)
    assert [e.probe for e in fired] == ["bf16_read_error"]
    state = mon.state()
    assert state["total_events"] == 2 and not state["healthy"]
    assert state["thresholds"]["staleness_ticks"]["value"] == 3.0
    # inf-bounded probes are omitted from the exported threshold table.
    assert "staleness_ticks" in state["thresholds"]


def test_monitor_event_buffer_caps_but_total_keeps_counting():
    mon = obs_probes.ProbeMonitor(max_events=4)
    for i in range(10):
        mon.update({"finite": 0.0}, tick=i)
    assert mon.total_events == 10
    assert len(mon.events) == 4
    assert [e.tick for e in mon.events] == [6, 7, 8, 9]


# ---------------------------------------------------------------------------
# Metrics: frexp bucketing, percentiles, merge
# ---------------------------------------------------------------------------


def test_histogram_sub_unit_observations_resolve_into_distinct_buckets():
    h = Histogram()
    # The old int(v).bit_length() rule put ALL of these in bucket 0.
    for v in (1e-3, 2e-3, 0.1, 0.5):
        assert h._bucket(v) > 0, v
    assert h._bucket(1e-3) != h._bucket(2e-3)
    assert h._bucket(0.1) != h._bucket(0.5)
    assert h._bucket(0.0) == 0
    # Bucket bounds bracket the value (the interpolation contract).
    for v in (1e-3, 0.37, 1.0, 3.5, 1e6):
        lo, hi = h._bucket_range(h._bucket(v))
        assert lo <= v <= hi or math.isclose(v, hi)


def test_histogram_percentile_semantics_pinned():
    h = Histogram()
    for _ in range(50):
        h.observe(1.0)
    for _ in range(50):
        h.observe(100.0)
    s = h.summary()
    assert s["count"] == 100 and s["min"] == 1.0 and s["max"] == 100.0
    # One-octave resolution: p50 lands at the top of 1.0's [1, 2) octave;
    # p95/p99 interpolate past 100 and clamp to the exact observed max.
    assert s["p50"] == 2.0
    assert s["p95"] == 100.0 and s["p99"] == 100.0
    assert s["p50"] <= s["p95"] <= s["p99"]


def test_histogram_merge_equals_single_stream():
    rng = np.random.default_rng(3)
    a_vals = rng.lognormal(0.0, 2.0, 200)
    b_vals = rng.lognormal(1.0, 1.0, 300)
    ha, hb, hall = Histogram(), Histogram(), Histogram()
    for v in a_vals:
        ha.observe(v)
        hall.observe(v)
    for v in b_vals:
        hb.observe(v)
        hall.observe(v)
    merged = ha.merge(hb)
    assert merged is ha
    assert merged.counts == hall.counts
    ms, hs = merged.summary(), hall.summary()
    for k in ("count", "min", "max", "p50", "p95", "p99"):
        assert ms[k] == hs[k], k
    assert ms["mean"] == pytest.approx(hs["mean"])  # summation order
    with pytest.raises(ValueError, match="bucket mismatch"):
        Histogram(max_buckets=8).merge(Histogram(max_buckets=16))


def test_registry_labels_and_merge():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("kernel.launches", op="klms_chunk").inc(3)
    b.counter("kernel.launches", op="klms_chunk").inc(2)
    b.counter("kernel.launches", op="krls_chunk").inc()
    a.set_gauge("kernel.bytes_moved", 10.0, op="klms_chunk")
    b.set_gauge("kernel.bytes_moved", 20.0, op="klms_chunk")
    a.histogram("latency.write_us").observe(4.0)
    b.histogram("latency.write_us").observe(16.0)
    a.merge(b)
    snap = a.snapshot()
    assert snap["counters"]["kernel.launches{op=klms_chunk}"] == 5
    assert snap["counters"]["kernel.launches{op=krls_chunk}"] == 1
    assert snap["gauges"]["kernel.bytes_moved{op=klms_chunk}"] == 20.0
    assert snap["histograms"]["latency.write_us"]["count"] == 2


# ---------------------------------------------------------------------------
# Dispatch telemetry: live vs traced counting, bytes gauges
# ---------------------------------------------------------------------------


def _chunk_operands(bank=2, tlen=10, seed=0):
    rng = np.random.default_rng(seed)
    theta = jnp.zeros((bank, D_FEAT), jnp.float32)
    xs = jnp.asarray(rng.normal(size=(bank, tlen, D_IN)), jnp.float32)
    ys = jnp.asarray(rng.normal(size=(bank, tlen)), jnp.float32)
    return theta, xs, ys


def test_live_dispatch_counts_launches_and_remainder():
    obs_telemetry.reset()
    theta, xs, ys = _chunk_operands(bank=2, tlen=10)
    ops.rff_klms_bank_chunk(theta, xs, ys, RFF.omega, RFF.bias, 0.2, chunk=4)
    reg = obs_telemetry.registry()
    # T=10 at chunk 4 -> 3 launches, the last one a masked remainder.
    assert reg.count("kernel.launches", op="klms_chunk") == 3
    assert reg.count("kernel.remainder_launches", op="klms_chunk") == 1
    assert reg.count("kernel.traces", op="klms_chunk") == 0
    bm = obs_telemetry.klms_chunk_bytes(2, D_IN, D_FEAT, 4)
    expect = bm["launch_bytes"] * 3 + bm["stream_bytes_per_tick"] * 10
    assert reg.gauge("kernel.bytes_moved", op="klms_chunk") == expect


def test_dispatch_under_enclosing_jit_counts_as_trace_not_launch():
    obs_telemetry.reset()
    theta, xs, ys = _chunk_operands(bank=2, tlen=10, seed=1)

    @jax.jit
    def program(th, x, y):
        th, preds, errs = ops.rff_klms_bank_chunk(
            th, x, y, RFF.omega, RFF.bias, 0.2, chunk=4
        )
        return th, preds, errs

    program(theta, xs, ys)
    program(theta, xs, ys)  # second call: cached program, no re-trace
    reg = obs_telemetry.registry()
    assert reg.count("kernel.traces", op="klms_chunk") == 1
    assert reg.count("kernel.launches", op="klms_chunk") == 0


def test_dispatch_spans_carry_shape_attrs():
    obs_telemetry.reset()
    tr = obs_trace.Tracer(clock=FakeClock())
    theta, xs, ys = _chunk_operands(bank=2, tlen=10, seed=2)
    with obs_trace.activate(tr):
        ops.rff_klms_bank_chunk(theta, xs, ys, RFF.omega, RFF.bias, 0.2, chunk=4)
    (sp,) = [s for s in tr.spans() if s.name == "kernel.klms_chunk"]
    assert sp.attrs["shape"] == [2, 10, D_IN]
    assert sp.attrs["dfeat"] == D_FEAT
    assert sp.attrs["launches"] == 3
    assert sp.attrs["traced"] is False
    assert sp.attrs["chunk"] == 4


# ---------------------------------------------------------------------------
# Server integration: bitwise purity, span coverage, overhead, export
# ---------------------------------------------------------------------------


def _drive(srv, traffic, read_every=5):
    for i, (t, x, y) in enumerate(traffic):
        if i % read_every == read_every - 1:
            srv.predict(t, x)
        else:
            srv.submit(t, x, y)
    srv.drain()


@pytest.mark.parametrize(
    "learner,hp",
    [
        ("klms", dict(mu=0.3)),
        ("krls", dict(beta=0.999, lam=0.1)),
    ],
)
def test_traced_probed_server_is_bitwise_identical_to_untraced(learner, hp):
    traffic = ragged_traffic(tenants=3, n=24, seed=4)
    plain = api.make_server(
        learner, feature_map=RFF, bank=3, chunk=4, **hp
    )
    traced = api.make_server(
        learner, feature_map=RFF, bank=3, chunk=4, trace=True, probe=True,
        **hp,
    )
    _drive(plain, traffic)
    _drive(traced, traffic)
    assert_trees_bitwise(plain.queue.state, traced.queue.state)
    # The observer actually observed: spans from the serve tiers...
    by_name = traced.tracer.summary()["by_name"]
    assert any(n.startswith("serve.") for n in by_name)
    assert any(n.startswith("queue.") for n in by_name)
    assert any(n.startswith("snapshot.") for n in by_name)
    # ...and the probe tap read real state at flush boundaries.
    assert traced.probe.updates > 0
    assert traced.probe.last_stats["finite"] == 1.0
    if learner == "krls":
        assert "pmat.asym_rel" in traced.probe.last_stats


def test_observability_export_schema_and_read_contract():
    srv = api.make_server(
        "klms", feature_map=RFF, bank=2, chunk=4, mu=0.3,
        trace=True, probe=True,
    )
    _drive(srv, ragged_traffic(tenants=2, n=16, seed=7))
    xq = np.ones((2, 3, D_IN), np.float32)
    err = srv.check_read_contract(xq)
    assert isinstance(err, float) and 0.0 <= err < 0.05
    assert srv.probe.last_stats["bf16_read_error"] == err
    out = srv.observability()
    assert set(out) == {"metrics", "dispatch", "probes", "trace"}
    assert "histograms" in out["metrics"]
    assert out["metrics"]["counters"]["requests.write"] > 0
    assert any(
        k.startswith("dispatch.launches") for k in out["dispatch"]["counters"]
    )
    assert out["probes"]["healthy"] in (True, False)
    assert out["trace"]["spans"] > 0 and "by_name" in out["trace"]
    json.dumps(out)  # the whole export is JSON-able as documented


def test_untraced_server_has_no_observability_overheads_wired():
    srv = api.make_server("klms", feature_map=RFF, bank=2, chunk=4, mu=0.3)
    assert srv.tracer is None and srv.probe is None
    out = srv.observability()
    assert out["probes"] is None and out["trace"] is None


def test_traced_flush_overhead_within_pinned_factor():
    def build(**obs_kw):
        return api.make_server(
            "klms", feature_map=RFF, bank=2, chunk=4, mu=0.3, **obs_kw
        )

    def cycle(srv, n=40):
        x = np.ones(D_IN, np.float32)
        t0 = time.perf_counter()
        for i in range(n):
            srv.submit(i % 2, x, 1.0)
            srv.flush()
        return time.perf_counter() - t0

    plain, traced = build(), build(trace=True, probe=True)
    cycle(plain, n=8)  # warm both (compile paths, allocator)
    cycle(traced, n=8)
    dt_plain = min(cycle(plain) for _ in range(3))
    dt_traced = min(cycle(traced) for _ in range(3))
    # Generous pin: spans + probe materialization must stay the same order
    # of magnitude as the flush itself, not multiply it.
    assert dt_traced < dt_plain * 20 + 0.05


def test_bf16_read_error_probe_is_small_on_trained_state():
    srv = api.make_server("krls", feature_map=RFF, bank=2, chunk=4,
                          beta=0.999, lam=0.1)
    _drive(srv, ragged_traffic(tenants=2, n=16, seed=9))
    err = obs_probes.bf16_read_error(
        srv.queue.state, RFF, np.ones((2, 4, D_IN), np.float32)
    )
    # bf16 mantissa floor on a tiny trained state (the serving-shape
    # contract at the default 2e-2 threshold is pinned by the Zipf bench
    # probes; here we only require the probe itself to be sane).
    assert 0.0 <= err < 0.05
