"""Checkpoint/restart, crash recovery, straggler watchdog, elastic remesh."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.lm_data import batch_at_step
from repro.train import checkpoint as ckpt
from repro.train.elastic import remesh
from repro.train.trainer import Trainer, TrainerConfig


def _tiny_cfg():
    return get_config("qwen2-0.5b").reduced()


def _batch_fn(cfg):
    def fn(step):
        return {
            "tokens": batch_at_step(
                0, step, global_batch=4, seq_len=16, vocab=cfg.vocab_size
            )
        }

    return fn


def test_checkpoint_roundtrip(tmp_path, key):
    state = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 3))}}
    ckpt.save(str(tmp_path), 5, state)
    restored, step = ckpt.restore(str(tmp_path))
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(10.0))


def test_checkpoint_keep_k_gc(tmp_path):
    for s in range(1, 8):
        ckpt.save(str(tmp_path), s, {"x": jnp.zeros(2)}, keep=3)
    assert ckpt.list_steps(str(tmp_path)) == [5, 6, 7]


def test_restore_survives_corrupt_latest(tmp_path):
    """A truncated newest checkpoint must fall back, not crash (node died
    mid-write is the normal case at 1000-node scale)."""
    ckpt.save(str(tmp_path), 1, {"x": jnp.ones(4)})
    ckpt.save(str(tmp_path), 2, {"x": 2 * jnp.ones(4)})
    # corrupt step 2 (simulate a crash mid-write that still got renamed)
    with open(os.path.join(str(tmp_path), "step_2.ckpt"), "wb") as f:
        f.write(b"garbage")
    restored, step = ckpt.restore(str(tmp_path))
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.ones(4))


def test_trainer_resume_bit_exact(tmp_path, key):
    """train 6 straight == train 3 + crash + resume 3 (stateless data)."""
    cfg = _tiny_cfg()

    tA = Trainer(
        cfg,
        TrainerConfig(total_steps=6, ckpt_every=100, ckpt_dir=str(tmp_path / "a"),
                      num_microbatches=2, log_every=100),
        _batch_fn(cfg),
    )
    tA.run()
    thetaA = jax.tree.leaves(tA.state["params"])[0]

    dirB = str(tmp_path / "b")
    tB1 = Trainer(
        cfg,
        TrainerConfig(total_steps=3, ckpt_every=3, ckpt_dir=dirB,
                      num_microbatches=2, log_every=100),
        _batch_fn(cfg),
    )
    tB1.run()
    del tB1  # "crash"
    tB2 = Trainer(
        cfg,
        TrainerConfig(total_steps=6, ckpt_every=3, ckpt_dir=dirB,
                      num_microbatches=2, log_every=100),
        _batch_fn(cfg),
    )
    tB2.run()
    thetaB = jax.tree.leaves(tB2.state["params"])[0]
    np.testing.assert_allclose(
        np.asarray(thetaA, np.float32), np.asarray(thetaB, np.float32),
        atol=1e-6,
    )


def test_straggler_watchdog_detects_slow_steps(tmp_path):
    cfg = _tiny_cfg()
    t = Trainer(
        cfg,
        TrainerConfig(total_steps=14, ckpt_every=100, ckpt_dir=str(tmp_path),
                      log_every=100),
        _batch_fn(cfg),
        delay_injector=lambda step: 0.4 if step == 12 else 0.0,
    )
    t.run()
    assert t.straggler_events >= 1


def test_elastic_remesh_preserves_values(key):
    """Re-sharding to a new (here: same-size) mesh preserves the state."""
    state = {"w": jax.random.normal(key, (8, 8))}
    mesh = jax.make_mesh((1,), ("data",))
    shard = jax.tree.map(
        lambda _: jax.NamedSharding(mesh, jax.sharding.PartitionSpec()), state
    )
    moved = remesh(state, shard)
    np.testing.assert_array_equal(np.asarray(moved["w"]), np.asarray(state["w"]))


def test_gradient_compression_error_feedback():
    """int8+EF: compression error stays O(1) over many rounds instead of
    accumulating (the residual re-injection property)."""
    from repro.optim.compression import compress_tree, decompress_tree, init_state

    grads = {"w": jnp.linspace(-1, 1, 1000)}
    st = init_state(grads)
    total_sent = jnp.zeros(1000)
    for _ in range(50):
        q, s, st = compress_tree(grads, st)
        total_sent = total_sent + decompress_tree(q, s)["w"]
    # after T rounds, sum of sent ~= T * grads (EF guarantees bounded bias)
    err = float(jnp.max(jnp.abs(total_sent / 50 - grads["w"])))
    assert err < 1e-3
