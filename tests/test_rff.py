"""RFF feature-map correctness: Theorem 1 and the eq. (2) estimator."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.rff import (
    gaussian_kernel,
    kernel_estimate,
    positive_random_features,
    rff_features,
    sample_prf,
    sample_rff,
)


def test_feature_shape_and_scale(key):
    rff = sample_rff(key, 5, 128, sigma=2.0)
    x = jax.random.normal(jax.random.PRNGKey(1), (7, 5))
    z = rff_features(rff, x)
    assert z.shape == (7, 128)
    # ||z(x)||^2 ~= kappa(0) = 1 in expectation
    norms = jnp.sum(z * z, axis=-1)
    assert jnp.all(jnp.abs(norms - 1.0) < 0.5)


@pytest.mark.parametrize("sigma", [0.5, 2.0, 5.0])
def test_kernel_estimate_converges_with_d(key, sigma):
    """Monte-Carlo error shrinks roughly like 1/sqrt(D) (paper eq. (2))."""
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 4))
    y = jax.random.normal(jax.random.PRNGKey(2), (64, 4))
    exact = gaussian_kernel(x, y, sigma)
    errs = []
    for d in (64, 1024):
        rff = sample_rff(key, 4, d, sigma)
        approx = kernel_estimate(rff, x, y)
        errs.append(float(jnp.sqrt(jnp.mean((approx - exact) ** 2))))
    assert errs[1] < errs[0]
    assert errs[1] < 0.1


def test_kernel_estimate_unbiased_across_seeds():
    """Averaging estimates over independent Omega draws approaches exact."""
    x = jnp.array([[0.3, -0.5, 1.0]])
    y = jnp.array([[-0.2, 0.1, 0.4]])
    exact = float(gaussian_kernel(x, y, 1.5)[0])
    vals = []
    for s in range(200):
        rff = sample_rff(jax.random.PRNGKey(s), 3, 16, 1.5)
        vals.append(float(kernel_estimate(rff, x, y)[0]))
    assert abs(np.mean(vals) - exact) < 0.02


def test_shift_invariance(key):
    """kappa(x-y) depends only on the difference: z(x).z(y) = z(x+c).z(y+c)
    in expectation; check with large D."""
    rff = sample_rff(key, 3, 8192, 1.0)
    x = jnp.array([0.1, 0.2, -0.3])
    y = jnp.array([-0.5, 0.4, 0.0])
    c = jnp.array([1.0, -2.0, 0.7])
    k1 = float(kernel_estimate(rff, x, y))
    k2 = float(kernel_estimate(rff, x + c, y + c))
    assert abs(k1 - k2) < 0.06


def test_prf_positive_and_softmax_kernel(key):
    rff = sample_prf(key, 8, 512)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    phi = positive_random_features(rff, x)
    assert jnp.all(phi > 0)
    # relative kernel weights approximate exp(q.k) ratios
    q = 0.2 * jax.random.normal(jax.random.PRNGKey(2), (1, 8))
    k1 = 0.2 * jax.random.normal(jax.random.PRNGKey(3), (1, 8))
    k2 = 0.2 * jax.random.normal(jax.random.PRNGKey(4), (1, 8))
    pq = positive_random_features(rff, q)
    r_est = float(jnp.sum(pq * positive_random_features(rff, k1))) / float(
        jnp.sum(pq * positive_random_features(rff, k2))
    )
    r_true = float(jnp.exp(jnp.sum(q * k1) - jnp.sum(q * k2)))
    assert abs(r_est - r_true) / r_true < 0.25


def test_orthogonal_rff_lower_variance(key):
    """Beyond-paper: orthogonal random features (Yu et al. 2016) keep the
    estimator unbiased but strictly reduce kernel-approximation variance —
    the same D buys a lower RFFKLMS error floor."""
    import numpy as np

    x = jax.random.normal(jax.random.PRNGKey(1), (128, 8))
    y = jax.random.normal(jax.random.PRNGKey(2), (128, 8))
    exact = gaussian_kernel(x, y, 2.0)
    errs = {}
    for orth in (False, True):
        sq = []
        for s in range(24):
            rff = sample_rff(jax.random.PRNGKey(100 + s), 8, 64, 2.0,
                             orthogonal=orth)
            approx = kernel_estimate(rff, x, y)
            sq.append(float(jnp.mean((approx - exact) ** 2)))
        errs[orth] = np.mean(sq)
    assert errs[True] < errs[False], errs
