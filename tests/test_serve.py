"""Serving-loop integration: generation across state families + training
actually reduces loss end-to-end (the e2e driver contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import (
    decode_state_init,
    decode_step,
    init_params,
    with_rff_attention,
)
from repro.serve import generate


@pytest.mark.parametrize(
    "arch", ["qwen2-0.5b", "mamba2-130m", "recurrentgemma-2b"]
)
def test_generate_shapes_and_determinism(arch, key):
    cfg = get_config(arch).reduced()
    params = init_params(key, cfg)
    prompt = jax.random.randint(key, (2, 5), 0, cfg.vocab_size)
    out1 = generate(params, cfg, prompt, steps=8, max_len=32)
    out2 = generate(params, cfg, prompt, steps=8, max_len=32)
    assert out1.shape == (2, 8)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert int(out1.max()) < cfg.padded_vocab


def test_generate_greedy_matches_manual_loop(key):
    """generate() == hand-rolled prefill+decode loop (pins scan plumbing)."""
    cfg = get_config("llama3-8b").reduced()
    params = init_params(key, cfg)
    prompt = jax.random.randint(key, (1, 4), 0, cfg.vocab_size)

    state = decode_state_init(cfg, 1, max_len=32)
    lg = None
    for t in range(4):
        lg, state = decode_step(params, cfg, state, prompt[:, t])
    toks = []
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    for _ in range(6):
        toks.append(tok)
        lg, state = decode_step(params, cfg, state, tok)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
    manual = jnp.stack(toks, 1)

    fast = generate(params, cfg, prompt, steps=6, max_len=32)
    np.testing.assert_array_equal(np.asarray(fast), np.asarray(manual))


def test_rff_generation_runs(key):
    cfg = with_rff_attention(get_config("llama3-8b").reduced())
    params = init_params(key, cfg)
    prompt = jax.random.randint(key, (2, 3), 0, cfg.vocab_size)
    out = generate(params, cfg, prompt, steps=5, max_len=16)
    assert out.shape == (2, 5)


def test_training_reduces_loss_end_to_end(key, tmp_path):
    """A few hundred steps of the e2e driver measurably reduce loss on the
    structured synthetic stream (deliverable (b): train a model end-to-end)."""
    from repro.data.lm_data import batch_at_step
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config("qwen2-0.5b").reduced()

    def batch_fn(step):
        return {
            "tokens": batch_at_step(
                0, step, global_batch=4, seq_len=32, vocab=cfg.vocab_size
            )
        }

    t = Trainer(
        cfg,
        TrainerConfig(total_steps=40, ckpt_every=1000, log_every=1000,
                      ckpt_dir=str(tmp_path), num_microbatches=2,
                      peak_lr=3e-3),
        batch_fn,
    )
    t.init_or_resume()
    # loss at step 0 vs trained
    from repro.models import lm_loss

    b0 = batch_fn(0)["tokens"]
    loss0 = float(lm_loss(t.state["params"], cfg, tokens=b0))
    t.run()
    loss1 = float(lm_loss(t.state["params"], cfg, tokens=b0))
    assert loss1 < loss0 - 0.5, (loss0, loss1)
