"""Snapshot-decoupled serving invariants: every prediction reflects a whole
publish boundary (no torn reads), staleness is bounded by publish_every,
and the watermark-driven background flush actually flushes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.rff import sample_rff
from repro.features.base import featurize
from repro.serve import klms_snapshot_server, krls_snapshot_server

RFF = sample_rff(jax.random.PRNGKey(0), 4, 48, sigma=3.0)
_RNG = np.random.RandomState(7)
XS = _RNG.randn(400, 4).astype(np.float32)
YS = _RNG.randn(400).astype(np.float32)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _expected_pred(theta_row, x):
    z = featurize(RFF, jnp.asarray(x))
    return jnp.sum(theta_row.astype(jnp.float32) * z.astype(jnp.float32))


def _drive(server, schedule, publish_every):
    """Run a submit/flush/predict schedule; verify every prediction against
    an offline replay of the publish history.

    ``schedule`` items: ("submit", tenant, i) enqueues sample i,
    ("flush",) flushes, ("predict", tenant, i) queries with sample i.
    The replay records theta at every publish boundary; a torn read — a
    prediction built from thetas of two different flushes — would match
    no recorded boundary.
    """
    boundary_thetas = [np.asarray(server.queue.state.theta)]
    for step in schedule:
        if step[0] == "submit":
            _, tenant, i = step
            server.submit(tenant, XS[i], YS[i])
        elif step[0] == "flush":
            ver_before = server.snapshot.version
            server.flush()
            if server.snapshot.version != ver_before:
                boundary_thetas.append(np.asarray(server.snapshot.state.theta))
        else:
            _, tenant, i = step
            got = float(server.predict(tenant, XS[i]))
            snap = server.snapshot
            # The served replica IS the latest recorded publish boundary.
            assert snap.version == len(boundary_thetas) - 1
            want = float(_expected_pred(
                jnp.asarray(boundary_thetas[snap.version][tenant]), XS[i]
            ))
            np.testing.assert_allclose(got, want, atol=1e-6, rtol=1e-6)
            # Staleness never reaches publish_every outside a flush.
            assert 0 <= server.staleness < publish_every
    return boundary_thetas


def test_interleaved_reads_consistent_with_publish_boundaries():
    """Deterministic interleaving: reads between flushes keep returning the
    frozen replica even as the live state trains past it."""
    publish_every = 6
    srv = klms_snapshot_server(
        RFF, 3, mu=0.5, chunk=4, publish_every=publish_every, mode="xla"
    )
    schedule = []
    i = 0
    for round_ in range(12):
        for _ in range(1 + round_ % 3):
            schedule.append(("submit", round_ % 3, i))
            i += 1
        schedule.append(("predict", round_ % 3, i % 50))
        schedule.append(("flush",))
        schedule.append(("predict", (round_ + 1) % 3, i % 50))
    boundaries = _drive(srv, schedule, publish_every)
    assert len(boundaries) >= 3  # publishes actually happened
    srv.drain()
    assert srv.staleness < publish_every


def test_reads_are_stale_until_publish():
    """publish_every > backlog: flushes advance the live state while the
    replica (and therefore reads) stay at version 0 until enough ticks
    accumulate — the read path provably does NOT track the live state."""
    srv = klms_snapshot_server(
        RFF, 1, mu=0.5, chunk=4, publish_every=100, mode="xla"
    )
    p0 = float(srv.predict(0, XS[0]))
    for i in range(8):
        srv.submit(0, XS[i], YS[i])
    srv.flush()
    srv.flush()
    assert srv.queue.ticks_served == 8
    assert srv.snapshot.version == 0 and srv.staleness == 8
    assert float(srv.predict(0, XS[0])) == p0  # frozen replica, frozen read
    srv.publish()  # manual publish releases the new state to readers
    assert srv.staleness == 0
    assert float(srv.predict(0, XS[0])) != p0


def test_size_watermark_background_flush():
    srv = klms_snapshot_server(
        RFF, 2, mu=0.5, chunk=8, publish_every=1, mode="xla", size_watermark=3
    )
    srv.submit(0, XS[0], YS[0])
    srv.submit(1, XS[1], YS[1])
    srv.submit(0, XS[2], YS[2])
    assert srv.queue.flushes == 0
    srv.submit(0, XS[3], YS[3])  # tenant 0 hits depth 3 -> flush
    assert srv.queue.flushes == 1
    assert srv.queue.backlog() == [0, 0]
    assert srv.snapshot.version == 1  # publish_every=1 published it


def test_age_watermark_background_flush():
    clock = FakeClock()
    srv = klms_snapshot_server(
        RFF,
        2,
        mu=0.5,
        chunk=8,
        publish_every=1,
        mode="xla",
        age_watermark=5.0,
        clock=clock,
    )
    srv.submit(0, XS[0], YS[0])
    clock.t = 4.0
    srv.submit(1, XS[1], YS[1])
    assert srv.queue.flushes == 0  # oldest is 4s — under the watermark
    clock.t = 5.5
    srv.maybe_flush()  # the event-loop poll hook
    assert srv.queue.flushes == 1 and srv.queue.backlog() == [0, 0]
    # Age resets with the queue drained: no spurious follow-up flush.
    srv.maybe_flush()
    assert srv.queue.flushes == 1


def test_direct_queue_flush_still_counts_toward_publish():
    """Publish due-ness derives from replica staleness, so ticks applied by
    calling queue.flush() directly (the queue's own API) still trigger a
    publish at the server's next flush."""
    srv = klms_snapshot_server(
        RFF, 1, mu=0.5, chunk=4, publish_every=3, mode="xla"
    )
    for i in range(4):
        srv.queue.submit(0, XS[i], YS[i])
    srv.queue.flush()  # bypasses the server: 4 ticks, no publish
    assert srv.snapshot.version == 0 and srv.staleness == 4
    srv.submit(0, XS[4], YS[4])
    srv.flush()  # staleness 5 >= publish_every 3 -> publish catches up
    assert srv.snapshot.version == 1 and srv.staleness == 0


def test_age_watermark_survives_interleaved_direct_submits():
    """A timed observation keeps its deadline even when untimed direct
    queue submissions sit ahead of it in the backlog."""
    clock = FakeClock()
    srv = klms_snapshot_server(
        RFF,
        1,
        mu=0.5,
        chunk=1,  # one observation per flush: the direct one goes first
        publish_every=1,
        mode="xla",
        age_watermark=5.0,
        clock=clock,
    )
    srv.queue.submit(0, XS[0], YS[0])  # untimed, position 0
    srv.submit(0, XS[1], YS[1])  # timed at t=0, position 1
    srv.flush()  # serves only the untimed head (chunk=1)
    assert srv.queue.backlog() == [1]
    clock.t = 6.0
    srv.maybe_flush()  # the timed observation's deadline must still fire
    assert srv.queue.backlog() == [0]


def test_krls_snapshot_server_predicts_from_replica():
    srv = krls_snapshot_server(
        RFF, 2, lam=1e-2, chunk=8, publish_every=4, mode="xla"
    )
    for i in range(6):
        srv.submit(0, XS[i], YS[i])
    srv.drain()
    assert srv.snapshot.version >= 1
    got = float(srv.predict(0, XS[10]))
    want = float(_expected_pred(srv.snapshot.state.theta[0], XS[10]))
    np.testing.assert_allclose(got, want, atol=1e-6, rtol=1e-6)
    # Block reads serve the whole bank from the same replica.
    blk = srv.predict_block(np.broadcast_to(XS[10][None, None], (2, 1, 4)))
    np.testing.assert_allclose(float(blk[0, 0]), got, atol=1e-6)


def test_predict_block_precision_knob():
    srv = klms_snapshot_server(
        RFF, 2, mu=0.5, chunk=8, publish_every=1, mode="xla", precision="bf16"
    )
    for i in range(8):
        srv.submit(0, XS[i], YS[i])
        srv.submit(1, XS[i], YS[i])
    srv.drain()
    f32 = _expected_pred(srv.snapshot.state.theta[0], XS[20])
    got = float(srv.predict(0, XS[20]))
    assert abs(got - float(f32)) < 2e-2  # the documented bf16 read bound
    # Training state is untouched by the read precision knob.
    assert srv.queue.state.theta.dtype == jnp.float32


def test_reset_requires_drained_queue():
    srv = klms_snapshot_server(RFF, 1, chunk=4, mode="xla")
    srv.submit(0, XS[0], YS[0])
    with pytest.raises(RuntimeError):
        srv.reset(srv.queue.state)
    srv.drain()
    srv.reset(srv.queue.state)
    assert srv.snapshot.version == 0 and srv.staleness == 0


# ---------------------------------------------------------------------------
# Property test: ANY interleaving of submit/flush/predict serves every read
# from some whole publish boundary with bounded staleness.
# ---------------------------------------------------------------------------

try:  # optional dep: only the hypothesis test skips without it — the
    # deterministic interleaving tests above must always run.
    from hypothesis import given, settings, strategies as st

    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    _ops = st.lists(
        st.one_of(
            st.tuples(
                st.just("submit"), st.integers(0, 2), st.integers(0, 99)
            ),
            st.tuples(st.just("flush")),
            st.tuples(
                st.just("predict"), st.integers(0, 2), st.integers(0, 99)
            ),
        ),
        min_size=5,
        max_size=40,
    )

    @given(schedule=_ops, publish_every=st.integers(1, 9))
    @settings(max_examples=15, deadline=None)
    def test_any_interleaving_no_torn_reads(schedule, publish_every):
        srv = klms_snapshot_server(
            RFF, 3, mu=0.5, chunk=4, publish_every=publish_every, mode="xla"
        )
        _drive(srv, schedule, publish_every)
        srv.drain()
        assert srv.staleness < publish_every
