"""Online-learner behaviour: RFFKLMS, RFFKRLS, QKLMS, ALD-KRLS."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ald_krls_run,
    qklms_run,
    rff_klms_batch_step,
    rff_klms_init,
    rff_klms_run,
    rff_krls_run,
    sample_rff,
)
from repro.core.theory import rzz_closed_form, steady_state_mse
from repro.data.synthetic import gen_kernel_expansion, gen_nonlinear_wiener


def _example1(n=3000, seed=3):
    return gen_kernel_expansion(jax.random.PRNGKey(seed), num_samples=n)


def test_klms_converges_to_theory_floor(key):
    """Paper Fig. 1: steady-state MSE ~= Prop. 1.4 model."""
    data = _example1(4000)
    rff = sample_rff(key, 5, 500, sigma=5.0)
    _, out = jax.jit(lambda: rff_klms_run(rff, data.xs, data.ys, mu=1.0))()
    tail = float(jnp.mean(out.error[-1000:] ** 2))
    rzz = rzz_closed_form(rff, 1.0)
    floor = float(steady_state_mse(rzz, 1.0, 0.1))
    start = float(jnp.mean(out.error[:100] ** 2))
    assert tail < start / 10  # converged hard
    assert tail < 3.0 * floor  # near the theoretical floor
    assert tail > 0.5 * floor  # and not magically below it


def test_klms_stability_bound(key):
    """mu > 2/lambda_max diverges; mu < 2/lambda_max converges (Prop 1.1)."""
    data = _example1(2000)
    rff = sample_rff(key, 5, 100, sigma=5.0)
    rzz = rzz_closed_form(rff, 1.0)
    lam_max = float(jnp.linalg.eigvalsh(rzz)[-1])
    mu_bad = 2.5 / lam_max * 2.0  # far above the bound
    _, out_bad = rff_klms_run(rff, data.xs, data.ys, mu=mu_bad)
    _, out_ok = rff_klms_run(rff, data.xs, data.ys, mu=1.0)
    assert float(jnp.mean(out_ok.error[-200:] ** 2)) < 1.0
    assert (
        not np.isfinite(float(jnp.mean(out_bad.error[-200:] ** 2)))
        or float(jnp.mean(out_bad.error[-200:] ** 2))
        > 10 * float(jnp.mean(out_ok.error[-200:] ** 2))
    )


def test_klms_batch_step_matches_stationary_point(key):
    """Mini-batch LMS moves theta toward the same LS solution."""
    data = _example1(2048)
    rff = sample_rff(key, 5, 64, sigma=5.0)
    state = rff_klms_init(64)
    for _ in range(6):  # a few epochs of mini-batch passes
        for i in range(0, 2048, 256):
            state, _ = rff_klms_batch_step(
                state, data.xs[i : i + 256], data.ys[i : i + 256], rff, mu=1.0
            )
    # prediction error on fresh data beats predicting zero
    test = gen_kernel_expansion(jax.random.PRNGKey(9), num_samples=512)
    # note: different centers -> compare on ITS OWN training tail instead
    from repro.core.rff import rff_features

    preds = rff_features(rff, data.xs[-512:]) @ state.theta
    mse = float(jnp.mean((preds - data.ys[-512:]) ** 2))
    var = float(jnp.var(data.ys[-512:]))
    assert mse < 0.5 * var


def test_krls_beats_klms_convergence_speed(key):
    """RLS converges faster than LMS (classic result; paper Fig. 2)."""
    xs, ys = gen_nonlinear_wiener(jax.random.PRNGKey(5), num_samples=2000)
    rff = sample_rff(key, 5, 200, sigma=5.0)
    _, out_lms = jax.jit(lambda: rff_klms_run(rff, xs, ys, mu=1.0))()
    _, out_rls = jax.jit(lambda: rff_krls_run(rff, xs, ys))()
    early_lms = float(jnp.mean(out_lms.error[200:600] ** 2))
    early_rls = float(jnp.mean(out_rls.error[200:600] ** 2))
    assert early_rls < early_lms


def test_qklms_dictionary_bounded_by_quantization(key):
    xs, ys = gen_nonlinear_wiener(jax.random.PRNGKey(6), num_samples=2000)
    f_coarse, _ = qklms_run(xs, ys, sigma=5.0, mu=1.0, eps=10.0, capacity=256)
    f_fine, _ = qklms_run(xs, ys, sigma=5.0, mu=1.0, eps=2.0, capacity=256)
    assert int(f_coarse.size) < int(f_fine.size)
    assert int(f_coarse.size) >= 1


def test_qklms_converges(key):
    xs, ys = gen_nonlinear_wiener(jax.random.PRNGKey(7), num_samples=4000)
    _, out = jax.jit(
        lambda: qklms_run(xs, ys, sigma=5.0, mu=1.0, eps=5.0, capacity=256)
    )()
    assert float(jnp.mean(out.error[-500:] ** 2)) < float(
        jnp.mean(out.error[:100] ** 2)
    )


def test_ald_krls_dictionary_and_convergence(key):
    xs, ys = gen_nonlinear_wiener(jax.random.PRNGKey(8), num_samples=1500)
    # nu=5e-3 (not the paper's 5e-4): with the near-flat sigma=5 kernel the
    # bordered inverse is ill-conditioned; f32 needs the larger threshold
    # (the paper ran f64 Matlab). See benchmarks/fig2b for the comparison.
    final, out = jax.jit(
        lambda: ald_krls_run(xs, ys, sigma=5.0, nu=5e-3, capacity=128)
    )()
    assert 1 <= int(final.size) <= 128
    assert float(jnp.mean(out.error[-300:] ** 2)) < float(
        jnp.mean(out.error[:50] ** 2)
    )


def test_rffkrls_matches_batch_ridge(key):
    """With beta=1, RLS after n steps == ridge regression on those n samples
    (textbook equivalence; strong correctness anchor for the recursion)."""
    from repro.core.krls import rff_krls_run
    from repro.core.rff import rff_features

    xs = jax.random.normal(jax.random.PRNGKey(1), (300, 3))
    w_true = jnp.array([0.5, -1.0, 2.0])
    ys = xs @ w_true + 0.01 * jax.random.normal(jax.random.PRNGKey(2), (300,))
    rff = sample_rff(key, 3, 50, sigma=2.0)
    lam = 1e-3
    final, _ = rff_krls_run(rff, xs, ys, lam=lam, beta=1.0)
    z = rff_features(rff, xs)  # (n, D)
    ridge = jnp.linalg.solve(
        z.T @ z + lam * jnp.eye(50), z.T @ ys
    )
    # compare on predictions (theta itself is conditioned by Z^T Z's small
    # eigenvalues; the fitted function is the meaningful object)
    np.testing.assert_allclose(
        np.asarray(z @ final.theta), np.asarray(z @ ridge), rtol=0.02,
        atol=0.02,
    )
