"""Facade / shim / policy-tier contracts (serve/api.py, serve/policy.py).

Three contract families:

* equivalence — the deprecated per-family entry points and the
  learner-parameterized facade run the SAME jitted programs, so identical
  input streams must produce bitwise-identical states (all five learner
  families; the three non-fused families are pinned against the core
  ``bank_run`` reference, which the generic masked chunk path must match
  exactly on lockstep traffic);
* deprecation — every old name still imports and emits exactly one
  ``DeprecationWarning`` per process (latch re-armed per test via the
  testing hook);
* policy — eviction-order determinism (score, then recency, then tenant
  id), the admission floor (reject when no incumbent scores strictly
  below the candidate), and pow2 resize compaction preserving resident
  rows bitwise.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bank import bank_init, bank_run, bank_size, resize_bank, tenant_row
from repro.core.rff import sample_rff
from repro.serve import api
from repro.serve.policy import SlotPolicy

D_IN, D_FEAT = 3, 16
RFF = sample_rff(jax.random.PRNGKey(0), D_IN, D_FEAT, 1.0)


def lockstep_stream(bank=4, n=12, seed=0):
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.normal(size=(bank, n, D_IN)), jnp.float32)
    ys = jnp.asarray(rng.normal(size=(bank, n)), jnp.float32)
    return xs, ys


def ragged_traffic(tenants=4, n=40, seed=0):
    rng = np.random.default_rng(seed)
    return [
        (
            int(rng.integers(0, tenants)),
            rng.normal(size=D_IN).astype(np.float32),
            float(rng.normal()),
        )
        for _ in range(n)
    ]


def assert_trees_bitwise(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def assert_trees_close(a, b, rtol=1e-5, atol=1e-6):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(
            np.asarray(la), np.asarray(lb), rtol=rtol, atol=atol
        )


# ---------------------------------------------------------------------------
# Facade vs deprecated entry points: bitwise equivalence, five families
# ---------------------------------------------------------------------------


def test_run_stream_matches_old_serve_bank_stream():
    from repro.serve.bank_loop import serve_bank_stream

    xs, ys = lockstep_stream()
    st_old, out_old = serve_bank_stream(RFF, xs, ys, 0.3)
    st_new, out_new = api.run_stream("klms", RFF, xs, ys, mu=0.3)
    assert_trees_bitwise(st_old, st_new)
    np.testing.assert_array_equal(
        np.asarray(out_old.prediction), np.asarray(out_new.prediction)
    )


def test_run_stream_matches_old_krls_stream():
    from repro.serve.bank_loop import serve_krls_bank_stream

    xs, ys = lockstep_stream(seed=1)
    st_old, _ = serve_krls_bank_stream(RFF, xs, ys, lam=1e-2, beta=0.999)
    st_new, _ = api.run_stream("krls", RFF, xs, ys, lam=1e-2, beta=0.999)
    assert_trees_bitwise(st_old, st_new)


@pytest.mark.parametrize("learner", ["nklms", "qklms", "ald"])
def test_run_stream_matches_core_bank_run(learner):
    """The families with no fused path ride the generic scan — which must
    be the exact program ``core.bank.bank_run`` runs."""
    xs, ys = lockstep_stream(seed=2)
    if learner == "nklms":
        fm, hp = RFF, dict(mu=0.3)
    else:
        fm, hp = None, dict(sigma=1.0, capacity=8)
    lrn = api.build_learner(learner, fm, input_dim=D_IN, **hp)
    ref_state, ref_out = jax.jit(lambda s: bank_run(lrn, s, xs, ys))(
        bank_init(lrn, 4)
    )
    st, out = api.run_stream(learner, fm, xs, ys, input_dim=D_IN, **hp)
    assert_trees_bitwise(ref_state, st)
    np.testing.assert_array_equal(
        np.asarray(ref_out.prediction), np.asarray(out.prediction)
    )


def test_make_queue_matches_old_micro_batch_queues():
    from repro.serve.queue import (
        klms_micro_batch_queue,
        krls_micro_batch_queue,
    )

    traffic = ragged_traffic(n=30)
    for old_factory, learner, hp in [
        (klms_micro_batch_queue, "klms", dict(mu=0.3)),
        (krls_micro_batch_queue, "krls", dict(lam=1e-2, beta=0.999)),
    ]:
        q_old = old_factory(RFF, 4, chunk=4, **hp)
        q_new = api.make_queue(learner, RFF, 4, chunk=4, **hp)
        for t, x, y in traffic:
            q_old.submit(t, x, y)
            q_new.submit(t, x, y)
        q_old.drain()
        q_new.drain()
        assert_trees_bitwise(q_old.state, q_new.state)


@pytest.mark.parametrize(
    "learner,kw",
    [
        ("klms", dict(feature_map=RFF, mu=0.3)),
        ("nklms", dict(feature_map=RFF, mu=0.3)),
        ("krls", dict(feature_map=RFF, lam=1e-2, beta=0.999)),
        ("qklms", dict(input_dim=D_IN, sigma=1.0, capacity=8)),
        ("ald", dict(input_dim=D_IN, sigma=1.0, capacity=8)),
    ],
)
def test_server_chunked_matches_lockstep_reference(learner, kw):
    """Full facade write path (queue + snapshot) on lockstep traffic ==
    the one-shot stream drive, for every family. KLMS and the generic
    families are bitwise; KRLS compares the one-launch stream kernel
    against per-chunk launches — different GEMM groupings for the P
    update — so it gets a tight f32 tolerance instead."""
    xs, ys = lockstep_stream(bank=3, n=8, seed=3)
    ref_state, _ = api.run_stream(
        learner, kw.get("feature_map"), xs, ys, chunk=4,
        **{k: v for k, v in kw.items() if k != "feature_map"},
    )
    srv = api.make_server(learner, bank=3, chunk=4, **kw)
    for t in range(xs.shape[1]):
        for b in range(3):
            srv.submit(b, np.asarray(xs[b, t]), float(ys[b, t]))
    srv.drain()
    if learner == "krls":
        assert_trees_close(ref_state, srv.queue.state, rtol=1e-4, atol=1e-5)
    else:
        assert_trees_bitwise(ref_state, srv.queue.state)


def test_old_snapshot_server_matches_facade_server():
    from repro.serve.snapshot import klms_snapshot_server

    traffic = ragged_traffic(n=40, seed=4)
    old = klms_snapshot_server(RFF, 4, mu=0.3, chunk=4, log_capacity=8)
    new = api.make_server(
        "klms", feature_map=RFF, bank=4, chunk=4, mu=0.3, log_capacity=8
    )
    for t, x, y in traffic:
        old.submit(t, x, y)
        new.submit(t, x, y)
    old.drain()
    new.drain()
    old.evict(2)
    new.evict(2)
    assert old.readmit(2) == new.readmit(2)
    assert_trees_bitwise(old.queue.state, new.queue.state)
    q = np.zeros(D_IN, np.float32)
    np.testing.assert_array_equal(
        np.asarray(old.predict(1, q)), np.asarray(new.predict(1, q))
    )


def test_reset_slots_matches_old_resets():
    from repro.serve.bank_loop import reset_krls_tenants, reset_tenants

    xs, ys = lockstep_stream()
    st, _ = api.run_stream("klms", RFF, xs, ys, mu=0.3)
    slots = jnp.array([0, 2])
    assert_trees_bitwise(
        reset_tenants(st, slots), api.reset_slots(st, slots)
    )
    kst, _ = api.run_stream("krls", RFF, xs, ys, lam=1e-2)
    assert_trees_bitwise(
        reset_krls_tenants(kst, slots, lam=1e-2),
        api.reset_slots(kst, slots, learner="krls", lam=1e-2),
    )


def test_facade_rejects_unknown_learner_and_hp():
    with pytest.raises(ValueError, match="unknown learner"):
        api.make_server("svm", feature_map=RFF)
    with pytest.raises(TypeError, match="unknown hyperparameters"):
        api.make_server("klms", feature_map=RFF, learning_rate=0.1)


# ---------------------------------------------------------------------------
# Deprecation shims: every old name importable, exactly one warning each
# ---------------------------------------------------------------------------

OLD_NAMES = [
    "make_bank_server",
    "serve_bank_stream",
    "reset_tenants",
    "make_krls_bank_server",
    "serve_krls_bank_stream",
    "reset_krls_tenants",
    "make_chunked_bank_server",
    "make_chunked_krls_bank_server",
    "klms_micro_batch_queue",
    "krls_micro_batch_queue",
    "klms_snapshot_server",
    "krls_snapshot_server",
]


def test_all_old_names_importable_from_serve():
    import repro.serve as serve

    for name in OLD_NAMES:
        assert callable(getattr(serve, name))
        assert name in serve.__all__


def test_deprecation_warning_fires_exactly_once_per_name():
    import repro.serve as serve

    api._reset_deprecation_state()
    xs, ys = lockstep_stream(bank=2, n=4)
    st, _ = api.run_stream("klms", RFF, xs, ys, mu=0.3)
    kst, _ = api.run_stream("krls", RFF, xs, ys)
    calls = {
        "make_bank_server": lambda: serve.make_bank_server(RFF, 0.3),
        "serve_bank_stream": lambda: serve.serve_bank_stream(
            RFF, xs, ys, 0.3
        ),
        "reset_tenants": lambda: serve.reset_tenants(st, jnp.array([0])),
        "make_krls_bank_server": lambda: serve.make_krls_bank_server(RFF),
        "serve_krls_bank_stream": lambda: serve.serve_krls_bank_stream(
            RFF, xs, ys
        ),
        "reset_krls_tenants": lambda: serve.reset_krls_tenants(
            kst, jnp.array([0])
        ),
        "make_chunked_bank_server": lambda: serve.make_chunked_bank_server(
            RFF, 0.3
        ),
        "make_chunked_krls_bank_server": (
            lambda: serve.make_chunked_krls_bank_server(RFF)
        ),
        "klms_micro_batch_queue": lambda: serve.klms_micro_batch_queue(
            RFF, 2
        ),
        "krls_micro_batch_queue": lambda: serve.krls_micro_batch_queue(
            RFF, 2
        ),
        "klms_snapshot_server": lambda: serve.klms_snapshot_server(RFF, 2),
        "krls_snapshot_server": lambda: serve.krls_snapshot_server(RFF, 2),
    }
    assert set(calls) == set(OLD_NAMES)
    for name, call in calls.items():
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            call()
            call()  # second call: latched, no second warning
        dep = [
            w for w in caught if issubclass(w.category, DeprecationWarning)
            and name in str(w.message)
        ]
        assert len(dep) == 1, f"{name}: {len(dep)} warnings"
        assert "deprecated" in str(dep[0].message)
    api._reset_deprecation_state()


# ---------------------------------------------------------------------------
# Policy unit tests
# ---------------------------------------------------------------------------


def drive(policy, events):
    """Replay (kind, tenant) events; return the decision/victim trace."""
    trace = []
    for kind, tenant in events:
        if kind == "touch":
            policy.touch(tenant)
        else:
            d = policy.admit(tenant)
            trace.append((tenant, d.action, d.slot, d.victim))
    return trace


def test_eviction_order_deterministic():
    rng = np.random.default_rng(7)
    events = []
    for _ in range(200):
        t = int(rng.integers(0, 12))
        events.append(("touch", t))
        events.append(("admit", t))
    for scorer in ("lru", "lfu", "cost"):
        a = SlotPolicy(3, scorer=scorer, cost_fn=lambda t: 1.0 + t % 3)
        b = SlotPolicy(3, scorer=scorer, cost_fn=lambda t: 1.0 + t % 3)
        assert drive(a, events) == drive(b, events)
        assert a.resident == b.resident


def test_victim_tie_break_is_recency_then_id():
    pol = SlotPolicy(3, scorer="lfu")
    for t in (0, 1, 2):
        pol.touch(t)
        pol.admit(t)
    # All scores tie at 1 touch; 0 was touched longest ago.
    assert pol.victim() == 0
    pol.touch(0)  # 0 now outranks on lfu score
    assert pol.victim() == 1


def test_admission_floor_rejects_cold_candidates():
    pol = SlotPolicy(2, scorer="lfu")
    for t in (0, 1):
        for _ in range(3):
            pol.touch(t)
        pol.admit(t)
    pol.touch(9)  # one-hit wonder: score 1 vs incumbents' 3
    d = pol.admit(9)
    assert d.action == "reject"
    assert pol.lookup(9) is None
    assert pol.rejects_since_resize == 1
    # force (operator readmit) bypasses the floor
    d = pol.admit(9, force=True)
    assert d.action == "evict" and d.victim == 0
    # LRU always admits: a fresh touch outranks any incumbent
    lru = SlotPolicy(1, scorer="lru")
    lru.touch(0)
    lru.admit(0)
    lru.touch(5)
    assert lru.admit(5).action == "evict"


def test_suggest_size_grow_and_shrink():
    pol = SlotPolicy(2, scorer="lfu", grow_rejects=2, min_slots=1)
    for t in (0, 1):
        for _ in range(3):
            pol.touch(t)
        pol.admit(t)
    assert pol.suggest_size() == 2
    for _ in range(2):
        pol.touch(7)
        pol.admit(7)
    assert pol.suggest_size() == 4
    pol.set_slots(4)
    assert pol.rejects_since_resize == 0
    pol.release(0)
    pol.release(1)
    pol.release(7)
    assert pol.suggest_size() == 2


def test_bank_resize_grow_preserves_rows_bitwise():
    xs, ys = lockstep_stream(bank=4, n=8)
    st, _ = api.run_stream("klms", RFF, xs, ys, mu=0.3)
    grown = resize_bank(st, 8)
    assert bank_size(grown) == 8
    for b in range(4):
        assert_trees_bitwise(tenant_row(st, b), tenant_row(grown, b))
    assert not np.asarray(tenant_row(grown, 6).theta).any()
    shrunk = resize_bank(grown, 2)
    for b in range(2):
        assert_trees_bitwise(tenant_row(st, b), tenant_row(shrunk, b))


def test_server_resize_compaction_preserves_resident_rows_bitwise():
    srv = api.make_server(
        "klms", feature_map=RFF, bank=4, chunk=4, mu=0.3,
        policy="lfu", log_capacity=16,
    )
    for t, x, y in ragged_traffic(tenants=4, n=40, seed=5):
        srv.submit(t, x, y)
    srv.drain()
    before = {
        t: tenant_row(srv.queue.state, s)
        for t, s in srv.policy.resident.items()
    }
    srv.resize(8)
    assert srv.slots == 8 and srv.queue.num_tenants == 8
    for t, s in srv.policy.resident.items():
        assert_trees_bitwise(before[t], tenant_row(srv.queue.state, s))
    # Shrink below occupancy: coldest evicted, survivors compacted bitwise
    srv.resize(2)
    assert srv.slots == 2 and srv.policy.occupancy <= 2
    for t, s in srv.policy.resident.items():
        assert s < 2
        assert_trees_bitwise(before[t], tenant_row(srv.queue.state, s))
    with pytest.raises(ValueError, match="power of two"):
        srv.resize(3)


# ---------------------------------------------------------------------------
# Policy-mode server integration
# ---------------------------------------------------------------------------


def test_policy_server_admits_evicts_and_rebuilds():
    srv = api.make_server(
        "klms", feature_map=RFF, bank=2, chunk=4, mu=0.3,
        policy="lru", log_capacity=32,
    )
    rng = np.random.default_rng(6)
    obs = {t: [] for t in range(3)}
    for _ in range(30):
        t = int(rng.integers(0, 3))
        x = rng.normal(size=D_IN).astype(np.float32)
        y = float(rng.normal())
        obs[t].append((x, y))
        srv.submit(t, x, y)
    srv.drain()
    m = srv.metrics
    assert m.count("evictions") > 0
    assert m.count("readmissions") > 0
    assert srv.policy.occupancy == 2
    # Every resident tenant's row must equal a from-scratch replay of its
    # full logged history (log_capacity was never exceeded). The live row
    # is mid-history rebuilds plus chunked online updates, so chunk
    # boundaries differ from the one-shot replay — tight f32 tolerance,
    # not bitwise (observed drift is ~1 ulp).
    for t, slot in srv.policy.resident.items():
        assert srv.log.complete(t)
        xs = jnp.asarray(np.stack([x for x, _ in obs[t]]))
        ys = jnp.asarray(np.asarray([y for _, y in obs[t]], np.float32))
        ref = srv._lrn.rebuild(xs, ys, mode="scan")
        assert_trees_close(ref, tenant_row(srv.queue.state, slot))


def test_policy_server_cold_read_returns_zeros_without_admitting():
    srv = api.make_server(
        "klms", feature_map=RFF, bank=2, chunk=4, mu=0.3, policy="lru",
    )
    q = np.ones(D_IN, np.float32)
    assert float(srv.predict(17, q)) == 0.0
    assert np.asarray(srv.predict(17, np.ones((5, D_IN), np.float32))).shape == (5,)
    assert srv.policy.lookup(17) is None
    assert srv.metrics.count("read.cold") == 2


def test_policy_server_rejection_logs_but_does_not_train():
    srv = api.make_server(
        "klms", feature_map=RFF, bank=1, chunk=4, mu=0.3,
        policy="lfu", log_capacity=8,
    )
    x = np.ones(D_IN, np.float32)
    for _ in range(3):
        srv.submit(0, x, 1.0)
    srv.drain()
    theta_before = np.asarray(srv.queue.state.theta).copy()
    srv.submit(42, x, 1.0)  # floor: 1 touch vs incumbent's 3 -> reject
    srv.drain()
    assert srv.metrics.count("admission.rejects") == 1
    assert srv.log.size(42) == 1
    np.testing.assert_array_equal(
        theta_before, np.asarray(srv.queue.state.theta)
    )
