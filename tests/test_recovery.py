"""Self-healing + durability tier units (serve/recovery.py + friends).

Covers the pieces the chaos matrix (tests/test_chaos.py) composes: the
JSONL write-ahead log's bitwise round-trip and torn-tail tolerance,
checkpoint save/restore (atomic generations, corrupt-newest fallback,
config validation, GC), the slot policy's durable state, the queue's
stale-arrival watchdog, the per-slot diagnostics, the expected-ticks
ledger, and the recovery ladder's escalation / backoff / give-up
mechanics driven directly (no fault injector).
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bank import krls_bank_init, resymmetrize_tenant
from repro.core.rff import sample_rff
from repro.obs.probes import ProbeMonitor, slot_stats
from repro.serve.api import make_server
from repro.serve.policy import SlotPolicy
from repro.serve.queue import MicroBatchQueue
from repro.serve.recovery import (
    DurableLog,
    RecoveryPolicy,
    restore_checkpoint,
    save_checkpoint,
)

_RFF = sample_rff(jax.random.PRNGKey(0), 3, 32, 1.0)


def _traffic(seed, n, tenants=3):
    rng = np.random.default_rng(seed)
    return [
        (
            int(rng.integers(0, tenants)),
            rng.standard_normal(3).astype(np.float32),
            float(rng.standard_normal()),
        )
        for _ in range(n)
    ]


def _leaves_equal(a, b):
    return all(
        np.array_equal(np.asarray(la), np.asarray(lb), equal_nan=True)
        for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


# -- DurableLog --------------------------------------------------------------


def test_wal_roundtrips_f32_bitwise_including_nan(tmp_path):
    path = tmp_path / "wal.jsonl"
    wal = DurableLog(path)
    rng = np.random.default_rng(0)
    xs = rng.standard_normal((8, 3)).astype(np.float32)
    xs[3, 1] = np.nan
    ys = rng.standard_normal(8).astype(np.float32)
    ys[5] = np.inf
    for i in range(8):
        assert wal.append(i % 3, xs[i], ys[i]) == i
    wal.close()
    back = DurableLog(path)
    entries = back.entries()
    assert [e["s"] for e in entries] == list(range(8))
    for i, e in enumerate(entries):
        assert np.array_equal(
            np.asarray(e["x"], np.float32), xs[i], equal_nan=True
        )
        assert np.array_equal(
            np.float32(e["y"]), ys[i], equal_nan=True
        )
    back.close()


def test_wal_tolerates_torn_tail_and_resumes_seq(tmp_path):
    path = tmp_path / "wal.jsonl"
    wal = DurableLog(path)
    for i in range(4):
        wal.append(0, np.zeros(3, np.float32), float(i))
    wal.close()
    # A crash mid-append leaves a torn final line.
    with open(path, "a", encoding="utf-8") as fh:
        fh.write('{"s": 4, "t": 0, "x": [0.0')
    resumed = DurableLog(path)
    assert resumed.seq == 3  # torn record ignored
    assert [e["s"] for e in resumed.entries()] == [0, 1, 2, 3]
    assert resumed.append(1, np.ones(3, np.float32), 9.0) == 4
    # The new record replaces the torn tail in the readable suffix.
    assert resumed.entries(after=3)[0]["t"] == 1
    resumed.close()


# -- checkpoint / restore ----------------------------------------------------


@pytest.mark.parametrize("learner", ["klms", "krls", "qklms"])
def test_checkpoint_restore_roundtrip_bitwise(tmp_path, learner):
    kw = {
        "klms": dict(mu=0.3),
        "krls": dict(lam=0.1, beta=0.99),
        "qklms": dict(sigma=1.0, mu=0.3, quant_eps=0.1, capacity=32),
    }[learner]
    args = dict(
        feature_map=_RFF, bank=4, chunk=4, policy="lru",
        log_capacity=64, **kw,
    )
    a = make_server(learner, **args)
    for t, x, y in _traffic(1, 30):
        a.submit(t, x, y)
    a.flush()  # leave a mid-stream backlog in the pending buffers
    path = a.checkpoint(tmp_path / "ckpt")
    assert os.path.basename(path) == "gen_00000000.ckpt"

    b = make_server(learner, **args)
    info = restore_checkpoint(b, tmp_path / "ckpt")
    assert info["generation"] == 0 and info["replayed"] == 0
    assert _leaves_equal(a.queue.state, b.queue.state)
    assert _leaves_equal(a.snapshot.state, b.snapshot.state)
    assert a.snapshot.version == b.snapshot.version
    assert a.queue.backlog() == b.queue.backlog()
    assert a.queue.ticks_served == b.queue.ticks_served
    assert a.queue.flushes == b.queue.flushes
    assert a.policy.state_dict() == b.policy.state_dict()
    assert a._expected == b._expected
    for t in a.log.tenants():
        assert a.log.size(t) == b.log.size(t)
        assert a.log.dropped(t) == b.log.dropped(t)
        ax, ay = a.log.arrays(t)
        bx, by = b.log.arrays(t)
        assert np.array_equal(ax, bx) and np.array_equal(ay, by)
    # Both servers continue identically from here.
    for t, x, y in _traffic(2, 20):
        a.submit(t, x, y)
        b.submit(t, x, y)
    a.drain()
    b.drain()
    assert _leaves_equal(a.queue.state, b.queue.state)


def test_checkpoint_preserves_ring_overflow_flag(tmp_path):
    args = dict(
        feature_map=_RFF, bank=2, chunk=4, policy="lru",
        log_capacity=4, mu=0.3,
    )
    a = make_server("klms", **args)
    for t, x, y in _traffic(3, 12, tenants=1):
        a.submit(0, x, y)
    a.drain()
    assert not a.log.complete(0)  # ring overflowed
    a.checkpoint(tmp_path / "ckpt")
    b = make_server("klms", **args)
    restore_checkpoint(b, tmp_path / "ckpt")
    assert not b.log.complete(0)
    assert b.log.dropped(0) == a.log.dropped(0)


def test_restore_skips_corrupt_newest_generation(tmp_path):
    args = dict(feature_map=_RFF, bank=2, chunk=4, mu=0.3,
                policy="lru", log_capacity=16)
    a = make_server("klms", **args)
    for t, x, y in _traffic(4, 10):
        a.submit(t % 2, x, y)
    a.drain()
    ckdir = tmp_path / "ckpt"
    a.checkpoint(ckdir)
    good_state = jax.tree.map(np.asarray, a.queue.state)
    for t, x, y in _traffic(5, 6):
        a.submit(t % 2, x, y)
    a.drain()
    newest = a.checkpoint(ckdir)
    with open(newest, "wb") as fh:
        fh.write(b"\x80garbage")  # torn write / disk corruption
    b = make_server("klms", **args)
    info = restore_checkpoint(b, ckdir)
    assert info["generation"] == 0  # fell back past the torn gen 1
    assert _leaves_equal(b.queue.state, good_state)


def test_restore_raises_on_config_mismatch(tmp_path):
    a = make_server("klms", feature_map=_RFF, bank=2, chunk=4, mu=0.3,
                    policy="lru")
    a.checkpoint(tmp_path / "ckpt")
    b = make_server("klms", feature_map=_RFF, bank=2, chunk=4, mu=0.7,
                    policy="lru")
    with pytest.raises(ValueError, match="mismatch"):
        restore_checkpoint(b, tmp_path / "ckpt")


def test_checkpoint_gc_keeps_newest_generations(tmp_path):
    a = make_server("klms", feature_map=_RFF, bank=2, chunk=4, mu=0.3,
                    policy="lru")
    ckdir = tmp_path / "ckpt"
    for i in range(5):
        save_checkpoint(a, ckdir, keep=2)
    names = sorted(n for n in os.listdir(ckdir) if n.endswith(".ckpt"))
    assert names == ["gen_00000003.ckpt", "gen_00000004.ckpt"]
    with open(ckdir / "LATEST") as fh:
        assert fh.read().strip() == "gen_00000004.ckpt"


def test_wal_replay_is_idempotent_across_restores(tmp_path):
    args = dict(feature_map=_RFF, bank=4, chunk=4, mu=0.3,
                policy="lru", log_capacity=64, size_watermark=4)
    wal_path = str(tmp_path / "wal.jsonl")
    a = make_server("klms", wal=wal_path, **args)
    traffic = _traffic(6, 40)
    for t, x, y in traffic[:25]:
        a.submit(t, x, y)
    a.checkpoint(tmp_path / "ckpt")
    for t, x, y in traffic[25:]:
        a.submit(t, x, y)
    a.drain()
    wal_size = os.path.getsize(wal_path)
    b = make_server("klms", wal=wal_path, **args)
    info = restore_checkpoint(b, tmp_path / "ckpt")
    assert info["replayed"] == 15
    # Replay suspended WAL appends: the file did not grow.
    assert os.path.getsize(wal_path) == wal_size
    b.drain()
    c = make_server("klms", wal=wal_path, **args)
    restore_checkpoint(c, tmp_path / "ckpt")
    c.drain()
    assert _leaves_equal(b.queue.state, c.queue.state)
    assert _leaves_equal(a.queue.state, b.queue.state)


# -- SlotPolicy durability ---------------------------------------------------


def test_policy_state_roundtrip_preserves_decisions():
    pol = SlotPolicy(2, scorer="lfu")
    for t in (7, 7, 8, 9, 9, 9):
        pol.touch(t)
        pol.admit(t)
    clone = SlotPolicy(2, scorer="lfu")
    clone.load_state(pol.state_dict())
    assert clone.resident == pol.resident
    assert clone.victim() == pol.victim()
    # Same future admission decision on both.
    pol.touch(11)
    clone.touch(11)
    assert pol.admit(11) == clone.admit(11)


def test_policy_load_state_rejects_scorer_mismatch():
    pol = SlotPolicy(2, scorer="lru")
    other = SlotPolicy(2, scorer="lfu")
    with pytest.raises(ValueError, match="scorer"):
        other.load_state(pol.state_dict())


# -- queue watchdog ----------------------------------------------------------


def test_queue_watchdog_force_flushes_stale_arrivals():
    fake = [0.0]
    queue = MicroBatchQueue(
        jax.jit(lambda s, xs, ys, m: (s, _fake_out(ys))),
        klms_init_state(),
        3,
        chunk=4,
        stale_after=5.0,
        clock=lambda: fake[0],
    )
    assert queue.maybe_flush() == {}
    queue.submit(1, np.zeros(3, np.float32), 1.0)
    fake[0] = 4.9
    assert not queue.has_stale()
    assert queue.maybe_flush() == {}
    fake[0] = 5.0
    assert queue.has_stale()
    res = queue.maybe_flush()
    assert 1 in res and queue.stale_flushes == 1
    assert not queue.has_stale()  # ledger cleared with the backlog


def test_queue_watchdog_keeps_stamp_across_partial_flush():
    fake = [0.0]
    queue = MicroBatchQueue(
        jax.jit(lambda s, xs, ys, m: (s, _fake_out(ys))),
        klms_init_state(),
        3,
        chunk=2,
        stale_after=10.0,
        clock=lambda: fake[0],
    )
    for i in range(5):  # deeper than one chunk
        queue.submit(0, np.zeros(3, np.float32), float(i))
    fake[0] = 10.0
    queue.maybe_flush()  # consumes 2, leaves 3 — still stale
    assert queue.backlog()[0] == 3
    assert queue.has_stale()
    queue.drop_pending(0)
    assert not queue.has_stale()


def _fake_out(ys):
    from repro.core.klms import StepOut

    return StepOut(prediction=jnp.zeros_like(ys), error=jnp.zeros_like(ys))


def klms_init_state():
    from repro.core.bank import klms_bank_init

    return klms_bank_init(_RFF, 3)


# -- per-slot diagnostics and the ledger -------------------------------------


def test_slot_stats_matches_numpy_oracle():
    state = krls_bank_init(_RFF, 3, 0.1)
    theta = np.asarray(state.theta).copy()
    theta[1] = 3.0
    pmat = np.asarray(state.pmat).copy()
    pmat[2, 0, 1] += 0.5
    state = state._replace(
        theta=jnp.asarray(theta), pmat=jnp.asarray(pmat)
    )
    stats = {k: np.asarray(v) for k, v in slot_stats(state).items()}
    np.testing.assert_allclose(
        stats["theta.norm"],
        np.linalg.norm(theta, axis=-1),
        rtol=1e-6,
    )
    asym = np.max(np.abs(pmat - np.swapaxes(pmat, -1, -2)), axis=(-2, -1))
    scale = np.max(np.abs(pmat), axis=(-2, -1))
    np.testing.assert_allclose(
        stats["pmat.asym_rel"], asym / (scale + 1e-30), rtol=1e-5
    )
    assert stats["finite"].tolist() == [1.0, 1.0, 1.0]
    bad = state._replace(theta=jnp.asarray(theta).at[0, 0].set(np.nan))
    assert slot_stats(bad)["finite"].tolist() == [0.0, 1.0, 1.0]


def test_resymmetrize_tenant_symmetrizes_one_slot_only():
    state = krls_bank_init(_RFF, 3, 0.1)
    pmat = np.asarray(state.pmat).copy()
    pmat[1, 0, 1] += 0.5
    pmat[2, 0, 1] += 0.5
    state = state._replace(pmat=jnp.asarray(pmat))
    fixed = resymmetrize_tenant(state, 1)
    p1 = np.asarray(fixed.pmat[1])
    assert np.allclose(p1, p1.T)
    # Slot 2 untouched (still asymmetric), theta untouched.
    assert not np.allclose(
        np.asarray(fixed.pmat[2]), np.asarray(fixed.pmat[2]).T
    )
    assert np.array_equal(np.asarray(fixed.theta), np.asarray(state.theta))


def test_ticks_lag_ledger_tracks_lost_arrivals():
    srv = make_server("klms", feature_map=_RFF, bank=3, chunk=4, mu=0.3,
                      probe=True)
    for t, x, y in _traffic(7, 20):
        srv.submit(t, x, y)
    srv.drain()
    assert srv._slot_lags() == [0, 0, 0]
    # Silently lose a backlog (bypassing the facade's accounting).
    srv.submit(1, np.zeros(3, np.float32), 1.0)
    srv.queue._pending[1].clear()
    srv.submit(0, np.zeros(3, np.float32), 0.0)  # drive a real flush
    srv.flush()
    assert srv._slot_lags()[1] == 1
    assert srv.probe.total_events >= 1
    assert any(
        ev.probe == "ticks_lag" for ev in srv.probe.events
    )


def test_probe_monitor_subscribers_receive_every_event():
    mon = ProbeMonitor()
    seen = []
    mon.subscribe(seen.append)
    mon.update({"finite": 0.0})
    mon.update({"finite": 1.0})
    mon.update({"finite": 0.0, "theta.norm_max": 1e9})
    assert [(ev.probe) for ev in seen] == [
        "finite", "finite", "theta.norm_max",
    ]


# -- the recovery ladder, driven directly ------------------------------------


def _degraded_server(**kw):
    """A policy-mode server with tenant 1 trained then NaN-poisoned."""
    srv = make_server(
        "klms", feature_map=_RFF, bank=4, chunk=4, mu=0.3,
        policy="lru", log_capacity=kw.pop("log_capacity", 64),
        recovery=kw.pop("recovery", True), **kw,
    )
    for t, x, y in _traffic(8, 30):
        srv.submit(t, x, y)
    srv.drain()
    slot = srv.resident[1]
    srv.queue.state = srv.queue.state._replace(
        theta=srv.queue.state.theta.at[slot].set(jnp.nan)
    )
    return srv, slot


def test_nan_poison_quarantines_then_rebuilds():
    srv, slot = _degraded_server()
    srv.submit(0, np.zeros(3, np.float32), 0.0)
    srv.drain()  # fold fires finite, recovery rebuilds in the same call
    rec = srv.recovery
    assert rec.history == [
        {"tenant": 1, "action": "rebuild", "verified": True}
    ]
    assert rec.quarantined == frozenset()
    counters = srv.metrics.snapshot()["counters"]
    assert counters["recovery.quarantines"] == 1
    assert counters["recovery.repairs{action=rebuild}"] == 1
    assert counters["recovery.releases"] == 1
    assert np.isfinite(np.asarray(srv.queue.state.theta)).all()


def test_overflowed_log_fails_complete_and_falls_through_to_reset():
    # The satellite: rebuild from a windowed ring must NOT install partial
    # state as full history — complete()==False surfaces through the
    # RecoveryPolicy pre-check and the ladder falls through to reset.
    srv, slot = _degraded_server(log_capacity=4)
    assert not srv.log.complete(1)
    srv.submit(0, np.zeros(3, np.float32), 0.0)
    srv.drain()
    rec = srv.recovery
    assert rec.history[0] == {
        "tenant": 1, "action": "rebuild",
        "outcome": "fallthrough", "reason": "incomplete_log",
    }
    assert rec.history[1] == {
        "tenant": 1, "action": "reset", "verified": True,
    }
    assert rec.quarantined == frozenset()
    # Reset forgot the (windowed) history along with the state.
    assert srv.log.size(1) == 0
    assert np.isfinite(np.asarray(srv.queue.state.theta)).all()
    row = np.asarray(srv.queue.state.theta[slot])
    assert np.array_equal(row, np.zeros_like(row))


def test_repeated_failures_escalate_backoff_then_give_up(monkeypatch):
    fake = [0.0]
    srv, slot = _degraded_server(
        recovery={"max_retries": 2, "backoff_base": 10.0,
                  "clock": lambda: fake[0]},
    )
    rec = srv.recovery
    monkeypatch.setattr(rec, "_verify", lambda ep: False)
    srv.submit(0, np.zeros(3, np.float32), 0.0)
    srv.drain()
    ep = rec._episodes[1]
    assert ep.attempts == 1 and ep.backoff_until == 10.0 * 2.0
    n_attempts = len(rec.history)
    srv.submit(0, np.zeros(3, np.float32), 0.0)
    srv.drain()  # still inside backoff: no new attempt
    assert len(rec.history) == n_attempts
    fake[0] = 100.0
    rec.process()  # attempt 2 (reset rung), fails, exceeds max_retries...
    fake[0] = 1000.0
    rec.process()
    assert ep.gave_up
    assert 1 in rec.quarantined  # kept for the operator
    counters = srv.metrics.snapshot()["counters"]
    assert counters["recovery.gave_up"] == 1
    assert "recovery.releases" not in counters
    # The parked slot is healthy, so bank-global probes stay quiet.
    assert np.isfinite(np.asarray(srv.queue.state.theta)).all()
    before = srv.probe.total_events
    srv.submit(0, np.zeros(3, np.float32), 0.0)
    srv.drain()
    assert srv.probe.total_events == before


def test_quarantined_tenant_reads_healthy_writes_deferred(monkeypatch):
    srv, slot = _degraded_server()
    rec = srv.recovery
    healthy_theta = np.asarray(rec._last_healthy[0].theta[slot]).copy()
    # Freeze the episode open so the quarantine behavior is observable.
    monkeypatch.setattr(rec, "_repair_due", lambda: None)
    srv.submit(0, np.zeros(3, np.float32), 0.0)
    srv.drain()
    assert 1 in rec.quarantined
    xq = np.ones(3, np.float32)
    pred = float(srv.predict(1, xq))
    from repro.serve.snapshot import predict_row

    expect = float(predict_row(healthy_theta, xq[None], _RFF)[0])
    assert pred == expect  # served from the captured healthy row
    assert np.isfinite(pred)
    n_before = srv.log.size(1)
    lag_before = srv._slot_lags()[slot]
    srv.submit(1, xq, 1.0)  # deferred: logged, not queued
    assert srv.log.size(1) == n_before + 1
    assert srv.queue.backlog()[slot] == 0
    assert srv._slot_lags()[slot] == lag_before
    counters = srv.metrics.snapshot()["counters"]
    assert counters["recovery.deferred"] == 1
    assert counters["read.quarantined"] == 1


def test_recovery_requires_probe_and_single_bind():
    with pytest.raises(ValueError, match="probe"):
        RecoveryPolicy().bind(
            make_server("klms", feature_map=_RFF, bank=2, chunk=4, mu=0.3)
        )
    srv = make_server("klms", feature_map=_RFF, bank=2, chunk=4, mu=0.3,
                      recovery=True)
    assert srv.probe is not None  # recovery implies probe
    with pytest.raises(RuntimeError, match="bound"):
        srv.recovery.bind(srv)


def test_process_drains_events_across_repeated_folds():
    # Regression: the monitor's subscriber holds a reference to the
    # pending-events list; process() must drain it in place, or every
    # event after the first fold is appended to an orphaned list.
    srv, slot = _degraded_server()
    rec = srv.recovery
    srv.submit(0, np.zeros(3, np.float32), 0.0)
    srv.drain()
    assert rec.history  # first fold acted
    # Poison again: the second episode must be seen too.
    slot2 = srv.resident[2]
    srv.queue.state = srv.queue.state._replace(
        theta=srv.queue.state.theta.at[slot2].set(jnp.nan)
    )
    srv.submit(0, np.zeros(3, np.float32), 0.0)
    srv.drain()
    assert len(rec.history) >= 2
    assert rec.quarantined == frozenset()
    assert np.isfinite(np.asarray(srv.queue.state.theta)).all()
