"""Feature-map subsystem: every family behind one contract.

Contracts pinned here:
* Each trig family's ``featurize`` IS its canonical ``(W, b, scale)`` form,
  and canonicalizing the legacy ``RFF`` struct changes nothing (bitwise).
* Deterministic families (qmc/gq/taylor) ignore PRNG keys entirely —
  bitwise identical across constructions — and reach the Monte-Carlo error
  floor at equal or smaller D.
* The fused + chunked Pallas kernels (interpret mode) match the reference
  oracle for every trig family — one kernel serves all of them.
* Learner adapters and bank tiers accept any family, including the
  non-trig Taylor map (generic fallback).
* The mixed-family bank matches sequential single-tenant runs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import features as F
from repro.core.bank import (
    bank_hparams,
    bank_init,
    bank_run,
    klms_bank_run,
    krls_bank_run,
    mixed_klms_bank_run,
    mixed_krls_bank_run,
    stack_feature_maps,
)
from repro.core.klms import rff_klms_run
from repro.core.krls import rff_krls_run
from repro.core.learner import klms_learner, krls_learner
from repro.core.rff import gaussian_kernel, rff_features, sample_rff
from repro.data.synthetic import gen_chaotic1
from repro.kernels import ops

TRIG_FAMILIES = ("rff", "orf", "qmc", "gq")
DET_FAMILIES = ("qmc", "gq", "taylor")


def _make(family, d=3, D=128, sigma=1.5, key=None):
    if key is None:
        key = jax.random.PRNGKey(0)
    return F.make_feature_map(family, d, D, sigma, key=key)


# ---------------------------------------------------------------------------
# Contract and canonical form
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", F.FAMILIES)
def test_contract_metadata(family):
    fm = _make(family)
    assert fm.family == family
    assert fm.input_dim == 3
    assert fm.num_features >= 1
    x = jax.random.normal(jax.random.PRNGKey(1), (7, 3))
    z = F.featurize(fm, x)
    assert z.shape == (7, fm.num_features)
    w = fm.weights
    assert w.shape == (fm.num_features,)
    assert bool(jnp.all(w >= 0))
    assert fm.deterministic == (family in DET_FAMILIES)


@pytest.mark.parametrize("family", TRIG_FAMILIES)
def test_trig_families_featurize_via_canonical_form(family):
    """featurize == scale * cos(x @ W + b) for every trig family, bitwise."""
    fm = _make(family)
    tf = F.as_trig(fm)
    x = jax.random.normal(jax.random.PRNGKey(2), (5, 3))
    np.testing.assert_array_equal(
        np.asarray(F.featurize(fm, x)), np.asarray(F.trig_features(tf, x))
    )


def test_rff_canonicalization_is_bitwise_legacy():
    """trig_from_rff(RFF) featurizes bitwise like core.rff.rff_features."""
    rff = sample_rff(jax.random.PRNGKey(0), 4, 300, 2.0)
    tf = F.trig_from_rff(rff)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 4))
    np.testing.assert_array_equal(
        np.asarray(rff_features(rff, x)), np.asarray(F.trig_features(tf, x))
    )


def test_taylor_has_no_trig_form():
    fm = _make("taylor")
    assert F.as_trig_or_none(fm) is None
    with pytest.raises(TypeError, match="taylor"):
        F.as_trig(fm)


@pytest.mark.parametrize("family", F.FAMILIES)
def test_feature_map_is_pytree(family):
    """FeatureMap flows through tree_flatten/jit/vmap like any param struct."""
    fm = _make(family)
    leaves, treedef = jax.tree_util.tree_flatten(fm)
    fm2 = jax.tree_util.tree_unflatten(treedef, leaves)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 3))
    jitted = jax.jit(lambda m, a: F.featurize(m, a))
    np.testing.assert_array_equal(
        np.asarray(jitted(fm2, x)), np.asarray(jitted(fm, x))
    )


# ---------------------------------------------------------------------------
# Determinism and accuracy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", DET_FAMILIES)
def test_deterministic_families_ignore_keys(family):
    """Two constructions under different keys are bitwise identical."""
    a = _make(family, key=jax.random.PRNGKey(0))
    b = _make(family, key=jax.random.PRNGKey(12345))
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


@pytest.mark.parametrize("family", F.FAMILIES)
def test_kernel_estimate_accuracy(family):
    """z(x).z(y) approximates the Gaussian kernel; deterministic families
    reach the D=256 Monte-Carlo floor already (qmc/gq/taylor <= rff)."""
    d, sigma, D = 3, 1.5, 256
    fm = _make(family, d=d, D=D, sigma=sigma)
    x = jax.random.normal(jax.random.PRNGKey(1), (128, d))
    y = jax.random.normal(jax.random.PRNGKey(2), (128, d))
    exact = gaussian_kernel(x, y, sigma)
    zx, zy = F.featurize(fm, x), F.featurize(fm, y)
    rmse = float(jnp.sqrt(jnp.mean((jnp.sum(zx * zy, -1) - exact) ** 2)))
    assert rmse < 0.08, f"{family}: rmse {rmse}"
    if family in DET_FAMILIES:
        rff_fm = _make("rff", d=d, D=D, sigma=sigma)
        zx_r = F.featurize(rff_fm, x)
        zy_r = F.featurize(rff_fm, y)
        rmse_rff = float(
            jnp.sqrt(jnp.mean((jnp.sum(zx_r * zy_r, -1) - exact) ** 2))
        )
        assert rmse <= rmse_rff, f"{family} {rmse} vs rff {rmse_rff}"


def test_gq_weights_sum_to_one():
    """Retained node weights renormalize so kappa(0) == 1 exactly: each
    node's weight appears in its cos AND sin feature (sum(scale^2) == 2)
    and cos^2 + sin^2 collapses the pair to one a_j."""
    fm = _make("gq", d=2, D=64)
    assert abs(float(jnp.sum(fm.weights)) - 2.0) < 1e-6
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 2))
    z = F.featurize(fm, x)
    # cos^2 + sin^2 = 1 per node: ||z(x)||^2 == sum a_j == 1 up to rounding
    np.testing.assert_allclose(
        np.asarray(jnp.sum(z * z, -1)), np.ones(8), atol=1e-5
    )


def test_qmc_even_d_required():
    with pytest.raises(ValueError, match="even"):
        F.qmc_map(3, 65, 1.0)
    with pytest.raises(ValueError, match="even"):
        F.gq_map(3, 65, 1.0)


def test_taylor_num_features_formula():
    fm = F.taylor_map(3, 4, 1.0)
    assert fm.num_features == F.taylor_num_features(3, 4)
    # degree auto-pick: largest degree fitting the budget
    fm2 = F.make_feature_map("taylor", 3, 128, 1.0)
    assert fm2.num_features <= 128


# ---------------------------------------------------------------------------
# One kernel serves every trig family (fused + chunked, interpret mode)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", TRIG_FAMILIES)
def test_features_kernel_all_families(family):
    """The Pallas feature kernel (interpret) == oracle for every family."""
    fm = _make(family, d=5, D=192, sigma=2.0)
    tf = F.as_trig(fm)
    x = jax.random.normal(jax.random.PRNGKey(4), (33, 5))
    got = ops.rff_features(x, tf.omega, tf.bias, tf.scale, mode="interpret")
    want = F.trig_features(tf, x)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=1e-5, rtol=1e-5
    )


@pytest.mark.parametrize("family", TRIG_FAMILIES)
def test_fused_klms_step_and_chunk_all_families(family):
    """Fused + chunked KLMS Pallas paths (interpret) == oracle, any family."""
    fm = _make(family, d=4, D=96, sigma=1.5)
    tf = F.as_trig(fm)
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    bank, tlen = 6, 5
    theta = jax.random.normal(ks[0], (bank, 96))
    xs = jax.random.normal(ks[1], (bank, tlen, 4))
    ys = jax.random.normal(ks[2], (bank, tlen))
    mu = jax.random.uniform(ks[3], (bank,), minval=0.1, maxval=1.0)

    got = ops.rff_klms_bank_step(
        theta, xs[:, 0], ys[:, 0], tf.omega, tf.bias, mu, tf.scale,
        mode="interpret",
    )
    want = ops.rff_klms_bank_step(
        theta, xs[:, 0], ys[:, 0], tf.omega, tf.bias, mu, tf.scale,
        mode="xla",
    )
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-5)

    got = ops.rff_klms_bank_chunk(
        theta, xs, ys, tf.omega, tf.bias, mu, None, tf.scale,
        mode="interpret",
    )
    want = ops.rff_klms_bank_chunk(
        theta, xs, ys, tf.omega, tf.bias, mu, None, tf.scale, mode="xla"
    )
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-5)


@pytest.mark.parametrize("family", TRIG_FAMILIES)
def test_fused_krls_step_and_chunk_all_families(family):
    """Fused + chunked EW-RLS Pallas paths (interpret) == oracle, any
    family — the per-feature quadrature weights ride through the full RLS
    downdate."""
    fm = _make(family, d=3, D=64, sigma=1.5)
    tf = F.as_trig(fm)
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    bank, tlen = 3, 4
    theta = 0.1 * jax.random.normal(ks[0], (bank, 64))
    pmat = jnp.broadcast_to(jnp.eye(64) * 10.0, (bank, 64, 64))
    xs = jax.random.normal(ks[1], (bank, tlen, 3))
    ys = jax.random.normal(ks[2], (bank, tlen))
    beta = jnp.asarray(0.999)

    got = ops.rff_krls_bank_step(
        theta, pmat, xs[:, 0], ys[:, 0], tf.omega, tf.bias, beta, tf.scale,
        mode="interpret",
    )
    want = ops.rff_krls_bank_step(
        theta, pmat, xs[:, 0], ys[:, 0], tf.omega, tf.bias, beta, tf.scale,
        mode="xla",
    )
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-4)

    got = ops.rff_krls_bank_chunk(
        theta, pmat, xs, ys, tf.omega, tf.bias, beta, None, tf.scale,
        mode="interpret",
    )
    want = ops.rff_krls_bank_chunk(
        theta, pmat, xs, ys, tf.omega, tf.bias, beta, None, tf.scale,
        mode="xla",
    )
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-4)


# ---------------------------------------------------------------------------
# Learners and banks accept every family
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", F.FAMILIES)
def test_learner_adapters_any_family(family):
    """klms/krls adapters learn the chaotic-series task with any family."""
    xs, ys = gen_chaotic1(jax.random.PRNGKey(7), num_samples=400)
    fm = _make(family, d=2, D=64, sigma=0.5)
    for make in (lambda: klms_learner(fm, 0.5), lambda: krls_learner(fm)):
        learner = make()
        state, out = learner.run(None, xs, ys)
        head = float(jnp.mean(out.error[:50] ** 2))
        tail = float(jnp.mean(out.error[-100:] ** 2))
        assert np.isfinite(tail) and tail < head, f"{family}: {head}->{tail}"
        pred = learner.predict(state, xs[-1])
        assert np.isfinite(float(pred))


@pytest.mark.parametrize("family", ("gq", "taylor"))
def test_deterministic_learners_bitwise_across_seeds(family):
    """GQ/Taylor learner trajectories are bitwise seed-independent."""
    xs, ys = gen_chaotic1(jax.random.PRNGKey(8), num_samples=200)
    runs = []
    for seed in (0, 99):
        fm = _make(family, d=2, D=64, sigma=0.5, key=jax.random.PRNGKey(seed))
        _, out = klms_learner(fm, 0.5).run(None, xs, ys)
        runs.append(np.asarray(out.error))
    np.testing.assert_array_equal(runs[0], runs[1])


def test_taylor_through_fused_bank_tiers():
    """Non-trig Taylor runs through klms/krls bank tiers (generic fallback)
    and matches the vmapped OnlineLearner bank (same update math)."""
    fm = _make("taylor", d=2, D=64, sigma=1.0)
    xs, ys = gen_chaotic1(jax.random.PRNGKey(9), num_samples=120)
    bank, n = 3, 40
    xb = xs[: bank * n].reshape(bank, n, -1)
    yb = ys[: bank * n].reshape(bank, n)

    _, out_fused = klms_bank_run(fm, xb, yb, 0.5)
    learner = klms_learner(fm, 0.5)
    _, out_generic = bank_run(learner, bank_init(learner, bank), xb, yb)
    np.testing.assert_allclose(
        np.asarray(out_fused.error), np.asarray(out_generic.error), atol=1e-6
    )
    # chunked path agrees as well (scan reschedule only)
    _, out_chunk = klms_bank_run(fm, xb, yb, 0.5, chunk=16)
    np.testing.assert_allclose(
        np.asarray(out_fused.error), np.asarray(out_chunk.error), atol=1e-6
    )

    _, out_krls = krls_bank_run(fm, xb, yb, lam=1e-2)
    klearner = krls_learner(fm, lam=1e-2)
    _, out_krls_gen = bank_run(klearner, bank_init(klearner, bank), xb, yb)
    np.testing.assert_allclose(
        np.asarray(out_krls.error), np.asarray(out_krls_gen.error), atol=1e-4
    )


# ---------------------------------------------------------------------------
# Mixed-family bank: per-tenant feature maps + per-tenant hyperparams
# ---------------------------------------------------------------------------


def test_mixed_bank_heterogeneous_families_klms():
    """One bank mixing rff/gq/qmc/orf tenants (per-tenant BankHParams)
    matches each tenant's sequential single-tenant run."""
    d, D, n = 2, 64, 120
    fms = [
        _make("rff", d=d, D=D, sigma=0.5, key=jax.random.PRNGKey(1)),
        _make("gq", d=d, D=D, sigma=0.5),
        _make("qmc", d=d, D=D, sigma=0.5),
        _make("orf", d=d, D=D, sigma=0.5, key=jax.random.PRNGKey(2)),
    ]
    tfs = stack_feature_maps(fms)
    xs, ys = gen_chaotic1(jax.random.PRNGKey(10), num_samples=4 * n)
    xb = xs[: 4 * n].reshape(4, n, -1)
    yb = ys[: 4 * n].reshape(4, n)
    hp = bank_hparams(4, mu=jnp.asarray([0.5, 0.3, 0.7, 0.4]))

    state, out = mixed_klms_bank_run(tfs, xb, yb, hparams=hp)
    for i, fm in enumerate(fms):
        _, want = rff_klms_run(fm, xb[i], yb[i], float(hp.mu[i]))
        np.testing.assert_allclose(
            np.asarray(out.error[i]), np.asarray(want.error), atol=1e-5
        )


def test_mixed_bank_heterogeneous_families_krls():
    """Mixed rff/gq KRLS tenants with per-tenant (beta, lam) match their
    sequential runs to the bank tier's f32 drift bound."""
    d, D, n = 2, 48, 80
    fms = [
        _make("rff", d=d, D=D, sigma=0.5, key=jax.random.PRNGKey(3)),
        _make("gq", d=d, D=D, sigma=0.5),
    ]
    tfs = stack_feature_maps(fms)
    xs, ys = gen_chaotic1(jax.random.PRNGKey(11), num_samples=2 * n)
    xb = xs[: 2 * n].reshape(2, n, -1)
    yb = ys[: 2 * n].reshape(2, n)
    hp = bank_hparams(
        2, beta=jnp.asarray([0.999, 0.9995]), lam=jnp.asarray([1e-2, 1e-3])
    )

    state, out = mixed_krls_bank_run(tfs, xb, yb, hparams=hp)
    for i, fm in enumerate(fms):
        _, want = rff_krls_run(
            fm, xb[i], yb[i], float(hp.lam[i]), float(hp.beta[i])
        )
        np.testing.assert_allclose(
            np.asarray(out.error[i]), np.asarray(want.error), atol=1e-3
        )


def test_stack_feature_maps_shape_mismatch():
    a = _make("gq", d=2, D=64)
    b = _make("gq", d=2, D=32)
    with pytest.raises(ValueError, match="share"):
        stack_feature_maps([a, b])


def test_kernel_estimate_same_object_fast_path():
    """kernel_estimate(rff, x, x) == kappa(0) path computes features once
    and agrees with the two-argument route."""
    from repro.core.rff import kernel_estimate

    rff = sample_rff(jax.random.PRNGKey(0), 3, 128, 1.0)
    x = jax.random.normal(jax.random.PRNGKey(1), (9, 3))
    same = kernel_estimate(rff, x, x)
    copy = kernel_estimate(rff, x, jnp.array(x))
    np.testing.assert_allclose(np.asarray(same), np.asarray(copy), atol=1e-6)
