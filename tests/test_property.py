"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dep: property tests")
from hypothesis import given, settings, strategies as st

from repro.core.rff import kernel_estimate, rff_features, sample_rff
from repro.core.klms import lms_step
from repro.core.distributed import dequantize_int8, quantize_int8
from repro.kernels import ref

_settings = dict(max_examples=20, deadline=None)


@given(
    seed=st.integers(0, 2**16),
    d=st.integers(1, 6),
    sigma=st.floats(0.5, 8.0),
)
@settings(**_settings)
def test_kernel_estimate_bounded_and_symmetric(seed, d, sigma):
    """z(x).z(y) is symmetric and bounded by ~2 (|cos|<=1 pairs, D avg)."""
    key = jax.random.PRNGKey(seed)
    rff = sample_rff(key, d, 256, sigma)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (4, d))
    y = jax.random.normal(jax.random.PRNGKey(seed + 2), (4, d))
    kxy = kernel_estimate(rff, x, y)
    kyx = kernel_estimate(rff, y, x)
    np.testing.assert_allclose(np.asarray(kxy), np.asarray(kyx), atol=1e-5)
    assert float(jnp.max(jnp.abs(kxy))) <= 2.0 + 1e-5


@given(seed=st.integers(0, 2**16), n=st.integers(4, 32))
@settings(**_settings)
def test_rff_gram_matrix_psd(seed, n):
    """Gram matrix of explicit features is PSD by construction — the
    reason RFF needs no dictionary pruning to stay well-posed."""
    key = jax.random.PRNGKey(seed)
    rff = sample_rff(key, 3, 64, 2.0)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, 3))
    z = rff_features(rff, x)
    gram = z @ z.T
    eig = jnp.linalg.eigvalsh(gram)
    assert float(eig[0]) > -1e-5


@given(
    seed=st.integers(0, 2**16),
    mu=st.floats(0.05, 0.9),
)
@settings(**_settings)
def test_lms_step_reduces_instantaneous_error(seed, mu):
    """After one LMS update, the error on the SAME sample shrinks by exactly
    (1 - mu ||z||^2) — the contraction that drives convergence."""
    key = jax.random.PRNGKey(seed)
    z = jax.random.normal(key, (16,))
    z = z / jnp.linalg.norm(z)  # ||z|| = 1 -> contraction factor (1 - mu)
    theta = jax.random.normal(jax.random.PRNGKey(seed + 1), (16,))
    y = jnp.asarray(0.7)
    theta2, out = lms_step(theta, z, y, mu)
    err_after = float(y - theta2 @ z)
    assert abs(err_after - (1 - mu) * float(out.error)) < 1e-5


@given(seed=st.integers(0, 2**16))
@settings(**_settings)
def test_int8_quantization_roundtrip_bound(seed):
    v = 3.0 * jax.random.normal(jax.random.PRNGKey(seed), (256,))
    q, s = quantize_int8(v)
    err = jnp.abs(dequantize_int8(q, s) - v)
    assert float(jnp.max(err)) <= float(s) * 0.5 + 1e-6


@given(
    seed=st.integers(0, 2**16),
    s=st.sampled_from([16, 48]),
    dv=st.sampled_from([4, 8]),
)
@settings(**_settings)
def test_linear_attention_causality(seed, s, dv):
    """Output at position t never depends on inputs after t."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.nn.relu(jax.random.normal(ks[0], (1, s, 8))) + 0.05
    k = jax.nn.relu(jax.random.normal(ks[1], (1, s, 8))) + 0.05
    v = jax.random.normal(ks[2], (1, s, dv))
    out1 = ref.rff_attention_ref(q, k, v)
    # perturb the future of the last-but-one position
    k2 = k.at[:, -1].set(k[:, -1] + 10.0)
    v2 = v.at[:, -1].set(-v[:, -1])
    out2 = ref.rff_attention_ref(q, k2, v2)
    np.testing.assert_allclose(
        np.asarray(out1[:, :-1]), np.asarray(out2[:, :-1]), atol=1e-5
    )


@given(seed=st.integers(0, 2**16), steps=st.integers(1, 30))
@settings(**_settings)
def test_data_pipeline_seekable(seed, steps):
    """batch_at_step is a pure function: seeking == streaming."""
    from repro.data.lm_data import batch_at_step

    a = batch_at_step(seed, steps, global_batch=2, seq_len=8, vocab=97)
    b = batch_at_step(seed, steps, global_batch=2, seq_len=8, vocab=97)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(a.max()) < 97 and int(a.min()) >= 0


# ---------------------------------------------------------------------------
# Feature-map subsystem invariants (repro.features)
# ---------------------------------------------------------------------------


@given(
    seed_a=st.integers(0, 2**16),
    seed_b=st.integers(0, 2**16),
    family=st.sampled_from(["gq", "taylor"]),
    sigma=st.floats(0.8, 4.0),
)
@settings(**_settings)
def test_deterministic_features_key_insensitive(seed_a, seed_b, family, sigma):
    """GQ/Taylor kernel estimates are a pure function of (d, D, sigma):
    construction keys change NOTHING (bitwise) — the zero-seed-variance
    property that lets serving replicas skip seed coordination."""
    from repro.features import featurize, make_feature_map

    fa = make_feature_map(family, 2, 32, sigma, key=jax.random.PRNGKey(seed_a))
    fb = make_feature_map(family, 2, 32, sigma, key=jax.random.PRNGKey(seed_b))
    x = jax.random.normal(jax.random.PRNGKey(seed_a + 1), (4, 2))
    y = jax.random.normal(jax.random.PRNGKey(seed_b + 2), (4, 2))
    ka = jnp.sum(featurize(fa, x) * featurize(fa, y), axis=-1)
    kb = jnp.sum(featurize(fb, x) * featurize(fb, y), axis=-1)
    np.testing.assert_array_equal(np.asarray(ka), np.asarray(kb))


@given(seed=st.integers(0, 2**16), d=st.integers(2, 6))
@settings(**_settings)
def test_orf_blocks_exactly_orthogonal(seed, d):
    """ORF omega columns within each QR block are exactly orthogonal (up to
    f32 QR rounding) — the structural property that cuts MC variance."""
    from repro.features import as_trig, orf_map

    D = 2 * d  # two full blocks
    fm = orf_map(jax.random.PRNGKey(seed), d, D, 1.5)
    omega = np.asarray(as_trig(fm).omega)  # (d, D)
    for blk in range(2):
        cols = omega[:, blk * d : (blk + 1) * d]
        gram = cols.T @ cols
        off = gram - np.diag(np.diag(gram))
        scale = np.abs(gram).max()
        assert np.abs(off).max() <= 1e-5 * max(scale, 1.0)


@given(
    family=st.sampled_from(["gq", "taylor", "qmc"]),
    sigma=st.floats(1.0, 3.0),
    seed=st.integers(0, 2**16),
)
@settings(**_settings)
def test_deterministic_estimates_converge_to_gaussian_kernel(
    family, sigma, seed
):
    """GQ/Taylor/QMC estimates approach the exact Gaussian kernel as the
    feature budget grows (truncation error is monotone in D here)."""
    from repro.core.rff import gaussian_kernel
    from repro.features import featurize, make_feature_map

    x = 0.8 * jax.random.normal(jax.random.PRNGKey(seed), (16, 2))
    y = 0.8 * jax.random.normal(jax.random.PRNGKey(seed + 1), (16, 2))
    exact = gaussian_kernel(x, y, sigma)
    errs = []
    for D in (16, 256):
        fm = make_feature_map(family, 2, D, sigma)
        est = jnp.sum(featurize(fm, x) * featurize(fm, y), axis=-1)
        errs.append(float(jnp.max(jnp.abs(est - exact))))
    assert errs[1] <= errs[0] + 1e-6
    assert errs[1] < 0.05
