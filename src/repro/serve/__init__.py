from repro.serve.serve_loop import generate, prefill_tokens
from repro.serve.api import (
    LEARNER_FAMILIES,
    Server,
    make_chunk_step,
    make_queue,
    make_server,
    make_tick,
    reset_slots,
    run_stream,
)
from repro.serve.metrics import Counter, Histogram, MetricsRegistry
from repro.serve.policy import SCORERS, AdmitDecision, SlotPolicy
from repro.serve.queue import MicroBatchQueue
from repro.serve.recovery import (
    DurableLog,
    RecoveryPolicy,
    restore_checkpoint,
    save_checkpoint,
)
from repro.serve.snapshot import ReplayLog, SnapshotServer, StateSnapshot

# Deprecated pre-facade entry points (DeprecationWarning shims; see
# repro/serve/api.py and the README migration table).
from repro.serve.bank_loop import (
    make_bank_server,
    make_krls_bank_server,
    reset_krls_tenants,
    reset_tenants,
    serve_bank_stream,
    serve_krls_bank_stream,
)
from repro.serve.queue import (
    klms_micro_batch_queue,
    krls_micro_batch_queue,
    make_chunked_bank_server,
    make_chunked_krls_bank_server,
)
from repro.serve.snapshot import klms_snapshot_server, krls_snapshot_server

__all__ = [
    "generate",
    "prefill_tokens",
    # the facade
    "LEARNER_FAMILIES",
    "Server",
    "make_server",
    "make_tick",
    "make_chunk_step",
    "make_queue",
    "run_stream",
    "reset_slots",
    # policy + metrics tiers
    "SlotPolicy",
    "AdmitDecision",
    "SCORERS",
    "MetricsRegistry",
    "Counter",
    "Histogram",
    # serving building blocks
    "MicroBatchQueue",
    "SnapshotServer",
    "StateSnapshot",
    "ReplayLog",
    # self-healing + durability tier
    "RecoveryPolicy",
    "DurableLog",
    "save_checkpoint",
    "restore_checkpoint",
    # deprecated shims
    "make_bank_server",
    "serve_bank_stream",
    "reset_tenants",
    "make_krls_bank_server",
    "serve_krls_bank_stream",
    "reset_krls_tenants",
    "make_chunked_bank_server",
    "make_chunked_krls_bank_server",
    "klms_micro_batch_queue",
    "krls_micro_batch_queue",
    "klms_snapshot_server",
    "krls_snapshot_server",
]
