from repro.serve.serve_loop import generate, prefill_tokens

__all__ = ["generate", "prefill_tokens"]
