from repro.serve.serve_loop import generate, prefill_tokens
from repro.serve.bank_loop import (
    make_bank_server,
    reset_tenants,
    serve_bank_stream,
)

__all__ = [
    "generate",
    "prefill_tokens",
    "make_bank_server",
    "serve_bank_stream",
    "reset_tenants",
]
