from repro.serve.serve_loop import generate, prefill_tokens
from repro.serve.bank_loop import (
    make_bank_server,
    make_krls_bank_server,
    reset_krls_tenants,
    reset_tenants,
    serve_bank_stream,
    serve_krls_bank_stream,
)
from repro.serve.queue import (
    MicroBatchQueue,
    klms_micro_batch_queue,
    krls_micro_batch_queue,
    make_chunked_bank_server,
    make_chunked_krls_bank_server,
)
from repro.serve.snapshot import (
    SnapshotServer,
    StateSnapshot,
    klms_snapshot_server,
    krls_snapshot_server,
)

__all__ = [
    "generate",
    "prefill_tokens",
    "make_bank_server",
    "serve_bank_stream",
    "reset_tenants",
    "make_krls_bank_server",
    "serve_krls_bank_stream",
    "reset_krls_tenants",
    "MicroBatchQueue",
    "make_chunked_bank_server",
    "make_chunked_krls_bank_server",
    "klms_micro_batch_queue",
    "krls_micro_batch_queue",
    "SnapshotServer",
    "StateSnapshot",
    "klms_snapshot_server",
    "krls_snapshot_server",
]
