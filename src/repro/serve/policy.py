"""Slot-lifecycle policy: the bank as a cache of hot tenants.

PR 6 shipped the eviction *mechanism* — O(1) row park plus replay-log
rebuild — but at tenants ≫ slots the scarce resource is the bank itself,
and something must decide **who lives in a slot**. This module is that
policy tier. It is deliberately pure host-side bookkeeping (no jax): the
facade (serve/api.py) asks it questions — "which slot serves tenant 17?",
"who do I evict to admit tenant 40961?" — and performs the actual state
movement through ``core.bank``'s ``tenant_row``/``set_tenant_row``/
``evict_tenant``/``rebuild_tenant`` primitives. Keeping the policy free of
array code makes eviction order unit-testable and bitwise-irrelevant: the
policy can never corrupt a resident row, only choose one.

Three pluggable eviction scores (LOWER = colder = evicted first):

* ``lru``  — score is the logical clock of the tenant's last touch.
* ``lfu``  — score is the lifetime touch count (kept across evictions, so
  a returning heavy hitter outranks a one-hit wonder immediately).
* ``cost`` — score = recency x rebuild-cost. Recency decays as
  ``1 / (1 + clock - last_touch)``; the rebuild cost comes from a
  caller-supplied ``cost_fn`` estimating what re-admitting this tenant
  would pay (the facade derives it from replay-log length and learner
  family — a KRLS rebuild pays a ``(D, D)`` solve per replay plus O(D^2)
  per tick, KLMS a cheap O(D) affine scan), so the policy preferentially
  keeps tenants that are expensive to bring back.

Admission control: when the bank is full, a new tenant is admitted only if
the coldest incumbent scores strictly *below* the candidate (the incumbent
floor). Ties keep the incumbent. Under LRU the floor always passes (a
fresh touch outranks any past touch — classic always-admit LRU); under
``lfu``/``cost`` a burst of one-off tail tenants stops flushing the hot
set, which is exactly the Zipf-tail scenario ``benchmarks/zipf_bench.py``
measures.

Capacity management: ``suggest_size()`` proposes pow2 grow/shrink targets
from occupancy and recent admission rejects; the facade applies them by
migrating live rows (compaction) through the bank primitives.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Union

__all__ = ["AdmitDecision", "SlotPolicy", "SCORERS"]


class AdmitDecision(NamedTuple):
    """Outcome of one admission request.

    ``action`` is one of ``"hit"`` (already resident), ``"admit"`` (placed
    in a free slot), ``"evict"`` (placed in ``slot`` after evicting
    ``victim``), or ``"reject"`` (bank full and no incumbent scored below
    the candidate — the arrival should be logged, not trained).
    """

    action: str
    slot: Optional[int] = None
    victim: Optional[int] = None


def _lru_score(policy: "SlotPolicy", tenant: int) -> float:
    return float(policy.last_touch.get(tenant, 0))


def _lfu_score(policy: "SlotPolicy", tenant: int) -> float:
    return float(policy.touches.get(tenant, 0))


def _cost_score(policy: "SlotPolicy", tenant: int) -> float:
    recency = 1.0 / (1.0 + policy.clock - policy.last_touch.get(tenant, 0))
    cost = policy.cost_fn(tenant) if policy.cost_fn is not None else 1.0
    return recency * cost


SCORERS: dict[str, Callable[["SlotPolicy", int], float]] = {
    "lru": _lru_score,
    "lfu": _lfu_score,
    "cost": _cost_score,
}


class SlotPolicy:
    """Decide which tenants occupy the bank's ``slots`` slots.

    Args:
      slots: number of bank slots currently under management.
      scorer: ``"lru"`` / ``"lfu"`` / ``"cost"`` or a callable
        ``(policy, tenant) -> float`` (lower = evicted first).
      cost_fn: ``tenant -> float`` rebuild-cost estimate consumed by the
        ``cost`` scorer (the facade wires replay-log length x family
        cost). Ignored by the other scorers.
      min_slots / max_slots: pow2 bounds for ``suggest_size``.
      grow_rejects: admission rejects since the last resize that trigger a
        grow suggestion.
      shrink_occupancy: occupancy fraction at or below which a shrink (one
        pow2 step) is suggested.

    Determinism contract: victim selection orders incumbents by
    ``(score, last_touch, tenant)`` — ties on score fall to the
    least-recently-touched, then the smallest tenant id — and free slots
    are handed out lowest-index first, so identical request streams
    produce identical placements (unit-tested).
    """

    def __init__(
        self,
        slots: int,
        scorer: Union[str, Callable] = "lru",
        *,
        cost_fn: Optional[Callable[[int], float]] = None,
        min_slots: int = 1,
        max_slots: int = 1 << 20,
        grow_rejects: int = 8,
        shrink_occupancy: float = 0.25,
    ):
        if slots < 1:
            raise ValueError("slots must be >= 1")
        if isinstance(scorer, str):
            if scorer not in SCORERS:
                raise ValueError(
                    f"unknown scorer {scorer!r}; pick from {sorted(SCORERS)}"
                )
            self.scorer_name = scorer
            self._scorer = SCORERS[scorer]
        else:
            self.scorer_name = getattr(scorer, "__name__", "custom")
            self._scorer = scorer
        self.slots = slots
        self.cost_fn = cost_fn
        self.min_slots = min_slots
        self.max_slots = max_slots
        self.grow_rejects = grow_rejects
        self.shrink_occupancy = shrink_occupancy
        self.clock = 0
        self.last_touch: dict[int, int] = {}
        self.touches: dict[int, int] = {}
        self._resident: dict[int, int] = {}
        self._free: list[int] = list(range(slots - 1, -1, -1))  # pop() -> 0
        self.rejects_since_resize = 0

    # -- observation --------------------------------------------------------

    def touch(self, tenant: int) -> None:
        """Record one request for ``tenant`` (advances the logical clock)."""
        self.clock += 1
        self.last_touch[tenant] = self.clock
        self.touches[tenant] = self.touches.get(tenant, 0) + 1

    def lookup(self, tenant: int) -> Optional[int]:
        """The slot serving ``tenant``, or None when not resident."""
        return self._resident.get(tenant)

    @property
    def resident(self) -> dict[int, int]:
        """Snapshot of the tenant -> slot map."""
        return dict(self._resident)

    @property
    def occupancy(self) -> int:
        return len(self._resident)

    def score(self, tenant: int) -> float:
        """Eviction score (lower = colder = evicted first)."""
        return self._scorer(self, tenant)

    def _key(self, tenant: int):
        return (self.score(tenant), self.last_touch.get(tenant, 0), tenant)

    def victim(self) -> Optional[int]:
        """The incumbent the policy would evict next (None if bank empty)."""
        if not self._resident:
            return None
        return min(self._resident, key=self._key)

    # -- placement ----------------------------------------------------------

    def admit(self, tenant: int, force: bool = False) -> AdmitDecision:
        """Place ``tenant`` in a slot, evicting or rejecting as scored.

        Mutates the resident map according to the returned decision — the
        caller performs the matching bank-state work (park the victim's
        slot, rebuild the admitted tenant from its log). ``force=True``
        bypasses the admission floor (operator-initiated readmit).
        """
        slot = self._resident.get(tenant)
        if slot is not None:
            return AdmitDecision("hit", slot=slot)
        if self._free:
            slot = self._free.pop()
            self._resident[tenant] = slot
            return AdmitDecision("admit", slot=slot)
        victim = self.victim()
        # The incumbent floor: the coldest incumbent must score strictly
        # below the candidate; ties keep the incumbent.
        if not force and self.score(victim) >= self.score(tenant):
            self.rejects_since_resize += 1
            return AdmitDecision("reject")
        slot = self._resident.pop(victim)
        self._resident[tenant] = slot
        return AdmitDecision("evict", slot=slot, victim=victim)

    def release(self, tenant: int) -> Optional[int]:
        """Voluntarily evict ``tenant``; returns the freed slot (or None)."""
        slot = self._resident.pop(tenant, None)
        if slot is not None:
            self._free.append(slot)
            self._free.sort(reverse=True)  # keep lowest-index-first handout
        return slot

    def move(self, tenant: int, new_slot: int) -> None:
        """Re-pin a resident tenant to another slot (compaction move)."""
        if tenant not in self._resident:
            raise KeyError(f"tenant {tenant} is not resident")
        self._resident[tenant] = new_slot

    # -- durability ---------------------------------------------------------

    def state_dict(self) -> dict:
        """Plain-dict export of the policy's mutable state (checkpointing).

        Covers everything admission decisions depend on — logical clock,
        touch history, residency, free list, reject pressure — so a
        restored policy makes the same decisions the live one would have.
        ``cost_fn`` is a live callable and is NOT serialized; the facade
        re-wires it at restore.
        """
        return {
            "slots": self.slots,
            "scorer": self.scorer_name,
            "clock": self.clock,
            "last_touch": dict(self.last_touch),
            "touches": dict(self.touches),
            "resident": dict(self._resident),
            "free": list(self._free),
            "rejects_since_resize": self.rejects_since_resize,
        }

    def load_state(self, d: dict) -> None:
        """Restore the mutable state exported by :meth:`state_dict`.

        The receiving policy must already be built with the same scorer
        and structural knobs; slot count is adopted from the snapshot.
        """
        if d["scorer"] != self.scorer_name:
            raise ValueError(
                f"checkpoint scorer {d['scorer']!r} != policy scorer "
                f"{self.scorer_name!r}"
            )
        self.slots = int(d["slots"])
        self.clock = int(d["clock"])
        self.last_touch = {int(k): int(v) for k, v in d["last_touch"].items()}
        self.touches = {int(k): int(v) for k, v in d["touches"].items()}
        self._resident = {int(k): int(v) for k, v in d["resident"].items()}
        self._free = [int(s) for s in d["free"]]
        self.rejects_since_resize = int(d["rejects_since_resize"])

    # -- capacity -----------------------------------------------------------

    def suggest_size(self) -> int:
        """Pow2 slot-count suggestion from occupancy and reject pressure.

        Grow one step when the bank is full and ``grow_rejects`` arrivals
        were rejected since the last resize; shrink one step when
        occupancy is at or below ``shrink_occupancy``. Otherwise the
        current size. The caller decides whether to apply it (and resets
        the reject counter via :meth:`set_slots`).
        """
        if (
            not self._free
            and self.rejects_since_resize >= self.grow_rejects
            and self.slots * 2 <= self.max_slots
        ):
            return self.slots * 2
        if (
            self.slots > self.min_slots
            and self.occupancy <= self.shrink_occupancy * self.slots
        ):
            return max(self.min_slots, self.slots // 2)
        return self.slots

    def set_slots(self, slots: int) -> None:
        """Adopt a new slot count after the caller migrated the bank.

        Every resident slot index must already be < ``slots`` (the facade
        compacts rows first); the free list is rebuilt from the gap.
        """
        used = set(self._resident.values())
        if any(s >= slots for s in used):
            raise ValueError(
                f"resident slots {sorted(used)} do not fit in {slots}"
            )
        self.slots = slots
        self._free = sorted((s for s in range(slots) if s not in used),
                            reverse=True)
        self.rejects_since_resize = 0
