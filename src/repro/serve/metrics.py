"""Lightweight serving metrics: counters, gauges, log-bucketed histograms.

The policy tier (serve/policy.py), the serving facade (serve/api.py) and
the observability layer (repro/obs) need to answer "what happens when
tenants ≫ slots" and "is the hot path healthy" with *numbers* —
evictions, readmissions, admission rejects, queue backlog, kernel-launch
counts, and the per-request latency distribution under skewed load. This
module is the smallest registry that supports that: pure host-side Python
(no jax, no locks — the serve path is single-threaded like the queue it
instruments), O(1) per observation, and a ``snapshot()`` that renders
everything to a plain JSON-able dict for the Zipf benchmark's
``BENCH_zipf.json`` records and ``Server.observability()``.

Metrics may carry **labels** (``registry.counter("kernel.launches",
op="klms_chunk")``); a labeled metric is keyed by its rendered name
``kernel.launches{op=klms_chunk}`` so snapshots stay flat dicts and the
bench tooling needs no schema change.

Histograms use fixed geometric (base-2) buckets so an observation costs
one ``math.frexp`` — no sorting, no reservoir — and percentiles are
estimated by linear interpolation inside the winning bucket (resolution
is one octave, which is plenty for p50/p95/p99 columns whose purpose is
trajectory tracking, not microsecond forensics). Bucketing is on the
*float* exponent, so sub-unit observations (ms-scale latencies recorded
in seconds, bf16 error magnitudes ~1e-3) resolve into distinct buckets
instead of collapsing into bucket 0 the way the old ``int(v).bit_length()``
rule did. Exact min/max are kept so the tails of the estimate never
leave the observed range. ``Histogram.merge`` sums two histograms with
identical bucketing — the cross-registry aggregation primitive for
multi-server / multi-host rollups.
"""
from __future__ import annotations

import math
from typing import Optional

__all__ = ["Counter", "Histogram", "MetricsRegistry"]


class Counter:
    """Monotonic event counter."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Histogram:
    """Geometric-bucket histogram over non-negative observations.

    Bucket ``i`` holds values whose ``math.frexp`` exponent is
    ``i - EXP_OFFSET``, i.e. the half-open octave
    ``[2**(i - EXP_OFFSET - 1), 2**(i - EXP_OFFSET))``; bucket 0 holds
    zero and anything below ``2**-EXP_OFFSET``. With the default 64
    buckets the resolvable range spans ~6e-8 .. 5.5e11 — microsecond
    latencies, second-scale latencies, and bf16 error floors all land in
    interior buckets. ``percentile`` walks the cumulative counts and
    interpolates linearly within the target bucket, clamped to the exact
    observed ``[min, max]``.
    """

    __slots__ = ("counts", "count", "total", "min", "max")

    # Exponent floor: bucket index = frexp exponent + EXP_OFFSET.
    EXP_OFFSET = 24

    def __init__(self, max_buckets: int = 64) -> None:
        self.counts = [0] * max_buckets
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def _bucket(self, v: float) -> int:
        if v <= 0.0:
            return 0
        return min(
            len(self.counts) - 1, max(0, math.frexp(v)[1] + self.EXP_OFFSET)
        )

    def _bucket_range(self, i: int) -> tuple[float, float]:
        lo = 0.0 if i == 0 else 2.0 ** (i - self.EXP_OFFSET - 1)
        return lo, 2.0 ** (i - self.EXP_OFFSET)

    def observe(self, value: float) -> None:
        v = max(0.0, float(value))
        self.counts[self._bucket(v)] += 1
        self.count += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Estimated q-th percentile (``q`` in [0, 100])."""
        if not self.count:
            return 0.0
        target = q / 100.0 * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            if seen + c >= target:
                lo, hi = self._bucket_range(i)
                frac = (target - seen) / c
                est = lo + frac * (hi - lo)
                return min(max(est, self.min), self.max)
            seen += c
        return self.max  # pragma: no cover - target <= count by construction

    def merge(self, other: "Histogram") -> "Histogram":
        """Fold ``other`` into self (in place; returns self).

        Both histograms must share the bucketing (same bucket count) —
        the percentile estimate of the merge is then exactly the estimate
        a single histogram observing both streams would give.
        """
        if len(self.counts) != len(other.counts):
            raise ValueError(
                f"bucket mismatch: {len(self.counts)} vs {len(other.counts)}"
            )
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.total += other.total
        for bound, pick in (("min", min), ("max", max)):
            theirs = getattr(other, bound)
            if theirs is not None:
                ours = getattr(self, bound)
                setattr(
                    self, bound,
                    theirs if ours is None else pick(ours, theirs),
                )
        return self

    def summary(self) -> dict:
        return {
            "count": self.count,
            "mean": self.mean,
            "min": self.min if self.min is not None else 0.0,
            "max": self.max if self.max is not None else 0.0,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }


def _key(name: str, labels: dict) -> str:
    """Render a metric identity: ``name`` or ``name{k=v,...}`` (sorted)."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Name (+ labels) -> metric registry with create-on-first-use.

    One registry instruments one server; ``snapshot()`` is the stable
    export format (plain dict) the Zipf bench embeds per record::

        {"counters": {name: int}, "gauges": {name: float},
         "histograms": {name: {count, mean, min, max, p50, p95, p99}}}

    Labeled metrics appear under their rendered ``name{k=v}`` key.
    ``merge`` folds another registry in (counters add, gauges last-write-
    wins, histograms bucket-merge) for cross-registry aggregation.
    """

    def __init__(self) -> None:
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, float] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str, **labels) -> Counter:
        key = _key(name, labels)
        if key not in self._counters:
            self._counters[key] = Counter()
        return self._counters[key]

    def histogram(self, name: str, **labels) -> Histogram:
        key = _key(name, labels)
        if key not in self._histograms:
            self._histograms[key] = Histogram()
        return self._histograms[key]

    def set_gauge(self, name: str, value: float, **labels) -> None:
        self._gauges[_key(name, labels)] = float(value)

    def gauge(self, name: str, default: float = 0.0, **labels) -> float:
        return self._gauges.get(_key(name, labels), default)

    def count(self, name: str, **labels) -> int:
        """Current value of a counter (0 if never incremented)."""
        c = self._counters.get(_key(name, labels))
        return c.value if c is not None else 0

    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """Fold ``other``'s metrics into self (in place; returns self)."""
        for k, c in other._counters.items():
            self.counter(k).inc(c.value)
        self._gauges.update(other._gauges)
        for k, h in other._histograms.items():
            self.histogram(k).merge(h)
        return self

    def snapshot(self) -> dict:
        return {
            "counters": {k: c.value for k, c in sorted(self._counters.items())},
            "gauges": dict(sorted(self._gauges.items())),
            "histograms": {
                k: h.summary() for k, h in sorted(self._histograms.items())
            },
        }
