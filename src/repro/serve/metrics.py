"""Lightweight serving metrics: counters, gauges, log-bucketed histograms.

The policy tier (serve/policy.py) and the serving facade (serve/api.py)
need to answer "what happens when tenants ≫ slots" with *numbers* —
evictions, readmissions, admission rejects, queue backlog, and the
per-request latency distribution under skewed load. This module is the
smallest registry that supports that: pure host-side Python (no jax, no
locks — the serve path is single-threaded like the queue it instruments),
O(1) per observation, and a ``snapshot()`` that renders everything to a
plain JSON-able dict for the Zipf benchmark's ``BENCH_zipf.json`` records.

Histograms use fixed geometric (base-2) buckets so a latency observation
costs one ``bit_length`` — no sorting, no reservoir — and percentiles are
estimated by linear interpolation inside the winning bucket (resolution is
one octave, which is plenty for p50/p95/p99 columns whose purpose is
trajectory tracking, not microsecond forensics). Exact min/max are kept so
the tails of the estimate never leave the observed range.
"""
from __future__ import annotations

from typing import Optional

__all__ = ["Counter", "Histogram", "MetricsRegistry"]


class Counter:
    """Monotonic event counter."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Histogram:
    """Geometric-bucket histogram over non-negative observations.

    Bucket ``i`` holds values in ``[2**(i-1), 2**i)`` (bucket 0 holds
    ``[0, 1)``), measured in whatever unit the caller observes — the serve
    facade records microseconds. ``percentile`` walks the cumulative
    counts and interpolates linearly within the target bucket, clamped to
    the exact observed ``[min, max]``.
    """

    __slots__ = ("counts", "count", "total", "min", "max")

    def __init__(self, max_buckets: int = 40) -> None:
        self.counts = [0] * max_buckets
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, value: float) -> None:
        v = max(0.0, float(value))
        idx = min(len(self.counts) - 1, int(v).bit_length())
        self.counts[idx] += 1
        self.count += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Estimated q-th percentile (``q`` in [0, 100])."""
        if not self.count:
            return 0.0
        target = q / 100.0 * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            if seen + c >= target:
                lo = 0.0 if i == 0 else float(2 ** (i - 1))
                hi = float(2**i)
                frac = (target - seen) / c
                est = lo + frac * (hi - lo)
                return min(max(est, self.min), self.max)
            seen += c
        return self.max  # pragma: no cover - target <= count by construction

    def summary(self) -> dict:
        return {
            "count": self.count,
            "mean": self.mean,
            "min": self.min if self.min is not None else 0.0,
            "max": self.max if self.max is not None else 0.0,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }


class MetricsRegistry:
    """Name -> metric registry with create-on-first-use semantics.

    One registry instruments one server; ``snapshot()`` is the stable
    export format (plain dict) the Zipf bench embeds per record::

        {"counters": {name: int}, "gauges": {name: float},
         "histograms": {name: {count, mean, min, max, p50, p95, p99}}}
    """

    def __init__(self) -> None:
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, float] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        if name not in self._counters:
            self._counters[name] = Counter()
        return self._counters[name]

    def histogram(self, name: str) -> Histogram:
        if name not in self._histograms:
            self._histograms[name] = Histogram()
        return self._histograms[name]

    def set_gauge(self, name: str, value: float) -> None:
        self._gauges[name] = float(value)

    def gauge(self, name: str, default: float = 0.0) -> float:
        return self._gauges.get(name, default)

    def count(self, name: str) -> int:
        """Current value of a counter (0 if never incremented)."""
        c = self._counters.get(name)
        return c.value if c is not None else 0

    def snapshot(self) -> dict:
        return {
            "counters": {k: c.value for k, c in sorted(self._counters.items())},
            "gauges": dict(sorted(self._gauges.items())),
            "histograms": {
                k: h.summary() for k, h in sorted(self._histograms.items())
            },
        }
