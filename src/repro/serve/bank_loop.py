"""Serving loop for the online filter bank (multi-tenant kernel regression).

The LM loop in serve_loop.py drives a decode state; this drives the other
fixed-size state in the repo — a bank of B online kernel filters, one per
tenant stream. Each tick every tenant delivers one ``(x, y)`` observation;
the server answers with the prior prediction (made *before* seeing ``y`` —
the honest online quantity) and folds the observation into its state via the
fused Pallas KLMS step. Fixed-size state means admission is O(1): a tenant
slot is a ``(D,)`` row, reset by zeroing it.

``make_bank_server`` returns the one-tick function (jit-compiled once,
reused every tick); ``serve_bank_stream`` scans a whole ``(B, n)`` traffic
matrix through it under a single jit — the benchmark's "≥64 concurrent
streams, one jitted call" path.

Every server accepts any :mod:`repro.features` map — deterministic GQ/QMC
families give variance-free serving (two replicas constructed from the same
config predict identically, no seed coordination needed); non-trig families
run through the generic bank fallback automatically.

KRLS tenants (``make_krls_bank_server`` / ``serve_krls_bank_stream``) get
the same treatment through the fused RLS bank kernel: per-tenant state is a
``(D,)`` theta plus a ``(D, D)`` inverse correlation, still fixed-size, so
admission stays O(1) — a slot reset re-seeds theta to zero and P to
``I / lam`` (``reset_krls_tenants``).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.bank import (
    klms_bank_run,
    klms_bank_step,
    krls_bank_run,
    krls_bank_step,
)
from repro.core.klms import LMSState, StepOut
from repro.core.krls import RLSState
from repro.features.base import FeatureLike

__all__ = [
    "make_bank_server",
    "serve_bank_stream",
    "reset_tenants",
    "make_krls_bank_server",
    "serve_krls_bank_stream",
    "reset_krls_tenants",
]


def make_bank_server(
    rff: FeatureLike, mu: Union[float, jax.Array], mode: str = "auto"
) -> Callable[[LMSState, jax.Array, jax.Array], tuple[LMSState, StepOut]]:
    """Build the jitted per-tick server: ``(state, xs (B,d), ys (B,)) ->
    (state, StepOut)``. Compile once, call per tick."""

    @jax.jit
    def tick(state: LMSState, xs: jax.Array, ys: jax.Array):
        return klms_bank_step(state, xs, ys, rff, mu, mode=mode)

    return tick


@functools.partial(jax.jit, static_argnames=("mode", "chunk"))
def serve_bank_stream(
    rff: FeatureLike,
    xs: jax.Array,
    ys: jax.Array,
    mu: Union[float, jax.Array],
    state: Optional[LMSState] = None,
    mode: str = "auto",
    chunk: Optional[int] = None,
) -> tuple[LMSState, StepOut]:
    """Serve B tenant streams ``xs (B, n, d)``, ``ys (B, n)`` in one jit.

    ``chunk=T`` drives the time-blocked kernel schedule (one launch per T
    ticks) instead of the per-tick scan — same trajectory, fewer dispatches.
    """
    return klms_bank_run(rff, xs, ys, mu, state=state, mode=mode, chunk=chunk)


def reset_tenants(state: LMSState, slots: jax.Array) -> LMSState:
    """Zero the given tenant rows (churn: admit a new tenant into a slot).

    ``slots`` is an int array of bank indices; O(1) per tenant because the
    per-tenant state is a fixed-size row, never a grown dictionary.
    """
    theta = state.theta.at[slots].set(0.0)
    step = state.step.at[slots].set(0)
    return LMSState(theta=theta, step=step)


def make_krls_bank_server(
    rff: FeatureLike, beta: Union[float, jax.Array] = 0.9995, mode: str = "auto"
) -> Callable[[RLSState, jax.Array, jax.Array], tuple[RLSState, StepOut]]:
    """Jitted per-tick KRLS server: ``(state, xs (B,d), ys (B,)) ->
    (state, StepOut)`` through the fused RLS bank kernel."""

    @jax.jit
    def tick(state: RLSState, xs: jax.Array, ys: jax.Array):
        return krls_bank_step(state, xs, ys, rff, beta, mode=mode)

    return tick


@functools.partial(jax.jit, static_argnames=("mode", "chunk"))
def serve_krls_bank_stream(
    rff: FeatureLike,
    xs: jax.Array,
    ys: jax.Array,
    lam: float = 1e-4,
    beta: Union[float, jax.Array] = 0.9995,
    state: Optional[RLSState] = None,
    mode: str = "auto",
    chunk: Optional[int] = None,
) -> tuple[RLSState, StepOut]:
    """Serve B KRLS tenant streams ``xs (B, n, d)``, ``ys (B, n)``.

    ``chunk=T`` selects the time-blocked kernel schedule (see
    :func:`serve_bank_stream`).
    """
    return krls_bank_run(
        rff, xs, ys, lam=lam, beta=beta, state=state, mode=mode, chunk=chunk
    )


def reset_krls_tenants(
    state: RLSState, slots: jax.Array, lam: float = 1e-4
) -> RLSState:
    """Re-admit KRLS tenants: theta -> 0, P -> I/lam, step -> 0 per slot."""
    dfeat = state.theta.shape[-1]
    theta = state.theta.at[slots].set(0.0)
    pmat = state.pmat.at[slots].set(
        jnp.eye(dfeat, dtype=state.pmat.dtype) / lam
    )
    step = state.step.at[slots].set(0)
    return RLSState(theta=theta, pmat=pmat, step=step)
