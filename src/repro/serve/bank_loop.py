"""Deprecated lockstep serving entry points (pre-facade names).

The per-family factories that used to live here — ``make_bank_server`` /
``make_krls_bank_server``, ``serve_bank_stream`` /
``serve_krls_bank_stream``, ``reset_tenants`` / ``reset_krls_tenants`` —
are now thin deprecation shims over the learner-parameterized facade in
serve/api.py (:func:`repro.serve.make_tick`, :func:`repro.serve.run_stream`,
:func:`repro.serve.reset_slots`). Each shim preserves its historical
signature and bitwise behavior (equivalence-tested in
tests/test_serve_api.py) and emits one :class:`DeprecationWarning` per
process. New code should call the facade directly.
"""
from __future__ import annotations

from typing import Callable, Optional, Union

import jax

from repro.core.klms import LMSState, StepOut
from repro.core.krls import RLSState
from repro.features.base import FeatureLike

__all__ = [
    "make_bank_server",
    "serve_bank_stream",
    "reset_tenants",
    "make_krls_bank_server",
    "serve_krls_bank_stream",
    "reset_krls_tenants",
]


def make_bank_server(
    rff: FeatureLike, mu: Union[float, jax.Array], mode: str = "auto"
) -> Callable[[LMSState, jax.Array, jax.Array], tuple[LMSState, StepOut]]:
    """Deprecated: use ``repro.serve.make_tick("klms", ...)``."""
    from repro.serve import api

    api._deprecated("make_bank_server", 'make_tick("klms", ...)')
    return api.make_tick("klms", rff, mode=mode, mu=mu)


def serve_bank_stream(
    rff: FeatureLike,
    xs: jax.Array,
    ys: jax.Array,
    mu: Union[float, jax.Array],
    state: Optional[LMSState] = None,
    mode: str = "auto",
    chunk: Optional[int] = None,
) -> tuple[LMSState, StepOut]:
    """Deprecated: use ``repro.serve.run_stream("klms", ...)``."""
    from repro.serve import api

    api._deprecated("serve_bank_stream", 'run_stream("klms", ...)')
    return api.run_stream(
        "klms", rff, xs, ys, state=state, mode=mode, chunk=chunk, mu=mu
    )


def reset_tenants(state: LMSState, slots: jax.Array) -> LMSState:
    """Deprecated: use ``repro.serve.reset_slots(state, slots)``."""
    from repro.serve import api

    api._deprecated("reset_tenants", "reset_slots(state, slots)")
    return api.reset_slots(state, slots, learner="klms")


def make_krls_bank_server(
    rff: FeatureLike, beta: Union[float, jax.Array] = 0.9995, mode: str = "auto"
) -> Callable[[RLSState, jax.Array, jax.Array], tuple[RLSState, StepOut]]:
    """Deprecated: use ``repro.serve.make_tick("krls", ...)``."""
    from repro.serve import api

    api._deprecated("make_krls_bank_server", 'make_tick("krls", ...)')
    return api.make_tick("krls", rff, mode=mode, beta=beta)


def serve_krls_bank_stream(
    rff: FeatureLike,
    xs: jax.Array,
    ys: jax.Array,
    lam: float = 1e-4,
    beta: Union[float, jax.Array] = 0.9995,
    state: Optional[RLSState] = None,
    mode: str = "auto",
    chunk: Optional[int] = None,
) -> tuple[RLSState, StepOut]:
    """Deprecated: use ``repro.serve.run_stream("krls", ...)``."""
    from repro.serve import api

    api._deprecated("serve_krls_bank_stream", 'run_stream("krls", ...)')
    return api.run_stream(
        "krls", rff, xs, ys, state=state, mode=mode, chunk=chunk,
        lam=lam, beta=beta,
    )


def reset_krls_tenants(
    state: RLSState, slots: jax.Array, lam: float = 1e-4
) -> RLSState:
    """Deprecated: use ``repro.serve.reset_slots(..., lam=lam)``."""
    from repro.serve import api

    api._deprecated(
        "reset_krls_tenants", 'reset_slots(state, slots, learner="krls")'
    )
    return api.reset_slots(state, slots, learner="krls", lam=lam)
