"""Unified serving facade: one learner-parameterized entry point.

Historically every serving tier grew a parallel factory per learner family
(``make_bank_server`` / ``make_krls_bank_server``, ``klms_micro_batch_queue``
/ ``krls_micro_batch_queue``, ...), which scales as tiers x families. This
module collapses them into ONE parameterized surface:

* :func:`make_server` — the facade. Returns a :class:`Server` wrapping the
  whole write path (micro-batch queue -> chunked kernels), read path
  (snapshot-decoupled fused predict), tenant lifecycle (evict / readmit
  over replay logs), and — new in this tier — the **slot policy**
  (serve/policy.py) that manages the bank as a cache of hot tenants when
  tenant ids outnumber slots, plus a metrics registry (serve/metrics.py)
  instrumenting every request.
* :func:`make_tick` / :func:`make_chunk_step` / :func:`run_stream` /
  :func:`make_queue` / :func:`reset_slots` — the learner-parameterized
  building blocks the facade (and benchmarks) compose; these replace the
  per-family factories, which remain importable as deprecation shims.

Learner families: ``"klms"`` / ``"nklms"`` / ``"krls"`` ride the fused
Pallas bank kernels and the fused block-predict read path (KLMS/KRLS) or a
generic masked scan (NKLMS — no fused chunk kernel exists for the
normalized update); ``"qklms"`` / ``"ald"`` are the growing-dictionary
baselines, driven through the same queue/snapshot machinery by vmapping
their ``OnlineLearner`` step, with dictionary-aware predict and
sequential-replay rebuilds.

Policy mode: pass ``policy=`` ("lru" / "lfu" / "cost", a config dict, or a
:class:`~repro.serve.policy.SlotPolicy`) and tenant ids become *unbounded*
— the Server maintains a tenant->slot cache over a B-slot bank: misses
admit (possibly evicting the coldest incumbent, subject to the admission
floor), rejected arrivals are logged-not-trained, readmissions rebuild
from the per-tenant replay log through the parallel-in-time engine, and
``resize`` grows/shrinks the bank in pow2 steps with bitwise row
migration. Without a policy, tenant ids ARE slot indices (the pre-policy
contract, equivalence-tested against the deprecated factories).
"""
from __future__ import annotations

import functools
import time
import warnings
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bank import (
    bank_init,
    bank_run,
    bank_size,
    bank_step,
    evict_tenant,
    klms_bank_chunk_step,
    klms_bank_init,
    klms_bank_run,
    klms_bank_step,
    krls_bank_chunk_step,
    krls_bank_init,
    krls_bank_run,
    krls_bank_step,
    resize_bank,
    set_tenant_row,
    tenant_row,
)
from repro.core.klms import LMSState, StepOut
from repro.core.krls import RLSState
from repro.core.learner import (
    OnlineLearner,
    ald_krls_learner,
    klms_learner,
    krls_learner,
    nklms_learner,
    qklms_learner,
)
from repro.features.base import FeatureLike
from repro.features.base import input_dim as fm_input_dim
from repro.obs import probes as _probes
from repro.obs import telemetry as _telemetry
from repro.obs import trace as _obtrace
from repro.serve.metrics import MetricsRegistry
from repro.serve.policy import SlotPolicy
from repro.serve.queue import MicroBatchQueue
from repro.serve.recovery import DurableLog, RecoveryPolicy, save_checkpoint
from repro.serve.snapshot import ReplayLog, SnapshotServer, predict_row

__all__ = [
    "LEARNER_FAMILIES",
    "Server",
    "make_server",
    "make_tick",
    "make_chunk_step",
    "run_stream",
    "make_queue",
    "reset_slots",
]

LEARNER_FAMILIES = ("klms", "nklms", "qklms", "krls", "ald")

# Families whose per-tenant state is a (D,) theta row sharing one feature
# map — they ride the fused read path; the rest carry dictionaries.
_THETA_FAMILIES = frozenset({"klms", "nklms", "krls"})

# One defaults table for every family; families read only their own knobs.
_HP_DEFAULTS = dict(
    mu=0.5,        # klms / nklms / qklms step size
    eps=1e-6,      # nklms normalizer
    lam=1e-4,      # krls init regularizer (P_0 = I/lam)
    beta=0.9995,   # krls forgetting factor
    sigma=1.0,     # qklms / ald kernel bandwidth
    quant_eps=0.1, # qklms quantization radius
    nu=5e-4,       # ald novelty threshold
    capacity=256,  # qklms / ald dictionary capacity
)


# ---------------------------------------------------------------------------
# Deprecation shims — the old per-family factory names wrap this helper.
# ---------------------------------------------------------------------------

_DEPRECATION_FIRED: set[str] = set()


def _deprecated(name: str, replacement: str) -> None:
    """Emit one DeprecationWarning per old factory name per process."""
    if name in _DEPRECATION_FIRED:
        return
    _DEPRECATION_FIRED.add(name)
    warnings.warn(
        f"repro.serve.{name} is deprecated; use {replacement}",
        DeprecationWarning,
        stacklevel=3,
    )


def _reset_deprecation_state() -> None:
    """Testing hook: re-arm the once-per-name deprecation latches."""
    _DEPRECATION_FIRED.clear()


# ---------------------------------------------------------------------------
# Learner construction
# ---------------------------------------------------------------------------


def _check_learner(learner: str) -> None:
    if learner not in LEARNER_FAMILIES:
        raise ValueError(
            f"unknown learner {learner!r}; pick from {LEARNER_FAMILIES}"
        )


def _resolve_hp(hp: dict) -> dict:
    unknown = set(hp) - set(_HP_DEFAULTS)
    if unknown:
        raise TypeError(
            f"unknown hyperparameters {sorted(unknown)}; "
            f"known: {sorted(_HP_DEFAULTS)}"
        )
    return {**_HP_DEFAULTS, **hp}


def _resolve_input_dim(
    learner: str, feature_map, input_dim: Optional[int]
) -> int:
    if feature_map is not None:
        return fm_input_dim(feature_map)
    if input_dim is not None:
        return input_dim
    raise ValueError(
        f"learner {learner!r} needs feature_map= or input_dim="
    )


def build_learner(
    learner: str,
    feature_map: Optional[FeatureLike] = None,
    input_dim: Optional[int] = None,
    **hp,
) -> OnlineLearner:
    """The :class:`OnlineLearner` bundle for one family (shared by the
    facade's predict/rebuild closures and the generic queue path)."""
    _check_learner(learner)
    h = _resolve_hp(hp)
    if learner in _THETA_FAMILIES and feature_map is None:
        raise ValueError(f"learner {learner!r} requires feature_map=")
    if learner == "klms":
        return klms_learner(feature_map, h["mu"])
    if learner == "nklms":
        return nklms_learner(feature_map, h["mu"], h["eps"])
    if learner == "krls":
        return krls_learner(feature_map, lam=h["lam"], beta=h["beta"])
    d = _resolve_input_dim(learner, feature_map, input_dim)
    if learner == "qklms":
        return qklms_learner(
            d, h["sigma"], h["mu"], h["quant_eps"], capacity=h["capacity"]
        )
    return ald_krls_learner(
        d, h["sigma"], nu=h["nu"], capacity=h["capacity"]
    )


# ---------------------------------------------------------------------------
# Per-tick and chunked step factories (the old make_*_server family)
# ---------------------------------------------------------------------------


def make_tick(
    learner: str,
    feature_map: Optional[FeatureLike] = None,
    *,
    mode: str = "auto",
    input_dim: Optional[int] = None,
    **hp,
) -> Callable:
    """Jitted lockstep tick for any family: ``(state, xs (B, d), ys (B,))
    -> (state, StepOut)``. KLMS/KRLS dispatch to the fused bank kernels;
    the rest vmap their ``OnlineLearner`` step."""
    _check_learner(learner)
    h = _resolve_hp(hp)
    if learner == "klms":

        @jax.jit
        def tick(state, xs, ys):
            return klms_bank_step(state, xs, ys, feature_map, h["mu"],
                                  mode=mode)

        return tick
    if learner == "krls":

        @jax.jit
        def tick(state, xs, ys):
            return krls_bank_step(state, xs, ys, feature_map, h["beta"],
                                  mode=mode)

        return tick
    lrn = build_learner(learner, feature_map, input_dim, **hp)

    @jax.jit
    def tick(state, xs, ys):
        return bank_step(lrn, state, xs, ys)

    return tick


def _gate_leaf(mask_b: jax.Array, new, old):
    m = mask_b.reshape(mask_b.shape + (1,) * (new.ndim - 1))
    return jnp.where(m > 0, new, old)


def _generic_chunk_server(lrn: OnlineLearner) -> Callable:
    """Masked chunked server over a vmapped ``OnlineLearner`` step.

    Same contract as the fused chunk factories: ``(state, xs (B, T, d),
    ys (B, T), mask (B, T)) -> (state, StepOut (B, T))``; masked ticks
    leave every state leaf untouched (per-leaf ``where`` gate), so ragged
    micro-batches stay exact for dictionary learners too."""

    @jax.jit
    def step(state, xs, ys, mask):
        def tick(s, xym):
            x_t, y_t, m_t = xym
            s2, out = jax.vmap(lrn.step_fn)(s, x_t, y_t)
            s3 = jax.tree.map(functools.partial(_gate_leaf, m_t), s2, s)
            return s3, out

        xs_t = jnp.swapaxes(xs, 0, 1)
        ys_t = jnp.swapaxes(ys, 0, 1)
        mask_t = jnp.swapaxes(mask, 0, 1)
        state, outs = jax.lax.scan(tick, state, (xs_t, ys_t, mask_t))
        return state, jax.tree.map(lambda a: jnp.swapaxes(a, 0, 1), outs)

    return step


def make_chunk_step(
    learner: str,
    feature_map: Optional[FeatureLike] = None,
    *,
    mode: str = "auto",
    input_dim: Optional[int] = None,
    **hp,
) -> Callable:
    """Jitted chunked server for any family: ``(state, xs (B, T, d),
    ys (B, T), mask (B, T)) -> (state, StepOut)`` — one launch per chunk
    (the micro-batch queue's step)."""
    _check_learner(learner)
    h = _resolve_hp(hp)
    if learner == "klms":

        @jax.jit
        def step(state, xs, ys, mask):
            return klms_bank_chunk_step(
                state, xs, ys, feature_map, h["mu"], mask, mode=mode
            )

        return step
    if learner == "krls":

        @jax.jit
        def step(state, xs, ys, mask):
            return krls_bank_chunk_step(
                state, xs, ys, feature_map, h["beta"], mask, mode=mode
            )

        return step
    return _generic_chunk_server(
        build_learner(learner, feature_map, input_dim, **hp)
    )


# ---------------------------------------------------------------------------
# Whole-stream drives and slot resets (the old serve_*_stream / reset_*)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("mode", "chunk"))
def _klms_stream(rff, xs, ys, mu, state=None, mode="auto", chunk=None):
    return klms_bank_run(rff, xs, ys, mu, state=state, mode=mode, chunk=chunk)


@functools.partial(jax.jit, static_argnames=("mode", "chunk"))
def _krls_stream(
    rff, xs, ys, lam=1e-4, beta=0.9995, state=None, mode="auto", chunk=None
):
    return krls_bank_run(
        rff, xs, ys, lam=lam, beta=beta, state=state, mode=mode, chunk=chunk
    )


def run_stream(
    learner: str,
    feature_map: Optional[FeatureLike],
    xs: jax.Array,
    ys: jax.Array,
    *,
    state=None,
    mode: str = "auto",
    chunk: Optional[int] = None,
    input_dim: Optional[int] = None,
    **hp,
):
    """Serve B lockstep tenant streams ``xs (B, n, d)``, ``ys (B, n)`` in
    one jit for any family (the old ``serve_bank_stream`` /
    ``serve_krls_bank_stream``, learner-parameterized). ``chunk=T`` picks
    the time-blocked kernel schedule for the fused families."""
    _check_learner(learner)
    h = _resolve_hp(hp)
    if learner == "klms":
        return _klms_stream(
            feature_map, xs, ys, h["mu"], state=state, mode=mode, chunk=chunk
        )
    if learner == "krls":
        return _krls_stream(
            feature_map, xs, ys, lam=h["lam"], beta=h["beta"], state=state,
            mode=mode, chunk=chunk,
        )
    lrn = build_learner(learner, feature_map, input_dim, **hp)
    if state is None:
        state = bank_init(lrn, xs.shape[0])
    return jax.jit(lambda s, x, y: bank_run(lrn, s, x, y))(state, xs, ys)


def reset_slots(state, slots, *, learner: Optional[str] = None,
                lam: Union[float, jax.Array] = 1e-4):
    """Re-admit tenants into bank ``slots`` (an int array of indices) on a
    fresh row — O(1) per slot. The family is inferred from the state
    (``learner=`` overrides): LMS rows zero, RLS rows re-seed
    ``P_0 = I/lam``, dictionary rows zero their buffers."""
    if learner is None:
        learner = "krls" if isinstance(state, RLSState) else "klms"
    if learner == "krls":
        dfeat = state.theta.shape[-1]
        return RLSState(
            theta=state.theta.at[slots].set(0.0),
            pmat=state.pmat.at[slots].set(
                jnp.eye(dfeat, dtype=state.pmat.dtype) / lam
            ),
            step=state.step.at[slots].set(0),
        )
    if isinstance(state, LMSState):
        return LMSState(
            theta=state.theta.at[slots].set(0.0),
            step=state.step.at[slots].set(0),
        )
    return jax.tree.map(lambda a: a.at[slots].set(jnp.zeros_like(a[slots])),
                        state)


# ---------------------------------------------------------------------------
# Queue factory (the old *_micro_batch_queue pair)
# ---------------------------------------------------------------------------


def make_queue(
    learner: str = "klms",
    feature_map: Optional[FeatureLike] = None,
    bank: int = 8,
    *,
    chunk: int = 16,
    mode: str = "auto",
    adaptive: bool = False,
    state=None,
    input_dim: Optional[int] = None,
    **hp,
) -> MicroBatchQueue:
    """Ready-to-serve micro-batch queue for any family: fresh bank state
    plus the jitted chunk server, coalescing ragged arrivals into masked
    ``(B, T)`` launches."""
    _check_learner(learner)
    h = _resolve_hp(hp)
    if state is None:
        if learner in ("klms", "nklms"):
            state = klms_bank_init(feature_map, bank)
        elif learner == "krls":
            state = krls_bank_init(feature_map, bank, h["lam"])
        else:
            state = bank_init(
                build_learner(learner, feature_map, input_dim, **hp), bank
            )
    d = _resolve_input_dim(learner, feature_map, input_dim)
    return MicroBatchQueue(
        make_chunk_step(
            learner, feature_map, mode=mode, input_dim=input_dim, **hp
        ),
        state,
        d,
        chunk=chunk,
        adaptive=adaptive,
    )


# ---------------------------------------------------------------------------
# The Server facade
# ---------------------------------------------------------------------------


class Server:
    """One serving object per bank: write path, read path, lifecycle,
    policy, and metrics behind a single learner-agnostic surface.

    Built by :func:`make_server`. Without a policy, ``tenant`` arguments
    are bank-slot indices in ``[0, slots)`` — exactly the pre-facade
    :class:`~repro.serve.snapshot.SnapshotServer` contract. With a policy,
    ``tenant`` is an arbitrary id; the Server runs the bank as a cache
    (see module docstring) and ``resize`` manages capacity in pow2 steps.

    Metrics (``self.metrics``): counters ``requests.write`` /
    ``requests.read`` / ``bank.hits`` / ``bank.misses`` / ``evictions`` /
    ``readmissions`` / ``admission.rejects`` / ``read.cold`` /
    ``resizes``, gauge ``queue.backlog``, histograms ``latency.write_us``
    / ``latency.read_us``.

    Observability (``make_server(trace=..., probe=...)``): a Tracer
    records nested ``serve.*`` / ``queue.*`` / ``snapshot.*`` /
    ``kernel.*`` spans for every request (it is *activated* around each
    public method, so the deeper tiers' spans land on it without API
    threading); a :class:`~repro.obs.probes.ProbeMonitor` rides the
    queue's fused in-jit numerics tap and raises degradation events.
    :meth:`observability` exports metrics + dispatch telemetry + probe
    state + trace summary as one plain dict (schema in README
    "Observability").
    """

    def __init__(
        self,
        inner: SnapshotServer,
        *,
        learner: str,
        lrn: OnlineLearner,
        feature_map: Optional[FeatureLike],
        hp: dict,
        policy: Optional[SlotPolicy] = None,
        metrics: Optional[MetricsRegistry] = None,
        log_capacity: Optional[int] = None,
        auto_resize: bool = False,
        latency_clock: Callable[[], float] = time.perf_counter,
        tracer: Optional[_obtrace.Tracer] = None,
        probe: Union[bool, dict, None] = None,
        recovery: Optional[RecoveryPolicy] = None,
        wal: Optional[DurableLog] = None,
    ):
        self._inner = inner
        self.learner = learner
        self._lrn = lrn
        self.feature_map = feature_map
        self._hp = hp
        self.policy = policy
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.auto_resize = auto_resize
        self._lat = latency_clock
        self._theta_family = learner in _THETA_FAMILIES
        self.tracer = tracer
        self.wal = wal
        self._wal_suspended = False
        # Expected-ticks ledger, slot-keyed: observations this facade put
        # on the queue that the bank is on the hook to train. The
        # ``ticks_lag`` probe compares it against backlog + the state's
        # step counters — a positive gap means arrivals were acknowledged
        # but silently lost between queue and bank.
        self._expected: dict[int, int] = {}
        self._probe_folded_flush = -1
        if probe:
            self.probe = _probes.ProbeMonitor(
                probe if isinstance(probe, dict) else None,
                registry=self.metrics,
            )
            inner.queue.attach_probe(_probes.stats_tap)
        else:
            self.probe = None
        if policy is not None:
            # Tenant-ID-keyed logs (ids are unbounded in policy mode); the
            # inner slot-indexed log stays disabled.
            self.log = ReplayLog(0, log_capacity or 256, inner.queue._dtype)
            if policy.cost_fn is None:
                policy.cost_fn = self._rebuild_cost
        else:
            self.log = inner.log
        # A pristine row captured before any training: the pad row for
        # bank growth (theta 0 / P_0 = I/lam / zeroed dictionaries).
        self._fresh_row = tenant_row(inner.queue.state, 0)
        self.recovery = recovery
        if recovery is not None:
            recovery.bind(self)
        if not self._theta_family:
            pf = lrn.predict_fn
            self._row_predict = jax.jit(
                lambda row, xq: jax.vmap(lambda x: pf(row, x))(xq)
            )
            self._block_predict = jax.jit(
                lambda state, xq: jax.vmap(
                    lambda s, q: jax.vmap(lambda x: pf(s, x))(q)
                )(state, xq)
            )

    # -- introspection -------------------------------------------------------

    @property
    def queue(self) -> MicroBatchQueue:
        return self._inner.queue

    @property
    def snapshot(self):
        return self._inner.snapshot

    @property
    def staleness(self) -> int:
        return self._inner.staleness

    @property
    def slots(self) -> int:
        return self._inner.queue.num_tenants

    @property
    def resident(self) -> dict:
        """tenant -> slot map (identity without a policy)."""
        if self.policy is None:
            return {t: t for t in range(self.slots)}
        return self.policy.resident

    @property
    def evicted(self):
        return self._inner.evicted

    @property
    def snapshot_server(self) -> SnapshotServer:
        """The underlying snapshot tier (slot-indexed)."""
        return self._inner

    def hit_rate(self) -> float:
        """Resident-lookup hit fraction over all reads + writes so far."""
        hits = self.metrics.count("bank.hits")
        misses = self.metrics.count("bank.misses")
        return hits / (hits + misses) if hits + misses else 1.0

    # -- observability -------------------------------------------------------

    def _act(self):
        """Activate this server's tracer (no-op context when untraced)."""
        return _obtrace.activate(self.tracer)

    def _slot_lags(self) -> list[int]:
        """Per-slot expected-minus-trained tick gap: the facade's ledger
        against queue backlog plus the state's own step counters. A
        positive entry means observations this server queued were never
        folded into the bank (the ``ticks_lag`` probe / a dropped flush);
        negative entries (someone fed the queue directly, bypassing the
        facade) are legal and never fire."""
        step = np.asarray(self._inner.queue.state.step)
        backlog = self._inner.queue.backlog()
        return [
            self._expected.get(s, 0) - backlog[s] - int(step[s])
            for s in range(self.slots)
        ]

    def _note_queued(self, slot: int) -> None:
        self._expected[slot] = self._expected.get(slot, 0) + 1

    def _probe_update(self) -> None:
        """Fold the queue's latest in-jit tap readout into the monitor —
        once per flush (the tap only changes at flush boundaries, and
        re-folding a stale readout would re-fire its events), then let
        the recovery policy act on anything that fired."""
        if self.probe is None:
            return
        queue = self._inner.queue
        tap = queue.last_probe
        if tap is None or queue.flushes == self._probe_folded_flush:
            if self.recovery is not None:
                self.recovery.process()  # backoff retries between flushes
            return
        self._probe_folded_flush = queue.flushes
        stats = {k: float(v) for k, v in tap.items()}
        stats["ticks_lag"] = float(max(self._slot_lags(), default=0))
        if (
            self.recovery is not None
            and self.recovery.reference_clock is not None
        ):
            stats["clock_skew"] = self.recovery.measure_skew()
        self.probe.update(
            stats,
            tick=queue.ticks_served,
            staleness=self._inner.staleness,
        )
        if self.recovery is not None:
            self.recovery.process()

    def check_read_contract(self, xq) -> float:
        """Measure the bf16 read-contract error vs the f32 path on a
        sampled ``(B, Q, d)`` query block against the current replica, and
        fold it into the probe monitor (when one is configured). Returns
        the max relative error. Theta families only."""
        if not self._theta_family:
            raise ValueError(
                "bf16 read contract applies to the fused theta families"
            )
        with self._act(), _obtrace.span("serve.read_contract"):
            err = _probes.bf16_read_error(
                self._inner.snapshot.state,
                self.feature_map,
                jnp.asarray(xq),
                mode=self._inner.mode,
            )
            if self.probe is not None:
                tap = {
                    k: v
                    for k, v in self.probe.last_stats.items()
                    if k not in ("staleness_ticks", "bf16_read_error",
                                 "ticks_lag", "clock_skew")
                }
                self.probe.update(
                    tap,
                    tick=self._inner.queue.ticks_served,
                    staleness=self._inner.staleness,
                    bf16_err=err,
                )
        return err

    def observability(self) -> dict:
        """One plain-dict export of everything observable about this
        server::

            {"metrics": MetricsRegistry.snapshot(),
             "dispatch": repro.obs.telemetry.snapshot(),   # process-wide
             "probes": ProbeMonitor.state() | None,
             "trace": Tracer.summary() | None}

        Stable schema (validated by scripts/check_bench_schema.py for the
        records the Zipf bench embeds); see README "Observability".
        """
        return {
            "metrics": self.metrics.snapshot(),
            "dispatch": _telemetry.snapshot(),
            "probes": self.probe.state() if self.probe is not None else None,
            "trace": (
                self.tracer.summary() if self.tracer is not None else None
            ),
        }

    # -- write path ----------------------------------------------------------

    def submit(self, tenant: int, x, y) -> None:
        """Enqueue one observation for ``tenant`` (admitting / evicting /
        rejecting through the policy when one is configured)."""
        t0 = self._lat()
        with self._act(), _obtrace.span("serve.submit", tenant=tenant):
            self.metrics.counter("requests.write").inc()
            if self.wal is not None and not self._wal_suspended:
                self.wal.append(tenant, x, y)
            if (
                self.recovery is not None
                and tenant in self.recovery.quarantined
            ):
                self._quarantined_submit(tenant, x, y)
            elif self.policy is None:
                if tenant not in self._inner._evicted:
                    self._note_queued(tenant)
                self._inner.submit(tenant, x, y)
            else:
                self._policy_submit(tenant, x, y)
            self._probe_update()
            self.metrics.set_gauge(
                "queue.backlog", float(sum(self._inner.queue.backlog()))
            )
            self.metrics.histogram("latency.write_us").observe(
                (self._lat() - t0) * 1e6
            )
            if self.policy is not None and self.auto_resize:
                target = self.policy.suggest_size()
                if target != self.slots:
                    self.resize(target)

    def _policy_submit(self, tenant: int, x, y) -> None:
        pol = self.policy
        pol.touch(tenant)
        slot = pol.lookup(tenant)
        if slot is not None:
            self.metrics.counter("bank.hits").inc()
        else:
            self.metrics.counter("bank.misses").inc()
            decision = pol.admit(tenant)
            if decision.action == "reject":
                # Logged, not trained: the history is intact for a later
                # admission, but the bank spends nothing on this tenant.
                self.metrics.counter("admission.rejects").inc()
                self.log.append(tenant, x, y)
                return
            if decision.action == "evict":
                self.metrics.counter("evictions").inc()
                self._inner.release_slot(decision.slot)
                self._expected[decision.slot] = 0
            slot = decision.slot
            self._install(tenant, slot)
        self.log.append(tenant, x, y)
        self._note_queued(slot)
        self._inner.submit(slot, x, y)

    def _quarantined_submit(self, tenant: int, x, y) -> None:
        """A quarantined tenant's arrivals are logged, never trained —
        a rebuild repair replays them; a reset forfeits them with the
        rest of the history. The policy clock still ticks so admission
        ordering stays deterministic across the episode."""
        self.metrics.counter("recovery.deferred").inc()
        if self.policy is not None:
            self.policy.touch(tenant)
            self.log.append(tenant, x, y)
        elif self.log is not None:
            self.log.append(tenant, x, y)

    def _install(self, tenant: int, slot: int) -> int:
        """Rebuild ``tenant``'s state from its log into ``slot``."""
        n = self.log.size(tenant)
        if n:
            with _obtrace.span(
                "serve.install", tenant=tenant, slot=slot, ticks=n
            ):
                xs, ys = self.log.arrays(tenant)
                self._inner.queue.state = self._inner._rebuild_fn(
                    self._inner.queue.state, slot, xs, ys
                )
                self.metrics.counter("readmissions").inc()
                self._inner.publish()
        self._expected[slot] = n
        return n

    def flush(self) -> dict:
        with self._act(), _obtrace.span("serve.flush"):
            res = self._inner.flush()
            self._probe_update()
            return res

    def maybe_flush(self) -> dict:
        with self._act():
            res = self._inner.maybe_flush()
            if res:
                self._probe_update()
            return res

    def drain(self) -> dict:
        with self._act(), _obtrace.span("serve.drain"):
            res = self._inner.drain()
            self._probe_update()
            return res

    # -- read path -----------------------------------------------------------

    def _slot_predict(self, slot: int, xs) -> jax.Array:
        if self._theta_family:
            return self._inner.predict(slot, xs)
        snap = self._inner.snapshot
        xq = jnp.asarray(xs)
        single = xq.ndim == 1
        if single:
            xq = xq[None]
        row = tenant_row(snap.state, slot)
        pred = self._row_predict(row, xq)
        return pred[0] if single else pred

    def predict(self, tenant: int, xs) -> jax.Array:
        """Serve queries for one tenant from the frozen read replica.

        ``xs`` is ``(d,)`` (scalar out) or ``(Q, d)`` (``(Q,)`` out). In
        policy mode a non-resident tenant gets the *cold* prediction
        (fresh-state zeros) — reads never admit, so the read path stays
        O(1) regardless of replay-log depth.
        """
        t0 = self._lat()
        with self._act(), _obtrace.span("serve.predict", tenant=tenant):
            self.metrics.counter("requests.read").inc()
            if (
                self.recovery is not None
                and tenant in self.recovery.quarantined
            ):
                pred = self._quarantined_predict(tenant, xs)
            elif self.policy is None:
                pred = self._slot_predict(tenant, xs)
            else:
                self.policy.touch(tenant)
                slot = self.policy.lookup(tenant)
                if slot is None:
                    self.metrics.counter("bank.misses").inc()
                    self.metrics.counter("read.cold").inc()
                    xq = np.asarray(xs)
                    shape = () if xq.ndim == 1 else (xq.shape[0],)
                    pred = jnp.zeros(shape, self._inner.queue._dtype)
                else:
                    self.metrics.counter("bank.hits").inc()
                    pred = self._slot_predict(slot, xs)
            self.metrics.histogram("latency.read_us").observe(
                (self._lat() - t0) * 1e6
            )
            return pred

    def _quarantined_predict(self, tenant: int, xs) -> jax.Array:
        """Serve a quarantined tenant's reads from the captured
        last-healthy replica row (cold zeros if it was never seen
        healthy) — the degraded slot is never read."""
        self.metrics.counter("read.quarantined").inc()
        if self.policy is not None:
            self.policy.touch(tenant)
        row = self.recovery.healthy_row(tenant)
        xq = jnp.asarray(xs)
        single = xq.ndim == 1
        if single:
            xq = xq[None]
        if row is None:
            pred = jnp.zeros((xq.shape[0],), self._inner.queue._dtype)
        elif self._theta_family:
            pred = predict_row(
                row.theta, xq, self.feature_map,
                mode=self._inner.mode, precision=self._inner.precision,
            )
        else:
            pred = self._row_predict(row, xq)
        return pred[0] if single else pred

    def predict_block(self, xq) -> jax.Array:
        """Serve a ``(B, Q, d)`` query block over the whole bank (slot
        space) in one launch from the frozen replica -> ``(B, Q)``."""
        t0 = self._lat()
        with self._act(), _obtrace.span("serve.predict_block"):
            self.metrics.counter("requests.read").inc()
            if self._theta_family:
                pred = self._inner.predict_block(xq)
            else:
                pred = self._block_predict(
                    self._inner.snapshot.state, jnp.asarray(xq)
                )
            self.metrics.histogram("latency.read_us").observe(
                (self._lat() - t0) * 1e6
            )
            return pred

    # -- lifecycle -----------------------------------------------------------

    def evict(self, tenant: int) -> int:
        """Release ``tenant``'s slot. Returns dropped pending count."""
        with self._act(), _obtrace.span("serve.evict", tenant=tenant):
            if self.policy is None:
                dropped = self._inner.evict(tenant)
                self._expected[tenant] = 0
            else:
                slot = self.policy.release(tenant)
                if slot is None:
                    return 0
                dropped = self._inner.release_slot(slot)
                self._expected[slot] = 0
            self.metrics.counter("evictions").inc()
            return dropped

    def readmit(self, tenant: int) -> int:
        """Re-admit ``tenant``, rebuilding its state from the replay log.

        Policy mode bypasses the admission floor (an explicit readmit is
        an operator decision), evicting the coldest incumbent if the bank
        is full. Returns the number of replayed ticks.
        """
        with self._act(), _obtrace.span("serve.readmit", tenant=tenant):
            if self.policy is None:
                n = self._inner.readmit(tenant)
                self._expected[tenant] = n
                self.metrics.counter("readmissions").inc()
                return n
            pol = self.policy
            if pol.lookup(tenant) is not None:
                return 0
            pol.touch(tenant)
            decision = pol.admit(tenant, force=True)
            if decision.action == "evict":
                self.metrics.counter("evictions").inc()
                self._inner.release_slot(decision.slot)
                self._expected[decision.slot] = 0
            return self._install(tenant, decision.slot)

    def reset_tenant(self, tenant: int) -> int:
        """Reset ONE tenant to a fresh row — the O(1) last rung of the
        recovery ladder, also useful as an operator action. The tenant's
        replay history (and its ring-overflow flag) is forgotten with the
        state; in policy mode a resident tenant keeps its slot. Returns
        the dropped pending count."""
        with self._act(), _obtrace.span("serve.reset_tenant", tenant=tenant):
            self.metrics.counter("resets").inc()
            if self.policy is None:
                dropped = self._inner.reset_tenant(tenant)
                self._expected[tenant] = 0
                return dropped
            self.log.clear(tenant)
            slot = self.policy.lookup(tenant)
            if slot is None:
                return 0
            inner = self._inner
            dropped = inner.queue.drop_pending(slot)
            inner._arrival_times[slot].clear()
            inner.queue.state = inner._evict_fn(inner.queue.state, slot)
            inner.publish()
            self._expected[slot] = 0
            return dropped

    def checkpoint(self, directory, *, keep: int = 3) -> str:
        """Write one durable checkpoint generation of this server's full
        state (serve/recovery.py); returns the checkpoint path."""
        with self._act():
            return save_checkpoint(self, directory, keep=keep)

    def reset(self, state=None) -> None:
        """Restart on a fresh bank state: queue, replica, logs, residency
        and policy clocks all drop to zero. Drain pending first."""
        if state is None:
            state = resize_bank(
                jax.tree.map(lambda a: a[:1], self._inner.queue.state),
                self.slots,
                fresh_row=self._fresh_row,
            )
            state = set_tenant_row(state, 0, self._fresh_row)
        self._inner.reset(state)
        self._expected.clear()
        if self.policy is not None:
            self.log.clear()
            pol = self.policy
            pol.clock = 0
            pol.last_touch.clear()
            pol.touches.clear()
            pol._resident.clear()
            pol.set_slots(bank_size(state))

    # -- capacity ------------------------------------------------------------

    def resize(self, new_slots: int) -> None:
        """Grow or shrink the bank to ``new_slots`` (a power of two).

        Growth appends fresh rows; resident tenants are bitwise-untouched.
        Shrink first evicts the coldest residents until the survivors fit,
        then compacts remaining residents into ``[0, new_slots)`` via
        ``tenant_row``/``set_tenant_row`` — surviving rows are
        bitwise-preserved (tested) — and slices the bank.
        """
        if self.policy is None:
            raise ValueError("resize requires a policy tier")
        if new_slots < 1 or (new_slots & (new_slots - 1)):
            raise ValueError(f"new_slots must be a power of two, got {new_slots}")
        cur = self.slots
        if new_slots == cur:
            return
        with self._act(), _obtrace.span(
            "serve.resize", slots=cur, new_slots=new_slots
        ):
            self.metrics.counter("resizes").inc()
            pol, inner = self.policy, self._inner
            if new_slots < cur:
                while pol.occupancy > new_slots:
                    self.evict(pol.victim())
                state = inner.queue.state
                used = set(pol.resident.values())
                free_low = [s for s in range(new_slots) if s not in used]
                for tenant, slot in sorted(
                    pol.resident.items(), key=lambda kv: kv[1]
                ):
                    if slot < new_slots:
                        continue
                    dst = free_low.pop(0)
                    state = set_tenant_row(
                        state, dst, tenant_row(state, slot)
                    )
                    inner.move_slot(slot, dst)
                    self._expected[dst] = self._expected.pop(slot, 0)
                    pol.move(tenant, dst)
                inner.queue.state = state
            new_state = resize_bank(
                inner.queue.state, new_slots, fresh_row=self._fresh_row
            )
            inner.adopt_resized(new_state)
            self._expected = {
                s: v for s, v in self._expected.items() if s < new_slots
            }
            pol.set_slots(new_slots)

    # -- policy support ------------------------------------------------------

    def _rebuild_cost(self, tenant: int) -> float:
        """Rebuild-cost estimate for the cost-aware scorer: replay-log
        length x per-tick family cost, plus the fixed solve for KRLS.

        KLMS-family replays are O(D) affine scans per tick; a KRLS replay
        pays O(D^2) per tick plus one (D, D) solve; the dictionary
        baselines replay sequentially over their capacity-M buffers
        (QKLMS O(M d), ALD O(M^2) per tick).
        """
        n = max(1, self.log.size(tenant))
        hp = self._hp
        if self._theta_family:
            dfeat = self.feature_map.num_features
            if self.learner == "krls":
                return float(n) * dfeat * dfeat + float(dfeat) ** 3
            return float(n) * dfeat
        cap = hp["capacity"]
        if self.learner == "ald":
            return float(n) * cap * cap
        return float(n) * cap


def _resolve_policy(policy, bank: int) -> Optional[SlotPolicy]:
    if policy is None:
        return None
    if isinstance(policy, SlotPolicy):
        if policy.slots != bank:
            raise ValueError(
                f"policy manages {policy.slots} slots but bank={bank}"
            )
        return policy
    if isinstance(policy, str):
        return SlotPolicy(bank, scorer=policy)
    if isinstance(policy, dict):
        return SlotPolicy(bank, **policy)
    raise TypeError(f"policy must be None, str, dict or SlotPolicy; got {policy!r}")


def make_server(
    learner: str = "klms",
    *,
    feature_map: Optional[FeatureLike] = None,
    bank: int = 8,
    chunk: int = 16,
    mode: str = "auto",
    adaptive: bool = False,
    precision: Optional[str] = None,
    publish_every: int = 1,
    age_watermark: Optional[float] = None,
    size_watermark: Optional[int] = None,
    clock: Callable[[], float] = time.monotonic,
    log_capacity: Optional[int] = None,
    rebuild_mode: str = "scan",
    policy=None,
    auto_resize: bool = False,
    metrics: Optional[MetricsRegistry] = None,
    input_dim: Optional[int] = None,
    state=None,
    trace: Union[None, bool, int, _obtrace.Tracer] = None,
    probe: Union[bool, dict, None] = None,
    recovery: Union[None, bool, dict, RecoveryPolicy] = None,
    wal: Union[None, str, DurableLog] = None,
    **hp,
) -> Server:
    """The serving facade: one :class:`Server` for any learner family.

    Args:
      learner: ``"klms"`` / ``"nklms"`` / ``"qklms"`` / ``"krls"`` /
        ``"ald"``.
      feature_map: any :mod:`repro.features` family (required for the
        theta families; the dictionary baselines take ``input_dim=``).
      bank: number of bank slots B.
      chunk / mode / adaptive: micro-batch queue knobs (serve/queue.py).
      precision / publish_every / age_watermark / size_watermark / clock:
        snapshot-tier knobs (serve/snapshot.py).
      log_capacity: per-tenant replay-log ring size. Policy mode defaults
        it to 256; without a policy, None disables the lifecycle log (the
        old snapshot-server contract).
      rebuild_mode: replay schedule for readmissions ("scan" / "blocked"
        / "sequential"; dictionary learners always replay sequentially).
      policy: None (tenant == slot), a scorer name ("lru" / "lfu" /
        "cost"), a ``SlotPolicy`` kwargs dict, or a ready instance.
      auto_resize: apply the policy's pow2 ``suggest_size`` after submits.
      metrics: a shared :class:`MetricsRegistry` (fresh one by default).
      state: initial bank state (fresh init by default).
      trace: request tracing — ``True`` for a fresh default
        :class:`~repro.obs.trace.Tracer`, an int for a fresh tracer with
        that ring capacity, or a ready (possibly shared) instance. The
        tracer lands on ``server.tracer`` (export via ``to_chrome_trace``
        / ``to_jsonl``); every public server method activates it, so
        queue / snapshot / kernel-dispatch spans nest under the request.
      probe: in-jit numerics probes — ``True`` fuses the
        :func:`~repro.obs.probes.stats_tap` into the flush program and
        monitors it against :data:`~repro.obs.probes.DEFAULT_THRESHOLDS`;
        a dict overrides thresholds (``{"name": value}`` or
        ``{"name": ("min"|"max", value)}``). Monitor lands on
        ``server.probe``; export via :meth:`Server.observability`.
      recovery: probe-triggered self-healing (serve/recovery.py) —
        ``True`` for a default :class:`~repro.serve.recovery
        .RecoveryPolicy`, a kwargs dict (``max_retries`` /
        ``backoff_base`` / ``backoff_factor`` / ``clock`` /
        ``reference_clock``), or a ready instance. Implies ``probe=True``
        when probes were not requested; the policy lands on
        ``server.recovery``.
      wal: durable write-ahead log — a JSONL path or a ready
        :class:`~repro.serve.recovery.DurableLog`. Every ``submit`` is
        appended before it is queued; ``Server.checkpoint`` +
        ``restore_checkpoint`` replay the post-checkpoint suffix so a
        killed server restores bitwise (README "Robustness").
      **hp: family hyperparameters — ``mu``, ``eps``, ``lam``, ``beta``,
        ``sigma``, ``quant_eps``, ``nu``, ``capacity`` (scalars; the
        per-tenant (B,) sweeps stay on the core tiers).
    """
    _check_learner(learner)
    h = _resolve_hp(hp)
    lrn = build_learner(learner, feature_map, input_dim, **hp)
    queue = make_queue(
        learner, feature_map, bank, chunk=chunk, mode=mode,
        adaptive=adaptive, state=state, input_dim=input_dim, **hp,
    )

    def rebuild_fn(bank_state, slot, xs, ys):
        row = lrn.rebuild(
            jnp.asarray(xs), jnp.asarray(ys), mode=rebuild_mode
        )
        return set_tenant_row(bank_state, slot, row)

    if learner == "krls":
        def evict_fn(bank_state, slot):
            return evict_tenant(bank_state, slot, lam=h["lam"])
    elif learner in ("qklms", "ald"):
        def evict_fn(bank_state, slot):
            fresh = jax.tree.map(
                jnp.zeros_like, tenant_row(bank_state, slot)
            )
            return set_tenant_row(bank_state, slot, fresh)
    else:
        evict_fn = evict_tenant

    rec: Optional[RecoveryPolicy] = None
    if recovery:
        if isinstance(recovery, RecoveryPolicy):
            rec = recovery
        elif isinstance(recovery, dict):
            rec = RecoveryPolicy(**recovery)
        else:
            rec = RecoveryPolicy()
        if not probe:
            probe = True
    if wal is None or isinstance(wal, DurableLog):
        wal_log = wal
    else:
        wal_log = DurableLog(wal)
    pol = _resolve_policy(policy, bank)
    inner = SnapshotServer(
        queue,
        feature_map,
        publish_every,
        mode=mode,
        precision=precision,
        age_watermark=age_watermark,
        size_watermark=size_watermark,
        clock=clock,
        log_capacity=None if pol is not None else log_capacity,
        evict_fn=evict_fn,
        rebuild_fn=rebuild_fn,
    )
    if isinstance(trace, _obtrace.Tracer):
        tracer = trace
    elif isinstance(trace, bool) or trace is None:
        tracer = _obtrace.Tracer() if trace else None
    else:
        tracer = _obtrace.Tracer(capacity=int(trace))
    return Server(
        inner,
        learner=learner,
        lrn=lrn,
        feature_map=feature_map,
        hp=h,
        policy=pol,
        metrics=metrics,
        log_capacity=log_capacity,
        auto_resize=auto_resize,
        tracer=tracer,
        probe=probe,
        recovery=rec,
        wal=wal_log,
    )
