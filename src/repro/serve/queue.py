"""Micro-batching serve queue: ragged tenant arrivals -> masked (B, T) chunks.

The lockstep servers in serve/bank_loop.py assume every tenant delivers
exactly one observation per tick — real traffic doesn't. This module is the
ROADMAP "async serving over the filter bank" item, landed as the natural
consumer of the chunked kernels: arrivals are enqueued per tenant at any
rate, and each ``flush()`` coalesces up to ``chunk`` pending observations
per tenant into ONE time-blocked kernel launch — a ``(B, T, d)`` batch with
a per-(tenant, tick) validity mask covering both idle tenants (empty rows)
and short backlogs (partial rows).

Why this is safe: the paper's fixed-size state means a tenant that missed k
flushes needs no catch-up bookkeeping — its next chunk simply replays its
queued samples in arrival order, and masked slots are proven no-ops
(tests/test_chunked.py). Per-flush cost is one dispatch for the whole bank
instead of ``sum(backlog)`` per-tick dispatches; the dispatch-amortization
math is in README "Throughput model".

The queue is deliberately host-side and synchronous (submit/flush), so it
composes with any outer event loop; it owns the jitted chunk step and the
bank state, and always launches the same ``(B, chunk)`` shape so the step
traces exactly once.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Callable, Optional, Union

import jax
import numpy as np

from repro.core.bank import set_tenant_row
from repro.features.base import FeatureLike
from repro.obs import telemetry as _telemetry
from repro.obs import trace as _trace

__all__ = [
    "MicroBatchQueue",
    "make_chunked_bank_server",
    "make_chunked_krls_bank_server",
    "klms_micro_batch_queue",
    "krls_micro_batch_queue",
]


def make_chunked_bank_server(
    rff: FeatureLike,
    mu: Union[float, jax.Array],
    mode: str = "auto",
) -> Callable:
    """Deprecated: use ``repro.serve.make_chunk_step("klms", ...)``."""
    from repro.serve import api

    api._deprecated(
        "make_chunked_bank_server", 'make_chunk_step("klms", ...)'
    )
    return api.make_chunk_step("klms", rff, mode=mode, mu=mu)


def make_chunked_krls_bank_server(
    rff: FeatureLike,
    beta: Union[float, jax.Array] = 0.9995,
    mode: str = "auto",
) -> Callable:
    """Deprecated: use ``repro.serve.make_chunk_step("krls", ...)``."""
    from repro.serve import api

    api._deprecated(
        "make_chunked_krls_bank_server", 'make_chunk_step("krls", ...)'
    )
    return api.make_chunk_step("krls", rff, mode=mode, beta=beta)


class MicroBatchQueue:
    """Coalesce ragged per-tenant arrivals into masked ``(B, T)`` chunks.

    Args:
      chunk_step: jitted ``(state, xs, ys, mask) -> (state, StepOut)`` —
        from :func:`make_chunked_bank_server` or the KRLS variant.
      state: initial bank state (owned and advanced by the queue).
      input_dim: ``d`` of the feature space.
      chunk: T — the time-block cap every flush launches (constant shape
        by default, so the server compiles exactly once).
      adaptive: pick each flush's T from backlog depth (next power of two,
        capped at ``chunk``) instead of the global constant — the
        per-tenant chunk-size-adaptation ROADMAP item. At most
        log2(chunk)+1 shapes ever trace; ragged-stream equivalence is
        unchanged (tested). ``arrivals`` tracks cumulative per-tenant
        arrival counts as the adaptation/monitoring signal.
      stale_after: watchdog age bound in ``clock`` units. Under adaptive
        flush a quiet bank can strand a minority tenant's ticks
        indefinitely (nothing ever trips the size watermark); with a
        bound set, :meth:`has_stale` reports any arrival pending longer
        than this and :meth:`maybe_flush` force-flushes it, counting
        ``queue.stale_flush``. ``None`` (default) disables the watchdog.
      clock: injectable time source for the watchdog (tests pin it).

    ``submit`` enqueues one observation; ``flush`` processes up to T queued
    observations per tenant in arrival order and returns
    ``{tenant: [(prediction, prior_error), ...]}`` for what it consumed;
    ``drain`` flushes until every backlog is empty.
    """

    def __init__(self, chunk_step: Callable, state, input_dim: int,
                 chunk: int = 16, adaptive: bool = False,
                 stale_after: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic):
        self._base_chunk_step = chunk_step
        self._chunk_step = chunk_step
        self.state = state
        self.input_dim = input_dim
        self.chunk = chunk
        self.adaptive = adaptive
        self.stale_after = stale_after
        self._clock = clock
        lead = jax.tree.leaves(state)[0]
        self.num_tenants = int(lead.shape[0])
        # Buffers take the bank's working precision (f64 banks under x64
        # must not round-trip observations through f32).
        self._dtype = np.dtype(lead.dtype)
        self._pending = [deque() for _ in range(self.num_tenants)]
        # Watchdog ledger: when each slot's *oldest* pending arrival was
        # enqueued (None = empty backlog). Set on the 0 -> 1 transition,
        # kept across partial flushes (the residual head is older than any
        # new arrival), cleared when the backlog empties.
        self._first_pending_at: list[Optional[float]] = (
            [None] * self.num_tenants
        )
        self.arrivals = [0] * self.num_tenants
        self.ticks_served = 0
        self.flushes = 0
        self.stale_flushes = 0
        self.last_probe: Optional[dict] = None

    def attach_probe(self, probe_fn: Callable) -> None:
        """Fuse a numerics tap into the flush program (obs/probes.py).

        ``probe_fn(state) -> {name: 0-d array}`` is composed *after* the
        chunk step inside one jitted program, so flush stays a single
        launch — the tap's reductions ride along instead of re-reading the
        state from HBM in a second dispatch. The latest readout lands in
        ``last_probe`` as device scalars; hosts (the serve facade's probe
        monitor) materialize it only at flush boundaries. Pass ``None``
        to detach and restore the bare step.
        """
        if probe_fn is None:
            self._chunk_step = self._base_chunk_step
            self.last_probe = None
            return
        base = self._base_chunk_step

        @jax.jit
        def probed_step(state, xs, ys, mask):
            state, out = base(state, xs, ys, mask)
            return state, out, probe_fn(state)

        self._chunk_step = probed_step

    def submit(self, tenant: int, x, y) -> None:
        """Enqueue one ``(x, y)`` observation for ``tenant``."""
        self.arrivals[tenant] += 1
        if not self._pending[tenant] and self.stale_after is not None:
            self._first_pending_at[tenant] = self._clock()
        self._pending[tenant].append(
            (np.asarray(x, self._dtype), self._dtype.type(y)),
        )

    def backlog(self) -> list[int]:
        """Pending observation count per tenant."""
        return [len(q) for q in self._pending]

    def drop_pending(self, tenant: int) -> int:
        """Discard ``tenant``'s queued observations (eviction hook).

        Returns the number dropped. Other tenants' backlogs, the bank
        state, and the served/arrival counters are untouched — a dropped
        observation was never folded into the state, so no counter lies.
        """
        dropped = len(self._pending[tenant])
        self._pending[tenant].clear()
        self._first_pending_at[tenant] = None
        return dropped

    def move_slot(self, src: int, dst: int) -> None:
        """Transfer one slot's pending backlog and arrival counter to
        another slot (bank-compaction hook — the state row itself moves
        via ``tenant_row``/``set_tenant_row``). ``src`` is left empty."""
        if src == dst:
            return
        self._pending[dst] = self._pending[src]
        self._pending[src] = deque()
        self._first_pending_at[dst] = self._first_pending_at[src]
        self._first_pending_at[src] = None
        self.arrivals[dst] = self.arrivals[src]
        self.arrivals[src] = 0

    def adopt(self, state) -> None:
        """Adopt a resized bank state (``core.bank.resize_bank``):
        re-derive B and grow/shrink the per-slot buffers with it. Slots
        being truncated must have empty backlogs — compact first."""
        new_b = int(jax.tree.leaves(state)[0].shape[0])
        if any(len(q) for q in self._pending[new_b:]):
            raise RuntimeError(
                "resize would drop pending observations; compact or drain"
            )
        self.state = state
        if new_b >= self.num_tenants:
            grow = new_b - self.num_tenants
            self._pending.extend(deque() for _ in range(grow))
            self._first_pending_at.extend([None] * grow)
            self.arrivals.extend([0] * grow)
        else:
            self._pending = self._pending[:new_b]
            self._first_pending_at = self._first_pending_at[:new_b]
            self.arrivals = self.arrivals[:new_b]
        self.num_tenants = new_b

    def replace_tenant(self, tenant: int, row) -> None:
        """Overwrite one tenant's slot of the live bank state in place
        (readmission hook — ``row`` is a single-tenant state pytree, e.g.
        from ``core.bank.rebuild_tenant``'s replay or ``tenant_row``)."""
        self.state = set_tenant_row(self.state, tenant, row)

    def _flush_chunk(self) -> int:
        """T for the next flush. Fixed mode always launches ``chunk`` (one
        trace ever); adaptive mode sizes T to the deepest backlog, rounded
        up to a power of two so only log2(chunk) shapes ever trace — a
        mostly-idle bank pays for a (B, 1) launch instead of a (B, chunk)
        one, and bursty tenants still get the full chunk."""
        if not self.adaptive:
            return self.chunk
        depth = max(1, max(self.backlog(), default=1))
        return min(self.chunk, 1 << (depth - 1).bit_length())

    def has_stale(self) -> bool:
        """True when some arrival has been pending past ``stale_after``.

        Always False with the watchdog disabled (``stale_after=None``).
        """
        if self.stale_after is None:
            return False
        now = self._clock()
        return any(
            t0 is not None and now - t0 >= self.stale_after
            for t0 in self._first_pending_at
        )

    def maybe_flush(self) -> dict[int, list[tuple[float, float]]]:
        """Watchdog flush: launch only if some backlog has gone stale.

        The stranded-tenant guard for adaptive/externally-paced flushing —
        a minority tenant whose arrivals never trip the caller's size
        watermark still gets trained within ``stale_after``. Each forced
        launch increments ``stale_flushes`` and the ``queue.stale_flush``
        counter.
        """
        if not self.has_stale():
            return {}
        self.stale_flushes += 1
        _telemetry.registry().counter("queue.stale_flush").inc()
        return self.flush()

    def flush(self) -> dict[int, list[tuple[float, float]]]:
        """One chunked launch over up to T queued ticks per tenant."""
        bsz, tlen, d = self.num_tenants, self._flush_chunk(), self.input_dim
        if not any(self._pending):
            _trace.instant("queue.flush.skip", tenants=bsz)
            return {}
        with _trace.span(
            "queue.flush", tenants=bsz, chunk=tlen, adaptive=self.adaptive
        ) as sp:
            xs = np.zeros((bsz, tlen, d), self._dtype)
            ys = np.zeros((bsz, tlen), self._dtype)
            mask = np.zeros((bsz, tlen), self._dtype)
            counts = []
            for b, q in enumerate(self._pending):
                take = min(len(q), tlen)
                for t in range(take):
                    x, y = q.popleft()
                    xs[b, t] = x
                    ys[b, t] = y
                    mask[b, t] = 1.0
                counts.append(take)
                if not q:
                    self._first_pending_at[b] = None
                # Residual backlog keeps its stamp: the surviving head is
                # at least as old as the arrival that set it.
            result = self._chunk_step(self.state, xs, ys, mask)
            if len(result) == 3:
                self.state, out, self.last_probe = result
            else:
                self.state, out = result
            preds = np.asarray(out.prediction)
            errs = np.asarray(out.error)
            self.flushes += 1
            served = sum(counts)
            self.ticks_served += served
            # One compiled-program execution per flush: the live launch
            # count for the serve path (the in-program kernel dispatches
            # were counted at trace time under kernel.traces).
            _telemetry.registry().counter(
                "dispatch.launches", site="queue.flush"
            ).inc()
            if sp is not None:
                sp.attrs["ticks"] = served
                sp.attrs["active"] = sum(1 for c in counts if c)
                sp.attrs["residual_backlog"] = sum(self.backlog())
            return {
                b: [(float(preds[b, t]), float(errs[b, t])) for t in range(c)]
                for b, c in enumerate(counts)
                if c
            }

    def drain(self) -> dict[int, list[tuple[float, float]]]:
        """Flush until all backlogs are empty; merge per-tenant results."""
        merged: dict[int, list[tuple[float, float]]] = {}
        while any(self._pending):
            for b, res in self.flush().items():
                merged.setdefault(b, []).extend(res)
        return merged


def klms_micro_batch_queue(
    rff: FeatureLike,
    num_tenants: int,
    mu: Union[float, jax.Array] = 0.5,
    chunk: int = 16,
    mode: str = "auto",
    state=None,
    adaptive: bool = False,
) -> MicroBatchQueue:
    """Deprecated: use ``repro.serve.make_queue("klms", ...)``."""
    from repro.serve import api

    api._deprecated(
        "klms_micro_batch_queue", 'make_queue("klms", ...)'
    )
    return api.make_queue(
        "klms", rff, num_tenants, chunk=chunk, mode=mode, state=state,
        adaptive=adaptive, mu=mu,
    )


def krls_micro_batch_queue(
    rff: FeatureLike,
    num_tenants: int,
    lam: Union[float, jax.Array] = 1e-4,
    beta: Union[float, jax.Array] = 0.9995,
    chunk: int = 16,
    mode: str = "auto",
    state=None,
    adaptive: bool = False,
) -> MicroBatchQueue:
    """Deprecated: use ``repro.serve.make_queue("krls", ...)``."""
    from repro.serve import api

    api._deprecated(
        "krls_micro_batch_queue", 'make_queue("krls", ...)'
    )
    return api.make_queue(
        "krls", rff, num_tenants, chunk=chunk, mode=mode, state=state,
        adaptive=adaptive, lam=lam, beta=beta,
    )
