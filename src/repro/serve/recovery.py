"""Self-healing tier: probe-triggered repair and durable checkpoint/restore.

PR 9's :class:`~repro.obs.probes.ProbeMonitor` *detects* degraded state
(non-finite leaves, theta blow-up, KRLS P asymmetry / conditioning drift)
but nothing in the stack acts on an event, and every byte of state is
process memory — one crash loses every tenant. This module closes the
loop; obs/faults.py manufactures the failures that drive it in tests:

* :class:`RecoveryPolicy` — subscribes to the monitor, localizes each
  degradation to a bank slot (per-slot :func:`~repro.obs.probes.slot_stats`
  on the rare event path; the hot path keeps the one fused bank-global
  tap), **quarantines** the offending tenant (reads served from its last
  healthy snapshot row, arrivals logged-not-trained — the cold-tenant
  path reused), then repairs by escalation::

      re-symmetrize P  ->  scan-rebuild from ReplayLog  ->  O(1) reset

  with bounded retries, per-tenant exponential backoff, and every action
  traced/counted through ``obs``. The paper's fixed-size state is what
  makes the ladder cheap: a tenant is O(D) to snapshot, O(log T) to
  rebuild (PR 6 scan replay), O(1) to reset. A rebuild is attempted only
  when the replay log is complete *and* finite — an overflowed ring
  (windowed history) or a corrupted entry falls straight through to
  reset rather than silently installing partial state as full history.
* :class:`DurableLog` — a JSONL write-ahead log of raw arrivals.
  Observations round-trip bitwise (f32 -> double -> shortest-repr JSON
  -> f32); a torn final line (crash mid-append) is tolerated and
  ignored on read.
* :func:`save_checkpoint` / :func:`restore_checkpoint` — crash-consistent
  serialization of a full ``serve.api.Server`` (bank state, queue
  counters and pending buffers, replica version, slot policy, replay
  logs, feature-map params) as atomically-renamed ``gen_N.ckpt`` files
  with generation numbers. Restore validates the config and the feature
  map bitwise, installs every leaf, and replays the WAL suffix recorded
  after the checkpoint through the ordinary submit path — so
  kill-at-arbitrary-flush -> restore matches the never-killed control
  bitwise on all state leaves (chaos-tested).

Quarantine and in-flight recovery episodes are deliberately NOT
checkpointed: a restore re-detects any surviving degradation from the
probes on the next flush, which is simpler and strictly safer than
trusting persisted judgments about state that the crash may have changed.
"""
from __future__ import annotations

import json
import os
import pickle
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.core.bank import resymmetrize_tenant, tenant_row
from repro.obs import telemetry as _telemetry
from repro.obs import trace as _trace
from repro.obs.probes import slot_stats

__all__ = [
    "CKPT_FORMAT",
    "DurableLog",
    "RecoveryPolicy",
    "save_checkpoint",
    "restore_checkpoint",
]

CKPT_FORMAT = "repro.server.ckpt/v1"

# The escalation ladder, cheapest repair first. ``resymmetrize`` is only
# offered to true RLS banks (a (B, D, D) P next to a theta row); every
# other reason starts at ``rebuild``.
LADDER = ("resymmetrize", "rebuild", "reset")

# Probes that are global to the server rather than attributable to one
# bank slot. ``clock_skew`` has a dedicated repair; the rest are operator
# signals, recorded but not acted on.
_GLOBAL_PROBES = ("clock_skew", "staleness_ticks", "bf16_read_error")


def _is_rls_bank(state) -> bool:
    return hasattr(state, "pmat") and not hasattr(state, "centers")


# ---------------------------------------------------------------------------
# Write-ahead log
# ---------------------------------------------------------------------------


class DurableLog:
    """Append-only JSONL write-ahead log of raw ``(tenant, x, y)`` arrivals.

    One line per arrival: ``{"s": seq, "t": tenant, "x": [...], "y": y}``.
    Floats are written as Python doubles — an f32 observation widens
    exactly and JSON's shortest-round-trip repr preserves the double, so
    the f32 read back after restore is bitwise the one submitted (NaN/Inf
    use the JSON-extension literals Python emits and accepts). Sequence
    numbers are contiguous from 0 and resume past the highest complete
    line of an existing file; a torn final line (crash mid-append) is
    detected by its parse failure and ignored.

    ``fsync=True`` makes every append durable against power loss at the
    cost of one fsync per arrival; the default flushes to the OS only
    (durable against process crash, the failure mode the chaos tests
    exercise).
    """

    def __init__(self, path, *, fsync: bool = False):
        self.path = str(path)
        self.fsync = fsync
        self.seq = -1
        if os.path.exists(self.path):
            # Scan for the resume seq and truncate a torn tail — appending
            # after an unterminated fragment would weld the next record
            # onto it and corrupt that one too.
            good_end = 0
            with open(self.path, "rb") as fh:
                for line in fh:
                    try:
                        rec = json.loads(line)
                    except json.JSONDecodeError:
                        break
                    self.seq = rec["s"]
                    good_end += len(line)
            if good_end < os.path.getsize(self.path):
                with open(self.path, "ab") as fh:
                    fh.truncate(good_end)
        self._fh = open(self.path, "a", encoding="utf-8")

    def _scan(self):
        with open(self.path, "r", encoding="utf-8") as fh:
            for line in fh:
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    break  # torn tail: everything after is garbage
                yield rec

    def append(self, tenant: int, x, y) -> int:
        """Durably record one arrival; returns its sequence number."""
        self.seq += 1
        rec = {
            "s": self.seq,
            "t": int(tenant),
            "x": [float(v) for v in np.asarray(x).ravel()],
            "y": float(y),
        }
        self._fh.write(json.dumps(rec) + "\n")
        self._fh.flush()
        if self.fsync:
            os.fsync(self._fh.fileno())
        _telemetry.record_wal_append()
        return self.seq

    def entries(self, after: int = -1) -> list[dict]:
        """All complete records with ``seq > after``, in order."""
        return [rec for rec in self._scan() if rec["s"] > after]

    def close(self) -> None:
        self._fh.close()


# ---------------------------------------------------------------------------
# Probe-triggered recovery
# ---------------------------------------------------------------------------


@dataclass
class _Episode:
    """One tenant's open quarantine: where it is on the ladder and what
    to serve its reads from while it heals."""

    tenant: int
    slot: int
    reason: str
    rung: int
    attempts: int = 0
    backoff_until: float = 0.0
    gave_up: bool = False
    healthy_row: Any = None
    actions: list = field(default_factory=list)


class RecoveryPolicy:
    """Quarantine-and-repair controller bound to one ``serve.api.Server``.

    The server's probe monitor pushes degradation events into this policy
    (``ProbeMonitor.subscribe``); the subscriber only *records* them, and
    the server calls :meth:`process` right after each probe fold — so all
    state mutation happens at a well-defined point outside the monitor
    update, never mid-probe.

    ``process`` localizes each event to a slot via the per-slot
    diagnostics, maps the slot to its tenant, captures the tenant's last
    healthy replica row, and quarantines it: the server serves the
    tenant's reads from the captured row and appends (but never trains)
    its arrivals until the episode closes. Repair walks :data:`LADDER`
    from a reason-dependent starting rung; each attempt is verified
    against the monitor's own thresholds on the repaired slot, a failed
    attempt escalates one rung and backs off exponentially
    (``backoff_base * backoff_factor ** attempts``), and after
    ``max_retries`` failed attempts the policy gives up — the slot is
    parked on a fresh row so the bank-global probes stop firing, and the
    tenant stays quarantined for the operator (healthy reads still
    served).

    ``reference_clock`` (optional) arms the clock-skew probe: the policy
    captures the offset between the snapshot tier's clock and the
    reference at bind time, the server reports ``|drift|`` from that
    baseline as the ``clock_skew`` stat, and the ``reclock`` repair
    re-bases the snapshot clock on the reference and re-stamps pending
    arrival times. Metrics: ``recovery.quarantines`` / ``recovery.repairs
    {action=...}`` / ``recovery.releases`` / ``recovery.gave_up``.
    """

    def __init__(
        self,
        *,
        max_retries: int = 3,
        backoff_base: float = 0.0,
        backoff_factor: float = 2.0,
        clock: Callable[[], float] = time.monotonic,
        reference_clock: Optional[Callable[[], float]] = None,
    ):
        self.max_retries = max_retries
        self.backoff_base = backoff_base
        self.backoff_factor = backoff_factor
        self.clock = clock
        self.reference_clock = reference_clock
        self._server = None
        self._pending_events: list = []
        self._episodes: dict[int, _Episode] = {}
        self.history: list[dict] = []
        self._last_healthy = None  # (replica state, resident map)
        self._clock_baseline = 0.0

    # -- wiring --------------------------------------------------------------

    def bind(self, server) -> "RecoveryPolicy":
        """Attach to a server (subscribes to its probe monitor)."""
        if server.probe is None:
            raise ValueError("recovery needs the server's probe monitor")
        if self._server is not None:
            raise RuntimeError("recovery policy already bound")
        self._server = server
        server.probe.subscribe(self._pending_events.append)
        if self.reference_clock is not None:
            self._clock_baseline = (
                server.snapshot_server._clock() - self.reference_clock()
            )
        return self

    @property
    def quarantined(self) -> frozenset[int]:
        """Tenants currently quarantined (reads from healthy snapshot)."""
        return frozenset(self._episodes)

    def healthy_row(self, tenant: int):
        """The quarantined tenant's captured healthy state row (or None —
        the tenant was never seen healthy; reads then serve cold)."""
        ep = self._episodes.get(tenant)
        return ep.healthy_row if ep is not None else None

    def measure_skew(self) -> float:
        """|drift| of the snapshot clock from the reference baseline."""
        inner = self._server.snapshot_server
        return abs(
            (inner._clock() - self.reference_clock()) - self._clock_baseline
        )

    # -- the control loop ----------------------------------------------------

    def process(self) -> None:
        """Act on events recorded since the last call (the server invokes
        this right after every probe fold)."""
        if self._server is None:
            return
        # Drain in place: the monitor's subscriber is this exact list's
        # bound ``append`` — rebinding would orphan it.
        events = list(self._pending_events)
        self._pending_events.clear()
        if not events:
            if not self._episodes:
                # Event-free fold: remember this replica as last-healthy.
                # A poisoned flush can never land here — publish precedes
                # the probe fold, so its events arrive in the same call.
                self._last_healthy = (
                    self._server.snapshot.state,
                    dict(self._server.resident),
                )
            self._repair_due()
            return
        for ev in events:
            self._ingest(ev)
        self._repair_due()

    def _ingest(self, ev) -> None:
        if ev.probe == "clock_skew":
            self._repair_clock(ev)
            return
        if ev.probe in _GLOBAL_PROBES:
            self.history.append(
                {"event": ev.probe, "action": "ignored", "tick": ev.tick}
            )
            return
        slots = self._diagnose(ev.probe, ev.threshold)
        by_slot = {s: t for t, s in self._server.resident.items()}
        for slot in slots:
            tenant = by_slot.get(slot)
            if tenant is None:
                continue  # unowned slot: nothing to quarantine
            ep = self._episodes.get(tenant)
            if ep is not None:
                # Re-degrade inside an open episode: the failed attempt
                # already escalated the rung; just note the recurrence.
                ep.actions.append({"event": ev.probe, "redegrade": True})
                continue
            self._quarantine(tenant, slot, ev.probe)

    def _diagnose(self, probe: str, threshold: float) -> list[int]:
        """Slots breaching ``probe``'s threshold, per-slot."""
        server = self._server
        if probe == "ticks_lag":
            lags = server._slot_lags()
            return [s for s, lag in enumerate(lags) if lag > threshold]
        stats = {
            k: np.asarray(v)
            for k, v in slot_stats(server.queue.state).items()
        }
        if probe == "finite":
            mask = stats["finite"] < 1.0
        elif probe == "theta.norm_max":
            if "theta.norm" not in stats:
                return []
            mask = stats["theta.norm"] > threshold
        elif probe in ("pmat.asym_rel", "pmat.cond_proxy"):
            if probe not in stats:
                return []
            mask = stats[probe] > threshold
        else:
            return []
        return [int(s) for s in np.nonzero(mask)[0]]

    def _quarantine(self, tenant: int, slot: int, reason: str) -> None:
        server = self._server
        healthy_row = None
        if self._last_healthy is not None:
            hstate, hres = self._last_healthy
            hslot = hres.get(tenant)
            if hslot is not None:
                healthy_row = tenant_row(hstate, hslot)
        start = (
            0
            if reason.startswith("pmat.")
            and _is_rls_bank(server.queue.state)
            else 1
        )
        ep = _Episode(
            tenant=tenant,
            slot=slot,
            reason=reason,
            rung=start,
            healthy_row=healthy_row,
        )
        self._episodes[tenant] = ep
        server.metrics.counter("recovery.quarantines").inc()
        _trace.instant(
            "recovery.quarantine", tenant=tenant, slot=slot, reason=reason,
            start_action=LADDER[start],
        )

    def _repair_due(self) -> None:
        now = self.clock()
        for tenant in list(self._episodes):
            ep = self._episodes.get(tenant)
            if ep is None or ep.gave_up or ep.backoff_until > now:
                continue
            self._attempt(ep)

    # -- repairs -------------------------------------------------------------

    def _attempt(self, ep: _Episode) -> None:
        server = self._server
        action = LADDER[ep.rung]
        if action == "rebuild":
            ok, why = self._check_log(ep)
            if not ok:
                # Pre-check failure is not a repair attempt: fall straight
                # through to reset, no retry budget spent, no backoff.
                ep.actions.append(
                    {"action": "rebuild", "outcome": "fallthrough",
                     "reason": why}
                )
                self.history.append(
                    {"tenant": ep.tenant, "action": "rebuild",
                     "outcome": "fallthrough", "reason": why}
                )
                ep.rung = len(LADDER) - 1
                action = LADDER[ep.rung]
        with _trace.span(
            "recovery.repair", tenant=ep.tenant, slot=ep.slot, action=action,
            attempt=ep.attempts,
        ):
            if action == "resymmetrize":
                inner = server.snapshot_server
                inner.queue.state = resymmetrize_tenant(
                    inner.queue.state, ep.slot
                )
                inner.publish()
            elif action == "rebuild":
                self._rebuild(ep)
            else:
                server.reset_tenant(ep.tenant)
        server.metrics.counter("recovery.repairs", action=action).inc()
        verified = self._verify(ep)
        ep.actions.append({"action": action, "verified": verified})
        self.history.append(
            {"tenant": ep.tenant, "action": action, "verified": verified}
        )
        if verified:
            del self._episodes[ep.tenant]
            server.metrics.counter("recovery.releases").inc()
            _trace.instant(
                "recovery.release", tenant=ep.tenant, action=action,
                attempts=ep.attempts,
            )
            return
        ep.attempts += 1
        if ep.attempts > self.max_retries:
            # Park a fresh row so the bank-global probes stop firing, but
            # keep the tenant quarantined: healthy reads keep flowing and
            # the operator decides what happens next.
            server.reset_tenant(ep.tenant)
            ep.gave_up = True
            ep.backoff_until = float("inf")
            server.metrics.counter("recovery.gave_up").inc()
            _trace.instant(
                "recovery.gave_up", tenant=ep.tenant, attempts=ep.attempts
            )
            return
        ep.rung = min(ep.rung + 1, len(LADDER) - 1)
        ep.backoff_until = self.clock() + self.backoff_base * (
            self.backoff_factor ** ep.attempts
        )

    def _check_log(self, ep: _Episode) -> tuple[bool, str]:
        """A rebuild may only install state that is the tenant's *full*,
        *finite* history — anything else resets instead."""
        log = self._server.log
        if log is None or log.size(ep.tenant) == 0:
            return False, "no_log"
        if not log.complete(ep.tenant):
            return False, "incomplete_log"
        xs, ys = log.arrays(ep.tenant)
        if not (np.isfinite(xs).all() and np.isfinite(ys).all()):
            return False, "corrupt_log"
        return True, ""

    def _rebuild(self, ep: _Episode) -> None:
        server = self._server
        inner = server.snapshot_server
        if server.policy is None:
            # Slot-keyed log: evict + readmit IS the rebuild, bitwise the
            # operator path a control server would take.
            inner.evict(ep.tenant)
            replayed = inner.readmit(ep.tenant)
        else:
            # Pending arrivals are already in the id-keyed log; drop the
            # slot's backlog and replay the whole history into the slot.
            inner.queue.drop_pending(ep.slot)
            inner._arrival_times[ep.slot].clear()
            xs, ys = server.log.arrays(ep.tenant)
            inner.queue.state = inner._rebuild_fn(
                inner.queue.state, ep.slot, xs, ys
            )
            inner.publish()
            replayed = len(ys)
        server._expected[ep.slot] = replayed

    def _verify(self, ep: _Episode) -> bool:
        """Check the repaired slot against the monitor's own thresholds."""
        server = self._server
        thr = server.probe.thresholds
        stats = {
            k: np.asarray(v)
            for k, v in slot_stats(server.queue.state).items()
        }
        s = ep.slot
        if float(stats["finite"][s]) < 1.0:
            return False
        for skey, tkey in (
            ("theta.norm", "theta.norm_max"),
            ("pmat.asym_rel", "pmat.asym_rel"),
            ("pmat.cond_proxy", "pmat.cond_proxy"),
        ):
            if skey in stats and tkey in thr:
                direction, bound = thr[tkey]
                value = float(stats[skey][s])
                if direction == "max" and value > bound:
                    return False
        if "ticks_lag" in thr:
            _, bound = thr["ticks_lag"]
            if server._slot_lags()[s] > bound:
                return False
        return True

    def _repair_clock(self, ev) -> None:
        server = self._server
        inner = server.snapshot_server
        if self.reference_clock is None:  # pragma: no cover - stat is only
            return  # reported when a reference exists
        with _trace.span("recovery.repair", action="reclock"):
            ref, base = self.reference_clock, self._clock_baseline
            inner._clock = lambda: ref() + base
            now = inner._clock()
            # The skewed clock stamped bogus arrival ages; re-stamp the
            # surviving positions in the trusted domain.
            inner._arrival_times = [
                deque((pos, now) for pos, _ in times)
                for times in inner._arrival_times
            ]
        server.metrics.counter("recovery.repairs", action="reclock").inc()
        self.history.append(
            {"event": "clock_skew", "action": "reclock", "skew": ev.value}
        )


# ---------------------------------------------------------------------------
# Durable checkpoint / restore
# ---------------------------------------------------------------------------


def _ckpt_name(gen: int) -> str:
    return f"gen_{gen:08d}.ckpt"


def _list_generations(directory: str) -> list[tuple[int, str]]:
    """(generation, path) pairs present in ``directory``, newest first."""
    out = []
    for name in os.listdir(directory):
        if name.startswith("gen_") and name.endswith(".ckpt"):
            try:
                gen = int(name[4:-5])
            except ValueError:
                continue
            out.append((gen, os.path.join(directory, name)))
    return sorted(out, reverse=True)


def _atomic_write(path: str, data: bytes) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as fh:
        fh.write(data)
        fh.flush()
        os.fsync(fh.fileno())
    os.replace(tmp, path)


def _log_payload(log) -> Optional[dict]:
    if log is None:
        return None
    return {
        "capacity": log.capacity,
        "tenants": {
            int(t): {
                "entries": [
                    (np.asarray(x), float(y)) for x, y in log._buf[t]
                ],
                "appended": log._appended.get(t, 0),
            }
            for t in log.tenants()
        },
    }


def _load_log(log, payload: Optional[dict]) -> None:
    log.clear()
    if payload is None:
        return
    for t, rec in payload["tenants"].items():
        t = int(t)
        for x, y in rec["entries"]:
            log.append(t, x, y)
        # Restore the overflow counter so complete() keeps telling the
        # truth about windowed history across a restore.
        log._appended[t] = int(rec["appended"])


def save_checkpoint(server, directory, *, keep: int = 3) -> str:
    """Write one crash-consistent checkpoint generation of ``server``.

    The payload covers everything a fresh identically-configured server
    needs to resume bitwise: bank-state leaves, queue counters and
    pending buffers, replica version/tick, the slot policy's decision
    state, replay logs (with their ring-overflow counters), the evicted
    set, the facade's expected-ticks ledger, and the WAL high-water mark.
    The feature map's leaves ride along for bitwise validation at restore
    (the map itself is rebuilt by the caller's ``make_server``).

    Write protocol: serialize -> temp file -> fsync -> ``os.replace`` to
    ``gen_N.ckpt`` (atomic on POSIX), then update the ``LATEST`` marker
    the same way. A crash at any point leaves either the old or the new
    generation fully intact, never a torn file; generations beyond
    ``keep`` are garbage-collected oldest-first. Returns the path.
    """
    os.makedirs(directory, exist_ok=True)
    gens = _list_generations(directory)
    gen = gens[0][0] + 1 if gens else 0
    inner = server.snapshot_server
    queue = inner.queue
    with _trace.span("recovery.checkpoint", generation=gen):
        state_leaves, _ = jax.tree_util.tree_flatten(queue.state)
        fm_leaves = (
            [np.asarray(a) for a in jax.tree_util.tree_leaves(
                server.feature_map)]
            if server.feature_map is not None
            else None
        )
        payload = {
            "format": CKPT_FORMAT,
            "generation": gen,
            "config": {
                "learner": server.learner,
                "slots": server.slots,
                "chunk": queue.chunk,
                "hp": dict(server._hp),
            },
            "state": [np.asarray(a) for a in jax.device_get(state_leaves)],
            "feature_map": fm_leaves,
            "queue": {
                "ticks_served": queue.ticks_served,
                "flushes": queue.flushes,
                "arrivals": list(queue.arrivals),
                "pending": [
                    [(np.asarray(x), float(y)) for x, y in q]
                    for q in queue._pending
                ],
            },
            "snapshot": {
                "version": inner._snapshot.version,
                "tick": inner._snapshot.tick,
            },
            "policy": (
                server.policy.state_dict()
                if server.policy is not None
                else None
            ),
            "log": _log_payload(server.log),
            "inner_log": (
                _log_payload(inner.log)
                if server.policy is not None
                else None
            ),
            "evicted": sorted(inner._evicted),
            "expected": dict(server._expected),
            "wal_seq": server.wal.seq if server.wal is not None else -1,
        }
        data = pickle.dumps(payload)
        path = os.path.join(directory, _ckpt_name(gen))
        _atomic_write(path, data)
        _atomic_write(
            os.path.join(directory, "LATEST"),
            (_ckpt_name(gen) + "\n").encode(),
        )
        for old_gen, old_path in gens[max(keep - 1, 0):]:
            os.remove(old_path)
    _telemetry.record_checkpoint(bytes_written=len(data))
    return path


def _validate(payload: dict, server) -> None:
    if payload.get("format") != CKPT_FORMAT:
        raise ValueError(
            f"unrecognized checkpoint format {payload.get('format')!r}"
        )
    cfg = payload["config"]
    mine = {
        "learner": server.learner,
        "slots": server.slots,
        "chunk": server.queue.chunk,
        "hp": dict(server._hp),
    }
    for key in ("learner", "chunk", "hp"):
        if cfg[key] != mine[key]:
            raise ValueError(
                f"checkpoint config mismatch on {key!r}: "
                f"saved {cfg[key]!r} != server {mine[key]!r}"
            )
    if payload["feature_map"] is not None:
        fresh = [
            np.asarray(a)
            for a in jax.tree_util.tree_leaves(server.feature_map)
        ]
        saved = payload["feature_map"]
        if len(fresh) != len(saved) or not all(
            a.shape == b.shape and np.array_equal(a, b, equal_nan=True)
            for a, b in zip(fresh, saved)
        ):
            raise ValueError(
                "checkpoint feature map does not match the server's "
                "(same seed/family required for a bitwise restore)"
            )


def _install(payload: dict, server) -> None:
    import jax.numpy as jnp

    from repro.serve.snapshot import StateSnapshot

    inner = server.snapshot_server
    queue = inner.queue
    if server.slots != payload["config"]["slots"]:
        # Bank geometry is restored by resize (policy mode); without a
        # policy the caller must build the server at the saved size.
        if server.policy is None:
            raise ValueError(
                f"checkpoint has {payload['config']['slots']} slots, "
                f"server has {server.slots}; rebuild at the saved size"
            )
        server.resize(payload["config"]["slots"])
    _, treedef = jax.tree_util.tree_flatten(queue.state)
    state = jax.tree_util.tree_unflatten(
        treedef, [jnp.asarray(a) for a in payload["state"]]
    )
    queue.state = state
    q = payload["queue"]
    queue.ticks_served = int(q["ticks_served"])
    queue.flushes = int(q["flushes"])
    queue.arrivals = [int(a) for a in q["arrivals"]]
    queue._pending = [
        deque((np.asarray(x, queue._dtype), queue._dtype.type(y))
              for x, y in pend)
        for pend in q["pending"]
    ]
    queue._first_pending_at = [None] * queue.num_tenants
    now = inner._clock()
    inner._arrival_times = [
        deque((i, now) for i in range(len(pend)))
        for pend in queue._pending
    ]
    inner._snapshot = StateSnapshot(
        state=state,
        version=int(payload["snapshot"]["version"]),
        tick=int(payload["snapshot"]["tick"]),
    )
    inner._evicted = set(payload["evicted"])
    if server.policy is not None:
        server.policy.load_state(payload["policy"])
        _load_log(server.log, payload["log"])
        if inner.log is not None:
            _load_log(inner.log, payload["inner_log"])
    elif inner.log is not None:
        _load_log(inner.log, payload["log"])
    server._expected = {
        int(k): int(v) for k, v in payload["expected"].items()
    }


def restore_checkpoint(
    server,
    directory,
    *,
    replay_wal: bool = True,
) -> dict:
    """Restore ``server`` (freshly built with the same ``make_server``
    arguments) from the newest loadable generation in ``directory``.

    Generations are tried newest-first: a torn or unpicklable file (crash
    mid-GC, disk corruption) is skipped with a trace mark and the next
    one is tried — only when *no* generation loads does restore raise.
    Config and feature map are validated before anything is mutated.

    When the server has a WAL and ``replay_wal`` is True, every WAL entry
    recorded after the checkpoint's high-water mark is re-fed through the
    ordinary ``submit`` path (appends suspended so replay is idempotent
    across repeated restores). Deterministic flush cadence then makes the
    restored server bitwise the never-killed control. Returns a summary
    dict (generation, replayed count).
    """
    gens = _list_generations(directory)
    if not gens:
        raise FileNotFoundError(f"no checkpoints in {directory!r}")
    payload = None
    errors = []
    for gen, path in gens:
        try:
            with open(path, "rb") as fh:
                candidate = pickle.load(fh)
            _validate(candidate, server)
        except (ValueError, TypeError, EOFError, pickle.UnpicklingError,
                KeyError) as exc:
            if isinstance(exc, ValueError) and "mismatch" in str(exc):
                raise  # config mismatch is a caller bug, not corruption
            errors.append((path, repr(exc)))
            _trace.instant("recovery.restore_skip", path=path, error=repr(exc))
            continue
        payload = candidate
        break
    if payload is None:
        raise ValueError(
            f"no loadable checkpoint in {directory!r}: {errors}"
        )
    with _trace.span(
        "recovery.restore", generation=payload["generation"]
    ):
        _install(payload, server)
        replayed = 0
        if replay_wal and server.wal is not None:
            suffix = server.wal.entries(after=int(payload["wal_seq"]))
            server._wal_suspended = True
            try:
                for rec in suffix:
                    server.submit(rec["t"], rec["x"], rec["y"])
                    _telemetry.record_wal_append(replayed=True)
                    replayed += 1
            finally:
                server._wal_suspended = False
    _telemetry.record_checkpoint(bytes_written=0, restore=True)
    return {
        "generation": payload["generation"],
        "replayed": replayed,
        "wal_seq": int(payload["wal_seq"]),
    }
