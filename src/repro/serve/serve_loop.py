"""Batched autoregressive serving loop.

``generate`` runs N decode steps under one jit (lax.scan over steps), with
greedy or temperature sampling; the decode state is whatever the arch
provides (KV cache / MLA latent cache / RFF fixed state / SSM / LRU) — all
thread through ``models.decode_step`` identically.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import decode_state_init, decode_step

__all__ = ["generate", "prefill_tokens"]


def prefill_tokens(params: dict, cfg: ModelConfig, state, tokens: jax.Array):
    """Feed a prompt token-by-token through the decode path (state warmup).

    tokens: (B, P). Returns (state, last_logits). Token-by-token prefill is
    the simple/robust form; chunked prefill is the production fast path for
    full-attention archs (see make_prefill_step).
    """

    def body(st, tok):
        logits, st = decode_step(params, cfg, st, tok)
        return st, logits

    state, logits = jax.lax.scan(body, state, tokens.T)
    return state, logits[-1]


@functools.partial(
    jax.jit, static_argnames=("cfg", "steps", "max_len", "temperature")
)
def generate(
    params: dict,
    cfg: ModelConfig,
    prompt: jax.Array,
    *,
    steps: int = 32,
    max_len: int = 1024,
    temperature: float = 0.0,
    rng: Optional[jax.Array] = None,
) -> jax.Array:
    """Generate ``steps`` tokens after ``prompt`` (B, P). Returns (B, steps)."""
    b = prompt.shape[0]
    state = decode_state_init(cfg, b, max_len=max_len)
    state, logits = prefill_tokens(params, cfg, state, prompt)
    rng = rng if rng is not None else jax.random.PRNGKey(0)

    def sample(logits, key):
        if temperature <= 0.0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature, -1).astype(jnp.int32)

    def body(carry, key):
        st, lg = carry
        tok = sample(lg, key)
        lg2, st2 = decode_step(params, cfg, st, tok)
        return (st2, lg2), tok

    keys = jax.random.split(rng, steps)
    (_, _), toks = jax.lax.scan(body, (state, logits), keys)
    return toks.T  # (B, steps)
