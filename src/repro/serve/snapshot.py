"""Snapshot-decoupled serving: train on the live state, read a frozen replica.

The micro-batch queue (serve/queue.py) made the *write* path cheap, but its
bank state is the only copy — a predict issued mid-flush would race the
trainer. This module splits the two: the queue keeps mutating its live
state, and a :class:`SnapshotServer` publishes an immutable read replica
every ``publish_every`` update-ticks. Reads (the fused query-block kernel,
``ops.rff_bank_predict``) only ever see a published replica, so

* **no torn reads** — a replica is one pytree reference captured at a flush
  boundary; JAX arrays are immutable and CPython reference assignment is
  atomic, so a concurrent reader sees the whole old replica or the whole
  new one, never a mix of flushes (property-tested);
* **bounded staleness** — publication happens at the first flush boundary
  where at least ``publish_every`` ticks have accumulated, so between
  flushes a reader lags the live state by fewer than ``publish_every``
  ticks (plus whatever the current flush is consuming);
* **deferred write-flush is safe** — because reads never touch the live
  state, flushes can wait for the age/size watermarks (the ROADMAP
  background-flush item) without blocking or corrupting the read path.

Everything stays host-side and synchronous like the queue itself (submit /
flush / predict compose with any outer event loop; watermarks are checked
on ``submit`` and via ``maybe_flush`` rather than from a thread).

Tenant lifecycle rides on the same machinery: when a ``log_capacity`` is
set, every arrival is also appended to a per-tenant :class:`ReplayLog`
ring buffer, so ``evict(tenant)`` can release the slot as one O(1) row
write (``core.bank.evict_tenant``) and ``readmit(tenant)`` reconstructs
the state by replaying the log through the parallel-in-time engine
(``core.bank.rebuild_tenant`` over core/scan.py) instead of keeping a cold
copy of the ``(D,)``/``(D, D)`` state around. While evicted, a tenant's
arrivals are *logged but not trained* — readmission folds them in.
"""
from __future__ import annotations

import time
from collections import deque
from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bank import bank_predict_block, evict_tenant, rebuild_tenant
from repro.features.base import FeatureLike
from repro.obs import trace as _trace
from repro.serve.queue import MicroBatchQueue

__all__ = [
    "ReplayLog",
    "StateSnapshot",
    "SnapshotServer",
    "predict_row",
    "klms_snapshot_server",
    "krls_snapshot_server",
]


class ReplayLog:
    """Per-tenant ring buffer of raw ``(x, y)`` arrivals for slot rebuilds.

    Capacity bounds host memory: a tenant whose history outgrows the ring
    loses its oldest ticks, and a rebuild from the log then reconstructs
    the *windowed* state (fresh init + last ``capacity`` ticks) rather than
    the full-history one — ``complete(tenant)`` tells callers which
    contract they are getting. Buffers are plain numpy (host-side, like the
    queue's pending deques); ``arrays`` materializes one ``(n, d)``/``(n,)``
    pair for the replay engine.

    Keys are arbitrary ints materialized on first append — slot indices on
    the snapshot tier, unbounded tenant *ids* on the policy tier
    (serve/api.py), which is why storage is a dict rather than a
    slot-indexed list. ``num_tenants`` is accepted for signature
    compatibility but no longer pre-sizes anything.
    """

    def __init__(self, num_tenants: int = 0, capacity: int = 256,
                 dtype=np.float32):
        if capacity < 1:
            raise ValueError("log capacity must be >= 1")
        self.capacity = capacity
        self._dtype = np.dtype(dtype)
        self._buf: dict[int, deque] = {}
        self._appended: dict[int, int] = {}

    def append(self, tenant: int, x, y) -> None:
        """Record one arrival (evicts the oldest entry when full)."""
        buf = self._buf.get(tenant)
        if buf is None:
            buf = self._buf[tenant] = deque(maxlen=self.capacity)
        self._appended[tenant] = self._appended.get(tenant, 0) + 1
        buf.append((np.asarray(x, self._dtype), self._dtype.type(y)))

    def tenants(self) -> list[int]:
        """Keys with any recorded history."""
        return list(self._buf)

    def size(self, tenant: int) -> int:
        """Entries currently held for ``tenant`` (<= capacity)."""
        buf = self._buf.get(tenant)
        return len(buf) if buf is not None else 0

    def dropped(self, tenant: int) -> int:
        """Arrivals lost to ring overflow since the last ``clear``."""
        return self._appended.get(tenant, 0) - self.size(tenant)

    def complete(self, tenant: int) -> bool:
        """True iff the log still holds the tenant's entire history, i.e.
        a rebuild from it matches the never-evicted state."""
        return self.dropped(tenant) == 0

    def arrays(self, tenant: int) -> tuple[np.ndarray, np.ndarray]:
        """Materialize the log as ``xs (n, d)``, ``ys (n,)`` in arrival
        order (empty logs yield ``(0, 0)``/``(0,)`` shapes)."""
        buf = self._buf.get(tenant)
        if not buf:
            return (
                np.zeros((0, 0), self._dtype),
                np.zeros((0,), self._dtype),
            )
        xs = np.stack([x for x, _ in buf])
        ys = np.asarray([y for _, y in buf], self._dtype)
        return xs, ys

    def move(self, src: int, dst: int) -> None:
        """Re-key one tenant's history (bank-compaction hook): ``dst``
        takes over ``src``'s buffer and overflow counter, including when
        ``src`` has none (``dst`` is then cleared)."""
        self.clear(dst)
        buf = self._buf.pop(src, None)
        if buf is not None:
            self._buf[dst] = buf
            self._appended[dst] = self._appended.pop(src)

    def clear(self, tenant: Optional[int] = None) -> None:
        """Forget one tenant's history — including the overflow counter,
        so the tenant reads ``complete()`` again — or every tenant's when
        None."""
        if tenant is None:
            self._buf.clear()
            self._appended.clear()
        else:
            self._buf.pop(tenant, None)
            self._appended.pop(tenant, None)


class StateSnapshot(NamedTuple):
    """A published read replica of the bank state.

    Attributes:
      state: the bank-state pytree at a flush boundary (immutable arrays).
      version: publish counter (0 = the initial, untrained state).
      tick: cumulative update-ticks folded into this replica — readers can
        bound their own staleness as ``queue.ticks_served - tick``.
    """

    state: Any
    version: int
    tick: int


class _Row(NamedTuple):
    """One-tenant view of a bank state (theta row) for the predict path."""

    theta: jax.Array


@partial(jax.jit, static_argnames=("mode", "precision"))
def _predict_block_jit(state, xq, fm, mode, precision):
    return bank_predict_block(state, xq, fm, mode=mode, precision=precision)


def predict_row(theta, xq, rff, *, mode: str = "auto",
                precision: Optional[str] = None) -> jax.Array:
    """Fused predict from one bare ``(D,)`` theta row: ``xq (Q, d)`` ->
    ``(Q,)``. The quarantine read path (serve/recovery.py) serves a
    tenant's captured last-healthy row through this without needing the
    row to live in any bank."""
    return _predict_block_jit(
        _Row(theta=jnp.asarray(theta)[None]),
        jnp.asarray(xq)[None],
        rff,
        mode=mode,
        precision=precision,
    )[0]


class SnapshotServer:
    """Double-buffered serving front end over a :class:`MicroBatchQueue`.

    Args:
      queue: the micro-batch queue owning the live (train) state.
      rff: the bank's shared feature map (any repro.features family).
      publish_every: publish a fresh read replica at the first flush
        boundary where this many update-ticks have accumulated since the
        last publish. 1 = publish after every flush (freshest reads);
        larger values amortize replica turnover at bounded staleness.
      mode / precision: read-path knobs forwarded to the fused predict
        kernel (``precision="bf16"`` = mixed-precision featurize, contract
        in kernels/ref.py). Training precision is untouched.
      age_watermark: seconds — flush when the oldest queued observation has
        waited this long (checked on ``submit`` / ``maybe_flush``).
      size_watermark: observations — flush when any tenant's backlog
        reaches this depth.
      clock: injectable monotonic clock (tests pass a fake).
      log_capacity: entries per tenant in the :class:`ReplayLog` ring
        buffer. None (default) disables logging — ``evict`` still works
        (the slot parks a fresh row) but ``readmit`` can only restart the
        tenant cold.
      evict_fn: ``(state, tenant) -> state`` releasing one slot; defaults
        to ``core.bank.evict_tenant`` with its family-inferred fresh row.
      rebuild_fn: ``(state, tenant, xs, ys) -> state`` replaying a log
        into one slot; the factories wire ``core.bank.rebuild_tenant``
        closures carrying the family hyperparameters and replay mode.
    """

    def __init__(
        self,
        queue: MicroBatchQueue,
        rff: FeatureLike,
        publish_every: int = 1,
        *,
        mode: str = "auto",
        precision: Optional[str] = None,
        age_watermark: Optional[float] = None,
        size_watermark: Optional[int] = None,
        clock: Callable[[], float] = time.monotonic,
        log_capacity: Optional[int] = None,
        evict_fn: Optional[Callable] = None,
        rebuild_fn: Optional[Callable] = None,
    ):
        if publish_every < 1:
            raise ValueError("publish_every must be >= 1")
        self.queue = queue
        self.rff = rff
        self.publish_every = publish_every
        self.mode = mode
        self.precision = precision
        self.age_watermark = age_watermark
        self.size_watermark = size_watermark
        self._clock = clock
        self._arrival_times = [deque() for _ in range(queue.num_tenants)]
        self._snapshot = StateSnapshot(state=queue.state, version=0, tick=0)
        self.log = (
            ReplayLog(queue.num_tenants, log_capacity, queue._dtype)
            if log_capacity is not None
            else None
        )
        self._evict_fn = evict_fn if evict_fn is not None else evict_tenant
        self._rebuild_fn = rebuild_fn
        self._evicted: set[int] = set()

    # -- read path ---------------------------------------------------------

    @property
    def snapshot(self) -> StateSnapshot:
        """The current read replica (grab once per request for consistency)."""
        return self._snapshot

    @property
    def staleness(self) -> int:
        """Update-ticks the read replica lags the live (train) state."""
        return self.queue.ticks_served - self._snapshot.tick

    def predict(self, tenant: int, xs) -> jax.Array:
        """Serve queries for one tenant from the frozen replica.

        ``xs`` is ``(d,)`` for one query (returns a scalar) or ``(Q, d)``
        for a query block (returns ``(Q,)``) — either way the fused
        predict-only path, never the live training state.
        """
        snap = self._snapshot  # one grab = one consistent replica
        xq = jnp.asarray(xs)
        single = xq.ndim == 1
        if single:
            xq = xq[None]
        row = _Row(theta=snap.state.theta[tenant][None])
        pred = _predict_block_jit(
            row, xq[None], self.rff, mode=self.mode, precision=self.precision
        )[0]
        return pred[0] if single else pred

    def predict_block(self, xq) -> jax.Array:
        """Serve a ``(B, Q, d)`` query block for the whole bank in one
        launch from the frozen replica -> ``(B, Q)``."""
        snap = self._snapshot
        return _predict_block_jit(
            snap.state,
            jnp.asarray(xq),
            self.rff,
            mode=self.mode,
            precision=self.precision,
        )

    # -- write path --------------------------------------------------------

    def submit(self, tenant: int, x, y) -> None:
        """Enqueue one observation; flush if a watermark trips.

        Every arrival is also appended to the replay log (when one is
        configured). An *evicted* tenant's arrivals stop here: they are
        logged but never queued, so the released slot stays untrained
        until :meth:`readmit` folds the whole log back in.
        """
        if self.log is not None:
            self.log.append(tenant, x, y)
        if tenant in self._evicted:
            return
        # Tag the arrival with its backlog position, not just a count:
        # observations submitted straight to the queue (legal; they opt out
        # of the age watermark) occupy positions too, and a flush must
        # consume exactly the timestamps of the positions it served.
        pos = len(self.queue._pending[tenant])
        self._arrival_times[tenant].append((pos, self._clock()))
        self.queue.submit(tenant, x, y)
        self.maybe_flush()

    def _consume_arrival_times(self, tenant: int, served: int) -> None:
        times = self._arrival_times[tenant]
        while times and times[0][0] < served:
            times.popleft()
        self._arrival_times[tenant] = deque(
            (pos - served, t) for pos, t in times
        )

    def maybe_flush(self) -> dict:
        """Background-flush hook: flush when the age or size watermark
        trips. Call from an outer event loop for purely time-driven
        flushes; ``submit`` calls it after every arrival."""
        backlog = self.queue.backlog()
        if not any(backlog):
            return {}
        if self.size_watermark is not None and max(backlog) >= self.size_watermark:
            return self.flush()
        if self.age_watermark is not None:
            oldest = min(
                (t[0][1] for t in self._arrival_times if t), default=None
            )
            if oldest is not None and (
                self._clock() - oldest >= self.age_watermark
            ):
                return self.flush()
        return {}

    def flush(self) -> dict:
        """One chunked train launch on the live state; publish when due.

        Due-ness is derived from :attr:`staleness` (replica tick vs
        ``queue.ticks_served``), not a local counter — so ticks applied by
        calling ``queue.flush()`` directly still count toward the bound.
        """
        res = self.queue.flush()
        for tenant, served in res.items():
            self._consume_arrival_times(tenant, len(served))
        if self.staleness >= self.publish_every:
            self.publish()
        return res

    def drain(self) -> dict:
        """Flush until every backlog is empty; merge per-tenant results."""
        merged: dict = {}
        while any(self.queue.backlog()):
            for tenant, served in self.flush().items():
                merged.setdefault(tenant, []).extend(served)
        return merged

    # -- tenant lifecycle --------------------------------------------------

    @property
    def evicted(self) -> frozenset[int]:
        """Tenants whose slots are currently released."""
        return frozenset(self._evicted)

    def evict(self, tenant: int) -> int:
        """Release one bank slot: drop the tenant's pending observations,
        park a fresh row in the slot (O(1) — ``core.bank.evict_tenant``),
        and publish so readers stop seeing the old weights immediately.

        The replay log is *kept*: it is the only record :meth:`readmit`
        rebuilds from. Returns the number of pending observations dropped
        (they were logged on submit, so readmission still replays them).
        """
        dropped = self.queue.drop_pending(tenant)
        self._arrival_times[tenant].clear()
        self.queue.state = self._evict_fn(self.queue.state, tenant)
        self._evicted.add(tenant)
        self.publish()
        return dropped

    def readmit(self, tenant: int, mode: Optional[str] = None) -> int:
        """Re-admit an evicted tenant by replaying its log into the slot.

        The rebuild runs through ``rebuild_fn`` (the factories wire
        ``core.bank.rebuild_tenant`` -> core/scan.py, so the slot is
        reconstructed in O(log T) scan depth rather than T sequential
        ticks), then a fresh replica is published. With no log or an empty
        one the tenant simply restarts cold on the parked fresh row.
        Returns the number of ticks replayed. If the ring overflowed
        (``log.complete(tenant)`` is False) the rebuilt state is the
        windowed one — fresh init + the last ``capacity`` ticks.
        """
        if tenant not in self._evicted:
            raise ValueError(f"tenant {tenant} is not evicted")
        replayed = 0
        if self.log is not None and self.log.size(tenant):
            if self._rebuild_fn is None:
                raise ValueError(
                    "readmit with a non-empty log needs a rebuild_fn "
                    "(use the klms/krls factories or pass one)"
                )
            xs, ys = self.log.arrays(tenant)
            with _trace.span(
                "snapshot.rebuild",
                tenant=tenant,
                ticks=len(ys),
                complete=self.log.complete(tenant),
            ):
                self.queue.state = self._rebuild_fn(
                    self.queue.state, tenant, xs, ys
                )
            replayed = len(ys)
        self._evicted.discard(tenant)
        self.publish()
        return replayed

    def release_slot(self, slot: int) -> int:
        """Release one bank slot *without* entering the evicted set (the
        policy tier's eviction hook): drop its pending observations, clear
        its arrival times, park a fresh row, publish. Unlike
        :meth:`evict`, subsequent submits to this slot train normally —
        the policy immediately reassigns the slot to another tenant, and
        per-tenant history lives in the policy tier's id-keyed log, not
        the slot-keyed one. Returns the dropped pending count."""
        dropped = self.queue.drop_pending(slot)
        self._arrival_times[slot].clear()
        self.queue.state = self._evict_fn(self.queue.state, slot)
        self._evicted.discard(slot)
        self.publish()
        return dropped

    def reset_tenant(self, tenant: int) -> int:
        """Reset ONE tenant to a fresh slot: drop its pending
        observations, clear its arrival times AND its replay-log history
        — including the ring-overflow counter, so the slot reads
        ``log.complete()`` again instead of inheriting the previous
        occupant's stale truncation flag — park a fresh row, and leave
        the evicted set. Returns the dropped pending count."""
        dropped = self.queue.drop_pending(tenant)
        self._arrival_times[tenant].clear()
        if self.log is not None:
            self.log.clear(tenant)
        self.queue.state = self._evict_fn(self.queue.state, tenant)
        self._evicted.discard(tenant)
        self.publish()
        return dropped

    def move_slot(self, src: int, dst: int) -> None:
        """Transfer slot-local bookkeeping from ``src`` to ``dst`` (bank
        compaction; the caller moves the state row itself): pending
        backlog, arrival counters and timestamps, evicted membership, and
        slot-keyed log history. ``src`` is left empty."""
        self.queue.move_slot(src, dst)
        self._arrival_times[dst] = self._arrival_times[src]
        self._arrival_times[src] = deque()
        if src in self._evicted:
            self._evicted.discard(src)
            self._evicted.add(dst)
        else:
            self._evicted.discard(dst)
        if self.log is not None:
            self.log.move(src, dst)

    def adopt_resized(self, state) -> None:
        """Adopt a grown/shrunk bank state (the policy tier's resize):
        resize the queue's per-slot buffers and the arrival-time ledger,
        drop lifecycle bookkeeping for truncated slots (which must be
        empty — compact first), and publish."""
        old = self.queue.num_tenants
        self.queue.adopt(state)
        new = self.queue.num_tenants
        if new >= old:
            self._arrival_times.extend(
                deque() for _ in range(new - old)
            )
        else:
            self._arrival_times = self._arrival_times[:new]
            self._evicted = {s for s in self._evicted if s < new}
            if self.log is not None:
                for t in self.log.tenants():
                    if t >= new:
                        self.log.clear(t)
        self.publish()

    def reset(self, state) -> None:
        """Restart both buffers on a fresh bank state (tenant-eviction /
        benchmark hook): the live queue state AND the published replica
        drop to version 0, and per-tenant lifecycle bookkeeping (arrival
        counters, replay logs with their truncation flags, the evicted
        set) is wiped with them. Pending observations must be drained
        first."""
        if any(self.queue.backlog()):
            raise RuntimeError("reset with pending observations; drain first")
        self.queue.state = state
        self.queue.ticks_served = 0
        self.queue.arrivals = [0] * self.queue.num_tenants
        self._arrival_times = [deque() for _ in range(self.queue.num_tenants)]
        self._snapshot = StateSnapshot(state=state, version=0, tick=0)
        if self.log is not None:
            self.log.clear()
        self._evicted.clear()

    def publish(self) -> StateSnapshot:
        """Swap the read replica to the live state (atomic: one reference
        assignment of an immutable pytree)."""
        self._snapshot = StateSnapshot(
            state=self.queue.state,
            version=self._snapshot.version + 1,
            tick=self.queue.ticks_served,
        )
        _trace.instant(
            "snapshot.publish",
            version=self._snapshot.version,
            tick=self._snapshot.tick,
        )
        return self._snapshot


def klms_snapshot_server(
    rff: FeatureLike,
    num_tenants: int,
    mu: Union[float, jax.Array] = 0.5,
    chunk: int = 16,
    publish_every: int = 1,
    mode: str = "auto",
    precision: Optional[str] = None,
    adaptive: bool = False,
    rebuild_mode: str = "scan",
    **kw,
) -> SnapshotServer:
    """Deprecated: use ``repro.serve.make_server(learner="klms", ...)``.

    Thin shim preserving the historical contract (returns the bare
    :class:`SnapshotServer`; per-tenant ``(B,)`` ``mu`` honored)."""
    from repro.serve import api

    api._deprecated(
        "klms_snapshot_server", 'make_server(learner="klms", ...)'
    )
    queue = api.make_queue(
        "klms", rff, num_tenants, chunk=chunk, mode=mode,
        adaptive=adaptive, mu=mu,
    )
    kw.setdefault(
        "rebuild_fn",
        lambda state, tenant, xs, ys: rebuild_tenant(
            state, tenant, rff, xs, ys, mu=mu, mode=rebuild_mode
        ),
    )
    return SnapshotServer(
        queue, rff, publish_every, mode=mode, precision=precision, **kw
    )


def krls_snapshot_server(
    rff: FeatureLike,
    num_tenants: int,
    lam: Union[float, jax.Array] = 1e-4,
    beta: Union[float, jax.Array] = 0.9995,
    chunk: int = 16,
    publish_every: int = 1,
    mode: str = "auto",
    precision: Optional[str] = None,
    adaptive: bool = False,
    rebuild_mode: str = "scan",
    **kw,
) -> SnapshotServer:
    """Deprecated: use ``repro.serve.make_server(learner="krls", ...)``.

    Thin shim preserving the historical contract (returns the bare
    :class:`SnapshotServer`; per-tenant ``(B,)`` ``lam``/``beta``
    honored)."""
    from repro.serve import api

    api._deprecated(
        "krls_snapshot_server", 'make_server(learner="krls", ...)'
    )
    queue = api.make_queue(
        "krls", rff, num_tenants, chunk=chunk, mode=mode,
        adaptive=adaptive, lam=lam, beta=beta,
    )
    kw.setdefault(
        "evict_fn",
        lambda state, tenant: evict_tenant(state, tenant, lam=lam),
    )
    kw.setdefault(
        "rebuild_fn",
        lambda state, tenant, xs, ys: rebuild_tenant(
            state, tenant, rff, xs, ys, lam=lam, beta=beta, mode=rebuild_mode
        ),
    )
    return SnapshotServer(
        queue, rff, publish_every, mode=mode, precision=precision, **kw
    )
