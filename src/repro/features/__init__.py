"""Pluggable feature-map subsystem: one contract, many families.

The learners never see a family — they see :class:`FeatureMap` (a pytree
param struct + pure ``featurize`` + metadata), and the fused Pallas paths
see its canonical affine-trig form ``(W, b, per-feature scale)`` via
:func:`as_trig`. Families:

====== ============= ======================= ==============================
family construction  variance                notes
====== ============= ======================= ==============================
rff    Monte-Carlo   O(1/sqrt(D)) MC         the paper's map (eq. (3)–(5))
orf    Monte-Carlo   strictly below rff      QR blocks + chi row norms
qmc    deterministic (log m)^d / m           Halton -> inverse Gaussian CDF
gq     deterministic spectral (quadrature)   Gauss-Hermite nodes + weights
taylor deterministic truncation (degree)     polynomial; no trig form
====== ============= ======================= ==============================

``make_feature_map`` is the registry entry point; deterministic families
ignore the key argument (zero seed variance, bitwise reproducible).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.features.base import (
    FeatureLike,
    FeatureMap,
    TrigFeatures,
    as_trig,
    as_trig_or_none,
    feature_dtype,
    feature_weights,
    featurize,
    input_dim,
    num_features,
    trig_features,
    trig_from_rff,
    trig_map,
    trig_weights,
    uniform_trig_scale,
)
from repro.features.deterministic import (
    TaylorParams,
    gq_map,
    taylor_features,
    taylor_map,
    taylor_num_features,
    taylor_weights,
)
from repro.features.qmc import halton_sequence, inverse_normal_cdf, qmc_map
from repro.features.random import orf_map, rff_map

__all__ = [
    "FAMILIES",
    "FeatureLike",
    "FeatureMap",
    "TrigFeatures",
    "TaylorParams",
    "as_trig",
    "as_trig_or_none",
    "feature_dtype",
    "feature_weights",
    "featurize",
    "gq_map",
    "halton_sequence",
    "input_dim",
    "inverse_normal_cdf",
    "make_feature_map",
    "num_features",
    "orf_map",
    "qmc_map",
    "rff_map",
    "taylor_features",
    "taylor_map",
    "taylor_num_features",
    "taylor_weights",
    "trig_features",
    "trig_from_rff",
    "trig_map",
    "trig_weights",
    "uniform_trig_scale",
]

FAMILIES = ("rff", "orf", "qmc", "gq", "taylor")


def make_feature_map(
    family: str,
    input_dim: int,
    num_features: int,
    sigma: float,
    key: Optional[jax.Array] = None,
    dtype: jnp.dtype = jnp.float32,
    degree: Optional[int] = None,
) -> FeatureMap:
    """Build a feature map by family name (the scenario/config axis).

    Monte-Carlo families (``rff`` / ``orf``) require ``key``; deterministic
    families ignore it. ``taylor`` takes ``degree`` (default: the largest
    degree whose feature count fits ``num_features``) and its actual
    ``num_features`` is ``C(d + degree, degree)``.
    """
    if family in ("rff", "orf"):
        if key is None:
            raise ValueError(f"family {family!r} is Monte-Carlo: pass key=")
        builder = rff_map if family == "rff" else orf_map
        return builder(key, input_dim, num_features, sigma, dtype)
    if family == "qmc":
        return qmc_map(input_dim, num_features, sigma, dtype)
    if family == "gq":
        return gq_map(input_dim, num_features, sigma, dtype)
    if family == "taylor":
        if degree is None:
            degree = 1
            while taylor_num_features(input_dim, degree + 1) <= num_features:
                degree += 1
        return taylor_map(input_dim, degree, sigma, dtype)
    raise ValueError(f"unknown feature family {family!r}; know {FAMILIES}")
