"""Quasi-Monte-Carlo trig features: Halton points through the Gaussian
inverse CDF.

Instead of iid spectral draws, take the first ``m = D/2`` points of the
d-dimensional Halton sequence (radical-inverse in the first d primes — a
low-discrepancy cover of the unit cube), map them through the inverse
Gaussian CDF to get spectral nodes ``omega_j ~ N(0, I/sigma^2)`` "as evenly
as possible", and use deterministic cos/sin pairs:

    kappa(x - y) ~= (1/m) sum_j [cos(w_j.x) cos(w_j.y) + sin(w_j.x) sin(w_j.y)]
                 =  z(x)^T z(y),
    z(x) = sqrt(1/m) [cos(Omega^T x); sin(Omega^T x)].

QMC integration error decays ~ (log m)^d / m vs the Monte-Carlo 1/sqrt(m),
so the same D buys a lower kernel-approximation error — and the map is
fully deterministic (zero seed variance; any PRNG key is ignored).

Canonical form: ``sin(t) = cos(t - pi/2)`` turns the pair into affine-trig
``(W, b, scale)`` with ``W = [Omega, Omega]``, ``b = [0, -pi/2]`` blocks and
the uniform ``sqrt(2/D) = sqrt(1/m)`` scale — the Pallas kernels run it
unchanged.
"""

from __future__ import annotations

import math

import numpy as np

import jax.numpy as jnp

from repro.features.base import FeatureMap, TrigFeatures, trig_map

__all__ = ["qmc_map", "halton_sequence", "inverse_normal_cdf"]


def _first_primes(n: int) -> list[int]:
    """The first ``n`` primes (Halton bases), by incremental trial division."""
    primes: list[int] = []
    candidate = 2
    while len(primes) < n:
        if all(candidate % p for p in primes):
            primes.append(candidate)
        candidate += 1
    return primes


def _radical_inverse(indices: np.ndarray, base: int) -> np.ndarray:
    """van der Corput radical inverse of ``indices`` in ``base`` (float64)."""
    idx = indices.astype(np.int64).copy()
    result = np.zeros(idx.shape, np.float64)
    frac = 1.0 / base
    while np.any(idx > 0):
        result += (idx % base) * frac
        idx //= base
        frac /= base
    return result


def halton_sequence(num_points: int, dims: int, skip: int = 1) -> np.ndarray:
    """First ``num_points`` d-dimensional Halton points, ``(n, dims)`` in
    (0, 1). ``skip=1`` drops the degenerate index-0 point (all zeros, which
    the inverse CDF would map to -inf)."""
    indices = np.arange(skip, skip + num_points)
    cols = [_radical_inverse(indices, p) for p in _first_primes(dims)]
    return np.stack(cols, axis=-1)


# Acklam's rational approximation of the inverse normal CDF (peak relative
# error ~1.15e-9), refined with one Halley step against math.erf — all in
# host-side f64 so the spectral nodes are independent of the jax x64 flag.
_A = (-3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
      1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00)
_B = (-5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
      6.680131188771972e+01, -1.328068155288572e+01)
_C = (-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
      -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00)
_D = (7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
      3.754408661907416e+00)


def inverse_normal_cdf(p: np.ndarray) -> np.ndarray:
    """Vectorized standard-normal quantile function on (0, 1), f64 numpy."""
    p = np.asarray(p, np.float64)
    q = np.where(p < 0.5, p, 1.0 - p)  # work in the lower half (x <= 0)

    low = q < 0.02425
    r = np.sqrt(-2.0 * np.log(np.where(low, q, 0.5)))
    tail = (((((_C[0] * r + _C[1]) * r + _C[2]) * r + _C[3]) * r + _C[4]) * r
            + _C[5]) / ((((_D[0] * r + _D[1]) * r + _D[2]) * r + _D[3]) * r
                        + 1.0)
    s = np.where(low, 0.5, q) - 0.5
    t = s * s
    central = (((((_A[0] * t + _A[1]) * t + _A[2]) * t + _A[3]) * t + _A[4])
               * t + _A[5]) * s / (((((_B[0] * t + _B[1]) * t + _B[2]) * t
                                     + _B[3]) * t + _B[4]) * t + 1.0)
    x = np.where(low, tail, central)

    # One Halley refinement: e = Phi(x) - q, u = e * sqrt(2 pi) exp(x^2 / 2).
    erf = np.vectorize(math.erf, otypes=[np.float64])
    e = 0.5 * (1.0 + erf(x / math.sqrt(2.0))) - q
    u = e * math.sqrt(2.0 * math.pi) * np.exp(0.5 * x * x)
    x = x - u / (1.0 + 0.5 * x * u)
    return np.where(p < 0.5, x, -x)


def qmc_map(
    input_dim: int,
    num_features: int,
    sigma: float,
    dtype: jnp.dtype = jnp.float32,
) -> FeatureMap:
    """Deterministic QMC feature map for ``exp(-||u||^2 / (2 sigma^2))``.

    ``num_features`` must be even (cos/sin pairs). No PRNG key: two
    constructions with identical arguments are bitwise identical.
    """
    if num_features % 2:
        raise ValueError(
            f"qmc num_features must be even (cos/sin pairs), got {num_features}"
        )
    m = num_features // 2
    u = halton_sequence(m, input_dim)  # (m, d) in (0, 1)
    omega_t = inverse_normal_cdf(u) / sigma  # (m, d) spectral nodes
    omega = jnp.asarray(np.concatenate([omega_t.T, omega_t.T], axis=1), dtype)
    half = float(np.pi / 2.0)
    bias = jnp.concatenate(
        [jnp.zeros((m,), dtype), jnp.full((m,), -half, dtype)]
    )
    scale = jnp.full((num_features,), float((1.0 / m) ** 0.5), dtype)
    params = TrigFeatures(omega=omega, bias=bias, scale=scale)
    return trig_map("qmc", params, deterministic=True)
