"""Monte-Carlo trig families: iid RFF and orthogonal random features (ORF).

Both wrap :func:`repro.core.rff.sample_rff` (the paper's sampler, eq. (5))
and canonicalize to :class:`repro.features.base.TrigFeatures` with the
uniform ``sqrt(2/D)`` Monte-Carlo scale — featurizing through the subsystem
is bitwise the legacy ``rff_features`` path.

ORF (Yu et al. 2016): blocks of up to ``d`` spectral samples are QR-
orthogonalized and re-scaled to chi(d)-distributed row norms. Marginals are
unchanged (the estimator stays unbiased) but the kernel-approximation
variance drops strictly at identical featurize cost — the same D buys a
lower error floor.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.rff import sample_rff
from repro.features.base import FeatureMap, trig_from_rff, trig_map

__all__ = ["rff_map", "orf_map"]


def rff_map(
    key: jax.Array,
    input_dim: int,
    num_features: int,
    sigma: float,
    dtype: jnp.dtype = jnp.float32,
) -> FeatureMap:
    """The paper's Monte-Carlo RFF map for ``exp(-||u||^2 / (2 sigma^2))``.

    ``omega ~ N(0, I/sigma^2)``, ``bias ~ U[0, 2pi]``, uniform scale.
    """
    rff = sample_rff(key, input_dim, num_features, sigma, dtype)
    return trig_map("rff", trig_from_rff(rff), deterministic=False)


def orf_map(
    key: jax.Array,
    input_dim: int,
    num_features: int,
    sigma: float,
    dtype: jnp.dtype = jnp.float32,
) -> FeatureMap:
    """Orthogonal random features: QR-orthogonalized blocks, chi-scaled rows.

    Identical cost and contract to :func:`rff_map`; strictly lower Monte-
    Carlo variance (rows within a block are exactly orthogonal — tested as a
    property invariant).
    """
    rff = sample_rff(
        key, input_dim, num_features, sigma, dtype, orthogonal=True
    )
    return trig_map("orf", trig_from_rff(rff), deterministic=False)
