"""Deterministic feature maps per "No-Trick KAF" (Li & Principe, 2019):
Gaussian-quadrature trig features and Taylor-expansion polynomial features.

Both hit the Monte-Carlo RFF error floor at equal or smaller D with ZERO
seed variance — two constructions with the same arguments are bitwise
identical, so serving replicas agree exactly and learning curves need no
averaging over feature draws.

Gaussian quadrature (``gq_map``)
--------------------------------
Bochner gives ``kappa(x - y) = E_{w ~ N(0, I/sigma^2)}[cos(w.(x - y))]``.
Replace the expectation with a tensor-product Gauss-Hermite rule: per-node
weight ``a_j`` and node ``w_j``, truncated to the ``m = D/2`` largest-weight
nodes (weights renormalized to sum 1 so ``kappa(0) = 1`` exactly), then

    kappa(u) ~= sum_j a_j cos(w_j . u),

which the cos/sin pair identity turns into canonical affine-trig features
with per-feature scale ``sqrt(a_j)`` — the quadrature weights ride in the
``scale`` slot the Pallas kernels already consume.

Taylor expansion (``taylor_map``)
---------------------------------
``exp(x.y / sigma^2) = sum_alpha x^alpha y^alpha / (alpha! sigma^(2|alpha|))``
over multi-indices alpha, so with the Gaussian envelope

    phi_alpha(x) = exp(-||x||^2 / (2 sigma^2)) x^alpha
                   / sqrt(alpha! sigma^(2|alpha|)),   |alpha| <= degree,

``phi(x).phi(y)`` is the Gaussian kernel truncated at ``degree``. These are
polynomial-times-envelope features — NOT affine-trig — so they exercise the
generic half of the ``FeatureMap`` contract: every learner adapter and
generic bank tier runs them; only the fused trig kernels don't apply.
"""

from __future__ import annotations

import itertools
import math
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.features.base import FeatureMap, TrigFeatures, trig_map

__all__ = [
    "gq_map",
    "taylor_map",
    "TaylorParams",
    "taylor_features",
    "taylor_num_features",
    "taylor_weights",
]

# Largest tensor grid we are willing to enumerate host-side before
# truncating to the D/2 largest-weight nodes.
_MAX_GRID = 1 << 21


def gq_map(
    input_dim: int,
    num_features: int,
    sigma: float,
    dtype: jnp.dtype = jnp.float32,
) -> FeatureMap:
    """Deterministic Gauss-Hermite feature map for the Gaussian kernel.

    ``num_features`` must be even (cos/sin pairs). The per-dimension order
    ``n`` is the smallest with ``n^d`` at least ``D/2`` nodes; the grid is
    truncated to the ``D/2`` largest-weight nodes and the retained weights
    renormalized (so the kernel estimate at lag 0 is exactly 1).
    """
    if num_features % 2:
        raise ValueError(
            f"gq num_features must be even (cos/sin pairs), got {num_features}"
        )
    m = num_features // 2
    order = 1
    while order**input_dim < m:
        order += 1
        if order**input_dim > _MAX_GRID:
            raise ValueError(
                f"gq tensor grid for input_dim={input_dim} cannot reach "
                f"{m} nodes under the {_MAX_GRID}-point cap; use qmc/rff/orf "
                "for high-dimensional inputs"
            )
    # Gauss-Hermite in physicists' convention: integral of e^{-t^2} f(t).
    # For omega ~ N(0, 1/sigma^2): omega = sqrt(2) t / sigma, weight w/sqrt(pi).
    nodes1, weights1 = np.polynomial.hermite.hermgauss(order)
    nodes1 = np.sqrt(2.0) * nodes1 / sigma
    weights1 = weights1 / np.sqrt(np.pi)

    grids = np.meshgrid(*([nodes1] * input_dim), indexing="ij")
    omega_all = np.stack([g.reshape(-1) for g in grids], axis=-1)  # (n^d, d)
    wgrids = np.meshgrid(*([weights1] * input_dim), indexing="ij")
    a_all = np.prod(np.stack([g.reshape(-1) for g in wgrids], -1), axis=-1)

    # Keep the m heaviest nodes; stable order on ties keeps the map a pure
    # function of (d, D, sigma). Renormalize so sum a_j == 1.
    keep = np.argsort(-a_all, kind="stable")[:m]
    omega_t = omega_all[keep]  # (m, d)
    a = a_all[keep]
    a = a / np.sum(a)

    root_a = np.sqrt(a)
    omega = jnp.asarray(np.concatenate([omega_t.T, omega_t.T], axis=1), dtype)
    half = float(np.pi / 2.0)
    bias = jnp.concatenate(
        [jnp.zeros((m,), dtype), jnp.full((m,), -half, dtype)]
    )
    scale = jnp.asarray(np.concatenate([root_a, root_a]), dtype)
    params = TrigFeatures(omega=omega, bias=bias, scale=scale)
    return trig_map("gq", params, deterministic=True)


class TaylorParams(NamedTuple):
    """Taylor feature parameters: one row per multi-index alpha.

    Attributes:
      exponents: ``(D, d)`` int32 multi-index exponents alpha.
      coeff: ``(D,)`` per-feature coefficients
             ``1 / sqrt(alpha! sigma^(2|alpha|))`` — the (root) quadrature
             weights of the expansion.
      inv_two_sigma_sq: ``()`` the Gaussian envelope constant
             ``1 / (2 sigma^2)``.
    """

    exponents: jax.Array
    coeff: jax.Array
    inv_two_sigma_sq: jax.Array

    @property
    def input_dim(self) -> int:
        return self.exponents.shape[1]

    @property
    def num_features(self) -> int:
        return self.exponents.shape[0]

    @property
    def dtype(self) -> jnp.dtype:
        return self.coeff.dtype


def taylor_features(params: TaylorParams, x: jax.Array) -> jax.Array:
    """``phi(x) = exp(-||x||^2 / 2 sigma^2) * coeff * x^alpha``, x (..., d)."""
    exps = params.exponents.astype(x.dtype)
    monomials = jnp.prod(x[..., None, :] ** exps, axis=-1)  # (..., D)
    envelope = jnp.exp(
        -params.inv_two_sigma_sq.astype(x.dtype)
        * jnp.sum(jnp.square(x), axis=-1, keepdims=True)
    )
    return params.coeff.astype(x.dtype) * monomials * envelope


def taylor_weights(params: TaylorParams) -> jax.Array:
    """Per-feature expansion weights ``coeff**2`` (module-level, not a
    closure, so identically-built maps are structurally equal pytrees)."""
    return jnp.square(params.coeff)


def taylor_num_features(input_dim: int, degree: int) -> int:
    """Number of multi-indices with ``|alpha| <= degree``: C(d + r, r)."""
    return math.comb(input_dim + degree, degree)


def taylor_map(
    input_dim: int,
    degree: int,
    sigma: float,
    dtype: jnp.dtype = jnp.float32,
) -> FeatureMap:
    """Deterministic Taylor feature map truncated at total ``degree``.

    ``num_features = C(d + degree, degree)`` — choose ``degree`` so that
    lands near the D budget. Accuracy degrades with ``||x|| / sigma`` (the
    expansion converges fastest near the origin), which is exactly the
    regime trade No-Trick KAF documents.
    """
    alphas = []
    for r in range(degree + 1):
        for combo in itertools.combinations_with_replacement(
            range(input_dim), r
        ):
            alpha = [0] * input_dim
            for i in combo:
                alpha[i] += 1
            alphas.append(alpha)
    exponents = np.asarray(alphas, np.int32)  # (D, d)
    orders = exponents.sum(axis=1)  # |alpha|
    # alpha! in exact integer arithmetic first: np.prod would fold the
    # python ints into int64 and silently overflow (negative!) beyond 20!.
    fact = np.array(
        [float(math.prod(math.factorial(int(e)) for e in row))
         for row in exponents],
        np.float64,
    )
    coeff = 1.0 / np.sqrt(fact * sigma ** (2.0 * orders))
    params = TaylorParams(
        exponents=jnp.asarray(exponents),
        coeff=jnp.asarray(coeff, dtype),
        inv_two_sigma_sq=jnp.asarray(1.0 / (2.0 * sigma**2), dtype),
    )
    return FeatureMap(
        family="taylor",
        params=params,
        featurize_fn=taylor_features,
        weights_fn=taylor_weights,
        deterministic=True,
    )
