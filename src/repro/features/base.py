"""The ``FeatureMap`` contract — one pluggable device under every learner.

The paper's entire efficiency argument rests on a fixed-size feature map
whose inner product approximates the kernel:

    kappa(x, y) ~= z(x)^T z(y),    z(x) in R^D.

Historically the repo hardcoded the Monte-Carlo RFF map
(``core.rff.rff_features``) at every call site. This module makes the map a
first-class subsystem: a feature map is

  * a **pytree param struct** (so it flows through jit / vmap / scan /
    shard_map unchanged),
  * a pure ``featurize(params, x) -> (..., D)`` function,
  * ``num_features`` / ``input_dim`` / per-feature ``weights`` metadata.

Canonical affine-trig form
--------------------------
Every trigonometric family (Monte-Carlo RFF, orthogonal random features,
quasi-Monte-Carlo, deterministic Gaussian quadrature) canonicalizes to

    z(x) = scale * cos(x @ omega + bias),        scale per-feature (D,),

captured by :class:`TrigFeatures`. This is the ONE form the Pallas kernels
(``kernels/rff_features.py``, the fused KLMS/KRLS bank step kernels and the
chunked multi-tick engine) consume — swapping families changes the params,
never the kernels. Pairs ``(cos(w.x), sin(w.x))`` fit the form because
``sin(t) = cos(t - pi/2)``; per-node quadrature weights ``a_j`` become
per-feature scales ``sqrt(a_j)``.

Non-trig families (the Taylor map in ``features/deterministic.py``) satisfy
the same :class:`FeatureMap` contract and run through every generic
(XLA/vmap) path; only the fused trig kernels require :func:`as_trig`.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

if TYPE_CHECKING:  # runtime import is lazy: core.klms/krls import this module
    from repro.core.rff import RFF

__all__ = [
    "TrigFeatures",
    "FeatureMap",
    "FeatureLike",
    "trig_features",
    "trig_weights",
    "featurize",
    "as_trig",
    "as_trig_or_none",
    "feature_weights",
    "num_features",
    "input_dim",
    "feature_dtype",
    "uniform_trig_scale",
    "trig_from_rff",
]


class TrigFeatures(NamedTuple):
    """Canonical affine-trig feature parameters (the Pallas-kernel contract).

    ``z(x) = scale * cos(x @ omega + bias)`` with per-feature scale, so one
    struct expresses Monte-Carlo RFF (uniform ``sqrt(2/D)`` scale), ORF,
    QMC cos/sin pairs and weighted Gaussian-quadrature nodes.

    Attributes:
      omega: ``(d, D)`` spectral points (columns are the omega_i).
      bias:  ``(D,)`` phases (``U[0, 2pi]`` draws, or ``0 / -pi/2`` for
             deterministic cos/sin pairs).
      scale: ``(D,)`` per-feature scales ``sqrt(a_i)`` — the square roots of
             the quadrature weights.
    """

    omega: jax.Array
    bias: jax.Array
    scale: jax.Array

    @property
    def input_dim(self) -> int:
        return self.omega.shape[0]

    @property
    def num_features(self) -> int:
        return self.omega.shape[1]

    @property
    def dtype(self) -> jnp.dtype:
        return self.omega.dtype


def uniform_trig_scale(
    num_features: int, dtype: jnp.dtype = jnp.float32
) -> jax.Array:
    """The Monte-Carlo ``sqrt(2/D)`` scale as a per-feature ``(D,)`` array.

    Computed exactly like ``core.rff.rff_features``'s scalar
    (``jnp.sqrt(2.0 / D)`` in the default precision, then cast) — for ~13%
    of D values that differs by 1 ulp from the f64-sqrt-then-cast route, and
    canonicalizing an :class:`repro.core.rff.RFF` must change NOTHING
    numerically (the adapter bit-exactness tests pin this).
    """
    scalar = jnp.sqrt(2.0 / num_features).astype(dtype)
    return jnp.broadcast_to(scalar, (num_features,))


def trig_from_rff(rff: "RFF") -> TrigFeatures:
    """Canonicalize the paper's RFF struct: uniform ``sqrt(2/D)`` scale."""
    return TrigFeatures(
        omega=rff.omega,
        bias=rff.bias,
        scale=uniform_trig_scale(rff.num_features, rff.omega.dtype),
    )


def trig_features(tf: TrigFeatures, x: jax.Array) -> jax.Array:
    """``z(x) = scale * cos(x @ omega + bias)`` — inputs ``(..., d)``."""
    proj = x @ tf.omega + tf.bias
    return tf.scale.astype(proj.dtype) * jnp.cos(proj)


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class FeatureMap:
    """A feature family behind one contract: params pytree + pure featurize.

    Instances are pytrees (``params`` holds the leaves; everything else is
    static aux data), so a ``FeatureMap`` can be passed straight into jitted
    functions, vmapped over, or closed over — exactly like the ``RFF``
    NamedTuple it generalizes.

    Attributes:
      family: registry name (``rff`` / ``orf`` / ``qmc`` / ``gq`` /
        ``taylor``).
      params: the param pytree — :class:`TrigFeatures` for trig families,
        a family-specific struct otherwise. Must expose ``num_features`` /
        ``input_dim`` / ``dtype`` properties.
      featurize_fn: pure ``(params, x) -> (..., D)``.
      weights_fn: pure ``(params,) -> (D,)`` per-feature quadrature weights
        (``scale**2`` for trig families).
      deterministic: True when construction ignores PRNG keys entirely — the
        zero-seed-variance families (QMC, GQ, Taylor).
    """

    family: str
    params: Any
    featurize_fn: Callable[[Any, jax.Array], jax.Array]
    weights_fn: Callable[[Any], jax.Array]
    deterministic: bool

    def tree_flatten(self):
        aux = (
            self.family,
            self.featurize_fn,
            self.weights_fn,
            self.deterministic,
        )
        return (self.params,), aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        family, featurize_fn, weights_fn, deterministic = aux
        return cls(
            family=family,
            params=children[0],
            featurize_fn=featurize_fn,
            weights_fn=weights_fn,
            deterministic=deterministic,
        )

    @property
    def num_features(self) -> int:
        return self.params.num_features

    @property
    def input_dim(self) -> int:
        return self.params.input_dim

    @property
    def dtype(self) -> jnp.dtype:
        return self.params.dtype

    @property
    def weights(self) -> jax.Array:
        """Per-feature quadrature weights ``a_i`` (``scale**2`` for trig).

        Trig families sum to ``2 kappa(0)``: cos/sin pairs carry each node
        weight twice (``cos^2 + sin^2`` collapses the pair, so
        ``||z||^2 = 1`` exactly for gq/qmc), while random-phase features
        contribute ``E[cos^2] = 1/2`` each (``||z||^2 = 1`` in expectation).
        """
        return self.weights_fn(self.params)

    @property
    def trig(self) -> Optional[TrigFeatures]:
        """The canonical affine-trig form, or None for non-trig families."""
        return self.params if isinstance(self.params, TrigFeatures) else None

    def featurize(self, x: jax.Array) -> jax.Array:
        return self.featurize_fn(self.params, x)


def trig_weights(params: TrigFeatures) -> jax.Array:
    """Per-feature quadrature weights of a trig map: ``scale**2``.

    Module-level (not a closure) on purpose: ``weights_fn`` is pytree aux
    data, and identically-constructed maps must compare structurally equal
    so jitted functions taking a map as a traced argument don't retrace per
    instance (the rebuild-anywhere serving story for deterministic maps).
    """
    return jnp.square(params.scale)


def trig_map(family: str, params: TrigFeatures, deterministic: bool) -> FeatureMap:
    """Wrap canonical trig params as a :class:`FeatureMap`."""
    return FeatureMap(
        family=family,
        params=params,
        featurize_fn=trig_features,
        weights_fn=trig_weights,
        deterministic=deterministic,
    )


# Anything the learners accept where a feature map is expected. ``RFF`` stays
# valid so every pre-subsystem call site keeps working unchanged. (The RFF
# reference is a forward string: core.klms/krls import this module, so the
# concrete class is only touched lazily at call time.)
FeatureLike = Union[FeatureMap, TrigFeatures, "RFF"]


def _is_rff(fm: Any) -> bool:
    from repro.core.rff import RFF

    return isinstance(fm, RFF)


def featurize(fm: FeatureLike, x: jax.Array) -> jax.Array:
    """Family-agnostic feature map: ``(..., d) -> (..., D)``."""
    if isinstance(fm, FeatureMap):
        return fm.featurize(x)
    if isinstance(fm, TrigFeatures):
        return trig_features(fm, x)
    if _is_rff(fm):
        from repro.core.rff import rff_features

        return rff_features(fm, x)
    raise TypeError(f"not a feature map: {type(fm).__name__}")


def as_trig_or_none(fm: FeatureLike) -> Optional[TrigFeatures]:
    """Canonical ``(W, b, scale)`` form, or None if the family has none."""
    if isinstance(fm, TrigFeatures):
        return fm
    if _is_rff(fm):
        return trig_from_rff(fm)
    if isinstance(fm, FeatureMap):
        return fm.trig
    raise TypeError(f"not a feature map: {type(fm).__name__}")


def as_trig(fm: FeatureLike) -> TrigFeatures:
    """Canonical trig form; raises for non-trig families (e.g. ``taylor``).

    The fused Pallas kernels and the sharded KRLS path inline the affine-trig
    activation and therefore require this form; non-trig families run through
    the generic ``featurize`` paths instead.
    """
    tf = as_trig_or_none(fm)
    if tf is None:
        family = fm.family if isinstance(fm, FeatureMap) else type(fm).__name__
        raise TypeError(
            f"feature family {family!r} has no affine-trig canonical form; "
            "use the generic (featurize-based) path for it"
        )
    return tf


def feature_weights(fm: FeatureLike) -> jax.Array:
    """Per-feature quadrature weights ``a_i`` (``scale**2`` for trig maps)."""
    if isinstance(fm, FeatureMap):
        return fm.weights
    return jnp.square(as_trig(fm).scale)


def num_features(fm: FeatureLike) -> int:
    return fm.num_features


def input_dim(fm: FeatureLike) -> int:
    return fm.input_dim


def feature_dtype(fm: FeatureLike) -> jnp.dtype:
    """Working dtype of a feature map (RFF has no ``.dtype`` property)."""
    if _is_rff(fm):
        return fm.omega.dtype
    return fm.dtype
