"""repro — RFF kernel adaptive filtering (KLMS/KRLS) at framework scale.

Reproduction + TPU-native extension of Bouboulis, Pougkakiotis & Theodoridis,
"Efficient KLMS and KRLS Algorithms: A Random Fourier Feature Perspective"
(2016). See DESIGN.md for the system map.
"""

__version__ = "1.0.0"
