"""Production training launcher.

On a real TPU slice this binary runs under `jax.distributed` with the
production mesh; on this CPU container it runs the same code path on the
local device(s) (use --force-devices N to simulate a small mesh).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 200 --batch 8 --seq 64 --ckpt-dir checkpoints/qwen

Restart the same command after a kill to resume from the newest checkpoint.
"""
import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="checkpoints/launch_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--force-devices", type=int, default=0,
                    help="force N host devices (set before jax init)")
    args = ap.parse_args()

    if args.force_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.force_devices}"
        )

    from repro.configs import get_config
    from repro.data.lm_data import batch_at_step
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    def batch_fn(step):
        return {
            "tokens": batch_at_step(
                0, step, global_batch=args.batch, seq_len=args.seq,
                vocab=cfg.vocab_size,
            )
        }

    trainer = Trainer(
        cfg,
        TrainerConfig(
            total_steps=args.steps,
            ckpt_every=args.ckpt_every,
            ckpt_dir=args.ckpt_dir,
            num_microbatches=args.micro,
            peak_lr=args.lr,
        ),
        batch_fn,
    )
    metrics = trainer.run()
    print(f"done: {metrics}", file=sys.stderr)


if __name__ == "__main__":
    main()
