"""Production meshes.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is an outer data/FSDP axis; cross-pod traffic is gradient
reduction (DCN), intra-pod is TP/EP/FSDP (ICI).

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

from repro.core.krls import KRLS_SHARD_AXIS

__all__ = [
    "make_production_mesh",
    "make_krls_mesh",
    "data_axes",
    "DP_AXES",
    "MODEL_AXIS",
    "KRLS_SHARD_AXIS",
]

MODEL_AXIS = "model"


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_krls_mesh(n_shards: int | None = None) -> jax.sharding.Mesh:
    """1-D mesh over the KRLS shard axis (the P row-block partition).

    Defaults to every visible device; for host-platform simulation set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before first jax
    use (the pattern tests/test_krls_sharded.py runs in a subprocess).
    """
    n = n_shards if n_shards is not None else jax.device_count()
    return jax.make_mesh((n,), (KRLS_SHARD_AXIS,))


def data_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """All data-parallel-like axes (everything except the model axis)."""
    return tuple(a for a in mesh.axis_names if a != MODEL_AXIS)


DP_AXES = data_axes  # alias
