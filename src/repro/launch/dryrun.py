import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (device count locks at
# first backend init). Everything below is ordinary.

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell.

For each cell this proves, without any TPU:
  * the GSPMD sharding is coherent (no partitioner errors),
  * the program fits (memory_analysis bytes per device),
  * and extracts roofline terms (flops / bytes / collective bytes) via the
    while-aware HLO cost parser (repro.roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch import specs as specs_mod
from repro.launch.mesh import data_axes, make_production_mesh
from repro.launch.sharding import moment_specs, param_specs
from repro.optim.optimizers import AdamWState
from repro.roofline import parse_hlo_cost, roofline_terms
from repro.train import steps as steps_mod

__all__ = ["run_cell", "model_flops"]


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Useful-work estimate: 6*N_active*D (train) / 2*N_active*D (inference),
    N = active matmul params (embedding lookup excluded unless tied)."""
    n = cfg.active_param_count()
    if not cfg.tie_embeddings:
        n -= cfg.vocab_size * cfg.d_model  # lookup table is not matmul work
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def _state_specs(cfg, mesh, state_shapes):
    pspecs = param_specs(cfg, mesh, state_shapes["params"])
    mspecs = moment_specs(cfg, mesh, state_shapes["params"])
    return {
        "params": pspecs,
        "opt": AdamWState(m=mspecs, v=mspecs, count=P()),
        "step": P(),
    }


def _shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    microbatch_override: int | None = None,
    want_hlo: bool = False,
) -> dict:
    """Lower + compile one cell; returns the result record."""
    base_cfg = get_config(arch)
    shape = SHAPES[shape_name]
    cfg, policy_note = specs_mod.resolve_cell(base_cfg, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "axes": list(mesh.axis_names),
        "chips": mesh.size,
        "policy": policy_note,
        "kind": shape.kind,
    }

    batch_shapes = specs_mod.input_specs(cfg, shape)
    batch_shardings = specs_mod.input_shardings(cfg, shape, mesh)

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            baxes = specs_mod.train_batch_axes(cfg, shape, mesh)
            bshards = 1
            for a in baxes:
                bshards *= mesh.shape[a]
            num_micro = (
                microbatch_override
                or cfg.train_microbatches
                or max(1, shape.global_batch // bshards)
            )
            record["num_microbatches"] = num_micro
            # pin activation batch sharding through the layer stack
            cfg = dataclasses.replace(cfg, activation_batch_axes=tuple(baxes))
            state_shapes = jax.eval_shape(
                lambda: steps_mod.init_train_state(jax.random.PRNGKey(0), cfg)
            )
            sspec = _state_specs(cfg, mesh, state_shapes)
            sshard = _shardings(mesh, sspec)
            step = steps_mod.make_train_step(
                cfg,
                num_microbatches=num_micro,
                batch_axes=baxes or None,
                grad_specs=jax.tree.map(
                    lambda s: NamedSharding(mesh, s), sspec["params"]
                ),
            )
            jitted = jax.jit(
                step,
                in_shardings=(sshard, batch_shardings),
                out_shardings=(sshard, None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_shapes, batch_shapes)
        elif shape.kind == "prefill":
            params_shapes = jax.eval_shape(
                lambda: steps_mod.transformer.init_params(jax.random.PRNGKey(0), cfg)
            )
            pshard = _shardings(mesh, param_specs(cfg, mesh, params_shapes))
            step = steps_mod.make_prefill_step(cfg)
            v_axis = "model" if cfg.preferred_parallelism == "tp" else None
            out_shard = NamedSharding(
                mesh, P(specs_mod.batch_specs(mesh, batch=shape.global_batch, kind="prefill")[0] if shape.global_batch >= specs_mod.dp_size(mesh) else None, v_axis)
            )
            jitted = jax.jit(
                step, in_shardings=(pshard, batch_shardings), out_shardings=out_shard
            )
            lowered = jitted.lower(params_shapes, batch_shapes)
        else:  # decode
            params_shapes = jax.eval_shape(
                lambda: steps_mod.transformer.init_params(jax.random.PRNGKey(0), cfg)
            )
            pshard = _shardings(mesh, param_specs(cfg, mesh, params_shapes))
            st_shapes = specs_mod.decode_state_shape(cfg, shape)
            st_shard = specs_mod.decode_state_shardings(cfg, shape, mesh)
            step = steps_mod.make_decode_step(cfg)
            b_axes = (
                data_axes(mesh)
                if shape.global_batch >= specs_mod.dp_size(mesh)
                else None
            )
            v_axis = "model" if cfg.preferred_parallelism == "tp" else None
            logits_shard = NamedSharding(mesh, P(b_axes, v_axis))
            jitted = jax.jit(
                step,
                in_shardings=(pshard, st_shard, batch_shardings),
                out_shardings=(logits_shard, st_shard),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_shapes, st_shapes, batch_shapes)

        record["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 2)

    # ---- memory analysis (proves it fits) ----
    try:
        ma = compiled.memory_analysis()
        record["memory"] = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(ma, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        record["memory"] = {"error": str(e)}

    # ---- XLA's own cost analysis (known to undercount scans; recorded for
    # comparison) ----
    try:
        ca = compiled.cost_analysis()
        record["xla_cost_analysis"] = {
            "flops": ca.get("flops"),
            "bytes_accessed": ca.get("bytes accessed"),
        }
    except Exception as e:  # pragma: no cover
        record["xla_cost_analysis"] = {"error": str(e)}

    # ---- while-aware HLO cost + roofline terms ----
    hlo = compiled.as_text()
    cost = parse_hlo_cost(hlo, total_devices=mesh.size)
    mf = model_flops(cfg, shape)
    terms = roofline_terms(cost, chips=mesh.size, model_flops_total=mf)
    record["cost"] = {
        "flops_per_device": cost.flops,
        "bytes_per_device": cost.bytes_accessed,
        "collective_bytes_per_device": cost.collective_bytes,
        "collective_breakdown": dict(cost.collective_breakdown),
        "collective_count": cost.collective_count,
        "unknown_trip_whiles": cost.unknown_trip_whiles,
        "transcendentals": cost.transcendentals,
    }
    record["roofline"] = {
        "compute_s": terms.compute_s,
        "memory_s": terms.memory_s,
        "collective_s": terms.collective_s,
        "dominant": terms.dominant,
        "bound_time_s": terms.bound_time_s,
        "model_flops_total": mf,
        "useful_flops_frac": terms.useful_flops_frac,
        "roofline_fraction": terms.roofline_fraction,
    }
    if want_hlo:
        record["hlo_text"] = hlo
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default=None)
    ap.add_argument("--shape", choices=tuple(SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else (args.arch,)
    shapes = tuple(SHAPES) if (args.all or not args.shape) else (args.shape,)
    meshes = (False, True) if args.both_meshes else (args.multi_pod,)

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape_name}__{'multi' if mp else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[skip] {tag} (cached)")
                    continue
                print(f"[lower+compile] {tag} ...", flush=True)
                try:
                    rec = run_cell(arch, shape_name, multi_pod=mp)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    r = rec["roofline"]
                    print(
                        f"  ok: compile={rec['compile_s']}s dominant={r['dominant']}"
                        f" compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s"
                        f" collective={r['collective_s']:.3e}s"
                        f" useful={r['useful_flops_frac']:.2f}",
                        flush=True,
                    )
                except Exception:
                    failures += 1
                    print(f"  FAILED {tag}\n{traceback.format_exc()}", flush=True)
                finally:
                    jax.clear_caches()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
