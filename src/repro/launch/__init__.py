"""Launchers: production mesh, sharding rules, dry-run, train/serve CLIs."""
from repro.launch.mesh import data_axes, make_production_mesh

__all__ = ["make_production_mesh", "data_axes"]
