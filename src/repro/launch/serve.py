"""Production serving launcher: batched autoregressive generation.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \
        --batch 4 --prompt-len 8 --tokens 32

``--rff`` switches full-attention archs to the paper's fixed-size-state
attention (O(1) decode memory in context length).
"""
import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=1024)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--rff", action="store_true",
                    help="use RFF fixed-state attention (paper technique)")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    args = ap.parse_args()

    import jax

    from repro.configs import get_config
    from repro.models import init_params, with_rff_attention
    from repro.serve import generate

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.rff:
        cfg = with_rff_attention(cfg)

    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    t0 = time.perf_counter()
    out = generate(
        params, cfg, prompt,
        steps=args.tokens, max_len=args.max_len,
        temperature=args.temperature,
    )
    out.block_until_ready()
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} attention={cfg.attention}")
    print(f"{args.batch}x{args.tokens} tokens in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s)")
    print("sample:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
