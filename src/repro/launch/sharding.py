"""GSPMD sharding rules for params, optimizer state, inputs and decode state.

Strategy (DESIGN.md §6) — four parallelism modes, chosen per arch and
per deployment kind (train vs serve):
  * ``tp`` (serve default): TP on the ``model`` axis over head-structured /
    hidden / expert / vocab dims; head interiors never split (3D projection
    weights; inert head/vocab padding for divisibility). ZeRO-1: params
    replicated over data, AdamW moments data-sharded (``moment_specs``).
  * ``zero_stage=3`` (arctic-480b train): contraction dims additionally
    sharded over data; pairs with the activation-batch constraint and
    grad-accumulator pinning in train/steps.py.
  * ``fsdp`` (train for <=35B dense/MoE archs): largest divisible weight
    dim sharded over ALL axes, batch over all axes, weights gathered at
    use — measured 2.7-5.8x better modelled step time than TP-16.
  * ``dp`` (qwen2, mamba2): params replicated, batch over every axis.
  * serve-time MoE for zero-3 archs: gather-free 2D expert layout
    (E x data, expert-ff x model).
  * decode: KV caches sequence-sharded over ``model`` (context
    parallelism); fixed-size RFF/SSM/LRU states shard heads/features.

Rules are name+rank driven over the param pytree paths — one place to audit.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import data_axes

__all__ = [
    "param_specs",
    "param_shardings",
    "moment_specs",
    "batch_specs",
    "decode_state_specs",
    "krls_state_shardings",
    "krls_feature_shardings",
    "krls_shard_bytes",
    "named",
]


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _key_names(path) -> list[str]:
    out = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            out.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            out.append(f"[{p.idx}]")
        elif isinstance(p, jax.tree_util.GetAttrKey):
            out.append(p.name)
    return out


def _model_ok(n: int, model_size: int) -> bool:
    return n % model_size == 0


def _leaf_spec(
    names: list[str],
    shape: tuple[int, ...],
    cfg: ModelConfig,
    fsdp,
    model_size: int,
) -> P:
    """Sharding rule for one (possibly scan-stacked) parameter leaf.

    Attention projections are 3D head-structured (d, H, dh)/(H, dh, d): the
    head axis is sharded on ``model`` directly (GSPMD pads uneven head
    counts), so head interiors are never split.
    """
    name = names[-1] if names else ""
    parent = names[-2] if len(names) >= 2 else ""
    gparent = names[-3] if len(names) >= 3 else ""

    stacked = "blocks" in names
    dims = list(shape[1:]) if stacked else list(shape)
    base_ndim = len(dims)

    def wrap(*spec_dims) -> P:
        sd = list(spec_dims) + [None] * (base_ndim - len(spec_dims))
        if stacked:
            sd = [None] + sd
        return P(*sd)

    # kv projections keep their (few) heads replicated; activations are
    # group-repeated to full heads at use (GQA repeat-kv), except when the
    # layer is RFF attention whose k/v are full-headed.
    kv_model = cfg.attention == "rff"

    # ---- scalars / vectors ----
    if base_ndim == 0:
        return wrap()
    if base_ndim == 1:
        if name in ("conv_b", "norm_scale", "lam") and cfg.mixer == "rglru_hybrid" and _model_ok(dims[0], model_size):
            return wrap("model")
        return wrap(None)

    # ---- embeddings / head (d_model dim stays replicated: contracting an
    # fsdp-sharded dim would AR logits over the data axis) ----
    if name == "table":  # (V, d)
        return wrap("model", None)
    if parent == "head":  # (d, V)
        return wrap(None, "model")

    # ---- MoE expert stacks (E, d, ff) / (E, ff, d) ----
    if gparent == "experts" or parent == "experts":
        if cfg.expert_2d_shard:
            # gather-free serve layout: E over data, expert-ff over model
            if name in ("wi", "wg"):
                return wrap("data", None, "model")
            if name == "wo":
                return wrap("data", "model", None)
        e_ok = cfg.moe is not None and _model_ok(cfg.moe.num_experts, model_size)
        eaxis = "model" if e_ok else None
        if name in ("wi", "wg"):
            return wrap(eaxis, fsdp, None)
        if name == "wo":
            return wrap(eaxis, None, fsdp)
    if parent == "router":  # (d, E)
        return wrap(fsdp, None)

    # ---- convs: rglru (Hp, hd, W) head-structured / mamba (C, W) ----
    if name == "conv_w":
        if cfg.mixer == "rglru_hybrid":
            return wrap("model", None, None)
        return wrap(None, None)
    if name == "conv_b" and cfg.mixer == "rglru_hybrid":
        return wrap("model", None)
    if name == "lam":  # (Hp, hd)
        return wrap("model", None)
    if name in ("w_r", "w_i") and base_ndim == 3:  # block-diag gates
        return wrap("model", None, None)

    # ---- MLA latents (2D) + head-structured up-projections (3D) ----
    if parent in ("w_dq", "w_dkv", "w_kr"):  # (d, r): latents small
        return wrap(fsdp, None)
    if parent in ("w_uq", "w_ukv"):  # (r, H, x)
        return wrap(None, "model", None)

    # ---- RFF feature buffers (dh, D): replicated ----
    if name == "omega":
        return wrap(None, None)
    if name == "bias" and gparent == "attn" and base_ndim == 1:
        return wrap(None)

    # ---- attention projections (3D head-structured) ----
    if parent == "wq":
        if name == "b":  # (H, dh)
            return wrap("model", None)
        return wrap(fsdp, "model", None)  # (d, H, dh)
    if parent in ("wk", "wv"):
        if name == "b":
            return wrap("model" if kv_model else None, None)
        return wrap(fsdp, "model" if kv_model else None, None)  # (d, Hkv, dh)
    if parent == "wo" and base_ndim == 3:  # (H, dh, d)
        return wrap("model", None, fsdp)

    # ---- mamba2: d_inner projections stay model-replicated (the in-proj
    # output packs z/x/B/C/dt segments whose boundaries don't align with a
    # model-axis split); parallelism for the SSM family is pure data/fsdp ----
    if cfg.mixer == "mamba2":
        if parent == "w_in":
            return wrap(fsdp, None)
        if parent == "w_out":
            return wrap(None, fsdp)

    # ---- rglru (gparent == "temporal"): head-structured like attention ----
    if parent in ("w_x", "w_gate"):  # (d, Hp, hd)
        return wrap(fsdp, "model", None)
    if parent == "w_out" and gparent == "temporal":  # (Hp, hd, d)
        return wrap("model", None, fsdp)

    # ---- generic MLP (ffn / mlp / shared / dense_residual) ----
    if parent in ("wi", "wg"):  # (d, ff)
        return wrap(fsdp, "model" if _model_ok(dims[1], model_size) else None)
    if parent == "wo":  # (ff, d)
        return wrap("model" if _model_ok(dims[0], model_size) else None, fsdp)

    # fallback: replicate
    return wrap(None)


def _fsdp_specs(mesh: Mesh, params_shape: Any) -> Any:
    """FSDP over ALL mesh axes: shard each weight's largest divisible dim;
    GSPMD gathers weights at use. Batch owns every axis for activations."""
    axes = tuple(mesh.axis_names)
    total = 1
    for a in axes:
        total *= mesh.shape[a]

    def rule(path, leaf):
        dims = tuple(leaf.shape)
        if len(dims) < 2:
            return P()
        # largest dim divisible by the full device count
        best, best_size = None, 0
        for i, d in enumerate(dims):
            if d % total == 0 and d > best_size:
                best, best_size = i, d
        if best is None:
            return P()
        spec = [None] * len(dims)
        spec[best] = axes
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def param_specs(cfg: ModelConfig, mesh: Mesh, params_shape: Any) -> Any:
    """PartitionSpec pytree matching ``params_shape`` (shapes or arrays).

    ``preferred_parallelism == "dp"`` (tiny archs where TP=16 is pure
    overhead): replicate all params — batch is sharded over every mesh axis
    instead (see specs.train_batch_axes).
    """
    if getattr(cfg, "preferred_parallelism", "tp") == "dp":
        return jax.tree.map(lambda _: P(), params_shape)
    if cfg.preferred_parallelism == "fsdp":
        return _fsdp_specs(mesh, params_shape)
    # ZeRO-1 (default): no fsdp on params — contraction dims replicated over
    # data, so GSPMD never trades weight gathers for activation partial-sum
    # all-reduces (observed pathology). ZeRO-3 (arctic): fsdp on contraction
    # dims because TP-sharded params alone exceed HBM.
    fsdp = data_axes(mesh) if cfg.zero_stage >= 3 else None
    model_size = mesh.shape["model"]

    def rule(path, leaf):
        return _leaf_spec(_key_names(path), tuple(leaf.shape), cfg, fsdp, model_size)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def moment_specs(cfg: ModelConfig, mesh: Mesh, params_shape: Any) -> Any:
    """AdamW moment shardings: param specs + data-axis sharding on the
    largest still-replicated dim (ZeRO-1 optimizer-state sharding)."""
    base = param_specs(cfg, mesh, params_shape)
    dp = data_axes(mesh)
    dp_total = 1
    for a in dp:
        dp_total *= mesh.shape[a]

    def add_fsdp(path, leaf, spec):
        dims = tuple(leaf.shape)
        parts = list(spec) + [None] * (len(dims) - len(spec))
        if any(p is not None and ("data" in (p if isinstance(p, tuple) else (p,)) or "pod" in (p if isinstance(p, tuple) else (p,))) for p in parts):
            return spec  # already data-sharded (zero-3 leaf)
        # largest replicated dim divisible by the dp extent
        best, best_size = None, 0
        for i, (d, p) in enumerate(zip(dims, parts)):
            if p is None and d % dp_total == 0 and d > best_size:
                best, best_size = i, d
        if best is None:
            return spec
        parts[best] = dp if len(dp) > 1 else dp[0]
        return P(*parts)

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf, spec: add_fsdp(path, leaf, spec), params_shape, base
    )


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_shape: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(cfg, mesh, params_shape)
    )


def krls_state_shardings(mesh: Mesh, axis: str | None = None):
    """NamedShardings for the sharded-KRLS ``RLSState`` on ``mesh``.

    theta ``(D,)`` and the inverse correlation ``P (D, D)`` are row-block
    partitioned over the shard axis; the step counter is replicated. The
    specs themselves live with the math in ``core.krls`` — this wrapper is
    the deployment-layer entry point (device_put targets).
    """
    from repro.core.krls import KRLS_SHARD_AXIS, krls_state_specs

    specs = krls_state_specs(axis or KRLS_SHARD_AXIS)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def krls_feature_shardings(mesh: Mesh, axis: str | None = None):
    """NamedShardings for the canonical trig feature bank
    (``repro.features.TrigFeatures``): omega/bias/scale column-partitioned
    so each shard featurizes exactly its P row block's slice.

    The targets follow the 3-leaf canonical form — canonicalize a legacy
    ``RFF`` struct with ``repro.features.as_trig`` before ``device_put``
    against these shardings (or use ``core.krls.shard_krls_rff``, which
    does both)."""
    from repro.core.krls import KRLS_SHARD_AXIS, krls_feature_specs

    specs = krls_feature_specs(axis or KRLS_SHARD_AXIS)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def krls_shard_bytes(
    num_features: int,
    n_shards: int,
    input_dim: int = 0,
    itemsize: int = 4,
) -> dict:
    """Per-shard memory model for sharded RFF-KRLS (the ROADMAP's VMEM/HBM
    budget arithmetic).

    Dominant term: the ``(D/n, D)`` P row block. Per tick each shard also
    materializes the full ``(2D+1,)`` psum payload (pz ++ scattered z ++
    partial prediction) plus its local ``(D/n,)`` slices.
    """
    d, n = num_features, n_shards
    if d % n:
        raise ValueError(f"D={d} must divide n_shards={n}")
    p_block = d * (d // n) * itemsize
    features = (input_dim + 1) * (d // n) * itemsize  # omega cols + bias
    theta = (d // n) * itemsize
    tick_payload = (2 * d + 1) * itemsize  # the one psum per tick
    return {
        "p_block_bytes": p_block,
        "feature_bytes": features,
        "theta_bytes": theta,
        "tick_payload_bytes": tick_payload,
        "total_bytes": p_block + features + theta + tick_payload,
        "dense_p_bytes": d * d * itemsize,
    }


def batch_specs(mesh: Mesh, *, batch: int, kind: str) -> P:
    """Spec for (B, S) token batches / (B,) decode tokens."""
    dp = data_axes(mesh)
    ndev = 1
    for a in dp:
        ndev *= mesh.shape[a]
    if batch >= ndev:
        return P(dp)  # shard batch
    return P()  # tiny batch (long_500k B=1): replicate


def decode_state_specs(
    cfg: ModelConfig, mesh: Mesh, state_shape: Any, batch: int
) -> Any:
    """Sharding for the per-layer decode-state pytree."""
    dp = data_axes(mesh)
    model_size = mesh.shape["model"]
    ndev = 1
    for a in dp:
        ndev *= mesh.shape[a]
    batch_axis: Optional[tuple] = dp if batch >= ndev else None

    # DP archs keep params (and head-structured state dims) replicated over
    # the model axis; heads may not divide it anyway (qwen: 14).
    is_dp = cfg.preferred_parallelism == "dp"
    hmodel = None if is_dp else "model"

    def rule(path, leaf):
        names = _key_names(path)
        ndim = len(leaf.shape)
        stacked = "stack" in names and cfg.scan_layers
        base_ndim = ndim - (1 if stacked else 0)
        name = names[-1] if names else ""

        def wrap(*spec_dims):
            sd = list(spec_dims) + [None] * (base_ndim - len(spec_dims))
            if stacked:
                sd = [None] + sd
            return P(*sd)

        if base_ndim == 0:
            return wrap()
        if name in ("k", "v"):  # KV cache (B, S, hkv, dh): decode context
            # parallelism — the SEQUENCE is sharded over the model axis
            # (heads stay whole; the per-step softmax combine is tiny).
            return wrap(batch_axis, "model", None, None)
        if name in ("c_kv", "k_rope"):  # MLA latent cache (B, S, r)
            if batch_axis:
                return wrap(batch_axis, "model", None)
            return wrap(None, ("model",) + tuple(dp), None)  # B=1
        if name == "s":  # RFF state (B, H, D, dv)
            if batch_axis:
                return wrap(batch_axis, hmodel, None, None)
            return wrap(None, hmodel, dp, None)
        if name == "z":  # (B, H, D)
            if batch_axis:
                return wrap(batch_axis, hmodel, None)
            return wrap(None, hmodel, dp)
        if name == "h" and base_ndim == 4:  # mamba2 (B, H, dh, N)
            if batch_axis:
                return wrap(batch_axis, None, None, None)
            return wrap(None, None, None, dp)
        if name == "h" and base_ndim == 3:  # rglru (B, Hp, hd)
            return wrap(batch_axis, hmodel, None)
        if name == "conv" and base_ndim == 4:  # rglru (B, W-1, Hp, hd)
            return wrap(batch_axis, None, hmodel, None)
        if name == "conv":  # mamba (B, W-1, C)
            return wrap(batch_axis, None, None)
        return wrap(batch_axis)

    return jax.tree_util.tree_map_with_path(rule, state_shape)
