"""ShapeDtypeStruct input stand-ins + shardings for every (arch x shape) cell.

``input_specs`` builds exactly what the dry-run lowers against: weak-type-
correct, shardable, zero device allocation. ``resolve_cell`` applies the
long_500k policy (RFF substitution for full-attention archs — the paper's
technique; DESIGN.md §5).
"""
from __future__ import annotations

from dataclasses import replace
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch.mesh import data_axes
from repro.launch.sharding import batch_specs, decode_state_specs
from repro.models import transformer

__all__ = [
    "resolve_cell",
    "input_specs",
    "input_shardings",
    "dp_size",
    "train_batch_axes",
]


def dp_size(mesh: Mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n


def train_batch_axes(
    cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh
) -> tuple[str, ...]:
    """Mesh axes the batch dim is sharded over.

    TP mode: the data-like axes. DP mode: greedily extend over every axis
    (pod, data, model) while the global batch stays divisible — for tiny
    archs the model axis carries batch instead of tensor shards.
    """
    if cfg.preferred_parallelism in ("dp", "fsdp") and shape.kind in ("train", "prefill"):
        axes: list[str] = []
        prod = 1
        for a in mesh.axis_names:
            if shape.global_batch % (prod * mesh.shape[a]) == 0:
                axes.append(a)
                prod *= mesh.shape[a]
        return tuple(axes)
    dp = data_axes(mesh)
    prod = 1
    axes = []
    for a in dp:
        if shape.global_batch % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
    return tuple(axes)


def resolve_cell(cfg: ModelConfig, shape: ShapeSpec) -> tuple[ModelConfig, str]:
    """Apply per-cell policy. Returns (possibly modified cfg, note)."""
    note = "native"
    if shape.name == "long_500k" and cfg.mixer == "attention":
        if cfg.attention in ("gqa", "mla") and cfg.rff_long_context:
            cfg = transformer.with_rff_attention(cfg)
            note = "rff-substituted (paper technique: fixed-size state replaces KV cache)"
    if shape.kind != "train" and cfg.zero_stage >= 3:
        # no optimizer state at serve time: drop ZeRO-3 (per-use weight
        # gathers would repeat every decoded token) for a gather-free
        # 2D expert layout.
        cfg = replace(cfg, zero_stage=1, expert_2d_shard=True)
        note += " + serve=2d-expert-shard"
    if shape.kind == "train" and cfg.train_parallelism:
        # training deployment mapping; head padding exists only for the TP
        # head-axis shard and is dropped with it (train/serve layout
        # conversion is a reshape, noted in DESIGN.md).
        kw = dict(preferred_parallelism=cfg.train_parallelism)
        if cfg.train_parallelism in ("dp", "fsdp"):
            kw["pad_heads_to"] = 0
        cfg = replace(cfg, **kw)
        note += f" + train={cfg.preferred_parallelism}"
    return cfg, note


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    """ShapeDtypeStruct batch for one cell (tokens or stub-frontend embeds)."""
    b, s = shape.global_batch, shape.seq_len
    tok_dt = jnp.int32
    emb_dt = jnp.dtype(cfg.dtype)
    if shape.kind in ("train", "prefill"):
        if cfg.frontend:
            batch = {
                "embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), emb_dt),
            }
            if shape.kind == "train":
                batch["labels"] = jax.ShapeDtypeStruct((b, s), tok_dt)
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((b, s), tok_dt)}
        return batch
    # decode: one new token against a seq_len-deep context state
    if cfg.frontend:
        return {"embed": jax.ShapeDtypeStruct((b, 1, cfg.d_model), emb_dt)}
    return {"token": jax.ShapeDtypeStruct((b,), tok_dt)}


def input_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh) -> dict[str, Any]:
    if shape.kind in ("train", "prefill"):
        baxes = train_batch_axes(cfg, shape, mesh) or None
    else:
        bspec = batch_specs(mesh, batch=shape.global_batch, kind=shape.kind)
        baxes = bspec[0] if len(bspec) else None

    out = {}
    for name in input_specs(cfg, shape):
        if name in ("tokens", "labels"):
            out[name] = NamedSharding(mesh, P(baxes, None))
        elif name == "embeds":
            out[name] = NamedSharding(mesh, P(baxes, None, None))
        elif name == "token":
            out[name] = NamedSharding(mesh, P(baxes))
        elif name == "embed":
            out[name] = NamedSharding(mesh, P(baxes, None, None))
    return out


def decode_state_shape(cfg: ModelConfig, shape: ShapeSpec) -> Any:
    """abstract decode-state pytree for a cell (no allocation)."""
    return jax.eval_shape(
        lambda: transformer.decode_state_init(
            cfg, shape.global_batch, max_len=shape.seq_len
        )
    )


def decode_state_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh) -> Any:
    st_shape = decode_state_shape(cfg, shape)
    specs = decode_state_specs(cfg, mesh, st_shape, shape.global_batch)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
