from repro.roofline.analysis import (
    HW,
    HloCost,
    RooflineTerms,
    parse_hlo_cost,
    roofline_terms,
)

__all__ = ["HW", "HloCost", "RooflineTerms", "parse_hlo_cost", "roofline_terms"]
