"""Roofline analysis from compiled HLO.

``compiled.cost_analysis()`` counts while-loop (lax.scan) bodies exactly
ONCE, which silently undercounts any scanned program (layers, microbatches,
attention KV blocks) — verified empirically in this repo. This module
re-derives costs by walking the partitioned HLO text and scaling each
``while`` body by its ``known_trip_count`` backend config, giving trustworthy
per-device FLOPs / bytes / collective-bytes for the roofline terms.

Hardware model (TPU v5e, per task spec):
  peak bf16 compute 197 TFLOP/s per chip, HBM BW 819 GB/s, ICI ~50 GB/s/link.

Collective cost model (ring algorithms on n participants):
  all-reduce 2(n-1)/n x bytes; all-gather / reduce-scatter / all-to-all
  (n-1)/n x full bytes; collective-permute 1 x bytes.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

__all__ = ["HloCost", "parse_hlo_cost", "RooflineTerms", "roofline_terms", "HW"]


@dataclasses.dataclass
class HW:
    peak_flops: float = 197e12  # bf16 / chip
    hbm_bw: float = 819e9  # bytes/s
    ici_bw: float = 50e9  # bytes/s/link


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s+([\w\-]+)\("
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes_elems(type_str: str) -> tuple[int, int]:
    """(bytes, elements) for a possibly-tuple HLO type string."""
    total_b = total_e = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        elems = 1
        if dims:
            for d in dims.split(","):
                elems *= int(d)
        total_e += elems
        total_b += elems * _DTYPE_BYTES[dt]
    return total_b, total_e


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0  # ring-adjusted, per device
    collective_breakdown: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )
    collective_count: int = 0
    unknown_trip_whiles: int = 0
    # optional detail ledger: (op, shape, ring_bytes) -> total bytes after
    # trip scaling. Used by the perf loop to rank collective hotspots.
    details: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    # dot-FLOPs ledger: "dot SHAPE k=K" -> flops after trip scaling.
    flop_details: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    # bytes ledger: "op SHAPE" -> bytes accessed after trip scaling.
    byte_details: dict = dataclasses.field(default_factory=lambda: defaultdict(float))

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.transcendentals += other.transcendentals * mult
        self.bytes_accessed += other.bytes_accessed * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.collective_breakdown.items():
            self.collective_breakdown[k] += v * mult
        self.collective_count += int(other.collective_count * mult)
        self.unknown_trip_whiles += other.unknown_trip_whiles
        for k, v in other.details.items():
            self.details[k] += v * mult
        for k, v in other.flop_details.items():
            self.flop_details[k] += v * mult
        for k, v in other.byte_details.items():
            self.byte_details[k] += v * mult

    def top_collectives(self, n: int = 12) -> list[tuple[str, float]]:
        return sorted(self.details.items(), key=lambda kv: -kv[1])[:n]

    def top_flops(self, n: int = 12) -> list[tuple[str, float]]:
        return sorted(self.flop_details.items(), key=lambda kv: -kv[1])[:n]

    def top_bytes(self, n: int = 12) -> list[tuple[str, float]]:
        return sorted(self.byte_details.items(), key=lambda kv: -kv[1])[:n]


_TRANSCENDENTAL_OPS = {
    "cosine", "sine", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "logistic", "expm1", "log1p", "erf",
}
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
    "custom-call", "rng-bit-generator", "optimization-barrier", "domain",
}


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur: Optional[str] = None
    lines: list[str] = []
    for line in text.splitlines():
        hdr = re.match(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$", line)
        if hdr:
            cur = hdr.group(1)
            if line.startswith("ENTRY"):
                comps["__entry__"] = lines = []
                comps[cur] = lines
            else:
                lines = comps.setdefault(cur, [])
            continue
        if cur is not None:
            if line.startswith("}"):
                cur = None
                continue
            lines.append(line)
    return comps


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return total_devices


def _cost_of_computation(
    name: str,
    comps: dict[str, list[str]],
    cache: dict[str, HloCost],
    total_devices: int,
) -> HloCost:
    if name in cache:
        return cache[name]
    cache[name] = HloCost()  # break cycles defensively
    cost = HloCost()
    symtab: dict[str, str] = {}
    for line in comps.get(name, ()):
        # /*index=N*/ comments inside long tuple types contain '=' and would
        # derail the instruction regex — strip them first.
        if "/*" in line:
            line = _COMMENT_RE.sub("", line)
        m = _INSTR_RE.match(line)
        if not m:
            continue
        out_name, out_type, op = m.group(1), m.group(2).strip(), m.group(3)
        symtab[out_name] = out_type
        out_bytes, out_elems = _shape_bytes_elems(out_type)

        if op in _FREE_OPS and op != "custom-call":
            continue

        if op == "while":
            body = re.search(r"body=%([\w.\-]+)", line)
            trips = 1
            tm = _TRIP_RE.search(line)
            if tm:
                trips = int(tm.group(1))
            else:
                cost.unknown_trip_whiles += 1
            if body:
                sub = _cost_of_computation(body.group(1), comps, cache, total_devices)
                cost.add(sub, trips)
            cond = re.search(r"condition=%([\w.\-]+)", line)
            if cond:
                sub = _cost_of_computation(cond.group(1), comps, cache, total_devices)
                cost.add(sub, trips)
            continue

        if op in ("fusion", "call"):
            callee = re.search(r"(?:calls|to_apply)=%([\w.\-]+)", line)
            if callee:
                sub = _cost_of_computation(callee.group(1), comps, cache, total_devices)
                # fusion: internal flops count, internal bytes do NOT (fused)
                c2 = HloCost(
                    flops=sub.flops,
                    transcendentals=sub.transcendentals,
                    bytes_accessed=0.0,
                    collective_bytes=sub.collective_bytes,
                    collective_breakdown=dict(sub.collective_breakdown),
                    collective_count=sub.collective_count,
                )
                cost.add(c2)
            # fusion I/O bytes: operands + result. In-place update pattern
            # (scan-state dynamic-update-slice fusions): an operand whose
            # type exactly matches an output element is the aliased buffer
            # XLA updates in place — counting it as a full read would charge
            # phantom traffic per loop trip, so it is excluded (the write is
            # still counted via out_bytes once).
            out_elem_types = set(
                f"{d}[{s}]" for d, s in _SHAPE_RE.findall(out_type)
            )
            # kLoop fusions are elementwise-shaped: each operand contributes
            # at most ~out_bytes of real reads (slice/gather fusions read a
            # window of a large buffer — charging the whole buffer per loop
            # trip charged 32x phantom traffic for scan-stacked params).
            # kInput/kOutput (reduce-rooted) fusions read operands fully.
            is_loop_fusion = "kind=kLoop" in line
            ops_bytes = 0
            tail = line.split(f"%{out_name}", 1)[1] if f"%{out_name}" in line else line
            for om in re.finditer(r"%([\w.\-]+)", tail):
                t = symtab.get(om.group(1))
                if not t:
                    continue
                o_types = set(f"{d}[{s}]" for d, s in _SHAPE_RE.findall(t))
                if o_types and o_types <= out_elem_types and len(out_elem_types) > 1:
                    continue  # aliased pass-through buffer (tuple fusions)
                b, _ = _shape_bytes_elems(t)
                if is_loop_fusion:
                    b = min(b, out_bytes)
                ops_bytes += b
            cost.bytes_accessed += out_bytes + ops_bytes
            cost.byte_details[f"fusion {out_type.split('{')[0][:80]}"] += (
                out_bytes + ops_bytes
            )
            continue

        if op == "dynamic-update-slice":
            # in-place update: traffic = the update operand, not the buffer
            ops_list = re.findall(r"%([\w.\-]+)", line.split("(", 1)[1])
            upd_bytes = 0
            if len(ops_list) >= 2:
                t = symtab.get(ops_list[1])
                if t:
                    upd_bytes, _ = _shape_bytes_elems(t)
            cost.bytes_accessed += 2 * (upd_bytes or out_bytes)
            cost.byte_details[f"dus {out_type.split('{')[0][:60]}"] += 2 * (
                upd_bytes or out_bytes
            )
            cost.flops += out_elems
            continue

        if op == "conditional":
            branches = re.findall(r"%([\w.\-]+)", line)
            sub_costs = [
                _cost_of_computation(b, comps, cache, total_devices)
                for b in branches
                if b in comps
            ]
            if sub_costs:
                cost.add(max(sub_costs, key=lambda c: c.flops))
            continue

        if any(op.startswith(c) for c in COLLECTIVES):
            base = next(c for c in COLLECTIVES if op.startswith(c))
            if op.endswith("-done"):
                continue
            n = _group_size(line, total_devices)
            if base == "all-reduce":
                moved = 2.0 * (n - 1) / max(n, 1) * out_bytes
            elif base == "all-gather":
                moved = (n - 1) / max(n, 1) * out_bytes
            elif base == "reduce-scatter":
                moved = (n - 1) * out_bytes  # out is the scattered shard
            elif base == "all-to-all":
                moved = (n - 1) / max(n, 1) * out_bytes
            else:  # collective-permute
                moved = float(out_bytes)
            cost.collective_bytes += moved
            cost.collective_breakdown[base] += moved
            cost.collective_count += 1
            cost.bytes_accessed += 2 * out_bytes
            shps = _SHAPE_RE.findall(out_type)
            label = "+".join(f"{d}[{s}]" for d, s in shps[:4]) or "?"
            if len(shps) > 4:
                label += f"+{len(shps) - 4}more"
            cost.details[f"{base} {label} n={n}"] += moved
            continue

        if op == "dot":
            # FLOPs = 2 * prod(result dims) * prod(contracting sizes of lhs)
            operands = re.findall(r"\(%([\w.\-]+)[,)]", line)
            lhs_m = re.search(r"dot\(%([\w.\-]+)", line)
            lhs_type = symtab.get(lhs_m.group(1), "") if lhs_m else ""
            lhs_dims = _shape_dims(lhs_type)
            cdims_m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            k = 1
            if cdims_m and cdims_m.group(1) and lhs_dims:
                for ci in cdims_m.group(1).split(","):
                    ci = int(ci)
                    if ci < len(lhs_dims):
                        k *= lhs_dims[ci]
            res_elems = 1
            for d in _shape_dims(out_type):
                res_elems *= d
            cost.flops += 2.0 * res_elems * k
            cost.flop_details[f"dot {out_type.split('{')[0]} k={k}"] += (
                2.0 * res_elems * k
            )
            in_bytes = 0
            for o in operands[:2]:
                t = symtab.get(o)
                if t:
                    b, _ = _shape_bytes_elems(t)
                    in_bytes += b
            cost.bytes_accessed += out_bytes + in_bytes
            cost.byte_details[f"dot {out_type.split('{')[0]}"] += out_bytes + in_bytes
            continue

        if op == "convolution":
            # rough: treat like dot over the window
            cost.flops += 2.0 * out_elems
            cost.bytes_accessed += 2 * out_bytes
            continue

        # generic elementwise / reduce / select / copy / dynamic-slice ...
        if op in _TRANSCENDENTAL_OPS:
            cost.transcendentals += out_elems
            cost.flops += out_elems
        elif op in ("reduce", "reduce-window", "sort", "scatter", "gather",
                    "dynamic-slice", "dynamic-update-slice", "pad", "slice",
                    "concatenate", "broadcast", "transpose", "copy", "select",
                    "compare", "convert", "clamp", "map"):
            cost.flops += out_elems
        else:
            cost.flops += out_elems
        cost.bytes_accessed += 2 * out_bytes
        cost.byte_details[f"{op} {out_type.split('{')[0]}"] += 2 * out_bytes

    cache[name] = cost
    return cost


def parse_hlo_cost(hlo_text: str, total_devices: int = 1) -> HloCost:
    """Whole-module per-device cost with while-loops scaled by trip count."""
    comps = _split_computations(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        m = re.match(r"^ENTRY\s+%([\w.\-]+)", line)
        if m:
            entry = m.group(1)
            break
    if entry is None:
        raise ValueError("no ENTRY computation found")
    cache: dict[str, HloCost] = {}
    # Cost every computation reachable from ENTRY only (fusion bodies are
    # reached via call sites; costing them directly would double count).
    return _cost_of_computation(entry, comps, cache, total_devices)


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_accessed: float
    collective_bytes: float
    model_flops: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_frac(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chip's compute roof achieved at the modelled
        bound: (useful model FLOPs / bound time) / peak."""
        if not self.bound_time_s:
            return 0.0
        hw = HW()
        return (self.model_flops / self.bound_time_s) / hw.peak_flops


def roofline_terms(
    cost: HloCost,
    *,
    chips: int,
    model_flops_total: float = 0.0,
    hw: HW | None = None,
) -> RooflineTerms:
    """Per-device HloCost -> roofline terms (seconds).

    ``cost`` is already per-device (partitioned HLO local shapes), so the
    denominators are per-chip rates; ``model_flops_total`` is the *global*
    useful-work estimate and is divided by ``chips`` here.
    """
    hw = hw or HW()
    return RooflineTerms(
        compute_s=cost.flops / hw.peak_flops,
        memory_s=cost.bytes_accessed / hw.hbm_bw,
        collective_s=cost.collective_bytes / hw.ici_bw,
        flops=cost.flops,
        bytes_accessed=cost.bytes_accessed,
        collective_bytes=cost.collective_bytes,
        model_flops=model_flops_total / max(chips, 1),
    )
