"""Optimizers: AdamW (hand-rolled, pytree-native) and plain SGD/LMS.

Moment dtype is configurable (``bfloat16`` halves optimizer HBM for the
480B-class archs); moments inherit the parameter shardings, so with FSDP
params the optimizer state is ZeRO-sharded for free.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "sgd_update", "global_norm"]


class AdamWState(NamedTuple):
    m: Any  # pytree like params
    v: Any
    count: jax.Array


def adamw_init(params: Any, moment_dtype: jnp.dtype = jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)  # noqa: E731
    return AdamWState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def adamw_update(
    params: Any,
    grads: Any,
    state: AdamWState,
    lr: float | jax.Array,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> tuple[Any, AdamWState]:
    count = state.count + 1
    if grad_clip:
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    c1 = 1.0 - b1**count.astype(jnp.float32)
    c2 = 1.0 - b2**count.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        step = (m32 / c1) / (jnp.sqrt(v32 / c2) + eps)
        if weight_decay and p.ndim >= 2:  # no decay on norms/biases
            step = step + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * step
        return p_new.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(m=new_m, v=new_v, count=count)


def sgd_update(params: Any, grads: Any, lr: float | jax.Array) -> Any:
    return jax.tree.map(lambda p, g: (p - lr * g.astype(p.dtype)).astype(p.dtype), params, grads)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )
