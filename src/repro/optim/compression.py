"""Gradient compression for DCN-bound (cross-pod) gradient reduction.

int8 symmetric quantization with error feedback (EF-SGD): the quantization
residual is carried and re-added next round, so compression error
accumulates to O(1) instead of O(T). Used by the diffusion-KLMS combine
(core/distributed.py) and available to the trainer for cross-pod all-reduce.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["CompressionState", "init_state", "compress_tree", "decompress_tree"]


class CompressionState(NamedTuple):
    residual: Any  # pytree like grads


def init_state(grads: Any) -> CompressionState:
    return CompressionState(
        residual=jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)
    )


def _q(v):
    scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(v / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_tree(
    grads: Any, state: CompressionState
) -> tuple[Any, Any, CompressionState]:
    """Returns (int8 tree, scale tree, new state with residuals)."""
    msg = jax.tree.map(
        lambda g, r: g.astype(jnp.float32) + r, grads, state.residual
    )
    qs = jax.tree.map(_q, msg, is_leaf=lambda x: isinstance(x, jnp.ndarray))
    q_tree = jax.tree.map(lambda t: t[0], qs, is_leaf=lambda t: isinstance(t, tuple))
    s_tree = jax.tree.map(lambda t: t[1], qs, is_leaf=lambda t: isinstance(t, tuple))
    deq = jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, q_tree, s_tree)
    new_res = jax.tree.map(lambda m, d: m - d, msg, deq)
    return q_tree, s_tree, CompressionState(residual=new_res)


def decompress_tree(q_tree: Any, s_tree: Any) -> Any:
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, q_tree, s_tree)
