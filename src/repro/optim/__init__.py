from repro.optim.optimizers import (
    AdamWState,
    adamw_init,
    adamw_update,
    global_norm,
    sgd_update,
)
from repro.optim import schedules

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "global_norm",
    "sgd_update",
    "schedules",
]
