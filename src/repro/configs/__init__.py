"""Architecture registry: ``get_config(arch_id)`` for all assigned archs."""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, MLAConfig, ModelConfig, MoEConfig, ShapeSpec

_ARCHS = {
    "internvl2-2b": "internvl2_2b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "arctic-480b": "arctic_480b",
    "mamba2-130m": "mamba2_130m",
    "command-r-35b": "command_r_35b",
    "minicpm3-4b": "minicpm3_4b",
    "llama3-8b": "llama3_8b",
    "qwen2-0.5b": "qwen2_0_5b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "musicgen-large": "musicgen_large",
}

ARCH_IDS = tuple(_ARCHS)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{_ARCHS[arch_id]}")
    return mod.CONFIG


__all__ = [
    "ARCH_IDS",
    "get_config",
    "ModelConfig",
    "MoEConfig",
    "MLAConfig",
    "ShapeSpec",
    "SHAPES",
]
