"""arctic-480b [moe] — Snowflake Arctic: dense residual + 128e top-2 MoE
[hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000; every layer runs a
dense FFN residually in parallel with a 128-expert top-2 MoE.

bf16 optimizer moments (opt_dtype) — at 480B params the f32-moment AdamW
state would exceed v5e HBM at 256 chips; see EXPERIMENTS.md memory notes.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    head_dim=128,
    pad_heads_to=64,
    attention="gqa",
    moe=MoEConfig(
        num_experts=128, top_k=2, d_ff_expert=4864, dense_residual_ff=4864
    ),
    opt_dtype="bfloat16",
    zero_stage=3,
    # 4 microbatches (64-seq micro, 4 seqs/device): amortizes the ZeRO-3
    # per-use expert-weight all-gathers 4x vs 1-seq microbatches (see
    # EXPERIMENTS.md section Perf, cell A)
    train_microbatches=4,
)
