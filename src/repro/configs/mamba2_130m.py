"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

24L d_model=768, attention-free, d_ff=0, vocab=50280, ssm_state=128.
The paper's technique (RFF) is inapplicable: SSD already has a fixed-size
state and no kernel to approximate — runs WITHOUT the technique
(DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=24,
    num_kv_heads=24,
    d_ff=0,
    vocab_size=50280,
    attention="none",
    mixer="mamba2",
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    tie_embeddings=True,
    rff_long_context=False,  # native fixed-state long context
    preferred_parallelism="dp",
)
