"""minicpm3-4b [dense] — deep-thin MLA [hf:openbmb/MiniCPM3-4B].

62L d_model=2560 40H d_ff=6400 vocab=73448; MLA with kv_lora_rank=256,
q_lora_rank=768, qk 64+32, v 64 (HF config values).
"""
from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attention="mla",
    pad_heads_to=48,
    mla=MLAConfig(
        kv_lora_rank=256,
        q_lora_rank=768,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
    # train deployment: FSDP over all 256 chips (2.7-5.8x better modelled
    # step time than TP-16; see EXPERIMENTS.md section Perf)
    train_parallelism="fsdp",
)
