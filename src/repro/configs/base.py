"""Config system: one immutable dataclass per architecture.

Every assigned architecture (and the paper's own experiments) is expressed as
a ``ModelConfig``; the unified ``TransformerLM`` assembles blocks from it.
``reduced()`` derives the CPU-smoke-test version of any config.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

import jax.numpy as jnp

__all__ = ["MoEConfig", "MLAConfig", "ModelConfig", "SHAPES", "ShapeSpec"]


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN (GShard-style capacity dispatch)."""

    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0  # shared (always-on) experts, deepseek-style
    dense_residual_ff: int = 0  # arctic: parallel dense FFN width (0 = off)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3)."""

    kv_lora_rank: int
    q_lora_rank: int = 0  # 0 = no query compression
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0  # 0 = d_model // num_heads
    # Pad query heads to this count for TP-axis divisibility (0 = off). The
    # extra heads are INERT: a constant zero head-mask before the output
    # projection keeps the function and all gradients exactly equal to the
    # unpadded architecture — the padding only buys an evenly-shardable head
    # axis. (GSPMD argument shardings must divide evenly.)
    pad_heads_to: int = 0
    attention: str = "gqa"  # gqa | mla | rff | none
    mixer: str = "attention"  # attention | mamba2 | rglru_hybrid
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    pad_vocab_to: int = 256  # vocab padding multiple (0 = off); inert slots

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None

    # mamba2 (ssm)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4

    # rglru hybrid (recurrentgemma): 1 local-attention block per `attn_every`
    lru_width: int = 0
    local_window: int = 2048
    attn_every: int = 3

    # RFF linear attention (the paper's technique; used natively when
    # attention == "rff", or substituted for long-context decode when
    # ``rff_long_context`` is True — see DESIGN.md long_500k policy)
    rff_num_features: int = 256
    rff_chunk: int = 256
    rff_long_context: bool = True

    # modality frontend stub: None | "vision" | "audio" — inputs arrive as
    # precomputed frame/patch embeddings (B, S, d_model) instead of token ids
    frontend: Optional[str] = None

    dtype: str = "bfloat16"
    # training
    remat: bool = True
    scan_layers: bool = True
    opt_dtype: str = "float32"  # adam moment dtype ("bfloat16" for 480B)
    # "tp":   TP on the model axis (+ ZeRO over data axes per zero_stage).
    # "dp":   replicate params, shard batch over every axis — the right
    #         mapping for sub-1B archs where 16-way TP is pure overhead.
    # "fsdp": shard weights' contraction dims over ALL axes, batch over all
    #         axes, weights all-gathered at use — the right mapping for
    #         1-40B dense models on 256 chips (weight-gather bytes are far
    #         below Megatron activation-AR bytes at these sizes).
    preferred_parallelism: str = "tp"
    # per-kind override: training deployments often want a different mapping
    # than serving (e.g. llama3: fsdp train / tp serve). Empty = preferred.
    train_parallelism: str = ""
    # ZeRO stage for optimizer/param sharding over the data axes:
    #  1 = params TP-only (replicated over data), adam moments data-sharded;
    #  3 = params also data-sharded (contraction dims) — needed when
    #      TP-sharded params alone exceed HBM (arctic-480b).
    zero_stage: int = 1
    # mesh axes carrying the batch dim of ACTIVATIONS inside the layer stack
    # (set by the launcher per cell). Without this constraint GSPMD may
    # resolve ZeRO-3 weight/activation conflicts by de-sharding the batch
    # and partial-sum all-reducing activations (observed on arctic-480b).
    activation_batch_axes: tuple = ()
    # explicit microbatch count for training (0 = one sequence per device);
    # larger microbatches amortize ZeRO-3 per-use weight gathers.
    train_microbatches: int = 0
    # stream the training loss logsumexp over this many vocab chunks
    # (1 = materialize full f32 logits)
    loss_vocab_chunks: int = 1
    # serve-time MoE layout: experts over `data` x expert-ff over `model`
    # (gather-free; tokens all-to-all to their experts). Set automatically
    # for zero-3 archs on non-train cells — re-gathering ZeRO-3 expert
    # shards per decoded token costs ~1.5 s/token (observed, arctic).
    expert_2d_shard: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_heads(self) -> int:
        return self.pad_heads_to or self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up for TP divisibility; padded logit slots are
        masked to -inf so the function equals the unpadded model exactly."""
        if not self.pad_vocab_to:
            return self.vocab_size
        m = self.pad_vocab_to
        return -(-self.vocab_size // m) * m

    @property
    def activation_dtype(self) -> jnp.dtype:
        return jnp.dtype(self.dtype)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            num_layers=2,
            d_model=64,
            pad_heads_to=0,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            local_window=32,
            rff_num_features=32,
            rff_chunk=16,
            ssm_chunk=16,
            lru_width=64 if self.lru_width else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16,
            dtype="float32",
            scan_layers=False,
            remat=False,
        )
        if self.moe is not None:
            kw["moe"] = replace(
                self.moe,
                num_experts=4,
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=32,
                num_shared=min(self.moe.num_shared, 1),
                dense_residual_ff=64 if self.moe.dense_residual_ff else 0,
            )
        if self.mla is not None:
            kw["mla"] = MLAConfig(
                kv_lora_rank=32,
                q_lora_rank=16 if self.mla.q_lora_rank else 0,
                qk_nope_head_dim=16,
                qk_rope_head_dim=8,
                v_head_dim=16,
            )
        if self.family == "hybrid":
            kw["num_layers"] = 3  # one full (rec, rec, attn) pattern
        return replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, l, v = self.d_model, self.num_layers, self.vocab_size
        dh = self.resolved_head_dim
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d
        per_layer = 2 * d  # norms
        if self.mixer == "attention":
            per_layer += self._attn_params(d, dh)
            per_layer += self._ffn_params(d)
        elif self.mixer == "mamba2":
            d_in = self.ssm_expand * d
            nheads = d_in // self.ssm_head_dim
            conv_dim = d_in + 2 * self.ssm_state
            per_layer += d * (2 * d_in + 2 * self.ssm_state + nheads)
            per_layer += conv_dim * self.conv_width
            per_layer += d_in * d  # out proj
            per_layer += 2 * nheads  # A, D
            per_layer += self._ffn_params(d)
        elif self.mixer == "rglru_hybrid":
            w = self.lru_width or d
            # recurrent block: in-proj x2, conv, lru gates x2 + lambda, out
            rec = d * w * 2 + w * self.conv_width + 2 * w * w + w + w * d
            att = self._attn_params(d, dh)
            per_layer += (2 * rec + att) / 3 + self._ffn_params(d)
        n += l * per_layer
        return int(n)

    def _attn_params(self, d: int, dh: int) -> int:
        if self.attention == "mla":
            m = self.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            n = d * m.kv_lora_rank + d * m.qk_rope_head_dim
            n += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
            if m.q_lora_rank:
                n += d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk
            else:
                n += d * self.num_heads * qk
            n += self.num_heads * m.v_head_dim * d
            return n
        n = d * self.num_heads * dh  # q
        n += 2 * d * self.num_kv_heads * dh  # k, v
        n += self.num_heads * dh * d  # o
        return n

    def _ffn_params(self, d: int) -> int:
        if self.moe is not None:
            m = self.moe
            expert = 3 * d * m.d_ff_expert  # gated MLP
            n = m.num_experts * expert + d * m.num_experts  # + router
            n += m.num_shared * expert
            if m.dense_residual_ff:
                n += 3 * d * m.dense_residual_ff
            return n
        return 3 * d * self.d_ff  # gated MLP (in, gate, out)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        expert = 3 * self.d_model * m.d_ff_expert
        inactive = (m.num_experts - m.top_k) * expert
        return int(self.param_count() - self.num_layers * inactive)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}
