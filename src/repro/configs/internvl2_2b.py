"""internvl2-2b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821].

Backbone only (InternLM2-1.8B): 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553. The ViT frontend is a stub: inputs arrive as precomputed patch
embeddings (B, S, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    head_dim=128,
    attention="gqa",
    frontend="vision",
    # train deployment: FSDP over all 256 chips (2.7-5.8x better modelled
    # step time than TP-16; see EXPERIMENTS.md section Perf)
    train_parallelism="fsdp",
)
