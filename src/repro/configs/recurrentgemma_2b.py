"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 [arXiv:2402.19427].

26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000, lru_width=2560,
sliding window 2048. Pattern: (recurrent, recurrent, local-attn) repeating —
8 scanned groups + 2 remainder recurrent blocks.

Fixed-size recurrent state + bounded attention window => native long-context
decode (no RFF substitution needed).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    pad_heads_to=16,
    attention="gqa",
    mixer="rglru_hybrid",
    lru_width=2560,
    local_window=2048,
    attn_every=3,
    rff_long_context=False,  # native fixed-state long context
    # train deployment: FSDP over all 256 chips (weight-gather bytes are
    # far below TP-16 Megatron activation-AR bytes at this size; see
    # EXPERIMENTS.md section Perf)
    train_parallelism="fsdp",
)
