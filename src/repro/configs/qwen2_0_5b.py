"""qwen2-0.5b [dense] — GQA with QKV bias [arXiv:2407.10671].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936; tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    head_dim=64,
    attention="gqa",
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    preferred_parallelism="dp",
)
