"""deepseek-v2-lite-16b [moe] — MLA + shared/routed MoE [arXiv:2405.04434].

27L d_model=2048 16H d_ff_expert=1408 vocab=102400, MoE 64 routed top-6 +
2 shared, MLA kv_lora_rank=512.

Assignment-note (also DESIGN.md §5): the spec line says both "64e top-6" and
"160 routed"; 160 routed belongs to the full V2-236B. We implement the
primary numbers: 64 routed / top-6 / 2 shared.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    attention="mla",
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=0,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408, num_shared=2),
    # train deployment: FSDP over all 256 chips (2.7-5.8x better modelled
    # step time than TP-16; see EXPERIMENTS.md section Perf)
    train_parallelism="fsdp",
)
