"""The paper's own experiment configurations (§5, §6) as named presets."""
from __future__ import annotations

from dataclasses import dataclass

__all__ = ["PaperExperiment", "EXPERIMENTS"]


@dataclass(frozen=True)
class PaperExperiment:
    name: str
    num_samples: int
    runs: int  # paper's Monte-Carlo run count
    sigma: float  # Gaussian kernel parameter
    mu: float  # step size
    rff_dim: int  # D for RFFKLMS
    qklms_eps: float  # quantization size for QKLMS
    qklms_capacity: int  # dictionary buffer bound
    # KRLS (example 2 only, §6)
    krls_lambda: float = 1e-4
    krls_beta: float = 0.9995
    krls_nu: float = 5e-4


EXPERIMENTS: dict[str, PaperExperiment] = {
    # §5.1 Fig 1: linear kernel expansion, steady state vs theory
    "example1": PaperExperiment(
        name="example1", num_samples=5000, runs=100, sigma=5.0, mu=1.0,
        rff_dim=1000, qklms_eps=0.0, qklms_capacity=0,
    ),
    # §5.2 Fig 2a/2b: nonlinear Wiener model (9)
    "example2": PaperExperiment(
        name="example2", num_samples=15000, runs=1000, sigma=5.0, mu=1.0,
        rff_dim=300, qklms_eps=5.0, qklms_capacity=256,
    ),
    # §5.3 Fig 3a: chaotic series 1
    "example3": PaperExperiment(
        name="example3", num_samples=500, runs=1000, sigma=0.05, mu=1.0,
        rff_dim=100, qklms_eps=0.01, qklms_capacity=64,
    ),
    # §5.4 Fig 3b: chaotic series 2
    "example4": PaperExperiment(
        name="example4", num_samples=1000, runs=1000, sigma=0.05, mu=1.0,
        rff_dim=100, qklms_eps=0.01, qklms_capacity=128,
    ),
}
