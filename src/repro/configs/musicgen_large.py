"""musicgen-large [audio] — decoder-only over EnCodec tokens [arXiv:2306.05284].

48L d_model=2048 32H (GQA kv=32, i.e. MHA) d_ff=8192 vocab=2048. The EnCodec
frontend is a stub: inputs arrive as precomputed frame embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    head_dim=64,
    attention="gqa",
    frontend="audio",
    # train deployment: FSDP over all 256 chips (2.7-5.8x better modelled
    # step time than TP-16; see EXPERIMENTS.md section Perf)
    train_parallelism="fsdp",
)
