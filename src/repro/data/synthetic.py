"""The paper's four experiment generators (§5.1–§5.4, §6) + model (7).

Each generator is a pure function of a PRNG key returning ``(xs, ys)`` (and
any ground-truth extras), so Monte-Carlo realizations are just a vmap/map over
split keys. All constants default to the paper's values.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.rff import gaussian_kernel

__all__ = [
    "KernelExpansionData",
    "gen_kernel_expansion",
    "gen_nonlinear_wiener",
    "gen_chaotic1",
    "gen_chaotic2",
    "make_lagged",
]


class KernelExpansionData(NamedTuple):
    xs: jax.Array  # (n, d)
    ys: jax.Array  # (n,)
    centers: jax.Array  # (M, d)
    coeffs: jax.Array  # (M,)


def gen_kernel_expansion(
    key: jax.Array,
    num_samples: int = 5000,
    input_dim: int = 5,
    num_centers: int = 10,
    sigma: float = 5.0,
    sigma_x: float = 1.0,
    sigma_eta: float = 0.1,
    coeff_std: float = 5.0,
) -> KernelExpansionData:
    """§5.1 / model (7): y = sum_m a_m kappa_sigma(c_m, x) + eta.

    a_m ~ N(0, 25) (coeff_std=5), x ~ N(0, I), eta ~ N(0, 0.1^2), sigma=5.
    """
    kc, ka, kx, ke = jax.random.split(key, 4)
    centers = jax.random.normal(kc, (num_centers, input_dim))
    coeffs = coeff_std * jax.random.normal(ka, (num_centers,))
    xs = sigma_x * jax.random.normal(kx, (num_samples, input_dim))
    kmat = gaussian_kernel(xs[:, None, :], centers[None, :, :], sigma)  # (n, M)
    ys = kmat @ coeffs + sigma_eta * jax.random.normal(ke, (num_samples,))
    return KernelExpansionData(xs=xs, ys=ys, centers=centers, coeffs=coeffs)


def gen_nonlinear_wiener(
    key: jax.Array,
    num_samples: int = 15000,
    input_dim: int = 5,
    sigma_eta: float = 0.05,
) -> tuple[jax.Array, jax.Array]:
    """§5.2 model (9): y = w0.x + 0.1 (w1.x)^2 + eta, w0/w1 ~ N(0, I)."""
    k0, k1, kx, ke = jax.random.split(key, 4)
    w0 = jax.random.normal(k0, (input_dim,))
    w1 = jax.random.normal(k1, (input_dim,))
    xs = jax.random.normal(kx, (num_samples, input_dim))
    ys = (
        xs @ w0
        + 0.1 * jnp.square(xs @ w1)
        + sigma_eta * jax.random.normal(ke, (num_samples,))
    )
    return xs, ys


def gen_chaotic1(
    key: jax.Array,
    num_samples: int = 500,
    sigma_u: float = 0.15,
    sigma_eta: float = 0.01,
    d_init: float = 1.0,
) -> tuple[jax.Array, jax.Array]:
    """§5.3: d_n = d_{n-1}/(1+d_{n-1}^2) + u_{n-1}^3;  y_n = d_n + eta_n.

    Inputs for the filter are ``x_n = (u_{n-1}, d_{n-1})`` (previous input and
    previous desired output — the standard setup for this series [20]).
    """
    ku, ke = jax.random.split(key)
    us = sigma_u * jax.random.normal(ku, (num_samples,))
    eta = sigma_eta * jax.random.normal(ke, (num_samples,))

    def body(d_prev, inp):
        u_prev, e = inp
        d = d_prev / (1.0 + d_prev**2) + u_prev**3
        return d, (d, d_prev)

    _, (ds, d_prevs) = jax.lax.scan(body, jnp.asarray(d_init), (us, eta))
    xs = jnp.stack([us, d_prevs], axis=-1)  # (n, 2)
    ys = ds + eta
    return xs, ys


def gen_chaotic2(
    key: jax.Array,
    num_samples: int = 1000,
    sigma_v2: float = 0.0156,
    sigma_eta: float = 0.001,
    d_init: float = 1.0,
) -> tuple[jax.Array, jax.Array]:
    """§5.4: ARMA-driven series through saturating nonlinearity phi.

    d_n = u_n + 0.5 v_n - 0.2 d_{n-1} + 0.35 d_{n-2}
    u_n = 0.5 v_n + eta_hat_n;  v, eta_hat iid N(0, 0.0156)
    y_n = phi(d_n) + eta_n
    Filter input: x_n = (u_n, v_n, d_{n-1}, d_{n-2})... the cited study [20]
    uses x_n = (u_n, u_{n-1}) (input regressor); we use the 2-lag input
    regressor (u_n, u_{n-1}) to match the nonlinear-channel setup.
    """
    kv, kh, ke = jax.random.split(key, 3)
    sv = jnp.sqrt(sigma_v2)
    vs = sv * jax.random.normal(kv, (num_samples,))
    eta_hat = sv * jax.random.normal(kh, (num_samples,))
    us = 0.5 * vs + eta_hat
    eta = sigma_eta * jax.random.normal(ke, (num_samples,))

    def body(carry, inp):
        d1, d2 = carry  # d_{n-1}, d_{n-2}
        u, v = inp
        d = u + 0.5 * v - 0.2 * d1 + 0.35 * d2
        return (d, d1), d

    _, ds = jax.lax.scan(
        body, (jnp.asarray(d_init), jnp.asarray(d_init)), (us, vs)
    )

    def phi(d):
        pos = d / (3.0 * jnp.sqrt(0.1 + 0.9 * d**2))
        neg = -jnp.square(d) * (1.0 - jnp.exp(0.7 * d)) / 3.0
        return jnp.where(d >= 0, pos, neg)

    ys = phi(ds) + eta
    u_prev = jnp.concatenate([jnp.zeros((1,)), us[:-1]])
    xs = jnp.stack([us, u_prev], axis=-1)  # (n, 2)
    return xs, ys


def make_lagged(series: jax.Array, num_lags: int) -> jax.Array:
    """Embed a scalar series into lag vectors: x_n = (s_n, ..., s_{n-L+1})."""
    cols = [jnp.roll(series, i) for i in range(num_lags)]
    x = jnp.stack(cols, axis=-1)
    return x.at[: num_lags - 1].set(0.0)
