"""Deterministic, seekable synthetic LM data pipeline.

Every batch is a pure function of ``(seed, step)`` via PRNG fold-in — no
iterator state to checkpoint, so restart-exactness is free: resuming at step
``n`` reproduces byte-identical batches regardless of how many workers died
in between. The same property gives elastic scaling (a re-sharded resume
consumes the identical global batch).

The token stream is a order-2 Markov chain over the vocab (cheap but
learnable structure, so training loss decreases measurably — used by the
end-to-end example).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["batch_at_step", "markov_batch"]


def batch_at_step(
    seed: int, step: int, *, global_batch: int, seq_len: int, vocab: int
) -> jax.Array:
    """(B, S) int32 tokens — pure function of (seed, step)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    return markov_batch(key, global_batch, seq_len, vocab)


def markov_batch(key: jax.Array, batch: int, seq_len: int, vocab: int) -> jax.Array:
    """Order-2-ish structured tokens: t_{n+1} = f(t_n) + small noise."""
    k1, k2, k3 = jax.random.split(key, 3)
    start = jax.random.randint(k1, (batch,), 0, vocab)
    # fixed pseudo-random transition: affine map mod vocab + occasional jump
    mult = 6364136223846793005 % vocab or 1
    noise = jax.random.bernoulli(k2, 0.1, (batch, seq_len))
    jumps = jax.random.randint(k3, (batch, seq_len), 0, vocab)

    def body(tok, inp):
        flip, jump = inp
        nxt = (tok * mult + 12345) % vocab
        nxt = jnp.where(flip, jump, nxt)
        return nxt, nxt

    _, toks = jax.lax.scan(
        body, start, (noise.T, jumps.T)
    )
    return toks.T.astype(jnp.int32)
