"""Observability layer: trace spans, dispatch telemetry, numerics probes.

Three tiers, all host-side and allocation-light so the serving hot path
stays one launch per flush:

* :mod:`repro.obs.trace` — nestable wall-clock spans over a bounded ring
  buffer, JSONL + Chrome trace-event (Perfetto) exports, and the
  active-tracer stack the serve/kernel layers emit into.
* :mod:`repro.obs.telemetry` — process-wide kernel-dispatch counters and
  bytes-moved gauges (the benches' closed-form models, live).
* :mod:`repro.obs.probes` — in-jit numerics health taps (finiteness,
  norms, KRLS P-matrix drift), the bf16 read-contract probe, and the
  threshold monitor that raises structured degradation events.
* :mod:`repro.obs.faults` — deterministic, seedable fault injection at
  flush boundaries, one fault kind per probe threshold (chaos tests and
  the recovery bench drive ``serve/recovery.py`` through it).

Wired through ``repro.serve.make_server(trace=..., probe=...)`` and
exported by ``Server.observability()``; see README "Observability".
"""
from repro.obs.trace import (
    Span,
    Tracer,
    activate,
    current_tracer,
    instant,
    span,
)
from repro.obs.probes import (
    DEFAULT_THRESHOLDS,
    DegradationEvent,
    ProbeMonitor,
    bf16_read_error,
    slot_stats,
    stats_tap,
)
from repro.obs import telemetry
from repro.obs.faults import FAULT_KINDS, Fault, FaultInjector, FaultPlan

__all__ = [
    "Span",
    "Tracer",
    "activate",
    "current_tracer",
    "instant",
    "span",
    "DEFAULT_THRESHOLDS",
    "DegradationEvent",
    "ProbeMonitor",
    "bf16_read_error",
    "slot_stats",
    "stats_tap",
    "telemetry",
    "FAULT_KINDS",
    "Fault",
    "FaultInjector",
    "FaultPlan",
]
