"""Deterministic fault injection for the serving stack's reliability loop.

PR 9's probes (obs/probes.py) detect degraded state; serve/recovery.py
repairs it. This module manufactures every failure mode that loop watches
for, on demand and reproducibly, so chaos tests and the recovery bench can
drive detection -> quarantine -> repair without waiting for real hardware
or numerics to misbehave:

* ``nan_state`` — poison one tenant's state leaves with NaN (the
  ``finite`` probe's target: a filter that silently went non-finite).
* ``asym_pmat`` — flip a KRLS P matrix off-symmetric by a relative delta
  (the ``pmat.asym_rel`` probe's target). On families without a true
  ``(D, D)`` P the fault degrades to an Inf poison (recorded in
  ``applied`` as ``effective="nonfinite"``) so the matrix stays total
  over all five learners.
* ``log_corrupt`` — overwrite one ReplayLog entry with NaN *and* poison
  the tenant's state: detection fires on ``finite``, and the recovery
  ladder's rebuild rung must then notice the corrupt log and fall
  through to reset instead of replaying garbage.
* ``drop_flush`` — silently discard a tenant's pending micro-batch
  backlog, bypassing the queue's accounting (the ``ticks_lag`` probe's
  target: arrivals acknowledged but never trained).
* ``clock_skew`` — wrap the snapshot tier's injectable clock with a
  constant offset (the ``clock_skew`` probe's target: a bad host clock
  silently starving or thrashing the age-watermark flush path).

Faults are declared in a :class:`FaultPlan` (each pinned to a tenant and
a flush index) and applied by a :class:`FaultInjector` that wraps the
snapshot tier's ``flush`` — the same boundary the probes sample at — so
an injected fault is observable at the very next tap readout. Everything
is seedable (:meth:`FaultPlan.random`) and pure host-side: injection
mutates state through the same ``tenant_row``-style primitives the
lifecycle tier uses, never through the jitted step programs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

__all__ = ["FAULT_KINDS", "Fault", "FaultPlan", "FaultInjector"]

FAULT_KINDS = (
    "nan_state",
    "asym_pmat",
    "log_corrupt",
    "drop_flush",
    "clock_skew",
)


@dataclass(frozen=True)
class Fault:
    """One scheduled fault: ``kind`` hits ``tenant`` just before the
    ``at_flush``-th flush the injector observes (0-based).

    ``magnitude`` scales the corruption: the relative off-symmetric delta
    for ``asym_pmat`` (default 0.05 — 5x the default ``pmat.asym_rel``
    threshold) and the clock offset in seconds for ``clock_skew``
    (tenant is ignored for this global kind).
    """

    kind: str
    tenant: int
    at_flush: int
    magnitude: float = 0.05

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; pick from {FAULT_KINDS}"
            )


@dataclass
class FaultPlan:
    """An ordered, deterministic set of :class:`Fault` declarations."""

    faults: list = field(default_factory=list)

    def due(self, flush_idx: int) -> list:
        """Faults scheduled for the given flush index, in plan order."""
        return [f for f in self.faults if f.at_flush == flush_idx]

    def kinds(self) -> list:
        return [f.kind for f in self.faults]

    @classmethod
    def random(
        cls,
        seed: int,
        tenants: int,
        *,
        n: int = 3,
        kinds=FAULT_KINDS,
        flush_lo: int = 1,
        flush_hi: int = 8,
        magnitude: float = 0.05,
    ) -> "FaultPlan":
        """A seed-deterministic plan: ``n`` faults drawn uniformly over
        ``kinds`` x ``[0, tenants)`` x ``[flush_lo, flush_hi)``."""
        rng = np.random.default_rng(seed)
        faults = [
            Fault(
                kind=str(rng.choice(list(kinds))),
                tenant=int(rng.integers(0, tenants)),
                at_flush=int(rng.integers(flush_lo, flush_hi)),
                magnitude=magnitude,
            )
            for _ in range(n)
        ]
        return cls(faults=faults)


def _is_rls_bank(state) -> bool:
    """A true RLS bank: a (B, D, D) ``pmat`` next to a theta row, not a
    dictionary state that happens to carry a P block."""
    return hasattr(state, "pmat") and not hasattr(state, "centers")


def _poison_leaf(state, slot: int, value: float):
    """Overwrite one float leaf's ``slot`` row with ``value`` (prefers a
    ``theta`` leaf so the poison is maximally visible to the probes)."""
    import jax
    import jax.numpy as jnp

    leaves, treedef = jax.tree_util.tree_flatten_with_path(state)
    target = None
    for i, (path, leaf) in enumerate(leaves):
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        name = str(path[-1]) if path else ""
        if "theta" in name or "coeffs" in name or "alpha" in name:
            target = i
            break
        if target is None:
            target = i
    if target is None:  # pragma: no cover - states always carry floats
        raise ValueError("state has no float leaf to poison")
    new_leaves = [
        leaf.at[slot].set(value) if i == target else leaf
        for i, (_, leaf) in enumerate(leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


class FaultInjector:
    """Apply a :class:`FaultPlan` to a live ``serve.api.Server`` at its
    flush boundaries.

    ``attach()`` wraps the snapshot tier's ``flush`` (an instance-level
    shadow, restored by ``detach()``); every wrapped call first applies
    the faults due at the current flush index, then runs the real flush —
    so the poisoned state is trained on and sampled by the in-jit tap in
    the same launch, exactly like an organic corruption would be.
    ``applied`` records what actually happened (kind, tenant, slot, flush
    index, and the effective corruption for degraded kinds).
    """

    def __init__(self, server, plan: FaultPlan):
        self.server = server
        self.plan = plan
        self.flushes = 0
        self.applied: list[dict] = []
        self._orig_flush = None
        self._orig_clock = None

    # -- lifecycle ----------------------------------------------------------

    def attach(self) -> "FaultInjector":
        if self._orig_flush is not None:
            raise RuntimeError("injector already attached")
        inner = self.server.snapshot_server
        orig = inner.flush

        def flush_with_faults():
            for fault in self.plan.due(self.flushes):
                self._apply(fault)
            self.flushes += 1
            return orig()

        self._orig_flush = orig
        inner.flush = flush_with_faults
        return self

    def detach(self) -> None:
        if self._orig_flush is None:
            return
        inner = self.server.snapshot_server
        if inner.__dict__.get("flush") is not None:
            del inner.flush
        self._orig_flush = None
        if self._orig_clock is not None:
            inner._clock = self._orig_clock
            self._orig_clock = None

    # -- application --------------------------------------------------------

    def _slot_of(self, tenant: int) -> Optional[int]:
        return self.server.resident.get(tenant)

    def _apply(self, fault: Fault) -> None:
        from repro.obs import trace as _trace

        record = {
            "kind": fault.kind,
            "tenant": fault.tenant,
            "flush": self.flushes,
            "effective": fault.kind,
        }
        if fault.kind == "clock_skew":
            self._skew_clock(fault.magnitude)
        else:
            slot = self._slot_of(fault.tenant)
            if slot is None:
                # Non-resident tenant: nothing in the bank to corrupt.
                record["effective"] = "skipped_cold"
                self.applied.append(record)
                return
            record["slot"] = slot
            if fault.kind == "nan_state":
                self._poison_state(slot, float("nan"))
            elif fault.kind == "asym_pmat":
                if not self._flip_asym(slot, fault.magnitude):
                    self._poison_state(slot, float("inf"))
                    record["effective"] = "nonfinite"
            elif fault.kind == "log_corrupt":
                self._corrupt_log(fault.tenant)
                self._poison_state(slot, float("nan"))
            elif fault.kind == "drop_flush":
                queue = self.server.queue
                record["dropped"] = len(queue._pending[slot])
                queue._pending[slot].clear()
        _trace.instant("fault.injected", **record)
        self.applied.append(record)

    def _poison_state(self, slot: int, value: float) -> None:
        queue = self.server.queue
        queue.state = _poison_leaf(queue.state, slot, value)

    def _flip_asym(self, slot: int, magnitude: float) -> bool:
        """Add an off-symmetric delta to P[slot]; False if no RLS P."""
        queue = self.server.queue
        state = queue.state
        if not _is_rls_bank(state):
            return False
        import jax.numpy as jnp

        scale = float(jnp.max(jnp.abs(state.pmat[slot])))
        delta = magnitude * max(scale, 1.0)
        queue.state = state._replace(
            pmat=state.pmat.at[slot, 0, 1].add(delta)
        )
        return True

    def _corrupt_log(self, tenant: int) -> None:
        log = self.server.log
        buf = log._buf.get(tenant) if log is not None else None
        if not buf:
            return
        idx = len(buf) // 2
        x, y = buf[idx]
        buf[idx] = (np.full_like(x, np.nan), y)

    def _skew_clock(self, offset: float) -> None:
        inner = self.server.snapshot_server
        if self._orig_clock is None:
            self._orig_clock = inner._clock
        base = inner._clock
        inner._clock = lambda: base() + offset
