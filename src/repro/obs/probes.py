"""In-jit numerics health probes and the host-side degradation monitor.

An online kernel filter that silently went non-finite (or whose KRLS P
matrix drifted off symmetric-positive) keeps serving garbage at full
throughput — counters and latency histograms never notice. These probes
make state health observable without breaking the serving hot path's
one-launch contract:

* :func:`stats_tap` — ONE fused reduction pass over the float leaves of a
  state pytree, built to run *inside* the existing jitted step/flush
  programs (the micro-batch queue composes it after its chunk step, so
  flush stays a single XLA program; see
  ``MicroBatchQueue.attach_probe``). It computes finiteness, max-abs and
  norm statistics plus the KRLS-specific P-matrix asymmetry and
  conditioning proxies, and returns a flat ``{name: 0-d array}`` dict
  that is only materialized host-side at flush boundaries.
* :func:`bf16_read_error` — the read-contract probe: relative error of
  the bf16 read path vs the f32 contract on a sampled query block
  (host-side, on demand — it runs two small predict launches).
* :class:`ProbeMonitor` — host-side thresholds over the tap's numbers
  (plus snapshot staleness in ticks). Breaches raise structured
  :class:`DegradationEvent` records, emit ``probe.degraded`` instant
  events into the active trace (repro/obs/trace.py) and increment a
  labeled ``probe.degraded{probe=...}`` counter — the hook the
  non-stationary ARFF direction's drift detection plugs into.

The tap only *reads* state leaves that the step program already produced,
so attaching it must not perturb training numerics — pinned by the
traced-vs-untraced bitwise equivalence test in tests/test_obs.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.obs import trace as obtrace

__all__ = [
    "DEFAULT_THRESHOLDS",
    "DegradationEvent",
    "ProbeMonitor",
    "bf16_read_error",
    "slot_stats",
    "stats_tap",
]

_TINY = 1e-30


def _path_name(path) -> str:
    parts = []
    for p in path:
        name = getattr(p, "name", None)
        if name is None:
            name = getattr(p, "key", None)
        if name is None:
            name = getattr(p, "idx", None)
        parts.append(str(name))
    return ".".join(parts) if parts else "leaf"


def stats_tap(state) -> dict[str, jax.Array]:
    """Fused numerics reduction over a (bank) state pytree — jit-safe.

    Returns a flat dict of 0-d arrays:

    * ``finite`` — 1.0 iff every float leaf is entirely finite;
    * ``<leaf>.max_abs`` — per float leaf;
    * ``theta.norm_max`` — largest per-row L2 norm of a ``theta`` leaf
      (rows = bank slots; the theta-growth probe);
    * ``pmat.asym_rel`` — ``max|P - P^T| / max|P|`` over the bank (an
      exactly-maintained RLS downdate keeps this at rounding level);
    * ``pmat.diag_min`` / ``pmat.diag_max`` / ``pmat.cond_proxy`` — the
      diagonal spread of P as a cheap conditioning-drift proxy (the true
      condition number needs an SVD; the diagonal ratio flags the same
      blowups for orders-of-magnitude monitoring).

    Integer leaves (step counters) are skipped. All outputs are f32.
    """
    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    stats: dict[str, jax.Array] = {}
    finite = jnp.asarray(True)
    for path, leaf in leaves:
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        name = _path_name(path)
        leaf32 = leaf.astype(jnp.float32)
        finite = jnp.logical_and(finite, jnp.all(jnp.isfinite(leaf)))
        stats[f"{name}.max_abs"] = jnp.max(jnp.abs(leaf32))
        if name.endswith("theta") and leaf.ndim >= 1:
            norms = jnp.sqrt(jnp.sum(leaf32 * leaf32, axis=-1))
            stats["theta.norm_max"] = jnp.max(norms)
        if name.endswith("pmat") and leaf.ndim >= 2:
            asym = jnp.max(
                jnp.abs(leaf32 - jnp.swapaxes(leaf32, -1, -2))
            )
            scale = jnp.max(jnp.abs(leaf32))
            stats["pmat.asym_rel"] = asym / (scale + _TINY)
            diag = jnp.abs(
                jnp.diagonal(leaf32, axis1=-2, axis2=-1)
            )
            dmin, dmax = jnp.min(diag), jnp.max(diag)
            stats["pmat.diag_min"] = dmin
            stats["pmat.diag_max"] = dmax
            # Zero diagonal entries are empty dictionary rows (ALD's
            # unused capacity), not conditioning blowups — the proxy
            # spreads only over the occupied part.
            dmin_pos = jnp.min(jnp.where(diag > 0, diag, jnp.inf))
            stats["pmat.cond_proxy"] = jnp.where(
                jnp.isinf(dmin_pos), 0.0, dmax / (dmin_pos + _TINY)
            )
    stats["finite"] = finite.astype(jnp.float32)
    return stats


@jax.jit
def slot_stats(state) -> dict[str, jax.Array]:
    """Per-slot diagnostics for the recovery tier: the same quantities
    :func:`stats_tap` reduces over the whole bank, kept per slot.

    Returns ``(B,)`` arrays — ``finite`` (1.0/0.0 per slot),
    ``theta.norm`` (per-row L2 when a theta leaf exists), and
    ``pmat.asym_rel`` / ``pmat.cond_proxy`` when a P leaf exists. The
    bank-global tap stays one fused reduction on the hot path; this
    per-slot pass runs only on the rare event path, where the recovery
    policy must localize a degradation to a tenant before quarantining.
    """
    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    bsz = leaves[0][1].shape[0]
    stats: dict[str, jax.Array] = {}
    finite = jnp.ones((bsz,), dtype=bool)
    for path, leaf in leaves:
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        name = _path_name(path)
        leaf32 = leaf.astype(jnp.float32)
        axes = tuple(range(1, leaf.ndim))
        finite = jnp.logical_and(
            finite, jnp.all(jnp.isfinite(leaf), axis=axes)
        )
        if name.endswith("theta") and leaf.ndim >= 2:
            stats["theta.norm"] = jnp.sqrt(
                jnp.sum(leaf32 * leaf32, axis=-1)
            )
        if name.endswith("pmat") and leaf.ndim >= 3:
            asym = jnp.max(
                jnp.abs(leaf32 - jnp.swapaxes(leaf32, -1, -2)),
                axis=(-2, -1),
            )
            scale = jnp.max(jnp.abs(leaf32), axis=(-2, -1))
            stats["pmat.asym_rel"] = asym / (scale + _TINY)
            diag = jnp.abs(jnp.diagonal(leaf32, axis1=-2, axis2=-1))
            # Same empty-dictionary-row exclusion as the bank-global tap.
            dmin_pos = jnp.min(
                jnp.where(diag > 0, diag, jnp.inf), axis=-1
            )
            stats["pmat.cond_proxy"] = jnp.where(
                jnp.isinf(dmin_pos),
                0.0,
                jnp.max(diag, axis=-1) / (dmin_pos + _TINY),
            )
    stats["finite"] = finite.astype(jnp.float32)
    return stats


def bf16_read_error(
    state,
    feature_map,
    xq,
    *,
    mode: str = "auto",
) -> float:
    """Max relative error of the bf16 read contract vs the f32 contract on
    one ``(B, Q, d)`` query block (host-side; two predict launches)."""
    from repro.core.bank import bank_predict_block

    f32 = bank_predict_block(state, xq, feature_map, mode=mode,
                             precision=None)
    bf16 = bank_predict_block(state, xq, feature_map, mode=mode,
                              precision="bf16")
    f32 = jnp.asarray(f32, jnp.float32)
    bf16 = jnp.asarray(bf16, jnp.float32)
    denom = jnp.max(jnp.abs(f32)) + 1e-6
    return float(jnp.max(jnp.abs(bf16 - f32)) / denom)


@dataclass(frozen=True)
class DegradationEvent:
    """One threshold breach, structured for the trace and the export."""

    probe: str
    value: float
    threshold: float
    direction: str  # "above" | "below"
    tick: Optional[int] = None

    def to_dict(self) -> dict:
        return {
            "probe": self.probe,
            "value": self.value,
            "threshold": self.threshold,
            "direction": self.direction,
            "tick": self.tick,
        }


# probe -> ("max" breaches above, "min" breaches below), threshold value.
# ``ticks_lag`` (acknowledged-but-never-trained arrivals, from the serve
# facade's expected-ticks ledger) is active by default at 0: any positive
# lag means observations were silently lost between queue and bank.
# ``clock_skew`` ships off (inf) — it needs a trusted reference clock,
# which only the recovery tier provides.
DEFAULT_THRESHOLDS: dict[str, tuple[str, float]] = {
    "finite": ("min", 1.0),
    "theta.norm_max": ("max", 1e6),
    "pmat.asym_rel": ("max", 1e-2),
    "pmat.cond_proxy": ("max", 1e12),
    "staleness_ticks": ("max", float("inf")),
    "bf16_read_error": ("max", 2e-2),
    "ticks_lag": ("max", 0.0),
    "clock_skew": ("max", float("inf")),
}


class ProbeMonitor:
    """Threshold monitor over :func:`stats_tap` outputs.

    Args:
      thresholds: overrides merged over :data:`DEFAULT_THRESHOLDS` —
        either ``{"name": value}`` (direction from the default table,
        "max" for unknown names) or ``{"name": ("min"|"max", value)}``.
      registry: optional :class:`~repro.serve.metrics.MetricsRegistry`
        receiving the ``probe.degraded{probe=...}`` counters.
      max_events: degradation events retained (older ones drop; the
        total count is kept).
    """

    def __init__(
        self,
        thresholds: Optional[dict] = None,
        registry=None,
        max_events: int = 64,
    ):
        merged: dict[str, tuple[str, float]] = dict(DEFAULT_THRESHOLDS)
        for name, spec in (thresholds or {}).items():
            if isinstance(spec, tuple):
                direction, value = spec
            else:
                direction = DEFAULT_THRESHOLDS.get(name, ("max", 0.0))[0]
                value = spec
            merged[name] = (direction, float(value))
        self.thresholds = merged
        self.registry = registry
        self.max_events = max_events
        self.events: list[DegradationEvent] = []
        self.total_events = 0
        self.last_stats: dict[str, float] = {}
        self.last_tick: Optional[int] = None
        self.updates = 0
        self._subscribers: list[Callable[[DegradationEvent], None]] = []

    def subscribe(self, fn: Callable[[DegradationEvent], None]) -> None:
        """Register a callback invoked (synchronously, from ``update``)
        for every degradation event. Subscribers must only *record* the
        event — the recovery tier enqueues and acts later, outside the
        update, so a callback can never mutate state mid-probe."""
        self._subscribers.append(fn)

    def _fire(self, ev: DegradationEvent) -> None:
        self.total_events += 1
        self.events.append(ev)
        if len(self.events) > self.max_events:
            self.events.pop(0)
        obtrace.instant("probe.degraded", **ev.to_dict())
        if self.registry is not None:
            self.registry.counter("probe.degraded", probe=ev.probe).inc()
        for fn in self._subscribers:
            fn(ev)

    def update(
        self,
        stats: dict[str, Any],
        *,
        tick: Optional[int] = None,
        staleness: Optional[int] = None,
        bf16_err: Optional[float] = None,
    ) -> list[DegradationEvent]:
        """Fold one tap readout (plus optional host-side probes) in;
        returns the degradation events it raised."""
        flat = {k: float(v) for k, v in stats.items()}
        if staleness is not None:
            flat["staleness_ticks"] = float(staleness)
        if bf16_err is not None:
            flat["bf16_read_error"] = float(bf16_err)
        self.last_stats = flat
        self.last_tick = tick
        self.updates += 1
        fired = []
        for name, value in flat.items():
            spec = self.thresholds.get(name)
            if spec is None:
                continue
            direction, bound = spec
            breached = value > bound if direction == "max" else value < bound
            if breached:
                ev = DegradationEvent(
                    probe=name,
                    value=value,
                    threshold=bound,
                    direction="above" if direction == "max" else "below",
                    tick=tick,
                )
                self._fire(ev)
                fired.append(ev)
        return fired

    def healthy(self) -> bool:
        """True iff no degradation event has ever fired."""
        return self.total_events == 0

    def state(self) -> dict:
        """JSON-able export for ``Server.observability()`` and the Zipf
        bench's numerics-health columns."""
        return {
            "last": dict(self.last_stats),
            "last_tick": self.last_tick,
            "updates": self.updates,
            "healthy": self.healthy(),
            "total_events": self.total_events,
            "events": [ev.to_dict() for ev in self.events],
            "thresholds": {
                k: {"direction": d, "value": v}
                for k, (d, v) in sorted(self.thresholds.items())
                if v != float("inf")
            },
        }
