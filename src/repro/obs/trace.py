"""Nestable wall-clock trace spans over a bounded ring buffer.

The serving stack (facade -> queue -> snapshot -> kernel dispatch) is
host-side and synchronous, so a plain span stack gives an exact causal
tree of every request: ``serve.submit`` contains ``queue.flush`` contains
``snapshot.publish`` contains nothing, and the first flush additionally
contains the trace-time ``kernel.*`` dispatch spans. This module is the
smallest tracer that supports that:

* :class:`Tracer` — ``with tracer.span("serve.flush", tenant=3):``
  records one completed :class:`Span` (name, start/end, attributes,
  parent id, depth) into a bounded ring buffer. Overflow drops the
  *oldest* spans and counts them (``dropped``), so a long-running server
  keeps the recent window instead of growing without bound; both export
  formats carry a ``truncated`` flag.
* Exports: :meth:`Tracer.to_jsonl` (one JSON object per span — the
  greppable form) and :meth:`Tracer.to_chrome_trace` (Chrome
  trace-event JSON: load the file at ``chrome://tracing`` or
  https://ui.perfetto.dev to see the span tree on a timeline).
* Instant events (:meth:`Tracer.instant`) for the probe tier's
  degradation events — zero-duration marks on the same timeline.
* An optional JAX bridge (``jax_annotations=True``): every span also
  enters ``jax.profiler.TraceAnnotation``/``jax.named_scope`` so host
  spans line up with device timelines when a ``jax.profiler`` trace is
  being captured, and compiled HLO carries the span names.

The **active-tracer stack** is how instrumentation points deep in the
stack (queue, snapshot, kernel dispatch, core bank) emit spans without
threading a tracer through every signature: the facade activates its
tracer around each request (``with activate(tracer):``) and the
module-level :func:`span`/:func:`instant` helpers no-op (one list check)
when nothing is active — the untraced hot path stays unperturbed. Like
the queue itself, the stack is deliberately single-threaded state.
"""
from __future__ import annotations

import contextlib
import json
import time
from collections import deque
from typing import Any, Callable, Iterator, Optional

__all__ = [
    "Span",
    "Tracer",
    "activate",
    "current_tracer",
    "instant",
    "span",
]


class Span:
    """One completed (or still-open) trace span."""

    __slots__ = (
        "name", "span_id", "parent_id", "depth", "t0", "t1", "attrs", "kind",
    )

    def __init__(self, name: str, span_id: int, parent_id: Optional[int],
                 depth: int, t0: float, attrs: dict):
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.depth = depth
        self.t0 = t0
        self.t1: Optional[float] = None
        self.attrs = attrs
        self.kind = "span"

    @property
    def duration(self) -> float:
        """Seconds (0.0 while still open and for instant events)."""
        return (self.t1 - self.t0) if self.t1 is not None else 0.0

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "id": self.span_id,
            "parent": self.parent_id,
            "depth": self.depth,
            "ts_us": round(self.t0 * 1e6, 3),
            "dur_us": round(self.duration * 1e6, 3),
            "kind": self.kind,
            "attrs": self.attrs,
        }


class Tracer:
    """Span recorder with a bounded ring buffer and stable exports.

    Args:
      capacity: completed spans/events kept; older ones are dropped (and
        counted in :attr:`dropped` / the exports' ``truncated`` flag).
      clock: injectable monotonic clock in seconds (tests pass a fake).
      jax_annotations: also wrap every span in
        ``jax.profiler.TraceAnnotation`` + ``jax.named_scope`` so device
        profiles and compiled HLO line up with host span names.
    """

    def __init__(self, capacity: int = 4096,
                 clock: Callable[[], float] = time.perf_counter,
                 jax_annotations: bool = False):
        if capacity < 1:
            raise ValueError("trace capacity must be >= 1")
        self.capacity = capacity
        self._clock = clock
        self._origin = clock()
        self._done: deque[Span] = deque()
        self._stack: list[Span] = []
        self._next_id = 0
        self.dropped = 0
        self._jax_ctx = None
        if jax_annotations:
            self._jax_ctx = self._make_jax_ctx()

    @staticmethod
    def _make_jax_ctx():
        try:
            import jax

            annotation = jax.profiler.TraceAnnotation
            named_scope = jax.named_scope
        except (ImportError, AttributeError):  # pragma: no cover - jax baked in
            return None

        @contextlib.contextmanager
        def ctx(name: str):
            with annotation(name), named_scope(name):
                yield

        return ctx

    # -- recording ---------------------------------------------------------

    def _now(self) -> float:
        return self._clock() - self._origin

    def _record(self, sp: Span) -> None:
        if len(self._done) >= self.capacity:
            self._done.popleft()
            self.dropped += 1
        self._done.append(sp)

    @contextlib.contextmanager
    def span(self, name: str, **attrs: Any) -> Iterator[Span]:
        """Open a nested span; attributes may be amended on the yielded
        object (``sp.attrs["ticks"] = n``) before it closes."""
        parent = self._stack[-1] if self._stack else None
        sp = Span(
            name,
            self._next_id,
            parent.span_id if parent is not None else None,
            len(self._stack),
            self._now(),
            attrs,
        )
        self._next_id += 1
        self._stack.append(sp)
        try:
            if self._jax_ctx is not None:
                with self._jax_ctx(name):
                    yield sp
            else:
                yield sp
        finally:
            sp.t1 = self._now()
            self._stack.pop()
            self._record(sp)

    def instant(self, name: str, **attrs: Any) -> Span:
        """Record a zero-duration event (degradation marks and the like)
        at the current nesting depth."""
        parent = self._stack[-1] if self._stack else None
        sp = Span(
            name,
            self._next_id,
            parent.span_id if parent is not None else None,
            len(self._stack),
            self._now(),
            attrs,
        )
        self._next_id += 1
        sp.t1 = sp.t0
        sp.kind = "instant"
        self._record(sp)
        return sp

    # -- introspection -----------------------------------------------------

    def spans(self) -> list[Span]:
        """Completed spans/events, oldest first (close order for spans)."""
        return list(self._done)

    @property
    def truncated(self) -> bool:
        """True iff ring overflow has dropped at least one span."""
        return self.dropped > 0

    def summary(self) -> dict:
        """Aggregate view for ``Server.observability()``: span counts and
        total wall time by name, plus buffer health."""
        by_name: dict[str, dict] = {}
        for sp in self._done:
            agg = by_name.setdefault(
                sp.name, {"count": 0, "total_us": 0.0, "events": 0}
            )
            if sp.kind == "instant":
                agg["events"] += 1
            else:
                agg["count"] += 1
                agg["total_us"] += sp.duration * 1e6
        for agg in by_name.values():
            agg["total_us"] = round(agg["total_us"], 3)
        return {
            "spans": len(self._done),
            "dropped": self.dropped,
            "truncated": self.truncated,
            "open": len(self._stack),
            "by_name": dict(sorted(by_name.items())),
        }

    # -- exports -----------------------------------------------------------

    def to_jsonl(self, path: Optional[str] = None) -> str:
        """One JSON object per completed span, oldest first. The first
        line is a header carrying the buffer-truncation contract."""
        header = {
            "kind": "header",
            "spans": len(self._done),
            "dropped": self.dropped,
            "truncated": self.truncated,
        }
        lines = [json.dumps(header)]
        lines += [json.dumps(sp.to_dict()) for sp in self._done]
        text = "\n".join(lines) + "\n"
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

    def to_chrome_trace(self, path: Optional[str] = None) -> dict:
        """Chrome trace-event JSON (Perfetto-loadable).

        Spans become complete (``ph: "X"``) events with microsecond
        ``ts``/``dur``; instants become ``ph: "i"`` marks. ``tid`` is the
        span depth so the nesting renders as stacked tracks even for
        viewers that ignore flow data.
        """
        events = []
        for sp in self._done:
            ev = {
                "name": sp.name,
                "cat": sp.name.split(".", 1)[0],
                "pid": 1,
                "tid": sp.depth,
                "ts": round(sp.t0 * 1e6, 3),
                "args": {
                    k: _jsonable(v) for k, v in sp.attrs.items()
                },
            }
            if sp.kind == "instant":
                ev["ph"] = "i"
                ev["s"] = "t"
            else:
                ev["ph"] = "X"
                ev["dur"] = round(sp.duration * 1e6, 3)
            events.append(ev)
        payload = {
            "displayTimeUnit": "ms",
            "traceEvents": events,
            "otherData": {
                "dropped": self.dropped,
                "truncated": self.truncated,
            },
        }
        if path is not None:
            with open(path, "w") as f:
                json.dump(payload, f, indent=1)
        return payload


def _jsonable(v: Any):
    """Attribute values must survive json.dump — stringify anything exotic
    (dtypes, shapes arrive as tuples which are fine)."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return str(v)


# ---------------------------------------------------------------------------
# Active-tracer stack: how deep layers emit spans without API threading.
# ---------------------------------------------------------------------------

_ACTIVE: list[Tracer] = []
_NULL = contextlib.nullcontext()


def current_tracer() -> Optional[Tracer]:
    """The innermost active tracer, or None (the untraced fast path)."""
    return _ACTIVE[-1] if _ACTIVE else None


@contextlib.contextmanager
def activate(tracer: Optional[Tracer]) -> Iterator[None]:
    """Make ``tracer`` the ambient tracer for the dynamic extent (re-entrant;
    ``activate(None)`` is a no-op so call sites need no branching)."""
    if tracer is None:
        yield
        return
    _ACTIVE.append(tracer)
    try:
        yield
    finally:
        _ACTIVE.pop()


def span(name: str, **attrs: Any):
    """Span on the ambient tracer — a reusable null context (one list
    check) when no tracer is active."""
    t = current_tracer()
    if t is None:
        return _NULL
    return t.span(name, **attrs)


def instant(name: str, **attrs: Any) -> Optional[Span]:
    """Instant event on the ambient tracer (None when inactive)."""
    t = current_tracer()
    if t is None:
        return None
    return t.instant(name, **attrs)
