"""Live dispatch telemetry: launch counters and bytes-moved gauges.

The benches (benchmarks/chunk_bench.py, benchmarks/serve_bench.py) compute
HBM bytes-moved models offline to explain their wall clocks; serving has
had no live view of the same numbers. This module is the process-wide
registry the kernel dispatch layer (kernels/ops.py) and the serve tier
report into:

* ``kernel.launches{op=...}`` / ``kernel.remainder_launches{op=...}`` —
  live counters of kernel launches dispatched from the host, including
  the sub-chunk scan structure (a (B, T) chunk call at kernel chunk k is
  ceil(T/k) launches, the last one masked/remainder);
* ``kernel.traces{op=...}`` — dispatch sites reached under an enclosing
  ``jax.jit`` trace. Those calls execute at *trace* time (once per
  compiled shape), so they are counted separately from live launches —
  the compiled program's launches surface at the tier that invokes it
  (e.g. ``dispatch.launches{site=queue.flush}`` per micro-batch flush);
* ``kernel.bytes_moved{op=...}`` — gauge: the bytes-moved model of the
  most recent dispatch, from the same closed forms the benches commit
  (re-exported here so benches and live telemetry cannot drift apart).

Everything lands in one :class:`~repro.serve.metrics.MetricsRegistry`
(labeled metrics), exported by :func:`snapshot` and embedded by
``Server.observability()``. ``reset()`` re-zeros the registry (benches,
tests). Imports of the registry class are deferred so ``repro.obs`` and
``repro.serve`` can instrument each other without an import cycle.
"""
from __future__ import annotations

from typing import Optional

__all__ = [
    "registry",
    "reset",
    "snapshot",
    "record_dispatch",
    "record_wal_append",
    "record_checkpoint",
    "klms_chunk_bytes",
    "krls_chunk_bytes",
    "predict_read_bytes",
]

_REG = None


def registry():
    """The process-wide dispatch-telemetry registry (lazily created)."""
    global _REG
    if _REG is None:
        from repro.serve.metrics import MetricsRegistry

        _REG = MetricsRegistry()
    return _REG


def reset() -> None:
    """Drop all dispatch telemetry (test / bench isolation hook)."""
    global _REG
    _REG = None


def snapshot() -> dict:
    """Plain-dict export of the dispatch registry."""
    return registry().snapshot()


def record_dispatch(
    op: str,
    *,
    launches: int = 1,
    remainder: int = 0,
    bytes_moved: Optional[float] = None,
    traced: bool = False,
) -> None:
    """Record one dispatch-layer call for ``op``.

    ``traced=True`` means the call happened under an enclosing jit trace
    (it compiles a launch, it does not execute one) — counted under
    ``kernel.traces`` instead of ``kernel.launches``.
    """
    reg = registry()
    if traced:
        reg.counter("kernel.traces", op=op).inc()
    else:
        reg.counter("kernel.launches", op=op).inc(launches)
        if remainder:
            reg.counter("kernel.remainder_launches", op=op).inc(remainder)
    if bytes_moved is not None:
        reg.set_gauge("kernel.bytes_moved", float(bytes_moved), op=op)


def record_wal_append(*, replayed: bool = False) -> None:
    """Count one write-ahead-log append (``wal.appends``), or one entry
    re-fed through ``submit`` during restore (``wal.replayed``)."""
    reg = registry()
    if replayed:
        reg.counter("wal.replayed").inc()
    else:
        reg.counter("wal.appends").inc()


def record_checkpoint(*, bytes_written: int, restore: bool = False) -> None:
    """Count one durable checkpoint save (or restore) and gauge its size."""
    reg = registry()
    if restore:
        reg.counter("checkpoint.restores").inc()
    else:
        reg.counter("checkpoint.saves").inc()
    reg.set_gauge("checkpoint.bytes", float(bytes_written))


# ---------------------------------------------------------------------------
# Bytes-moved closed forms — the single source the benches and the live
# gauges share (benchmarks/chunk_bench.py, benchmarks/serve_bench.py).
# ---------------------------------------------------------------------------


def klms_chunk_bytes(bank: int, d: int, dfeat: int, tchunk: int) -> dict:
    """f32 HBM bytes moved per tick by the fused KLMS path at chunk T.

    Per launch: W (d*D) + b (D) fetched once, theta (B*D) read+written
    once, plus per-tick streams x (B*d), y/mu/mask (3B) in and pred/err
    (2B) out.
    """
    per_launch = 4 * (d * dfeat + dfeat + 2 * bank * dfeat)
    per_tick = 4 * (bank * d + 5 * bank)
    return {
        "bytes_per_tick_model": per_launch / tchunk + per_tick,
        "launch_bytes": per_launch,
        "stream_bytes_per_tick": per_tick,
    }


def krls_chunk_bytes(bank: int, d: int, dfeat: int, tchunk: int) -> dict:
    """f32 HBM bytes/tick for fused KRLS at chunk T — P dominates."""
    per_launch = 4 * (
        d * dfeat + dfeat + 2 * bank * dfeat + 2 * bank * dfeat * dfeat
    )
    per_tick = 4 * (bank * d + 5 * bank)
    return {
        "bytes_per_tick_model": per_launch / tchunk + per_tick,
        "launch_bytes": per_launch,
        "stream_bytes_per_tick": per_tick,
    }


def predict_read_bytes(bank: int, d: int, dfeat: int, q: int) -> dict:
    """f32 HBM bytes for Q queries/tenant on the fused read path vs the
    per-query adapter: shared operands (W, b, theta) amortize over the
    whole launch in the fused kernel but are re-fetched per query by the
    adapter."""
    shared = 4 * (d * dfeat + dfeat + bank * dfeat)
    stream = 4 * (bank * d + bank)
    return {
        "adapter_bytes": q * (shared + stream),
        "fused_bytes": shared + q * stream,
        "shared_bytes_per_launch": shared,
        "stream_bytes_per_query": stream,
    }
