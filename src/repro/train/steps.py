"""Jittable step functions: train (grad-accum microbatch scan + AdamW),
prefill, and decode — shared by the real launcher and the dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.optim.optimizers import adamw_init, adamw_update, global_norm
from repro.optim import schedules

__all__ = [
    "TrainStateDict",
    "init_train_state",
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
]

TrainStateDict = dict  # {"params", "opt": AdamWState, "step": int32}


def init_train_state(key: jax.Array, cfg: ModelConfig) -> TrainStateDict:
    params = transformer.init_params(key, cfg)
    return {
        "params": params,
        "opt": adamw_init(params, jnp.dtype(cfg.opt_dtype)),
        "step": jnp.zeros((), jnp.int32),
    }


def make_train_step(
    cfg: ModelConfig,
    *,
    num_microbatches: int = 1,
    lr_schedule: Callable[[jax.Array], jax.Array] | None = None,
    peak_lr: float = 3e-4,
    batch_axes: tuple[str, ...] | None = None,
    grad_specs: Any = None,
) -> Callable:
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    ``batch``: {"tokens": (B, S) int32} or, for frontend archs,
    {"embeds": (B, S, d), "labels": (B, S) int32}. The global batch is split
    into ``num_microbatches`` sequential microbatches (lax.scan) with
    gradient accumulation in ``cfg.opt_dtype``.

    ``batch_axes``: mesh axes carrying the batch dim. The (global_batch,) ->
    (micro, batch) reshape is ambiguous to GSPMD — without an explicit
    constraint it can shard the MICRO dim instead, replicating each
    microbatch's compute across the data axes (observed: 16x redundant
    compute + activation all-reduces). The constraint pins batch sharding.
    """
    if lr_schedule is None:
        lr_schedule = functools.partial(schedules.constant, lr=peak_lr)
    acc_dtype = jnp.dtype(cfg.opt_dtype)

    def constrain_grads(g):
        # Pin the accumulator to the param sharding: each microbatch's grads
        # reduce-scatter straight into the ZeRO shards instead of
        # all-reducing to a replicated layout (and dragging the optimizer
        # update into an unsharded f32 layout — observed on arctic-480b).
        if grad_specs is None:
            return g
        return jax.tree.map(jax.lax.with_sharding_constraint, g, grad_specs)

    def loss_fn(params, mb):
        return transformer.lm_loss(
            params,
            cfg,
            tokens=mb.get("tokens"),
            embeds=mb.get("embeds"),
            labels=mb.get("labels"),
        )

    def train_step(state: TrainStateDict, batch: dict) -> tuple[TrainStateDict, dict]:
        params = state["params"]

        def reshape(x):
            b = x.shape[0]
            assert b % num_microbatches == 0, (b, num_microbatches)
            y = x.reshape((num_microbatches, b // num_microbatches) + x.shape[1:])
            if batch_axes:
                from jax.sharding import PartitionSpec as P

                spec = P(None, batch_axes, *([None] * (y.ndim - 2)))
                y = jax.lax.with_sharding_constraint(y, spec)
            return y

        micro = jax.tree.map(reshape, batch)

        def accum(carry, mb):
            gsum, lsum = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            gsum = jax.tree.map(
                lambda a, g: a + g.astype(acc_dtype), gsum, grads
            )
            return (constrain_grads(gsum), lsum + loss), None

        gzero = constrain_grads(
            jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype), params)
        )
        (gsum, lsum), _ = jax.lax.scan(accum, (gzero, jnp.zeros((), jnp.float32)), micro)
        grads = jax.tree.map(lambda g: g / num_microbatches, gsum)
        lr = lr_schedule(state["step"])
        new_params, new_opt = adamw_update(params, grads, state["opt"], lr)
        metrics = {
            "loss": lsum / num_microbatches,
            "grad_norm": global_norm(grads),
            "lr": lr,
        }
        return (
            {"params": new_params, "opt": new_opt, "step": state["step"] + 1},
            metrics,
        )

    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    """``prefill(params, batch) -> last-position logits (B, V)``."""

    def prefill_step(params, batch: dict):
        # Compute hidden states once; head only on the final position — the
        # serving-realistic prefill output (next-token logits).
        x_tokens = batch.get("tokens")
        embeds = batch.get("embeds")
        if embeds is None:
            x = jnp.take(params["embed"]["table"], x_tokens, axis=0)
        else:
            x = embeds.astype(cfg.activation_dtype)
        h = transformer._apply_stack(params, cfg, x)
        h = transformer.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        last = h[:, -1:, :]
        if cfg.tie_embeddings:
            logits = last @ params["embed"]["table"].T
        else:
            logits = transformer.dense(params["head"], last)
        return transformer._mask_vocab(cfg, logits)[:, 0]

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    """``decode(params, state, token_batch) -> (logits, new_state)``."""

    def decode(params, state, batch: dict):
        return transformer.decode_step(
            params, cfg, state, batch.get("token"), embed_in=batch.get("embed")
        )

    return decode
