"""Elastic scaling: re-shard a TrainState onto a different mesh.

When the healthy device set changes (node failure, pool resize), the state
must move to a new topology. Two paths:

  * **checkpoint path** (slow, always works): newest checkpoint is loaded
    with the new mesh's shardings — nothing here but ``restore`` +
    ``device_put``.
  * **live path** (fast): gather shards to host once and re-place with the
    new shardings. On a real cluster the gather/scatter is a cross-host
    resharding collective; in this single-process container it degenerates
    to the same device_get/device_put, exercised by tests.

The data pipeline is stateless in (seed, step), so training continues with
bit-identical global batches after any re-mesh.
"""
from __future__ import annotations

from typing import Any

import jax

__all__ = ["remesh"]


def remesh(state: Any, new_shardings: Any) -> Any:
    """Re-shard ``state`` to ``new_shardings`` (pytree of NamedSharding)."""
    host = jax.tree.map(lambda x: jax.device_get(x), state)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), host, new_shardings
    )
