"""Training loop with checkpoint/restart, straggler watchdog and elastic
re-meshing hooks.

Fault model (designed for 1000+ nodes, simulated on CPU):
  * **Crash/restart**: every ``ckpt_every`` steps the full TrainState is
    written atomically (train/checkpoint.py); on start the trainer resumes
    from the newest readable checkpoint. The data pipeline is stateless in
    ``(seed, step)`` so a resume replays the exact global batch sequence.
  * **Straggler mitigation**: a per-step wall-clock watchdog; steps slower
    than ``straggler_factor`` x the trailing median are counted and surfaced
    (on a real cluster this signal feeds the scheduler to re-slice the
    failing host; here it is logged + tested via an injected delay).
  * **Elastic scaling**: ``elastic.remesh`` re-shards a TrainState onto a
    new mesh between steps (checkpoint -> new topology -> resume is the
    degenerate path; live remesh is the fast path).
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax

from repro.configs.base import ModelConfig
from repro.train import checkpoint as ckpt_lib
from repro.train.steps import init_train_state, make_train_step

__all__ = ["TrainerConfig", "Trainer"]


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "checkpoints"
    keep: int = 3
    num_microbatches: int = 1
    peak_lr: float = 3e-4
    straggler_factor: float = 3.0
    log_every: int = 10
    seed: int = 0


@dataclass
class Trainer:
    cfg: ModelConfig
    tcfg: TrainerConfig
    batch_fn: Callable[[int], Any]  # step -> batch dict (stateless/seekable)
    step_fn: Optional[Callable] = None
    state: Any = None
    step_times: list = field(default_factory=list)
    straggler_events: int = 0
    # test hook: callable(step) -> extra delay seconds (simulates stragglers)
    delay_injector: Optional[Callable[[int], float]] = None

    def __post_init__(self):
        if self.step_fn is None:
            self.step_fn = jax.jit(
                make_train_step(
                    self.cfg,
                    num_microbatches=self.tcfg.num_microbatches,
                    peak_lr=self.tcfg.peak_lr,
                ),
                donate_argnums=(0,),
            )

    # -- lifecycle ---------------------------------------------------------

    def init_or_resume(self) -> int:
        restored = ckpt_lib.restore(self.tcfg.ckpt_dir)
        if restored is not None:
            self.state, step = restored
            self.state = jax.tree.map(jax.numpy.asarray, self.state)
            return step
        self.state = init_train_state(jax.random.PRNGKey(self.tcfg.seed), self.cfg)
        return 0

    def _watch(self, dt: float):
        self.step_times.append(dt)
        window = self.step_times[-32:]
        if len(window) >= 8:
            med = statistics.median(window[:-1])
            if dt > self.tcfg.straggler_factor * med:
                self.straggler_events += 1

    # -- main loop ----------------------------------------------------------

    def run(self) -> dict:
        start = self.init_or_resume()
        metrics = {}
        for step in range(start, self.tcfg.total_steps):
            t0 = time.time()
            if self.delay_injector is not None:
                time.sleep(self.delay_injector(step))
            batch = self.batch_fn(step)
            self.state, metrics = self.step_fn(self.state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            self._watch(time.time() - t0)
            if (step + 1) % self.tcfg.ckpt_every == 0 or step + 1 == self.tcfg.total_steps:
                ckpt_lib.save(
                    self.tcfg.ckpt_dir, step + 1, self.state, keep=self.tcfg.keep
                )
            if (step + 1) % self.tcfg.log_every == 0:
                print(
                    f"step {step + 1}: loss={metrics.get('loss', float('nan')):.4f}"
                    f" grad_norm={metrics.get('grad_norm', float('nan')):.3f}"
                    f" stragglers={self.straggler_events}",
                    flush=True,
                )
        return metrics
