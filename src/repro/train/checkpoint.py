"""Fault-tolerant checkpointing: atomic, step-indexed, keep-last-k.

Write protocol (crash-safe at every point):
  1. serialize to ``<dir>/tmp.<step>.<pid>`` (never a live name),
  2. fsync file,
  3. ``os.replace`` to ``<dir>/step_<n>.ckpt`` (atomic on POSIX),
  4. update ``LATEST`` marker the same way,
  5. GC checkpoints beyond ``keep``.

Restore never trusts ``LATEST`` blindly: if the marked file is missing or
truncated it falls back to the newest readable checkpoint — a half-written
checkpoint can never brick a resume (this is the node-failure story: any
worker can die at any byte).

Sharded arrays are gathered to host before writing (single-writer model; a
real multi-host deployment writes per-shard files via the same protocol —
the container has one process, so that path is documented, not exercised).
"""
from __future__ import annotations

import os
import pickle
import re
from typing import Any

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "list_steps"]

_CKPT_RE = re.compile(r"^step_(\d+)\.ckpt$")


def _to_host(tree: Any) -> Any:
    return jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)


def save(ckpt_dir: str, step: int, state: Any, *, keep: int = 3) -> str:
    """Atomically persist ``state`` for ``step``. Returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    payload = {"step": int(step), "state": _to_host(state)}
    tmp = os.path.join(ckpt_dir, f"tmp.{step}.{os.getpid()}")
    final = os.path.join(ckpt_dir, f"step_{step}.ckpt")
    with open(tmp, "wb") as f:
        pickle.dump(payload, f, protocol=pickle.HIGHEST_PROTOCOL)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)

    latest_tmp = os.path.join(ckpt_dir, f"tmp.latest.{os.getpid()}")
    with open(latest_tmp, "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))

    for old in list_steps(ckpt_dir)[:-keep]:
        try:
            os.remove(os.path.join(ckpt_dir, f"step_{old}.ckpt"))
        except OSError:
            pass
    return final


def list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        m = _CKPT_RE.match(name)
        if m:
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> int | None:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def _try_load(path: str) -> dict | None:
    try:
        with open(path, "rb") as f:
            return pickle.load(f)
    except Exception:
        return None


def restore(ckpt_dir: str, step: int | None = None) -> tuple[Any, int] | None:
    """Load (state, step); newest readable checkpoint wins. None if empty."""
    candidates: list[int]
    if step is not None:
        candidates = [step]
    else:
        candidates = list(reversed(list_steps(ckpt_dir)))
        marker = os.path.join(ckpt_dir, "LATEST")
        if os.path.exists(marker):
            try:
                marked = int(open(marker).read().strip())
                if marked in candidates:  # prefer the marker if readable
                    candidates.remove(marked)
                    candidates.insert(0, marked)
            except Exception:
                pass
    for s in candidates:
        payload = _try_load(os.path.join(ckpt_dir, f"step_{s}.ckpt"))
        if payload is not None:
            return payload["state"], payload["step"]
    return None
