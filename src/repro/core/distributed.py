"""Distributed (diffusion) RFF-KLMS — the paper's §1 motivation, ref [21].

Classic diffusion KLMS must ship *growing dictionaries* between nodes and
cross-match them (sequential searches per neighbor). With RFF the solution is
a fixed ``theta in R^D``, so the combine step is a single fixed-size
collective — exactly why the paper calls RFF the enabler for distributed
kernel adaptive filtering.

Adapt-then-Combine (ATC) diffusion over a JAX mesh axis:

    adapt:    theta_k' = theta_k + mu e_k z(x_k)        (local LMS step)
    combine:  theta_k  = sum_j c_jk theta_j'            (here: uniform pmean)

Implemented with ``shard_map`` over the ``data`` axis; the combine is a
``lax.pmean`` — on real hardware an ICI all-reduce of D floats per step
(or per round when ``combine_every > 1``).

Also provides an int8-quantized combine with error feedback, the standard
gradient-compression trick, for DCN-bound (cross-pod) deployments.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.klms import LMSState, rff_klms_init, rff_klms_step
from repro.core.rff import RFF

# ``shard_map`` moved from jax.experimental to the jax namespace (and the
# experimental module was later removed); support both spellings.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # pragma: no cover - exercised only on older jax
    from jax.experimental.shard_map import shard_map as _shard_map


def _mark_varying(tree, axis: str):
    """Mark a pytree as device-varying over ``axis`` (newer-jax carry typing).

    The marking primitive is ``jax.lax.pcast`` on current jax and
    ``jax.lax.pvary`` on the releases that introduced varying types; on
    older jax neither exists, every value is implicitly varying, and this
    is the identity.
    """
    pcast = getattr(jax.lax, "pcast", None)
    if pcast is not None:
        return jax.tree.map(lambda a: pcast(a, axis, to="varying"), tree)
    pvary = getattr(jax.lax, "pvary", None)
    if pvary is not None:
        return jax.tree.map(lambda a: pvary(a, axis), tree)
    return tree


__all__ = [
    "DiffusionState",
    "diffusion_klms_run",
    "quantize_int8",
    "dequantize_int8",
]


class DiffusionState(NamedTuple):
    lms: LMSState  # per-node filter state (theta sharded over nodes)
    comp_err: jax.Array  # (D,) error-feedback residual for compression


def quantize_int8(v: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization; returns (q, scale)."""
    scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(v / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _node_stream(
    rff: RFF,
    xs: jax.Array,
    ys: jax.Array,
    mu: float,
    combine_every: int,
    compress: bool,
    axis: str,
) -> tuple[jax.Array, jax.Array]:
    """Per-node body under shard_map: local adapt + periodic pmean combine."""
    # shard_map passes the local block with a leading node axis of size 1.
    xs = xs[0]  # (n, d) local stream shard
    ys = ys[0]
    n = xs.shape[0]
    state = DiffusionState(
        lms=rff_klms_init(rff.num_features, xs.dtype),
        comp_err=jnp.zeros((rff.num_features,), xs.dtype),
    )
    # the carry becomes device-varying after one data-dependent update;
    # mark the init as varying so scan's carry types match.
    state = _mark_varying(state, axis)

    def combine(theta: jax.Array, comp_err: jax.Array):
        if not compress:
            return jax.lax.pmean(theta, axis), comp_err
        # error-feedback int8: quantize (theta + residual), average the
        # dequantized messages, keep the local quantization error.
        msg = theta + comp_err
        q, scale = quantize_int8(msg)
        deq = dequantize_int8(q, scale)
        new_err = msg - deq
        return jax.lax.pmean(deq, axis), new_err

    def body(s: DiffusionState, inp):
        xy, step_idx = inp
        lms, out = rff_klms_step(s.lms, xy, rff, mu)
        do_combine = (step_idx + 1) % combine_every == 0
        theta_c, err_c = combine(lms.theta, s.comp_err)
        theta = jnp.where(do_combine, theta_c, lms.theta)
        comp_err = jnp.where(do_combine, err_c, s.comp_err)
        return DiffusionState(LMSState(theta, lms.step), comp_err), out.error

    (final, errs) = jax.lax.scan(body, state, ((xs, ys), jnp.arange(n)))
    return final.lms.theta[None], errs[None]


def diffusion_klms_run(
    mesh: Mesh,
    axis: str,
    rff: RFF,
    xs: jax.Array,
    ys: jax.Array,
    mu: float,
    combine_every: int = 1,
    compress: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Run ATC diffusion RFF-KLMS over mesh ``axis``.

    Args:
      xs: ``(nodes, n, d)`` per-node streams (node axis sharded over ``axis``).
      ys: ``(nodes, n)``.

    Returns:
      (theta per node ``(nodes, D)``, prior errors ``(nodes, n)``).
    """
    body = functools.partial(
        _node_stream,
        rff,
        mu=mu,
        combine_every=combine_every,
        compress=compress,
        axis=axis,
    )
    spec = P(axis)
    shmapped = _shard_map(
        lambda x, y: body(xs=x, ys=y),
        mesh=mesh,
        in_specs=(spec, spec),
        out_specs=(spec, spec),
    )
    xs = jax.device_put(xs, NamedSharding(mesh, spec))
    ys = jax.device_put(ys, NamedSharding(mesh, spec))
    return jax.jit(shmapped)(xs, ys)
