"""Engel's KRLS with ALD sparsification (Engel, Mannor & Meir 2004).

The paper's §6 baseline. Growing-dictionary kernel RLS: a point joins the
dictionary when its Approximate Linear Dependence (ALD) residual

    delta_t = k(x_t, x_t) - k_t^T a_t,   a_t = Ktilde^{-1} k_t

exceeds ``nu``. Otherwise only the reduced coefficients are updated.

Fixed-capacity buffers + masks (static shapes for scan), like qklms.py; the
O(M^2) per-step cost of the growing method is faithfully reproduced.

Recursions (Engel 2004, Table 1):

  ALD (grow):   Kinv' = (1/delta) [[delta*Kinv + a a^T, -a], [-a^T, 1]]
                P'    = [[P, 0], [0, 1]]
                alpha'= [alpha - (a/delta) e ; e/delta],  e = y - k^T alpha
  else (stay):  q = P a / (1 + a^T P a)
                P' = P - q (a^T P)
                alpha' = alpha + Kinv q e
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.klms import StepOut

__all__ = [
    "ALDKRLSState",
    "ald_krls_init",
    "ald_krls_step",
    "ald_krls_run",
    "ald_krls_predict",
]


class ALDKRLSState(NamedTuple):
    centers: jax.Array  # (cap, d)
    alpha: jax.Array  # (cap,)
    kinv: jax.Array  # (cap, cap)  Ktilde^{-1} on the occupied block
    pmat: jax.Array  # (cap, cap)  P on the occupied block
    size: jax.Array  # () int32
    step: jax.Array  # () int32


def ald_krls_init(
    capacity: int, input_dim: int, dtype: jnp.dtype = jnp.float32
) -> ALDKRLSState:
    return ALDKRLSState(
        centers=jnp.zeros((capacity, input_dim), dtype),
        alpha=jnp.zeros((capacity,), dtype),
        kinv=jnp.zeros((capacity, capacity), dtype),
        pmat=jnp.zeros((capacity, capacity), dtype),
        size=jnp.zeros((), jnp.int32),
        step=jnp.zeros((), jnp.int32),
    )


def _gauss_vec(centers: jax.Array, x: jax.Array, sigma: float) -> jax.Array:
    sq = jnp.sum(jnp.square(centers - x[None, :]), axis=-1)
    return jnp.exp(-sq / (2.0 * sigma**2))


def ald_krls_predict(
    state: ALDKRLSState, x: jax.Array, sigma: float
) -> jax.Array:
    """f(x) = sum_k alpha_k kappa(c_k, x) over occupied slots.

    Same masked dot (and accumulation order) as the prediction inside
    ald_krls_step.
    """
    occ = (jnp.arange(state.centers.shape[0]) < state.size).astype(x.dtype)
    kvec = _gauss_vec(state.centers, x, sigma) * occ
    return kvec @ state.alpha


def ald_krls_step(
    state: ALDKRLSState,
    sample: tuple[jax.Array, jax.Array],
    sigma: float,
    nu: float,
) -> tuple[ALDKRLSState, StepOut]:
    x, y = sample
    cap = state.centers.shape[0]
    idx = jnp.arange(cap)
    occ = idx < state.size  # (cap,) occupancy mask
    occ_f = occ.astype(x.dtype)

    kvec = _gauss_vec(state.centers, x, sigma) * occ_f  # (cap,)
    ktt = jnp.asarray(1.0, x.dtype)  # Gaussian: k(x,x)=1
    y_hat = kvec @ state.alpha
    err = y - y_hat

    a = state.kinv @ kvec  # (cap,) zero outside occupied block
    delta = ktt - kvec @ a
    delta = jnp.maximum(delta, 1e-12)

    grow = (delta > nu) & (state.size < cap)
    first = state.size == 0
    grow = grow | (first & (state.size < cap))
    pos = jnp.minimum(state.size, cap - 1)

    # ---- grow branch (rank-1 bordering of Kinv; P gets a unit border) ----
    onehot = (idx == pos).astype(x.dtype)
    kinv_g = (
        state.kinv
        + jnp.outer(a, a) / delta
        - jnp.outer(onehot, a) / delta
        - jnp.outer(a, onehot) / delta
        + jnp.outer(onehot, onehot) / delta
    )
    pmat_g = state.pmat + jnp.outer(onehot, onehot)
    alpha_g = state.alpha - (a / delta) * err + onehot * (err / delta)

    # ---- stay branch ----
    pa = state.pmat @ a
    qden = 1.0 + a @ pa
    q = pa / qden
    pmat_s = state.pmat - jnp.outer(q, pa)
    alpha_s = state.alpha + (state.kinv @ q) * err

    centers = jnp.where(grow, state.centers.at[pos].set(x), state.centers)
    kinv = jnp.where(grow, kinv_g, state.kinv)
    pmat = jnp.where(grow, pmat_g, pmat_s)
    alpha = jnp.where(grow, alpha_g, alpha_s)
    size = state.size + jnp.where(grow, 1, 0).astype(jnp.int32)
    # symmetrize to slow f32 drift (the paper's Matlab runs were f64; with a
    # near-flat Gaussian kernel K~1 the bordered inverse is ill-conditioned)
    kinv = 0.5 * (kinv + kinv.T)
    pmat = 0.5 * (pmat + pmat.T)

    return (
        ALDKRLSState(
            centers=centers,
            alpha=alpha,
            kinv=kinv,
            pmat=pmat,
            size=size,
            step=state.step + 1,
        ),
        StepOut(prediction=y_hat, error=err),
    )


def ald_krls_run(
    xs: jax.Array,
    ys: jax.Array,
    sigma: float,
    nu: float = 5e-4,
    capacity: int = 256,
) -> tuple[ALDKRLSState, StepOut]:
    """Stream driver. Paper §6 setting: nu = 0.0005."""
    state = ald_krls_init(capacity, xs.shape[-1], xs.dtype)

    def body(s, xy):
        return ald_krls_step(s, xy, sigma, nu)

    return jax.lax.scan(body, state, (xs, ys))
