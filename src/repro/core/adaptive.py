"""Unified stream-driver utilities for the online learners.

Monte-Carlo experiment harness used by every paper benchmark: a *realization*
is (sample data, run filter, collect squared prior errors); realizations are
vmapped over seeds and averaged — bit-identical math to the paper's per-run
Matlab loops, but one fused XLA program.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["monte_carlo_mse", "ema"]


def monte_carlo_mse(
    realization: Callable[[jax.Array], jax.Array],
    key: jax.Array,
    num_runs: int,
) -> jax.Array:
    """Average squared-error learning curves over ``num_runs`` seeds.

    ``realization(key) -> errors (n,)`` (prior errors e_n). Returns the MSE
    curve ``(n,)`` = mean over runs of e_n^2 — exactly the quantity plotted in
    the paper's figures 1-3.
    """
    keys = jax.random.split(key, num_runs)
    errs = jax.lax.map(realization, keys)  # (runs, n) — map caps memory
    return jnp.mean(jnp.square(errs), axis=0)


def ema(curve: jax.Array, alpha: float = 0.05) -> jax.Array:
    """Exponential smoothing for readable learning-curve summaries."""

    def body(m, x):
        m2 = (1 - alpha) * m + alpha * x
        return m2, m2

    _, out = jax.lax.scan(body, curve[0], curve)
    return out
