"""Filter-bank engine: B independent online learners as one batched program.

The serving scenario the ROADMAP asks for: many concurrent streams (one
filter per tenant, or one per hyperparameter in a sweep) driven in lockstep
by a *single* jitted call. Because every learner state is a fixed-size pytree
(the paper's whole point), ``jax.vmap`` turns B filters into one batched
state whose leaves carry a leading bank axis — no padding, no ragged
dictionaries, one XLA program regardless of B.

Three tiers:

* Generic (any ``OnlineLearner``): :func:`bank_init` / :func:`bank_step` /
  :func:`bank_run` / :func:`bank_predict` — vmapped adapter calls. The
  hyperparam-sweep variants (:func:`hp_bank_init` / :func:`hp_bank_step` /
  :func:`hp_bank_run`) additionally vmap over a :class:`BankHParams` pytree
  (mu, beta, lam), so one bank can sweep KRLS forgetting factors AND
  regularizers — not just the state axis.
* Fused KLMS fast path: :func:`klms_bank_run` — the bank shares one RFF
  feature map and steps through ``kernels.rff_klms_bank_step`` (the Pallas
  kernel that keeps the feature block in VMEM), with per-filter ``mu``
  supported for step-size sweeps.
* Fused KRLS fast path: :func:`krls_bank_run` — B tenants of EW-RLS (each a
  ``(D,)`` theta + ``(D, D)`` P) ticked in one pass through
  ``kernels.rff_krls_bank_step``, with per-tenant ``beta`` (and per-tenant
  ``lam`` at init) supported for hyperparameter sweeps.

Time is the scan axis and the bank is the batch axis, so the per-tick
program is exactly the serving hot loop (serve/bank_loop.py wraps it).
``chunk=T`` switches both fused run-loops from a per-tick scan to a scan
over T-tick chunks through the time-blocked kernels (one launch per chunk,
masked final remainder) — the dispatch-amortized schedule the serve queue
and benchmarks drive.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.klms import LMSState, StepOut, rff_klms_init
from repro.core.krls import RLSState, rff_krls_init
from repro.core.learner import OnlineLearner
from repro.core.rff import RFF
from repro.kernels import ops

__all__ = [
    "bank_init",
    "bank_step",
    "bank_run",
    "bank_predict",
    "BankHParams",
    "bank_hparams",
    "hp_bank_init",
    "hp_bank_step",
    "hp_bank_run",
    "klms_bank_init",
    "klms_bank_step",
    "klms_bank_chunk_step",
    "klms_bank_run",
    "krls_bank_init",
    "krls_bank_step",
    "krls_bank_chunk_step",
    "krls_bank_run",
]


def bank_init(
    learner: OnlineLearner, size: int, key: Optional[jax.Array] = None
):
    """Batched state for ``size`` independent filters (leading bank axis)."""
    keys = jax.random.split(
        key if key is not None else jax.random.PRNGKey(0), size
    )
    return jax.vmap(learner.init_fn)(keys)


def bank_step(learner: OnlineLearner, states, xs: jax.Array, ys: jax.Array):
    """One lockstep tick: ``xs (B, d)``, ``ys (B,)`` -> batched (state, out)."""
    return jax.vmap(learner.step_fn)(states, xs, ys)


def bank_run(learner: OnlineLearner, states, xs: jax.Array, ys: jax.Array):
    """Drive B streams ``xs (B, n, d)``, ``ys (B, n)`` under one scan.

    Scan runs over time with a vmapped step inside (lockstep streams — the
    serving schedule), which compiles to the same program as vmapping
    ``learner.run``. Returns (batched final state, StepOut arrays ``(B, n)``).
    """

    def body(s, xy):
        return bank_step(learner, s, *xy)

    xs_t = jnp.swapaxes(xs, 0, 1)  # (n, B, d) time-major
    ys_t = jnp.swapaxes(ys, 0, 1)  # (n, B)
    states, outs = jax.lax.scan(body, states, (xs_t, ys_t))
    return states, jax.tree.map(lambda a: jnp.swapaxes(a, 0, 1), outs)


def bank_predict(learner: OnlineLearner, states, xs: jax.Array) -> jax.Array:
    """Batched inference: one ``x (d,)`` per filter, ``xs (B, d)``."""
    return jax.vmap(learner.predict_fn)(states, xs)


# ---------------------------------------------------------------------------
# Hyperparameter-swept generic bank — vmap over (state, hyperparams), not
# just state. One bank = a full grid of (mu, beta, lam) candidates.
# ---------------------------------------------------------------------------


class BankHParams(NamedTuple):
    """Per-tenant hyperparameters, one leading bank axis per leaf.

    A single pytree covering every filter family in core/: KLMS reads
    ``mu``, EW-RLS reads ``beta`` (forgetting) and ``lam`` (init
    regularizer). Families ignore fields they don't use, so one struct
    sweeps heterogeneous grids without per-algorithm plumbing.
    """

    mu: jax.Array  # (B,) LMS step sizes
    beta: jax.Array  # (B,) RLS forgetting factors
    lam: jax.Array  # (B,) RLS init regularizers


def bank_hparams(
    size: int,
    mu: Union[float, jax.Array] = 0.5,
    beta: Union[float, jax.Array] = 0.9995,
    lam: Union[float, jax.Array] = 1e-4,
    dtype: jnp.dtype = jnp.float32,
) -> BankHParams:
    """Broadcast scalars / ``(B,)`` arrays into a full ``BankHParams``."""

    def to_b(v):
        return jnp.broadcast_to(jnp.asarray(v, dtype), (size,))

    return BankHParams(mu=to_b(mu), beta=to_b(beta), lam=to_b(lam))


def hp_bank_init(
    init_fn: Callable,
    hparams: BankHParams,
    key: Optional[jax.Array] = None,
):
    """Batched state from a per-tenant init: ``init_fn(hp, key) -> state``.

    ``init_fn`` sees one ``BankHParams`` row (scalar leaves) — e.g. a KRLS
    init reading ``hp.lam`` so every tenant gets its own ``P_0 = I/lam``.
    """
    size = hparams.mu.shape[0]
    keys = jax.random.split(
        key if key is not None else jax.random.PRNGKey(0), size
    )
    return jax.vmap(init_fn)(hparams, keys)


def hp_bank_step(
    step_fn: Callable, states, hparams: BankHParams, xs: jax.Array, ys: jax.Array
):
    """One lockstep tick of ``step_fn(state, hp, x, y)`` across the bank."""
    return jax.vmap(step_fn)(states, hparams, xs, ys)


def hp_bank_run(
    step_fn: Callable, states, hparams: BankHParams, xs: jax.Array, ys: jax.Array
):
    """Drive B hyperparameter candidates ``xs (B, n, d)`` under one scan."""

    def body(s, xy):
        return hp_bank_step(step_fn, s, hparams, *xy)

    xs_t = jnp.swapaxes(xs, 0, 1)
    ys_t = jnp.swapaxes(ys, 0, 1)
    states, outs = jax.lax.scan(body, states, (xs_t, ys_t))
    return states, jax.tree.map(lambda a: jnp.swapaxes(a, 0, 1), outs)


# ---------------------------------------------------------------------------
# Fused KLMS bank — shared feature map, Pallas hot path.
# ---------------------------------------------------------------------------


def klms_bank_init(
    rff: RFF, size: int, dtype: Optional[jnp.dtype] = None
) -> LMSState:
    """Batched ``LMSState`` with ``theta (B, D)`` for the fused path."""
    single = rff_klms_init(rff.num_features, dtype or rff.omega.dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (size,) + a.shape), single
    )


def klms_bank_step(
    state: LMSState,
    xs: jax.Array,
    ys: jax.Array,
    rff: RFF,
    mu: Union[float, jax.Array],
    mode: str = "auto",
) -> tuple[LMSState, StepOut]:
    """One fused tick for the whole bank: ``xs (B, d)``, ``ys (B,)``."""
    theta, pred, err = ops.rff_klms_bank_step(
        state.theta, xs, ys, rff.omega, rff.bias, mu, mode=mode
    )
    return (
        LMSState(theta=theta, step=state.step + 1),
        StepOut(prediction=pred, error=err),
    )


def klms_bank_chunk_step(
    state: LMSState,
    xs: jax.Array,
    ys: jax.Array,
    rff: RFF,
    mu: Union[float, jax.Array],
    mask: Optional[jax.Array] = None,
    mode: str = "auto",
) -> tuple[LMSState, StepOut]:
    """T ticks for the whole bank in one launch: ``xs (B, T, d)``,
    ``ys (B, T)``, optional ``mask (B, T)`` validity gate (the serve
    queue's ragged-arrival chunks). Masked ticks don't advance ``step``."""
    theta, pred, err = ops.rff_klms_bank_chunk(
        state.theta, xs, ys, rff.omega, rff.bias, mu, mask, mode=mode
    )
    ticks = (
        ys.shape[1]
        if mask is None
        else jnp.sum(mask, axis=1).astype(state.step.dtype)
    )
    return (
        LMSState(theta=theta, step=state.step + ticks),
        StepOut(prediction=pred, error=err),
    )


def klms_bank_run(
    rff: RFF,
    xs: jax.Array,
    ys: jax.Array,
    mu: Union[float, jax.Array],
    state: Optional[LMSState] = None,
    mode: str = "auto",
    chunk: Optional[int] = None,
) -> tuple[LMSState, StepOut]:
    """Serve B KLMS streams ``xs (B, n, d)``, ``ys (B, n)`` in one jit.

    ``mu`` may be a scalar (per-tenant isolation with shared hyperparams) or
    ``(B,)`` (step-size sweep: one stream per candidate mu). Matches B
    sequential ``rff_klms_run`` calls numerically (tested).

    ``chunk=T`` scans over T-tick chunks through the time-blocked kernel
    (one launch per chunk, zero-masked final remainder) instead of ticks —
    bitwise identical to the per-tick schedule (tested) at 1/T the
    dispatches and theta round-trips.
    """
    if state is None:
        state = klms_bank_init(rff, xs.shape[0])
    if chunk is not None:
        theta, pred, err = ops.rff_klms_bank_chunk(
            state.theta, xs, ys, rff.omega, rff.bias, mu,
            mode=mode, chunk=chunk,
        )
        state = LMSState(theta=theta, step=state.step + ys.shape[1])
        return state, StepOut(prediction=pred, error=err)

    def body(s, xy):
        x_t, y_t = xy
        return klms_bank_step(s, x_t, y_t, rff, mu, mode=mode)

    xs_t = jnp.swapaxes(xs, 0, 1)
    ys_t = jnp.swapaxes(ys, 0, 1)
    state, outs = jax.lax.scan(body, state, (xs_t, ys_t))
    return state, jax.tree.map(lambda a: jnp.swapaxes(a, 0, 1), outs)


# ---------------------------------------------------------------------------
# Fused KRLS bank — shared feature map, per-tenant (D, D) inverse
# correlation, Pallas hot path.
# ---------------------------------------------------------------------------


def krls_bank_init(
    rff: RFF,
    size: int,
    lam: Union[float, jax.Array] = 1e-4,
    dtype: Optional[jnp.dtype] = None,
) -> RLSState:
    """Batched ``RLSState``: theta ``(B, D)``, pmat ``(B, D, D)``.

    ``lam`` may be a scalar or ``(B,)`` — per-tenant regularizers, so one
    bank sweeps ``P_0 = I/lam`` alongside per-tenant ``beta`` (the ROADMAP
    per-tenant-hyperparams item for the KRLS family).
    """
    dt = dtype or rff.omega.dtype
    single = rff_krls_init(rff.num_features, 1.0, dt)
    state = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (size,) + a.shape), single
    )
    lam_b = jnp.broadcast_to(jnp.asarray(lam, dt), (size,))
    return RLSState(
        theta=state.theta,
        pmat=state.pmat / lam_b[:, None, None],
        step=state.step,
    )


def krls_bank_step(
    state: RLSState,
    xs: jax.Array,
    ys: jax.Array,
    rff: RFF,
    beta: Union[float, jax.Array] = 0.9995,
    mode: str = "auto",
) -> tuple[RLSState, StepOut]:
    """One fused RLS tick for the whole bank: ``xs (B, d)``, ``ys (B,)``."""
    theta, pmat, pred, err = ops.rff_krls_bank_step(
        state.theta, state.pmat, xs, ys, rff.omega, rff.bias, beta, mode=mode
    )
    return (
        RLSState(theta=theta, pmat=pmat, step=state.step + 1),
        StepOut(prediction=pred, error=err),
    )


def krls_bank_chunk_step(
    state: RLSState,
    xs: jax.Array,
    ys: jax.Array,
    rff: RFF,
    beta: Union[float, jax.Array] = 0.9995,
    mask: Optional[jax.Array] = None,
    mode: str = "auto",
) -> tuple[RLSState, StepOut]:
    """T RLS ticks for the whole bank in one launch: ``xs (B, T, d)``,
    ``ys (B, T)``, optional ``mask (B, T)`` validity gate. Masked ticks
    don't advance ``step`` and leave theta/P untouched."""
    theta, pmat, pred, err = ops.rff_krls_bank_chunk(
        state.theta, state.pmat, xs, ys, rff.omega, rff.bias, beta, mask,
        mode=mode,
    )
    ticks = (
        ys.shape[1]
        if mask is None
        else jnp.sum(mask, axis=1).astype(state.step.dtype)
    )
    return (
        RLSState(theta=theta, pmat=pmat, step=state.step + ticks),
        StepOut(prediction=pred, error=err),
    )


def krls_bank_run(
    rff: RFF,
    xs: jax.Array,
    ys: jax.Array,
    lam: Union[float, jax.Array] = 1e-4,
    beta: Union[float, jax.Array] = 0.9995,
    state: Optional[RLSState] = None,
    mode: str = "auto",
    chunk: Optional[int] = None,
) -> tuple[RLSState, StepOut]:
    """Serve B KRLS streams ``xs (B, n, d)``, ``ys (B, n)`` in one jit.

    ``beta`` / ``lam`` may be scalars or ``(B,)`` (hyperparameter sweeps:
    one stream per candidate — the ROADMAP's per-tenant-hyperparams item
    for the KRLS family). Matches B sequential ``rff_krls_run`` calls to
    f32 accumulation-order tolerance (tested).

    ``chunk=T`` scans over T-tick chunks through the time-blocked kernel
    (one launch per chunk, zero-masked final remainder) — equivalent to the
    per-tick schedule to reduction-order tolerance (tested) at 1/T the
    dispatches and P round-trips.
    """
    if state is None:
        state = krls_bank_init(rff, xs.shape[0], lam)
    if chunk is not None:
        theta, pmat, pred, err = ops.rff_krls_bank_chunk(
            state.theta, state.pmat, xs, ys, rff.omega, rff.bias, beta,
            mode=mode, chunk=chunk,
        )
        state = RLSState(
            theta=theta, pmat=pmat, step=state.step + ys.shape[1]
        )
        return state, StepOut(prediction=pred, error=err)

    def body(s, xy):
        x_t, y_t = xy
        return krls_bank_step(s, x_t, y_t, rff, beta, mode=mode)

    xs_t = jnp.swapaxes(xs, 0, 1)
    ys_t = jnp.swapaxes(ys, 0, 1)
    state, outs = jax.lax.scan(body, state, (xs_t, ys_t))
    return state, jax.tree.map(lambda a: jnp.swapaxes(a, 0, 1), outs)
