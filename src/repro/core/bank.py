"""Filter-bank engine: B independent online learners as one batched program.

The serving scenario the ROADMAP asks for: many concurrent streams (one
filter per tenant, or one per hyperparameter in a sweep) driven in lockstep
by a *single* jitted call. Because every learner state is a fixed-size pytree
(the paper's whole point), ``jax.vmap`` turns B filters into one batched
state whose leaves carry a leading bank axis — no padding, no ragged
dictionaries, one XLA program regardless of B.

Three tiers:

* Generic (any ``OnlineLearner``): :func:`bank_init` / :func:`bank_step` /
  :func:`bank_run` / :func:`bank_predict` — vmapped adapter calls. The
  hyperparam-sweep variants (:func:`hp_bank_init` / :func:`hp_bank_step` /
  :func:`hp_bank_run`) additionally vmap over a :class:`BankHParams` pytree
  (mu, beta, lam), so one bank can sweep KRLS forgetting factors AND
  regularizers — not just the state axis.
* Fused KLMS fast path: :func:`klms_bank_run` — the bank shares one RFF
  feature map and steps through ``kernels.rff_klms_bank_step`` (the Pallas
  kernel that keeps the feature block in VMEM), with per-filter ``mu``
  supported for step-size sweeps.
* Fused KRLS fast path: :func:`krls_bank_run` — B tenants of EW-RLS (each a
  ``(D,)`` theta + ``(D, D)`` P) ticked in one pass through
  ``kernels.rff_krls_bank_step``, with per-tenant ``beta`` (and per-tenant
  ``lam`` at init) supported for hyperparameter sweeps.

Time is the scan axis and the bank is the batch axis, so the per-tick
program is exactly the serving hot loop (serve/bank_loop.py wraps it).
``chunk=T`` switches both fused run-loops from a per-tick scan to a scan
over T-tick chunks through the time-blocked kernels (one launch per chunk,
masked final remainder) — the dispatch-amortized schedule the serve queue
and benchmarks drive.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.core.klms import LMSState, StepOut, rff_klms_init, rff_klms_step
from repro.core.krls import RLSState, rff_krls_init, rff_krls_step
from repro.core.learner import OnlineLearner
from repro.features.base import (
    FeatureLike,
    TrigFeatures,
    as_trig,
    as_trig_or_none,
    feature_dtype,
    featurize,
)
from repro.kernels import ops, ref
from repro.obs import trace as _trace

__all__ = [
    "bank_init",
    "bank_step",
    "bank_run",
    "bank_predict",
    "bank_predict_block",
    "BankHParams",
    "bank_hparams",
    "hp_bank_init",
    "hp_bank_step",
    "hp_bank_run",
    "klms_bank_init",
    "klms_bank_step",
    "klms_bank_chunk_step",
    "klms_bank_run",
    "krls_bank_init",
    "krls_bank_step",
    "krls_bank_chunk_step",
    "krls_bank_run",
    "stack_feature_maps",
    "mixed_klms_bank_run",
    "mixed_krls_bank_run",
    "tenant_row",
    "set_tenant_row",
    "evict_tenant",
    "resymmetrize_tenant",
    "rebuild_tenant",
    "bank_size",
    "resize_bank",
]


def bank_init(
    learner: OnlineLearner, size: int, key: Optional[jax.Array] = None
):
    """Batched state for ``size`` independent filters (leading bank axis)."""
    keys = jax.random.split(
        key if key is not None else jax.random.PRNGKey(0), size
    )
    return jax.vmap(learner.init_fn)(keys)


def bank_step(learner: OnlineLearner, states, xs: jax.Array, ys: jax.Array):
    """One lockstep tick: ``xs (B, d)``, ``ys (B,)`` -> batched (state, out)."""
    return jax.vmap(learner.step_fn)(states, xs, ys)


def bank_run(learner: OnlineLearner, states, xs: jax.Array, ys: jax.Array):
    """Drive B streams ``xs (B, n, d)``, ``ys (B, n)`` under one scan.

    Scan runs over time with a vmapped step inside (lockstep streams — the
    serving schedule), which compiles to the same program as vmapping
    ``learner.run``. Returns (batched final state, StepOut arrays ``(B, n)``).
    """

    def body(s, xy):
        return bank_step(learner, s, *xy)

    xs_t = jnp.swapaxes(xs, 0, 1)  # (n, B, d) time-major
    ys_t = jnp.swapaxes(ys, 0, 1)  # (n, B)
    states, outs = jax.lax.scan(body, states, (xs_t, ys_t))
    return states, jax.tree.map(lambda a: jnp.swapaxes(a, 0, 1), outs)


def bank_predict(learner: OnlineLearner, states, xs: jax.Array) -> jax.Array:
    """Batched inference: one ``x (d,)`` per filter, ``xs (B, d)``."""
    return jax.vmap(learner.predict_fn)(states, xs)


def bank_predict_block(
    state,
    xq: jax.Array,
    rff: FeatureLike,
    mode: str = "auto",
    precision: Optional[str] = None,
) -> jax.Array:
    """Fused read path: a ``(B, Q, d)`` query block per tenant -> ``(B, Q)``.

    Works for every theta-carrying bank state (``LMSState`` and
    ``RLSState`` predict identically: ``z(x) . theta``) and every feature
    family — trig families dispatch to ``ops.rff_bank_predict`` (one
    launch, theta and W fetched once for the whole block), non-trig
    families fall back to a batched ``featurize`` with the same f32
    reduction. ``precision="bf16"`` drops the featurize GEMM / feature
    block to bf16 with f32 accumulation (contract in kernels/ref.py);
    state is read-only and stays f32. Per query this matches the
    :func:`bank_predict` adapter (tested; bitwise at f32 for trig
    families).
    """
    theta = state.theta
    precision = ref.canon_precision(precision)
    tf = as_trig_or_none(rff)
    if tf is None:
        z = featurize(rff, xq)  # (B, Q, D)
        if precision == "bf16":
            z = z.astype(jnp.bfloat16)
        pred = jnp.sum(
            theta[:, None, :].astype(jnp.float32) * z.astype(jnp.float32),
            axis=-1,
        )
        return pred.astype(theta.dtype)
    return ops.rff_bank_predict(
        theta, xq, tf.omega, tf.bias, tf.scale, mode=mode,
        precision=precision,
    )


# ---------------------------------------------------------------------------
# Hyperparameter-swept generic bank — vmap over (state, hyperparams), not
# just state. One bank = a full grid of (mu, beta, lam) candidates.
# ---------------------------------------------------------------------------


class BankHParams(NamedTuple):
    """Per-tenant hyperparameters, one leading bank axis per leaf.

    A single pytree covering every filter family in core/: KLMS reads
    ``mu``, EW-RLS reads ``beta`` (forgetting) and ``lam`` (init
    regularizer). Families ignore fields they don't use, so one struct
    sweeps heterogeneous grids without per-algorithm plumbing.
    """

    mu: jax.Array  # (B,) LMS step sizes
    beta: jax.Array  # (B,) RLS forgetting factors
    lam: jax.Array  # (B,) RLS init regularizers


def bank_hparams(
    size: int,
    mu: Union[float, jax.Array] = 0.5,
    beta: Union[float, jax.Array] = 0.9995,
    lam: Union[float, jax.Array] = 1e-4,
    dtype: jnp.dtype = jnp.float32,
) -> BankHParams:
    """Broadcast scalars / ``(B,)`` arrays into a full ``BankHParams``."""

    def to_b(v):
        return jnp.broadcast_to(jnp.asarray(v, dtype), (size,))

    return BankHParams(mu=to_b(mu), beta=to_b(beta), lam=to_b(lam))


def hp_bank_init(
    init_fn: Callable,
    hparams: BankHParams,
    key: Optional[jax.Array] = None,
):
    """Batched state from a per-tenant init: ``init_fn(hp, key) -> state``.

    ``init_fn`` sees one ``BankHParams`` row (scalar leaves) — e.g. a KRLS
    init reading ``hp.lam`` so every tenant gets its own ``P_0 = I/lam``.
    """
    size = hparams.mu.shape[0]
    keys = jax.random.split(
        key if key is not None else jax.random.PRNGKey(0), size
    )
    return jax.vmap(init_fn)(hparams, keys)


def hp_bank_step(
    step_fn: Callable, states, hparams: BankHParams, xs: jax.Array, ys: jax.Array
):
    """One lockstep tick of ``step_fn(state, hp, x, y)`` across the bank."""
    return jax.vmap(step_fn)(states, hparams, xs, ys)


def hp_bank_run(
    step_fn: Callable, states, hparams: BankHParams, xs: jax.Array, ys: jax.Array
):
    """Drive B hyperparameter candidates ``xs (B, n, d)`` under one scan."""

    def body(s, xy):
        return hp_bank_step(step_fn, s, hparams, *xy)

    xs_t = jnp.swapaxes(xs, 0, 1)
    ys_t = jnp.swapaxes(ys, 0, 1)
    states, outs = jax.lax.scan(body, states, (xs_t, ys_t))
    return states, jax.tree.map(lambda a: jnp.swapaxes(a, 0, 1), outs)


# ---------------------------------------------------------------------------
# Fused KLMS bank — shared feature map, Pallas hot path.
#
# The feature map may be ANY repro.features family. Trig-canonical families
# (rff / orf / qmc / gq) dispatch to the fused Pallas kernels with their
# (W, b, scale) form; non-trig families (taylor) fall back to a generic
# two-pass XLA step over ``featurize`` with identical update math, so the
# bank tiers accept every family behind one signature.
# ---------------------------------------------------------------------------


def _generic_klms_tick(fm, theta, xs, ys, mu):
    """Two-pass KLMS bank tick over ``featurize`` — delegates the update
    to the oracle's ``ref.klms_tick_math`` (single source of truth)."""
    z = featurize(fm, xs)  # (B, D)
    mu_b = jnp.broadcast_to(jnp.asarray(mu, theta.dtype), ys.shape)
    return ref.klms_tick_math(theta, z, ys, mu_b)


def klms_bank_init(
    rff: FeatureLike, size: int, dtype: Optional[jnp.dtype] = None
) -> LMSState:
    """Batched ``LMSState`` with ``theta (B, D)`` for the fused path."""
    single = rff_klms_init(rff.num_features, dtype or feature_dtype(rff))
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (size,) + a.shape), single
    )


def klms_bank_step(
    state: LMSState,
    xs: jax.Array,
    ys: jax.Array,
    rff: FeatureLike,
    mu: Union[float, jax.Array],
    mode: str = "auto",
) -> tuple[LMSState, StepOut]:
    """One fused tick for the whole bank: ``xs (B, d)``, ``ys (B,)``."""
    tf = as_trig_or_none(rff)
    if tf is None:
        theta, pred, err = _generic_klms_tick(rff, state.theta, xs, ys, mu)
    else:
        theta, pred, err = ops.rff_klms_bank_step(
            state.theta, xs, ys, tf.omega, tf.bias, mu, tf.scale, mode=mode
        )
    return (
        LMSState(theta=theta, step=state.step + 1),
        StepOut(prediction=pred, error=err),
    )


def _generic_klms_chunk(fm, theta, xs, ys, mu, mask):
    """Masked T-tick scan of the two-pass KLMS recursion over ``featurize``
    (non-trig chunk path; mirrors ``ref.rff_klms_bank_chunk_ref``): masked
    ticks emit their prior prediction/error but leave theta untouched."""
    if mask is None:
        mask = jnp.ones(ys.shape, theta.dtype)
    mu_b = jnp.broadcast_to(jnp.asarray(mu, theta.dtype), ys.shape[:1])

    def tick(th, xym):
        x_t, y_t, m_t = xym
        z = featurize(fm, x_t)  # (B, D)
        th, pred, err = ref.klms_tick_math(th, z, y_t, mu_b, gate=m_t)
        return th, (pred, err)

    xs_t = jnp.swapaxes(xs, 0, 1)
    ys_t = jnp.swapaxes(ys, 0, 1)
    mask_t = jnp.swapaxes(mask.astype(theta.dtype), 0, 1)
    theta, (preds, errs) = jax.lax.scan(tick, theta, (xs_t, ys_t, mask_t))
    return theta, jnp.swapaxes(preds, 0, 1), jnp.swapaxes(errs, 0, 1)


def klms_bank_chunk_step(
    state: LMSState,
    xs: jax.Array,
    ys: jax.Array,
    rff: FeatureLike,
    mu: Union[float, jax.Array],
    mask: Optional[jax.Array] = None,
    mode: str = "auto",
) -> tuple[LMSState, StepOut]:
    """T ticks for the whole bank in one launch: ``xs (B, T, d)``,
    ``ys (B, T)``, optional ``mask (B, T)`` validity gate (the serve
    queue's ragged-arrival chunks). Masked ticks don't advance ``step``."""
    tf = as_trig_or_none(rff)
    if tf is None:
        theta, pred, err = _generic_klms_chunk(
            rff, state.theta, xs, ys, mu, mask
        )
    else:
        theta, pred, err = ops.rff_klms_bank_chunk(
            state.theta, xs, ys, tf.omega, tf.bias, mu, mask, tf.scale,
            mode=mode,
        )
    ticks = (
        ys.shape[1]
        if mask is None
        else jnp.sum(mask, axis=1).astype(state.step.dtype)
    )
    return (
        LMSState(theta=theta, step=state.step + ticks),
        StepOut(prediction=pred, error=err),
    )


def klms_bank_run(
    rff: FeatureLike,
    xs: jax.Array,
    ys: jax.Array,
    mu: Union[float, jax.Array],
    state: Optional[LMSState] = None,
    mode: str = "auto",
    chunk: Optional[int] = None,
) -> tuple[LMSState, StepOut]:
    """Serve B KLMS streams ``xs (B, n, d)``, ``ys (B, n)`` in one jit.

    ``mu`` may be a scalar (per-tenant isolation with shared hyperparams) or
    ``(B,)`` (step-size sweep: one stream per candidate mu). Matches B
    sequential ``rff_klms_run`` calls numerically (tested).

    ``chunk=T`` scans over T-tick chunks through the time-blocked kernel
    (one launch per chunk, zero-masked final remainder) instead of ticks —
    bitwise identical to the per-tick schedule (tested) at 1/T the
    dispatches and theta round-trips.
    """
    if state is None:
        state = klms_bank_init(rff, xs.shape[0])
    # Canonicalize ONCE at entry: building the trig form inside the scan
    # body would embed the scale as an XLA constant, which folds/fuses
    # differently from the traced argument the chunk branch passes — and
    # the chunk-vs-tick bitwise contract forbids that divergence.
    tf = as_trig_or_none(rff)
    fm = rff if tf is None else tf
    if chunk is not None:
        if tf is None:
            theta, pred, err = _generic_klms_chunk(
                fm, state.theta, xs, ys, mu, None
            )
        else:
            theta, pred, err = ops.rff_klms_bank_chunk(
                state.theta, xs, ys, tf.omega, tf.bias, mu, None, tf.scale,
                mode=mode, chunk=chunk,
            )
        state = LMSState(theta=theta, step=state.step + ys.shape[1])
        return state, StepOut(prediction=pred, error=err)

    def body(s, xy):
        x_t, y_t = xy
        return klms_bank_step(s, x_t, y_t, fm, mu, mode=mode)

    xs_t = jnp.swapaxes(xs, 0, 1)
    ys_t = jnp.swapaxes(ys, 0, 1)
    state, outs = jax.lax.scan(body, state, (xs_t, ys_t))
    return state, jax.tree.map(lambda a: jnp.swapaxes(a, 0, 1), outs)


# ---------------------------------------------------------------------------
# Fused KRLS bank — shared feature map, per-tenant (D, D) inverse
# correlation, Pallas hot path.
# ---------------------------------------------------------------------------


def krls_bank_init(
    rff: FeatureLike,
    size: int,
    lam: Union[float, jax.Array] = 1e-4,
    dtype: Optional[jnp.dtype] = None,
) -> RLSState:
    """Batched ``RLSState``: theta ``(B, D)``, pmat ``(B, D, D)``.

    ``lam`` may be a scalar or ``(B,)`` — per-tenant regularizers, so one
    bank sweeps ``P_0 = I/lam`` alongside per-tenant ``beta`` (the ROADMAP
    per-tenant-hyperparams item for the KRLS family).
    """
    dt = dtype or feature_dtype(rff)
    single = rff_krls_init(rff.num_features, 1.0, dt)
    state = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (size,) + a.shape), single
    )
    lam_b = jnp.broadcast_to(jnp.asarray(lam, dt), (size,))
    return RLSState(
        theta=state.theta,
        pmat=state.pmat / lam_b[:, None, None],
        step=state.step,
    )


def _generic_krls_tick(fm, theta, pmat, xs, ys, beta):
    """Two-pass EW-RLS bank tick over ``featurize`` — delegates the full
    downdate (incl. symmetrization) to ``ref.krls_tick_math``."""
    z = featurize(fm, xs)  # (B, D)
    beta_b = jnp.broadcast_to(jnp.asarray(beta, theta.dtype), ys.shape)
    return ref.krls_tick_math(theta, pmat, z, ys, beta_b)


def _generic_krls_chunk(fm, theta, pmat, xs, ys, beta, mask):
    """Masked T-tick scan of :func:`_generic_krls_tick` (non-trig chunk
    path; mirrors ``ref.rff_krls_bank_chunk_ref``)."""
    if mask is None:
        mask = jnp.ones(ys.shape, theta.dtype)

    def tick(carry, xym):
        th, pm = carry
        x_t, y_t, m_t = xym
        th2, pm2, pred, err = _generic_krls_tick(fm, th, pm, x_t, y_t, beta)
        th = jnp.where(m_t[:, None] > 0, th2, th)
        pm = jnp.where(m_t[:, None, None] > 0, pm2, pm)
        return (th, pm), (pred, err)

    xs_t = jnp.swapaxes(xs, 0, 1)
    ys_t = jnp.swapaxes(ys, 0, 1)
    mask_t = jnp.swapaxes(mask.astype(theta.dtype), 0, 1)
    (theta, pmat), (preds, errs) = jax.lax.scan(
        tick, (theta, pmat), (xs_t, ys_t, mask_t)
    )
    return theta, pmat, jnp.swapaxes(preds, 0, 1), jnp.swapaxes(errs, 0, 1)


def krls_bank_step(
    state: RLSState,
    xs: jax.Array,
    ys: jax.Array,
    rff: FeatureLike,
    beta: Union[float, jax.Array] = 0.9995,
    mode: str = "auto",
) -> tuple[RLSState, StepOut]:
    """One fused RLS tick for the whole bank: ``xs (B, d)``, ``ys (B,)``."""
    tf = as_trig_or_none(rff)
    if tf is None:
        theta, pmat, pred, err = _generic_krls_tick(
            rff, state.theta, state.pmat, xs, ys, beta
        )
    else:
        theta, pmat, pred, err = ops.rff_krls_bank_step(
            state.theta, state.pmat, xs, ys, tf.omega, tf.bias, beta,
            tf.scale, mode=mode,
        )
    return (
        RLSState(theta=theta, pmat=pmat, step=state.step + 1),
        StepOut(prediction=pred, error=err),
    )


def krls_bank_chunk_step(
    state: RLSState,
    xs: jax.Array,
    ys: jax.Array,
    rff: FeatureLike,
    beta: Union[float, jax.Array] = 0.9995,
    mask: Optional[jax.Array] = None,
    mode: str = "auto",
) -> tuple[RLSState, StepOut]:
    """T RLS ticks for the whole bank in one launch: ``xs (B, T, d)``,
    ``ys (B, T)``, optional ``mask (B, T)`` validity gate. Masked ticks
    don't advance ``step`` and leave theta/P untouched."""
    tf = as_trig_or_none(rff)
    if tf is None:
        theta, pmat, pred, err = _generic_krls_chunk(
            rff, state.theta, state.pmat, xs, ys, beta, mask
        )
    else:
        theta, pmat, pred, err = ops.rff_krls_bank_chunk(
            state.theta, state.pmat, xs, ys, tf.omega, tf.bias, beta, mask,
            tf.scale, mode=mode,
        )
    ticks = (
        ys.shape[1]
        if mask is None
        else jnp.sum(mask, axis=1).astype(state.step.dtype)
    )
    return (
        RLSState(theta=theta, pmat=pmat, step=state.step + ticks),
        StepOut(prediction=pred, error=err),
    )


def krls_bank_run(
    rff: FeatureLike,
    xs: jax.Array,
    ys: jax.Array,
    lam: Union[float, jax.Array] = 1e-4,
    beta: Union[float, jax.Array] = 0.9995,
    state: Optional[RLSState] = None,
    mode: str = "auto",
    chunk: Optional[int] = None,
) -> tuple[RLSState, StepOut]:
    """Serve B KRLS streams ``xs (B, n, d)``, ``ys (B, n)`` in one jit.

    ``beta`` / ``lam`` may be scalars or ``(B,)`` (hyperparameter sweeps:
    one stream per candidate — the ROADMAP's per-tenant-hyperparams item
    for the KRLS family). Matches B sequential ``rff_krls_run`` calls to
    f32 accumulation-order tolerance (tested).

    ``chunk=T`` scans over T-tick chunks through the time-blocked kernel
    (one launch per chunk, zero-masked final remainder) — equivalent to the
    per-tick schedule to reduction-order tolerance (tested) at 1/T the
    dispatches and P round-trips.
    """
    if state is None:
        state = krls_bank_init(rff, xs.shape[0], lam)
    # Canonicalize once at entry — see klms_bank_run for the bitwise
    # rationale (constant-embedded vs traced scale).
    tf = as_trig_or_none(rff)
    fm = rff if tf is None else tf
    if chunk is not None:
        if tf is None:
            theta, pmat, pred, err = _generic_krls_chunk(
                fm, state.theta, state.pmat, xs, ys, beta, None
            )
        else:
            theta, pmat, pred, err = ops.rff_krls_bank_chunk(
                state.theta, state.pmat, xs, ys, tf.omega, tf.bias, beta,
                None, tf.scale, mode=mode, chunk=chunk,
            )
        state = RLSState(
            theta=theta, pmat=pmat, step=state.step + ys.shape[1]
        )
        return state, StepOut(prediction=pred, error=err)

    def body(s, xy):
        x_t, y_t = xy
        return krls_bank_step(s, x_t, y_t, fm, beta, mode=mode)

    xs_t = jnp.swapaxes(xs, 0, 1)
    ys_t = jnp.swapaxes(ys, 0, 1)
    state, outs = jax.lax.scan(body, state, (xs_t, ys_t))
    return state, jax.tree.map(lambda a: jnp.swapaxes(a, 0, 1), outs)


# ---------------------------------------------------------------------------
# Mixed-family bank — per-tenant feature maps AND per-tenant hyperparams.
#
# The fused tiers above share ONE feature map across the bank (that is what
# makes W grid-invariant in the kernels). When tenants need *different*
# families — e.g. deterministic GQ for variance-free serving next to
# Monte-Carlo RFF sweeps — their trig-canonical params stack into a
# (B, d, D) / (B, D) / (B, D) TrigFeatures pytree and the bank vmaps the
# SAME per-tick recursions the single-tenant drivers use, over
# (feature row, BankHParams row, state row). Per-tenant trajectories match
# the sequential single-tenant runs to batched-reduction rounding (KLMS
# ~1e-6 f32; KRLS inherits the bank tier's 1e-3 f32 drift bound through
# the P recursion — same tolerance the generic bank tests pin).
# ---------------------------------------------------------------------------


def stack_feature_maps(fms: Sequence[FeatureLike]) -> TrigFeatures:
    """Stack per-tenant trig-canonical maps into one bank-axis pytree.

    All maps must share ``input_dim`` and ``num_features`` (pad D with
    zero-scale features to mix sizes); any trig family mixes freely. The
    result's leaves carry a leading bank axis: omega ``(B, d, D)``, bias
    ``(B, D)``, scale ``(B, D)``.
    """
    tfs = [as_trig(fm) for fm in fms]
    shapes = {(tf.input_dim, tf.num_features) for tf in tfs}
    if len(shapes) != 1:
        raise ValueError(
            f"stacked feature maps must share (d, D); got {sorted(shapes)}"
        )
    return TrigFeatures(
        omega=jnp.stack([tf.omega for tf in tfs]),
        bias=jnp.stack([tf.bias for tf in tfs]),
        scale=jnp.stack([tf.scale for tf in tfs]),
    )


def mixed_klms_bank_run(
    tfs: TrigFeatures,
    xs: jax.Array,
    ys: jax.Array,
    hparams: Optional[BankHParams] = None,
    mu: Union[float, jax.Array] = 0.5,
    state: Optional[LMSState] = None,
) -> tuple[LMSState, StepOut]:
    """Drive B KLMS tenants with per-tenant feature maps in one scan.

    ``tfs`` is a :func:`stack_feature_maps` pytree (leading bank axis);
    ``hparams`` supplies per-tenant ``mu`` (or pass ``mu`` directly). Each
    tenant's trajectory is its sequential ``rff_klms_run`` with its own
    map — the bank axis batches the identical per-tick recursion, so the
    two differ only by batched-GEMM reduction order (~1e-6 f32, tested).
    """
    size = ys.shape[0]
    if hparams is None:
        hparams = bank_hparams(size, mu=mu, dtype=tfs.omega.dtype)
    if state is None:
        # Stacked leaves carry a leading bank axis, so D is the LAST axis.
        single = rff_klms_init(tfs.omega.shape[-1], tfs.omega.dtype)
        state = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (size,) + a.shape), single
        )

    def tick_one(s, tf, hp, x, y):
        return rff_klms_step(s, (x, y), tf, hp.mu)

    def body(s, xy):
        return jax.vmap(tick_one)(s, tfs, hparams, *xy)

    xs_t = jnp.swapaxes(xs, 0, 1)  # (n, B, d) time-major
    ys_t = jnp.swapaxes(ys, 0, 1)
    state, outs = jax.lax.scan(body, state, (xs_t, ys_t))
    return state, jax.tree.map(lambda a: jnp.swapaxes(a, 0, 1), outs)


def mixed_krls_bank_run(
    tfs: TrigFeatures,
    xs: jax.Array,
    ys: jax.Array,
    hparams: Optional[BankHParams] = None,
    lam: Union[float, jax.Array] = 1e-4,
    beta: Union[float, jax.Array] = 0.9995,
    state: Optional[RLSState] = None,
) -> tuple[RLSState, StepOut]:
    """Drive B EW-RLS tenants with per-tenant feature maps in one scan.

    Per-tenant ``beta`` and init ``lam`` come from ``hparams`` (or the
    ``lam``/``beta`` arguments). Matches sequential ``rff_krls_run`` calls
    to the bank tier's f32 drift bound (the P recursion amplifies batched-
    reduction rounding; 1e-3 over ~100 ticks, same as the generic bank).
    """
    size = ys.shape[0]
    if hparams is None:
        hparams = bank_hparams(
            size, beta=beta, lam=lam, dtype=tfs.omega.dtype
        )
    if state is None:
        dfeat = tfs.omega.shape[-1]  # leading axis is the bank, D is last
        state = jax.vmap(
            lambda hp: rff_krls_init(dfeat, hp.lam, tfs.omega.dtype)
        )(hparams)

    def tick_one(s, tf, hp, x, y):
        return rff_krls_step(s, (x, y), tf, hp.beta)

    def body(s, xy):
        return jax.vmap(tick_one)(s, tfs, hparams, *xy)

    xs_t = jnp.swapaxes(xs, 0, 1)  # (n, B, d) time-major
    ys_t = jnp.swapaxes(ys, 0, 1)
    state, outs = jax.lax.scan(body, state, (xs_t, ys_t))
    return state, jax.tree.map(lambda a: jnp.swapaxes(a, 0, 1), outs)


# ---------------------------------------------------------------------------
# Bank-slot tenant lifecycle — eviction and scan-based rebuild.
#
# Because every tenant's state is a fixed-size SLICE of the bank pytree,
# releasing a slot is one O(1) row write (no compaction, no reallocation,
# the bank program never retraces), and re-admitting a tenant is a replay
# of its observation log through core/scan.py's parallel-in-time engine
# back into the same slot. ``mode="sequential"`` routes through the exact
# jitted run-loops the training path uses — bitwise the never-evicted
# state by construction; ``"scan"`` / ``"blocked"`` trade that for O(log T)
# rebuild depth within the pinned tolerances of tests/test_replay.py.
# ---------------------------------------------------------------------------


def tenant_row(state, tenant: int):
    """One tenant's view of a bank state (scalar-leaf learner state)."""
    return jax.tree.map(lambda a: a[tenant], state)


def set_tenant_row(state, tenant: int, row):
    """Write a single-learner state into bank slot ``tenant`` (O(1))."""
    return jax.tree.map(
        lambda a, r: a.at[tenant].set(jnp.asarray(r, a.dtype)), state, row
    )


def _fresh_row(state, lam: Union[float, jax.Array] = 1e-4, tenant: int = 0):
    """A fresh single-learner row shaped like one slot of ``state``."""
    row = tenant_row(state, tenant)
    if hasattr(state, "pmat"):
        dfeat = state.pmat.shape[-1]
        fresh = rff_krls_init(dfeat, _hp_row(lam, tenant), state.pmat.dtype)
        return RLSState(
            theta=fresh.theta.astype(state.theta.dtype),
            pmat=fresh.pmat,
            step=fresh.step,
        )
    return jax.tree.map(jnp.zeros_like, row)


def _hp_row(v, tenant: int):
    """Scalar hyperparam, or one tenant's entry of a per-tenant ``(B,)``.

    Python scalars pass through *unwrapped*: promoting a float to a 0-d
    array changes weak-typing/constant folding, which costs the sequential
    replay its bitwise match with the training path (1-ulp drift)."""
    if isinstance(v, (int, float)):
        return v
    arr = jnp.asarray(v)
    return arr[tenant] if arr.ndim else arr


def evict_tenant(state, tenant: int, init_row=None, lam: Union[float, jax.Array] = 1e-4):
    """Release bank slot ``tenant``: one O(1) row write, nothing else moves.

    ``init_row`` is the row to park in the slot (a fresh single-learner
    state by default — zero theta for LMS banks, ``P_0 = I/lam`` for RLS
    banks, with per-tenant ``lam`` honored when it is a ``(B,)`` sweep).
    The slot keeps serving the parked row until :func:`rebuild_tenant`
    re-admits the tenant, so the bank program never changes shape.
    """
    if init_row is None:
        init_row = _fresh_row(state, lam, tenant)
    return set_tenant_row(state, tenant, init_row)


def bank_size(state) -> int:
    """Number of slots B (the leading bank axis of every state leaf)."""
    return int(jax.tree.leaves(state)[0].shape[0])


def resize_bank(
    state,
    new_size: int,
    fresh_row=None,
    lam: Union[float, jax.Array] = 1e-4,
):
    """Grow or shrink the bank's leading axis to ``new_size`` slots.

    Growth appends fresh single-learner rows (``fresh_row``, defaulting to
    the family-inferred init — zero theta for LMS banks, ``P_0 = I/lam``
    for RLS banks); existing rows are untouched, so resident tenants are
    bitwise-preserved. Shrink slices the first ``new_size`` rows — the
    caller (the serve policy tier) is responsible for compacting live
    tenants below ``new_size`` first via :func:`tenant_row` /
    :func:`set_tenant_row`. The resulting state retraces downstream jitted
    programs once per distinct size, which is why the policy tier resizes
    in pow2 steps.
    """
    size = bank_size(state)
    if new_size < 1:
        raise ValueError("bank must keep at least one slot")
    if new_size == size:
        return state
    with _trace.span("bank.resize", size=size, new_size=new_size):
        if new_size < size:
            return jax.tree.map(lambda a: a[:new_size], state)
        if fresh_row is None:
            fresh_row = _fresh_row(state, lam)

        def grow(a, r):
            pad = jnp.broadcast_to(
                jnp.asarray(r, a.dtype), (new_size - size,) + a.shape[1:]
            )
            return jnp.concatenate([a, pad], axis=0)

        return jax.tree.map(grow, state, fresh_row)


def resymmetrize_tenant(state, tenant: int):
    """Project slot ``tenant``'s P back onto the symmetric matrices.

    ``P <- (P + P^T) / 2`` is the cheapest rung of the recovery ladder:
    the RLS covariance is symmetric by construction, so any measured
    asymmetry is accumulated drift (or an injected fault) and the
    symmetric projection is the closest matrix in Frobenius norm. The
    repair is exact on the structure (``(a + b) / 2`` is symmetric in
    f32) but only bounds the value error by the asymmetric part's norm —
    the recovery tier verifies via probes and escalates to a log replay
    if predictions stay degraded. Raises ``ValueError`` for bank states
    without a P leaf (LMS/dictionary families have nothing to project).
    """
    if not hasattr(state, "pmat"):
        raise ValueError("resymmetrize_tenant needs a bank state with a P leaf")
    with _trace.span("bank.resymmetrize_tenant", tenant=tenant):
        p = state.pmat[tenant]
        return state._replace(
            pmat=state.pmat.at[tenant].set((p + p.T) / 2)
        )


def rebuild_tenant(
    state,
    tenant: int,
    rff: FeatureLike,
    xs: jax.Array,
    ys: jax.Array,
    *,
    mu: Union[float, jax.Array] = 0.5,
    lam: Union[float, jax.Array] = 1e-4,
    beta: Union[float, jax.Array] = 0.9995,
    mode: str = "scan",
    chunk: Optional[int] = None,
    normalized: bool = False,
) -> "jax.Array":
    """Reconstruct slot ``tenant`` from its replay log ``xs (T, d)``,
    ``ys (T,)`` and write it back into the bank.

    The family is inferred from the bank state (``pmat`` leaf = RLS);
    hyperparameters may be scalars or per-tenant ``(B,)`` sweeps (the
    tenant's entry is used). ``mode``/``chunk`` select the replay schedule
    (core/scan.py): ``"sequential"`` is bitwise the training path,
    ``"scan"``/``"blocked"`` rebuild in O(log T) depth within pinned
    tolerance. Returns the updated bank state.
    """
    from repro.core.scan import replay_klms, replay_krls

    with _trace.span(
        "bank.rebuild_tenant", tenant=tenant, ticks=int(xs.shape[0]),
        mode=mode,
    ):
        if hasattr(state, "pmat"):
            row = replay_krls(
                rff, xs, ys,
                lam=_hp_row(lam, tenant), beta=_hp_row(beta, tenant),
                mode=mode, chunk=chunk,
            )
        else:
            row = replay_klms(
                rff, xs, ys, _hp_row(mu, tenant),
                mode=mode, chunk=chunk, normalized=normalized,
            )
        return set_tenant_row(state, tenant, row)
