"""RFFKRLS — paper §6: exponentially-weighted RLS on RFF-mapped data.

"One only needs to choose the random samples omega_i and replace the
instances of x_n in the standard RLS algorithm with z_Omega(x_n)." The state
is a fixed ``(D,)`` weight vector plus a fixed ``(D, D)`` inverse-correlation
matrix — size independent of the stream length (contrast Engel's KRLS whose
kernel matrices grow with the dictionary).

Standard EW-RLS recursions (forgetting factor beta, regularizer lam):

    P_0   = I / lam
    z     = z_Omega(x_n)
    e     = y_n - theta^T z
    g     = P z / (beta + z^T P z)
    theta <- theta + g e
    P     <- (P - g z^T P) / beta

Per-step cost O(D^2) — fixed, vs O(M_n^2) growing for Engel's KRLS.

Sharded variant (this module's second half): the dense ``(D, D)`` matrix
``P`` is the only state that outgrows a single chip. Because the RFF
formulation keeps every quantity a fixed Euclidean object (Bouboulis et al.
2017 use exactly this to distribute KLMS over networks), ``P`` partitions
cleanly into row blocks ``(D/n, D)`` over a mesh axis, and the rank-1 RLS
update needs ONE ``psum`` per tick — see :func:`sharded_krls_run`.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.distributed import _mark_varying, _shard_map
from repro.core.klms import StepOut
from repro.core.rff import RFF, rff_features

__all__ = [
    "RLSState",
    "rff_krls_init",
    "rff_krls_step",
    "rff_krls_run",
    "KRLS_SHARD_AXIS",
    "krls_state_specs",
    "krls_feature_specs",
    "shard_krls_rff",
    "sharded_krls_init",
    "make_sharded_krls_step",
    "make_sharded_krls_predict",
    "sharded_krls_run",
]


class RLSState(NamedTuple):
    theta: jax.Array  # (D,)
    pmat: jax.Array  # (D, D) inverse correlation estimate
    step: jax.Array  # () int32


def rff_krls_init(
    num_features: int, lam: float = 1e-4, dtype: jnp.dtype = jnp.float32
) -> RLSState:
    return RLSState(
        theta=jnp.zeros((num_features,), dtype),
        pmat=jnp.eye(num_features, dtype=dtype) / lam,
        step=jnp.zeros((), jnp.int32),
    )


def rls_step(
    theta: jax.Array,
    pmat: jax.Array,
    z: jax.Array,
    y: jax.Array,
    beta: float,
) -> tuple[jax.Array, jax.Array, StepOut]:
    """One EW-RLS update in feature space; returns (theta, P, out)."""
    y_hat = theta @ z
    err = y - y_hat
    pz = pmat @ z
    denom = beta + z @ pz
    gain = pz / denom
    theta = theta + gain * err
    pmat = (pmat - jnp.outer(gain, pz)) / beta
    # Symmetrize to fight drift over long streams (numerical hygiene).
    pmat = 0.5 * (pmat + pmat.T)
    return theta, pmat, StepOut(prediction=y_hat, error=err)


def rff_krls_step(
    state: RLSState,
    sample: tuple[jax.Array, jax.Array],
    rff: RFF,
    beta: float = 0.9995,
) -> tuple[RLSState, StepOut]:
    x, y = sample
    z = rff_features(rff, x)
    theta, pmat, out = rls_step(state.theta, state.pmat, z, y, beta)
    return RLSState(theta=theta, pmat=pmat, step=state.step + 1), out


def rff_krls_run(
    rff: RFF,
    xs: jax.Array,
    ys: jax.Array,
    lam: float = 1e-4,
    beta: float = 0.9995,
    state: RLSState | None = None,
) -> tuple[RLSState, StepOut]:
    """Stream driver. Paper §6 settings: lam=1e-4, beta=0.9995, D=300."""
    if state is None:
        state = rff_krls_init(rff.num_features, lam, rff.omega.dtype)

    def body(s, xy):
        return rff_krls_step(s, xy, rff, beta)

    return jax.lax.scan(body, state, (xs, ys))


# ---------------------------------------------------------------------------
# Sharded RFF-KRLS — partition P (and the feature bank) over a mesh axis.
#
# Layout (mesh axis ``shard``, n = axis size, Dn = D / n):
#   omega (d, D)  -> column blocks (d, Dn)   each shard owns features rows_i
#   bias  (D,)    -> blocks (Dn,)
#   theta (D,)    -> row blocks (Dn,)
#   P     (D, D)  -> row blocks (Dn, D)      per-shard bytes: 4*D*Dn
#
# Per tick, each shard featurizes only its slice ``z_i`` and computes
#   pz_partial = z_i @ P[rows_i, :]          (valid because P is symmetric:
#                                             Pz = P^T z = sum_i P_i^T z_i)
#   yhat_partial = theta_i @ z_i
# One psum of the packed ``(2D + 1,)`` vector [pz_partial, scatter(z_i),
# yhat_partial] then gives every shard the full ``Pz``, the full ``z`` and
# the prediction; the gain, theta update and the (Dn, D) outer-product
# downdate are pure local work. The downdate is applied in the exactly
# symmetric form ``(pz_i pz_j) * (1/denom)`` (commutative products round
# identically on both sides of the diagonal), so P stays bitwise symmetric
# without the dense path's explicit re-symmetrization pass — which is what
# licenses the ``z_i @ P_i`` transpose trick above.
# ---------------------------------------------------------------------------

KRLS_SHARD_AXIS = "shard"


def krls_state_specs(axis: str = KRLS_SHARD_AXIS) -> RLSState:
    """PartitionSpecs for RLSState: theta/P row-sharded, step replicated."""
    return RLSState(theta=P(axis), pmat=P(axis, None), step=P())


def krls_feature_specs(axis: str = KRLS_SHARD_AXIS) -> RFF:
    """PartitionSpecs for the feature bank: omega/bias column-sharded."""
    return RFF(omega=P(None, axis), bias=P(axis))


def shard_krls_rff(mesh: Mesh, rff: RFF, axis: str = KRLS_SHARD_AXIS) -> RFF:
    """Place the feature bank with its columns partitioned over ``axis``."""
    return jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
        rff,
        krls_feature_specs(axis),
    )


def sharded_krls_init(
    mesh: Mesh,
    num_features: int,
    lam: float = 1e-4,
    dtype: jnp.dtype = jnp.float32,
    axis: str = KRLS_SHARD_AXIS,
) -> RLSState:
    """``rff_krls_init`` placed row-sharded over ``axis`` (D must divide)."""
    n = mesh.shape[axis]
    if num_features % n:
        raise ValueError(
            f"num_features={num_features} must divide the {axis!r} axis ({n})"
        )
    state = rff_krls_init(num_features, lam, dtype)
    return jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
        state,
        krls_state_specs(axis),
    )


def _sharded_rls_tick(
    theta_l: jax.Array,  # (Dn,) local row block
    pmat_l: jax.Array,  # (Dn, D) local row block
    omega_l: jax.Array,  # (d, Dn) local feature columns
    bias_l: jax.Array,  # (Dn,)
    x: jax.Array,  # (d,) replicated
    y: jax.Array,  # () replicated
    beta: float,
    axis: str,
    num_features: int,
) -> tuple[jax.Array, jax.Array, StepOut]:
    """One sharded EW-RLS update; exactly one psum over ``axis``."""
    dfull = num_features
    dloc = theta_l.shape[0]
    offset = jax.lax.axis_index(axis) * dloc

    scale = jnp.sqrt(2.0 / dfull).astype(omega_l.dtype)
    z_l = scale * jnp.cos(x @ omega_l + bias_l)  # (Dn,) local feature slice

    pz_part = z_l @ pmat_l  # (D,) — P^T z contribution of our rows (P sym)
    yhat_part = z_l @ theta_l  # () partial prediction
    z_scat = jax.lax.dynamic_update_slice(
        jnp.zeros((dfull,), z_l.dtype), z_l, (offset,)
    )
    packed = jnp.concatenate([pz_part, z_scat, yhat_part[None]])
    packed = jax.lax.psum(packed, axis)  # the tick's one collective

    pz = packed[:dfull]
    z = packed[dfull : 2 * dfull]
    y_hat = packed[2 * dfull]
    err = y - y_hat
    inv_denom = 1.0 / (beta + z @ pz)

    pz_l = jax.lax.dynamic_slice(pz, (offset,), (dloc,))
    theta_l = theta_l + (err * inv_denom) * pz_l
    pmat_l = (pmat_l - jnp.outer(pz_l, pz) * inv_denom) / beta
    return theta_l, pmat_l, StepOut(prediction=y_hat, error=err)


def make_sharded_krls_step(
    mesh: Mesh,
    rff: RFF,
    beta: float = 0.9995,
    axis: str = KRLS_SHARD_AXIS,
):
    """Jitted one-tick function ``(state, x, y) -> (state, StepOut)``.

    ``rff`` may be given unsharded; it is placed via :func:`shard_krls_rff`
    and closed over. State arrays must carry the :func:`krls_state_specs`
    layout (use :func:`sharded_krls_init`).
    """
    rff = shard_krls_rff(mesh, rff, axis)
    dfull = rff.num_features
    sspec = krls_state_specs(axis)

    def body(omega_l, bias_l, theta_l, pmat_l, step, x, y):
        theta_l, pmat_l, out = _sharded_rls_tick(
            theta_l, pmat_l, omega_l, bias_l, x, y, beta, axis, dfull
        )
        return theta_l, pmat_l, step + 1, out

    shmapped = _shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(None, axis), P(axis), sspec.theta, sspec.pmat, sspec.step,
            P(), P(),
        ),
        out_specs=(sspec.theta, sspec.pmat, sspec.step, P()),
    )

    @jax.jit
    def step_fn(state: RLSState, x: jax.Array, y: jax.Array):
        theta, pmat, step, out = shmapped(
            rff.omega, rff.bias, state.theta, state.pmat, state.step, x, y
        )
        return RLSState(theta=theta, pmat=pmat, step=step), out

    return step_fn


def make_sharded_krls_predict(
    mesh: Mesh, rff: RFF, axis: str = KRLS_SHARD_AXIS
):
    """Jitted ``(state, x) -> y_hat`` on the sharded layout (one psum)."""
    rff = shard_krls_rff(mesh, rff, axis)
    dfull = rff.num_features
    scale = float((2.0 / dfull) ** 0.5)

    def body(omega_l, bias_l, theta_l, x):
        z_l = scale * jnp.cos(x @ omega_l + bias_l)
        return jax.lax.psum(z_l @ theta_l, axis)

    shmapped = _shard_map(
        body,
        mesh=mesh,
        in_specs=(P(None, axis), P(axis), P(axis), P()),
        out_specs=P(),
    )

    @jax.jit
    def predict_fn(state: RLSState, x: jax.Array) -> jax.Array:
        return shmapped(rff.omega, rff.bias, state.theta, x)

    return predict_fn


@functools.lru_cache(maxsize=None)
def _sharded_krls_run_program(mesh: Mesh, axis: str, beta: float, dfull: int):
    """Build (and cache) the jitted whole-stream program for one
    (mesh, axis, beta, D) — repeat drivers re-use the compiled scan."""
    sspec = krls_state_specs(axis)

    def node(omega_l, bias_l, theta_l, pmat_l, step, xs, ys):
        carry0 = _mark_varying((theta_l, pmat_l), axis)

        def body(carry, xy):
            th, pm = carry
            x, y = xy
            th, pm, out = _sharded_rls_tick(
                th, pm, omega_l, bias_l, x, y, beta, axis, dfull
            )
            return (th, pm), out

        (theta_l, pmat_l), outs = jax.lax.scan(body, carry0, (xs, ys))
        return theta_l, pmat_l, step + xs.shape[0], outs

    shmapped = _shard_map(
        node,
        mesh=mesh,
        in_specs=(
            P(None, axis), P(axis), sspec.theta, sspec.pmat, sspec.step,
            P(), P(),
        ),
        out_specs=(sspec.theta, sspec.pmat, sspec.step, P()),
    )
    return jax.jit(shmapped)


def sharded_krls_run(
    mesh: Mesh,
    rff: RFF,
    xs: jax.Array,
    ys: jax.Array,
    lam: float = 1e-4,
    beta: float = 0.9995,
    state: RLSState | None = None,
    axis: str = KRLS_SHARD_AXIS,
) -> tuple[RLSState, StepOut]:
    """Stream driver on the sharded layout: scan over time *inside* one
    shard_map, so the whole stream is a single program with one psum/tick.

    ``xs (n, d)`` / ``ys (n,)`` are replicated (each tick is one global
    sample — the single-stream setting; the bank engine handles multi-tenant
    batches). Numerically equivalent to :func:`rff_krls_run` to ~1e-5.
    """
    if state is None:
        state = sharded_krls_init(
            mesh, rff.num_features, lam, rff.omega.dtype, axis
        )
    rff = shard_krls_rff(mesh, rff, axis)
    program = _sharded_krls_run_program(mesh, axis, beta, rff.num_features)
    theta, pmat, step, outs = program(
        rff.omega, rff.bias, state.theta, state.pmat, state.step, xs, ys
    )
    return RLSState(theta=theta, pmat=pmat, step=step), outs
