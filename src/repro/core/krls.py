"""RFFKRLS — paper §6: exponentially-weighted RLS on RFF-mapped data.

"One only needs to choose the random samples omega_i and replace the
instances of x_n in the standard RLS algorithm with z_Omega(x_n)." The state
is a fixed ``(D,)`` weight vector plus a fixed ``(D, D)`` inverse-correlation
matrix — size independent of the stream length (contrast Engel's KRLS whose
kernel matrices grow with the dictionary).

Standard EW-RLS recursions (forgetting factor beta, regularizer lam):

    P_0   = I / lam
    z     = z_Omega(x_n)
    e     = y_n - theta^T z
    g     = P z / (beta + z^T P z)
    theta <- theta + g e
    P     <- (P - g z^T P) / beta

Per-step cost O(D^2) — fixed, vs O(M_n^2) growing for Engel's KRLS.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.klms import StepOut
from repro.core.rff import RFF, rff_features

__all__ = ["RLSState", "rff_krls_init", "rff_krls_step", "rff_krls_run"]


class RLSState(NamedTuple):
    theta: jax.Array  # (D,)
    pmat: jax.Array  # (D, D) inverse correlation estimate
    step: jax.Array  # () int32


def rff_krls_init(
    num_features: int, lam: float = 1e-4, dtype: jnp.dtype = jnp.float32
) -> RLSState:
    return RLSState(
        theta=jnp.zeros((num_features,), dtype),
        pmat=jnp.eye(num_features, dtype=dtype) / lam,
        step=jnp.zeros((), jnp.int32),
    )


def rls_step(
    theta: jax.Array,
    pmat: jax.Array,
    z: jax.Array,
    y: jax.Array,
    beta: float,
) -> tuple[jax.Array, jax.Array, StepOut]:
    """One EW-RLS update in feature space; returns (theta, P, out)."""
    y_hat = theta @ z
    err = y - y_hat
    pz = pmat @ z
    denom = beta + z @ pz
    gain = pz / denom
    theta = theta + gain * err
    pmat = (pmat - jnp.outer(gain, pz)) / beta
    # Symmetrize to fight drift over long streams (numerical hygiene).
    pmat = 0.5 * (pmat + pmat.T)
    return theta, pmat, StepOut(prediction=y_hat, error=err)


def rff_krls_step(
    state: RLSState,
    sample: tuple[jax.Array, jax.Array],
    rff: RFF,
    beta: float = 0.9995,
) -> tuple[RLSState, StepOut]:
    x, y = sample
    z = rff_features(rff, x)
    theta, pmat, out = rls_step(state.theta, state.pmat, z, y, beta)
    return RLSState(theta=theta, pmat=pmat, step=state.step + 1), out


def rff_krls_run(
    rff: RFF,
    xs: jax.Array,
    ys: jax.Array,
    lam: float = 1e-4,
    beta: float = 0.9995,
    state: RLSState | None = None,
) -> tuple[RLSState, StepOut]:
    """Stream driver. Paper §6 settings: lam=1e-4, beta=0.9995, D=300."""
    if state is None:
        state = rff_krls_init(rff.num_features, lam, rff.omega.dtype)

    def body(s, xy):
        return rff_krls_step(s, xy, rff, beta)

    return jax.lax.scan(body, state, (xs, ys))
