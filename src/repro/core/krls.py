"""RFFKRLS — paper §6: exponentially-weighted RLS on RFF-mapped data.

"One only needs to choose the random samples omega_i and replace the
instances of x_n in the standard RLS algorithm with z_Omega(x_n)." The state
is a fixed ``(D,)`` weight vector plus a fixed ``(D, D)`` inverse-correlation
matrix — size independent of the stream length (contrast Engel's KRLS whose
kernel matrices grow with the dictionary).

Standard EW-RLS recursions (forgetting factor beta, regularizer lam):

    P_0   = I / lam
    z     = z_Omega(x_n)
    e     = y_n - theta^T z
    g     = P z / (beta + z^T P z)
    theta <- theta + g e
    P     <- (P - g z^T P) / beta

Per-step cost O(D^2) — fixed, vs O(M_n^2) growing for Engel's KRLS.

Sharded variant (this module's second half): the dense ``(D, D)`` matrix
``P`` is the only state that outgrows a single chip. Because the RFF
formulation keeps every quantity a fixed Euclidean object (Bouboulis et al.
2017 use exactly this to distribute KLMS over networks), ``P`` partitions
cleanly into row blocks ``(D/n, D)`` over a mesh axis, and the rank-1 RLS
update needs ONE ``psum`` per tick — see :func:`sharded_krls_run`.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.distributed import _mark_varying, _shard_map
from repro.core.klms import StepOut
from repro.features.base import (
    FeatureLike,
    TrigFeatures,
    as_trig,
    feature_dtype,
    featurize,
)
from repro.kernels.chunking import time_blocks, unblock_time, valid_time_mask

__all__ = [
    "RLSState",
    "rff_krls_init",
    "rff_krls_step",
    "rff_krls_run",
    "KRLS_SHARD_AXIS",
    "krls_state_specs",
    "krls_feature_specs",
    "shard_krls_rff",
    "sharded_krls_init",
    "make_sharded_krls_step",
    "make_sharded_krls_block_step",
    "make_sharded_krls_predict",
    "sharded_krls_run",
]


class RLSState(NamedTuple):
    theta: jax.Array  # (D,)
    pmat: jax.Array  # (D, D) inverse correlation estimate
    step: jax.Array  # () int32


def rff_krls_init(
    num_features: int, lam: float = 1e-4, dtype: jnp.dtype = jnp.float32
) -> RLSState:
    return RLSState(
        theta=jnp.zeros((num_features,), dtype),
        pmat=jnp.eye(num_features, dtype=dtype) / lam,
        step=jnp.zeros((), jnp.int32),
    )


def rls_step(
    theta: jax.Array,
    pmat: jax.Array,
    z: jax.Array,
    y: jax.Array,
    beta: float,
) -> tuple[jax.Array, jax.Array, StepOut]:
    """One EW-RLS update in feature space; returns (theta, P, out)."""
    y_hat = theta @ z
    err = y - y_hat
    pz = pmat @ z
    denom = beta + z @ pz
    gain = pz / denom
    theta = theta + gain * err
    pmat = (pmat - jnp.outer(gain, pz)) / beta
    # Symmetrize to fight drift over long streams (numerical hygiene).
    pmat = 0.5 * (pmat + pmat.T)
    return theta, pmat, StepOut(prediction=y_hat, error=err)


def rff_krls_step(
    state: RLSState,
    sample: tuple[jax.Array, jax.Array],
    rff: FeatureLike,
    beta: float = 0.9995,
) -> tuple[RLSState, StepOut]:
    x, y = sample
    z = featurize(rff, x)
    theta, pmat, out = rls_step(state.theta, state.pmat, z, y, beta)
    return RLSState(theta=theta, pmat=pmat, step=state.step + 1), out


def rff_krls_run(
    rff: FeatureLike,
    xs: jax.Array,
    ys: jax.Array,
    lam: float = 1e-4,
    beta: float = 0.9995,
    state: RLSState | None = None,
    chunk: int | None = None,
) -> tuple[RLSState, StepOut]:
    """Stream driver. Paper §6 settings: lam=1e-4, beta=0.9995, D=300.

    ``chunk=T`` scans over T-tick chunks: each chunk featurizes its T
    samples in one ``(T, d) @ (d, D)`` GEMM and replays the sequential RLS
    recursion over the precomputed rows (zero-masked final remainder).
    Matches the per-tick scan to feature-GEMM rounding (tested).
    """
    if state is None:
        state = rff_krls_init(rff.num_features, lam, feature_dtype(rff))
    if chunk is not None:
        return _rff_krls_run_chunked(rff, xs, ys, beta, state, chunk)

    def body(s, xy):
        return rff_krls_step(s, xy, rff, beta)

    return jax.lax.scan(body, state, (xs, ys))


def _rff_krls_run_chunked(
    rff: FeatureLike,
    xs: jax.Array,
    ys: jax.Array,
    beta: float,
    state: RLSState,
    chunk: int,
) -> tuple[RLSState, StepOut]:
    """Chunked scan: featurize T samples per GEMM, replay ticks in-chunk."""
    n = xs.shape[0]
    xs_c = time_blocks(xs, chunk)
    ys_c = time_blocks(ys, chunk)
    mask_c = valid_time_mask(n, chunk, xs.dtype)

    def body(s: RLSState, args):
        xc, yc, mc = args
        zc = featurize(rff, xc)  # (T, D) — one GEMM per chunk

        def tick(st: RLSState, zym):
            z, y, m = zym
            theta, pmat, out = rls_step(st.theta, st.pmat, z, y, beta)
            keep = m > 0
            return (
                RLSState(
                    theta=jnp.where(keep, theta, st.theta),
                    pmat=jnp.where(keep, pmat, st.pmat),
                    step=st.step + m.astype(st.step.dtype),
                ),
                out,
            )

        return jax.lax.scan(tick, s, (zc, yc, mc))

    state, outs = jax.lax.scan(body, state, (xs_c, ys_c, mask_c))
    return state, jax.tree.map(lambda a: unblock_time(a, n), outs)


# ---------------------------------------------------------------------------
# Sharded RFF-KRLS — partition P (and the feature bank) over a mesh axis.
#
# Layout (mesh axis ``shard``, n = axis size, Dn = D / n). The feature bank
# is the canonical affine-trig form (repro.features.as_trig), so any trig
# family — RFF, ORF, QMC, weighted Gaussian quadrature — shards identically:
#   omega (d, D)  -> column blocks (d, Dn)   each shard owns features rows_i
#   bias  (D,)    -> blocks (Dn,)
#   scale (D,)    -> blocks (Dn,)            per-feature quadrature weights
#   theta (D,)    -> row blocks (Dn,)
#   P     (D, D)  -> row blocks (Dn, D)      per-shard bytes: 4*D*Dn
#
# Per tick, each shard featurizes only its slice ``z_i`` and computes
#   pz_partial = z_i @ P[rows_i, :]          (valid because P is symmetric:
#                                             Pz = P^T z = sum_i P_i^T z_i)
#   yhat_partial = theta_i @ z_i
# One psum of the packed ``(2D + 1,)`` vector [pz_partial, scatter(z_i),
# yhat_partial] then gives every shard the full ``Pz``, the full ``z`` and
# the prediction; the gain, theta update and the (Dn, D) outer-product
# downdate are pure local work. The downdate is applied in the exactly
# symmetric form ``(pz_i pz_j) * (1/denom)`` (commutative products round
# identically on both sides of the diagonal), so P stays bitwise symmetric
# without the dense path's explicit re-symmetrization pass — which is what
# licenses the ``z_i @ P_i`` transpose trick above.
# ---------------------------------------------------------------------------

KRLS_SHARD_AXIS = "shard"


def krls_state_specs(axis: str = KRLS_SHARD_AXIS) -> RLSState:
    """PartitionSpecs for RLSState: theta/P row-sharded, step replicated."""
    return RLSState(theta=P(axis), pmat=P(axis, None), step=P())


def krls_feature_specs(axis: str = KRLS_SHARD_AXIS) -> TrigFeatures:
    """PartitionSpecs for the canonical trig feature bank: omega/bias/scale
    column-sharded (each shard featurizes exactly its P row block's slice)."""
    return TrigFeatures(omega=P(None, axis), bias=P(axis), scale=P(axis))


def shard_krls_rff(
    mesh: Mesh, rff: FeatureLike, axis: str = KRLS_SHARD_AXIS
) -> TrigFeatures:
    """Canonicalize to the affine-trig form and place it with feature
    columns partitioned over ``axis``. Any trig family works (RFF, ORF, QMC,
    GQ); non-trig families (Taylor) have no column decomposition of the
    featurize GEMM and raise here."""
    return jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
        as_trig(rff),
        krls_feature_specs(axis),
    )


def sharded_krls_init(
    mesh: Mesh,
    num_features: int,
    lam: float = 1e-4,
    dtype: jnp.dtype = jnp.float32,
    axis: str = KRLS_SHARD_AXIS,
) -> RLSState:
    """``rff_krls_init`` placed row-sharded over ``axis`` (D must divide)."""
    n = mesh.shape[axis]
    if num_features % n:
        raise ValueError(
            f"num_features={num_features} must divide the {axis!r} axis ({n})"
        )
    state = rff_krls_init(num_features, lam, dtype)
    return jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
        state,
        krls_state_specs(axis),
    )


def _sharded_rls_tick(
    theta_l: jax.Array,  # (Dn,) local row block
    pmat_l: jax.Array,  # (Dn, D) local row block
    omega_l: jax.Array,  # (d, Dn) local feature columns
    bias_l: jax.Array,  # (Dn,)
    scale_l: jax.Array,  # (Dn,) local per-feature scales
    x: jax.Array,  # (d,) replicated
    y: jax.Array,  # () replicated
    beta: float,
    axis: str,
    num_features: int,
) -> tuple[jax.Array, jax.Array, StepOut]:
    """One sharded EW-RLS update; exactly one psum over ``axis``."""
    dfull = num_features
    dloc = theta_l.shape[0]
    offset = jax.lax.axis_index(axis) * dloc

    z_l = scale_l * jnp.cos(x @ omega_l + bias_l)  # (Dn,) local slice

    pz_part = z_l @ pmat_l  # (D,) — P^T z contribution of our rows (P sym)
    yhat_part = z_l @ theta_l  # () partial prediction
    z_scat = jax.lax.dynamic_update_slice(
        jnp.zeros((dfull,), z_l.dtype), z_l, (offset,)
    )
    packed = jnp.concatenate([pz_part, z_scat, yhat_part[None]])
    packed = jax.lax.psum(packed, axis)  # the tick's one collective

    pz = packed[:dfull]
    z = packed[dfull : 2 * dfull]
    y_hat = packed[2 * dfull]
    err = y - y_hat
    inv_denom = 1.0 / (beta + z @ pz)

    pz_l = jax.lax.dynamic_slice(pz, (offset,), (dloc,))
    theta_l = theta_l + (err * inv_denom) * pz_l
    pmat_l = (pmat_l - jnp.outer(pz_l, pz) * inv_denom) / beta
    return theta_l, pmat_l, StepOut(prediction=y_hat, error=err)


def _sharded_rls_block_tick(
    theta_l: jax.Array,  # (Dn,) local row block
    pmat_l: jax.Array,  # (Dn, D) local row block
    omega_l: jax.Array,  # (d, Dn) local feature columns
    bias_l: jax.Array,  # (Dn,)
    scale_l: jax.Array,  # (Dn,) local per-feature scales
    xs: jax.Array,  # (k, d) replicated block of samples
    ys: jax.Array,  # (k,) replicated
    mask: jax.Array,  # (k,) replicated validity gate (1 = real tick)
    beta: float,
    axis: str,
    num_features: int,
) -> tuple[jax.Array, jax.Array, StepOut]:
    """k sharded EW-RLS ticks with ONE psum — the combine_every block.

    The per-tick path pays one ``(2D+1,)`` psum per sample. Here each shard
    featurizes its slice for all k samples, contributes the *block-start*
    partial matvecs ``P_0^T z_j`` and predictions ``theta_0 . z_j``, and a
    single packed ``(k, 2D+1)`` psum replicates them. The k-tick recursion
    is then replayed exactly from those block-start quantities:

        P_{i+1} z = (P_i z - (pz_i . z / denom_i) pz_i) / beta
        theta_{i+1} . z = theta_i . z + (e_i / denom_i)(pz_i . z)

    i.e. every per-tick ``pz_j = P_j z_j``, gain denominator and prior error
    is an O(k^2 D) combination of the psum'd vectors — pure replicated
    local work, no further collectives. The restructuring is algebraically
    EXACT (this is the fixed-size-state dividend: k rank-1 updates commute
    into closed form); only floating-point summation order differs from the
    per-tick path, and tests bound that drift at 1e-5 f32 / 1e-8 f64.
    Masked ticks (mask=0) contribute nothing and skip their downdate.
    """
    k = xs.shape[0]
    dfull = num_features
    dloc = theta_l.shape[0]
    offset = jax.lax.axis_index(axis) * dloc

    z_l = scale_l * jnp.cos(xs @ omega_l + bias_l)  # (k, Dn) local slices
    pz0_part = z_l @ pmat_l  # (k, D) — P_0^T z_j contributions (P sym)
    yhat0_part = z_l @ theta_l  # (k,) partial block-start predictions
    zero = jnp.zeros((), offset.dtype)  # match axis_index dtype under x64
    z_scat = jax.lax.dynamic_update_slice(
        jnp.zeros((k, dfull), z_l.dtype), z_l, (zero, offset)
    )
    packed = jnp.concatenate(
        [pz0_part, z_scat, yhat0_part[:, None]], axis=1
    )
    packed = jax.lax.psum(packed, axis)  # the block's ONE collective

    pz0 = packed[:, :dfull]  # (k, D) P_0 z_j
    z = packed[:, dfull : 2 * dfull]  # (k, D) full feature vectors
    yhat0 = packed[:, 2 * dfull]  # (k,) theta_0 . z_j

    # Replicated replay (k is static -> unrolled; O(k^2 D) VPU work).
    pzs, inv_dens, errs_m, preds, errs = [], [], [], [], []
    for j in range(k):
        v = pz0[j]
        yh = yhat0[j]
        for i in range(j):
            c = pzs[i] @ z[j]
            corr = c * inv_dens[i]
            v = (v - (mask[i] * corr) * pzs[i]) / jnp.where(
                mask[i] > 0, beta, 1.0
            )
            yh = yh + errs_m[i] * corr
        inv_den = 1.0 / (beta + z[j] @ v)
        e = ys[j] - yh
        pzs.append(v)
        inv_dens.append(inv_den)
        errs_m.append(mask[j] * e)
        preds.append(yh)
        errs.append(e)

    # Local state: theta additions commute into one (k,) @ (k, Dn) matvec;
    # P downdates replay in order with the exactly-symmetric (pz_i pz_j)
    # form (same commutative-rounding argument as the per-tick path).
    pz_mat = jnp.stack(pzs)  # (k, D)
    pz_loc = jax.lax.dynamic_slice(pz_mat, (zero, offset), (k, dloc))
    coeff = jnp.stack(errs_m) * jnp.stack(inv_dens)  # (k,)
    theta_l = theta_l + coeff @ pz_loc
    for j in range(k):
        downd = (
            pmat_l - jnp.outer(pz_loc[j], pz_mat[j]) * inv_dens[j]
        ) / beta
        pmat_l = jnp.where(mask[j] > 0, downd, pmat_l)
    return theta_l, pmat_l, StepOut(
        prediction=jnp.stack(preds), error=jnp.stack(errs)
    )


def make_sharded_krls_step(
    mesh: Mesh,
    rff: FeatureLike,
    beta: float = 0.9995,
    axis: str = KRLS_SHARD_AXIS,
):
    """Jitted one-tick function ``(state, x, y) -> (state, StepOut)``.

    ``rff`` is any trig-canonical feature map, given unsharded; it is placed
    via :func:`shard_krls_rff` and closed over. State arrays must carry the
    :func:`krls_state_specs` layout (use :func:`sharded_krls_init`).
    """
    tf = shard_krls_rff(mesh, rff, axis)
    dfull = tf.num_features
    sspec = krls_state_specs(axis)
    fspec = krls_feature_specs(axis)

    def body(omega_l, bias_l, scale_l, theta_l, pmat_l, step, x, y):
        theta_l, pmat_l, out = _sharded_rls_tick(
            theta_l, pmat_l, omega_l, bias_l, scale_l, x, y, beta, axis,
            dfull,
        )
        return theta_l, pmat_l, step + 1, out

    shmapped = _shard_map(
        body,
        mesh=mesh,
        in_specs=(
            fspec.omega, fspec.bias, fspec.scale,
            sspec.theta, sspec.pmat, sspec.step,
            P(), P(),
        ),
        out_specs=(sspec.theta, sspec.pmat, sspec.step, P()),
    )

    @jax.jit
    def step_fn(state: RLSState, x: jax.Array, y: jax.Array):
        theta, pmat, step, out = shmapped(
            tf.omega, tf.bias, tf.scale,
            state.theta, state.pmat, state.step, x, y,
        )
        return RLSState(theta=theta, pmat=pmat, step=step), out

    return step_fn


def make_sharded_krls_block_step(
    mesh: Mesh,
    rff: FeatureLike,
    beta: float = 0.9995,
    combine_every: int = 8,
    axis: str = KRLS_SHARD_AXIS,
):
    """Jitted k-tick function ``(state, xs (k, d), ys (k,)) -> (state,
    StepOut (k,))`` issuing ONE psum per k ticks (``combine_every``).

    The DCN-deployment form of :func:`make_sharded_krls_step`: collective
    count drops k-fold while the update stays algebraically exact (see
    :func:`_sharded_rls_block_tick` for the replay construction and its
    drift bound).
    """
    tf = shard_krls_rff(mesh, rff, axis)
    dfull = tf.num_features
    k = combine_every
    sspec = krls_state_specs(axis)
    fspec = krls_feature_specs(axis)

    def body(omega_l, bias_l, scale_l, theta_l, pmat_l, step, xs, ys):
        mask = jnp.ones((k,), xs.dtype)
        theta_l, pmat_l, out = _sharded_rls_block_tick(
            theta_l, pmat_l, omega_l, bias_l, scale_l, xs, ys, mask, beta,
            axis, dfull,
        )
        return theta_l, pmat_l, step + k, out

    shmapped = _shard_map(
        body,
        mesh=mesh,
        in_specs=(
            fspec.omega, fspec.bias, fspec.scale,
            sspec.theta, sspec.pmat, sspec.step,
            P(), P(),
        ),
        out_specs=(sspec.theta, sspec.pmat, sspec.step, P()),
    )

    @jax.jit
    def block_step_fn(state: RLSState, xs: jax.Array, ys: jax.Array):
        theta, pmat, step, out = shmapped(
            tf.omega, tf.bias, tf.scale,
            state.theta, state.pmat, state.step, xs, ys,
        )
        return RLSState(theta=theta, pmat=pmat, step=step), out

    return block_step_fn


def make_sharded_krls_predict(
    mesh: Mesh, rff: FeatureLike, axis: str = KRLS_SHARD_AXIS
):
    """Jitted ``(state, x) -> y_hat`` on the sharded layout (one psum)."""
    tf = shard_krls_rff(mesh, rff, axis)
    fspec = krls_feature_specs(axis)

    def body(omega_l, bias_l, scale_l, theta_l, x):
        z_l = scale_l * jnp.cos(x @ omega_l + bias_l)
        return jax.lax.psum(z_l @ theta_l, axis)

    shmapped = _shard_map(
        body,
        mesh=mesh,
        in_specs=(fspec.omega, fspec.bias, fspec.scale, P(axis), P()),
        out_specs=P(),
    )

    @jax.jit
    def predict_fn(state: RLSState, x: jax.Array) -> jax.Array:
        return shmapped(tf.omega, tf.bias, tf.scale, state.theta, x)

    return predict_fn


@functools.lru_cache(maxsize=None)
def _sharded_krls_run_program(
    mesh: Mesh, axis: str, beta: float, dfull: int, combine_every: int = 1
):
    """Build (and cache) the jitted whole-stream program for one
    (mesh, axis, beta, D, k) — repeat drivers re-use the compiled scan.

    ``combine_every == 1`` scans per-tick ticks (one psum each);
    ``combine_every == k`` scans k-tick blocks (one packed psum each) and
    takes an extra replicated ``mask (nblocks, k)`` input for the
    zero-padded final block.
    """
    sspec = krls_state_specs(axis)
    k = combine_every

    fspec = krls_feature_specs(axis)
    if k == 1:

        def node(omega_l, bias_l, scale_l, theta_l, pmat_l, step, xs, ys):
            carry0 = _mark_varying((theta_l, pmat_l), axis)

            def body(carry, xy):
                th, pm = carry
                x, y = xy
                th, pm, out = _sharded_rls_tick(
                    th, pm, omega_l, bias_l, scale_l, x, y, beta, axis, dfull
                )
                return (th, pm), out

            (theta_l, pmat_l), outs = jax.lax.scan(body, carry0, (xs, ys))
            return theta_l, pmat_l, step + xs.shape[0], outs

        in_specs = (
            fspec.omega, fspec.bias, fspec.scale,
            sspec.theta, sspec.pmat, sspec.step,
            P(), P(),
        )
    else:

        def node(
            omega_l, bias_l, scale_l, theta_l, pmat_l, step, xs, ys, mask
        ):
            carry0 = _mark_varying((theta_l, pmat_l), axis)

            def body(carry, xym):
                th, pm = carry
                xb, yb, mb = xym
                th, pm, out = _sharded_rls_block_tick(
                    th, pm, omega_l, bias_l, scale_l, xb, yb, mb, beta,
                    axis, dfull,
                )
                return (th, pm), out

            (theta_l, pmat_l), outs = jax.lax.scan(
                body, carry0, (xs, ys, mask)
            )
            outs = jax.tree.map(lambda a: a.reshape(-1), outs)
            ticks = jnp.sum(mask).astype(step.dtype)
            return theta_l, pmat_l, step + ticks, outs

        in_specs = (
            fspec.omega, fspec.bias, fspec.scale,
            sspec.theta, sspec.pmat, sspec.step,
            P(), P(), P(),
        )

    shmapped = _shard_map(
        node,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(sspec.theta, sspec.pmat, sspec.step, P()),
    )
    return jax.jit(shmapped)


def sharded_krls_run(
    mesh: Mesh,
    rff: FeatureLike,
    xs: jax.Array,
    ys: jax.Array,
    lam: float = 1e-4,
    beta: float = 0.9995,
    state: RLSState | None = None,
    axis: str = KRLS_SHARD_AXIS,
    combine_every: int = 1,
) -> tuple[RLSState, StepOut]:
    """Stream driver on the sharded layout: scan over time *inside* one
    shard_map, so the whole stream is a single program with one psum/tick.

    ``xs (n, d)`` / ``ys (n,)`` are replicated (each tick is one global
    sample — the single-stream setting; the bank engine handles multi-tenant
    batches). Numerically equivalent to :func:`rff_krls_run` to ~1e-5.

    ``combine_every=k`` batches k ticks per psum (the DCN deployment knob):
    the stream is scanned in k-tick blocks through the packed-psum replay
    of :func:`_sharded_rls_block_tick` (zero-masked final block for
    ``n % k``). Exact modulo FP summation order — drift vs the per-tick
    psum is bounded at 1e-5 f32 / 1e-8 f64 in tests.
    """
    if state is None:
        state = sharded_krls_init(
            mesh, rff.num_features, lam, feature_dtype(rff), axis
        )
    tf = shard_krls_rff(mesh, rff, axis)
    program = _sharded_krls_run_program(
        mesh, axis, beta, tf.num_features, combine_every
    )
    if combine_every == 1:
        theta, pmat, step, outs = program(
            tf.omega, tf.bias, tf.scale,
            state.theta, state.pmat, state.step, xs, ys,
        )
        return RLSState(theta=theta, pmat=pmat, step=step), outs

    k = combine_every
    n = xs.shape[0]
    xs_b = time_blocks(xs, k)
    ys_b = time_blocks(ys, k)
    mask_b = valid_time_mask(n, k, xs.dtype)
    theta, pmat, step, outs = program(
        tf.omega, tf.bias, tf.scale,
        state.theta, state.pmat, state.step,
        xs_b, ys_b, mask_b,
    )
    outs = jax.tree.map(lambda a: a[:n], outs)
    return RLSState(theta=theta, pmat=pmat, step=step), outs
