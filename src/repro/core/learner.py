"""Unified ``OnlineLearner`` interface over every kernel adaptive filter.

The five algorithms in core/ (RFFKLMS, normalized RFFKLMS, QKLMS, RFFKRLS,
ALD-KRLS) historically exposed ad-hoc ``*_init/_step/_run`` signatures. This
module wraps each behind one protocol:

    init(key) -> state                    (key ignored by deterministic inits)
    step(state, x, y) -> (state, StepOut) (one online sample)
    run(state, xs, ys) -> (state, StepOut arrays)   (lax.scan stream drive)
    predict(state, x) -> y_hat            (inference, no update)
    rebuild(xs, ys, state, mode) -> state (parallel-in-time replay; falls
                                           back to a sequential run for
                                           learners without scan elements)

so drivers, benchmarks, the vmapped filter bank (core/bank.py) and the
serving loop never branch on the algorithm. Adapters are thin closures over
the existing pure functions — the legacy API stays available and every
adapter is numerically identical to the ``rff_*_run`` it wraps (tested).

The design also makes the *feature family* a constructor argument ("No-Trick
Kernel Adaptive Filtering using Deterministic Features" motivates swapping
RFF for deterministic maps): every RFF-family adapter takes any
:mod:`repro.features` map — the legacy ``RFF`` struct, a canonical
``TrigFeatures``, or a ``FeatureMap`` of any family (rff / orf / qmc / gq /
taylor) — and drives it through the generic ``featurize`` contract. Only
the sharded-KRLS adapter requires a trig-canonical family (its shard_map
program inlines the affine-trig activation).

An ``OnlineLearner`` is a static bundle of pure functions — close over it in
jitted code (don't pass it as a traced argument); only ``state`` is a pytree.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.klms import (
    rff_klms_init,
    rff_klms_step,
    rff_nklms_step,
)
from repro.core.krls import (
    KRLS_SHARD_AXIS,
    make_sharded_krls_predict,
    make_sharded_krls_step,
    rff_krls_init,
    rff_krls_step,
    sharded_krls_init,
)
from repro.core.krls_ald import ald_krls_init, ald_krls_predict, ald_krls_step
from repro.core.qklms import qklms_init, qklms_predict, qklms_step
from repro.core.scan import (
    ScanElement,
    klms_scan_element,
    krls_scan_element,
    nklms_scan_element,
    replay_klms,
    replay_krls,
)
from repro.features.base import FeatureLike, feature_dtype, featurize

__all__ = [
    "OnlineLearner",
    "klms_learner",
    "nklms_learner",
    "krls_learner",
    "sharded_krls_learner",
    "qklms_learner",
    "ald_krls_learner",
]


@dataclass(frozen=True)
class OnlineLearner:
    """Algorithm-agnostic online learner: three pure functions + a driver.

    Attributes:
      init_fn: ``(key | None) -> state`` — fresh filter state.
      step_fn: ``(state, x, y) -> (state, StepOut)`` — one online update.
      predict_fn: ``(state, x) -> y_hat`` — inference without updating.
      scan_element: the recurrence as an associative algebra
        (:class:`repro.core.scan.ScanElement`), or None for learners whose
        state update is not an associative element (growing-dictionary
        baselines, sharded programs).
      replay_fn: ``(xs, ys, state=None, mode=..., chunk=...) -> state`` —
        the parallel-in-time state rebuild (core/scan.py), or None to fall
        back to a sequential ``run`` in :meth:`rebuild`.
    """

    init_fn: Callable
    step_fn: Callable
    predict_fn: Callable
    scan_element: Optional[ScanElement] = None
    replay_fn: Optional[Callable] = None

    def init(self, key: Optional[jax.Array] = None):
        return self.init_fn(key)

    def step(self, state, x: jax.Array, y: jax.Array):
        return self.step_fn(state, x, y)

    def predict(self, state, x: jax.Array) -> jax.Array:
        return self.predict_fn(state, x)

    def run(self, state, xs: jax.Array, ys: jax.Array):
        """Drive the filter over a stream ``xs (n, d)``, ``ys (n,)``.

        ``state=None`` starts fresh. Returns (final state, per-step StepOut
        arrays) — ``out.error**2`` is the learning-curve quantity.
        """
        if state is None:
            state = self.init()

        def body(s, xy):
            return self.step_fn(s, *xy)

        return jax.lax.scan(body, state, (xs, ys))

    def rebuild(
        self,
        xs: jax.Array,
        ys: jax.Array,
        state=None,
        mode: str = "scan",
        chunk: Optional[int] = None,
    ):
        """Reconstruct the final state from a replay log (no per-tick outs).

        ``mode="sequential"`` (or a learner without a ``replay_fn``) drives
        the ordinary scan — bitwise the training path. ``"scan"`` /
        ``"blocked"`` rebuild through the associative-element engine in
        O(log T) / O(Tc + log nc) depth within the tolerances pinned in
        tests/test_replay.py.
        """
        if self.replay_fn is None or mode == "sequential":
            final, _ = self.run(state, xs, ys)
            return final
        return self.replay_fn(xs, ys, state=state, mode=mode, chunk=chunk)


def klms_learner(rff: FeatureLike, mu: float) -> OnlineLearner:
    """RFFKLMS (paper §4): fixed-size theta, per-step O(D d).

    ``rff`` is any feature map from :mod:`repro.features` (or the legacy
    ``RFF`` struct) — deterministic families drop in unchanged."""
    return OnlineLearner(
        init_fn=lambda key=None: rff_klms_init(
            rff.num_features, feature_dtype(rff)
        ),
        step_fn=lambda s, x, y: rff_klms_step(s, (x, y), rff, mu),
        predict_fn=lambda s, x: featurize(rff, x) @ s.theta,
        scan_element=klms_scan_element(mu),
        replay_fn=lambda xs, ys, state=None, mode="scan", chunk=None: (
            replay_klms(rff, xs, ys, mu, state=state, mode=mode, chunk=chunk)
        ),
    )


def nklms_learner(
    rff: FeatureLike, mu: float, eps: float = 1e-6
) -> OnlineLearner:
    """Normalized RFFKLMS: mu_eff = mu / (eps + ||z||^2)."""
    return OnlineLearner(
        init_fn=lambda key=None: rff_klms_init(
            rff.num_features, feature_dtype(rff)
        ),
        step_fn=lambda s, x, y: rff_nklms_step(s, (x, y), rff, mu, eps),
        predict_fn=lambda s, x: featurize(rff, x) @ s.theta,
        scan_element=nklms_scan_element(mu, eps),
        replay_fn=lambda xs, ys, state=None, mode="scan", chunk=None: (
            replay_klms(
                rff, xs, ys, mu, state=state, mode=mode, chunk=chunk,
                normalized=True, eps=eps,
            )
        ),
    )


def krls_learner(
    rff: FeatureLike, lam: float = 1e-4, beta: float = 0.9995
) -> OnlineLearner:
    """RFFKRLS (paper §6): fixed (D,) theta + (D, D) inverse correlation."""
    return OnlineLearner(
        init_fn=lambda key=None: rff_krls_init(
            rff.num_features, lam, feature_dtype(rff)
        ),
        step_fn=lambda s, x, y: rff_krls_step(s, (x, y), rff, beta),
        predict_fn=lambda s, x: featurize(rff, x) @ s.theta,
        scan_element=krls_scan_element(beta),
        replay_fn=lambda xs, ys, state=None, mode="scan", chunk=None: (
            replay_krls(
                rff, xs, ys, lam=lam, beta=beta, state=state, mode=mode,
                chunk=chunk,
            )
        ),
    )


def sharded_krls_learner(
    mesh,
    rff: FeatureLike,
    lam: float = 1e-4,
    beta: float = 0.9995,
    axis: str = KRLS_SHARD_AXIS,
) -> OnlineLearner:
    """RFFKRLS with ``P`` row-sharded over mesh ``axis`` (one psum/tick).

    Drop-in replacement for :func:`krls_learner` past the single-chip memory
    wall: state leaves are globally-shaped arrays carrying the
    ``core.krls.krls_state_specs`` layout, and step/predict are jitted
    ``shard_map`` programs. Numerically equivalent to the dense adapter to
    ~1e-5 (tested over 500+ ticks on an 8-way host mesh).
    """
    step = make_sharded_krls_step(mesh, rff, beta, axis)
    predict = make_sharded_krls_predict(mesh, rff, axis)
    return OnlineLearner(
        init_fn=lambda key=None: sharded_krls_init(
            mesh, rff.num_features, lam, feature_dtype(rff), axis
        ),
        step_fn=step,
        predict_fn=predict,
    )


def qklms_learner(
    input_dim: int,
    sigma: float,
    mu: float,
    eps: float,
    capacity: int = 512,
    dtype: jnp.dtype = jnp.float32,
) -> OnlineLearner:
    """QKLMS baseline (growing dictionary, fixed-capacity buffer)."""
    return OnlineLearner(
        init_fn=lambda key=None: qklms_init(capacity, input_dim, dtype),
        step_fn=lambda s, x, y: qklms_step(s, (x, y), sigma, mu, eps),
        predict_fn=lambda s, x: qklms_predict(s, x, sigma),
    )


def ald_krls_learner(
    input_dim: int,
    sigma: float,
    nu: float = 5e-4,
    capacity: int = 256,
    dtype: jnp.dtype = jnp.float32,
) -> OnlineLearner:
    """Engel's ALD-KRLS baseline (growing dictionary, O(M^2) per step)."""
    return OnlineLearner(
        init_fn=lambda key=None: ald_krls_init(capacity, input_dim, dtype),
        step_fn=lambda s, x, y: ald_krls_step(s, (x, y), sigma, nu),
        predict_fn=lambda s, x: ald_krls_predict(s, x, sigma),
    )
