"""QKLMS — Quantized Kernel LMS (Chen et al. 2012), the paper's §2 baseline.

Growing-dictionary KLMS with input-space quantization: a new center is added
only if its squared distance to the dictionary exceeds ``eps``; otherwise the
nearest center's coefficient absorbs the update.

JAX needs static shapes, so the dictionary is a fixed-capacity buffer
``(capacity, d)`` with an occupancy count; per-step cost is O(capacity * d)
(the sequential dictionary search the paper criticizes — faithfully
reproduced, including its cost profile).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.klms import StepOut

__all__ = ["QKLMSState", "qklms_init", "qklms_step", "qklms_run", "qklms_predict"]

_BIG = 1e30


class QKLMSState(NamedTuple):
    centers: jax.Array  # (capacity, d)
    coeffs: jax.Array  # (capacity,)
    size: jax.Array  # () int32 current dictionary size M
    step: jax.Array  # () int32


def qklms_init(
    capacity: int, input_dim: int, dtype: jnp.dtype = jnp.float32
) -> QKLMSState:
    return QKLMSState(
        centers=jnp.zeros((capacity, input_dim), dtype),
        coeffs=jnp.zeros((capacity,), dtype),
        size=jnp.zeros((), jnp.int32),
        step=jnp.zeros((), jnp.int32),
    )


def _kernel_vec(centers: jax.Array, x: jax.Array, sigma: float) -> jax.Array:
    sq = jnp.sum(jnp.square(centers - x[None, :]), axis=-1)
    return jnp.exp(-sq / (2.0 * sigma**2)), sq


def qklms_predict(state: QKLMSState, x: jax.Array, sigma: float) -> jax.Array:
    """f(x) = sum_k theta_k kappa(c_k, x) over occupied slots."""
    kvec, _ = _kernel_vec(state.centers, x, sigma)
    mask = jnp.arange(state.centers.shape[0]) < state.size
    return jnp.sum(jnp.where(mask, state.coeffs * kvec, 0.0))


def qklms_step(
    state: QKLMSState,
    sample: tuple[jax.Array, jax.Array],
    sigma: float,
    mu: float,
    eps: float,
) -> tuple[QKLMSState, StepOut]:
    """One QKLMS iteration (paper §2 steps 1–6).

    ``eps`` is the quantization size (squared-distance threshold, matching the
    paper's ``d_k = ||x - c_k||^2`` comparison).
    """
    x, y = sample
    capacity = state.centers.shape[0]
    occupied = jnp.arange(capacity) < state.size

    kvec, sq = _kernel_vec(state.centers, x, sigma)
    y_hat = jnp.sum(jnp.where(occupied, state.coeffs * kvec, 0.0))
    err = y - y_hat

    dists = jnp.where(occupied, sq, _BIG)
    k_min = jnp.argmin(dists)
    d_min = dists[k_min]

    # Insert position when growing (clamped; if full we fall back to nearest).
    insert_at = jnp.minimum(state.size, capacity - 1)
    full = state.size >= capacity
    grow = (d_min >= eps) & (state.size > 0) & ~full
    first = state.size == 0
    do_insert = grow | first
    slot = jnp.where(do_insert, insert_at, k_min)

    new_coeff = jnp.where(
        do_insert, mu * err, state.coeffs[slot] + mu * err
    )
    coeffs = state.coeffs.at[slot].set(new_coeff)
    centers = jnp.where(
        do_insert,
        state.centers.at[slot].set(x),
        state.centers,
    )
    size = state.size + jnp.where(do_insert, 1, 0).astype(jnp.int32)
    return (
        QKLMSState(centers=centers, coeffs=coeffs, size=size, step=state.step + 1),
        StepOut(prediction=y_hat, error=err),
    )


def qklms_run(
    xs: jax.Array,
    ys: jax.Array,
    sigma: float,
    mu: float,
    eps: float,
    capacity: int = 512,
) -> tuple[QKLMSState, StepOut]:
    """Stream driver (lax.scan). ``capacity`` bounds dictionary growth."""
    state = qklms_init(capacity, xs.shape[-1], xs.dtype)

    def body(s, xy):
        return qklms_step(s, xy, sigma, mu, eps)

    return jax.lax.scan(body, state, (xs, ys))
