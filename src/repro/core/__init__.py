"""Core library: the paper's contribution as composable JAX modules.

RFF feature maps (rff), RFFKLMS (klms), RFFKRLS (krls), the paper's baselines
QKLMS (qklms) and Engel's ALD-KRLS (krls_ald), the convergence theory oracles
(theory), Monte-Carlo drivers (adaptive), diffusion-distributed variants
(distributed), the unified OnlineLearner interface (learner) and the vmapped
multi-stream filter bank (bank).
"""
from repro.core.rff import (
    RFF,
    sample_rff,
    rff_features,
    kernel_estimate,
    gaussian_kernel,
    sample_prf,
    positive_random_features,
)
from repro.core.klms import (
    LMSState,
    StepOut,
    rff_klms_init,
    rff_klms_step,
    rff_klms_run,
    rff_klms_batch_step,
)
from repro.core.krls import (
    KRLS_SHARD_AXIS,
    RLSState,
    krls_feature_specs,
    krls_state_specs,
    make_sharded_krls_predict,
    make_sharded_krls_step,
    rff_krls_init,
    rff_krls_run,
    rff_krls_step,
    shard_krls_rff,
    sharded_krls_init,
    sharded_krls_run,
)
from repro.core.qklms import QKLMSState, qklms_init, qklms_step, qklms_run
from repro.core.krls_ald import (
    ALDKRLSState,
    ald_krls_init,
    ald_krls_step,
    ald_krls_run,
)
from repro.core.learner import (
    OnlineLearner,
    klms_learner,
    nklms_learner,
    krls_learner,
    sharded_krls_learner,
    qklms_learner,
    ald_krls_learner,
)
from repro.core.bank import (
    bank_init,
    bank_step,
    bank_run,
    bank_predict,
    klms_bank_init,
    klms_bank_step,
    klms_bank_run,
    krls_bank_init,
    krls_bank_step,
    krls_bank_run,
)
from repro.core import theory, adaptive, distributed

__all__ = [
    "OnlineLearner",
    "klms_learner",
    "nklms_learner",
    "krls_learner",
    "qklms_learner",
    "ald_krls_learner",
    "bank_init",
    "bank_step",
    "bank_run",
    "bank_predict",
    "klms_bank_init",
    "klms_bank_step",
    "klms_bank_run",
    "krls_bank_init",
    "krls_bank_step",
    "krls_bank_run",
    "RFF",
    "sample_rff",
    "rff_features",
    "kernel_estimate",
    "gaussian_kernel",
    "sample_prf",
    "positive_random_features",
    "LMSState",
    "StepOut",
    "rff_klms_init",
    "rff_klms_step",
    "rff_klms_run",
    "rff_klms_batch_step",
    "RLSState",
    "rff_krls_init",
    "rff_krls_step",
    "rff_krls_run",
    "KRLS_SHARD_AXIS",
    "krls_state_specs",
    "krls_feature_specs",
    "shard_krls_rff",
    "sharded_krls_init",
    "sharded_krls_run",
    "make_sharded_krls_step",
    "make_sharded_krls_predict",
    "sharded_krls_learner",
    "QKLMSState",
    "qklms_init",
    "qklms_step",
    "qklms_run",
    "ALDKRLSState",
    "ald_krls_init",
    "ald_krls_step",
    "ald_krls_run",
    "theory",
    "adaptive",
    "distributed",
]
