"""Parallel-in-time replay: learner recurrences as associative scan elements.

The paper's fixed-size-state dividend, pushed one level further. Because the
RFF map turns every learner's state into a fixed-size Euclidean object, each
per-tick update is a *structured affine map* on that state — and affine maps
compose associatively. T strictly-sequential ticks therefore rebuild in
O(log T) depth via ``jax.lax.associative_scan`` (the Blelloch up/down sweep
of SNIPPETS.md's ``MatScan``), which is what makes tenant rebuild from a
replay log, bulk import, and recovery after bank-slot eviction
*throughput*-bound instead of latency-bound.

Two element algebras cover every scannable learner in core/:

* **Affine elements** (KLMS / NKLMS): the LMS tick is
  ``theta' = (I - mu z z^T) theta + mu y z`` — an :class:`AffineElement`
  ``(A, v)`` acting as ``theta -> A theta + v``, composed by
  ``(A2 A1, A2 v1 + v2)``. Normalized LMS fits because ``mu_eff`` depends
  only on ``z``. Composition is a (D, D) matmul, so the parallel scan
  trades O(D) extra work for O(T / log T) less depth.
* **Decay elements** (KRLS): Sherman-Morrison order-dependence disappears in
  information form. With ``Phi = P^{-1}`` the EW-RLS recursion is
  ``Phi' = beta Phi + z z^T``, ``r' = beta r + y z`` and
  ``theta = Phi^{-1} r`` — a :class:`DecayElement` ``(g, Phi_add, r_add)``
  whose combine is O(D^2) adds, the *same* order as a sequential tick. The
  one matrix inversion happens once at the end, not once per tick; the
  rank-1 inverse-update order the sequential path commits to is recovered
  only to solver accuracy, so the dense sequential replay
  (:func:`repro.core.krls.rff_krls_run`) stays the fallback where exact
  inversion order matters (tolerances pinned in tests/test_replay.py).

Execution modes (``replay_klms`` / ``replay_krls``):

* ``"sequential"`` — the existing jitted per-tick/chunked drivers; bitwise
  the training path (the rebuild-correctness reference).
* ``"scan"`` — XLA ``associative_scan`` over per-tick elements. O(log T)
  depth; materializes (T, D, D) elements, so it is the small-D/medium-T
  reference implementation.
* ``"blocked"`` — the production path: a time-blocked Pallas kernel
  (kernels/rff_scan.py) composes each chunk's ticks into ONE element on a
  VMEM-resident (D, D) accumulator (the chunk kernels' scratch-residency
  pattern, O(D^2) rank-1 composition per tick), then a short cross-chunk
  ``associative_scan`` over the nc per-chunk elements finishes in
  O(Tc + log nc) depth with only (nc, D, D) materialized.

Non-trig feature families (taylor) run the generic ``featurize`` path under
``"scan"``; ``"blocked"`` requires the canonical affine-trig form and falls
back to ``"scan"`` otherwise.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.klms import LMSState, rff_klms_init, rff_klms_run
from repro.core.krls import RLSState, rff_krls_init, rff_krls_run
from repro.features.base import (
    FeatureLike,
    as_trig_or_none,
    feature_dtype,
    featurize,
)
from repro.kernels import ops

__all__ = [
    "AffineElement",
    "DecayElement",
    "ScanElement",
    "affine_combine",
    "affine_identity",
    "affine_apply",
    "decay_combine",
    "decay_identity",
    "decay_apply",
    "klms_to_element",
    "nklms_to_element",
    "krls_to_element",
    "klms_scan_element",
    "nklms_scan_element",
    "krls_scan_element",
    "replay_klms",
    "replay_krls",
]


# ---------------------------------------------------------------------------
# Element algebras.
# ---------------------------------------------------------------------------


class AffineElement(NamedTuple):
    """One (or a batch of) affine state maps ``theta -> a @ theta + v``.

    Attributes:
      a: ``(..., D, D)`` linear part (``I - mu z z^T`` for one LMS tick).
      v: ``(..., D)`` offset (``mu y z`` for one LMS tick).
    """

    a: jax.Array
    v: jax.Array


def affine_combine(first: AffineElement, second: AffineElement) -> AffineElement:
    """Compose two affine maps: apply ``first``, then ``second``.

    ``(A2, v2) . (A1, v1) = (A2 A1, A2 v1 + v2)`` — associative, which is
    the whole point. Leading batch axes broadcast (``associative_scan``
    calls this on stacked slices).
    """
    return AffineElement(
        a=jnp.einsum("...ij,...jk->...ik", second.a, first.a),
        v=jnp.einsum("...ij,...j->...i", second.a, first.v) + second.v,
    )


def affine_identity(num_features: int, dtype=jnp.float32) -> AffineElement:
    """The do-nothing tick: ``(I, 0)``."""
    return AffineElement(
        a=jnp.eye(num_features, dtype=dtype),
        v=jnp.zeros((num_features,), dtype),
    )


def affine_apply(element: AffineElement, theta: jax.Array) -> jax.Array:
    """``A theta + v`` — advance a start state through a composed element."""
    return jnp.einsum("...ij,...j->...i", element.a, theta) + element.v


class DecayElement(NamedTuple):
    """Scalar-gated additive maps ``(Phi, r) -> (g Phi + phi, g r + r_add)``.

    The information-form KRLS algebra: one tick contributes
    ``(g=beta, phi=z z^T, r=y z)``. Composition stays O(D^2) — no matmul —
    so the parallel scan costs the same work as the sequential recursion.
    """

    g: jax.Array  # (...,) scalar decay
    phi: jax.Array  # (..., D, D) additive information matrix
    r: jax.Array  # (..., D) additive information vector


def decay_combine(first: DecayElement, second: DecayElement) -> DecayElement:
    """Compose two decay elements: apply ``first``, then ``second``."""
    g2 = second.g
    return DecayElement(
        g=g2 * first.g,
        phi=g2[..., None, None] * first.phi + second.phi,
        r=g2[..., None] * first.r + second.r,
    )


def decay_identity(num_features: int, dtype=jnp.float32) -> DecayElement:
    """The do-nothing tick: ``(1, 0, 0)``."""
    return DecayElement(
        g=jnp.ones((), dtype),
        phi=jnp.zeros((num_features, num_features), dtype),
        r=jnp.zeros((num_features,), dtype),
    )


def decay_apply(
    element: DecayElement, phi0: jax.Array, r0: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Advance start information state ``(Phi_0, r_0)`` through an element."""
    return (
        element.g[..., None, None] * phi0 + element.phi,
        element.g[..., None] * r0 + element.r,
    )


# ---------------------------------------------------------------------------
# Per-learner tick elements.
# ---------------------------------------------------------------------------


def klms_to_element(z: jax.Array, y: jax.Array, mu) -> AffineElement:
    """One KLMS tick as an affine element: ``(I - mu z z^T, mu y z)``.

    ``z`` ``(..., D)`` featurized inputs, ``y`` ``(...,)`` targets; leading
    axes batch (build all T tick elements in one call).
    """
    dfeat = z.shape[-1]
    eye = jnp.eye(dfeat, dtype=z.dtype)
    mu = jnp.asarray(mu, z.dtype)
    a = eye - mu * z[..., :, None] * z[..., None, :]
    return AffineElement(a=a, v=mu * y[..., None] * z)


def nklms_to_element(
    z: jax.Array, y: jax.Array, mu, eps: float = 1e-6
) -> AffineElement:
    """One normalized-LMS tick: ``mu_eff = mu / (eps + ||z||^2)`` — still
    affine in theta because the normalizer depends only on ``z``."""
    mu_eff = jnp.asarray(mu, z.dtype) / (
        eps + jnp.sum(z * z, axis=-1, keepdims=True)
    )
    a = (
        jnp.eye(z.shape[-1], dtype=z.dtype)
        - mu_eff[..., None] * z[..., :, None] * z[..., None, :]
    )
    return AffineElement(a=a, v=mu_eff * y[..., None] * z)


def krls_to_element(z: jax.Array, y: jax.Array, beta) -> DecayElement:
    """One EW-RLS tick in information form: ``(beta, z z^T, y z)``."""
    beta = jnp.asarray(beta, z.dtype)
    return DecayElement(
        g=jnp.broadcast_to(beta, z.shape[:-1]),
        phi=z[..., :, None] * z[..., None, :],
        r=y[..., None] * z,
    )


# ---------------------------------------------------------------------------
# The ScanElement contract — one bundle per learner family, carried by
# core.learner.OnlineLearner so drivers can replay any scannable learner
# without branching on the algorithm.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ScanElement:
    """A learner recurrence packaged as an associative algebra.

    Attributes:
      to_element: ``(z, y) -> element`` — one tick (hyperparams closed
        over), batched over leading axes.
      combine: associative ``(first, second) -> element`` composition.
      identity: ``(num_features, dtype) -> element`` — the no-op tick.
      apply: ``(element, state) -> state`` — advance a learner state
        through a composed element (the only non-element-space step).
    """

    to_element: Callable
    combine: Callable
    identity: Callable
    apply: Callable


def _affine_apply_state(element: AffineElement, state: LMSState) -> LMSState:
    """Advance an :class:`LMSState` through a composed affine element.

    A composed element has no memory of how many ticks it folded, so step
    accounting is the driver's job (``replay_*`` add the log length)."""
    return LMSState(theta=affine_apply(element, state.theta), step=state.step)


def klms_scan_element(mu: float) -> ScanElement:
    """The KLMS recurrence as a :class:`ScanElement` (fixed ``mu``)."""
    return ScanElement(
        to_element=lambda z, y: klms_to_element(z, y, mu),
        combine=affine_combine,
        identity=affine_identity,
        apply=_affine_apply_state,
    )


def nklms_scan_element(mu: float, eps: float = 1e-6) -> ScanElement:
    """The normalized-KLMS recurrence as a :class:`ScanElement`."""
    return ScanElement(
        to_element=lambda z, y: nklms_to_element(z, y, mu, eps),
        combine=affine_combine,
        identity=affine_identity,
        apply=_affine_apply_state,
    )


def krls_scan_element(beta: float) -> ScanElement:
    """The EW-RLS recurrence (information form) as a :class:`ScanElement`.

    ``apply`` converts the composed element back to covariance form with one
    solve + one inversion — see :func:`_decay_to_rls` for the numerics.
    """
    return ScanElement(
        to_element=lambda z, y: krls_to_element(z, y, beta),
        combine=decay_combine,
        identity=decay_identity,
        apply=_decay_apply_state,
    )


def _decay_to_rls(
    phi: jax.Array, r: jax.Array, step: jax.Array
) -> RLSState:
    """Information form -> covariance form: ``theta = Phi^{-1} r``,
    ``P = Phi^{-1}`` (symmetrized, same hygiene as the sequential path)."""
    pmat = jnp.linalg.inv(phi)
    pmat = 0.5 * (pmat + pmat.T)
    theta = jnp.linalg.solve(phi, r)
    return RLSState(theta=theta, pmat=pmat, step=step)


def _decay_apply_state(element: DecayElement, state: RLSState) -> RLSState:
    """Advance an :class:`RLSState` through a composed decay element.

    The start covariance is inverted once (``Phi_0 = P_0^{-1}``,
    ``r_0 = Phi_0 theta_0``) — exact for the fresh ``P_0 = I / lam`` and
    solver-accurate for warm starts. Step accounting is the driver's job
    (a composed element has no memory of how many ticks it folded).
    """
    phi0 = jnp.linalg.inv(state.pmat)
    phi0 = 0.5 * (phi0 + phi0.T)
    r0 = phi0 @ state.theta
    phi, r = decay_apply(element, phi0, r0)
    return _decay_to_rls(phi, r, state.step)


# ---------------------------------------------------------------------------
# Replay drivers — rebuild a learner state from a (xs, ys) log.
# ---------------------------------------------------------------------------


def _last(tree):
    return jax.tree.map(lambda a: a[-1], tree)


def replay_klms(
    rff: FeatureLike,
    xs: jax.Array,
    ys: jax.Array,
    mu,
    state: Optional[LMSState] = None,
    mode: str = "scan",
    chunk: Optional[int] = None,
    normalized: bool = False,
    eps: float = 1e-6,
    kernel_mode: str = "auto",
) -> LMSState:
    """Rebuild a KLMS state from a replay log ``xs (T, d)``, ``ys (T,)``.

    ``mode``:
      * ``"sequential"`` — jitted per-tick scan (:func:`rff_klms_run`);
        bitwise the training path.
      * ``"scan"`` — per-tick affine elements + ``associative_scan``
        (O(log T) depth, (T, D, D) element memory).
      * ``"blocked"`` — Pallas per-chunk element composition + short
        cross-chunk scan (O(Tc + log nc) depth, (nc, D, D) memory);
        ``chunk=None`` picks the element-aware VMEM-budget default.

    Non-sequential modes match the sequential trajectory to reassociation
    rounding (pinned in tests/test_replay.py), not bitwise — composing
    ``A_t`` products reorders the floating-point work by design.
    """
    if state is None:
        state = rff_klms_init(rff.num_features, feature_dtype(rff))
    if mode == "sequential":
        final, _ = rff_klms_run(
            rff, xs, ys, mu, state=state, normalized=normalized
        )
        return final
    tf = as_trig_or_none(rff)
    if mode == "blocked" and tf is None:
        mode = "scan"  # non-trig families have no fused kernel form
    if mode == "scan":
        fm = rff if tf is None else tf
        z = featurize(fm, xs)  # (T, D) — one GEMM
        to_el = nklms_to_element if normalized else klms_to_element
        args = (mu, eps) if normalized else (mu,)
        elements = to_el(z, ys, *args)
        composed = _last(jax.lax.associative_scan(affine_combine, elements))
    elif mode == "blocked":
        a, v = ops.rff_klms_chunk_elements(
            xs, ys, tf.omega, tf.bias, mu, tf.scale,
            mode=kernel_mode, chunk=chunk, normalized=normalized, eps=eps,
        )
        composed = _last(
            jax.lax.associative_scan(affine_combine, AffineElement(a, v))
        )
    else:
        raise ValueError(f"unknown replay mode {mode!r}")
    return LMSState(
        theta=affine_apply(composed, state.theta),
        step=state.step + xs.shape[0],
    )


def replay_krls(
    rff: FeatureLike,
    xs: jax.Array,
    ys: jax.Array,
    lam: float = 1e-4,
    beta: float = 0.9995,
    state: Optional[RLSState] = None,
    mode: str = "scan",
    chunk: Optional[int] = None,
    kernel_mode: str = "auto",
) -> RLSState:
    """Rebuild a KRLS state from a replay log ``xs (T, d)``, ``ys (T,)``.

    ``mode`` as :func:`replay_klms`, with ``"sequential"`` the dense
    Sherman-Morrison replay (:func:`rff_krls_run`) — the fallback where
    exact inversion order matters. Scan modes accumulate the information
    form and invert ONCE; they track the sequential trajectory to solver
    accuracy (<= 1e-5 f32 / 1e-8 f64 over >= 1024 ticks, pinned in
    tests/test_replay.py).
    """
    if mode == "sequential":
        final, _ = rff_krls_run(
            rff, xs, ys, lam=lam, beta=beta, state=state
        )
        return final
    dtype = feature_dtype(rff)
    tf = as_trig_or_none(rff)
    if mode == "blocked" and tf is None:
        mode = "scan"
    if mode == "scan":
        fm = rff if tf is None else tf
        z = featurize(fm, xs)  # (T, D) — one GEMM
        elements = krls_to_element(z, ys, beta)
        composed = _last(jax.lax.associative_scan(decay_combine, elements))
    elif mode == "blocked":
        g, phi, r = ops.rff_krls_chunk_elements(
            xs, ys, tf.omega, tf.bias, beta, tf.scale,
            mode=kernel_mode, chunk=chunk,
        )
        composed = _last(
            jax.lax.associative_scan(decay_combine, DecayElement(g, phi, r))
        )
    else:
        raise ValueError(f"unknown replay mode {mode!r}")
    dfeat = rff.num_features
    if state is None:
        # Fresh start: Phi_0 = lam I exactly — no inversion needed.
        phi0 = lam * jnp.eye(dfeat, dtype=dtype)
        phi, r = decay_apply(composed, phi0, jnp.zeros((dfeat,), dtype))
        return _decay_to_rls(phi, r, jnp.asarray(xs.shape[0], jnp.int32))
    final = _decay_apply_state(composed, state)
    return final._replace(step=state.step + xs.shape[0])
