"""Random Fourier feature maps (Rahimi & Recht) — the paper's core device.

Theorem 1 (paper): for a shift-invariant PD kernel ``kappa(x - y)`` with
Fourier transform ``p(omega)`` (a probability density by Bochner's theorem),

    z_{omega,b}(x) = sqrt(2) * cos(omega^T x + b),
    kappa(x - y)  = E_{omega~p, b~U[0,2pi]}[ z(x) z(y) ].

Sampling ``D`` features gives the Monte-Carlo estimate (paper eq. (2)–(4)):

    kappa(x - y) ~= z_Omega(x)^T z_Omega(y),
    z_Omega(x)   = sqrt(2/D) [cos(omega_i^T x + b_i)]_{i=1..D}.

For the Gaussian kernel ``kappa_sigma(u, v) = exp(-||u-v||^2 / (2 sigma^2))``
the spectral density is ``omega ~ N(0, I_d / sigma^2)`` — paper eq. (5),
whose published form reads ``sigma^D``: the ``D`` exponent is a typo for the
input dimension ``d`` (the density normalizer is ``(sigma sqrt(2 pi))^-d``);
``D`` is the paper's feature count, which never enters the density.

Two feature families live here:

* :func:`sample_rff` / :func:`rff_features` — the paper's trig features
  (unbiased for any shift-invariant kernel; Gaussian sampling built in).
* :func:`sample_prf` / :func:`positive_random_features` — positive random
  features for the *exponential* (softmax) kernel, used by the RFF linear
  attention layer. Same fixed-size-state insight, different kernel.

This module is the Monte-Carlo seed of the pluggable feature-map subsystem
in :mod:`repro.features`: deterministic Gaussian-quadrature, Taylor, QMC and
orthogonal families all satisfy the same contract there and canonicalize to
the affine-trig form ``scale * cos(x @ W + b)`` that generalizes eq. (3) —
new code should accept any such map rather than hardcoding :class:`RFF`.

Everything is a pure function over an explicit, immutable parameter struct so
it composes with jit / vmap / scan / pjit without ceremony.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "RFF",
    "sample_rff",
    "rff_features",
    "rff_features_unscaled",
    "kernel_estimate",
    "gaussian_kernel",
    "sample_prf",
    "positive_random_features",
    "softmax_kernel_estimate",
]


class RFF(NamedTuple):
    """Immutable random-feature parameters.

    Attributes:
      omega: ``(d, D)`` spectral samples (columns are the omega_i).
      bias:  ``(D,)`` phases drawn from U[0, 2pi] (trig features) or zeros
             (positive features).
    """

    omega: jax.Array
    bias: jax.Array

    @property
    def input_dim(self) -> int:
        return self.omega.shape[0]

    @property
    def num_features(self) -> int:
        return self.omega.shape[1]


def sample_rff(
    key: jax.Array,
    input_dim: int,
    num_features: int,
    sigma: float,
    dtype: jnp.dtype = jnp.float32,
    orthogonal: bool = False,
) -> RFF:
    """Draw RFF parameters for the Gaussian kernel ``exp(-||d||^2/(2 sigma^2))``.

    ``omega ~ N(0, I/sigma^2)``, ``b ~ U[0, 2pi]`` — paper §4, eq. (5).

    ``orthogonal=True`` (beyond-paper): Orthogonal Random Features
    (Yu et al. 2016) — blocks of up to ``input_dim`` spectral samples are
    orthogonalized and rescaled to chi(d) norms. Marginals are unchanged
    (the estimator stays unbiased) but the kernel-approximation variance
    drops strictly, so the same D buys a lower RFFKLMS error floor.
    """
    k_omega, k_bias = jax.random.split(key)
    bias = jax.random.uniform(
        k_bias, (num_features,), dtype, minval=0.0, maxval=2.0 * jnp.pi
    )
    if not orthogonal:
        omega = jax.random.normal(k_omega, (input_dim, num_features), dtype) / sigma
        return RFF(omega=omega, bias=bias)

    n_blocks = -(-num_features // input_dim)
    keys = jax.random.split(k_omega, n_blocks + 1)
    blocks = []
    for i in range(n_blocks):
        g = jax.random.normal(keys[i], (input_dim, input_dim), dtype)
        q, _ = jnp.linalg.qr(g)
        blocks.append(q)
    omega = jnp.concatenate(blocks, axis=1)[:, :num_features]
    norms = jnp.sqrt(
        jax.random.chisquare(
            keys[-1], input_dim, shape=(num_features,)
        ).astype(dtype)
    )
    return RFF(omega=omega * norms[None, :] / sigma, bias=bias)


def rff_features(rff: RFF, x: jax.Array) -> jax.Array:
    """``z_Omega(x) = sqrt(2/D) cos(x @ omega + b)`` — paper eq. (3).

    Args:
      rff: feature parameters ``(d, D)`` / ``(D,)``.
      x: inputs ``(..., d)``.

    Returns:
      features ``(..., D)`` such that ``z(x) @ z(y) ~= kappa(x - y)``.
    """
    d = rff.num_features
    proj = x @ rff.omega + rff.bias
    return jnp.sqrt(2.0 / d).astype(proj.dtype) * jnp.cos(proj)


def rff_features_unscaled(rff: RFF, x: jax.Array) -> jax.Array:
    """``sqrt(2) cos(x @ omega + b)`` — per-feature form of Theorem 1."""
    proj = x @ rff.omega + rff.bias
    return jnp.sqrt(2.0).astype(proj.dtype) * jnp.cos(proj)


def kernel_estimate(rff: RFF, x: jax.Array, y: jax.Array) -> jax.Array:
    """Monte-Carlo kernel estimate ``z(x)^T z(y)`` — paper eq. (4).

    Broadcasts over leading axes: ``x (..., d)``, ``y (..., d)``. When both
    arguments are the same array object (the ``kappa(0)`` norm check), the
    feature map is computed once instead of twice.
    """
    zx = rff_features(rff, x)
    zy = zx if y is x else rff_features(rff, y)
    return jnp.sum(zx * zy, axis=-1)


def gaussian_kernel(x: jax.Array, y: jax.Array, sigma: float) -> jax.Array:
    """Exact Gaussian kernel ``exp(-||x-y||^2 / (2 sigma^2))`` (oracle)."""
    sq = jnp.sum(jnp.square(x - y), axis=-1)
    return jnp.exp(-sq / (2.0 * sigma**2))


# ---------------------------------------------------------------------------
# Positive random features (softmax / exponential kernel) — used by the
# RFF linear-attention layer (the paper's fixed-size-state idea applied to
# the attention kernel; see DESIGN.md §2).
# ---------------------------------------------------------------------------


def sample_prf(
    key: jax.Array,
    input_dim: int,
    num_features: int,
    dtype: jnp.dtype = jnp.float32,
    orthogonal: bool = True,
) -> RFF:
    """Sample projections for positive random features of ``exp(q.k)``.

    Rows are standard Gaussian; when ``orthogonal=True`` blocks of up to
    ``input_dim`` rows are orthogonalized (QR) and re-scaled to chi(d) norms,
    which provably lowers estimator variance (orthogonal random features).
    """
    if not orthogonal:
        omega = jax.random.normal(key, (input_dim, num_features), dtype)
        return RFF(omega=omega, bias=jnp.zeros((num_features,), dtype))

    n_blocks = -(-num_features // input_dim)
    keys = jax.random.split(key, n_blocks + 1)
    blocks = []
    for i in range(n_blocks):
        g = jax.random.normal(keys[i], (input_dim, input_dim), dtype)
        q, _ = jnp.linalg.qr(g)
        blocks.append(q)
    omega = jnp.concatenate(blocks, axis=1)[:, :num_features]
    # re-scale columns to chi(d)-distributed norms so marginals match iid.
    norms = jnp.sqrt(
        jax.random.chisquare(keys[-1], input_dim, shape=(num_features,)).astype(dtype)
    )
    omega = omega * norms[None, :]
    return RFF(omega=omega, bias=jnp.zeros((num_features,), dtype))


def positive_random_features(
    rff: RFF, x: jax.Array, eps: float = 1e-6
) -> jax.Array:
    """``phi(x) = exp(x @ omega - ||x||^2/2) / sqrt(D)`` (+eps), so that
    ``phi(q)^T phi(k) ~= exp(q . k)`` in expectation (softmax kernel).

    No per-vector max-shift: a shift that differs between two keys biases
    their attention-weight *ratio* and breaks the prefill/decode state
    contract (a common constant would cancel; per-key constants don't).
    Inputs are pre-scaled by ``dh**-0.25`` at the attention layer, keeping
    the exponent moderate; the ``-||x||^2/2`` term keeps it unbiased.
    """
    d = rff.num_features
    proj = x @ rff.omega
    stab = proj - jnp.sum(jnp.square(x), axis=-1, keepdims=True) / 2.0
    return jnp.exp(stab) / jnp.sqrt(jnp.asarray(d, proj.dtype)) + eps


def softmax_kernel_estimate(rff: RFF, q: jax.Array, k: jax.Array) -> jax.Array:
    """Estimate ``exp(q . k)`` up to the stability shift (relative weights)."""
    pq = positive_random_features(rff, q)
    pk = positive_random_features(rff, k)
    return jnp.sum(pq * pk, axis=-1)
