"""RFFKLMS — the paper's Algorithm (§4): linear LMS on RFF-mapped data.

The solution is a *fixed-size* vector ``theta in R^D`` — no dictionary, no
sparsification, no per-step search. Per-step cost O(D d).

    y_hat_n  = theta^T z_Omega(x_n)
    e_n      = y_n - y_hat_n
    theta   <- theta + mu * e_n * z_Omega(x_n)

Implemented as a pure ``(state, sample) -> (state, out)`` step for
``jax.lax.scan`` stream driving, plus a normalized-LMS variant (beyond-paper,
standard adaptive-filtering practice) and a mini-batch form used by the
batched benchmarks.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.features.base import FeatureLike, feature_dtype, featurize
from repro.kernels.chunking import time_blocks, unblock_time, valid_time_mask

__all__ = [
    "LMSState",
    "StepOut",
    "rff_klms_init",
    "rff_klms_step",
    "rff_klms_run",
    "rff_nklms_step",
    "rff_klms_batch_step",
    "lms_step",
]


class LMSState(NamedTuple):
    theta: jax.Array  # (D,) fixed-size solution
    step: jax.Array  # () int32 iteration counter


class StepOut(NamedTuple):
    prediction: jax.Array  # () y_hat_n
    error: jax.Array  # () e_n (prior error — the learning-curve quantity)


def rff_klms_init(num_features: int, dtype: jnp.dtype = jnp.float32) -> LMSState:
    """theta = 0 (paper: 'Set theta = 0')."""
    return LMSState(
        theta=jnp.zeros((num_features,), dtype), step=jnp.zeros((), jnp.int32)
    )


def lms_step(
    theta: jax.Array, z: jax.Array, y: jax.Array, mu: float
) -> tuple[jax.Array, StepOut]:
    """One linear-LMS update in feature space (shared by KLMS variants)."""
    y_hat = theta @ z
    err = y - y_hat
    return theta + mu * err * z, StepOut(prediction=y_hat, error=err)


def rff_klms_step(
    state: LMSState,
    sample: tuple[jax.Array, jax.Array],
    rff: FeatureLike,
    mu: float,
) -> tuple[LMSState, StepOut]:
    """Paper §4 steps 1–3 on one ``(x_n, y_n)`` pair.

    ``rff`` is any feature map satisfying the :mod:`repro.features`
    contract — the legacy ``RFF`` struct, a canonical ``TrigFeatures``, or a
    ``FeatureMap`` of any family (incl. non-trig Taylor)."""
    x, y = sample
    z = featurize(rff, x)
    theta, out = lms_step(state.theta, z, y, mu)
    return LMSState(theta=theta, step=state.step + 1), out


def rff_nklms_step(
    state: LMSState,
    sample: tuple[jax.Array, jax.Array],
    rff: FeatureLike,
    mu: float,
    eps: float = 1e-6,
) -> tuple[LMSState, StepOut]:
    """Normalized variant: mu_eff = mu / (eps + ||z||^2). Beyond-paper.

    Note ``||z_Omega(x)||^2 ~= kappa(0) = 1`` for the paper's scaling, so for
    Gaussian-kernel RFF this behaves like plain KLMS with auto step-sizing.
    """
    x, y = sample
    z = featurize(rff, x)
    y_hat = state.theta @ z
    err = y - y_hat
    theta = state.theta + (mu / (eps + z @ z)) * err * z
    return LMSState(theta=theta, step=state.step + 1), StepOut(y_hat, err)


def rff_klms_run(
    rff: FeatureLike,
    xs: jax.Array,
    ys: jax.Array,
    mu: float,
    state: LMSState | None = None,
    normalized: bool = False,
    chunk: int | None = None,
) -> tuple[LMSState, StepOut]:
    """Drive the filter over a stream ``xs (n, d)``, ``ys (n,)`` with scan.

    Returns the final state and per-step ``StepOut`` arrays ``(n,)`` —
    ``out.error**2`` averaged over realizations is the paper's learning curve.

    ``chunk=T`` scans over T-tick chunks instead of ticks: each chunk
    featurizes its T samples in ONE ``(T, d) @ (d, D)`` GEMM (the O(Dd)
    hot spot becomes matrix- rather than vector-level work) and replays the
    strictly-sequential LMS recursion over the precomputed rows. A zero-
    masked final chunk handles ``n % T`` remainders; the trajectory matches
    the per-tick scan to feature-GEMM rounding (tested).
    """
    if state is None:
        state = rff_klms_init(rff.num_features, feature_dtype(rff))
    if chunk is not None:
        return _rff_klms_run_chunked(rff, xs, ys, mu, state, normalized, chunk)
    step = rff_nklms_step if normalized else rff_klms_step

    def body(s: LMSState, xy: tuple[jax.Array, jax.Array]):
        return step(s, xy, rff, mu)

    return jax.lax.scan(body, state, (xs, ys))


def _rff_klms_run_chunked(
    rff: FeatureLike,
    xs: jax.Array,
    ys: jax.Array,
    mu: float,
    state: LMSState,
    normalized: bool,
    chunk: int,
    eps: float = 1e-6,
) -> tuple[LMSState, StepOut]:
    """Chunked scan: featurize T samples per GEMM, replay ticks in-chunk."""
    n = xs.shape[0]
    xs_c = time_blocks(xs, chunk)
    ys_c = time_blocks(ys, chunk)
    mask_c = valid_time_mask(n, chunk, xs.dtype)

    def body(s: LMSState, args):
        xc, yc, mc = args
        zc = featurize(rff, xc)  # (T, D) — one GEMM per chunk

        def tick(st: LMSState, zym):
            z, y, m = zym
            # Same update rule as the per-tick drivers: delegate to
            # lms_step (with rff_nklms_step's normalization when asked)
            # and mask via state select, so the two paths can't diverge.
            mu_eff = mu / (eps + z @ z) if normalized else mu
            theta, out = lms_step(st.theta, z, y, mu_eff)
            return (
                LMSState(
                    theta=jnp.where(m > 0, theta, st.theta),
                    step=st.step + m.astype(st.step.dtype),
                ),
                out,
            )

        return jax.lax.scan(tick, s, (zc, yc, mc))

    state, outs = jax.lax.scan(body, state, (xs_c, ys_c, mask_c))
    return state, jax.tree.map(lambda a: unblock_time(a, n), outs)


def rff_klms_batch_step(
    state: LMSState,
    xb: jax.Array,
    yb: jax.Array,
    rff: FeatureLike,
    mu: float,
) -> tuple[LMSState, jax.Array]:
    """Mini-batch LMS: average the per-sample gradients of a batch.

    This is the throughput-oriented form (one fused GEMM through the Pallas
    feature kernel instead of ``B`` matvecs); it changes the stochastic
    trajectory but not the stationary point. Returns (state, prior errors).
    """
    zb = featurize(rff, xb)  # (B, D)
    preds = zb @ state.theta
    errs = yb - preds
    grad = zb.T @ errs / xb.shape[0]
    return (
        LMSState(theta=state.theta + mu * grad, step=state.step + xb.shape[0]),
        errs,
    )
