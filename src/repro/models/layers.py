"""Functional building blocks (param dicts + pure apply fns).

No framework dependency: parameters are nested dicts of jnp arrays, inits are
explicit, apply functions are pure — trivially compatible with jit / scan /
GSPMD sharding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init",
    "dense",
    "rmsnorm_init",
    "rmsnorm",
    "embed_init",
    "glu_mlp_init",
    "glu_mlp",
    "rope_freqs",
    "apply_rope",
]


def dense_init(
    key: jax.Array,
    d_in: int,
    d_out: int,
    *,
    bias: bool = False,
    dtype=jnp.float32,
    scale: float | None = None,
) -> dict:
    scale = scale if scale is not None else d_in**-0.5
    p = {"w": (scale * jax.random.normal(key, (d_in, d_out))).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: dict, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * p["scale"].astype(jnp.float32)).astype(dt)


def embed_init(key: jax.Array, vocab: int, d: int, dtype=jnp.float32) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d)) * d**-0.5).astype(dtype)}


def glu_mlp_init(key: jax.Array, d: int, d_ff: int, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, d, d_ff, dtype=dtype),
        "wg": dense_init(k2, d, d_ff, dtype=dtype),
        "wo": dense_init(k3, d_ff, d, dtype=dtype),
    }


def glu_mlp(p: dict, x: jax.Array) -> jax.Array:
    """SwiGLU feed-forward."""
    return dense(p["wo"], jax.nn.silu(dense(p["wg"], x)) * dense(p["wi"], x))


def rope_freqs(
    positions: jax.Array, head_dim: int, theta: float = 10_000.0
) -> tuple[jax.Array, jax.Array]:
    """Rotary cos/sin tables for integer positions ``(...,)`` -> ``(..., hd/2)``."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate pairs. x: (..., S, H, hd); cos/sin: (..., S, hd/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # broadcast over heads
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1).astype(
        x.dtype
    )
