"""Mamba-2 SSD (state-space duality) mixer — chunked, MXU-friendly.

Per head: scalar decay ``a_t = exp(dt_t * A)`` (A < 0 learned), state
``h in R^{dh x N}``:

    h_t = a_t h_{t-1} + dt_t x_t B_t^T,      y_t = h_t C_t + D x_t

The chunked form (the same blocking as our RFF linear-attention kernel, plus
decays — this *is* the state-space duality) computes within a chunk

    M[t,s] = exp(L_t - L_s) (C_t . B_s) dt_s   (s <= t),  L = cumsum(log a)
    y_intra = M x,  y_inter[t] = exp(L_t) (C_t . h_prev)

entirely with GEMMs. Sequential dependency only across chunks (lax.scan).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense, dense_init

__all__ = ["mamba2_init", "mamba2_apply", "mamba2_decode", "Mamba2State"]


class Mamba2State(NamedTuple):
    h: jax.Array  # (B, H, dh, N) SSM state
    conv: jax.Array  # (B, conv_dim, W-1) depthwise-conv tail
    pos: jax.Array


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    conv_dim = d_inner + 2 * cfg.ssm_state
    return d_inner, nheads, conv_dim


def mamba2_init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    d_inner, nheads, conv_dim = _dims(cfg)
    n = cfg.ssm_state
    keys = jax.random.split(key, 5)
    # in_proj emits [z (gate), x, B, C, dt] concatenated.
    return {
        "w_in": dense_init(keys[0], d, 2 * d_inner + 2 * n + nheads, dtype=dtype),
        "conv_w": (
            jax.random.normal(keys[1], (conv_dim, cfg.conv_width)) * 0.1
        ).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(
            jnp.linspace(1.0, 16.0, nheads)
        ).astype(jnp.float32),  # A = -exp(a_log)
        "d_skip": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "w_out": dense_init(keys[2], d_inner, d, dtype=dtype),
    }


def _split_in(cfg, proj):
    d_inner, nheads, _ = _dims(cfg)
    n = cfg.ssm_state
    z, xbc_dt = jnp.split(proj, [d_inner], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_inner + 2 * n], axis=-1)
    return z, xbc, dt  # gate, conv-input, per-head dt


def _causal_conv(xbc, w, b, tail=None):
    """Depthwise causal conv over time. xbc: (B, S, C); w: (C, W)."""
    width = w.shape[1]
    if tail is None:
        pad = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = tail  # (B, W-1, C)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(
        xp[:, i : i + xbc.shape[1], :] * w[:, i] for i in range(width)
    )
    new_tail = xp[:, -(width - 1) :, :] if width > 1 else pad
    return jax.nn.silu(out + b), new_tail


def _ssd_chunked(x, b_in, c_in, dt, a_log, chunk):
    """Chunked SSD scan.

    x: (B, S, H, dh); b_in/c_in: (B, S, N); dt: (B, S, H) (softplus'd).
    Returns y (B, S, H, dh), final state (B, H, dh, N).
    """
    bsz, s, h, dh = x.shape
    n = b_in.shape[-1]
    c = min(chunk, s)
    assert s % c == 0, f"seq {s} % chunk {c} != 0"
    nc = s // c
    a = -jnp.exp(a_log)  # (H,) negative decay rates

    xc = x.reshape(bsz, nc, c, h, dh)
    bc = b_in.reshape(bsz, nc, c, n)
    cc = c_in.reshape(bsz, nc, c, n)
    dtc = dt.reshape(bsz, nc, c, h)

    def body(h_state, inp):
        xk, bk, ck, dtk = inp  # (B,c,H,dh), (B,c,N), (B,c,N), (B,c,H)
        loga = dtk * a  # (B,c,H) log per-step decay
        lcum = jnp.cumsum(loga, axis=1)  # L_t inclusive
        # M[t,s] = exp(L_t - L_s) * (C_t.B_s) * dt_s, s<=t
        ldiff = lcum[:, :, None, :] - lcum[:, None, :, :]  # (B,c,c,H)
        mask = jnp.tril(jnp.ones((c, c), bool))
        ldiff = jnp.where(mask[None, :, :, None], ldiff, -jnp.inf)
        cb = jnp.einsum("btn,bsn->bts", ck, bk)  # (B,c,c)
        m = jnp.exp(ldiff) * (cb[..., None] * dtk[:, None, :, :])
        y = jnp.einsum("btsh,bshd->bthd", m, xk)  # intra
        # inter-chunk: y += exp(L_t) C_t . h_prev
        decay_t = jnp.exp(lcum)  # (B,c,H)
        y = y + jnp.einsum(
            "bth,btn,bhdn->bthd", decay_t, ck, h_state
        )
        # state update: h = exp(L_c) h_prev + sum_s exp(L_c - L_s) dt_s x_s B_s^T
        total = lcum[:, -1:, :]  # (B,1,H)
        w_s = jnp.exp(total - lcum) * dtk  # (B,c,H)
        h_new = jnp.einsum("bsh,bshd,bsn->bhdn", w_s, xk, bk)
        h_state = h_state * jnp.exp(total[:, 0])[:, :, None, None] + h_new
        return h_state, y

    h0 = jnp.zeros((bsz, h, dh, n), jnp.float32)
    xs = (
        jnp.moveaxis(xc, 1, 0).astype(jnp.float32),
        jnp.moveaxis(bc, 1, 0).astype(jnp.float32),
        jnp.moveaxis(cc, 1, 0).astype(jnp.float32),
        jnp.moveaxis(dtc, 1, 0).astype(jnp.float32),
    )
    h_final, ys = jax.lax.scan(body, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, dh)
    return y, h_final


def mamba2_apply(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Full-sequence SSD block. x: (B, S, d)."""
    bsz, s, _ = x.shape
    d_inner, nheads, _ = _dims(cfg)
    n = cfg.ssm_state
    proj = dense(p["w_in"], x)
    z, xbc, dt = _split_in(cfg, proj)
    xbc, _ = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs, b_in, c_in = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    xh = xs.reshape(bsz, s, nheads, cfg.ssm_head_dim)
    dt_sp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    y, _ = _ssd_chunked(xh, b_in, c_in, dt_sp, p["a_log"], cfg.ssm_chunk)
    y = y + p["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, d_inner).astype(x.dtype)
    # gated RMS-ish norm (mamba2 uses RMSNorm(y * silu(z)))
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-5)).astype(x.dtype)
    y = y * p["norm_scale"]
    return dense(p["w_out"], y)


def mamba2_state_init(cfg: ModelConfig, batch: int) -> Mamba2State:
    d_inner, nheads, conv_dim = _dims(cfg)
    return Mamba2State(
        h=jnp.zeros((batch, nheads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_width - 1, conv_dim), jnp.float32),
        pos=jnp.zeros((), jnp.int32),
    )


def mamba2_decode(
    p: dict, cfg: ModelConfig, x: jax.Array, state: Mamba2State
) -> tuple[jax.Array, Mamba2State]:
    """One-token SSD decode: O(H dh N) state update. x: (B, 1, d)."""
    bsz = x.shape[0]
    d_inner, nheads, _ = _dims(cfg)
    n = cfg.ssm_state
    proj = dense(p["w_in"], x)
    z, xbc, dt = _split_in(cfg, proj)
    xbc, new_tail = _causal_conv(
        xbc, p["conv_w"], p["conv_b"], tail=state.conv.astype(xbc.dtype)
    )
    xs, b_in, c_in = jnp.split(xbc[:, 0], [d_inner, d_inner + n], axis=-1)
    xh = xs.reshape(bsz, nheads, cfg.ssm_head_dim).astype(jnp.float32)
    dt_sp = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt_sp * a)  # (B, H)
    b32 = b_in.astype(jnp.float32)
    h_new = state.h * decay[:, :, None, None] + jnp.einsum(
        "bh,bhd,bn->bhdn", dt_sp, xh, b32
    )
    y = jnp.einsum("bhdn,bn->bhd", h_new, c_in.astype(jnp.float32))
    y = y + p["d_skip"][None, :, None] * xh
    y = y.reshape(bsz, 1, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-5)).astype(x.dtype)
    y = y * p["norm_scale"]
    out = dense(p["w_out"], y)
    return out, Mamba2State(h=h_new, conv=new_tail.astype(jnp.float32), pos=state.pos + 1)
