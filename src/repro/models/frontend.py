"""Stub modality frontends (per assignment: backbone only, frontend = STUB).

For ``[vlm]`` (internvl2) and ``[audio]`` (musicgen) the transformer consumes
*precomputed* patch/frame embeddings. ``input_specs()`` in the launcher emits
``(B, S, d_model)`` embedding stand-ins; these helpers generate random but
shape-correct embeddings for smoke tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

__all__ = ["stub_embeddings"]


def stub_embeddings(
    key: jax.Array, cfg: ModelConfig, batch: int, seq: int
) -> jax.Array:
    """Random unit-scale embeddings standing in for ViT patches / EnCodec
    frames. (B, S, d_model)."""
    return jax.random.normal(key, (batch, seq, cfg.d_model), jnp.float32) * (
        cfg.d_model**-0.5
    )
