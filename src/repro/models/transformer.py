"""Unified decoder-only LM assembled from ModelConfig.

One model class covers all ten assigned architectures:
  * mixer = "attention": [dense | moe] transformers with gqa / mla / rff
    attention (internvl2, deepseek, arctic, command-r, minicpm3, llama3,
    qwen2, musicgen)
  * mixer = "mamba2": SSD blocks, no FFN (mamba2-130m)
  * mixer = "rglru_hybrid": (recurrent, recurrent, local-attn) pattern with
    MLPs (recurrentgemma)

Layer stacks are ``lax.scan``-ned over stacked params (compile time
independent of depth) with optional remat. Decode threads a per-layer state
stack through the same scan.
"""
from __future__ import annotations

import functools
from dataclasses import replace
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rff_attention as rff_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    dense,
    dense_init,
    embed_init,
    glu_mlp,
    glu_mlp_init,
    rmsnorm,
    rmsnorm_init,
)

__all__ = [
    "init_params",
    "forward",
    "lm_loss",
    "decode_state_init",
    "decode_step",
    "with_rff_attention",
]


def with_rff_attention(cfg: ModelConfig) -> ModelConfig:
    """Switch a full-attention config to RFF linear attention (the paper's
    fixed-size-state technique) — used for the long_500k cells."""
    return replace(cfg, attention="rff")


# ---------------------------------------------------------------------------
# Block init / apply (full sequence)
# ---------------------------------------------------------------------------


def _attn_block_init(key, cfg: ModelConfig, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"ln1": rmsnorm_init(cfg.d_model, dtype)}
    if cfg.attention == "mla":
        p["attn"] = attn_mod.mla_init(k1, cfg, dtype)
    elif cfg.attention == "rff":
        p["attn"] = rff_mod.rff_attn_init(k1, cfg, dtype)
    else:
        p["attn"] = attn_mod.gqa_init(k1, cfg, dtype)
    p["ln2"] = rmsnorm_init(cfg.d_model, dtype)
    if cfg.moe is not None:
        p["ffn"] = moe_mod.moe_init(k2, cfg, dtype)
    else:
        p["ffn"] = glu_mlp_init(k3, cfg.d_model, cfg.d_ff, dtype)
    return p


def _attn_block_apply(p, cfg: ModelConfig, x, window: int = 0):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.attention == "mla":
        a = attn_mod.mla_apply(p["attn"], cfg, h)
    elif cfg.attention == "rff":
        a = rff_mod.rff_attn_apply(p["attn"], cfg, h)
    else:
        a = attn_mod.gqa_apply(p["attn"], cfg, h, window=window)
    x = x + a
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.moe is not None:
        f = moe_mod.moe_apply(p["ffn"], cfg, h)
    else:
        f = glu_mlp(p["ffn"], h)
    return x + f


def _mamba_block_init(key, cfg: ModelConfig, dtype):
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "mixer": ssm_mod.mamba2_init(key, cfg, dtype),
    }


def _mamba_block_apply(p, cfg: ModelConfig, x):
    return x + ssm_mod.mamba2_apply(p["mixer"], cfg, rmsnorm(p["ln1"], x, cfg.norm_eps))


def _rec_block_init(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "temporal": rglru_mod.rglru_init(k1, cfg, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "mlp": glu_mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _rec_block_apply(p, cfg: ModelConfig, x):
    x = x + rglru_mod.rglru_apply(p["temporal"], cfg, rmsnorm(p["ln1"], x, cfg.norm_eps))
    return x + glu_mlp(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps))


def _local_attn_block_init(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_mod.gqa_init(k1, cfg, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "mlp": glu_mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _local_attn_block_apply(p, cfg: ModelConfig, x):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    x = x + attn_mod.gqa_apply(p["attn"], cfg, h, window=cfg.local_window)
    return x + glu_mlp(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps))


def _hybrid_group_init(key, cfg: ModelConfig, dtype):
    """(recurrent, recurrent, local-attention) super-block."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "rec1": _rec_block_init(k1, cfg, dtype),
        "rec2": _rec_block_init(k2, cfg, dtype),
        "attn": _local_attn_block_init(k3, cfg, dtype),
    }


def _hybrid_group_apply(p, cfg: ModelConfig, x):
    x = _rec_block_apply(p["rec1"], cfg, x)
    x = _rec_block_apply(p["rec2"], cfg, x)
    return _local_attn_block_apply(p["attn"], cfg, x)


def _layer_init_fn(cfg: ModelConfig):
    if cfg.mixer == "mamba2":
        return _mamba_block_init
    if cfg.mixer == "rglru_hybrid":
        return _hybrid_group_init
    return _attn_block_init


def _layer_apply_fn(cfg: ModelConfig):
    if cfg.mixer == "mamba2":
        return _mamba_block_apply
    if cfg.mixer == "rglru_hybrid":
        return _hybrid_group_apply
    return _attn_block_apply


def _num_scan_layers(cfg: ModelConfig) -> tuple[int, int]:
    """(scanned stack length, unrolled remainder) — hybrid groups by 3."""
    if cfg.mixer == "rglru_hybrid":
        return cfg.num_layers // 3, cfg.num_layers % 3
    return cfg.num_layers, 0


# ---------------------------------------------------------------------------
# Model init / forward
# ---------------------------------------------------------------------------


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    dtype = cfg.activation_dtype
    k_embed, k_layers, k_extra, k_head = jax.random.split(key, 4)
    n_scan, n_extra = _num_scan_layers(cfg)
    layer_init = _layer_init_fn(cfg)

    params: dict[str, Any] = {
        "embed": embed_init(k_embed, cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    layer_keys = jax.random.split(k_layers, max(n_scan, 1))
    if cfg.scan_layers:
        params["blocks"] = jax.vmap(lambda k: layer_init(k, cfg, dtype))(layer_keys)
    else:
        params["blocks_list"] = [
            layer_init(layer_keys[i], cfg, dtype) for i in range(n_scan)
        ]
    if n_extra:  # hybrid remainder: recurrent blocks
        extra_keys = jax.random.split(k_extra, n_extra)
        params["extra"] = [
            _rec_block_init(extra_keys[i], cfg, dtype) for i in range(n_extra)
        ]
    if not cfg.tie_embeddings:
        params["head"] = dense_init(k_head, cfg.d_model, cfg.padded_vocab, dtype=dtype)
    return params


def _mask_vocab(cfg: ModelConfig, logits: jax.Array) -> jax.Array:
    """-inf the inert padded vocab slots (exactly the unpadded function)."""
    vp = cfg.padded_vocab
    if vp == cfg.vocab_size:
        return logits
    valid = jnp.arange(vp) < cfg.vocab_size
    return jnp.where(valid, logits, jnp.asarray(-1e30, logits.dtype))


def _constrain_batch(cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Pin the activation batch sharding through the layer stack (see
    ModelConfig.activation_batch_axes)."""
    if not cfg.activation_batch_axes:
        return x
    from jax.sharding import PartitionSpec as P

    spec = P(tuple(cfg.activation_batch_axes), *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def _apply_stack(params, cfg: ModelConfig, x):
    apply_fn = _layer_apply_fn(cfg)
    block = functools.partial(apply_fn, cfg=cfg)
    if cfg.remat:
        block = jax.checkpoint(
            lambda p, h: _constrain_batch(cfg, apply_fn(p, cfg, h)),
            prevent_cse=False,
        )
    else:
        block = lambda p, h: _constrain_batch(cfg, apply_fn(p, cfg, h))  # noqa: E731

    x = _constrain_batch(cfg, x)
    if cfg.scan_layers:
        def body(h, layer_p):
            return block(layer_p, h), None

        x, _ = jax.lax.scan(body, x, params["blocks"])
    else:
        for layer_p in params["blocks_list"]:
            x = block(layer_p, x)
    for extra_p in params.get("extra", []):
        x = _rec_block_apply(extra_p, cfg, x)
    return x


def forward(
    params: dict, cfg: ModelConfig, tokens: jax.Array | None = None,
    embeds: jax.Array | None = None,
) -> jax.Array:
    """Full-sequence forward. tokens: (B, S) int32 — or, for frontend archs,
    embeds: (B, S, d) precomputed patch/frame embeddings (stub frontend).

    Returns logits (B, S, V).
    """
    if embeds is None:
        x = jnp.take(params["embed"]["table"], tokens, axis=0)
    else:
        x = embeds.astype(cfg.activation_dtype)
    x = _apply_stack(params, cfg, x)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T
    else:
        logits = dense(params["head"], x)
    return _mask_vocab(cfg, logits)


def lm_loss(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array | None = None,
    embeds: jax.Array | None = None,
    labels: jax.Array | None = None,
) -> jax.Array:
    """Next-token cross entropy (f32 logsumexp), mean over tokens.

    ``cfg.loss_vocab_chunks > 1`` streams the logsumexp over vocab chunks
    (running-max/denominator, the flash-softmax trick over V) so the f32
    logits tensor is never materialized at full vocab width — cuts the
    training-loss memory peak for 100k+ vocabs.
    """
    logits = forward(params, cfg, tokens=tokens, embeds=embeds)
    if labels is None:
        labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
        mask = jnp.ones_like(labels).at[:, -1].set(0)
    else:
        mask = (labels >= 0).astype(jnp.int32)
        labels = jnp.maximum(labels, 0)

    nc = max(int(cfg.loss_vocab_chunks), 1)
    vp = logits.shape[-1]
    if nc > 1 and vp % nc == 0:
        vc = vp // nc
        lgc = jnp.moveaxis(
            logits.reshape(logits.shape[:-1] + (nc, vc)), -2, 0
        )  # (nc, B, S, vc)

        def body(carry, inp):
            m, s, gold = carry
            chunk, idx = inp
            c32 = chunk.astype(jnp.float32)
            m_new = jnp.maximum(m, jnp.max(c32, axis=-1))
            s = s * jnp.exp(m - m_new) + jnp.sum(
                jnp.exp(c32 - m_new[..., None]), axis=-1
            )
            local = labels - idx * vc
            hit = (local >= 0) & (local < vc)
            g = jnp.take_along_axis(
                c32, jnp.clip(local, 0, vc - 1)[..., None], axis=-1
            )[..., 0]
            gold = jnp.where(hit, g, gold)
            return (m_new, s, gold), None

        init = (
            jnp.full(labels.shape, -1e30, jnp.float32),
            jnp.zeros(labels.shape, jnp.float32),
            jnp.zeros(labels.shape, jnp.float32),
        )
        (m, s, gold), _ = jax.lax.scan(body, init, (lgc, jnp.arange(nc)))
        lse = m + jnp.log(s)
    else:
        lg = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------


def _block_state_init(cfg: ModelConfig, batch: int, max_len: int):
    dtype = cfg.activation_dtype
    if cfg.mixer == "mamba2":
        return ssm_mod.mamba2_state_init(cfg, batch)
    if cfg.mixer == "rglru_hybrid":
        dh = cfg.resolved_head_dim
        win = min(cfg.local_window, max_len)
        return {
            "rec1": rglru_mod.rglru_state_init(cfg, batch),
            "rec2": rglru_mod.rglru_state_init(cfg, batch),
            "attn": attn_mod.KVCache(
                k=jnp.zeros((batch, win, cfg.num_kv_heads, dh), dtype),
                v=jnp.zeros((batch, win, cfg.num_kv_heads, dh), dtype),
                pos=jnp.zeros((), jnp.int32),
            ),
        }
    if cfg.attention == "rff":
        return rff_mod.rff_state_init(cfg, batch)
    if cfg.attention == "mla":
        m = cfg.mla
        return attn_mod.MLACache(
            c_kv=jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            k_rope=jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
            pos=jnp.zeros((), jnp.int32),
        )
    dh = cfg.resolved_head_dim
    return attn_mod.KVCache(
        k=jnp.zeros((batch, max_len, cfg.num_kv_heads, dh), dtype),
        v=jnp.zeros((batch, max_len, cfg.num_kv_heads, dh), dtype),
        pos=jnp.zeros((), jnp.int32),
    )


def decode_state_init(cfg: ModelConfig, batch: int, max_len: int):
    """Per-layer decode state, stacked along the layer axis when scanning."""
    n_scan, n_extra = _num_scan_layers(cfg)
    one = _block_state_init(cfg, batch, max_len)
    if cfg.scan_layers:
        stack = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_scan,) + a.shape), one
        )
    else:
        stack = [_block_state_init(cfg, batch, max_len) for _ in range(n_scan)]
    extras = [rglru_mod.rglru_state_init(cfg, batch) for _ in range(n_extra)]
    return {"stack": stack, "extra": extras}


def _block_decode(p, cfg: ModelConfig, x, state):
    if cfg.mixer == "mamba2":
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        out, new_state = ssm_mod.mamba2_decode(p["mixer"], cfg, h, state)
        return x + out, new_state
    if cfg.mixer == "rglru_hybrid":
        # rec1
        h = rmsnorm(p["rec1"]["ln1"], x, cfg.norm_eps)
        out, s1 = rglru_mod.rglru_decode(p["rec1"]["temporal"], cfg, h, state["rec1"])
        x = x + out
        x = x + glu_mlp(p["rec1"]["mlp"], rmsnorm(p["rec1"]["ln2"], x, cfg.norm_eps))
        # rec2
        h = rmsnorm(p["rec2"]["ln1"], x, cfg.norm_eps)
        out, s2 = rglru_mod.rglru_decode(p["rec2"]["temporal"], cfg, h, state["rec2"])
        x = x + out
        x = x + glu_mlp(p["rec2"]["mlp"], rmsnorm(p["rec2"]["ln2"], x, cfg.norm_eps))
        # local attention (ring-buffer KV cache of window size)
        h = rmsnorm(p["attn"]["ln1"], x, cfg.norm_eps)
        out, s3 = _ring_gqa_decode(p["attn"]["attn"], cfg, h, state["attn"])
        x = x + out
        x = x + glu_mlp(p["attn"]["mlp"], rmsnorm(p["attn"]["ln2"], x, cfg.norm_eps))
        return x, {"rec1": s1, "rec2": s2, "attn": s3}
    # attention families
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.attention == "rff":
        out, new_state = rff_mod.rff_attn_decode(p["attn"], cfg, h, state)
    elif cfg.attention == "mla":
        out, new_state = attn_mod.mla_decode(p["attn"], cfg, h, state)
    else:
        out, new_state = attn_mod.gqa_decode(p["attn"], cfg, h, state)
    x = x + out
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.moe is not None:
        f = moe_mod.moe_apply(p["ffn"], cfg, h)
    else:
        f = glu_mlp(p["ffn"], h)
    return x + f, new_state


def _ring_gqa_decode(p, cfg: ModelConfig, x, cache: attn_mod.KVCache):
    """Sliding-window decode with a ring-buffer cache (bounded memory).

    Ring semantics make *positional* masking incorrect after wrap-around, but
    every resident entry is by construction within the window, so attention
    over all valid slots is exactly sliding-window attention.
    """
    win = cache.k.shape[1]
    b = x.shape[0]
    positions = cache.pos[None, None] + jnp.zeros((b, 1), jnp.int32)
    q, k_new, v_new = attn_mod._project_qkv(p, cfg, x, positions)
    slot = jnp.mod(cache.pos, win)
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, slot, 1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, slot, 1)
    kv_len = jnp.minimum(cache.pos + 1, win)
    out = attn_mod.dense_attention(
        q, k_cache, v_cache, causal=False, kv_len=kv_len
    )
    return (
        attn_mod.head_out(p["wo"], out),
        attn_mod.KVCache(k=k_cache, v=v_cache, pos=cache.pos + 1),
    )


def decode_step(
    params: dict, cfg: ModelConfig, state: dict, token: jax.Array,
    embed_in: jax.Array | None = None,
) -> tuple[jax.Array, dict]:
    """One serving step: next-token logits + updated state.

    token: (B,) int32 (or embed_in (B, 1, d) for frontend archs).
    """
    if embed_in is None:
        x = jnp.take(params["embed"]["table"], token[:, None], axis=0)
    else:
        x = embed_in.astype(cfg.activation_dtype)

    if cfg.scan_layers:
        def body(h, inp):
            layer_p, layer_s = inp
            h2, new_s = _block_decode(layer_p, cfg, h, layer_s)
            return h2, new_s

        x, new_stack = jax.lax.scan(body, x, (params["blocks"], state["stack"]))
    else:
        new_stack = []
        for layer_p, layer_s in zip(params["blocks_list"], state["stack"]):
            x, s = _block_decode(layer_p, cfg, x, layer_s)
            new_stack.append(s)

    new_extras = []
    for extra_p, extra_s in zip(params.get("extra", []), state["extra"]):
        h = rmsnorm(extra_p["ln1"], x, cfg.norm_eps)
        out, s = rglru_mod.rglru_decode(extra_p["temporal"], cfg, h, extra_s)
        x = x + out
        x = x + glu_mlp(extra_p["mlp"], rmsnorm(extra_p["ln2"], x, cfg.norm_eps))
        new_extras.append(s)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T
    else:
        logits = dense(params["head"], x)
    return _mask_vocab(cfg, logits)[:, 0], {"stack": new_stack, "extra": new_extras}
