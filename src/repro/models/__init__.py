"""LM substrate: unified decoder covering all assigned architecture families."""
from repro.models import attention, layers, moe, rff_attention, rglru, ssm
from repro.models.transformer import (
    decode_state_init,
    decode_step,
    forward,
    init_params,
    lm_loss,
    with_rff_attention,
)

__all__ = [
    "attention",
    "layers",
    "moe",
    "rff_attention",
    "rglru",
    "ssm",
    "decode_state_init",
    "decode_step",
    "forward",
    "init_params",
    "lm_loss",
    "with_rff_attention",
]
