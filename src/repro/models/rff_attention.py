"""RFF linear attention — the paper's technique as a first-class layer.

Softmax attention is a kernel machine whose dictionary (the KV cache) grows
with context length; following the paper, we replace the kernel trick with an
explicit random-feature map and obtain a *fixed-size* state per head:

    S_t = sum_{s<=t} phi(k_s) v_s^T   (D x dv)      "theta of the layer"
    z_t = sum_{s<=t} phi(k_s)         (D,)

Full-sequence form runs through the chunked Pallas kernel
(`repro.kernels.rff_attention`); decode is an O(D dv) state update — O(1) in
context length, which is what makes the 524k-token decode cell lowerable.

Feature maps: "prf" (positive random features, unbiased softmax-kernel
estimator — default) or "trig" (the paper's cos features, Gaussian-kernel).
The random projections are *non-trainable* buffers derived from a fixed seed,
exactly like the paper's Omega.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.rff import RFF, positive_random_features, sample_prf
from repro.kernels import ops
from repro.models.layers import apply_rope, rope_freqs

__all__ = [
    "rff_attn_init",
    "rff_attn_apply",
    "rff_attn_decode",
    "RFFState",
    "rff_state_init",
]


class RFFState(NamedTuple):
    s: jax.Array  # (B, H, D, dv) running sum phi(k) v^T
    z: jax.Array  # (B, H, D) running sum phi(k)
    pos: jax.Array  # () int32


def rff_attn_init(
    key: jax.Array, cfg: ModelConfig, dtype=jnp.float32
) -> dict:
    """Projections + fixed random features (per-layer Omega buffer)."""
    d, h = cfg.d_model, cfg.padded_heads
    dh = cfg.resolved_head_dim
    kq, kk, kv, ko, kf = jax.random.split(key, 5)
    feat = sample_prf(kf, dh, cfg.rff_num_features, dtype=jnp.float32)
    from repro.models.attention import head_out_init, head_proj_init

    return {
        "wq": head_proj_init(kq, d, h, dh, dtype=dtype),
        "wk": head_proj_init(kk, d, h, dh, dtype=dtype),
        "wv": head_proj_init(kv, d, h, dh, dtype=dtype),
        "wo": head_out_init(ko, h, dh, d, dtype=dtype),
        # non-trainable buffers (stop_gradient applied at use sites)
        "omega": feat.omega,
        "bias": feat.bias,
    }


def _feature(p: dict, x: jax.Array, kind: str) -> jax.Array:
    rff = RFF(
        omega=jax.lax.stop_gradient(p["omega"]).astype(jnp.float32),
        bias=jax.lax.stop_gradient(p["bias"]).astype(jnp.float32),
    )
    x32 = x.astype(jnp.float32)
    if kind == "trig":
        return rff_features(rff, x32)
    return positive_random_features(rff, x32)


def _project(p, cfg: ModelConfig, x, positions):
    from repro.models.attention import head_proj

    dh = cfg.resolved_head_dim
    q = head_proj(p["wq"], x)  # (B, S, H, dh)
    k = head_proj(p["wk"], x)
    v = head_proj(p["wv"], x)
    cos, sin = rope_freqs(positions, dh, cfg.rope_theta)
    # RoPE before the feature map: kernel of the rotated vectors — relative-
    # position-aware kernel attention.
    return apply_rope(q, cos, sin), apply_rope(k, cos, sin), v


def rff_attn_apply(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    feature_kind: str = "prf",
    kernel_mode: str = "auto",
) -> jax.Array:
    """Full-sequence causal RFF linear attention. x: (B, S, d)."""
    b, s, _ = x.shape
    h, dh = cfg.padded_heads, cfg.resolved_head_dim
    positions = jnp.arange(s)[None, :]
    q, k, v = _project(p, cfg, x, positions)
    scale = dh**-0.25  # split the 1/sqrt(dh) between q and k (exp kernel)
    phi_q = _feature(p, q * scale, feature_kind)  # (B, S, H, D)
    phi_k = _feature(p, k * scale, feature_kind)
    dfeat = phi_q.shape[-1]
    # (BH, S, ...) layout for the kernel
    pq = phi_q.transpose(0, 2, 1, 3).reshape(b * h, s, dfeat)
    pk = phi_k.transpose(0, 2, 1, 3).reshape(b * h, s, dfeat)
    vv = v.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    out = ops.rff_attention(
        pq.astype(jnp.float32),
        pk.astype(jnp.float32),
        vv.astype(jnp.float32),
        mode=kernel_mode,
        chunk=min(cfg.rff_chunk, s),
        normalize=feature_kind == "prf",
    )
    out = out.reshape(b, h, s, dh).transpose(0, 2, 1, 3)  # (B, S, H, dh)
    from repro.models.attention import apply_head_mask, head_mask, head_out

    out = apply_head_mask(out, head_mask(cfg))
    return head_out(p["wo"], out.astype(x.dtype))


def rff_state_init(
    cfg: ModelConfig, batch: int, dtype=jnp.float32
) -> RFFState:
    h, dh, dfeat = cfg.padded_heads, cfg.resolved_head_dim, cfg.rff_num_features
    return RFFState(
        s=jnp.zeros((batch, h, dfeat, dh), dtype),
        z=jnp.zeros((batch, h, dfeat), dtype),
        pos=jnp.zeros((), jnp.int32),
    )


def rff_attn_decode(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,
    state: RFFState,
    *,
    feature_kind: str = "prf",
) -> tuple[jax.Array, RFFState]:
    """One-token decode from the fixed-size state. x: (B, 1, d).

    Cost O(H · D · dv) per token — independent of how many tokens came
    before. This is the LLM-serving analogue of RFFKLMS's fixed theta.
    """
    b = x.shape[0]
    h, dh = cfg.padded_heads, cfg.resolved_head_dim
    positions = state.pos[None, None] + jnp.zeros((b, 1), jnp.int32)
    q, k, v = _project(p, cfg, x, positions)
    scale = dh**-0.25
    phi_q = _feature(p, q * scale, feature_kind)[:, 0]  # (B, H, D)
    phi_k = _feature(p, k * scale, feature_kind)[:, 0]
    vv = v[:, 0].astype(jnp.float32)  # (B, H, dh)

    dfeat = phi_q.shape[-1]
    pq = phi_q.reshape(b * h, dfeat)
    pk = phi_k.reshape(b * h, dfeat)
    vflat = vv.reshape(b * h, dh)
    s_flat = state.s.astype(jnp.float32).reshape(b * h, dfeat, dh)
    z_flat = state.z.astype(jnp.float32).reshape(b * h, dfeat)
    out, s_new, z_new = ops.rff_attention_decode(s_flat, z_flat, pq, pk, vflat)
    new_state = RFFState(
        s=s_new.reshape(b, h, dfeat, dh).astype(state.s.dtype),
        z=z_new.reshape(b, h, dfeat).astype(state.z.dtype),
        pos=state.pos + 1,
    )
    out = out.reshape(b, 1, h, dh).astype(x.dtype)
    from repro.models.attention import apply_head_mask, head_mask, head_out

    out = apply_head_mask(out, head_mask(cfg))
    return head_out(p["wo"], out), new_state
