"""RFF linear attention — the paper's technique as a first-class layer.

Softmax attention is a kernel machine whose dictionary (the KV cache) grows
with context length; following the paper, we replace the kernel trick with an
explicit random-feature map and obtain a *fixed-size* state per head:

    S_t = sum_{s<=t} phi(k_s) v_s^T   (D x dv)      "theta of the layer"
    z_t = sum_{s<=t} phi(k_s)         (D,)

Full-sequence form runs through the chunked Pallas kernel
(`repro.kernels.rff_attention`); decode is an O(D dv) state update — O(1) in
context length, which is what makes the 524k-token decode cell lowerable.

Feature maps: "prf" (positive random features, unbiased softmax-kernel
estimator — default) or "trig" (affine-trig Gaussian-kernel features,
``scale * cos(x @ omega + bias)``). The trig path stores the canonical
:class:`repro.features.TrigFeatures` triple, so ``rff_attn_init`` accepts any
``as_trig``-canonicalizable family (rff / orf / qmc / gq) via ``feature_map=``
— the deterministic families hit the iid-RFF floor at 2-8x smaller D
(BENCH_features.json) and that saving now applies to attention state too.
The projections are *non-trainable* buffers derived from a fixed seed,
exactly like the paper's Omega.

Decode comes in two grains: ``rff_attn_decode_block`` feeds a (B, T, d) block
of tokens to the fused Pallas decode kernel (state resident in VMEM across
all T in-kernel ticks — one launch and one state read/write per block), and
``rff_attn_decode`` is its T=1 case.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.rff import RFF, positive_random_features, sample_prf
from repro.features import TrigFeatures, as_trig, trig_features, uniform_trig_scale
from repro.kernels import ops
from repro.models.attention import (
    apply_head_mask,
    head_mask,
    head_out,
    head_out_init,
    head_proj,
    head_proj_init,
)
from repro.models.layers import apply_rope, rope_freqs

__all__ = [
    "rff_attn_init",
    "rff_attn_apply",
    "rff_attn_decode",
    "rff_attn_decode_block",
    "RFFState",
    "rff_state_init",
]


class RFFState(NamedTuple):
    s: jax.Array  # (B, H, D, dv) running sum phi(k) v^T
    z: jax.Array  # (B, H, D) running sum phi(k)
    pos: jax.Array  # () int32


def rff_attn_init(
    key: jax.Array,
    cfg: ModelConfig,
    dtype=jnp.float32,
    feature_map=None,
) -> dict:
    """Projections + fixed feature buffers (per-layer Omega).

    ``feature_map``: any ``as_trig``-canonicalizable family (a
    :class:`repro.features.FeatureMap`, :class:`TrigFeatures` or ``RFF``)
    replaces the default Monte-Carlo draw — this is how qmc/gq run the
    attention path at their smaller D. It must match ``cfg``'s head dim and
    ``rff_num_features``; the prf path reads only ``omega`` (Gaussian rows),
    so deterministic trig families pair with ``feature_kind="trig"``.
    """
    d, h = cfg.d_model, cfg.padded_heads
    dh = cfg.resolved_head_dim
    dfeat = cfg.rff_num_features
    kq, kk, kv, ko, kf = jax.random.split(key, 5)
    if feature_map is None:
        feat = sample_prf(kf, dh, dfeat, dtype=jnp.float32)
        omega, bias = feat.omega, feat.bias
        scale = uniform_trig_scale(dfeat, jnp.float32)
    else:
        tf = as_trig(feature_map)
        if tf.input_dim != dh or tf.num_features != dfeat:
            raise ValueError(
                f"feature_map is ({tf.input_dim}, {tf.num_features}); "
                f"cfg wants head_dim={dh}, rff_num_features={dfeat}"
            )
        omega = tf.omega.astype(jnp.float32)
        bias = tf.bias.astype(jnp.float32)
        scale = tf.scale.astype(jnp.float32)
    return {
        "wq": head_proj_init(kq, d, h, dh, dtype=dtype),
        "wk": head_proj_init(kk, d, h, dh, dtype=dtype),
        "wv": head_proj_init(kv, d, h, dh, dtype=dtype),
        "wo": head_out_init(ko, h, dh, d, dtype=dtype),
        # non-trainable buffers (stop_gradient applied at use sites)
        "omega": omega,
        "bias": bias,
        "scale": scale,
    }


def _trig_buffers(p: dict) -> TrigFeatures:
    return TrigFeatures(
        omega=jax.lax.stop_gradient(p["omega"]).astype(jnp.float32),
        bias=jax.lax.stop_gradient(p["bias"]).astype(jnp.float32),
        scale=jax.lax.stop_gradient(
            p.get("scale", uniform_trig_scale(p["omega"].shape[1]))
        ).astype(jnp.float32),
    )


def _feature(p: dict, x: jax.Array, kind: str) -> jax.Array:
    tf = _trig_buffers(p)
    x32 = x.astype(jnp.float32)
    if kind == "trig":
        return trig_features(tf, x32)
    return positive_random_features(RFF(omega=tf.omega, bias=tf.bias), x32)


def _project(p, cfg: ModelConfig, x, positions):
    dh = cfg.resolved_head_dim
    q = head_proj(p["wq"], x)  # (B, S, H, dh)
    k = head_proj(p["wk"], x)
    v = head_proj(p["wv"], x)
    cos, sin = rope_freqs(positions, dh, cfg.rope_theta)
    # RoPE before the feature map: kernel of the rotated vectors — relative-
    # position-aware kernel attention.
    return apply_rope(q, cos, sin), apply_rope(k, cos, sin), v


def rff_attn_apply(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    feature_kind: str = "prf",
    kernel_mode: str = "auto",
) -> jax.Array:
    """Full-sequence causal RFF linear attention. x: (B, S, d)."""
    b, s, _ = x.shape
    h, dh = cfg.padded_heads, cfg.resolved_head_dim
    positions = jnp.arange(s)[None, :]
    q, k, v = _project(p, cfg, x, positions)
    scale = dh**-0.25  # split the 1/sqrt(dh) between q and k (exp kernel)
    phi_q = _feature(p, q * scale, feature_kind)  # (B, S, H, D)
    phi_k = _feature(p, k * scale, feature_kind)
    dfeat = phi_q.shape[-1]
    # (BH, S, ...) layout for the kernel
    pq = phi_q.transpose(0, 2, 1, 3).reshape(b * h, s, dfeat)
    pk = phi_k.transpose(0, 2, 1, 3).reshape(b * h, s, dfeat)
    vv = v.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    out = ops.rff_attention(
        pq.astype(jnp.float32),
        pk.astype(jnp.float32),
        vv.astype(jnp.float32),
        mode=kernel_mode,
        chunk=min(cfg.rff_chunk, s),
        normalize=feature_kind == "prf",
    )
    out = out.reshape(b, h, s, dh).transpose(0, 2, 1, 3)  # (B, S, H, dh)
    out = apply_head_mask(out, head_mask(cfg))
    return head_out(p["wo"], out.astype(x.dtype))


def rff_state_init(
    cfg: ModelConfig, batch: int, dtype=jnp.float32
) -> RFFState:
    h, dh, dfeat = cfg.padded_heads, cfg.resolved_head_dim, cfg.rff_num_features
    return RFFState(
        s=jnp.zeros((batch, h, dfeat, dh), dtype),
        z=jnp.zeros((batch, h, dfeat), dtype),
        pos=jnp.zeros((), jnp.int32),
    )


def rff_attn_decode_block(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,
    state: RFFState,
    *,
    feature_kind: str = "prf",
    kernel_mode: str = "auto",
    block_t: Optional[int] = None,
    precision: Optional[str] = None,
) -> tuple[jax.Array, RFFState]:
    """Decode a (B, T, d) block of tokens from the fixed-size state.

    The block rides the fused decode kernel: featurization is one GEMM and
    the per-head (D, dv) S tile + (D,) z row stay VMEM-resident across all T
    sequential in-kernel ticks — T decode steps cost one launch and one
    state read/write instead of T. ``precision="bf16"`` runs the feature /
    numerator GEMMs under the read-path contract (bf16 operands, f32
    accumulation, f32 state). Cost per token is O(H D dv) regardless of how
    many tokens came before — the LLM-serving analogue of RFFKLMS's fixed
    theta.
    """
    b, t = x.shape[0], x.shape[1]
    h, dh = cfg.padded_heads, cfg.resolved_head_dim
    positions = jnp.full((b, t), state.pos, jnp.int32) + jnp.arange(
        t, dtype=jnp.int32
    )[None, :]
    q, k, v = _project(p, cfg, x, positions)
    scale = dh**-0.25
    tf = _trig_buffers(p)
    # (BH, T, ...) layout; tokens enter RAW — the kernel owns featurization.
    qq = (q * scale).astype(jnp.float32).transpose(0, 2, 1, 3).reshape(b * h, t, dh)
    kk = (k * scale).astype(jnp.float32).transpose(0, 2, 1, 3).reshape(b * h, t, dh)
    vv = v.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(b * h, t, dh)
    dfeat = tf.num_features
    s_flat = state.s.astype(jnp.float32).reshape(b * h, dfeat, dh)
    z_flat = state.z.astype(jnp.float32).reshape(b * h, dfeat)
    out, s_new, z_new = ops.rff_attention_decode_block(
        s_flat,
        z_flat,
        qq,
        kk,
        vv,
        tf.omega,
        tf.bias,
        tf.scale if feature_kind == "trig" else None,
        feature_kind=feature_kind,
        mode=kernel_mode,
        block_t=block_t,
        normalize=feature_kind == "prf",
        precision=precision,
    )
    new_state = RFFState(
        s=s_new.reshape(b, h, dfeat, dh).astype(state.s.dtype),
        z=z_new.reshape(b, h, dfeat).astype(state.z.dtype),
        pos=state.pos + t,
    )
    out = out.reshape(b, h, t, dh).transpose(0, 2, 1, 3).astype(x.dtype)
    out = apply_head_mask(out, head_mask(cfg))
    return head_out(p["wo"], out), new_state


def rff_attn_decode(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,
    state: RFFState,
    *,
    feature_kind: str = "prf",
    kernel_mode: str = "auto",
    precision: Optional[str] = None,
) -> tuple[jax.Array, RFFState]:
    """One-token decode from the fixed-size state — the T=1 block case.

    x: (B, 1, d)."""
    return rff_attn_decode_block(
        p,
        cfg,
        x,
        state,
        feature_kind=feature_kind,
        kernel_mode=kernel_mode,
        precision=precision,
    )
