"""Mixture-of-Experts FFN — GShard-style capacity-based einsum dispatch.

Fully jit/GSPMD-compatible (no ragged ops): experts are a stacked weight
tensor sharded over the ``model`` axis (expert parallelism); the dispatch and
combine einsums induce the all-to-all traffic that shows up in the roofline's
collective term.

Supports DeepSeek-style shared experts (always-on) and Arctic-style parallel
dense residual FFN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import dense_init, glu_mlp, glu_mlp_init

__all__ = ["moe_init", "moe_apply"]


def _expert_stack_init(key, n: int, d: int, dff: int, dtype) -> dict:
    """Stacked gated-MLP experts: (E, d, ff) x2 and (E, ff, d)."""
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d**-0.5
    s_out = dff**-0.5
    return {
        "wi": (s_in * jax.random.normal(k1, (n, d, dff))).astype(dtype),
        "wg": (s_in * jax.random.normal(k2, (n, d, dff))).astype(dtype),
        "wo": (s_out * jax.random.normal(k3, (n, dff, d))).astype(dtype),
    }


def moe_init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    m: MoEConfig = cfg.moe
    d = cfg.d_model
    kr, ke, ks, kd = jax.random.split(key, 4)
    p = {
        "router": dense_init(kr, d, m.num_experts, dtype=jnp.float32),
        "experts": _expert_stack_init(ke, m.num_experts, d, m.d_ff_expert, dtype),
    }
    if m.num_shared:
        p["shared"] = glu_mlp_init(ks, d, m.num_shared * m.d_ff_expert, dtype)
    if m.dense_residual_ff:
        p["dense_residual"] = glu_mlp_init(kd, d, m.dense_residual_ff, dtype)
    return p


def _dispatch_combine(gates: jax.Array, top_k: int, capacity: int):
    """Top-k capacity assignment.

    Args:
      gates: (B, S, E) softmax router probabilities.

    Returns:
      dispatch (B, S, E, C) one-hot-ish bool->dtype, combine (B, S, E, C)
      gate-weighted. Built k-slice at a time to avoid a (B,S,k,E,C) blow-up.
    """
    b, s, e = gates.shape
    topv, topi = jax.lax.top_k(gates, top_k)  # (B, S, k)
    # Normalize the k selected gates (standard for k>1 routers).
    topv = topv / jnp.maximum(jnp.sum(topv, -1, keepdims=True), 1e-9)

    dispatch = jnp.zeros((b, s, e, capacity), gates.dtype)
    combine = jnp.zeros((b, s, e, capacity), gates.dtype)
    # Running per-expert fill count, accumulated across k slices so slot
    # assignment is collision-free.
    fill = jnp.zeros((b, e), jnp.int32)
    for j in range(top_k):
        idx = topi[:, :, j]  # (B, S)
        gate = topv[:, :, j]  # (B, S)
        onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)  # (B, S, E)
        # position of each token within its expert queue (token order)
        prior = jnp.cumsum(onehot, axis=1) - onehot + fill[:, None, :]
        pos = jnp.sum(prior * onehot, axis=-1)  # (B, S)
        keep = pos < capacity
        slot = jax.nn.one_hot(jnp.where(keep, pos, capacity), capacity,
                              dtype=gates.dtype)[..., :capacity]
        d_j = onehot.astype(gates.dtype)[..., None] * slot[:, :, None, :]
        dispatch = dispatch + d_j
        combine = combine + d_j * gate[:, :, None, None]
        fill = fill + jnp.sum(onehot, axis=1)
    return dispatch, combine


def moe_apply(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """MoE FFN. x: (B, S, d) -> (B, S, d)."""
    m: MoEConfig = cfg.moe
    b, s, d = x.shape
    logits = (x.astype(jnp.float32) @ p["router"]["w"])  # (B, S, E)
    gates = jax.nn.softmax(logits, axis=-1)
    capacity = max(
        1, int(m.top_k * s * m.capacity_factor / m.num_experts)
    )
    dispatch, combine = _dispatch_combine(gates.astype(x.dtype), m.top_k, capacity)

    # (E, B, C, d): tokens grouped per expert — the all-to-all einsum.
    xe = jnp.einsum("bsd,bsec->ebcd", x, dispatch)
    hi = jnp.einsum("ebcd,edf->ebcf", xe, p["experts"]["wi"])
    hg = jnp.einsum("ebcd,edf->ebcf", xe, p["experts"]["wg"])
    he = jax.nn.silu(hg) * hi
    ye = jnp.einsum("ebcf,efd->ebcd", he, p["experts"]["wo"])
    y = jnp.einsum("ebcd,bsec->bsd", ye, combine)

    if m.num_shared:
        y = y + glu_mlp(p["shared"], x)
    if m.dense_residual_ff:
        y = y + glu_mlp(p["dense_residual"], x)
    return y
