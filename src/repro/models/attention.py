"""Attention blocks: GQA with flash-style blocked softmax, local (sliding
window) attention, MLA (multi-head latent attention), and cache-based decode.

The blocked softmax (lax.scan over KV chunks with running max/normalizer)
keeps prefill memory at O(S · block) instead of O(S^2) — required for the
32k-prefill shapes.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.models.layers import apply_rope, dense, dense_init, rope_freqs

__all__ = [
    "gqa_init",
    "gqa_apply",
    "gqa_decode",
    "mla_init",
    "mla_apply",
    "KVCache",
    "flash_attention",
]

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_max, Hkv, dh)
    v: jax.Array  # (B, S_max, Hkv, dh)
    pos: jax.Array  # () int32 — next write position


# ---------------------------------------------------------------------------
# Head-structured projections: weights are (d, H, dh) / (H, dh, d) so the
# HEAD axis is a real tensor dim — TP shards whole heads and can never split
# a head interior (which would turn attention contractions into partial sums
# and all-reduce score-sized tensors; observed before this layout).
# ---------------------------------------------------------------------------


def head_proj_init(
    key: jax.Array, d: int, heads: int, head_dim: int, *, bias: bool = False,
    dtype=jnp.float32,
) -> dict:
    p = {"w": (jax.random.normal(key, (d, heads, head_dim)) * d**-0.5).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((heads, head_dim), dtype)
    return p


def head_proj(p: dict, x: jax.Array) -> jax.Array:
    """(..., d) -> (..., H, dh)."""
    y = jnp.einsum("...d,dhe->...he", x, p["w"])
    if "b" in p:
        y = y + p["b"]
    return y


def head_out_init(
    key: jax.Array, heads: int, head_dim: int, d: int, dtype=jnp.float32
) -> dict:
    scale = (heads * head_dim) ** -0.5
    return {"w": (jax.random.normal(key, (heads, head_dim, d)) * scale).astype(dtype)}


def head_out(p: dict, x: jax.Array) -> jax.Array:
    """(..., H, dh) -> (..., d)."""
    return jnp.einsum("...he,hed->...d", x, p["w"])


def repeat_kv(k: jax.Array, num_heads: int) -> jax.Array:
    """Expand (B, S, Hkv, dh) -> (B, S, H, dh) by group repetition (GQA)."""
    hkv = k.shape[2]
    if hkv == num_heads:
        return k
    return jnp.repeat(k, num_heads // hkv, axis=2)


def head_mask(cfg: ModelConfig, dtype=jnp.float32) -> Optional[jax.Array]:
    """(Hp, 1) constant mask zeroing inert padding heads (see ModelConfig
    ``pad_heads_to``): masking before the output projection keeps both the
    function and all gradients identical to the unpadded architecture."""
    hp = cfg.padded_heads
    if hp == cfg.num_heads:
        return None
    m = jnp.concatenate(
        [jnp.ones((cfg.num_heads,), dtype), jnp.zeros((hp - cfg.num_heads,), dtype)]
    )
    return m[:, None]


def apply_head_mask(x: jax.Array, mask: Optional[jax.Array]) -> jax.Array:
    """x: (..., H, dh) * mask (H, 1)."""
    if mask is None:
        return x
    return x * mask.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blocked-softmax attention (flash-style, pure XLA)
# ---------------------------------------------------------------------------


def dense_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    kv_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Plain masked softmax attention (scores materialized once).

    Used for training-length sequences: under autodiff a scanned
    online-softmax stores per-block residuals for the backward pass, which
    is strictly worse than one materialized score tensor (observed: the scan
    carries stacked (blocks, ...) score residuals through the grad). XLA:TPU
    fuses this form well; the scanned form below is for long forward-only
    prefill.
    """
    b, sq, h, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    group = h // hkv
    qg = (q.astype(jnp.float32) * dh**-0.5).reshape(b, sq, hkv, group, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= qpos[:, None] - kpos[None, :] < window
    if kv_len is not None:
        mask &= kpos[None, :] < kv_len
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhv->bqhgv", p, v.astype(jnp.float32))
    return out.reshape(b, sq, hkv * group, dv).astype(q.dtype)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_k: int = 1024,
    q_offset: int = 0,
    kv_len: Optional[jax.Array] = None,
    dense_threshold: int = 8192,
) -> jax.Array:
    """Attention dispatcher: dense path for short sequences (train-friendly
    autodiff), blocked online-softmax scan for long forward-only contexts.

    Args:
      q: ``(B, Sq, H, dh)``; k, v: ``(B, Sk, Hkv, dh)`` (GQA: H % Hkv == 0).
      causal: apply causal mask with query positions offset by ``q_offset``.
      window: if > 0, sliding-window (local) attention of this width.
      block_k: KV chunk size for the scan.
      kv_len: optional dynamic KV validity length (decode: cache fill level).

    Returns ``(B, Sq, H, dh)``.
    """
    # TPU fast path: the Pallas flash kernel covers the plain full-sequence
    # causal MHA case (kv already group-repeated, no window/kv_len) — the
    # train/prefill hot spot. All other cases use the XLA paths below.
    if (
        jax.default_backend() == "tpu"
        and causal
        and not window
        and kv_len is None
        and q_offset == 0
        and q.shape == k.shape
        and q.shape[1] == k.shape[1]
    ):
        from repro.kernels.ops import flash_attention as flash_kernel

        b, s, h, dh = q.shape
        bq = min(512, s)
        if s % bq == 0:
            def bh(x):
                return x.transpose(0, 2, 1, 3).reshape(b * h, s, x.shape[-1])

            out = flash_kernel(bh(q), bh(k), bh(v), block_q=bq, block_k=bq)
            return (
                out.reshape(b, h, s, v.shape[-1]).transpose(0, 2, 1, 3)
            )

    if k.shape[1] <= dense_threshold:
        return dense_attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            kv_len=kv_len,
        )
    b, sq, h, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    group = h // hkv
    scale = dh**-0.5
    bk = min(block_k, sk)
    nblocks = -(-sk // bk)
    pad = nblocks * bk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    q32 = q.astype(jnp.float32) * scale
    # (B, Hkv, group, Sq, dh)
    qg = q32.reshape(b, sq, hkv, group, dh).transpose(0, 2, 3, 1, 4)
    kb = k.reshape(b, nblocks, bk, hkv, dh).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, nblocks, bk, hkv, dv).transpose(1, 0, 3, 2, 4)

    qpos = q_offset + jnp.arange(sq)

    def body(carry, inp):
        m, l, acc = carry  # (B,Hkv,g,Sq), same, (B,Hkv,g,Sq,dh)
        kblk, vblk, blk_idx = inp  # (B,Hkv,bk,dh) x2, ()
        kpos = blk_idx * bk + jnp.arange(bk)
        s = jnp.einsum(
            "bhgqd,bhkd->bhgqk", qg, kblk.astype(jnp.float32)
        )  # (B,Hkv,g,Sq,bk)
        mask = jnp.ones((sq, bk), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window:
            mask &= qpos[:, None] - kpos[None, :] < window
        mask &= kpos[None, :] < (sk if kv_len is None else kv_len)
        s = jnp.where(mask, s, NEG_INF)
        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_blk)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vblk.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((b, hkv, group, sq), NEG_INF, jnp.float32),
        jnp.zeros((b, hkv, group, sq), jnp.float32),
        jnp.zeros((b, hkv, group, sq, dv), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, (kb, vb, jnp.arange(nblocks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dv)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------


def gqa_init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, hp, hkv = cfg.d_model, cfg.padded_heads, cfg.num_kv_heads
    dh = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": head_proj_init(kq, d, hp, dh, bias=cfg.qkv_bias, dtype=dtype),
        "wk": head_proj_init(kk, d, hkv, dh, bias=cfg.qkv_bias, dtype=dtype),
        "wv": head_proj_init(kv, d, hkv, dh, bias=cfg.qkv_bias, dtype=dtype),
        "wo": head_out_init(ko, hp, dh, d, dtype=dtype),
    }


def _project_qkv(p, cfg: ModelConfig, x, positions):
    dh = cfg.resolved_head_dim
    q = head_proj(p["wq"], x)  # (B, S, Hp, dh)
    k = head_proj(p["wk"], x)  # (B, S, Hkv, dh)
    v = head_proj(p["wv"], x)
    cos, sin = rope_freqs(positions, dh, cfg.rope_theta)
    return apply_rope(q, cos, sin), apply_rope(k, cos, sin), v


def gqa_apply(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    window: int = 0,
    block_k: int = 1024,
) -> jax.Array:
    """Full-sequence causal (optionally windowed) GQA. x: (B, S, d)."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions)
    # kv repeated to full (padded) heads as activations: per-head einsums
    # stay local under head sharding (cheap-kv-projection / shardable-q).
    k = repeat_kv(k, cfg.padded_heads)
    v = repeat_kv(v, cfg.padded_heads)
    out = flash_attention(q, k, v, causal=True, window=window, block_k=block_k)
    return head_out(p["wo"], apply_head_mask(out, head_mask(cfg)))


def gqa_decode(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,
    cache: KVCache,
    *,
    window: int = 0,
) -> tuple[jax.Array, KVCache]:
    """One-token decode against a KV cache (stored unrepeated; the cache is
    sequence-sharded over the model axis — decode context parallelism)."""
    b = x.shape[0]
    positions = cache.pos[None, None] + jnp.zeros((b, 1), jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, cache.pos, 1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, cache.pos, 1)
    out = dense_attention(
        q,
        k_cache,
        v_cache,
        causal=False,  # validity handled via kv_len
        window=window,
        kv_len=cache.pos + 1,
    )
    new_cache = KVCache(k=k_cache, v=v_cache, pos=cache.pos + 1)
    return head_out(p["wo"], apply_head_mask(out, head_mask(cfg))), new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 / MiniCPM3 multi-head latent attention)
# ---------------------------------------------------------------------------


def mla_init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.padded_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    keys = jax.random.split(key, 6)
    p = {
        # shared latent paths (2D) + head-structured up-projections (3D)
        "w_dkv": dense_init(keys[0], d, m.kv_lora_rank, dtype=dtype),
        "w_kr": dense_init(keys[1], d, m.qk_rope_head_dim, dtype=dtype),
        "w_ukv": head_proj_init(
            keys[2], m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim,
            dtype=dtype,
        ),
        "wo": head_out_init(keys[3], h, m.v_head_dim, d, dtype=dtype),
    }
    if m.q_lora_rank:
        p["w_dq"] = dense_init(keys[4], d, m.q_lora_rank, dtype=dtype)
        p["w_uq"] = head_proj_init(keys[5], m.q_lora_rank, h, qk, dtype=dtype)
    else:
        p["wq"] = head_proj_init(keys[4], d, h, qk, dtype=dtype)
    return p


def mla_apply(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    block_k: int = 1024,
) -> jax.Array:
    """Full-sequence causal MLA. x: (B, S, d)."""
    m: MLAConfig = cfg.mla
    b, s, _ = x.shape
    h = cfg.padded_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    positions = jnp.arange(s)[None, :]

    if m.q_lora_rank:
        q = head_proj(p["w_uq"], dense(p["w_dq"], x))
    else:
        q = head_proj(p["wq"], x)  # (B, S, H, dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]

    c_kv = dense(p["w_dkv"], x)  # (B, S, r)
    kv = head_proj(p["w_ukv"], c_kv)  # (B, S, H, dn+dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k_rope = dense(p["w_kr"], x).reshape(b, s, 1, dr)  # shared across heads

    cos, sin = rope_freqs(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope, cos, sin)
    k_rope = jnp.broadcast_to(k_rope, (b, s, h, dr))

    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope], axis=-1)
    out = flash_attention(q_full, k_full, v, causal=True, block_k=block_k)
    return head_out(p["wo"], apply_head_mask(out, head_mask(cfg)))


class MLACache(NamedTuple):
    """Latent cache: per-token compressed KV (r) + rope key — the MLA
    memory win: cache is (r + dr) per token instead of 2·H·dh."""

    c_kv: jax.Array  # (B, S_max, r)
    k_rope: jax.Array  # (B, S_max, dr)
    pos: jax.Array


def mla_decode(
    p: dict, cfg: ModelConfig, x: jax.Array, cache: MLACache
) -> tuple[jax.Array, MLACache]:
    """One-token MLA decode from the latent cache (sequence-sharded)."""
    m: MLAConfig = cfg.mla
    b = x.shape[0]
    h = cfg.padded_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    positions = cache.pos[None, None] + jnp.zeros((b, 1), jnp.int32)

    if m.q_lora_rank:
        q = head_proj(p["w_uq"], dense(p["w_dq"], x))
    else:
        q = head_proj(p["wq"], x)  # (B, 1, H, dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    cos, sin = rope_freqs(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    c_new = dense(p["w_dkv"], x)  # (B, 1, r)
    kr_new = dense(p["w_kr"], x)  # (B, 1, dr)
    kr_new = apply_rope(kr_new[:, :, None, :], cos, sin)[:, :, 0]
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache.c_kv, c_new, cache.pos, 1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache.k_rope, kr_new, cache.pos, 1)

    # Expand latents for attention (weight-absorbed decode is the §Perf
    # optimization; the paper-faithful baseline expands then dots).
    s_max = c_kv.shape[1]
    kv = head_proj(p["w_ukv"], c_kv)  # (B, S, H, dn+dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s_max, h, dr))], -1
    )
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = dense_attention(q_full, k_full, v, causal=False, kv_len=cache.pos + 1)
    new_cache = MLACache(c_kv=c_kv, k_rope=k_rope, pos=cache.pos + 1)
    return head_out(p["wo"], apply_head_mask(out, head_mask(cfg))), new_cache
