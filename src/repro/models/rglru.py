"""RG-LRU recurrent block (RecurrentGemma / Griffin) + its hybrid pattern.

Real-Gated Linear Recurrent Unit with **block-diagonal per-head gates**
(faithful to the published RecurrentGemma: ``BlockDiagonalLinear`` with
``num_blocks = num_heads``; this also makes the gates local under head
sharding — a dense (W, W) gate would partial-sum all-reduce a full-width
activation per gate per layer, observed before this layout):

    r_t = sigmoid(blockdiag(W_r) xw_t)      (recurrence gate)
    i_t = sigmoid(blockdiag(W_i) xw_t)      (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)  (per-channel decay, c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * xw_t)

Channels are organized as (heads, head_dim) throughout: projections are
head-structured (shardable whole-head), conv/recurrence/gates operate
per-head, inert padding heads (cfg.pad_heads_to) are masked at the output
projection exactly like attention heads.

Block structure (Griffin recurrent block): conv1d -> RG-LRU on one branch,
gelu gate on the other, merged by elementwise product, then out-projection.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import (
    apply_head_mask,
    head_mask,
    head_out,
    head_out_init,
    head_proj,
    head_proj_init,
)

__all__ = ["rglru_init", "rglru_apply", "rglru_decode", "RGLRUState"]

_C = 8.0


class RGLRUState(NamedTuple):
    h: jax.Array  # (B, Hp, hd) recurrent state
    conv: jax.Array  # (B, conv_width-1, Hp, hd) conv tail
    pos: jax.Array


def _dims(cfg: ModelConfig) -> tuple[int, int]:
    """(padded head count, lru head dim)."""
    w = cfg.lru_width or cfg.d_model
    hd = w // cfg.num_heads
    return cfg.padded_heads, hd


def rglru_init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    hp, hd = _dims(cfg)
    keys = jax.random.split(key, 6)
    scale = hd**-0.5
    return {
        "w_x": head_proj_init(keys[0], d, hp, hd, dtype=dtype),
        "w_gate": head_proj_init(keys[1], d, hp, hd, dtype=dtype),
        "conv_w": (jax.random.normal(keys[2], (hp, hd, cfg.conv_width)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((hp, hd), dtype),
        # block-diagonal gates: one (hd, hd) block per head
        "w_r": (scale * jax.random.normal(keys[3], (hp, hd, hd))).astype(dtype),
        "w_i": (scale * jax.random.normal(keys[4], (hp, hd, hd))).astype(dtype),
        # Lambda param init so decays start in a useful range
        "lam": jnp.log(
            jnp.expm1(jnp.linspace(0.3, 1.5, hp * hd))
        ).reshape(hp, hd).astype(jnp.float32),
        "w_out": head_out_init(keys[5], hp, hd, d, dtype=dtype),
    }


def _causal_conv(u, w, b, tail=None):
    """Depthwise causal conv over time. u: (B, S, Hp, hd); w: (Hp, hd, W)."""
    width = w.shape[-1]
    if tail is None:
        pad = jnp.zeros((u.shape[0], width - 1) + u.shape[2:], u.dtype)
    else:
        pad = tail
    up = jnp.concatenate([pad, u], axis=1)
    out = sum(
        up[:, i : i + u.shape[1]] * w[None, None, :, :, i] for i in range(width)
    )
    return out + b, up[:, -(width - 1) :]


def _lru_scan(u: jax.Array, a: jax.Array, h0: jax.Array, chunk: int):
    """Diagonal recurrence h_t = a_t h_{t-1} + u_t, chunked assoc-scan.

    u, a: (B, S, Hp, hd); h0: (B, Hp, hd).
    """
    bsz, s = u.shape[:2]
    rest = u.shape[2:]
    c = min(chunk, s)
    assert s % c == 0
    nc = s // c
    uc = jnp.moveaxis(u.reshape((bsz, nc, c) + rest), 1, 0)
    ac = jnp.moveaxis(a.reshape((bsz, nc, c) + rest), 1, 0)

    def combine(x, y):
        a1, u1 = x
        a2, u2 = y
        return a1 * a2, u1 * a2 + u2

    def body(h, inp):
        au, uu = inp  # (B, c, Hp, hd)
        a_cum, u_cum = jax.lax.associative_scan(combine, (au, uu), axis=1)
        hs = a_cum * h[:, None] + u_cum
        return hs[:, -1], hs

    h_final, hs = jax.lax.scan(body, h0, (ac, uc))
    return jnp.moveaxis(hs, 0, 1).reshape((bsz, s) + rest), h_final


def _gates(p, xw):
    """Block-diagonal gates. xw: (..., Hp, hd)."""
    r_pre = jnp.einsum("...he,hef->...hf", xw, p["w_r"])
    i_pre = jnp.einsum("...he,hef->...hf", xw, p["w_i"])
    r = jax.nn.sigmoid(r_pre.astype(jnp.float32))
    i = jax.nn.sigmoid(i_pre.astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * i


def rglru_apply(
    p: dict, cfg: ModelConfig, x: jax.Array, chunk: int = 256
) -> jax.Array:
    """Full-sequence recurrent block. x: (B, S, d)."""
    bsz, s, _ = x.shape
    hp, hd = _dims(cfg)
    gate = jax.nn.gelu(head_proj(p["w_gate"], x))  # (B, S, Hp, hd)
    xw = head_proj(p["w_x"], x)
    xw, _ = _causal_conv(xw, p["conv_w"], p["conv_b"])
    a, scaled_in = _gates(p, xw)
    u = scaled_in * xw.astype(jnp.float32)
    h0 = jnp.zeros((bsz, hp, hd), jnp.float32)
    hs, _ = _lru_scan(u, a, h0, chunk)
    y = hs.astype(x.dtype) * gate
    return head_out(p["w_out"], apply_head_mask(y, head_mask(cfg)))


def rglru_state_init(cfg: ModelConfig, batch: int) -> RGLRUState:
    hp, hd = _dims(cfg)
    return RGLRUState(
        h=jnp.zeros((batch, hp, hd), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_width - 1, hp, hd), jnp.float32),
        pos=jnp.zeros((), jnp.int32),
    )


def rglru_decode(
    p: dict, cfg: ModelConfig, x: jax.Array, state: RGLRUState
) -> tuple[jax.Array, RGLRUState]:
    """One-token decode: O(W) state update. x: (B, 1, d)."""
    gate = jax.nn.gelu(head_proj(p["w_gate"], x))  # (B, 1, Hp, hd)
    xw = head_proj(p["w_x"], x)
    xw, new_tail = _causal_conv(
        xw, p["conv_w"], p["conv_b"], tail=state.conv.astype(xw.dtype)
    )
    a, scaled_in = _gates(p, xw[:, 0])
    u = scaled_in * xw[:, 0].astype(jnp.float32)
    h = a * state.h + u
    y = h[:, None].astype(x.dtype) * gate
    out = head_out(p["w_out"], apply_head_mask(y, head_mask(cfg)))
    return out, RGLRUState(h=h, conv=new_tail.astype(jnp.float32), pos=state.pos + 1)
