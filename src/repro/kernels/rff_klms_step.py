"""Pallas TPU kernel: fully-fused RFF-KLMS step for a bank of B filters.

The per-step hot path of the paper's Algorithm (§4) is, per stream,

    z     = sqrt(2/D) cos(W^T x + b)      (feature map, O(D d))
    y_hat = theta^T z                      (predict)
    e     = y - y_hat                      (prior error)
    theta <- theta + mu e z                (LMS update)

Run two-pass (feature kernel, then update) this costs two HBM round-trips of
the ``(B, D)`` activation ``z`` plus a second read of ``theta``. Fused, ``z``
never leaves VMEM: one read of ``x``/``W``/``b``/``theta``, one write of the
updated ``theta`` — the arithmetic intensity the serving bank needs.

TPU mapping:
  * grid over blocks of the bank axis B only; each grid step owns ``block_b``
    filters end-to-end (their full ``(block_b, D)`` theta row-block), so the
    predict-reduction over D and the dependent update happen entirely in VMEM
    with no cross-block communication;
  * the projection ``x @ W`` runs on the MXU in f32; cos / dot / axpy are VPU
    work on the same tile;
  * ``W (d, D)`` is grid-invariant (index_map pins it to block (0, 0)), so
    Pallas fetches it once and re-uses the same VMEM tile across the bank —
    the "one HBM read of W" property. VMEM budget: W d*D f32 (e.g.
    128x2048 = 1 MiB) + 3 theta/z tiles of block_b*D ≈ well under 16 MiB.

Padding (all exact): the contraction dim d zero-pads x columns / W rows
(adds 0 to the projection); padded D columns produce garbage z but the
*input* theta is zero there so the prediction is untouched, and the wrapper
slices the updated theta back to the true D; padded B rows are sliced off.

``mu`` is an array ``(B,)`` — per-filter step sizes, the hyperparameter-sweep
axis of the filter bank — broadcast from a scalar by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.rff_features import _ceil_to, _pad2

__all__ = ["rff_klms_step_kernel", "rff_klms_bank_step_pallas"]


def rff_klms_step_kernel(
    x_ref, w_ref, b_ref, theta_ref, y_ref, mu_ref, theta_out_ref, pred_ref,
    err_ref, *, scale: float
):
    """One bank-block: featurize, predict, error, update — all in VMEM."""
    proj = jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) + b_ref[...].astype(jnp.float32)
    z = scale * jnp.cos(proj)  # (bb, D) — never written to HBM
    theta = theta_ref[...].astype(jnp.float32)
    pred = jnp.sum(theta * z, axis=1, keepdims=True)  # (bb, 1)
    err = y_ref[...].astype(jnp.float32) - pred
    theta_out_ref[...] = (
        theta + mu_ref[...].astype(jnp.float32) * err * z
    ).astype(theta_out_ref.dtype)
    pred_ref[...] = pred.astype(pred_ref.dtype)
    err_ref[...] = err.astype(err_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def rff_klms_bank_step_pallas(
    theta: jax.Array,
    x: jax.Array,
    y: jax.Array,
    w: jax.Array,
    b: jax.Array,
    mu: jax.Array,
    *,
    block_b: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused KLMS step for B independent filters sharing one feature map.

    Args:
      theta: ``(B, D)`` per-filter solutions.
      x: ``(B, d)`` one input sample per filter/stream.
      y: ``(B,)`` targets.
      w: ``(d, D)`` shared spectral matrix.
      b: ``(D,)`` shared phases.
      mu: scalar or ``(B,)`` per-filter step sizes.

    Returns:
      (theta_new ``(B, D)``, predictions ``(B,)``, prior errors ``(B,)``).
    """
    bsz, dfeat = theta.shape
    d = x.shape[-1]
    assert x.shape == (bsz, d) and y.shape == (bsz,)
    assert w.shape == (d, dfeat) and b.shape == (dfeat,)
    scale = float((2.0 / dfeat) ** 0.5)  # true D, not padded

    bb = min(block_b, _ceil_to(bsz, 8))
    bp, dp, np_ = _ceil_to(bsz, bb), _ceil_to(d, 128), _ceil_to(dfeat, 128)

    mu_col = jnp.broadcast_to(jnp.asarray(mu, theta.dtype), (bsz,))
    theta_p = _pad2(theta, bp, np_)
    x_p = _pad2(x, bp, dp)
    y_p = jnp.pad(y, (0, bp - bsz))[:, None]  # (Bp, 1)
    mu_p = jnp.pad(mu_col, (0, bp - bsz))[:, None]
    w_p = _pad2(w, dp, np_)
    b_p = jnp.pad(b, (0, np_ - dfeat))[None, :]  # (1, Np)

    grid = (bp // bb,)
    theta_new, pred, err = pl.pallas_call(
        functools.partial(rff_klms_step_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, dp), lambda i: (i, 0)),
            pl.BlockSpec((dp, np_), lambda i: (0, 0)),  # grid-invariant W
            pl.BlockSpec((1, np_), lambda i: (0, 0)),
            pl.BlockSpec((bb, np_), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, np_), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, np_), theta.dtype),
            jax.ShapeDtypeStruct((bp, 1), theta.dtype),
            jax.ShapeDtypeStruct((bp, 1), theta.dtype),
        ],
        interpret=interpret,
    )(x_p, w_p, b_p, theta_p, y_p, mu_p)
    return theta_new[:bsz, :dfeat], pred[:bsz, 0], err[:bsz, 0]
