"""Pallas TPU kernel: fully-fused RFF-KLMS step for a bank of B filters.

The per-step hot path of the paper's Algorithm (§4) is, per stream,

    z     = sqrt(2/D) cos(W^T x + b)      (feature map, O(D d))
    y_hat = theta^T z                      (predict)
    e     = y - y_hat                      (prior error)
    theta <- theta + mu e z                (LMS update)

Run two-pass (feature kernel, then update) this costs two HBM round-trips of
the ``(B, D)`` activation ``z`` plus a second read of ``theta``. Fused, ``z``
never leaves VMEM: one read of ``x``/``W``/``b``/``theta``, one write of the
updated ``theta`` — the arithmetic intensity the serving bank needs.

TPU mapping:
  * grid over blocks of the bank axis B only; each grid step owns ``block_b``
    filters end-to-end (their full ``(block_b, D)`` theta row-block), so the
    predict-reduction over D and the dependent update happen entirely in VMEM
    with no cross-block communication;
  * the projection ``x @ W`` runs on the MXU in f32; cos / dot / axpy are VPU
    work on the same tile;
  * ``W (d, D)`` is grid-invariant (index_map pins it to block (0, 0)), so
    Pallas fetches it once and re-uses the same VMEM tile across the bank —
    the "one HBM read of W" property. VMEM budget: W d*D f32 (e.g.
    128x2048 = 1 MiB) + 3 theta/z tiles of block_b*D ≈ well under 16 MiB.

Padding (all exact): the contraction dim d zero-pads x columns / W rows
(adds 0 to the projection); padded D columns produce garbage z but the
*input* theta is zero there so the prediction is untouched, and the wrapper
slices the updated theta back to the true D; padded B rows are sliced off.

``mu`` is an array ``(B,)`` — per-filter step sizes, the hyperparameter-sweep
axis of the filter bank — broadcast from a scalar by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.rff_features import _ceil_to, _pad2

__all__ = [
    "rff_klms_step_kernel",
    "rff_klms_bank_step_pallas",
    "rff_klms_chunk_kernel",
    "rff_klms_bank_chunk_pallas",
]


def rff_klms_step_kernel(
    x_ref, w_ref, b_ref, s_ref, theta_ref, y_ref, mu_ref, theta_out_ref,
    pred_ref, err_ref
):
    """One bank-block: featurize, predict, error, update — all in VMEM.

    ``s`` is the per-feature scale row of the canonical affine-trig form
    (repro.features) — zero in padded-D columns, so padded z is exactly 0.
    """
    proj = jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) + b_ref[...].astype(jnp.float32)
    z = s_ref[...].astype(jnp.float32) * jnp.cos(proj)  # (bb, D), VMEM-only
    theta = theta_ref[...].astype(jnp.float32)
    pred = jnp.sum(theta * z, axis=1, keepdims=True)  # (bb, 1)
    err = y_ref[...].astype(jnp.float32) - pred
    theta_out_ref[...] = (
        theta + mu_ref[...].astype(jnp.float32) * err * z
    ).astype(theta_out_ref.dtype)
    pred_ref[...] = pred.astype(pred_ref.dtype)
    err_ref[...] = err.astype(err_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def rff_klms_bank_step_pallas(
    theta: jax.Array,
    x: jax.Array,
    y: jax.Array,
    w: jax.Array,
    b: jax.Array,
    mu: jax.Array,
    s: jax.Array | None = None,
    *,
    block_b: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused KLMS step for B independent filters sharing one feature map.

    Args:
      theta: ``(B, D)`` per-filter solutions.
      x: ``(B, d)`` one input sample per filter/stream.
      y: ``(B,)`` targets.
      w: ``(d, D)`` shared spectral matrix.
      b: ``(D,)`` shared phases.
      mu: scalar or ``(B,)`` per-filter step sizes.
      s: ``(D,)`` shared per-feature scales; None = Monte-Carlo
         ``sqrt(2/D)``.

    Returns:
      (theta_new ``(B, D)``, predictions ``(B,)``, prior errors ``(B,)``).
    """
    bsz, dfeat = theta.shape
    d = x.shape[-1]
    assert x.shape == (bsz, d) and y.shape == (bsz,)
    assert w.shape == (d, dfeat) and b.shape == (dfeat,)
    if s is None:
        s = jnp.full((dfeat,), float((2.0 / dfeat) ** 0.5), jnp.float32)
    assert s.shape == (dfeat,)

    bb = min(block_b, _ceil_to(bsz, 8))
    bp, dp, np_ = _ceil_to(bsz, bb), _ceil_to(d, 128), _ceil_to(dfeat, 128)

    mu_col = jnp.broadcast_to(jnp.asarray(mu, theta.dtype), (bsz,))
    theta_p = _pad2(theta, bp, np_)
    x_p = _pad2(x, bp, dp)
    y_p = jnp.pad(y, (0, bp - bsz))[:, None]  # (Bp, 1)
    mu_p = jnp.pad(mu_col, (0, bp - bsz))[:, None]
    w_p = _pad2(w, dp, np_)
    b_p = jnp.pad(b, (0, np_ - dfeat))[None, :]  # (1, Np)
    s_p = jnp.pad(s, (0, np_ - dfeat))[None, :]  # (1, Np), padded scales 0

    grid = (bp // bb,)
    theta_new, pred, err = pl.pallas_call(
        rff_klms_step_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, dp), lambda i: (i, 0)),
            pl.BlockSpec((dp, np_), lambda i: (0, 0)),  # grid-invariant W
            pl.BlockSpec((1, np_), lambda i: (0, 0)),
            pl.BlockSpec((1, np_), lambda i: (0, 0)),
            pl.BlockSpec((bb, np_), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, np_), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, np_), theta.dtype),
            jax.ShapeDtypeStruct((bp, 1), theta.dtype),
            jax.ShapeDtypeStruct((bp, 1), theta.dtype),
        ],
        interpret=interpret,
    )(x_p, w_p, b_p, s_p, theta_p, y_p, mu_p)
    return theta_new[:bsz, :dfeat], pred[:bsz, 0], err[:bsz, 0]


# ---------------------------------------------------------------------------
# Time-blocked (chunked) variant: T ticks per Pallas launch.
#
# The per-tick kernel above amortizes the feature round-trip but still pays
# one launch + one HBM read/write of the full (B, D) theta *per tick*. The
# chunk kernel runs a (bank_blocks, T) grid with T as the minor dimension
# and carries theta in a VMEM *scratch* accumulator (the same device the
# rff_features K-loop uses): seeded from HBM at t == 0, updated in place for
# all T ticks of a bank block, written back once at t == T-1. Theta traffic
# drops from 2*B*D*4 bytes/tick to 2*B*D*4/T, and W/b are still fetched once
# per launch (d*D*4 / (B*T) bytes per tick).
# ---------------------------------------------------------------------------


def rff_klms_chunk_kernel(
    x_ref, w_ref, b_ref, s_ref, theta_ref, y_ref, mu_ref, mask_ref,
    theta_out_ref, pred_ref, err_ref, acc_ref
):
    """Grid point (i, t): tick t for bank block i on the resident theta tile.

    ``mask`` (0/1 per (filter, tick)) gates the update only — masked ticks
    still emit their prior prediction/error but leave theta untouched. With
    mask==1 the update expression multiplies by exactly 1.0, so an unmasked
    chunk is bitwise identical to T per-tick kernel calls (f32 state).

    The resident theta carries across ticks, so z's padded-D columns must
    be exactly zero — guaranteed structurally: the per-feature scale row
    ``s`` is zero-padded, and 0 * cos(garbage) == 0.
    """
    t = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(t == 0)
    def _seed():
        acc_ref[...] = theta_ref[...].astype(jnp.float32)

    proj = jnp.dot(
        x_ref[:, 0, :].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) + b_ref[...].astype(jnp.float32)
    z = s_ref[...].astype(jnp.float32) * jnp.cos(proj)  # (bb, D), VMEM-only
    theta = acc_ref[...]
    pred = jnp.sum(theta * z, axis=1, keepdims=True)  # (bb, 1)
    err = y_ref[...].astype(jnp.float32) - pred
    gated = mask_ref[...].astype(jnp.float32) * err
    acc_ref[...] = theta + mu_ref[...].astype(jnp.float32) * gated * z
    pred_ref[...] = pred.astype(pred_ref.dtype)
    err_ref[...] = err.astype(err_ref.dtype)

    @pl.when(t == nt - 1)
    def _writeback():
        theta_out_ref[...] = acc_ref[...].astype(theta_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def rff_klms_bank_chunk_pallas(
    theta: jax.Array,
    xs: jax.Array,
    ys: jax.Array,
    w: jax.Array,
    b: jax.Array,
    mu: jax.Array,
    mask: jax.Array | None = None,
    s: jax.Array | None = None,
    *,
    block_b: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """T-chunked fused KLMS: one launch advances every filter by T ticks.

    Args:
      theta: ``(B, D)`` per-filter solutions.
      xs: ``(B, T, d)`` T samples per filter/stream.
      ys: ``(B, T)`` targets.
      w: ``(d, D)`` shared spectral matrix.
      b: ``(D,)`` shared phases.
      mu: scalar or ``(B,)`` per-filter step sizes.
      mask: optional ``(B, T)`` validity gate (1 = apply the update); the
        masked-remainder contract of the chunked run-loops and the serve
        queue's ragged-arrival chunks.
      s: ``(D,)`` shared per-feature scales; None = Monte-Carlo
         ``sqrt(2/D)``.

    Returns:
      (theta_new ``(B, D)``, predictions ``(B, T)``, prior errors ``(B, T)``).
    """
    bsz, tlen, d = xs.shape
    dfeat = theta.shape[-1]
    assert theta.shape == (bsz, dfeat) and ys.shape == (bsz, tlen)
    assert w.shape == (d, dfeat) and b.shape == (dfeat,)
    if s is None:
        s = jnp.full((dfeat,), float((2.0 / dfeat) ** 0.5), jnp.float32)
    assert s.shape == (dfeat,)

    bb = min(block_b, _ceil_to(bsz, 8))
    bp, dp, np_ = _ceil_to(bsz, bb), _ceil_to(d, 128), _ceil_to(dfeat, 128)

    mu_col = jnp.broadcast_to(jnp.asarray(mu, theta.dtype), (bsz,))
    if mask is None:
        mask = jnp.ones((bsz, tlen), theta.dtype)
    theta_p = _pad2(theta, bp, np_)
    xs_p = jnp.pad(xs, ((0, bp - bsz), (0, 0), (0, dp - d)))
    ys_p = jnp.pad(ys, ((0, bp - bsz), (0, 0)))
    mask_p = jnp.pad(mask.astype(theta.dtype), ((0, bp - bsz), (0, 0)))
    mu_p = jnp.pad(mu_col, (0, bp - bsz))[:, None]
    w_p = _pad2(w, dp, np_)
    b_p = jnp.pad(b, (0, np_ - dfeat))[None, :]  # (1, Np)
    s_p = jnp.pad(s, (0, np_ - dfeat))[None, :]  # (1, Np), padded scales 0

    grid = (bp // bb, tlen)  # t minor: theta tile resident across the chunk
    theta_new, pred, err = pl.pallas_call(
        rff_klms_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, 1, dp), lambda i, t: (i, t, 0)),
            pl.BlockSpec((dp, np_), lambda i, t: (0, 0)),  # grid-invariant W
            pl.BlockSpec((1, np_), lambda i, t: (0, 0)),
            pl.BlockSpec((1, np_), lambda i, t: (0, 0)),
            pl.BlockSpec((bb, np_), lambda i, t: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i, t: (i, t)),
            pl.BlockSpec((bb, 1), lambda i, t: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i, t: (i, t)),
        ],
        out_specs=[
            pl.BlockSpec((bb, np_), lambda i, t: (i, 0)),  # revisited over t
            pl.BlockSpec((bb, 1), lambda i, t: (i, t)),
            pl.BlockSpec((bb, 1), lambda i, t: (i, t)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, np_), theta.dtype),
            jax.ShapeDtypeStruct((bp, tlen), theta.dtype),
            jax.ShapeDtypeStruct((bp, tlen), theta.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bb, np_), jnp.float32)],
        interpret=interpret,
    )(xs_p, w_p, b_p, s_p, theta_p, ys_p, mu_p, mask_p)
    return theta_new[:bsz, :dfeat], pred[:bsz], err[:bsz]
