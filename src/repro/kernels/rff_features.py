"""Pallas TPU kernel: fused affine-trig feature map ``s * cos(x @ W + b)``.

This is the compute hot-spot of every RFF algorithm in the paper (per-step
cost O(D d) is *this* op), and of the RFF-attention layer (where it runs at
(batch*seq, head_dim) x (head_dim, D) scale). The per-feature scale row
``s`` (default: the Monte-Carlo ``sqrt(2/D)``) is what makes the kernel
family-agnostic — weighted Gaussian-quadrature, QMC and orthogonal feature
maps (repro.features) all canonicalize to this exact form, so ONE kernel
serves every family.

TPU mapping:
  * GEMM on the MXU with (block_m, block_k) x (block_k, block_n) VMEM tiles,
    f32 accumulation in a VMEM scratch accumulator;
  * grid (M/bm, N/bn, K/bk), K innermost so the accumulator carries across
    the minor grid dimension;
  * bias-add + cos + scale fused on the *last* K step only (VPU work), so the
    transcendental is applied exactly once per output tile — no extra HBM
    round-trip for the activation.

Block shapes default to 128x128x128: MXU-aligned (multiples of 128 on both
GEMM dims), 3 * 64KiB f32 tiles + 64KiB accumulator ≈ 256 KiB VMEM — far
under the ~16 MiB/core budget, leaving room for double-buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import canon_precision

__all__ = ["rff_features_kernel", "rff_features_pallas"]


def rff_features_kernel(x_ref, w_ref, b_ref, s_ref, o_ref, acc_ref, *,
                        precision=None):
    """Grid point (i, j, k): accumulate x[i,k] @ w[k,j]; finalize on last k.

    The per-feature scale row ``s`` is applied with the bias-add/cos on the
    last K step (VPU work, one extra (1, bn) tile in VMEM). Padded-D columns
    carry s == 0, so their outputs are exactly 0 before the wrapper slices
    them off. ``precision="bf16"`` (contract in kernels/ref.py) feeds the
    MXU bf16 operands; the accumulator stays f32 either way.
    """
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    gemm_dtype = jnp.bfloat16 if precision == "bf16" else jnp.float32
    acc_ref[...] += jnp.dot(
        x_ref[...].astype(gemm_dtype),
        w_ref[...].astype(gemm_dtype),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _finalize():
        proj = acc_ref[...] + b_ref[...].astype(jnp.float32)
        o_ref[...] = (s_ref[...].astype(jnp.float32) * jnp.cos(proj)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_m", "block_n", "block_k", "interpret", "out_dtype", "precision",
    ),
)
def rff_features_pallas(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    s: jax.Array | None = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    out_dtype: jnp.dtype | None = None,
    precision: str | None = None,
) -> jax.Array:
    """``s * cos(x @ w + b)`` via pallas_call.

    Args:
      x: ``(M, d)`` inputs (any float dtype).
      w: ``(d, D)`` spectral matrix.
      b: ``(D,)`` phases.
      s: ``(D,)`` per-feature scales; None means the Monte-Carlo
         ``sqrt(2/D)`` (legacy RFF behavior, bitwise unchanged).
      precision: None/"f32" (legacy, bitwise unchanged) or "bf16" — the
        GEMM operands drop to bf16 with f32 accumulation and the feature
        block is emitted in bf16 (kernels/ref.py documents the contract).

    Shapes are padded up to block multiples internally (zero-padding the
    contraction dim is exact: it adds 0 to the pre-activation; zero-padding
    ``s`` zeroes padded output columns exactly).
    """
    m, d = x.shape
    d2, n = w.shape
    assert d == d2 and b.shape == (n,)
    precision = canon_precision(precision)
    if precision == "bf16":
        out_dtype = out_dtype or jnp.bfloat16
    out_dtype = out_dtype or x.dtype
    if s is None:
        # f32 regardless of w's dtype: the kernel multiplies in f32, and the
        # legacy static-scalar scale was a full-precision python float.
        s = jnp.full((n,), float((2.0 / n) ** 0.5), jnp.float32)  # true D
    assert s.shape == (n,)

    bm, bn, bk = (min(block_m, _ceil_to(m, 8)),
                  min(block_n, _ceil_to(n, 128)),
                  min(block_k, _ceil_to(d, 128)))
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(d, bk)

    xp = _pad2(x, mp, kp)
    wp = _pad2(w, kp, np_)
    bp = jnp.pad(b, (0, np_ - n))[None, :]  # (1, Np)
    sp = jnp.pad(s, (0, np_ - n))[None, :]  # (1, Np), padded scales are 0

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(rff_features_kernel, precision=precision),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xp, wp, bp, sp)
    return out[:m, :n]


def _ceil_to(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def _pad2(a: jax.Array, r: int, c: int) -> jax.Array:
    return jnp.pad(a, ((0, r - a.shape[0]), (0, c - a.shape[1])))
