"""Pallas TPU kernel: chunked causal linear attention over random features.

This is the paper's fixed-size-state insight applied to attention (DESIGN.md
§2): with kernelized attention weights ``kappa(q_t, k_s) ~= phi(q_t)^T
phi(k_s)``, the causal attention output

    o_t = sum_{s<=t} phi(q_t)^T phi(k_s) v_s   /   sum_{s<=t} phi(q_t)^T phi(k_s)

is computable from a *fixed-size* running state ``S_t = sum phi(k_s) v_s^T in
R^{D x dv}`` and ``z_t = sum phi(k_s) in R^D`` — the exact analogue of
RFFKLMS's theta replacing the growing dictionary (here: the growing KV cache).

TPU adaptation — *chunkwise-parallel* form, not a per-token scan:
  * sequence is split into chunks of C tokens;
  * intra-chunk term: ``(Q K^T ∘ causal_mask) V`` — three MXU GEMMs;
  * inter-chunk term: ``Q @ S_prev`` — one MXU GEMM against the carried state;
  * the state lives in VMEM scratch and carries across the (sequential) minor
    grid dimension; each (batch*head) slice re-initializes it at chunk 0.

Grid: ``(BH, S/C)`` — minor dim is the chunk index, so for each bh the chunks
run in order while the state persists in scratch; different bh are
independent (state re-init at c == 0).

VMEM at defaults (C=256, D=256, dv=128, f32): q/k tiles 256KiB each, v 128KiB,
state 128KiB + z 1KiB, A 256KiB → ≈ 1 MiB, well within budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import canon_precision, mp_project, mp_trig

__all__ = [
    "rff_attention_kernel",
    "rff_attention_pallas",
    "rff_attention_decode_block_kernel",
    "rff_attention_decode_block_pallas",
]


def rff_attention_kernel(
    q_ref, k_ref, v_ref, o_ref, s_ref, z_ref, *, normalize: bool, eps: float
):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    q = q_ref[0].astype(jnp.float32)  # (C, D)
    k = k_ref[0].astype(jnp.float32)  # (C, D)
    v = v_ref[0].astype(jnp.float32)  # (C, dv)

    cs = q.shape[0]
    # Causal mask including the diagonal (token attends to itself).
    row = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 1)
    mask = (row >= col).astype(jnp.float32)

    a = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * mask  # (C, C)
    out = jnp.dot(a, v, preferred_element_type=jnp.float32)  # intra
    out += jnp.dot(q, s_ref[...], preferred_element_type=jnp.float32)  # inter

    if normalize:
        denom = jnp.sum(a, axis=-1) + jnp.dot(
            q, z_ref[...][0], preferred_element_type=jnp.float32
        )
        out = out / (denom + eps)[:, None]

    o_ref[0] = out.astype(o_ref.dtype)

    # State update AFTER emitting this chunk's outputs.
    s_ref[...] += jnp.dot(k.T, v, preferred_element_type=jnp.float32)
    z_ref[...] += jnp.sum(k, axis=0)[None, :]


@functools.partial(
    jax.jit,
    static_argnames=("chunk", "normalize", "eps", "interpret"),
)
def rff_attention_pallas(
    phi_q: jax.Array,
    phi_k: jax.Array,
    v: jax.Array,
    *,
    chunk: int = 256,
    normalize: bool = True,
    eps: float = 1e-6,
    interpret: bool = False,
) -> jax.Array:
    """Causal linear attention.

    Args:
      phi_q, phi_k: ``(BH, S, D)`` feature-mapped queries/keys (non-negative
        when ``normalize=True`` — use positive random features).
      v: ``(BH, S, dv)`` values.

    Returns:
      ``(BH, S, dv)`` attention outputs.
    """
    bh, s, d = phi_q.shape
    dv = v.shape[-1]
    c = min(chunk, s)
    assert s % c == 0, f"seq {s} must be divisible by chunk {c}"
    grid = (bh, s // c)
    return pl.pallas_call(
        functools.partial(rff_attention_kernel, normalize=normalize, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, c, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, c, dv), lambda b, i: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, dv), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dv), phi_q.dtype),
        scratch_shapes=[
            pltpu.VMEM((d, dv), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(phi_q, phi_k, v)


# ---------------------------------------------------------------------------
# Fused decode-block kernel: T decode ticks per launch, state VMEM-resident.
#
# Per-token decode (ops.rff_attention_decode) pays one XLA launch AND one
# HBM round-trip of the whole (D, dv) state per token. This kernel is the
# predict kernel's theta-residency trick applied to attention state: a
# (BH, T, dh) block of PRE-PROJECTED q/k/v tokens enters, the per-head
# S (D, dv) / z (D,) state is read into VMEM once, all T strictly
# sequential ticks run against the resident copy, and the state is written
# back once — T ticks cost one launch and one state read/write instead
# of T.
#
# The feature map is fused too (the featurize GEMM the per-token path
# materialized in HBM): one (T, dh) @ (dh, D) MXU GEMM per block, in
# either the canonical affine-trig form (any as_trig family: rff/orf/
# qmc/gq) or the positive-random-feature (softmax-kernel) form, under the
# read-path precision contract of kernels/ref.py (bf16 GEMM operands, f32
# accumulation, f32 state — state never drops precision).
#
# Grid: (BH,) — one program per head; the T ticks are a fori_loop carrying
# (S, z) as values, so the state never leaves VMEM/registers mid-block.
# kernels.chunking.default_decode_block_t budgets T by charging the
# resident (D, dv) state + (dh, D) W tiles against VMEM.
# ---------------------------------------------------------------------------


def rff_attention_decode_block_kernel(
    q_ref,
    k_ref,
    v_ref,
    w_ref,
    b_ref,
    sc_ref,
    s_in_ref,
    z_in_ref,
    o_ref,
    s_out_ref,
    z_out_ref,
    *,
    tlen: int,
    dfeat: int,
    feature_kind: str,
    normalize: bool,
    eps: float,
    precision,
):
    q = q_ref[0].astype(jnp.float32)  # (Tp, dhp)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)  # (Tp, dvp)
    w = w_ref[...].astype(jnp.float32)  # (dhp, Dp)
    bias = b_ref[...].astype(jnp.float32)  # (1, Dp)
    sc = sc_ref[...].astype(jnp.float32)  # (1, Dp); padded columns are 0

    # Featurize the WHOLE block in one MXU GEMM per q/k — exactly
    # ref.decode_features_ref, inlined so padded-D handling stays in-kernel.
    def feat(x):
        proj = mp_project(x, w, precision)
        if feature_kind == "trig":
            phi = mp_trig(proj, bias, sc, precision)
        else:  # prf: sc is a 0/1 mask killing padded-D columns
            stab = proj - jnp.sum(jnp.square(x), axis=-1, keepdims=True) / 2.0
            phi = sc * (
                jnp.exp(stab) / jnp.sqrt(jnp.float32(dfeat)) + 1e-6
            )
            if canon_precision(precision) == "bf16":
                phi = phi.astype(jnp.bfloat16)
        return phi.astype(jnp.float32)

    phi_q = feat(q)  # (Tp, Dp)
    phi_k = feat(k)

    o_ref[...] = jnp.zeros_like(o_ref)

    def tick(i, carry):
        s_st, z_st = carry  # (Dp, dvp) f32, (1, Dp) f32
        qt = jax.lax.dynamic_slice_in_dim(phi_q, i, 1, axis=0)  # (1, Dp)
        kt = jax.lax.dynamic_slice_in_dim(phi_k, i, 1, axis=0)
        vt = jax.lax.dynamic_slice_in_dim(v, i, 1, axis=0)  # (1, dvp)
        # Update BEFORE emitting — the token attends to itself (the
        # ops.rff_attention_decode contract).
        s_st = s_st + kt.T * vt  # rank-1, same elementwise order as oracle
        z_st = z_st + kt
        num = jnp.dot(qt, s_st, preferred_element_type=jnp.float32)
        if normalize:
            den = jnp.sum(qt * z_st, axis=-1) + eps
            num = num / den[:, None]
        o_ref[0, pl.ds(i, 1), :] = num.astype(o_ref.dtype)
        return s_st, z_st

    s_f, z_f = jax.lax.fori_loop(
        0,
        tlen,
        tick,
        (s_in_ref[0].astype(jnp.float32), z_in_ref[...].astype(jnp.float32)),
    )
    s_out_ref[0] = s_f
    z_out_ref[...] = z_f


@functools.partial(
    jax.jit,
    static_argnames=(
        "feature_kind", "normalize", "eps", "precision", "interpret",
    ),
)
def rff_attention_decode_block_pallas(
    s_state: jax.Array,
    z_state: jax.Array,
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    b: jax.Array,
    s: jax.Array | None = None,
    *,
    feature_kind: str = "prf",
    normalize: bool = True,
    eps: float = 1e-6,
    precision: str | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """T decode ticks per launch with the (D, dv) state VMEM-resident.

    Args:
      s_state: ``(BH, D, dv)`` f32 running sum of phi(k) v^T.
      z_state: ``(BH, D)`` f32 running sum of phi(k).
      q, k: ``(BH, T, dh)`` pre-projected (RoPE'd, pre-scaled) tokens.
      v: ``(BH, T, dv)`` values.
      w: ``(dh, D)`` shared spectral matrix, b: ``(D,)`` phases.
      s: ``(D,)`` per-feature scales (trig) / column mask (prf); None =
        ``ref.default_decode_scale``.
      feature_kind: "trig" (affine-trig canonical form) or "prf".
      precision: None/"f32" or "bf16" per the kernels/ref.py contract.

    Returns:
      (outputs ``(BH, T, dv)`` f32, new_s ``(BH, D, dv)``, new_z
      ``(BH, D)``).

    Padding is exact: dh zero-pads (adds 0 to projections and ``||x||^2``),
    padded D columns carry scale/mask 0 so features are exactly 0 there,
    padded T rows are never ticked (the fori_loop stops at the real T),
    padded dv columns are sliced off.
    """
    from repro.kernels.ref import default_decode_scale
    from repro.kernels.rff_features import _ceil_to, _pad2

    precision = canon_precision(precision)
    bh, tlen, dh = q.shape
    dv = v.shape[-1]
    dfeat = w.shape[-1]
    assert s_state.shape == (bh, dfeat, dv)
    assert z_state.shape == (bh, dfeat)
    assert w.shape == (dh, dfeat) and b.shape == (dfeat,)
    if s is None:
        s = default_decode_scale(dfeat, feature_kind)
    assert s.shape == (dfeat,)

    tp = _ceil_to(tlen, 8)
    dhp, dp, dvp = _ceil_to(dh, 128), _ceil_to(dfeat, 128), _ceil_to(dv, 128)

    q_p = jnp.pad(q, ((0, 0), (0, tp - tlen), (0, dhp - dh)))
    k_p = jnp.pad(k, ((0, 0), (0, tp - tlen), (0, dhp - dh)))
    v_p = jnp.pad(v, ((0, 0), (0, tp - tlen), (0, dvp - dv)))
    w_p = _pad2(w, dhp, dp)
    b_p = jnp.pad(b, (0, dp - dfeat))[None, :]  # (1, Dp)
    s_p = jnp.pad(s, (0, dp - dfeat))[None, :]  # (1, Dp), padded scales 0
    sm_p = jnp.pad(
        s_state.astype(jnp.float32),
        ((0, 0), (0, dp - dfeat), (0, dvp - dv)),
    )
    zv_p = jnp.pad(z_state.astype(jnp.float32), ((0, 0), (0, dp - dfeat)))

    out, s_new, z_new = pl.pallas_call(
        functools.partial(
            rff_attention_decode_block_kernel,
            tlen=tlen,
            dfeat=dfeat,
            feature_kind=feature_kind,
            normalize=normalize,
            eps=eps,
            precision=precision,
        ),
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, tp, dhp), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, tp, dhp), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, tp, dvp), lambda i: (i, 0, 0)),
            pl.BlockSpec((dhp, dp), lambda i: (0, 0)),  # grid-invariant W
            pl.BlockSpec((1, dp), lambda i: (0, 0)),
            pl.BlockSpec((1, dp), lambda i: (0, 0)),
            pl.BlockSpec((1, dp, dvp), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, dp), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tp, dvp), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, dp, dvp), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, dp), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, tp, dvp), jnp.float32),
            jax.ShapeDtypeStruct((bh, dp, dvp), jnp.float32),
            jax.ShapeDtypeStruct((bh, dp), jnp.float32),
        ],
        interpret=interpret,
    )(q_p, k_p, v_p, w_p, b_p, s_p, sm_p, zv_p)
    return (
        out[:, :tlen, :dv],
        s_new[:, :dfeat, :dv],
        z_new[:, :dfeat],
    )
