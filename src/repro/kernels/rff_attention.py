"""Pallas TPU kernel: chunked causal linear attention over random features.

This is the paper's fixed-size-state insight applied to attention (DESIGN.md
§2): with kernelized attention weights ``kappa(q_t, k_s) ~= phi(q_t)^T
phi(k_s)``, the causal attention output

    o_t = sum_{s<=t} phi(q_t)^T phi(k_s) v_s   /   sum_{s<=t} phi(q_t)^T phi(k_s)

is computable from a *fixed-size* running state ``S_t = sum phi(k_s) v_s^T in
R^{D x dv}`` and ``z_t = sum phi(k_s) in R^D`` — the exact analogue of
RFFKLMS's theta replacing the growing dictionary (here: the growing KV cache).

TPU adaptation — *chunkwise-parallel* form, not a per-token scan:
  * sequence is split into chunks of C tokens;
  * intra-chunk term: ``(Q K^T ∘ causal_mask) V`` — three MXU GEMMs;
  * inter-chunk term: ``Q @ S_prev`` — one MXU GEMM against the carried state;
  * the state lives in VMEM scratch and carries across the (sequential) minor
    grid dimension; each (batch*head) slice re-initializes it at chunk 0.

Grid: ``(BH, S/C)`` — minor dim is the chunk index, so for each bh the chunks
run in order while the state persists in scratch; different bh are
independent (state re-init at c == 0).

VMEM at defaults (C=256, D=256, dv=128, f32): q/k tiles 256KiB each, v 128KiB,
state 128KiB + z 1KiB, A 256KiB → ≈ 1 MiB, well within budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rff_attention_kernel", "rff_attention_pallas"]


def rff_attention_kernel(
    q_ref, k_ref, v_ref, o_ref, s_ref, z_ref, *, normalize: bool, eps: float
):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    q = q_ref[0].astype(jnp.float32)  # (C, D)
    k = k_ref[0].astype(jnp.float32)  # (C, D)
    v = v_ref[0].astype(jnp.float32)  # (C, dv)

    cs = q.shape[0]
    # Causal mask including the diagonal (token attends to itself).
    row = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 1)
    mask = (row >= col).astype(jnp.float32)

    a = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * mask  # (C, C)
    out = jnp.dot(a, v, preferred_element_type=jnp.float32)  # intra
    out += jnp.dot(q, s_ref[...], preferred_element_type=jnp.float32)  # inter

    if normalize:
        denom = jnp.sum(a, axis=-1) + jnp.dot(
            q, z_ref[...][0], preferred_element_type=jnp.float32
        )
        out = out / (denom + eps)[:, None]

    o_ref[0] = out.astype(o_ref.dtype)

    # State update AFTER emitting this chunk's outputs.
    s_ref[...] += jnp.dot(k.T, v, preferred_element_type=jnp.float32)
    z_ref[...] += jnp.sum(k, axis=0)[None, :]


@functools.partial(
    jax.jit,
    static_argnames=("chunk", "normalize", "eps", "interpret"),
)
def rff_attention_pallas(
    phi_q: jax.Array,
    phi_k: jax.Array,
    v: jax.Array,
    *,
    chunk: int = 256,
    normalize: bool = True,
    eps: float = 1e-6,
    interpret: bool = False,
) -> jax.Array:
    """Causal linear attention.

    Args:
      phi_q, phi_k: ``(BH, S, D)`` feature-mapped queries/keys (non-negative
        when ``normalize=True`` — use positive random features).
      v: ``(BH, S, dv)`` values.

    Returns:
      ``(BH, S, dv)`` attention outputs.
    """
    bh, s, d = phi_q.shape
    dv = v.shape[-1]
    c = min(chunk, s)
    assert s % c == 0, f"seq {s} must be divisible by chunk {c}"
    grid = (bh, s // c)
    return pl.pallas_call(
        functools.partial(rff_attention_kernel, normalize=normalize, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, c, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, c, dv), lambda b, i: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, dv), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dv), phi_q.dtype),
        scratch_shapes=[
            pltpu.VMEM((d, dv), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(phi_q, phi_k, v)
