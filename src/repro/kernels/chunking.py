"""Time-axis chunking utilities shared by every chunked run-loop.

One place for the pad-to-multiple / reshape-into-blocks / masked-remainder
bookkeeping so the kernel dispatchers (kernels/ops.py), the single-stream
drivers (core/klms.py, core/krls.py) and the sharded combine_every driver
(core/krls.py) can't drift apart on remainder handling.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["num_chunks", "time_blocks", "valid_time_mask", "unblock_time"]


def num_chunks(n: int, chunk: int) -> int:
    """ceil(n / chunk) — the scan length after chunking."""
    return -(-n // chunk)


def time_blocks(a: jax.Array, chunk: int, axis: int = 0) -> jax.Array:
    """Zero-pad ``axis`` to a multiple of ``chunk`` and split it into a
    leading scan axis: ``(..., n, ...) -> (nc, ..., chunk, ...)``."""
    n = a.shape[axis]
    nc = num_chunks(n, chunk)
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, nc * chunk - n)
    ap = jnp.pad(a, widths)
    ap = ap.reshape(a.shape[:axis] + (nc, chunk) + a.shape[axis + 1 :])
    return jnp.moveaxis(ap, axis, 0)


def valid_time_mask(n: int, chunk: int, dtype=jnp.float32) -> jax.Array:
    """``(nc, chunk)`` gate: 1 for real ticks, 0 for the padded tail."""
    nc = num_chunks(n, chunk)
    return jnp.pad(jnp.ones((n,), dtype), (0, nc * chunk - n)).reshape(
        nc, chunk,
    )


def unblock_time(a: jax.Array, n: int, axis: int = 0) -> jax.Array:
    """Inverse of :func:`time_blocks` on stacked scan outputs:
    ``(nc, ..., chunk, ...) -> (..., n, ...)`` with the padding sliced off."""
    a = jnp.moveaxis(a, 0, axis)  # (..., nc, chunk, ...)
    a = a.reshape(a.shape[:axis] + (-1,) + a.shape[axis + 2 :])
    return jax.lax.slice_in_dim(a, 0, n, axis=axis)
