"""Time-axis chunking utilities shared by every chunked run-loop.

One place for the pad-to-multiple / reshape-into-blocks / masked-remainder
bookkeeping so the kernel dispatchers (kernels/ops.py), the single-stream
drivers (core/klms.py, core/krls.py) and the sharded combine_every driver
(core/krls.py) can't drift apart on remainder handling.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "num_chunks",
    "time_blocks",
    "valid_time_mask",
    "unblock_time",
    "default_chunk_t",
    "default_decode_block_t",
]

# Conservative per-launch working-set budget for the chunked kernels: half
# of a ~16 MiB/core VMEM, leaving the other half for double-buffering and
# the per-tick stream tiles the pipeline keeps in flight.
DEFAULT_VMEM_BUDGET = 8 * 2**20

# Bank-axis block the chunk kernels tile with (rff_klms_step.py block_b
# default; the KRLS chunk kernel owns one (D, D) P tile at a time).
_BLOCK_B = 8
_LANES = 128


def default_chunk_t(
    bank: int,
    dfeat: int,
    dtype=jnp.float32,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    pmat: bool = False,
    input_dim: int | None = None,
    elements: bool = False,
) -> int:
    """VMEM-budget-aware default tick count T for one chunked launch.

    The chunk kernels keep the state resident per bank block (theta
    ``(block_b, D)``; plus one ``(D, D)`` P tile for KRLS) alongside the
    grid-invariant ``W`` tile, and stream one ``(block_b, lanes)`` input
    tile plus a handful of per-tick scalars per tick. T is the largest
    power of two whose streamed ticks fit in the budget left over after
    the resident tiles — i.e. "as many ticks per launch as VMEM lets the
    pipeline keep in flight", clamped to [8, 512]. When the resident state
    alone busts the budget (huge-D KRLS) the floor of 8 still amortizes
    dispatch without asking VMEM for more than the per-tick kernel already
    does.

    ``bank`` only matters below the bank-block width (a 2-tenant bank
    streams 2-row tiles); ``dtype`` is the *stream* dtype — state scratch
    is always f32 in the kernels. ``input_dim`` is the true input d; the
    W tile and per-tick x tile are charged at its lane-padded width
    (default: one 128-lane tile — the low-d serving shapes).

    ``elements=True`` sizes for the replay chunk-element kernels
    (kernels/rff_scan.py): their resident accumulator is a full ``(D, D)``
    element tile and the per-chunk ``(D, D)`` output block must
    double-buffer against the next chunk's writeback — both charged here
    so large-D replays don't bust the budget the way a theta-only charge
    would suggest they could afford.
    """
    item = jnp.dtype(dtype).itemsize
    bb = max(1, min(_BLOCK_B, bank))
    dpad = -(-dfeat // _LANES) * _LANES
    din = _LANES if input_dim is None else -(-input_dim // _LANES) * _LANES
    state_bytes = bb * dpad * 4 + (dpad * dpad * 4 if pmat else 0)
    if elements:
        # Resident (D, D) element accumulator + double-buffered (D, D)
        # element output tile.
        state_bytes += 2 * dpad * dpad * 4
    w_bytes = din * dpad * 4  # the grid-invariant (d, D) tile, lane-padded
    # Per tick: one (bb, din) x tile + y/mu/mask in, pred/err out.
    stream_bytes = bb * (din + 4) * item
    spare = vmem_budget - state_bytes - w_bytes
    if spare < 8 * stream_bytes:
        return 8
    t = 1 << ((spare // stream_bytes).bit_length() - 1)  # floor pow2
    return int(min(512, t))


def default_decode_block_t(
    dfeat: int,
    dv: int,
    head_dim: int,
    dtype=jnp.float32,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
) -> int:
    """VMEM-budget-aware default T for one fused decode-block launch.

    The decode-block attention kernel (kernels/rff_attention.py) owns one
    head per grid step: the ``(D, dv)`` S tile, the ``(D,)`` z row and the
    grid-invariant ``(dh, D)`` W tile are resident for the whole block
    (that residency IS the win — one state read/write per T ticks), and
    each token streams two ``(dh,)`` q/k rows, a ``(dv,)`` v row, a
    ``(dv,)`` output row and two ``(D,)`` feature rows. T is the largest
    power of two whose streamed tokens fit the budget left after the
    resident tiles, clamped to [8, 512] exactly like
    :func:`default_chunk_t`. ``dtype`` is the *stream* dtype (bf16 halves
    the feature-row charge under the read-path precision contract); state
    is always charged at f32.
    """
    item = jnp.dtype(dtype).itemsize
    dp = -(-dfeat // _LANES) * _LANES
    dhp = -(-head_dim // _LANES) * _LANES
    dvp = -(-dv // _LANES) * _LANES
    state_bytes = dp * dvp * 4 + dp * 4  # resident S tile + z row, f32
    w_bytes = dhp * dp * 4  # grid-invariant W tile
    # Per token: q/k rows, v + output rows, phi_q/phi_k feature rows.
    stream_bytes = (2 * dhp + 2 * dvp) * item + 2 * dp * item
    spare = vmem_budget - state_bytes - w_bytes
    if spare < 8 * stream_bytes:
        return 8
    t = 1 << ((spare // stream_bytes).bit_length() - 1)  # floor pow2
    return int(min(512, t))


def num_chunks(n: int, chunk: int) -> int:
    """ceil(n / chunk) — the scan length after chunking."""
    return -(-n // chunk)


def time_blocks(a: jax.Array, chunk: int, axis: int = 0) -> jax.Array:
    """Zero-pad ``axis`` to a multiple of ``chunk`` and split it into a
    leading scan axis: ``(..., n, ...) -> (nc, ..., chunk, ...)``."""
    n = a.shape[axis]
    nc = num_chunks(n, chunk)
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, nc * chunk - n)
    ap = jnp.pad(a, widths)
    ap = ap.reshape(a.shape[:axis] + (nc, chunk) + a.shape[axis + 1 :])
    return jnp.moveaxis(ap, axis, 0)


def valid_time_mask(n: int, chunk: int, dtype=jnp.float32) -> jax.Array:
    """``(nc, chunk)`` gate: 1 for real ticks, 0 for the padded tail."""
    nc = num_chunks(n, chunk)
    return jnp.pad(jnp.ones((n,), dtype), (0, nc * chunk - n)).reshape(
        nc, chunk,
    )


def unblock_time(a: jax.Array, n: int, axis: int = 0) -> jax.Array:
    """Inverse of :func:`time_blocks` on stacked scan outputs:
    ``(nc, ..., chunk, ...) -> (..., n, ...)`` with the padding sliced off."""
    a = jnp.moveaxis(a, 0, axis)  # (..., nc, chunk, ...)
    a = a.reshape(a.shape[:axis] + (-1,) + a.shape[axis + 2 :])
    return jax.lax.slice_in_dim(a, 0, n, axis=axis)
