"""Pure-jnp oracles for every Pallas kernel (ground truth for allclose tests).

Deliberately naive implementations — clarity over speed.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "canon_precision",
    "mp_project",
    "mp_trig",
    "rff_features_ref",
    "klms_tick_math",
    "krls_tick_math",
    "rff_klms_bank_step_ref",
    "rff_klms_bank_chunk_ref",
    "rff_bank_predict_ref",
    "rff_krls_bank_step_ref",
    "rff_krls_bank_chunk_ref",
    "klms_chunk_elements_ref",
    "krls_chunk_elements_ref",
    "decode_features_ref",
    "default_decode_scale",
    "rff_attention_ref",
    "rff_attention_state_ref",
    "rff_attention_decode_block_ref",
    "flash_attention_ref",
]

# The read-path precision contract (ONE definition, shared by the oracles
# here and the Pallas kernels, so they can never drift):
#
#   precision=None / "f32"  — the GEMM runs in f32 (bitwise-unchanged
#     legacy behavior for f32 inputs).
#   precision="bf16"        — the featurize GEMM inputs are cast to bf16
#     and accumulated in f32 (one MXU pass at half the input bandwidth);
#     the bias-add / cos / scale run in f32 on the f32 accumulator; the
#     feature block is then *stored* in bf16 (halving activation bytes).
#     Every downstream reduction against theta accumulates in f32.
#
# Training state is never touched by this knob: KRLS ``P`` and both
# families' theta stay f32 — only the read path and feature maps drop
# precision (the ISSUE-5 contract; tolerance per family is pinned in
# tests/test_read_path.py).
_BF16 = ("bf16", "bfloat16")
_F32 = (None, "f32", "float32")


def canon_precision(precision):
    """Validate + canonicalize the knob: ``"bf16"`` or ``None`` (f32).

    Every read-path entry point (ops dispatchers, Pallas wrappers, the
    generic bank fallback) funnels through this, so a typo'd precision
    string raises identically on every backend instead of silently running
    f32 on one of them.
    """
    if precision in _BF16:
        return "bf16"
    if precision in _F32:
        return None
    raise ValueError(f"unknown precision {precision!r}; use None/'f32'/'bf16'")


def mp_project(x, w, precision=None):
    """``x @ w`` under the read-path precision contract (f32 accumulate)."""
    if canon_precision(precision) == "bf16":
        return jnp.dot(
            x.astype(jnp.bfloat16),
            w.astype(jnp.bfloat16),
            preferred_element_type=jnp.float32,
        )
    return x @ w


def mp_trig(proj, b, s, precision=None):
    """bias-add + cos + per-feature scale; bf16 storage when asked."""
    z = s * jnp.cos(proj + b)
    if precision in _BF16:
        return z.astype(jnp.bfloat16)
    return z


def rff_features_ref(x, w, b, s=None, precision=None):
    """``s * cos(x @ w + b)`` — oracle for kernels/rff_features.py.

    ``s`` is the per-feature scale row of the canonical affine-trig form
    (repro.features); None means the Monte-Carlo ``sqrt(2/D)``.
    ``precision`` follows the module-level read-path contract (bf16 GEMM +
    f32 accumulation + bf16 feature storage); the default is bitwise the
    legacy f32 path.
    """
    if s is None:
        d = w.shape[1]
        s = jnp.sqrt(2.0 / d).astype(x.dtype)
    else:
        s = s.astype(x.dtype)
    if precision in _F32:
        return s * jnp.cos(x @ w + b)
    return mp_trig(mp_project(x, w, precision), b, s, precision)


def klms_tick_math(theta, z, y, mu_b, gate=None):
    """ONE KLMS bank tick given a precomputed feature block ``z (B, D)``.

    The single source of truth for the update math: the fused-kernel
    oracles below AND the generic (featurize-based) bank fallback in
    core/bank.py both delegate here, so the non-trig path can never
    silently diverge from the oracle. ``gate`` optionally masks the state
    update (masked ticks still emit their prior prediction/error); with
    gate==1 the expression multiplies by exactly 1.0, preserving the
    chunk-vs-tick bitwise contract.
    """
    pred = jnp.sum(theta * z, axis=-1)
    err = y - pred
    upd = err if gate is None else gate * err
    return theta + (mu_b * upd)[:, None] * z, pred, err


def krls_tick_math(theta, pmat, z, y, beta_b):
    """ONE EW-RLS bank tick (incl. the symmetrization pass) given ``z``.

    Shared by the fused-kernel oracles and core/bank.py's generic fallback
    — exactly ``core.krls.rls_step`` vmapped over the bank.
    """
    pred = jnp.sum(theta * z, axis=-1)
    err = y - pred
    pz = jnp.einsum("bij,bj->bi", pmat, z)  # (B, D)
    denom = beta_b + jnp.sum(z * pz, axis=-1)
    gain = pz / denom[:, None]
    theta_new = theta + gain * err[:, None]
    pmat_new = (
        pmat - gain[:, :, None] * pz[:, None, :]
    ) / beta_b[:, None, None]
    pmat_new = 0.5 * (pmat_new + jnp.swapaxes(pmat_new, -1, -2))
    return theta_new, pmat_new, pred, err


def rff_klms_bank_step_ref(theta, x, y, w, b, mu, s=None):
    """Two-pass fused-KLMS-step oracle — for kernels/rff_klms_step.py.

    theta (B, D), x (B, d), y (B,), mu scalar or (B,), s optional (D,)
    per-feature scales. Materializes the feature block z (the HBM
    round-trip the fused kernel removes).
    """
    z = rff_features_ref(x, w, b, s)  # (B, D)
    mu_b = jnp.broadcast_to(jnp.asarray(mu, theta.dtype), y.shape)
    return klms_tick_math(theta, z, y, mu_b)


def rff_klms_bank_chunk_ref(theta, xs, ys, w, b, mu, mask=None, s=None):
    """T-chunked KLMS oracle — for ``rff_klms_bank_chunk_pallas``.

    A ``lax.scan`` of the per-tick recursion over the chunk's time axis:
    theta (B, D), xs (B, T, d), ys (B, T), mask (B, T) validity gate
    (1 = apply the update; masked ticks still emit their prior prediction).
    With mask==1 every tick multiplies by exactly 1.0, so an unmasked chunk
    is bitwise identical to T per-tick ``rff_klms_bank_step_ref`` calls.
    """
    import jax

    if mask is None:
        mask = jnp.ones(ys.shape, theta.dtype)
    mu_b = jnp.broadcast_to(jnp.asarray(mu, theta.dtype), ys.shape[:1])

    def tick(th, xym):
        x_t, y_t, m_t = xym
        z = rff_features_ref(x_t, w, b, s)  # (B, D)
        th, pred, err = klms_tick_math(th, z, y_t, mu_b, gate=m_t)
        return th, (pred, err)

    xs_t = jnp.swapaxes(xs, 0, 1)  # (T, B, d) time-major
    ys_t = jnp.swapaxes(ys, 0, 1)
    mask_t = jnp.swapaxes(mask.astype(theta.dtype), 0, 1)
    theta, (preds, errs) = jax.lax.scan(tick, theta, (xs_t, ys_t, mask_t))
    return theta, jnp.swapaxes(preds, 0, 1), jnp.swapaxes(errs, 0, 1)


def rff_bank_predict_ref(theta, xq, w, b, s=None, precision=None):
    """Predict-only bank oracle — for kernels/rff_predict.py.

    The read path of the paper's fixed-cost claim: a query block of Q
    inputs per tenant is one featurize GEMM plus one f32 reduction against
    the tenant's theta — no state is touched. theta (B, D), xq (B, Q, d),
    shared w (d, D) / b (D,), s optional (D,) per-feature scales,
    ``precision`` per the module-level read-path contract. Returns
    predictions (B, Q).

    Numerically this is ``vmap over tenants of vmap over queries of
    ``featurize(x) . theta`` — the `core.bank.bank_predict` adapter — with
    the per-query matvecs batched into one GEMM.
    """
    z = rff_features_ref(xq, w, b, s, precision)  # (B, Q, D)
    pred = jnp.sum(
        theta[:, None, :].astype(jnp.float32) * z.astype(jnp.float32),
        axis=-1,
    )
    return pred.astype(theta.dtype)


def rff_krls_bank_step_ref(theta, pmat, x, y, w, b, beta, s=None):
    """Two-pass fused-KRLS-step oracle — for kernels/rff_krls_step.py.

    Exactly the EW-RLS recursion of ``core.krls.rls_step`` (including the
    symmetrization pass) vmapped over the bank: theta (B, D),
    pmat (B, D, D), x (B, d), y (B,), beta scalar or (B,) per-tenant
    forgetting factors, s optional (D,) per-feature scales. Materializes z
    and pz in HBM (the round-trips the fused kernel removes).
    """
    z = rff_features_ref(x, w, b, s)  # (B, D)
    beta_b = jnp.broadcast_to(jnp.asarray(beta, theta.dtype), y.shape)
    return krls_tick_math(theta, pmat, z, y, beta_b)


def rff_krls_bank_chunk_ref(theta, pmat, xs, ys, w, b, beta, mask=None, s=None):
    """T-chunked EW-RLS oracle — for ``rff_krls_bank_chunk_pallas``.

    ``lax.scan`` of :func:`rff_krls_bank_step_ref` over the chunk's time
    axis with a per-(tenant, tick) validity gate: masked ticks emit their
    prior prediction but select the untouched theta/P (``jnp.where``), so
    an unmasked chunk is bitwise T per-tick steps.
    """
    import jax

    if mask is None:
        mask = jnp.ones(ys.shape, theta.dtype)

    def tick(carry, xym):
        th, pm = carry
        x_t, y_t, m_t = xym
        th2, pm2, pred, err = rff_krls_bank_step_ref(
            th, pm, x_t, y_t, w, b, beta, s
        )
        th = jnp.where(m_t[:, None] > 0, th2, th)
        pm = jnp.where(m_t[:, None, None] > 0, pm2, pm)
        return (th, pm), (pred, err)

    xs_t = jnp.swapaxes(xs, 0, 1)  # (T, B, d) time-major
    ys_t = jnp.swapaxes(ys, 0, 1)
    mask_t = jnp.swapaxes(mask.astype(theta.dtype), 0, 1)
    (theta, pmat), (preds, errs) = jax.lax.scan(
        tick, (theta, pmat), (xs_t, ys_t, mask_t)
    )
    return theta, pmat, jnp.swapaxes(preds, 0, 1), jnp.swapaxes(errs, 0, 1)


def klms_chunk_elements_ref(
    xs, ys, w, b, mu, mask=None, s=None, normalized=False, eps=1e-6
):
    """Per-chunk composed KLMS affine elements — oracle for
    kernels/rff_scan.py's ``rff_klms_chunk_elements_pallas``.

    xs (nc, Tc, d), ys (nc, Tc), mask optional (nc, Tc), mu scalar. Each
    chunk's Tc ticks fold into ONE ``theta -> a theta + v`` map via the
    same rank-1 recursion the kernel runs on its resident tile:

        row = z A;  A <- A - mu_eff z row^T;  v <- v - mu_eff ((z.v) - y) z

    Masked ticks have ``mu_eff = 0`` and compose the identity. Returns
    ``(a (nc, D, D), v (nc, D))`` f32.
    """
    import jax

    if mask is None:
        mask = jnp.ones(ys.shape, jnp.float32)
    dfeat = w.shape[-1]

    def per_chunk(xc, yc, mc):
        zc = rff_features_ref(xc, w, b, s).astype(jnp.float32)  # (Tc, D)

        def tick(carry, zym):
            a, v = carry
            z, y, m = zym
            mu_t = mu / (eps + z @ z) if normalized else mu
            mu_eff = m * mu_t
            row = z @ a  # (D,)
            a = a - mu_eff * jnp.outer(z, row)
            v = v - mu_eff * ((z @ v) - y) * z
            return (a, v), None

        init = (
            jnp.eye(dfeat, dtype=jnp.float32),
            jnp.zeros((dfeat,), jnp.float32),
        )
        (a, v), _ = jax.lax.scan(
            tick, init, (zc, yc.astype(jnp.float32), mc)
        )
        return a, v

    return jax.vmap(per_chunk)(xs, ys, mask.astype(jnp.float32))


def krls_chunk_elements_ref(xs, ys, w, b, beta, mask=None, s=None):
    """Per-chunk composed KRLS decay elements — oracle for
    kernels/rff_scan.py's ``rff_krls_chunk_elements_pallas``.

    xs (nc, Tc, d), ys (nc, Tc), mask optional (nc, Tc), beta scalar. Each
    chunk folds its ticks into the information-form accumulator

        g <- beta g;  Phi <- beta Phi + z z^T;  r <- beta r + y z

    with masked ticks composing the identity ``(1, 0, 0)``. Returns
    ``(g (nc,), phi (nc, D, D), r (nc, D))`` f32.
    """
    import jax

    if mask is None:
        mask = jnp.ones(ys.shape, jnp.float32)
    dfeat = w.shape[-1]

    def per_chunk(xc, yc, mc):
        zc = rff_features_ref(xc, w, b, s).astype(jnp.float32)  # (Tc, D)

        def tick(carry, zym):
            g, phi, r = carry
            z, y, m = zym
            beta_eff = jnp.where(m > 0, jnp.float32(beta), 1.0)
            g = g * beta_eff
            phi = beta_eff * phi + m * jnp.outer(z, z)
            r = beta_eff * r + (m * y) * z
            return (g, phi, r), None

        init = (
            jnp.ones((), jnp.float32),
            jnp.zeros((dfeat, dfeat), jnp.float32),
            jnp.zeros((dfeat,), jnp.float32),
        )
        (g, phi, r), _ = jax.lax.scan(
            tick, init, (zc, yc.astype(jnp.float32), mc)
        )
        return g, phi, r

    return jax.vmap(per_chunk)(xs, ys, mask.astype(jnp.float32))


def decode_features_ref(
    x, w, b, s, feature_kind="trig", precision=None, prf_eps=1e-6
):
    """Attention-path feature map under the read-path precision contract.

    The ONE definition of how the decode kernel featurizes a block of
    pre-projected tokens ``x (..., dh)`` against the shared spectral matrix
    ``w (dh, D)`` — shared by :func:`rff_attention_decode_block_ref` and the
    Pallas decode-block kernel so they can never drift:

    * ``feature_kind="trig"`` — the canonical affine-trig form
      ``s * cos(x @ w + b)`` every ``as_trig``-canonicalizable family
      (rff/orf/qmc/gq) lowers to; runs through :func:`mp_project` /
      :func:`mp_trig`.
    * ``feature_kind="prf"`` — positive random features of the softmax
      kernel, ``s * (exp(x @ w - ||x||^2/2) / sqrt(D) + prf_eps)`` with
      ``b`` unused (PRF has no phase). ``s`` here is a 0/1 column mask
      (1 everywhere unpadded) so zero-padded D columns are exactly 0 —
      exp of a padded column is NOT 0 and would poison the normalizer.

    ``precision`` follows the module-level contract: bf16 GEMM operands,
    f32 accumulation, bf16 feature storage.
    """
    x32 = x.astype(jnp.float32)
    w32 = w.astype(jnp.float32)
    s32 = s.astype(jnp.float32)
    proj = mp_project(x32, w32, precision)
    if feature_kind == "trig":
        return mp_trig(proj, b.astype(jnp.float32), s32, precision)
    if feature_kind != "prf":
        raise ValueError(f"unknown feature_kind {feature_kind!r}")
    d = w.shape[-1]
    stab = proj - jnp.sum(jnp.square(x32), axis=-1, keepdims=True) / 2.0
    phi = s32 * (jnp.exp(stab) / jnp.sqrt(jnp.float32(d)) + prf_eps)
    if canon_precision(precision) == "bf16":
        return phi.astype(jnp.bfloat16)
    return phi


def default_decode_scale(dfeat, feature_kind="trig"):
    """Default per-feature scale row for the decode path.

    Trig: the Monte-Carlo ``sqrt(2/D)`` (matching ``core.rff.rff_features``);
    PRF: an all-ones column mask (PRF carries its ``1/sqrt(D)`` inside).
    """
    if feature_kind == "prf":
        return jnp.ones((dfeat,), jnp.float32)
    return jnp.broadcast_to(
        jnp.sqrt(2.0 / dfeat).astype(jnp.float32), (dfeat,)
    )


def rff_attention_ref(phi_q, phi_k, v, normalize=True, eps=1e-6):
    """Quadratic-form causal kernel attention — oracle for rff_attention.

    o_t = sum_{s<=t} (phi_q_t . phi_k_s) v_s [/ normalizer]. Shapes as the
    kernel: (BH, S, D), (BH, S, D), (BH, S, dv).
    """
    s = phi_q.shape[1]
    a = jnp.einsum("btd,bsd->bts", phi_q, phi_k)
    mask = jnp.tril(jnp.ones((s, s), a.dtype))
    a = a * mask[None]
    out = jnp.einsum("bts,bsv->btv", a, v)
    if normalize:
        denom = jnp.sum(a, axis=-1, keepdims=True)
        out = out / (denom + eps)
    return out


def rff_attention_state_ref(phi_q, phi_k, v, normalize=True, eps=1e-6):
    """Same computation via the fixed-size running state (recurrent oracle).

    Returns (outputs, final_S (BH, D, dv), final_z (BH, D)) — validates the
    state semantics the decode path relies on.
    """
    import jax

    def per_head(q, k, vv):
        def body(carry, qkv):
            s_state, z_state = carry
            qt, kt, vt = qkv
            s_state = s_state + jnp.outer(kt, vt)
            z_state = z_state + kt
            num = qt @ s_state
            if normalize:
                num = num / (qt @ z_state + eps)
            return (s_state, z_state), num

        init = (
            jnp.zeros((q.shape[-1], vv.shape[-1]), jnp.float32),
            jnp.zeros((q.shape[-1],), jnp.float32),
        )
        (s_f, z_f), outs = jax.lax.scan(
            body,
            init,
            (
                q.astype(jnp.float32),
                k.astype(jnp.float32),
                vv.astype(jnp.float32),
            ),
        )
        return outs.astype(q.dtype), s_f, z_f

    import jax as _jax

    return _jax.vmap(per_head)(phi_q, phi_k, v)


def rff_attention_decode_block_ref(
    s_state,
    z_state,
    q,
    k,
    v,
    w,
    b,
    s=None,
    *,
    feature_kind="prf",
    normalize=True,
    eps=1e-6,
    precision=None,
):
    """Scan-of-tick oracle for the fused decode-block kernel.

    A block of T pre-projected decode tokens advances the fixed-size
    attention state exactly like T ``ops.rff_attention_decode`` calls:
    the whole block featurizes in one GEMM (:func:`decode_features_ref`,
    under the precision contract), then each token applies the
    update-then-emit tick

        S += phi_k v^T;  z += phi_k;  o = phi_q S [/ (phi_q . z + eps)]

    in f32 regardless of feature storage precision (state never drops
    precision).

    Args:
      s_state: ``(BH, D, dv)`` f32 running sum of phi(k) v^T.
      z_state: ``(BH, D)`` f32 running sum of phi(k).
      q, k: ``(BH, T, dh)`` pre-projected (RoPE'd, pre-scaled) tokens.
      v: ``(BH, T, dv)`` values.
      w: ``(dh, D)`` shared spectral matrix; b ``(D,)`` phases (trig only).
      s: ``(D,)`` per-feature scales; None = trig ``sqrt(2/D)`` / prf ones.

    Returns:
      (outputs ``(BH, T, dv)`` f32, new_s, new_z).
    """
    import jax

    if s is None:
        s = default_decode_scale(w.shape[-1], feature_kind)
    phi_q = decode_features_ref(q, w, b, s, feature_kind, precision)
    phi_k = decode_features_ref(k, w, b, s, feature_kind, precision)
    phi_q = phi_q.astype(jnp.float32)
    phi_k = phi_k.astype(jnp.float32)
    v32 = v.astype(jnp.float32)

    def tick(carry, qkv):
        s_st, z_st = carry
        qt, kt, vt = qkv  # (BH, D), (BH, D), (BH, dv)
        s_st = s_st + kt[:, :, None] * vt[:, None, :]
        z_st = z_st + kt
        num = jnp.einsum("bd,bdv->bv", qt, s_st)
        if normalize:
            den = jnp.sum(qt * z_st, axis=-1) + eps
            num = num / den[:, None]
        return (s_st, z_st), num

    qt_ = jnp.swapaxes(phi_q, 0, 1)  # (T, BH, D) time-major
    kt_ = jnp.swapaxes(phi_k, 0, 1)
    vt_ = jnp.swapaxes(v32, 0, 1)
    (s_f, z_f), outs = jax.lax.scan(
        tick,
        (s_state.astype(jnp.float32), z_state.astype(jnp.float32)),
        (qt_, kt_, vt_),
    )
    return jnp.swapaxes(outs, 0, 1), s_f, z_f


def flash_attention_ref(q, k, v, causal=True):
    """Exact softmax attention — oracle for kernels/flash_attention.py.

    q, k: (BH, S, dh); v: (BH, S, dv).
    """
    dh = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * dh**-0.5
    if causal:
        n = q.shape[1]
        mask = jnp.tril(jnp.ones((n, n), bool))
        s = jnp.where(mask[None], s, -1e30)
    import jax

    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqk,bkv->bqv", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
