"""Pallas TPU kernels: time-blocked chunk-element builders for the
parallel-in-time replay engine (core/scan.py).

The replay scan needs, per time chunk of Tc ticks, ONE composed element:

  * KLMS — the affine map ``theta -> A theta + v`` of the whole chunk,
    where one tick contributes ``A_t = I - mu z_t z_t^T``, ``v_t = mu y_t
    z_t`` and the chunk element is ``A = A_Tc ... A_1`` (and the matching
    folded offset).
  * KRLS (information form) — ``(g, Phi_add, r_add)`` with per-tick
    contribution ``(beta, z z^T, y z)`` under scalar-gated accumulation.

Building these naively as Tc (D, D) matmuls costs O(Tc D^3); these kernels
exploit that every tick is a RANK-1 perturbation of the running element, so
each tick folds into the resident accumulator with O(D^2) work:

  KLMS:  row = z A            (one MXU matvec against the resident tile)
         A  <- A - mu_eff * z^T row        (rank-1 downdate)
         v  <- v - mu_eff * ((z . v) - y) * z
  KRLS:  g   <- beta g
         Phi <- beta Phi + z z^T
         r   <- beta r + y z

TPU mapping reuses the chunk kernels' scratch-residency pattern
(rff_klms_step.py / rff_krls_step.py): grid ``(nc, Tc)`` with the tick axis
minor, the (D, D) accumulator lives in VMEM scratch — seeded to the algebra
identity at ``t == 0`` via ``pl.when``, updated in place for all Tc ticks,
written to HBM once at ``t == Tc - 1``. Element traffic is one (D, D) write
per CHUNK instead of per tick; ``W``/``b``/``s`` are grid-invariant and
fetched once per launch.

Masking: a masked tick multiplies its update by exactly 0 (KLMS
``mu_eff = 0``; KRLS ``beta_eff = 1``, contribution gate 0), so the padded
remainder of the last chunk composes the identity — same contract as the
chunked run-loops. Padded-D columns have zero scale so ``z`` is exactly 0
there: the KLMS accumulator keeps its identity diagonal and the KRLS
accumulator stays 0 in the padded block, and the wrappers slice both back
to the true D.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.rff_features import _ceil_to, _pad2

__all__ = [
    "rff_klms_elements_kernel",
    "rff_klms_chunk_elements_pallas",
    "rff_krls_elements_kernel",
    "rff_krls_chunk_elements_pallas",
]


def rff_klms_elements_kernel(
    x_ref, w_ref, b_ref, s_ref, y_ref, mu_ref, mask_ref,
    a_out_ref, v_out_ref, a_acc, v_acc, *, normalized: bool, eps: float,
):
    """Grid point (i, t): fold tick t into chunk i's resident (A, v) tiles.

    The identity seed uses a broadcasted iota pair (Mosaic has no
    ``jnp.eye`` lowering for scratch writes). ``row = z A`` must read the
    PRE-update A — both rank-1 folds below consume only old-tile values.
    """
    f32 = jnp.float32
    t = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(t == 0)
    def _seed():
        rows = jax.lax.broadcasted_iota(jnp.int32, a_acc.shape, 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, a_acc.shape, 1)
        a_acc[...] = jnp.where(rows == cols, 1.0, 0.0).astype(f32)
        v_acc[...] = jnp.zeros_like(v_acc)

    proj = jnp.dot(
        x_ref[:, 0, :].astype(f32),
        w_ref[...].astype(f32),
        preferred_element_type=f32,
    ) + b_ref[...].astype(f32)
    z = s_ref[...].astype(f32) * jnp.cos(proj)  # (1, D), VMEM-only
    mu = mu_ref[...].astype(f32)  # (1, 1)
    if normalized:
        mu = mu / (eps + jnp.sum(z * z, axis=1, keepdims=True))
    mu_eff = mask_ref[...].astype(f32) * mu  # (1, 1); masked tick -> 0

    a = a_acc[...]  # (D, D) — resident across the chunk
    # row = z A: contract z's feature dim with A's row dim (MXU matvec).
    row = jax.lax.dot_general(
        z, a, (((1,), (0,)), ((), ())), preferred_element_type=f32
    )  # (1, D)
    # outer(z, row): contract the unit leading dims — an MXU (D,1)@(1,D).
    outer = jax.lax.dot_general(
        z, row, (((0,), (0,)), ((), ())), preferred_element_type=f32
    )  # (D, D)
    a_acc[...] = a - mu_eff * outer

    v = v_acc[...]  # (1, D)
    zdotv = jnp.sum(z * v, axis=1, keepdims=True)  # (1, 1)
    v_acc[...] = v - mu_eff * (zdotv - y_ref[...].astype(f32)) * z

    @pl.when(t == nt - 1)
    def _writeback():
        a_out_ref[0] = a_acc[...].astype(a_out_ref.dtype)
        v_out_ref[...] = v_acc[...].astype(v_out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("normalized", "eps", "interpret")
)
def rff_klms_chunk_elements_pallas(
    xs: jax.Array,
    ys: jax.Array,
    w: jax.Array,
    b: jax.Array,
    mu: jax.Array,
    mask: jax.Array | None = None,
    s: jax.Array | None = None,
    *,
    normalized: bool = False,
    eps: float = 1e-6,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Per-chunk composed KLMS affine elements, one launch for all chunks.

    Args:
      xs: ``(nc, Tc, d)`` time-blocked inputs (kernels/chunking.py layout).
      ys: ``(nc, Tc)`` targets.
      w: ``(d, D)`` shared spectral matrix.
      b: ``(D,)`` shared phases.
      mu: scalar step size (one replayed stream, not a bank sweep).
      mask: optional ``(nc, Tc)`` validity gate (1 = real tick); masked
        ticks compose the identity.
      s: ``(D,)`` per-feature scales; None = Monte-Carlo ``sqrt(2/D)``.
      normalized: NKLMS step sizing ``mu / (eps + ||z||^2)`` — still affine
        because the normalizer depends only on ``z``.

    Returns:
      ``(a (nc, D, D), v (nc, D))`` f32 — chunk c's element maps a state
      entering the chunk to the state leaving it: ``theta -> a theta + v``.
    """
    nc, tlen, d = xs.shape
    dfeat = w.shape[-1]
    assert ys.shape == (nc, tlen)
    assert w.shape == (d, dfeat) and b.shape == (dfeat,)
    if s is None:
        s = jnp.full((dfeat,), float((2.0 / dfeat) ** 0.5), jnp.float32)
    assert s.shape == (dfeat,)
    if mask is None:
        mask = jnp.ones((nc, tlen), jnp.float32)

    dp, np_ = _ceil_to(d, 128), _ceil_to(dfeat, 128)
    xs_p = jnp.pad(xs, ((0, 0), (0, 0), (0, dp - d)))
    w_p = _pad2(w, dp, np_)
    b_p = jnp.pad(b, (0, np_ - dfeat))[None, :]  # (1, Np)
    s_p = jnp.pad(s, (0, np_ - dfeat))[None, :]  # (1, Np), padded scales 0
    mu_p = jnp.broadcast_to(jnp.asarray(mu, jnp.float32), (1, 1))
    mask_p = mask.astype(jnp.float32)

    grid = (nc, tlen)  # t minor: element tiles resident across the chunk
    kernel = functools.partial(
        rff_klms_elements_kernel, normalized=normalized, eps=eps
    )
    a, v = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, dp), lambda i, t: (i, t, 0)),
            pl.BlockSpec((dp, np_), lambda i, t: (0, 0)),  # grid-invariant W
            pl.BlockSpec((1, np_), lambda i, t: (0, 0)),
            pl.BlockSpec((1, np_), lambda i, t: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, t: (i, t)),
            pl.BlockSpec((1, 1), lambda i, t: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, t: (i, t)),
        ],
        out_specs=[
            pl.BlockSpec((1, np_, np_), lambda i, t: (i, 0, 0)),
            pl.BlockSpec((1, np_), lambda i, t: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nc, np_, np_), jnp.float32),
            jax.ShapeDtypeStruct((nc, np_), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((np_, np_), jnp.float32),
            pltpu.VMEM((1, np_), jnp.float32),
        ],
        interpret=interpret,
    )(xs_p, w_p, b_p, s_p, ys, mu_p, mask_p)
    return a[:, :dfeat, :dfeat], v[:, :dfeat]


def rff_krls_elements_kernel(
    x_ref, w_ref, b_ref, s_ref, y_ref, beta_ref, mask_ref,
    g_out_ref, phi_out_ref, r_out_ref, g_acc, phi_acc, r_acc,
):
    """Grid point (i, t): fold tick t into chunk i's resident (g, Phi, r).

    A masked tick must compose the identity ``(1, 0, 0)``: its decay gate
    becomes exactly 1 and its additive contribution exactly 0.
    """
    f32 = jnp.float32
    t = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(t == 0)
    def _seed():
        g_acc[...] = jnp.ones_like(g_acc)
        phi_acc[...] = jnp.zeros_like(phi_acc)
        r_acc[...] = jnp.zeros_like(r_acc)

    proj = jnp.dot(
        x_ref[:, 0, :].astype(f32),
        w_ref[...].astype(f32),
        preferred_element_type=f32,
    ) + b_ref[...].astype(f32)
    z = s_ref[...].astype(f32) * jnp.cos(proj)  # (1, D), VMEM-only
    m = mask_ref[...].astype(f32)  # (1, 1)
    beta_eff = jnp.where(m > 0, beta_ref[...].astype(f32), 1.0)  # (1, 1)

    # outer(z, z): contract the unit leading dims — an MXU (D,1)@(1,D).
    outer = jax.lax.dot_general(
        z, z, (((0,), (0,)), ((), ())), preferred_element_type=f32
    )  # (D, D)
    g_acc[...] = g_acc[...] * beta_eff
    phi_acc[...] = beta_eff * phi_acc[...] + m * outer
    r_acc[...] = beta_eff * r_acc[...] + (m * y_ref[...].astype(f32)) * z

    @pl.when(t == nt - 1)
    def _writeback():
        g_out_ref[...] = g_acc[...].astype(g_out_ref.dtype)
        phi_out_ref[0] = phi_acc[...].astype(phi_out_ref.dtype)
        r_out_ref[...] = r_acc[...].astype(r_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rff_krls_chunk_elements_pallas(
    xs: jax.Array,
    ys: jax.Array,
    w: jax.Array,
    b: jax.Array,
    beta: jax.Array,
    mask: jax.Array | None = None,
    s: jax.Array | None = None,
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-chunk composed KRLS decay elements, one launch for all chunks.

    Args / layout as :func:`rff_klms_chunk_elements_pallas`, ``beta`` the
    scalar forgetting factor.

    Returns:
      ``(g (nc,), phi (nc, D, D), r (nc, D))`` f32 — chunk c's information-
      form element ``(Phi, r) -> (g Phi + phi, g r + r_add)``.
    """
    nc, tlen, d = xs.shape
    dfeat = w.shape[-1]
    assert ys.shape == (nc, tlen)
    assert w.shape == (d, dfeat) and b.shape == (dfeat,)
    if s is None:
        s = jnp.full((dfeat,), float((2.0 / dfeat) ** 0.5), jnp.float32)
    assert s.shape == (dfeat,)
    if mask is None:
        mask = jnp.ones((nc, tlen), jnp.float32)

    dp, np_ = _ceil_to(d, 128), _ceil_to(dfeat, 128)
    xs_p = jnp.pad(xs, ((0, 0), (0, 0), (0, dp - d)))
    w_p = _pad2(w, dp, np_)
    b_p = jnp.pad(b, (0, np_ - dfeat))[None, :]  # (1, Np)
    s_p = jnp.pad(s, (0, np_ - dfeat))[None, :]  # (1, Np), padded scales 0
    beta_p = jnp.broadcast_to(jnp.asarray(beta, jnp.float32), (1, 1))
    mask_p = mask.astype(jnp.float32)

    grid = (nc, tlen)  # t minor: element tiles resident across the chunk
    g, phi, r = pl.pallas_call(
        rff_krls_elements_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, dp), lambda i, t: (i, t, 0)),
            pl.BlockSpec((dp, np_), lambda i, t: (0, 0)),  # grid-invariant W
            pl.BlockSpec((1, np_), lambda i, t: (0, 0)),
            pl.BlockSpec((1, np_), lambda i, t: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, t: (i, t)),
            pl.BlockSpec((1, 1), lambda i, t: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, t: (i, t)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, t: (i, 0)),
            pl.BlockSpec((1, np_, np_), lambda i, t: (i, 0, 0)),
            pl.BlockSpec((1, np_), lambda i, t: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nc, 1), jnp.float32),
            jax.ShapeDtypeStruct((nc, np_, np_), jnp.float32),
            jax.ShapeDtypeStruct((nc, np_), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((np_, np_), jnp.float32),
            pltpu.VMEM((1, np_), jnp.float32),
        ],
        interpret=interpret,
    )(xs_p, w_p, b_p, s_p, ys, beta_p, mask_p)
    return g[:, 0], phi[:, :dfeat, :dfeat], r[:, :dfeat]
