"""Pallas TPU kernel: blocked causal flash attention (online softmax).

The perf-critical hot spot of the full-attention architectures (train/
prefill). Complements the RFF linear-attention kernel: flash keeps the
*exact* softmax kernel at O(S·blk) memory; RFF replaces it with a fixed-size
state. Same VMEM/MXU blocking discipline:

  * grid ``(BH, S/bq, S/bk)`` — kv-block index innermost, so the online-
    softmax running statistics (m, l) and the output accumulator carry in
    VMEM scratch across the minor dimension;
  * q tile (bq, dh) is read once per (bh, qi) and re-used for all kv blocks;
  * causal masking per tile via 2D iota; fully-masked tiles still execute
    (structural roofline cost — Pallas TPU grids are static) but their
    contribution is exactly zero.

VMEM at defaults (bq=bk=256, dh=128, f32): q/k/v tiles 128 KiB each,
acc 128 KiB, scores 256 KiB → < 1 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel", "flash_attention_pallas"]

NEG_INF = -1e30


def flash_attention_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale: float,
    causal: bool, bq: int, bk: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale  # (bq, dh)
    k = k_ref[0].astype(jnp.float32)  # (bk, dh)
    v = v_ref[0].astype(jnp.float32)  # (bk, dv)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
    if causal:
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)

    m_prev = m_ref[...][:, 0]  # (bq,)
    l_prev = l_ref[...][:, 0]
    m_blk = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_blk)
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new[:, None]
    l_ref[...] = l_new[:, None]

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...][:, 0], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_k", "causal", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    block_q: int = 256,
    block_k: int = 256,
    causal: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """Exact softmax attention, blocked. Shapes ``(BH, S, dh)`` (MHA layout:
    repeat GQA kv to full heads upstream, like the model layer does).
    """
    bh, s, dh = q.shape
    dv = v.shape[-1]
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    scale = dh**-0.5
    grid = (bh, s // bq, s // bk)
    return pl.pallas_call(
        functools.partial(
            flash_attention_kernel, scale=scale, causal=causal, bq=bq, bk=bk
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),  # running max
            pltpu.VMEM((bq, 1), jnp.float32),  # running denominator
            pltpu.VMEM((bq, dv), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
