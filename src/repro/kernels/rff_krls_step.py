"""Pallas TPU kernel: fully-fused RFF-KRLS (EW-RLS) step for a bank of B
tenants — the KRLS analogue of kernels/rff_klms_step.py.

Per tenant, the paper's §6 recursion on RFF-mapped data:

    z     = sqrt(2/D) cos(W^T x + b)        (feature map, O(D d))
    y_hat = theta^T z                        (predict)
    e     = y - y_hat                        (prior error)
    pz    = P z                              (O(D^2) matvec)
    denom = beta + z^T pz
    g     = pz / denom
    theta <- theta + g e
    P     <- (P - g pz^T) / beta             (rank-1 downdate)

Run two-pass (feature kernel, then the RLS update over a ``(B, D, D)``
batched matvec) this reads ``P`` from HBM twice and round-trips the ``(B,
D)`` activations ``z`` and ``pz``. Fused, each grid step owns ONE tenant
end-to-end: its ``(D, D)`` P tile is read once, the matvec, gain, theta
update and outer-product downdate all happen on that VMEM tile, and only the
updated P/theta go back out — per-tick HBM traffic drops from ~4 B D^2 reads
+ 2 B D^2 writes to the structural minimum of one read + one write of P.

TPU mapping:
  * grid over the bank axis B, one tenant per grid step (its full
    ``(D, D)`` P block — VMEM budget 2 * D^2 * 4 bytes, e.g. D=1024 = 8 MiB;
    tenants needing larger D belong to the sharded path in core/krls.py);
  * ``W (d, D)`` and ``b`` are grid-invariant (index_map pinned to block 0),
    fetched once and re-used across the bank;
  * the matvec ``z P^T``, the outer product ``g^T pz`` and the projection
    ``x W`` run on the MXU via ``dot_general``; cos / scalar work is VPU.

Padding (all exact): padded d columns add 0 to the projection; padded D
columns produce garbage z but every padded row/column of the *input* P and
theta is zero, so pz, denom, gain, the downdate and the prediction are
untouched in the real region and stay exactly zero in the padded region
(the wrapper slices them off).

``beta`` is an array ``(B,)`` — per-tenant forgetting factors (the
hyperparameter-sweep axis) — broadcast from a scalar by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.rff_features import _ceil_to, _pad2

__all__ = [
    "rff_krls_step_kernel",
    "rff_krls_bank_step_pallas",
    "rff_krls_chunk_kernel",
    "rff_krls_bank_chunk_pallas",
]


def rff_krls_step_kernel(
    x_ref, w_ref, b_ref, s_ref, theta_ref, p_ref, y_ref, beta_ref,
    theta_out_ref, p_out_ref, pred_ref, err_ref
):
    """One tenant: featurize, predict, full RLS downdate — all in VMEM.

    ``s`` is the per-feature scale row of the canonical affine-trig form
    (repro.features) — zero in padded-D columns, so padded z is exactly 0.
    """
    f32 = jnp.float32
    proj = jnp.dot(
        x_ref[...].astype(f32),
        w_ref[...].astype(f32),
        preferred_element_type=f32,
    ) + b_ref[...].astype(f32)
    z = s_ref[...].astype(f32) * jnp.cos(proj)  # (1, D), VMEM-only
    theta = theta_ref[...].astype(f32)  # (1, D)
    pred = jnp.sum(theta * z, axis=1, keepdims=True)  # (1, 1)
    err = y_ref[...].astype(f32) - pred
    beta = beta_ref[...].astype(f32)  # (1, 1)

    p = p_ref[0].astype(f32)  # (D, D)
    # pz[j] = sum_k P[j, k] z[k] — contract z's feature dim with P's column
    # dim; stays a (1, D) row so no relayout is needed.
    pz = jax.lax.dot_general(
        z, p, (((1,), (1,)), ((), ())), preferred_element_type=f32
    )  # (1, D)
    denom = beta + jnp.sum(z * pz, axis=1, keepdims=True)  # (1, 1)
    gain = pz / denom  # (1, D)
    theta_out_ref[...] = (theta + gain * err).astype(theta_out_ref.dtype)

    # outer(g, pz): contract the unit leading dims — an MXU (D,1)@(1,D).
    outer = jax.lax.dot_general(
        gain, pz, (((0,), (0,)), ((), ())), preferred_element_type=f32
    )  # (D, D)
    p_new = (p - outer) / beta
    # Same numerical hygiene as the dense path: symmetrize to fight drift.
    p_new = 0.5 * (p_new + p_new.T)
    p_out_ref[0] = p_new.astype(p_out_ref.dtype)
    pred_ref[...] = pred.astype(pred_ref.dtype)
    err_ref[...] = err.astype(err_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rff_krls_bank_step_pallas(
    theta: jax.Array,
    pmat: jax.Array,
    x: jax.Array,
    y: jax.Array,
    w: jax.Array,
    b: jax.Array,
    beta: jax.Array,
    s: jax.Array | None = None,
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused EW-RLS step for B independent tenants sharing one feature map.

    Args:
      theta: ``(B, D)`` per-tenant solutions.
      pmat: ``(B, D, D)`` per-tenant inverse-correlation estimates.
      x: ``(B, d)`` one input sample per tenant/stream.
      y: ``(B,)`` targets.
      w: ``(d, D)`` shared spectral matrix.
      b: ``(D,)`` shared phases.
      beta: scalar or ``(B,)`` per-tenant forgetting factors.
      s: ``(D,)`` shared per-feature scales; None = Monte-Carlo
         ``sqrt(2/D)``.

    Returns:
      (theta_new ``(B, D)``, pmat_new ``(B, D, D)``, predictions ``(B,)``,
      prior errors ``(B,)``).
    """
    bsz, dfeat = theta.shape
    d = x.shape[-1]
    assert pmat.shape == (bsz, dfeat, dfeat)
    assert x.shape == (bsz, d) and y.shape == (bsz,)
    assert w.shape == (d, dfeat) and b.shape == (dfeat,)
    if s is None:
        s = jnp.full((dfeat,), float((2.0 / dfeat) ** 0.5), jnp.float32)
    assert s.shape == (dfeat,)

    dp, np_ = _ceil_to(d, 128), _ceil_to(dfeat, 128)
    beta_col = jnp.broadcast_to(jnp.asarray(beta, theta.dtype), (bsz,))

    theta_p = _pad2(theta, bsz, np_)
    p_p = jnp.pad(
        pmat, ((0, 0), (0, np_ - dfeat), (0, np_ - dfeat))
    )
    x_p = _pad2(x, bsz, dp)
    y_p = y[:, None]  # (B, 1)
    beta_p = beta_col[:, None]
    w_p = _pad2(w, dp, np_)
    b_p = jnp.pad(b, (0, np_ - dfeat))[None, :]  # (1, Np)
    s_p = jnp.pad(s, (0, np_ - dfeat))[None, :]  # (1, Np), padded scales 0

    grid = (bsz,)
    theta_new, p_new, pred, err = pl.pallas_call(
        rff_krls_step_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, dp), lambda i: (i, 0)),
            pl.BlockSpec((dp, np_), lambda i: (0, 0)),  # grid-invariant W
            pl.BlockSpec((1, np_), lambda i: (0, 0)),
            pl.BlockSpec((1, np_), lambda i: (0, 0)),
            pl.BlockSpec((1, np_), lambda i: (i, 0)),
            pl.BlockSpec((1, np_, np_), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, np_), lambda i: (i, 0)),
            pl.BlockSpec((1, np_, np_), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, np_), theta.dtype),
            jax.ShapeDtypeStruct((bsz, np_, np_), pmat.dtype),
            jax.ShapeDtypeStruct((bsz, 1), theta.dtype),
            jax.ShapeDtypeStruct((bsz, 1), theta.dtype),
        ],
        interpret=interpret,
    )(x_p, w_p, b_p, s_p, theta_p, p_p, y_p, beta_p)
    return (
        theta_new[:, :dfeat],
        p_new[:, :dfeat, :dfeat],
        pred[:, 0],
        err[:, 0],
    )


# ---------------------------------------------------------------------------
# Time-blocked (chunked) variant: T RLS ticks per Pallas launch.
#
# The dominant HBM cost of the per-tick kernel is the (D, D) P tile: one
# read + one write per tick (8*D^2 bytes at f32 — 8 MiB/tick at D=1024).
# The chunk kernel runs a (B, T) grid with T minor and carries each tenant's
# theta/P in VMEM *scratch* accumulators (the rff_features K-loop device):
# seeded from HBM at t == 0, downdated in place for all T ticks, written
# back once at t == T-1 — P traffic per tick drops by the full factor T,
# which is exactly the paper's fixed-size-state dividend (no dictionary
# growth means the T-step replay needs zero extra bookkeeping).
# ---------------------------------------------------------------------------


def rff_krls_chunk_kernel(
    x_ref, w_ref, b_ref, s_ref, theta_ref, p_ref, y_ref, beta_ref, mask_ref,
    theta_out_ref, p_out_ref, pred_ref, err_ref, th_acc, p_acc
):
    """Grid point (i, t): tick t for tenant i on the resident theta/P tiles.

    ``mask`` gates the state update only (masked ticks emit predictions but
    change nothing); with mask==1 each tick is the per-tick kernel verbatim.
    Padded-D columns of z are exactly zero (zero-padded scale row ``s``), so
    the resident P never accumulates garbage outside the true D block.
    """
    f32 = jnp.float32
    t = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(t == 0)
    def _seed():
        th_acc[...] = theta_ref[...].astype(f32)
        p_acc[...] = p_ref[0].astype(f32)

    proj = jnp.dot(
        x_ref[:, 0, :].astype(f32),
        w_ref[...].astype(f32),
        preferred_element_type=f32,
    ) + b_ref[...].astype(f32)
    z = s_ref[...].astype(f32) * jnp.cos(proj)  # (1, D), VMEM-only
    theta = th_acc[...]  # (1, D)
    pred = jnp.sum(theta * z, axis=1, keepdims=True)  # (1, 1)
    err = y_ref[...].astype(f32) - pred
    beta = beta_ref[...].astype(f32)  # (1, 1)
    m = mask_ref[...].astype(f32)  # (1, 1)

    p = p_acc[...]  # (D, D) — resident across the chunk
    pz = jax.lax.dot_general(
        z, p, (((1,), (1,)), ((), ())), preferred_element_type=f32
    )  # (1, D)
    denom = beta + jnp.sum(z * pz, axis=1, keepdims=True)  # (1, 1)
    gain = pz / denom  # (1, D)
    th_acc[...] = theta + gain * (m * err)

    outer = jax.lax.dot_general(
        gain, pz, (((0,), (0,)), ((), ())), preferred_element_type=f32
    )  # (D, D)
    p_new = (p - outer) / beta
    p_new = 0.5 * (p_new + p_new.T)
    p_acc[...] = jnp.where(m[0, 0] > 0, p_new, p)
    pred_ref[...] = pred.astype(pred_ref.dtype)
    err_ref[...] = err.astype(err_ref.dtype)

    @pl.when(t == nt - 1)
    def _writeback():
        theta_out_ref[...] = th_acc[...].astype(theta_out_ref.dtype)
        p_out_ref[0] = p_acc[...].astype(p_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rff_krls_bank_chunk_pallas(
    theta: jax.Array,
    pmat: jax.Array,
    xs: jax.Array,
    ys: jax.Array,
    w: jax.Array,
    b: jax.Array,
    beta: jax.Array,
    mask: jax.Array | None = None,
    s: jax.Array | None = None,
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """T-chunked fused EW-RLS: one launch advances every tenant by T ticks.

    Args:
      theta: ``(B, D)`` per-tenant solutions.
      pmat: ``(B, D, D)`` per-tenant inverse-correlation estimates.
      xs: ``(B, T, d)`` T samples per tenant/stream.
      ys: ``(B, T)`` targets.
      w: ``(d, D)`` shared spectral matrix.
      b: ``(D,)`` shared phases.
      beta: scalar or ``(B,)`` per-tenant forgetting factors.
      mask: optional ``(B, T)`` validity gate (1 = apply the update).
      s: ``(D,)`` shared per-feature scales; None = Monte-Carlo
         ``sqrt(2/D)``.

    Returns:
      (theta_new ``(B, D)``, pmat_new ``(B, D, D)``, predictions ``(B, T)``,
      prior errors ``(B, T)``).
    """
    bsz, tlen, d = xs.shape
    dfeat = theta.shape[-1]
    assert theta.shape == (bsz, dfeat)
    assert pmat.shape == (bsz, dfeat, dfeat) and ys.shape == (bsz, tlen)
    assert w.shape == (d, dfeat) and b.shape == (dfeat,)
    if s is None:
        s = jnp.full((dfeat,), float((2.0 / dfeat) ** 0.5), jnp.float32)
    assert s.shape == (dfeat,)

    dp, np_ = _ceil_to(d, 128), _ceil_to(dfeat, 128)
    beta_col = jnp.broadcast_to(jnp.asarray(beta, theta.dtype), (bsz,))
    if mask is None:
        mask = jnp.ones((bsz, tlen), theta.dtype)

    theta_p = _pad2(theta, bsz, np_)
    p_p = jnp.pad(pmat, ((0, 0), (0, np_ - dfeat), (0, np_ - dfeat)))
    xs_p = jnp.pad(xs, ((0, 0), (0, 0), (0, dp - d)))
    beta_p = beta_col[:, None]
    mask_p = mask.astype(theta.dtype)
    w_p = _pad2(w, dp, np_)
    b_p = jnp.pad(b, (0, np_ - dfeat))[None, :]  # (1, Np)
    s_p = jnp.pad(s, (0, np_ - dfeat))[None, :]  # (1, Np), padded scales 0

    grid = (bsz, tlen)  # t minor: theta/P tiles resident across the chunk
    theta_new, p_new, pred, err = pl.pallas_call(
        rff_krls_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, dp), lambda i, t: (i, t, 0)),
            pl.BlockSpec((dp, np_), lambda i, t: (0, 0)),  # grid-invariant W
            pl.BlockSpec((1, np_), lambda i, t: (0, 0)),
            pl.BlockSpec((1, np_), lambda i, t: (0, 0)),
            pl.BlockSpec((1, np_), lambda i, t: (i, 0)),
            pl.BlockSpec((1, np_, np_), lambda i, t: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, t: (i, t)),
            pl.BlockSpec((1, 1), lambda i, t: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, t: (i, t)),
        ],
        out_specs=[
            pl.BlockSpec((1, np_), lambda i, t: (i, 0)),  # revisited over t
            pl.BlockSpec((1, np_, np_), lambda i, t: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, t: (i, t)),
            pl.BlockSpec((1, 1), lambda i, t: (i, t)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, np_), theta.dtype),
            jax.ShapeDtypeStruct((bsz, np_, np_), pmat.dtype),
            jax.ShapeDtypeStruct((bsz, tlen), theta.dtype),
            jax.ShapeDtypeStruct((bsz, tlen), theta.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, np_), jnp.float32),
            pltpu.VMEM((np_, np_), jnp.float32),
        ],
        interpret=interpret,
    )(xs_p, w_p, b_p, s_p, theta_p, p_p, ys, beta_p, mask_p)
    return (
        theta_new[:, :dfeat],
        p_new[:, :dfeat, :dfeat],
        pred,
        err,
    )
